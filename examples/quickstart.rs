//! Quickstart: the library in ~60 lines.
//!
//! Generate a matrix, classify its sparsity pattern, predict attainable
//! performance from the matching sparsity-aware roofline model, run
//! SpMM on all native kernels, and compare measured vs predicted.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use spmm_roofline::gen::{chung_lu, ChungLuParams, Prng};
use spmm_roofline::harness::measure_kernel;
use spmm_roofline::membench;
use spmm_roofline::model::{AiParams, Roofline};
use spmm_roofline::pattern::classify;
use spmm_roofline::spmm::{build_native, Impl};

fn main() -> spmm_roofline::Result<()> {
    // 1. a scale-free graph, like the GNN workloads in the paper's intro
    let mut rng = Prng::new(7);
    let a = chung_lu(
        ChungLuParams { n: 30_000, alpha: 2.3, avg_deg: 16.0, k_min: 4.0 },
        &mut rng,
    );
    println!("matrix: {}x{}, {} nonzeros", a.nrows, a.ncols, a.nnz());

    // 2. classify the sparsity pattern (no provenance needed)
    let cls = classify(&a);
    println!("pattern: {} — {}", cls.class, cls.rationale);

    // 3. calibrate this machine's roofline (STREAM β + FMA π)
    let machine = membench::measure_machine(1);
    let roofline = Roofline::new(machine);
    println!("machine: β={:.1} GB/s, π={:.0} GFLOP/s", machine.beta_gbs, machine.pi_gflops);

    // 4. the sparsity-aware model's attainable performance per width
    let d = 16;
    let ai = cls.model.ai(AiParams::new(a.nrows, d, a.nnz()));
    let roof = roofline.attainable_gflops(ai);
    println!("model: AI={ai:.4} FLOP/byte → attainable {roof:.2} GFLOP/s at d={d}");

    // 5. measure every native kernel against that roof
    for im in Impl::NATIVE {
        let kernel = build_native(im, &a, 1)?;
        let m = measure_kernel(kernel.as_ref(), d, 3, 1)?;
        println!(
            "  {im}: {:.2} GFLOP/s  ({:.0}% of the {} roof)",
            m.gflops,
            100.0 * m.gflops / roof,
            cls.class
        );
    }
    println!(
        "note: ELL pads every row to the longest ({} slots) — hub rows make \n\
         padded formats pathological on scale-free matrices, which is why the \n\
         engine never routes them there.",
        a.max_row_len()
    );
    Ok(())
}
