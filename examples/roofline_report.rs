//! **End-to-end driver** (deliverable (b)/DESIGN.md): runs the paper's
//! entire evaluation pipeline on the proxy dataset and emits every
//! artifact — Table III/IV analogs, the Table V grid, Fig. 1 and
//! Fig. 2 SVGs, the AI-model validation, and the engine's
//! routing/prediction report — into `results/`.
//!
//! This is the run recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example roofline_report [scale]
//! ```

use spmm_roofline::config::ExperimentConfig;
use spmm_roofline::coordinator::{Engine, EngineConfig, JobSpec};
use spmm_roofline::gen::{proxy_suite, representative_suite};
use spmm_roofline::harness;
use spmm_roofline::report::{probe_system, Table};
use spmm_roofline::spmm::Impl;

fn main() -> spmm_roofline::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.25);
    let cfg = ExperimentConfig { scale, iters: 3, warmup: 1, ..Default::default() };
    std::fs::create_dir_all(&cfg.out_dir)?;
    let mut md = String::new();

    // ---- Table IV analog: the machine ------------------------------
    println!("== calibrating machine (STREAM + FMA) ==");
    let machine = harness::machine_params_cached(cfg.threads);
    let sys_table = probe_system().to_table(Some(machine));
    println!("{}", sys_table.to_text());
    md.push_str(&sys_table.to_markdown());

    // ---- Table III analog: the dataset ------------------------------
    let mut t3 = Table::new(
        format!("Table III analog — proxy dataset (scale {scale})"),
        &["Pattern", "Proxy", "Paper matrix", "Rows", "Nonzeros", "nnz/row"],
    );
    for p in proxy_suite() {
        let m = p.generate(cfg.scale);
        t3.row(vec![
            p.class.to_string(),
            p.name.into(),
            p.paper_name.into(),
            m.nrows.to_string(),
            m.nnz().to_string(),
            format!("{:.2}", m.avg_row_len()),
        ]);
    }
    println!("{}", t3.to_text());
    md.push_str(&t3.to_markdown());

    // ---- Table V -----------------------------------------------------
    println!("== Table V sweep (12 matrices × 3 impls × 4 widths) ==");
    let tv = harness::run_table_v(&cfg)?;
    println!("{}", tv.render(&cfg).to_text());
    md.push_str(&tv.render(&cfg).to_markdown());
    tv.save_csv(&format!("{}/table_v.csv", cfg.out_dir))?;
    for (desc, ok) in tv.shape_checks(&cfg) {
        println!("  [{}] {desc}", if ok { "PASS" } else { "FAIL" });
        md.push_str(&format!("- [{}] {desc}\n", if ok { "x" } else { " " }));
    }

    // ---- Fig. 1 -------------------------------------------------------
    println!("\n== Fig. 1 sweep ==");
    let f1 = harness::run_fig1(&cfg)?;
    println!("{}", f1.render().to_text());
    f1.save_svgs(&cfg.out_dir)?;
    f1.save_csv(&format!("{}/fig1.csv", cfg.out_dir))?;
    md.push_str(&f1.render().to_markdown());

    // ---- Fig. 2 -------------------------------------------------------
    println!("== Fig. 2 roofline overlays ==");
    let f2 = harness::run_fig2(&cfg, Some(machine))?;
    println!("{}", f2.render().to_text());
    f2.save_svgs(&cfg.out_dir)?;
    f2.save_csv(&format!("{}/fig2.csv", cfg.out_dir))?;
    for (desc, ok) in f2.shape_checks() {
        println!("  [{}] {desc}", if ok { "PASS" } else { "FAIL" });
        md.push_str(&format!("- [{}] {desc}\n", if ok { "x" } else { " " }));
    }

    // ---- V1: model vs simulated traffic -------------------------------
    println!("\n== V1: AI models vs simulated DRAM traffic ==");
    let mut small = cfg.clone();
    small.scale = (scale / 8.0).max(0.005);
    let rows = harness::run_validate_ai(&small)?;
    let vt = harness::validate::render(&rows);
    println!("{}", vt.to_text());
    md.push_str(&vt.to_markdown());
    harness::validate::save_csv(&rows, &format!("{}/validate_ai.csv", cfg.out_dir))?;

    // ---- the engine: classify → predict → route ------------------------
    println!("== roofline-guided engine (with XLA backend if artifacts exist) ==");
    let mut engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: Some(machine),
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb, Impl::Ell],
        artifacts_dir: Some(cfg.artifacts_dir.clone()),
        ..EngineConfig::default()
    })?;
    println!("xla backend: {}", if engine.has_xla() { "loaded" } else { "absent (run `make artifacts`)" });
    for proxy in representative_suite() {
        engine.register(proxy.name, proxy.generate(cfg.scale))?;
    }
    let mut te = Table::new(
        "Engine routing (auto-selected kernel per job)",
        &["Matrix", "Class", "d", "Routed", "Pred GF/s", "Meas GF/s", "Ratio"],
    );
    let names: Vec<String> = engine.registry().names().iter().map(|s| s.to_string()).collect();
    for name in &names {
        for &d in &cfg.d_values {
            let rec = engine.submit(&JobSpec::new(name.clone(), d))?;
            te.row(vec![
                rec.matrix.clone(),
                rec.class.to_string(),
                d.to_string(),
                rec.chosen.to_string(),
                format!("{:.2}", rec.predicted_gflops),
                format!("{:.2}", rec.measured_gflops),
                format!("{:.2}", rec.prediction_ratio()),
            ]);
        }
    }
    println!("{}", te.to_text());
    md.push_str(&te.to_markdown());
    let rep = engine.prediction_report();
    let summary = format!(
        "engine prediction: n={} geomean(meas/pred)={:.2} mean|ln err|={:.2}\n",
        rep.n_jobs, rep.geomean_ratio, rep.mean_abs_log_err
    );
    println!("{summary}");
    md.push_str(&summary);

    std::fs::write(format!("{}/report.md", cfg.out_dir), md)?;
    println!("full report written to {}/report.md (+ CSVs and SVGs)", cfg.out_dir);
    Ok(())
}
