//! FEM/DFT-style workload: block-Krylov iteration over a banded
//! stiffness-matrix stand-in (Table II's "banded, mesh-local" row).
//!
//! Demonstrates the diagonal roofline model as an upper bound: the
//! banded matrix's measured SpMM lands between the random-model and
//! diagonal-model predictions, and degrading the bandedness (wider
//! band, same nnz) moves it toward the random bound.
//!
//! ```sh
//! cargo run --release --example fem_banded
//! ```

use spmm_roofline::gen::{banded, Prng};
use spmm_roofline::harness::measure_kernel;
use spmm_roofline::membench;
use spmm_roofline::model::{ai_diagonal, ai_random, AiParams, Roofline};
use spmm_roofline::pattern::classify;
use spmm_roofline::spmm::{DenseMatrix, OptSpmm, Spmm};

fn main() -> spmm_roofline::Result<()> {
    let n = 120_000usize;
    let d = 16usize; // block of eigenvector candidates
    let machine = membench::measure_machine(1);
    let roofline = Roofline::new(machine);
    println!("machine: β={:.1} GB/s", machine.beta_gbs);
    println!(
        "{:>10} {:>9} {:>12} {:>12} {:>12} {:>10}",
        "bandwidth", "nnz/row", "AI(diag)", "AI(random)", "meas GF/s", "pos in [R,D]"
    );

    for (bw, fill) in [(4usize, 0.95f64), (16, 0.24), (64, 0.06), (1024, 0.0037)] {
        let mut rng = Prng::new(42);
        let a = banded(n, bw, fill, &mut rng);
        let p = AiParams::new(n, d, a.nnz());
        let (ai_d, ai_r) = (ai_diagonal(p), ai_random(p));
        let (roof_d, roof_r) =
            (roofline.attainable_gflops(ai_d), roofline.attainable_gflops(ai_r));
        let kernel = OptSpmm::new(a.clone(), 1);
        let m = measure_kernel(&kernel, d, 3, 1)?;
        // where the measurement falls between the random (0) and
        // diagonal (1) bounds
        let pos = (m.gflops - roof_r) / (roof_d - roof_r);
        println!(
            "{:>10} {:>9.2} {:>12.4} {:>12.4} {:>12.2} {:>10.2}",
            format!("±{bw}"),
            a.avg_row_len(),
            ai_d,
            ai_r,
            m.gflops,
            pos
        );
    }

    // block-Krylov flavor: Y = A·X repeatedly, checking stability
    let mut rng = Prng::new(43);
    let a = banded(n, 8, 0.45, &mut rng);
    let cls = classify(&a);
    println!("\nKrylov matrix classified as: {} — {}", cls.class, cls.rationale);
    let kernel = OptSpmm::new(a, 1);
    let mut x = DenseMatrix::random(n, d, &mut rng);
    let mut y = DenseMatrix::zeros(n, d);
    for it in 0..5 {
        kernel.execute(&x, &mut y)?;
        let norm = y.frob_norm().max(1e-30);
        for v in y.data.iter_mut() {
            *v /= norm;
        }
        std::mem::swap(&mut x, &mut y);
        println!("  krylov iter {it}: |X| normalized, ok");
    }
    Ok(())
}
