//! Pattern explorer: sweep every generator's knobs and watch the
//! classifier + the AI models respond — a tour of the library's
//! structural-analysis layer.
//!
//! ```sh
//! cargo run --release --example pattern_explorer
//! ```

use spmm_roofline::gen::{
    banded, chung_lu, erdos_renyi, mesh2d, rmat, ChungLuParams, MeshKind, Prng,
};
use spmm_roofline::model::AiParams;
use spmm_roofline::pattern::classify;
use spmm_roofline::report::Table;
use spmm_roofline::sparse::Csr;

fn main() {
    let mut t = Table::new(
        "pattern explorer — generator → classifier → model AI (d=16)",
        &["Generator", "n", "nnz/row", "CV", "Classified", "AI model", "AI@16"],
    );
    let n = 20_000usize;
    let mut add = |name: &str, a: Csr| {
        let cls = classify(&a);
        let ai = cls.model.ai(AiParams::new(a.nrows, 16, a.nnz()));
        t.row(vec![
            name.to_string(),
            a.nrows.to_string(),
            format!("{:.1}", a.avg_row_len()),
            format!("{:.2}", cls.stats.row_len_cv),
            cls.class.to_string(),
            cls.model.name().to_string(),
            format!("{ai:.4}"),
        ]);
    };

    let mut rng = Prng::new(1);
    add("erdos_renyi deg=2", erdos_renyi(n, n, 2.0, &mut rng));
    add("erdos_renyi deg=20", erdos_renyi(n, n, 20.0, &mut rng));
    add("banded bw=4", banded(n, 4, 0.8, &mut rng));
    add("banded bw=32", banded(n, 32, 0.1, &mut rng));
    add("mesh road", mesh2d(141, MeshKind::Road, 0.62, &mut rng));
    add("mesh triangular", mesh2d(141, MeshKind::Triangular, 0.9, &mut rng));
    add("mesh path", mesh2d(141, MeshKind::Path, 0.5, &mut rng));
    add(
        "chung_lu α=2.1",
        chung_lu(ChungLuParams { n, alpha: 2.1, avg_deg: 16.0, k_min: 3.0 }, &mut rng),
    );
    add(
        "chung_lu α=2.9",
        chung_lu(ChungLuParams { n, alpha: 2.9, avg_deg: 16.0, k_min: 3.0 }, &mut rng),
    );
    add("rmat skewed", rmat(14, 12.0, 0.57, 0.19, 0.19, &mut rng));
    add("rmat uniform", rmat(14, 12.0, 0.25, 0.25, 0.25, &mut rng));

    println!("{}", t.to_text());
    println!("Notes:");
    println!("- heavier tails (smaller α, skewed R-MAT) should classify Scale-free;");
    println!("- meshes classify Blocked via tile-local edges, bands classify Diagonal;");
    println!("- the AI column orders exactly as §III predicts: diagonal > blocked/scale-free > random.");
}
