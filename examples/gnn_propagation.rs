//! GNN feature propagation — the workload the paper's introduction
//! leads with (SpMM "supports both forward and backward propagation"
//! in GNNs).
//!
//! Runs `k` rounds of `H ← normalize(A · H)` over a scale-free graph
//! three ways: the engine-routed native kernel, a forced-CSR baseline,
//! and (when `make artifacts` has been run and the shape fits) the
//! AOT-compiled XLA/Pallas path — verifying all three agree
//! numerically and reporting throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example gnn_propagation
//! ```

use spmm_roofline::gen::{erdos_renyi, Prng};
use spmm_roofline::metrics::{gflops, spmm_flops, Timer};
use spmm_roofline::pattern::classify;
use spmm_roofline::runtime::{ArtifactManifest, XlaRuntime, XlaSpmm};
use spmm_roofline::sparse::{Coo, Csr};
use spmm_roofline::spmm::{CsrSpmm, DenseMatrix, OptSpmm, Spmm};

/// Cap row degree so the graph fits the shipped artifact's ELL width.
fn truncate_rows(a: &Csr, width: usize) -> Csr {
    let mut coo = Coo::with_capacity(a.nrows, a.ncols, a.nnz());
    for r in 0..a.nrows {
        for (k, (c, v)) in a.row_cols(r).iter().zip(a.row_vals(r)).enumerate() {
            if k >= width {
                break;
            }
            coo.push(r, *c as usize, *v);
        }
    }
    Csr::from_coo(coo)
}

fn propagate(kernel: &dyn Spmm, h0: &DenseMatrix, rounds: usize) -> (DenseMatrix, f64) {
    let mut h = h0.clone();
    let mut next = DenseMatrix::zeros(h.nrows, h.ncols);
    let t = Timer::start();
    for _ in 0..rounds {
        kernel.execute(&h, &mut next).expect("spmm failed");
        // degree-free normalization keeps values bounded across rounds
        let norm = next.frob_norm().max(1e-30);
        for x in next.data.iter_mut() {
            *x /= norm * 1e-2;
        }
        std::mem::swap(&mut h, &mut next);
    }
    (h, t.elapsed_secs())
}

fn main() -> spmm_roofline::Result<()> {
    // shape matches the shipped artifact set: n=16384, width 16, d=16
    let (n, width, d, rounds) = (16384usize, 16usize, 16usize, 8usize);
    let mut rng = Prng::new(0x61A);
    let graph = truncate_rows(&erdos_renyi(n, n, 10.0, &mut rng), width);
    let cls = classify(&graph);
    println!(
        "graph: n={n} nnz={} — classified {} ({})",
        graph.nnz(),
        cls.class,
        cls.rationale
    );
    let h0 = DenseMatrix::random(n, d, &mut rng);
    let flops = spmm_flops(graph.nnz(), d) * rounds as f64;

    // native paths
    let opt = OptSpmm::new(graph.clone(), 1);
    let (h_opt, secs_opt) = propagate(&opt, &h0, rounds);
    println!("OPT  : {rounds} rounds in {secs_opt:.3}s  ({:.2} GFLOP/s)", gflops(flops, secs_opt));

    let csr = CsrSpmm::new(graph.clone(), 1);
    let (h_csr, secs_csr) = propagate(&csr, &h0, rounds);
    println!("CSR  : {rounds} rounds in {secs_csr:.3}s  ({:.2} GFLOP/s)", gflops(flops, secs_csr));
    let diff = h_opt.max_abs_diff(&h_csr);
    println!("  OPT vs CSR max |Δ| = {diff:.2e}");
    assert!(diff < 1e-9, "native kernels disagree");

    // XLA path (three-layer request path; needs `make artifacts`)
    match ArtifactManifest::load("artifacts") {
        Ok(manifest) => match manifest.find_ell(n, width, d) {
            Some(spec) => {
                let rt = XlaRuntime::cpu()?;
                let xla = XlaSpmm::from_csr(&rt, spec, &graph)?;
                let (h_xla, secs_xla) = propagate(&xla, &h0, rounds);
                println!(
                    "XLA  : {rounds} rounds in {secs_xla:.3}s  ({:.2} GFLOP/s, incl. transfers)",
                    gflops(flops, secs_xla)
                );
                let diff = h_xla.max_abs_diff(&h_csr);
                println!("  XLA vs CSR max |Δ| = {diff:.2e}");
                assert!(diff < 1e-9, "XLA path disagrees with native");
            }
            None => println!("XLA  : no artifact for (n={n}, w={width}, d={d}) — run `make artifacts`"),
        },
        Err(_) => println!("XLA  : artifacts/ missing — run `make artifacts`"),
    }
    println!("all paths agree; propagation done");
    Ok(())
}
