//! # spmm-roofline
//!
//! Reproduction of *"Sparsity-Aware Roofline Models for Sparse
//! Matrix-Matrix Multiplication"* (Qian, Ramadan, Anubha, Azad — CS.DC
//! 2026) as a three-layer Rust + JAX + Pallas stack.
//!
//! The library provides:
//!
//! * **Sparse substrate** ([`sparse`], [`gen`]): COO/CSR/CSC/CSB/ELL
//!   formats, conversions, MatrixMarket IO, and structural generators
//!   (Erdős–Rényi, banded, mesh/blocked, scale-free) that reproduce the
//!   paper's Table III dataset at configurable scale.
//! * **SpGEMM kernels** ([`spgemm`]): sparse×sparse `C = A·B` as a
//!   second workload — a per-row hash/dense-accumulator kernel
//!   ([`spgemm::HashSpGemm`], à la Nagasaka) and a
//!   propagation-blocking merge kernel ([`spgemm::PbMergeSpGemm`], à la
//!   Gu et al.) that reuses the PB column-band binning; both share the
//!   worker pool and the [`spmm::Schedule`] layer, emit sorted
//!   deduplicated CSR, and are routed by the engine per matrix pair
//!   from compression-factor-parameterized traffic models
//!   ([`model::bytes_spgemm_hash`], [`model::bytes_spgemm_pb`]).
//! * **SpMM kernels** ([`spmm`]): row-parallel CSR, a register-blocked
//!   d-specialised "OPT" kernel (the MKL stand-in), block-parallel CSB,
//!   padded ELL, dense-tile BSR, and two-phase propagation-blocking PB
//!   ([`spmm::PbSpmm`]) — all multithreaded over the persistent worker
//!   pool (below), all executing through a precomputed
//!   [`spmm::Schedule`] (nnz-balanced partitions + model-chosen column
//!   tiles + nnz row bins, `spmm/schedule.rs`), and all running their
//!   inner loops through the runtime-dispatched SIMD micro-kernels
//!   ([`spmm::simd`]: scalar/SSE2/AVX, probed once, bitwise-identical
//!   across widths).
//! * **Machine calibration** ([`membench`]): the STREAM port and FMA
//!   peak loop for the flat roofline, plus the per-cache-level
//!   read/write/triad sweep and width-aware peak probe producing a
//!   [`membench::MeasuredLadder`] the planner prefers over its nominal
//!   ladder ([`coordinator::Planner::install_measured`]) — persisted
//!   in the autotune snapshot so restarts skip re-calibration.
//! * **Sparsity-aware roofline models** ([`model`]): the paper's four
//!   arithmetic-intensity formulas (Eqs. 2, 3, 4, 6), the blocked-column
//!   occupancy model `z = t(1-e^{-D/t})`, the scale-free hub-mass
//!   derivation from the appendix, and the structure-*independent*
//!   propagation-blocking traffic model ([`model::bytes_pb`]). Every
//!   formula is derived in prose, with worked examples, in `MODELS.md`.
//! * **Pattern classification** ([`pattern`]): structural statistics
//!   (bandwidth profile, power-law MLE, block fill) that map a matrix to
//!   the roofline model that governs it.
//! * **Cache simulation** ([`cachesim`]): a set-associative LRU
//!   L1/L2/L3+DRAM hierarchy that replays exact SpMM access streams to
//!   *measure* memory traffic against the analytic models.
//! * **A roofline-guided execution engine** ([`coordinator`]): classify →
//!   predict → route each SpMM job to the predicted-best kernel, with
//!   prediction-vs-measurement bookkeeping — including a batched
//!   submission path ([`coordinator::Engine::submit_batch`]) with
//!   recycled dense operands and per-batch aggregate reporting, and a
//!   concurrent serving front-end ([`coordinator::Server`]): a bounded
//!   job queue with explicit admission control, per-tenant matrix
//!   namespaces, same-matrix batch coalescing, contained kernel
//!   panics, and autotune decisions persisted across restarts
//!   ([`report::AutotuneState`]).
//! * **Application pipelines** ([`workloads`], [`coordinator`]): GCN
//!   forward passes, block power iteration, batched PageRank, and
//!   SpGEMM→SpMM chains as first-class multi-op pipelines
//!   ([`coordinator::Engine::submit_pipeline`]) — one cached schedule
//!   and pooled ping-pong intermediates per chain, the whole chain
//!   autotuned end-to-end and pinned per `(matrix, chain)`
//!   ([`coordinator::PipelineKind`]), priced by the inter-op roofline
//!   term ([`model::ai_pipeline`]: a cache-resident intermediate drops
//!   the following op's dense-operand traffic). The standalone
//!   functions ([`workloads::gcn_forward`] and friends) wrap the same
//!   chain cores, so engine-routed results are bitwise-identical to
//!   manual composition.
//! * **Out-of-core execution and corpus harness** ([`sparse::ooc`],
//!   [`harness::corpus`]): a streaming MatrixMarket reader
//!   ([`sparse::mm_io::MmStream`]) that rejects malformed input with
//!   typed errors, row-band planning under a byte budget
//!   ([`sparse::mm_io::plan_row_bands`]), band-by-band SpMM
//!   ([`sparse::OocSpmm`]) that is bitwise-identical to whole-matrix
//!   CSR, the band-pass traffic term ([`model::bytes_ooc`], MODELS.md
//!   §9), and a corpus harness that ingests a directory of `.mtx`
//!   files, classifies each matrix, routes it through the autotuner,
//!   and reports per structure group (`BENCH_corpus.json`).
//! * **XLA/PJRT runtime** ([`runtime`]): loads AOT artifacts produced by
//!   the JAX/Pallas compile path (`python/compile/`) and exposes them as
//!   a fourth SpMM implementation.
//! * **Experiment harness** ([`harness`], [`report`]): regenerates every
//!   table and figure in the paper's evaluation (Table V, Fig. 1, Fig. 2)
//!   plus model-validation and ablation studies.
//!
//! # How the layers hand off
//!
//! One request flows **classify → predict → schedule → route →
//! execute**, each arrow a module boundary:
//!
//! 1. **classify** — [`MatrixRegistry`](coordinator::MatrixRegistry)
//!    registration runs [`pattern::classify()`] once per matrix:
//!    structural statistics pick the sparsity regime and its
//!    parameterised model ([`model::SparsityModel`]).
//! 2. **predict** — the [`Planner`](coordinator::Planner) turns the
//!    classification into per-implementation GFLOP/s predictions:
//!    model AI × bandwidth roof × learned `(class, impl)` prior. The
//!    PB kernel's line is structure-independent ([`model::ai_pb`]), so
//!    it rises and falls *relative to* the structural lines.
//! 3. **schedule** — the prediction's tile width `dt` selects (or
//!    builds, then caches) a [`spmm::Schedule`]: nnz-balanced
//!    partitions plus column tiles, planned once per
//!    `(matrix, impl, threads, d, dt)`.
//! 4. **route** — the [`Engine`](coordinator::Engine) picks the
//!    implementation: predicted-best, or, with autotuning on, the
//!    pinned measured-best across formats × reorderings
//!    ([`coordinator::Autotuner`]).
//! 5. **execute** — the chosen kernel consumes the schedule on the
//!    shared worker pool ([`spmm::Spmm::execute_with`]); the
//!    measurement feeds back into the planner's priors
//!    (`Planner::observe`), closing the loop.
//!
//! # Execution model
//!
//! All parallelism runs on one **persistent worker pool**
//! ([`spmm::pool`]): worker threads are spawned lazily on first use,
//! parked on a condvar between jobs, and shared by every kernel, the
//! STREAM calibration loops, and the cache-simulator batch replay.
//! Steady state spawns zero threads — per-call dispatch wakes only as
//! many workers as the call requests, which keeps high-rate small-SpMM
//! measurements (the regime the engine serves) free of thread-churn
//! noise. Requests beyond the pool size grow it once to that
//! high-water mark (oversubscription stays meaningful). Size it with
//! the `SPMM_POOL_THREADS` env var (`0` forces inline serial
//! execution).
//!
//! # Features
//!
//! The crate is dependency-free and builds offline. The optional `xla`
//! cargo feature compiles the real PJRT client (requires the
//! unvendored `xla` crate); without it a stub reports the backend
//! unavailable and everything runs native-only.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod cachesim;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod gen;
pub mod harness;
pub mod membench;
pub mod metrics;
pub mod model;
pub mod pattern;
pub mod report;
pub mod runtime;
pub mod sparse;
pub mod spgemm;
pub mod spmm;
pub mod testutil;
pub mod workloads;

pub use error::{Error, Result};

/// Bytes per double-precision value (the paper stores all matrix values
/// as f64).
pub const BYTES_VAL: usize = 8;
/// Bytes per sparse index (the paper stores indices as 32-bit integers).
pub const BYTES_IDX: usize = 4;
