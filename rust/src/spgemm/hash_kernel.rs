//! Hash-accumulator SpGEMM — the gathering kernel of the SpGEMM pair,
//! after Nagasaka et al.'s hash SpGEMM (PAPERS.md, arXiv:1804.01698).
//!
//! One pass over `A`'s rows: row `i` of `C` is accumulated by
//! expanding `v · B[k, :]` for every `(k, v)` in row `i` of `A`. The
//! accumulator is chosen **per row** from the row's *upper-bound fill*
//! `ub = Σ_{k ∈ row} |B_k|` (the partial-product count, known before
//! any arithmetic — Nagasaka's symbolic bound):
//!
//! * **dense array** when the row is dense enough that an `O(ncols)`
//!   array beats hashing (`ub ≥ ncols /` [`DENSE_ACCUM_DIVISOR`], or
//!   tiny outputs, ≤ [`DENSE_ACCUM_MIN_COLS`] columns) — epoch-stamped
//!   slots, so resetting costs nothing per row;
//! * **open-addressing hash map** otherwise, sized to the next power
//!   of two ≥ `2·ub` (load factor ≤ ½, probes terminate).
//!
//! Either way each output column's contributions are added in arrival
//! order — ascending `k` — so the two accumulator paths, the other
//! SpGEMM kernel, and [`crate::spgemm::reference_spgemm`] all produce
//! bit-identical values (see the module docs in `spgemm/mod.rs`).
//!
//! Parallelism: schedule partitions over `A`'s rows, claimed
//! dynamically on the shared worker pool. Accumulator scratch is
//! recycled through a pool so adversarial one-row-per-partition
//! schedules do not allocate per row; finished partitions push
//! [`RowSlab`]s that are stitched into the output CSR.

use std::sync::Mutex;

use crate::error::Result;
use crate::sparse::Csr;
use crate::spgemm::{assemble_slabs, check_spgemm_dims, RowSlab, SpGemm, SpGemmImpl};
use crate::spmm::pool::parallel_chunks_dynamic;
use crate::spmm::{check_schedule, Schedule};

/// A row switches from the hash map to the dense accumulator when its
/// upper-bound fill reaches `ncols(C) / DENSE_ACCUM_DIVISOR`: at that
/// density the `O(touched)` dense bookkeeping beats the hash probe's
/// constant factor.
pub const DENSE_ACCUM_DIVISOR: usize = 4;

/// Output widths at or below this always use the dense accumulator —
/// the whole array is smaller than a useful hash table.
pub const DENSE_ACCUM_MIN_COLS: usize = 64;

/// Empty-slot sentinel for the hash table. Valid column indices are
/// `< ncols ≤ u32::MAX` (guarded in `check_spgemm_dims`), so the
/// sentinel cannot collide with a key.
const EMPTY: u32 = u32::MAX;

/// Reusable per-worker accumulation scratch (recycled through a pool
/// across partition claims).
struct Accum {
    /// Dense value slots, grown to the widest output seen.
    dense: Vec<f64>,
    /// Epoch stamp per dense slot (`stamp[j] == epoch` ⇒ live).
    stamp: Vec<u32>,
    epoch: u32,
    /// Live columns of the current dense row.
    touched: Vec<u32>,
    /// Hash keys (columns), [`EMPTY`] when vacant.
    keys: Vec<u32>,
    /// Hash values, parallel to `keys`.
    slot_vals: Vec<f64>,
    /// (column, value) staging for the per-row sort.
    pairs: Vec<(u32, f64)>,
}

impl Accum {
    fn new() -> Accum {
        Accum {
            dense: Vec::new(),
            stamp: Vec::new(),
            epoch: 0,
            touched: Vec::new(),
            keys: Vec::new(),
            slot_vals: Vec::new(),
            pairs: Vec::new(),
        }
    }

    /// Accumulate row `i` of `C = A·B`, appending its sorted,
    /// deduplicated entries to `out_cols`/`out_vals`. Returns the row
    /// length.
    fn row(
        &mut self,
        a: &Csr,
        b: &Csr,
        i: usize,
        ncols: usize,
        out_cols: &mut Vec<u32>,
        out_vals: &mut Vec<f64>,
    ) -> usize {
        let mut ub = 0usize;
        for &k in a.row_cols(i) {
            ub += b.row_len(k as usize);
        }
        if ub == 0 {
            return 0;
        }
        if ncols <= DENSE_ACCUM_MIN_COLS || ub >= ncols / DENSE_ACCUM_DIVISOR {
            self.row_dense(a, b, i, ncols, out_cols, out_vals)
        } else {
            self.row_hash(a, b, i, ub, out_cols, out_vals)
        }
    }

    fn row_dense(
        &mut self,
        a: &Csr,
        b: &Csr,
        i: usize,
        ncols: usize,
        out_cols: &mut Vec<u32>,
        out_vals: &mut Vec<f64>,
    ) -> usize {
        if self.dense.len() < ncols {
            self.dense.resize(ncols, 0.0);
            self.stamp.resize(ncols, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // epoch counter wrapped: stale stamps could alias — reset
            self.stamp.fill(0);
            self.epoch = 1;
        }
        let e = self.epoch;
        self.touched.clear();
        for (&k, &v) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            let kk = k as usize;
            for (&j, &w) in b.row_cols(kk).iter().zip(b.row_vals(kk)) {
                let jj = j as usize;
                if self.stamp[jj] == e {
                    self.dense[jj] += v * w;
                } else {
                    self.stamp[jj] = e;
                    self.dense[jj] = v * w;
                    self.touched.push(j);
                }
            }
        }
        self.touched.sort_unstable();
        for &j in &self.touched {
            out_cols.push(j);
            out_vals.push(self.dense[j as usize]);
        }
        self.touched.len()
    }

    fn row_hash(
        &mut self,
        a: &Csr,
        b: &Csr,
        i: usize,
        ub: usize,
        out_cols: &mut Vec<u32>,
        out_vals: &mut Vec<f64>,
    ) -> usize {
        let cap = (2 * ub).next_power_of_two().max(8);
        if self.keys.len() < cap {
            self.keys.resize(cap, EMPTY);
            self.slot_vals.resize(cap, 0.0);
        }
        self.keys[..cap].fill(EMPTY);
        let mask = cap - 1;
        for (&k, &v) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            let kk = k as usize;
            for (&j, &w) in b.row_cols(kk).iter().zip(b.row_vals(kk)) {
                // Fibonacci mix, then fold the high bits down: the low
                // bits of j·odd alone cluster for banded columns
                let h = j.wrapping_mul(0x9E37_79B9);
                let mut idx = ((h ^ (h >> 16)) as usize) & mask;
                loop {
                    let key = self.keys[idx];
                    if key == j {
                        self.slot_vals[idx] += v * w;
                        break;
                    }
                    if key == EMPTY {
                        self.keys[idx] = j;
                        self.slot_vals[idx] = v * w;
                        break;
                    }
                    idx = (idx + 1) & mask;
                }
            }
        }
        self.pairs.clear();
        for (&k, &v) in self.keys[..cap].iter().zip(&self.slot_vals[..cap]) {
            if k != EMPTY {
                self.pairs.push((k, v));
            }
        }
        // keys are unique, so the unstable sort is deterministic
        self.pairs.sort_unstable_by_key(|p| p.0);
        for &(j, v) in &self.pairs {
            out_cols.push(j);
            out_vals.push(v);
        }
        self.pairs.len()
    }
}

/// Hash-accumulator SpGEMM kernel (see module docs).
pub struct HashSpGemm {
    a: Csr,
    /// Untiled nnz-balanced base schedule over `A`'s rows.
    base: Schedule,
}

impl HashSpGemm {
    /// Wrap a CSR left operand; `threads` workers at execute time.
    pub fn new(a: Csr, threads: usize) -> Self {
        let base = Schedule::nnz_balanced(&a.row_ptr, threads.max(1));
        HashSpGemm { a, base }
    }

    /// Borrow the underlying left operand.
    pub fn matrix(&self) -> &Csr {
        &self.a
    }
}

impl SpGemm for HashSpGemm {
    fn id(&self) -> SpGemmImpl {
        SpGemmImpl::Hash
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }
    fn plan(&self) -> Schedule {
        self.base.clone()
    }

    fn execute(&self, b: &Csr) -> Result<Csr> {
        self.execute_with(b, &self.base)
    }

    fn execute_with(&self, b: &Csr, s: &Schedule) -> Result<Csr> {
        check_spgemm_dims(self.a.nrows, self.a.ncols, b)?;
        check_schedule(self.a.nrows, s)?;
        let ncols = b.ncols;
        let a = &self.a;
        let slabs: Mutex<Vec<RowSlab>> = Mutex::new(Vec::new());
        let scratch: Mutex<Vec<Accum>> = Mutex::new(Vec::new());
        parallel_chunks_dynamic(s.n_parts(), s.threads, 1, |parts| {
            let mut acc = {
                let mut pool = scratch.lock().unwrap_or_else(|e| e.into_inner());
                pool.pop()
            }
            .unwrap_or_else(Accum::new);
            for pi in parts {
                let rows = s.part(pi);
                if rows.is_empty() {
                    continue;
                }
                let mut slab = RowSlab {
                    first_row: rows.start,
                    row_lens: Vec::with_capacity(rows.len()),
                    cols: Vec::new(),
                    vals: Vec::new(),
                };
                for i in rows {
                    let len = acc.row(a, b, i, ncols, &mut slab.cols, &mut slab.vals);
                    slab.row_lens.push(len as u32);
                }
                slabs.lock().unwrap_or_else(|e| e.into_inner()).push(slab);
            }
            scratch.lock().unwrap_or_else(|e| e.into_inner()).push(acc);
        });
        let slabs = slabs.into_inner().unwrap_or_else(|e| e.into_inner());
        Ok(assemble_slabs(self.a.nrows, ncols, slabs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};
    use crate::spgemm::reference_spgemm;

    #[test]
    fn matches_reference_bitwise_various_threads() {
        let mut rng = Prng::new(0x5b0);
        let a = erdos_renyi(200, 200, 6.0, &mut rng);
        let b = erdos_renyi(200, 200, 6.0, &mut rng);
        let want = reference_spgemm(&a, &b);
        for threads in [1usize, 3] {
            let k = HashSpGemm::new(a.clone(), threads);
            let c = k.execute(&b).unwrap();
            c.validate().unwrap();
            assert_eq!(c, want, "threads={threads}");
        }
    }

    #[test]
    fn dense_path_matches_hash_path() {
        // ncols ≤ DENSE_ACCUM_MIN_COLS forces the dense accumulator;
        // a wide B with sparse rows forces the hash map. Both must
        // agree with the reference bitwise.
        let mut rng = Prng::new(0x5b1);
        let a = erdos_renyi(80, 80, 4.0, &mut rng);
        let b_narrow = erdos_renyi(80, DENSE_ACCUM_MIN_COLS, 3.0, &mut rng);
        let b_wide = erdos_renyi(80, 5000, 2.0, &mut rng);
        for b in [&b_narrow, &b_wide] {
            let k = HashSpGemm::new(a.clone(), 2);
            let c = k.execute(b).unwrap();
            c.validate().unwrap();
            assert_eq!(c, reference_spgemm(&a, b));
        }
    }

    #[test]
    fn rectangular_and_degenerate_shapes() {
        let mut rng = Prng::new(0x5b2);
        for (m, k, n) in [(1usize, 1usize, 1usize), (1, 40, 7), (40, 1, 7), (30, 70, 20)] {
            let a = erdos_renyi(m, k, 3.0, &mut rng);
            let b = erdos_renyi(k, n, 3.0, &mut rng);
            let kern = HashSpGemm::new(a.clone(), 2);
            let c = kern.execute(&b).unwrap();
            c.validate().unwrap();
            assert_eq!(c, reference_spgemm(&a, &b), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn empty_operands_give_empty_product() {
        let a = Csr::from_dense(8, 8, &[0.0; 64]);
        let b = Csr::from_dense(8, 8, &[0.0; 64]);
        let k = HashSpGemm::new(a, 2);
        let c = k.execute(&b).unwrap();
        c.validate().unwrap();
        assert_eq!(c.nnz(), 0);
        assert_eq!((c.nrows, c.ncols), (8, 8));
    }

    #[test]
    fn one_row_per_partition_schedule() {
        use crate::spmm::Schedule;
        let mut rng = Prng::new(0x5b3);
        let a = erdos_renyi(16, 16, 4.0, &mut rng);
        let b = erdos_renyi(16, 16, 4.0, &mut rng);
        let k = HashSpGemm::new(a.clone(), 2);
        let s = Schedule::uniform(16, 2);
        assert_eq!(s.n_parts(), 16);
        let c = k.execute_with(&b, &s).unwrap();
        assert_eq!(c, reference_spgemm(&a, &b));
    }

    #[test]
    fn foreign_schedule_rejected() {
        use crate::spmm::Schedule;
        let mut rng = Prng::new(0x5b4);
        let a = erdos_renyi(10, 10, 2.0, &mut rng);
        let b = erdos_renyi(10, 10, 2.0, &mut rng);
        let k = HashSpGemm::new(a, 1);
        let foreign = Schedule::uniform(11, 1);
        assert!(k.execute_with(&b, &foreign).is_err());
    }
}
