//! SpGEMM kernels: `C = A · B` with **both** operands sparse CSR.
//!
//! The paper's thesis — that roofline analysis must be sparsity-aware
//! and per-structure — bites even harder for sparse×sparse
//! multiplication: output fill-in depends on the operands' structure,
//! and the **compression factor** `cf = flops / nnz(C)` (how many
//! partial products collapse onto each stored output) drives the
//! arithmetic intensity. This module opens SpGEMM as the crate's
//! second workload, with two native kernels that mirror the SpMM
//! kernel family's central contrast (gathering vs streaming):
//!
//! | Kernel | Lineage | Strategy |
//! |---|---|---|
//! | [`HashSpGemm`]    | Nagasaka et al. (arXiv:1804.01698) | per-row accumulator, dense array or hash map chosen per row by upper-bound fill |
//! | [`PbMergeSpGemm`] | Gu et al. (arXiv:2002.11302)       | propagation-blocking merge: spill partial products by column band, merge per destination bucket |
//!
//! Both parallelise over the shared worker pool ([`crate::spmm::pool`])
//! and consume the same nnz-balanced [`Schedule`] the SpMM kernels use
//! (partitions over `A`'s rows; column tiles do not apply to a sparse
//! right operand and are ignored). Both emit **sorted, deduplicated**
//! CSR that passes [`Csr::validate`].
//!
//! **Accumulation order.** Every kernel here — and
//! [`reference_spgemm`] — accumulates each `C[i, j]` in ascending-`k`
//! order (the order row `i` of `A` stores its entries): the hash and
//! dense accumulators add contributions on arrival, and the merge
//! kernel's bucket streams arrive band-ascending with a *stable*
//! per-row sort, which preserves the same arrival order per output
//! column. The kernels therefore agree **bit for bit** with each other
//! and with the reference, which `tests/prop_spgemm.rs` pins across
//! every structural generator.
//!
//! **Hand-off**: the coordinator routes SpGEMM jobs exactly like SpMM
//! ones — classify `A`, predict per kernel from the cf-parameterized
//! traffic models ([`crate::model::bytes_spgemm_hash`],
//! [`crate::model::bytes_spgemm_pb`], derived in `MODELS.md` §6),
//! explore/measure under autotune, and pin a winner per matrix pair
//! ([`crate::coordinator::Autotuner::tune_spgemm`]).

mod hash_kernel;
mod pb_merge;

pub use hash_kernel::{HashSpGemm, DENSE_ACCUM_DIVISOR, DENSE_ACCUM_MIN_COLS};
pub use pb_merge::{PbMergeSpGemm, SPGEMM_MAX_SPILL_BYTES, SPGEMM_PB_PRODUCT_BYTES_USZ};

use crate::error::{Error, Result};
use crate::sparse::Csr;
use crate::spmm::Schedule;

/// Identifier for every SpGEMM implementation the engine can route to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpGemmImpl {
    /// Per-row hash/dense accumulator ([`HashSpGemm`]): gathers rows of
    /// `B` in whatever order `A`'s columns dictate — structure-
    /// sensitive traffic, like the gathering SpMM kernels.
    Hash,
    /// Propagation-blocking merge ([`PbMergeSpGemm`]): trades the
    /// random gathers for a sequential spill/merge round trip —
    /// structure-independent traffic, like [`crate::spmm::PbSpmm`].
    PbMerge,
}

impl SpGemmImpl {
    /// All native SpGEMM implementations (the router's candidate set).
    pub const ALL: [SpGemmImpl; 2] = [SpGemmImpl::Hash, SpGemmImpl::PbMerge];
}

impl std::fmt::Display for SpGemmImpl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            SpGemmImpl::Hash => "HASH",
            SpGemmImpl::PbMerge => "PBMERGE",
        };
        write!(f, "{s}")
    }
}

/// An SpGEMM kernel over a prepared left operand `A`.
///
/// Mirrors [`crate::spmm::Spmm`]: construction is the one-time
/// structural preparation (outside any timed region), `execute` is the
/// hot path, and execution is plan/execute split — kernels precompute
/// an nnz-balanced [`Schedule`] over `A`'s rows at construction and
/// consume a `&Schedule` at execute time. Unlike SpMM, the output is
/// allocated per call (its size is data-dependent), so `execute`
/// *returns* the product instead of filling a caller buffer.
pub trait SpGemm: Send + Sync {
    /// Which implementation this is.
    fn id(&self) -> SpGemmImpl;
    /// Rows of `A` (== rows of `C`).
    fn nrows(&self) -> usize;
    /// Cols of `A` (== rows of `B`).
    fn ncols(&self) -> usize;
    /// Stored nonzeros of `A`.
    fn nnz(&self) -> usize;
    /// The precomputed nnz-balanced base schedule over `A`'s rows.
    fn plan(&self) -> Schedule;
    /// Compute `C = A·B` over the base schedule.
    fn execute(&self, b: &Csr) -> Result<Csr>;
    /// Compute `C = A·B` over a precomputed schedule
    /// (`s.units() == nrows`; column tiles are ignored).
    fn execute_with(&self, b: &Csr, s: &Schedule) -> Result<Csr>;
}

/// Construct the requested SpGEMM kernel from a CSR left operand with
/// default tuning. Returns a boxed trait object the coordinator can
/// route to.
pub fn build_spgemm(im: SpGemmImpl, csr: &Csr, threads: usize) -> Box<dyn SpGemm> {
    match im {
        SpGemmImpl::Hash => Box::new(HashSpGemm::new(csr.clone(), threads)),
        SpGemmImpl::PbMerge => Box::new(PbMergeSpGemm::from_csr(csr, threads)),
    }
}

/// Exact SpGEMM FLOP count: `2 · Σ_{(i,k) ∈ A} |B_k|` (one multiply +
/// one add per partial product — the SpGEMM analog of the paper's
/// Eq. 1). An `O(nnz(A))` scan, so the planner computes it exactly
/// *before* execution; only `nnz(C)` needs estimating.
pub fn spgemm_flops(a: &Csr, b: &Csr) -> f64 {
    debug_assert_eq!(a.ncols, b.nrows);
    let mut prods = 0usize;
    for &k in &a.col_idx {
        prods += b.row_len(k as usize);
    }
    2.0 * prods as f64
}

/// Measured compression factor `cf = flops / nnz(C)`. Every stored
/// output needs at least one partial product, so `cf ≥ 2`; the empty
/// product conventionally reports the floor.
pub fn compression_factor(flops: f64, nnz_c: usize) -> f64 {
    if nnz_c == 0 {
        2.0
    } else {
        (flops / nnz_c as f64).max(2.0)
    }
}

/// Shape guard shared by both kernels. Also rejects a right operand
/// whose width would collide with the `u32::MAX` accumulator sentinel
/// (column indices are `u32`, so valid columns are `< ncols ≤ 2³²−1`).
pub(crate) fn check_spgemm_dims(a_nrows: usize, a_ncols: usize, b: &Csr) -> Result<()> {
    if b.nrows != a_ncols {
        return Err(Error::DimensionMismatch(format!(
            "A is {a_nrows}x{a_ncols} but B has {} rows",
            b.nrows
        )));
    }
    if b.ncols > u32::MAX as usize {
        return Err(Error::InvalidStructure(format!(
            "B has {} columns; SpGEMM column indices are u32",
            b.ncols
        )));
    }
    Ok(())
}

/// One finished slab of contiguous output rows
/// (`first_row .. first_row + row_lens.len()`), with the rows'
/// concatenated column/value runs. Workers push slabs as partitions
/// (or buckets) complete; [`assemble_slabs`] stitches them into one
/// CSR.
pub(crate) struct RowSlab {
    pub first_row: usize,
    pub row_lens: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

/// Assemble a CSR from disjoint row slabs. Slabs may arrive in any
/// order (they are sorted by first row here); rows covered by no slab
/// are empty. Each slab's rows must be internally sorted and
/// deduplicated — this function only concatenates.
pub(crate) fn assemble_slabs(nrows: usize, ncols: usize, mut slabs: Vec<RowSlab>) -> Csr {
    slabs.sort_by_key(|s| s.first_row);
    let nnz: usize = slabs.iter().map(|s| s.cols.len()).sum();
    let mut row_ptr = vec![0usize; nrows + 1];
    for s in &slabs {
        for (t, &len) in s.row_lens.iter().enumerate() {
            row_ptr[s.first_row + t + 1] = len as usize;
        }
    }
    for i in 0..nrows {
        row_ptr[i + 1] += row_ptr[i];
    }
    let mut col_idx = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(nnz);
    for s in &slabs {
        col_idx.extend_from_slice(&s.cols);
        vals.extend_from_slice(&s.vals);
    }
    Csr { nrows, ncols, row_ptr, col_idx, vals }
}

/// Reference (serial, obviously-correct) SpGEMM used as the oracle in
/// every kernel test: per-row dense accumulator, contributions added
/// in ascending-`k` order — the floating-point sequence both native
/// kernels reproduce bit for bit (see module docs).
pub fn reference_spgemm(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.ncols, b.nrows);
    let n = b.ncols;
    let mut acc = vec![0.0f64; n];
    let mut live = vec![false; n];
    let mut touched: Vec<u32> = Vec::new();
    let mut row_ptr = vec![0usize; a.nrows + 1];
    let mut cols: Vec<u32> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    for i in 0..a.nrows {
        touched.clear();
        for (&k, &v) in a.row_cols(i).iter().zip(a.row_vals(i)) {
            let kk = k as usize;
            for (&j, &w) in b.row_cols(kk).iter().zip(b.row_vals(kk)) {
                let jj = j as usize;
                if live[jj] {
                    acc[jj] += v * w;
                } else {
                    live[jj] = true;
                    acc[jj] = v * w;
                    touched.push(j);
                }
            }
        }
        touched.sort_unstable();
        for &j in &touched {
            cols.push(j);
            vals.push(acc[j as usize]);
            live[j as usize] = false;
        }
        row_ptr[i + 1] = cols.len();
    }
    Csr { nrows: a.nrows, ncols: n, row_ptr, col_idx: cols, vals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};

    #[test]
    fn reference_matches_dense_matmul() {
        let mut rng = Prng::new(0x5a0);
        let a = erdos_renyi(40, 30, 4.0, &mut rng);
        let b = erdos_renyi(30, 50, 3.0, &mut rng);
        let c = reference_spgemm(&a, &b);
        c.validate().unwrap();
        let (ad, bd, cd) = (a.to_dense(), b.to_dense(), c.to_dense());
        for i in 0..40 {
            for j in 0..50 {
                let mut want = 0.0;
                for k in 0..30 {
                    want += ad[i * 30 + k] * bd[k * 50 + j];
                }
                assert!((cd[i * 50 + j] - want).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn flops_and_cf() {
        let mut rng = Prng::new(0x5a1);
        let a = erdos_renyi(60, 60, 4.0, &mut rng);
        let b = erdos_renyi(60, 60, 4.0, &mut rng);
        let fl = spgemm_flops(&a, &b);
        let brute: usize =
            a.col_idx.iter().map(|&k| b.row_len(k as usize)).sum();
        assert_eq!(fl, 2.0 * brute as f64);
        let c = reference_spgemm(&a, &b);
        let cf = compression_factor(fl, c.nnz());
        assert!(cf >= 2.0, "cf={cf}");
        // cf · nnz(C) ≈ flops (exact when no row collapsed below 1)
        assert!((cf * c.nnz() as f64 - fl).abs() < 1e-9 || cf == 2.0);
        // degenerate: empty product reports the floor
        assert_eq!(compression_factor(0.0, 0), 2.0);
    }

    #[test]
    fn build_both_kernels() {
        let mut rng = Prng::new(0x5a2);
        let a = erdos_renyi(50, 50, 3.0, &mut rng);
        for im in SpGemmImpl::ALL {
            let k = build_spgemm(im, &a, 2);
            assert_eq!(k.id(), im);
            assert_eq!(k.nrows(), 50);
            assert_eq!(k.nnz(), a.nnz());
        }
        assert_eq!(SpGemmImpl::Hash.to_string(), "HASH");
        assert_eq!(SpGemmImpl::PbMerge.to_string(), "PBMERGE");
    }

    #[test]
    fn assemble_handles_gaps_and_order() {
        // slabs out of order, with an uncovered (empty) row in between
        let slabs = vec![
            RowSlab {
                first_row: 3,
                row_lens: vec![1],
                cols: vec![0],
                vals: vec![5.0],
            },
            RowSlab {
                first_row: 0,
                row_lens: vec![2, 0],
                cols: vec![1, 3],
                vals: vec![1.0, 2.0],
            },
        ];
        let c = assemble_slabs(4, 4, slabs);
        c.validate().unwrap();
        assert_eq!(c.row_ptr, vec![0, 2, 2, 2, 3]);
        assert_eq!(c.col_idx, vec![1, 3, 0]);
        assert_eq!(c.vals, vec![1.0, 2.0, 5.0]);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut rng = Prng::new(0x5a3);
        let a = erdos_renyi(10, 12, 2.0, &mut rng);
        let b = erdos_renyi(11, 5, 2.0, &mut rng);
        for im in SpGemmImpl::ALL {
            let k = build_spgemm(im, &a, 1);
            assert!(k.execute(&b).is_err(), "{im}");
        }
    }
}
