//! Propagation-blocking merge SpGEMM — the streaming kernel of the
//! SpGEMM pair, after Gu et al.'s propagation-blocking SpGEMM
//! (PAPERS.md, arXiv:2002.11302), reusing the column-band binning
//! machinery of [`crate::spmm::PbSpmm`] (`spmm/pb_kernel.rs`).
//!
//! The hash kernel gathers rows of `B` in whatever order `A`'s column
//! indices dictate — the random access the sparsity-aware models
//! charge for. This kernel trades the gathers for sequential traffic,
//! in two phases:
//!
//! 1. **Spill**: `A`'s nonzeros, re-binned at construction into column
//!    bands of [`PbMergeSpGemm::col_band`] consecutive columns, are
//!    streamed band by band. Within one band every `B` access lands in
//!    a narrow row-panel of `B` that stays cache-resident, so `B` is
//!    read from DRAM once overall. Each entry `(i, k, v)` expands into
//!    `|B_k|` partial products `(j, v·w, i)` written to a precomputed
//!    arena range — sequential, race-free writes (the per-entry ranges
//!    are disjoint by construction).
//! 2. **Merge**: partial products are laid out bucket-major (buckets =
//!    [`PbMergeSpGemm::row_band`]-row windows of destination rows);
//!    each bucket's run is streamed back, grouped per row, stably
//!    sorted by column, and reduced into sorted deduplicated CSR rows.
//!
//! Bucket ownership under a [`Schedule`] uses the same first-row rule
//! as `PbSpmm::gather` — both bounds round *up*, so a bucket
//! straddling a partition boundary has exactly one owner (the
//! one-row-per-partition regression in `tests/prop_spgemm.rs` pins
//! this).
//!
//! **Accumulation order**: arena slots per destination row arrive in
//! (band-ascending, then `k`-ascending) order, i.e. globally
//! `k`-ascending; the per-row sort is *stable* by column, so each
//! output's contributions reduce in exactly the arrival order — the
//! same floating-point sequence as [`crate::spgemm::HashSpGemm`] and
//! [`crate::spgemm::reference_spgemm`], bit for bit.

use std::sync::Mutex;

use crate::error::Result;
use crate::sparse::Csr;
use crate::spgemm::{assemble_slabs, check_spgemm_dims, RowSlab, SpGemm, SpGemmImpl};
use crate::spmm::pool::parallel_chunks_dynamic;
use crate::spmm::{
    bin_col_bands, check_schedule, ColBandBins, Schedule, PB_DEFAULT_COL_BAND,
    PB_DEFAULT_ROW_BAND,
};

/// Spill-arena budget, the SpGEMM mirror of the SpMM kernel's
/// `PB_MAX_SPILL_BYTES` (see [`crate::spmm::pb_spill_tile`]): a full
/// product expansion needs
/// [`SPGEMM_PB_PRODUCT_BYTES_USZ`] bytes per partial product, so
/// heavy-tailed operands (Σ deg² products) are processed in multiple
/// **bucket-range passes** — each pass spills and merges a contiguous
/// run of destination buckets whose products fit the budget (always
/// at least one bucket), re-streaming only the binned `A` structure
/// per pass. The traffic model charges a flops-derived lower bound on
/// this pass count ([`crate::model::spgemm_spill_passes`]; greedy
/// whole-bucket packing can run more).
pub const SPGEMM_MAX_SPILL_BYTES: usize = 1 << 26;

/// Bytes per partial product in the spill arena: column (4) +
/// value (8) + destination row (4).
pub const SPGEMM_PB_PRODUCT_BYTES_USZ: usize = 16;

/// Shared-pointer shim over the three product arrays: phase-1 workers
/// write *disjoint* slot ranges without locks. Soundness: every binned
/// entry owns a private contiguous slot range (`entry_off`), and each
/// entry is processed by exactly one worker (its band is claimed
/// once).
#[derive(Clone, Copy)]
struct RawProducts {
    col: *mut u32,
    val: *mut f64,
    row: *mut u32,
}
unsafe impl Send for RawProducts {}
unsafe impl Sync for RawProducts {}

impl RawProducts {
    /// Write one partial product. Caller must hold exclusive logical
    /// ownership of `slot`.
    #[inline(always)]
    unsafe fn set(&self, slot: usize, col: u32, val: f64, row: u32) {
        *self.col.add(slot) = col;
        *self.val.add(slot) = val;
        *self.row.add(slot) = row;
    }
}

/// Reusable per-worker merge scratch: one (column, value) list per row
/// of the bucket being merged.
struct MergeScratch {
    rows: Vec<Vec<(u32, f64)>>,
}

impl MergeScratch {
    fn new() -> MergeScratch {
        MergeScratch { rows: Vec::new() }
    }
    fn ensure(&mut self, height: usize) {
        if self.rows.len() < height {
            self.rows.resize_with(height, Vec::new);
        }
    }
}

/// Propagation-blocking merge SpGEMM kernel (see module docs).
pub struct PbMergeSpGemm {
    nrows: usize,
    ncols: usize,
    col_band: usize,
    row_band: usize,
    /// `A`'s entries binned by column band (shared machinery with
    /// `PbSpmm` — see `spmm/pb_kernel.rs::bin_col_bands`).
    bins: ColBandBins,
    /// Untiled nnz-balanced base schedule over `A`'s rows.
    base: Schedule,
    /// Spill-arena budget in bytes ([`SPGEMM_MAX_SPILL_BYTES`] unless
    /// overridden for tests/ablation).
    spill_cap: usize,
}

impl PbMergeSpGemm {
    /// Bin a CSR left operand with the default band geometry, shrunk
    /// where the matrix is small (same rule as `PbSpmm::from_csr`:
    /// ≈8 claimable bins per worker on both axes).
    pub fn from_csr(csr: &Csr, threads: usize) -> Self {
        let t = threads.max(1);
        let col_band = PB_DEFAULT_COL_BAND.min(csr.ncols.div_ceil(8 * t).max(1));
        let row_band = PB_DEFAULT_ROW_BAND.min(csr.nrows.div_ceil(8 * t).max(1));
        Self::from_csr_with_bands(csr, col_band, row_band, threads)
    }

    /// Bin with explicit band geometry (adversarial-test hook).
    pub fn from_csr_with_bands(
        csr: &Csr,
        col_band: usize,
        row_band: usize,
        threads: usize,
    ) -> Self {
        let col_band = col_band.max(1);
        let row_band = row_band.max(1);
        let bins = bin_col_bands(csr, col_band);
        let base = Schedule::nnz_balanced(&csr.row_ptr, threads.max(1));
        PbMergeSpGemm {
            nrows: csr.nrows,
            ncols: csr.ncols,
            col_band,
            row_band,
            bins,
            base,
            spill_cap: SPGEMM_MAX_SPILL_BYTES,
        }
    }

    /// Override the spill-arena budget (adversarial-test / ablation
    /// hook; the default is [`SPGEMM_MAX_SPILL_BYTES`]).
    pub fn with_spill_cap(mut self, bytes: usize) -> Self {
        self.spill_cap = bytes.max(1);
        self
    }

    /// The column-band width entries were binned with.
    pub fn col_band(&self) -> usize {
        self.col_band
    }

    /// The bucket height (destination-row bin size).
    pub fn row_band(&self) -> usize {
        self.row_band
    }
}

impl SpGemm for PbMergeSpGemm {
    fn id(&self) -> SpGemmImpl {
        SpGemmImpl::PbMerge
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.bins.col.len()
    }
    fn plan(&self) -> Schedule {
        self.base.clone()
    }

    fn execute(&self, b: &Csr) -> Result<Csr> {
        self.execute_with(b, &self.base)
    }

    fn execute_with(&self, b: &Csr, s: &Schedule) -> Result<Csr> {
        check_spgemm_dims(self.nrows, self.ncols, b)?;
        check_schedule(self.nrows, s)?;
        let rb = self.row_band;
        let nb = self.bins.band_ptr.len() - 1;
        let n_buckets = self.nrows.div_ceil(rb);
        let nnz = self.bins.col.len();

        // Per-(bucket, band) product-segment offsets: entry `e`
        // expands into `|B_{col[e]}|` partial products, laid out
        // bucket-major (one contiguous arena run per bucket) and
        // band-major within a bucket — the same layout PbSpmm's `seg`
        // computes once per matrix; here it depends on B, so it is
        // recomputed per execution (an O(nnz) scan).
        let mut seg = vec![0usize; n_buckets * nb + 1];
        for beta in 0..nb {
            for e in self.bins.band_ptr[beta]..self.bins.band_ptr[beta + 1] {
                let cell = (self.bins.src[e] as usize / rb) * nb + beta;
                seg[cell + 1] += b.row_len(self.bins.col[e] as usize);
            }
        }
        for i in 0..n_buckets * nb {
            seg[i + 1] += seg[i];
        }
        let bucket_ptr: Vec<usize> = (0..=n_buckets).map(|j| seg[j * nb]).collect();
        // per-entry *global* slot offset, assigned in band order within
        // a cell; a pass's arena index is this minus the pass base
        // (bucket-major layout makes each pass's slots contiguous)
        let mut segcur: Vec<usize> = seg[..n_buckets * nb].to_vec();
        let mut entry_off = vec![0usize; nnz];
        for beta in 0..nb {
            for e in self.bins.band_ptr[beta]..self.bins.band_ptr[beta + 1] {
                let cell = (self.bins.src[e] as usize / rb) * nb + beta;
                entry_off[e] = segcur[cell];
                segcur[cell] += b.row_len(self.bins.col[e] as usize);
            }
        }

        // Bucket-range passes bounded by the spill budget: each pass
        // spills and merges a contiguous run of buckets whose products
        // fit the cap (always at least one bucket, so the arena never
        // exceeds max(cap, largest single bucket)). One pass re-streams
        // the binned structure once — the per-pass term the traffic
        // model lower-bounds from flops (`model::spgemm_spill_passes`).
        let cap_products = (self.spill_cap / SPGEMM_PB_PRODUCT_BYTES_USZ).max(1);
        let slabs: Mutex<Vec<RowSlab>> = Mutex::new(Vec::new());
        let scratch: Mutex<Vec<MergeScratch>> = Mutex::new(Vec::new());
        let mut prod_col: Vec<u32> = Vec::new();
        let mut prod_val: Vec<f64> = Vec::new();
        let mut prod_row: Vec<u32> = Vec::new();
        let mut pass_lo = 0usize;
        while pass_lo < n_buckets {
            let mut pass_hi = pass_lo + 1;
            while pass_hi < n_buckets
                && bucket_ptr[pass_hi + 1] - bucket_ptr[pass_lo] <= cap_products
            {
                pass_hi += 1;
            }
            let base = bucket_ptr[pass_lo];
            let len = bucket_ptr[pass_hi] - base;
            if prod_col.len() < len {
                prod_col.resize(len, 0);
                prod_val.resize(len, 0.0);
                prod_row.resize(len, 0);
            }

            // Phase 1 — spill this pass's partial products band by band.
            let raw = RawProducts {
                col: prod_col.as_mut_ptr(),
                val: prod_val.as_mut_ptr(),
                row: prod_row.as_mut_ptr(),
            };
            parallel_chunks_dynamic(nb, s.threads, 1, |bands| {
                for beta in bands {
                    for e in self.bins.band_ptr[beta]..self.bins.band_ptr[beta + 1] {
                        let bucket = self.bins.src[e] as usize / rb;
                        if bucket < pass_lo || bucket >= pass_hi {
                            continue;
                        }
                        let k = self.bins.col[e] as usize;
                        let v = self.bins.val[e];
                        let r = self.bins.src[e];
                        let mut slot = entry_off[e] - base;
                        for (&j, &w) in b.row_cols(k).iter().zip(b.row_vals(k)) {
                            // SAFETY: entry e owns arena slots
                            // entry_off[e]-base .. +|B_k| exclusively,
                            // and band β has exactly one claimant.
                            unsafe { raw.set(slot, j, v * w, r) };
                            slot += 1;
                        }
                    }
                }
            });

            // Phase 2 — merge: each schedule partition reduces the
            // buckets it owns within this pass (first-row ownership,
            // both bounds rounded up — see module docs).
            parallel_chunks_dynamic(s.n_parts(), s.threads, 1, |parts| {
                let mut ms = {
                    let mut pool = scratch.lock().unwrap_or_else(|e| e.into_inner());
                    pool.pop()
                }
                .unwrap_or_else(MergeScratch::new);
                for pi in parts {
                    let part = s.part(pi);
                    if part.is_empty() {
                        continue;
                    }
                    let j_lo = part.start.div_ceil(rb).max(pass_lo);
                    let j_hi = part.end.div_ceil(rb).min(pass_hi);
                    for j in j_lo..j_hi {
                        let r_lo = j * rb;
                        let r_hi = ((j + 1) * rb).min(self.nrows);
                        let height = r_hi - r_lo;
                        ms.ensure(height);
                        for t in bucket_ptr[j]..bucket_ptr[j + 1] {
                            let local = prod_row[t - base] as usize - r_lo;
                            ms.rows[local].push((prod_col[t - base], prod_val[t - base]));
                        }
                        let mut slab = RowSlab {
                            first_row: r_lo,
                            row_lens: Vec::with_capacity(height),
                            cols: Vec::new(),
                            vals: Vec::new(),
                        };
                        for pairs in ms.rows.iter_mut().take(height) {
                            // stable: preserves the k-ascending arrival
                            // order per output column
                            pairs.sort_by_key(|p| p.0);
                            let mut len = 0u32;
                            let mut it = pairs.iter();
                            if let Some(&(c0, v0)) = it.next() {
                                let mut cur_c = c0;
                                let mut cur_v = v0;
                                for &(c, v) in it {
                                    if c == cur_c {
                                        cur_v += v;
                                    } else {
                                        slab.cols.push(cur_c);
                                        slab.vals.push(cur_v);
                                        len += 1;
                                        cur_c = c;
                                        cur_v = v;
                                    }
                                }
                                slab.cols.push(cur_c);
                                slab.vals.push(cur_v);
                                len += 1;
                            }
                            slab.row_lens.push(len);
                            pairs.clear();
                        }
                        slabs.lock().unwrap_or_else(|e| e.into_inner()).push(slab);
                    }
                }
                scratch.lock().unwrap_or_else(|e| e.into_inner()).push(ms);
            });
            pass_lo = pass_hi;
        }
        let slabs = slabs.into_inner().unwrap_or_else(|e| e.into_inner());
        Ok(assemble_slabs(self.nrows, b.ncols, slabs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, erdos_renyi, Prng};
    use crate::spgemm::{reference_spgemm, HashSpGemm};

    #[test]
    fn matches_reference_bitwise_various_bands_and_threads() {
        let mut rng = Prng::new(0x5c0);
        let a = erdos_renyi(150, 150, 5.0, &mut rng);
        let b = erdos_renyi(150, 150, 5.0, &mut rng);
        let want = reference_spgemm(&a, &b);
        for threads in [1usize, 3] {
            for (cb, rbw) in [(2048usize, 2048usize), (7, 5), (1, 1)] {
                let k = PbMergeSpGemm::from_csr_with_bands(&a, cb, rbw, threads);
                let c = k.execute(&b).unwrap();
                c.validate().unwrap();
                assert_eq!(c, want, "threads={threads} cb={cb} rb={rbw}");
            }
        }
    }

    #[test]
    fn matches_hash_kernel_bitwise() {
        let mut rng = Prng::new(0x5c1);
        let a = banded(120, 5, 0.4, &mut rng);
        let b = erdos_renyi(120, 120, 4.0, &mut rng);
        let hash = HashSpGemm::new(a.clone(), 2).execute(&b).unwrap();
        let pb = PbMergeSpGemm::from_csr_with_bands(&a, 16, 8, 2).execute(&b).unwrap();
        assert_eq!(pb, hash);
    }

    #[test]
    fn one_row_per_partition_schedule_does_not_double_count() {
        // buckets straddle every partition boundary: 1-row partitions,
        // 3-row buckets — the same ownership regression PbSpmm pins
        let mut rng = Prng::new(0x5c2);
        let a = erdos_renyi(16, 16, 4.0, &mut rng);
        let b = erdos_renyi(16, 16, 4.0, &mut rng);
        let want = reference_spgemm(&a, &b);
        let k = PbMergeSpGemm::from_csr_with_bands(&a, 4, 3, 2);
        let s = Schedule::uniform(16, 2);
        assert_eq!(s.n_parts(), 16);
        let c = k.execute_with(&b, &s).unwrap();
        assert_eq!(c, want);
    }

    #[test]
    fn rectangular_and_degenerate_shapes() {
        let mut rng = Prng::new(0x5c3);
        for (m, k, n) in [(1usize, 1usize, 1usize), (1, 40, 7), (40, 1, 7), (30, 70, 20)] {
            let a = erdos_renyi(m, k, 3.0, &mut rng);
            let b = erdos_renyi(k, n, 3.0, &mut rng);
            let kern = PbMergeSpGemm::from_csr_with_bands(&a, 8, 8, 2);
            let c = kern.execute(&b).unwrap();
            c.validate().unwrap();
            assert_eq!(c, reference_spgemm(&a, &b), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn tiny_spill_cap_forces_passes_and_stays_bitwise() {
        // a cap far below the product footprint forces many
        // bucket-range passes; the result must not change by a bit
        let mut rng = Prng::new(0x5c5);
        let a = erdos_renyi(120, 120, 5.0, &mut rng);
        let b = erdos_renyi(120, 120, 5.0, &mut rng);
        let want = PbMergeSpGemm::from_csr_with_bands(&a, 16, 8, 2).execute(&b).unwrap();
        for cap in [1usize, 64, 4096] {
            let k = PbMergeSpGemm::from_csr_with_bands(&a, 16, 8, 2).with_spill_cap(cap);
            let c = k.execute(&b).unwrap();
            c.validate().unwrap();
            assert_eq!(c, want, "cap={cap}");
        }
        // and under an adversarial one-row-per-partition schedule
        let k = PbMergeSpGemm::from_csr_with_bands(&a, 16, 3, 2).with_spill_cap(64);
        let s = Schedule::uniform(120, 15);
        assert_eq!(s.n_parts(), 120);
        let c = k.execute_with(&b, &s).unwrap();
        assert_eq!(c, reference_spgemm(&a, &b));
    }

    #[test]
    fn empty_product_is_empty() {
        let a = Csr::from_dense(12, 12, &[0.0; 144]);
        let b = Csr::from_dense(12, 12, &[0.0; 144]);
        let k = PbMergeSpGemm::from_csr_with_bands(&a, 5, 5, 2);
        let c = k.execute(&b).unwrap();
        c.validate().unwrap();
        assert_eq!(c.nnz(), 0);
    }

    #[test]
    fn foreign_schedule_rejected() {
        let mut rng = Prng::new(0x5c4);
        let a = erdos_renyi(10, 10, 2.0, &mut rng);
        let b = erdos_renyi(10, 10, 2.0, &mut rng);
        let k = PbMergeSpGemm::from_csr(&a, 1);
        let foreign = Schedule::uniform(11, 1);
        assert!(k.execute_with(&b, &foreign).is_err());
    }
}
