//! The roofline itself: `P = min(β·AI, π)` (§II-C).

/// Machine parameters of the roofline: peak DRAM bandwidth `β` (GB/s)
/// and peak compute `π` (GFLOP/s).
///
/// The paper measured `β = 122.6 GB/s` with STREAM on one EPYC-7763
/// socket; on this testbed both values come from
/// [`crate::membench::measure_machine`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MachineParams {
    /// Peak memory bandwidth in GB/s.
    pub beta_gbs: f64,
    /// Peak compute throughput in GFLOP/s.
    pub pi_gflops: f64,
}

impl MachineParams {
    /// The paper's Perlmutter test system (Table IV + §IV-B): measured
    /// STREAM bandwidth 122.6 GB/s; peak FP64 of one 64-core EPYC 7763
    /// socket ≈ 64 cores · 2.45 GHz · 16 FLOP/cycle ≈ 2509 GFLOP/s.
    pub const PAPER_PERLMUTTER: MachineParams =
        MachineParams { beta_gbs: 122.6, pi_gflops: 2509.0 };

    /// Ridge point: the AI where the bandwidth roof meets the compute
    /// roof.
    pub fn ridge_ai(&self) -> f64 {
        self.pi_gflops / self.beta_gbs
    }
}

/// A roofline model for one machine.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub machine: MachineParams,
}

impl Roofline {
    pub fn new(machine: MachineParams) -> Self {
        Roofline { machine }
    }

    /// Attainable performance at arithmetic intensity `ai`:
    /// `P = min(β·AI, π)` in GFLOP/s.
    pub fn attainable_gflops(&self, ai: f64) -> f64 {
        (self.machine.beta_gbs * ai).min(self.machine.pi_gflops)
    }

    /// Is a kernel with this AI memory-bound on this machine?
    pub fn memory_bound(&self, ai: f64) -> bool {
        ai < self.machine.ridge_ai()
    }

    /// Fraction of the model-predicted roof a measured performance
    /// achieves (the "closeness to the roofline" the paper's Fig. 2
    /// reads off visually).
    pub fn efficiency(&self, ai: f64, measured_gflops: f64) -> f64 {
        let roof = self.attainable_gflops(ai);
        if roof <= 0.0 {
            0.0
        } else {
            measured_gflops / roof
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: MachineParams = MachineParams { beta_gbs: 100.0, pi_gflops: 1000.0 };

    #[test]
    fn bandwidth_region_linear() {
        let r = Roofline::new(M);
        assert_eq!(r.attainable_gflops(1.0), 100.0);
        assert_eq!(r.attainable_gflops(5.0), 500.0);
    }

    #[test]
    fn compute_region_capped() {
        let r = Roofline::new(M);
        assert_eq!(r.attainable_gflops(50.0), 1000.0);
    }

    #[test]
    fn ridge() {
        assert_eq!(M.ridge_ai(), 10.0);
        let r = Roofline::new(M);
        assert!(r.memory_bound(9.9));
        assert!(!r.memory_bound(10.1));
    }

    #[test]
    fn spmm_is_memory_bound_on_paper_machine() {
        // the paper's core premise: SpMM AI (< ~0.25) is far below the
        // EPYC ridge (~20)
        let r = Roofline::new(MachineParams::PAPER_PERLMUTTER);
        assert!(r.memory_bound(0.25));
    }

    #[test]
    fn efficiency_fraction() {
        let r = Roofline::new(M);
        assert!((r.efficiency(1.0, 50.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.efficiency(0.0, 10.0), 0.0);
    }
}
