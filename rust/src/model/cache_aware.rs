//! Cache-aware roofline extensions — the direction the paper's §V
//! explicitly flags as future work ("our model does not adequately
//! capture cache behavior and ignores memory latency effects") and its
//! §II-D cites from Ilic et al.'s cache-aware roofline.
//!
//! Two additions over the flat `P = min(β·AI, π)`:
//!
//! * [`CacheAwareRoofline`] — multiple bandwidth ceilings, one per
//!   memory level, each measured by running STREAM at a working-set
//!   size that fits that level ([`crate::membench::bandwidth_ladder`]).
//!   Attainable performance for a kernel whose working set lives at
//!   level L is `min(β_L·AI, π)`.
//! * [`LatencyModel`] — an effective-bandwidth correction for
//!   *irregular* access: a random gather of `line` bytes pays
//!   `latency + line/β` per line instead of `line/β`, so
//!   `β_eff = line / (latency + line/β)`. This quantifies the gap the
//!   paper observes between random-sparsity measurements and even the
//!   conservative Eq. 2 roof (§IV-D-1: "random sparsity incurs high
//!   memory latency … may further explain the gap").

use crate::model::MachineParams;

/// One bandwidth ceiling: a named memory level with its measured
/// bandwidth and capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct BandwidthCeiling {
    pub level: String,
    /// Working sets up to this many bytes enjoy this ceiling.
    pub capacity_bytes: usize,
    pub beta_gbs: f64,
}

/// A roofline with per-level bandwidth ceilings (Ilic et al. style).
#[derive(Debug, Clone)]
pub struct CacheAwareRoofline {
    /// Ceilings ordered from smallest (fastest) to largest level.
    pub ceilings: Vec<BandwidthCeiling>,
    pub pi_gflops: f64,
}

impl CacheAwareRoofline {
    /// Build from measured ceilings (must be non-empty, ordered by
    /// capacity ascending).
    pub fn new(mut ceilings: Vec<BandwidthCeiling>, pi_gflops: f64) -> CacheAwareRoofline {
        assert!(!ceilings.is_empty());
        ceilings.sort_by_key(|c| c.capacity_bytes);
        CacheAwareRoofline { ceilings, pi_gflops }
    }

    /// The ceiling serving a given working-set size: the smallest level
    /// that fits it (falling back to the last = DRAM).
    pub fn ceiling_for(&self, working_set_bytes: usize) -> &BandwidthCeiling {
        self.ceilings
            .iter()
            .find(|c| working_set_bytes <= c.capacity_bytes)
            .unwrap_or_else(|| self.ceilings.last().unwrap())
    }

    /// Attainable GFLOP/s at intensity `ai` for a kernel whose hot
    /// working set is `working_set_bytes`.
    pub fn attainable_gflops(&self, ai: f64, working_set_bytes: usize) -> f64 {
        (self.ceiling_for(working_set_bytes).beta_gbs * ai).min(self.pi_gflops)
    }

    /// Whether a working set of this size is served by an actual
    /// *cache* rung — some level short of the last (DRAM fallback)
    /// ceiling fits it. The pipeline model's inter-op reuse term
    /// ([`crate::model::bytes_pipeline`]) keys on this: an
    /// intermediate block that is cache-resident is charged to DRAM
    /// once, not re-streamed by the consuming op. A single-rung
    /// (DRAM-only) ladder resolves to `false` for every size.
    pub fn cache_resident(&self, working_set_bytes: usize) -> bool {
        let n = self.ceilings.len();
        self.ceilings[..n - 1].iter().any(|c| working_set_bytes <= c.capacity_bytes)
    }

    /// A calibration-free ladder from flat machine parameters plus the
    /// host's cache capacities: per-level bandwidths are the DRAM `β`
    /// scaled by conventional multipliers (`2×` per level inward —
    /// L3 `2β`, L2 `4β`, L1 `8β` on a three-level hierarchy). This is
    /// a *prior*, not a measurement — it exists so tile-width selection
    /// can run without the multi-second per-level STREAM sweep
    /// (`membench::bandwidth_ladder` measures the real ladder). The
    /// capacity per level is halved as the effective residency
    /// threshold: a working set at exactly the nominal capacity
    /// thrashes against the kernel's other streams.
    ///
    /// `levels` are `(name, capacity_bytes)` ascending, e.g. from
    /// `membench::cache_levels()`.
    pub fn nominal(machine: MachineParams, levels: &[(String, usize)]) -> CacheAwareRoofline {
        let mut ceilings: Vec<BandwidthCeiling> = levels
            .iter()
            .enumerate()
            .map(|(i, (name, cap))| BandwidthCeiling {
                level: name.clone(),
                capacity_bytes: (cap / 2).max(1),
                beta_gbs: machine.beta_gbs * (1u64 << (levels.len() - i)) as f64,
            })
            .collect();
        ceilings.push(BandwidthCeiling {
            level: "DRAM".into(),
            capacity_bytes: usize::MAX,
            beta_gbs: machine.beta_gbs,
        });
        CacheAwareRoofline::new(ceilings, machine.pi_gflops)
    }

    /// The flat (DRAM-only) machine this degenerates to — what the
    /// paper's Fig. 2 used.
    pub fn flat(&self) -> MachineParams {
        MachineParams {
            beta_gbs: self.ceilings.last().unwrap().beta_gbs,
            pi_gflops: self.pi_gflops,
        }
    }

    /// SpMM working set for the B-reuse question: the bytes of `B`
    /// (`8·n·d`) — the array whose residency decides which ceiling
    /// applies (A and C stream regardless).
    pub fn spmm_working_set(n: usize, d: usize) -> usize {
        8 * n * d
    }
}

/// Latency-corrected effective bandwidth for irregular access.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyModel {
    /// DRAM (or level) streaming bandwidth in GB/s.
    pub beta_gbs: f64,
    /// Average miss latency in nanoseconds.
    pub latency_ns: f64,
    /// Cache line size in bytes.
    pub line_bytes: f64,
    /// Outstanding-miss parallelism (MLP): how many misses the core
    /// overlaps. 1 = fully serialised pointer chasing; modern cores
    /// sustain ~8–16 on independent streams.
    pub mlp: f64,
}

impl LatencyModel {
    /// Effective bandwidth of a random-gather stream:
    /// `β_eff = line / (latency/mlp + line/β)`.
    pub fn effective_beta_gbs(&self) -> f64 {
        let per_line_stream = self.line_bytes / (self.beta_gbs * 1e9) * 1e9; // ns
        let per_line = self.latency_ns / self.mlp.max(1e-9) + per_line_stream;
        self.line_bytes / per_line // bytes per ns == GB/s
    }

    /// Attainable GFLOP/s at `ai` when the traffic is gather-dominated.
    pub fn attainable_gflops(&self, ai: f64, pi_gflops: f64) -> f64 {
        (self.effective_beta_gbs() * ai).min(pi_gflops)
    }

    /// Blend: a fraction `irregular` of the traffic pays the latency
    /// bandwidth, the rest streams. Harmonic (serial-time) blend.
    pub fn blended_beta_gbs(&self, irregular: f64) -> f64 {
        let irr = irregular.clamp(0.0, 1.0);
        let be = self.effective_beta_gbs();
        1.0 / (irr / be + (1.0 - irr) / self.beta_gbs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> CacheAwareRoofline {
        CacheAwareRoofline::new(
            vec![
                BandwidthCeiling { level: "L1".into(), capacity_bytes: 32 << 10, beta_gbs: 400.0 },
                BandwidthCeiling { level: "L2".into(), capacity_bytes: 2 << 20, beta_gbs: 150.0 },
                BandwidthCeiling { level: "DRAM".into(), capacity_bytes: usize::MAX, beta_gbs: 20.0 },
            ],
            100.0,
        )
    }

    #[test]
    fn picks_the_right_ceiling() {
        let r = ladder();
        assert_eq!(r.ceiling_for(1 << 10).level, "L1");
        assert_eq!(r.ceiling_for(1 << 20).level, "L2");
        assert_eq!(r.ceiling_for(1 << 30).level, "DRAM");
    }

    #[test]
    fn attainable_uses_level_bandwidth() {
        let r = ladder();
        assert_eq!(r.attainable_gflops(0.1, 1 << 10), 40.0);
        assert_eq!(r.attainable_gflops(0.1, 1 << 30), 2.0);
        // compute roof still caps
        assert_eq!(r.attainable_gflops(10.0, 1 << 10), 100.0);
    }

    #[test]
    fn flat_is_dram() {
        let r = ladder();
        assert_eq!(r.flat().beta_gbs, 20.0);
    }

    #[test]
    fn cache_resident_stops_at_the_dram_fallback() {
        let r = ladder();
        assert!(r.cache_resident(1 << 10), "fits L1");
        assert!(r.cache_resident(1 << 20), "fits L2");
        assert!(!r.cache_resident(1 << 30), "only the DRAM rung fits this");
        // a DRAM-only ladder is never resident
        let dram = CacheAwareRoofline::new(
            vec![BandwidthCeiling {
                level: "DRAM".into(),
                capacity_bytes: usize::MAX,
                beta_gbs: 20.0,
            }],
            100.0,
        );
        assert!(!dram.cache_resident(1));
    }

    #[test]
    fn latency_degrades_bandwidth() {
        let m = LatencyModel { beta_gbs: 20.0, latency_ns: 100.0, line_bytes: 64.0, mlp: 1.0 };
        let be = m.effective_beta_gbs();
        // 64B / (100ns + 3.2ns) ≈ 0.62 GB/s — latency-dominated
        assert!(be < 1.0, "{be}");
        // with MLP=10 the latency amortises 10×
        let m10 = LatencyModel { mlp: 10.0, ..m };
        assert!(m10.effective_beta_gbs() > 5.0 * be);
        // infinite-ish MLP approaches streaming bandwidth
        let m_inf = LatencyModel { mlp: 1e9, ..m };
        assert!((m_inf.effective_beta_gbs() - 20.0).abs() < 0.1);
    }

    #[test]
    fn blend_interpolates_harmonically() {
        let m = LatencyModel { beta_gbs: 20.0, latency_ns: 80.0, line_bytes: 64.0, mlp: 4.0 };
        let b0 = m.blended_beta_gbs(0.0);
        let b1 = m.blended_beta_gbs(1.0);
        let bh = m.blended_beta_gbs(0.5);
        assert!((b0 - 20.0).abs() < 1e-9);
        assert!((b1 - m.effective_beta_gbs()).abs() < 1e-9);
        assert!(bh > b1 && bh < b0);
    }

    #[test]
    fn spmm_working_set_is_b() {
        assert_eq!(CacheAwareRoofline::spmm_working_set(1000, 16), 128_000);
    }

    #[test]
    fn nominal_ladder_scales_from_flat_beta() {
        let machine = MachineParams { beta_gbs: 20.0, pi_gflops: 100.0 };
        let levels = vec![
            ("L1".to_string(), 32 << 10),
            ("L2".to_string(), 1 << 20),
            ("L3".to_string(), 16 << 20),
        ];
        let r = CacheAwareRoofline::nominal(machine, &levels);
        assert_eq!(r.ceilings.len(), 4);
        // 2× per level inward over DRAM β, DRAM last at β itself
        assert_eq!(r.ceilings[0].beta_gbs, 160.0);
        assert_eq!(r.ceilings[1].beta_gbs, 80.0);
        assert_eq!(r.ceilings[2].beta_gbs, 40.0);
        assert_eq!(r.ceilings[3].beta_gbs, 20.0);
        assert_eq!(r.ceilings[3].capacity_bytes, usize::MAX);
        // residency threshold is half the nominal capacity
        assert_eq!(r.ceilings[0].capacity_bytes, 16 << 10);
        assert_eq!(r.flat().beta_gbs, 20.0);
        assert_eq!(r.pi_gflops, 100.0);
    }
}
