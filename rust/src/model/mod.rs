//! Sparsity-aware roofline models — §III of the paper.
//!
//! Everything here is pure math over structural statistics; the
//! measured side lives in [`crate::metrics`] / [`crate::harness`], and
//! the memory-traffic *validation* (simulated DRAM bytes vs these
//! analytic byte counts) lives in [`crate::cachesim`].

mod ai;
mod blocked;
mod cache_aware;
mod roofline;
mod scalefree;

pub use ai::{AiParams, SparsityModel};
pub use blocked::{expected_z, expected_z_exact, BlockStats};
pub use cache_aware::{BandwidthCeiling, CacheAwareRoofline, LatencyModel};
pub use roofline::{MachineParams, Roofline};
pub use scalefree::{hub_mass_fraction, measured_hub_mass, HubParams};

pub use ai::{
    ai_blocked, ai_blocked_text_variant, ai_diagonal, ai_random, ai_scalefree, bytes_blocked,
    bytes_diagonal, bytes_random, bytes_scalefree,
};
