//! Sparsity-aware roofline models — §III of the paper, plus this
//! repo's extensions (tile-aware traffic, the cache-aware ladder, the
//! propagation-blocking model, the compression-factor-parameterized
//! SpGEMM models [`bytes_spgemm_hash`]/[`bytes_spgemm_pb`], and the
//! chained-workload inter-op reuse term [`bytes_pipeline`]).
//!
//! Everything here is pure math over structural statistics; the
//! measured side lives in [`crate::metrics`] / [`crate::harness`], and
//! the memory-traffic *validation* (simulated DRAM bytes vs these
//! analytic byte counts) lives in [`crate::cachesim`]. Every formula
//! is derived in prose, with symbol names matching these identifiers
//! and worked examples, in `MODELS.md`.
//!
//! **Hand-off** (classify → predict → schedule → route → execute):
//! this module is the vocabulary of the *predict* stage. The
//! classifier ([`crate::pattern`]) selects a [`SparsityModel`]; the
//! planner ([`crate::coordinator::Planner`]) evaluates its AI — flat
//! ([`SparsityModel::ai`]), tiled ([`SparsityModel::ai_tiled`]), or
//! the structure-independent propagation-blocking line ([`ai_pb`]) —
//! against a roofline ([`Roofline`], [`CacheAwareRoofline`]) to rank
//! implementations and choose the column-tile width the schedule
//! layer executes with.

mod ai;
mod blocked;
mod cache_aware;
mod features;
mod ooc;
mod pb;
mod pipeline;
mod roofline;
mod scalefree;
mod spgemm;

pub use ai::{AiParams, SparsityModel};
pub use blocked::{expected_z, expected_z_exact, BlockStats};
pub use cache_aware::{BandwidthCeiling, CacheAwareRoofline, LatencyModel};
pub use features::{FeatureVec, FEATURE_NAMES, N_FEATURES};
pub use ooc::{ai_ooc, bytes_ooc, bytes_ooc_extra};
pub use pb::{ai_pb, ai_pb_tiled, bytes_pb, bytes_pb_tiled, PB_STRUCT_BYTES_PER_NNZ};
pub use pipeline::{
    ai_pipeline, ai_pipeline_pb, bytes_pipeline, intermediate_resident, PipelineParams,
};
pub use roofline::{MachineParams, Roofline};
pub use scalefree::{hub_mass_fraction, measured_hub_mass, HubParams};
pub use spgemm::{
    ai_spgemm, bytes_spgemm, bytes_spgemm_hash, bytes_spgemm_pb, csr_bytes,
    spgemm_spill_passes, SpGemmParams, CF_FLOOR, SPGEMM_PB_PRODUCT_BYTES,
};

pub use ai::{
    ai_blocked, ai_blocked_text_variant, ai_diagonal, ai_random, ai_scalefree, bytes_blocked,
    bytes_diagonal, bytes_random, bytes_scalefree,
};
