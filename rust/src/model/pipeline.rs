//! Inter-op roofline term for chained workloads — the pipeline
//! extension of the paper's single-op models.
//!
//! The paper's thesis is that *structure* changes effective arithmetic
//! intensity. Chained workloads (GCN layers, PageRank iterations,
//! Krylov blocks) change it again: the output of one op is the hot
//! input of the next, and that inter-op reuse is a traffic term no
//! single-op roofline captures. Every per-op model charges the dense
//! operand `B` as if it arrived from DRAM — correct for a cold
//! operand, wrong for a chain whose intermediate `n×d` block never
//! left cache between ops.
//!
//! The correction is a residency test plus a byte subtraction:
//!
//! * The intermediate block's working set is `8·n·d` bytes
//!   ([`CacheAwareRoofline::spmm_working_set`]). If it fits a cache
//!   rung of the measured ladder
//!   ([`CacheAwareRoofline::cache_resident`]), each *subsequent* op's
//!   `B` traffic ([`SparsityModel::traffic_split`]'s second component)
//!   is dropped from the DRAM byte count: the block was already
//!   charged once as the producing op's `C` write, and the consumer
//!   reads it at cache bandwidth.
//! * If it does not fit, nothing changes: every op pays its full
//!   structural byte count and the chain AI collapses to the
//!   single-op AI.
//!
//! Formally, for a chain of `ops` identical SpMM applications
//! (`A` is `n×n` with `nnz`, intermediates `n×d`):
//!
//! ```text
//! bytes_chain = bytes_op + (ops − 1) · follow + extra_bytes
//! follow      = bytes_op − B_term        (resident)
//!             = bytes_op                 (streamed)
//! AI_chain    = (ops · 2·d·nnz + extra_flops) / bytes_chain
//! ```
//!
//! `extra_flops`/`extra_bytes` fold in the non-SpMM stages riding the
//! chain (GCN dense transforms, PageRank vector updates) so the
//! whole-pipeline prediction and the whole-pipeline measurement
//! divide the same work. The full derivation with a worked GCN
//! example is MODELS.md §8; [`crate::coordinator::Planner::predict_pipeline`]
//! feeds this into the ladder.
//!
//! Propagation blocking is the exception that proves the rule: PB
//! streams every byte by construction (its bin/spill arena re-streams
//! the dense operand regardless of residency), so its chain line
//! ([`ai_pipeline_pb`]) charges the full per-op byte count every op
//! and stays on the flat DRAM roof — inter-op residency buys the
//! gathering kernels a ceiling hop that PB can never take.

use crate::model::{bytes_pb, AiParams, CacheAwareRoofline, SparsityModel};

/// Shape of a chained workload: `ops` SpMM applications over the same
/// `n×n`/`nnz` operand with `n×d` intermediates, plus the non-SpMM
/// work that rides along.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineParams {
    /// Per-op SpMM parameters (the intermediate block is `n×d`).
    pub p: AiParams,
    /// Number of chained SpMM applications (layers / iterations).
    pub ops: usize,
    /// Non-SpMM FLOPs across the whole chain (dense transforms,
    /// normalization, rank-update vector math).
    pub extra_flops: f64,
    /// DRAM bytes those extra stages stream (weight panels, per-op
    /// score vectors).
    pub extra_bytes: f64,
}

impl PipelineParams {
    /// A pure SpMM chain: `ops` applications, no side work.
    pub fn new(p: AiParams, ops: usize) -> PipelineParams {
        PipelineParams { p, ops, extra_flops: 0.0, extra_bytes: 0.0 }
    }

    /// Attach the chain's non-SpMM work.
    pub fn with_extra(self, flops: f64, bytes: f64) -> PipelineParams {
        PipelineParams { extra_flops: flops, extra_bytes: bytes, ..self }
    }

    /// Whole-chain FLOPs: `ops · 2·d·nnz + extra_flops`.
    pub fn flops(&self) -> f64 {
        self.ops as f64 * self.p.flops() + self.extra_flops
    }
}

/// Whole-chain modeled DRAM bytes under a structural model. The first
/// op always pays its full byte count; each subsequent op drops its
/// `B` term when `resident` (the intermediate is served from cache —
/// charged once as the producer's `C` write) and pays in full
/// otherwise.
pub fn bytes_pipeline(model: SparsityModel, pp: PipelineParams, resident: bool) -> f64 {
    if pp.ops == 0 {
        return pp.extra_bytes;
    }
    let per_op = model.bytes(pp.p);
    let follow = if resident {
        let (_, b_bytes) = model.traffic_split(pp.p);
        per_op - b_bytes
    } else {
        per_op
    };
    per_op + (pp.ops - 1) as f64 * follow + pp.extra_bytes
}

/// Whole-chain arithmetic intensity. With `resident = false` (or a
/// single op) this reproduces the per-op model exactly; with
/// residency the chain AI rises toward the `B`-free limit as `ops`
/// grows — the inter-op reuse the single-op roofline cannot see.
pub fn ai_pipeline(model: SparsityModel, pp: PipelineParams, resident: bool) -> f64 {
    pp.flops() / bytes_pipeline(model, pp, resident)
}

/// Chain AI for propagation blocking: every op pays the full
/// structure-independent PB byte count ([`bytes_pb`]) — the two-phase
/// bin/spill traffic streams the dense operand from DRAM regardless of
/// whether the intermediate would fit a cache rung, so residency buys
/// PB nothing.
pub fn ai_pipeline_pb(pp: PipelineParams) -> f64 {
    pp.flops() / (pp.ops as f64 * bytes_pb(pp.p) + pp.extra_bytes)
}

/// Residency of the inter-op `n×d` block on a given ladder — the
/// predicate [`bytes_pipeline`]'s `resident` flag comes from.
pub fn intermediate_resident(ladder: &CacheAwareRoofline, n: usize, d: usize) -> bool {
    ladder.cache_resident(CacheAwareRoofline::spmm_working_set(n, d))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ai_pb, BandwidthCeiling, MachineParams};

    const P: AiParams = AiParams { n: 4096, d: 16, nnz: 40_960 };

    #[test]
    fn single_op_matches_the_flat_model() {
        for model in [SparsityModel::Random, SparsityModel::Diagonal] {
            let pp = PipelineParams::new(P, 1);
            assert_eq!(bytes_pipeline(model, pp, true), model.bytes(P), "{model:?}");
            assert_eq!(bytes_pipeline(model, pp, false), model.bytes(P), "{model:?}");
            assert!((ai_pipeline(model, pp, false) - model.ai(P)).abs() < 1e-15);
        }
    }

    #[test]
    fn streamed_chain_ai_equals_per_op_ai() {
        // no residency → chain bytes scale exactly with ops, so AI is
        // invariant in chain length
        let m = SparsityModel::Random;
        let a1 = ai_pipeline(m, PipelineParams::new(P, 1), false);
        let a8 = ai_pipeline(m, PipelineParams::new(P, 8), false);
        assert!((a1 - a8).abs() < 1e-15);
    }

    #[test]
    fn resident_chain_ai_rises_with_ops() {
        // residency drops the B term from every follow-on op: the
        // random model's dominant 8·d·nnz re-stream disappears, so the
        // chain AI climbs strictly with ops and beats the per-op AI
        let m = SparsityModel::Random;
        let a1 = ai_pipeline(m, PipelineParams::new(P, 1), true);
        let a2 = ai_pipeline(m, PipelineParams::new(P, 2), true);
        let a8 = ai_pipeline(m, PipelineParams::new(P, 8), true);
        assert!(a2 > a1);
        assert!(a8 > a2);
        assert!(a8 > m.ai(P));
        // and the subtraction is exactly (ops−1) B terms
        let (_, b) = m.traffic_split(P);
        let want = 8.0 * m.bytes(P) - 7.0 * b;
        assert!((bytes_pipeline(m, PipelineParams::new(P, 8), true) - want).abs() < 1e-6);
    }

    #[test]
    fn extra_work_is_charged_on_both_sides() {
        let m = SparsityModel::Diagonal;
        let bare = PipelineParams::new(P, 4);
        let loaded = bare.with_extra(1e6, 1e5);
        assert_eq!(loaded.flops(), bare.flops() + 1e6);
        assert_eq!(
            bytes_pipeline(m, loaded, true),
            bytes_pipeline(m, bare, true) + 1e5
        );
    }

    #[test]
    fn zero_ops_is_just_the_extra_work() {
        let pp = PipelineParams::new(P, 0).with_extra(10.0, 5.0);
        assert_eq!(bytes_pipeline(SparsityModel::Random, pp, true), 5.0);
        assert_eq!(pp.flops(), 10.0);
    }

    #[test]
    fn pb_chain_ignores_residency() {
        let pp = PipelineParams::new(P, 6);
        assert!((ai_pipeline_pb(pp) - ai_pb(P)).abs() < 1e-15);
    }

    #[test]
    fn residency_predicate_matches_the_ladder() {
        let machine = MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 };
        let levels = vec![("L2".to_string(), 8 << 20)];
        let ladder = CacheAwareRoofline::nominal(machine, &levels);
        // 8·n·d = 512 KiB fits the halved 4 MiB L2 threshold
        assert!(intermediate_resident(&ladder, P.n, P.d));
        // a much wider block does not
        assert!(!intermediate_resident(&ladder, P.n, 4096));
        // DRAM-only ladder: nothing is ever resident
        let dram = CacheAwareRoofline::new(
            vec![BandwidthCeiling {
                level: "DRAM".into(),
                capacity_bytes: usize::MAX,
                beta_gbs: 10.0,
            }],
            100.0,
        );
        assert!(!intermediate_resident(&dram, 8, 1));
    }
}
