//! Traffic models for the SpGEMM kernels ([`crate::spgemm`]) —
//! parameterized by the **compression factor** `cf = flops / nnz(C)`.
//!
//! For SpMM the dense width `d` fixes the FLOP count and the output
//! size; for sparse×sparse multiplication both depend on structure:
//! the partial-product count is exact and cheap
//! (`flops = 2·Σ_{(i,k)∈A} |B_k|`, an `O(nnz(A))` scan —
//! [`crate::spgemm::spgemm_flops`]), but the output size `nnz(C)` is
//! only known after a symbolic pass. The models therefore take `cf`,
//! with `nnz(C) = flops / cf`: predictions before the first execution
//! use the conservative floor [`CF_FLOOR`] (`cf = 2`, no compression —
//! every partial product survives), and the engine re-predicts with
//! the measured `cf` once a pair has executed
//! ([`crate::spgemm::compression_factor`]).
//!
//! Byte counts follow the paper's storage model (8-byte values, 4-byte
//! indices; a CSR structure of `nnz` entries over `rows` rows occupies
//! `12·nnz + 4·(rows+1)` bytes — [`csr_bytes`]). Derivations with a
//! worked R-MAT example live in `MODELS.md` §6.

use crate::spgemm::{SpGemmImpl, SPGEMM_MAX_SPILL_BYTES, SPGEMM_PB_PRODUCT_BYTES_USZ};

/// The conservative pre-execution compression factor: `cf = 2` means
/// zero compression (one stored output per partial product), the
/// worst case for both kernels' `C`-write term.
pub const CF_FLOOR: f64 = 2.0;

/// Bytes of one partial product in the PB-merge spill arena:
/// column (4) + value (8) + destination row (4) — the identifiers are
/// the `prod_*` arrays of [`crate::spgemm::PbMergeSpGemm`], and the
/// value is defined by the kernel's own
/// [`crate::spgemm::SPGEMM_PB_PRODUCT_BYTES_USZ`] so model and kernel
/// cannot desynchronize.
pub const SPGEMM_PB_PRODUCT_BYTES: f64 = SPGEMM_PB_PRODUCT_BYTES_USZ as f64;

/// Shared SpGEMM problem parameters: `C = A·B` with `A` an
/// `m × p` CSR of `nnz_a` entries, `B` a `p × n` CSR of `nnz_b`
/// entries, `flops` the exact partial-product FLOP count, and `cf`
/// the (estimated or measured) compression factor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpGemmParams {
    /// Rows of `A` (= rows of `C`).
    pub m: usize,
    /// Rows of `B` (= cols of `A`).
    pub p: usize,
    /// Stored nonzeros of `A`.
    pub nnz_a: usize,
    /// Stored nonzeros of `B`.
    pub nnz_b: usize,
    /// `2 · Σ_{(i,k) ∈ A} |B_k|` ([`crate::spgemm::spgemm_flops`]).
    pub flops: f64,
    /// Compression factor `flops / nnz(C)`, clamped to ≥ [`CF_FLOOR`].
    pub cf: f64,
}

impl SpGemmParams {
    /// Parameters with the conservative pre-execution `cf` floor.
    pub fn new(m: usize, p: usize, nnz_a: usize, nnz_b: usize, flops: f64) -> SpGemmParams {
        SpGemmParams { m, p, nnz_a, nnz_b, flops, cf: CF_FLOOR }
    }

    /// The same parameters under a measured compression factor.
    pub fn with_cf(mut self, cf: f64) -> SpGemmParams {
        self.cf = cf.max(CF_FLOOR);
        self
    }

    /// Modeled output size `nnz(C) = flops / cf`.
    pub fn nnz_c(&self) -> f64 {
        self.flops / self.cf.max(CF_FLOOR)
    }
}

/// Bytes of a CSR structure: `12·nnz + 4·(rows+1)` (values + column
/// indices + row pointers).
pub fn csr_bytes(nnz: f64, rows: usize) -> f64 {
    12.0 * nnz + 4.0 * (rows as f64 + 1.0)
}

/// Modeled DRAM bytes for the hash kernel
/// ([`crate::spgemm::HashSpGemm`]) — the *gathering* line:
///
/// * `A` is streamed once: [`csr_bytes`]`(nnz_a, m)`;
/// * every partial product gathers one `B` entry (8-byte value +
///   4-byte column) with no modeled reuse — the random lower bound,
///   exactly as the SpMM random model charges `B`: `12 · flops/2 =
///   6·flops`;
/// * `C` is written once: [`csr_bytes`]`(flops/cf, m)`.
pub fn bytes_spgemm_hash(p: SpGemmParams) -> f64 {
    csr_bytes(p.nnz_a as f64, p.m) + 6.0 * p.flops + csr_bytes(p.nnz_c(), p.m)
}

/// Spill passes charged to the PB-merge kernel: the arena is capped
/// at [`SPGEMM_MAX_SPILL_BYTES`]
/// ([`crate::spgemm::PbMergeSpGemm::with_spill_cap`]), so product
/// bytes ([`SPGEMM_PB_PRODUCT_BYTES`] per product, `flops/2`
/// products) beyond the cap force extra bucket-range passes — each
/// re-streaming the binned `A` structure and the gathered `B` panels
/// once. The SpGEMM analog of `⌈d/dt⌉` in
/// [`crate::model::bytes_pb_tiled`].
///
/// This is a *lower bound* on the kernel's actual pass count: the
/// kernel packs whole buckets greedily into each pass, so bucket
/// granularity can add passes (a run of ~0.6·cap buckets fits one per
/// pass). The bound is what the planner can know from `flops` alone,
/// before any bucket layout exists.
pub fn spgemm_spill_passes(flops: f64) -> f64 {
    let product_bytes = (SPGEMM_PB_PRODUCT_BYTES / 2.0) * flops;
    (product_bytes / SPGEMM_MAX_SPILL_BYTES as f64).ceil().max(1.0)
}

/// Modeled DRAM bytes for the PB-merge kernel
/// ([`crate::spgemm::PbMergeSpGemm`]) — the *streaming*,
/// structure-independent line:
///
/// * per spill pass ([`spgemm_spill_passes`]): the binned `A` stream
///   (`col` 4 + `val` 8 + `src` 4 = `16·nnz_a`, the `ColBandBins`
///   fields) plus `B` read once ([`csr_bytes`]`(nnz_b, p)` — within a
///   band every gather lands in a cache-resident row panel, the same
///   argument as [`crate::model::bytes_pb`]);
/// * the spill round trip: every partial product
///   ([`SPGEMM_PB_PRODUCT_BYTES`] = 16 B) is written in the spill
///   phase and read back in the merge — `2 · 16 · flops/2 =
///   16·flops` (pass-invariant: passes partition the products);
/// * `C` is written once: [`csr_bytes`]`(flops/cf, m)`.
pub fn bytes_spgemm_pb(p: SpGemmParams) -> f64 {
    spgemm_spill_passes(p.flops)
        * (16.0 * p.nnz_a as f64 + csr_bytes(p.nnz_b as f64, p.p))
        + SPGEMM_PB_PRODUCT_BYTES * p.flops
        + csr_bytes(p.nnz_c(), p.m)
}

/// Modeled bytes for one SpGEMM implementation.
pub fn bytes_spgemm(p: SpGemmParams, im: SpGemmImpl) -> f64 {
    match im {
        SpGemmImpl::Hash => bytes_spgemm_hash(p),
        SpGemmImpl::PbMerge => bytes_spgemm_pb(p),
    }
}

/// Arithmetic intensity (FLOPs/byte) for one SpGEMM implementation.
/// Like the SpMM PB line, the merge kernel's AI sits *below* the hash
/// kernel's (16 vs 6 bytes per product-FLOP-pair): its win comes from
/// every byte streaming at full bandwidth, credited through the
/// planner's efficiency prior, not from fewer bytes.
pub fn ai_spgemm(p: SpGemmParams, im: SpGemmImpl) -> f64 {
    let bytes = bytes_spgemm(p, im);
    if bytes <= 0.0 {
        0.0
    } else {
        p.flops / bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> SpGemmParams {
        // a 2^16-row square pair, ~16 nnz/row, cf measured at 8
        SpGemmParams::new(1 << 16, 1 << 16, 1 << 20, 1 << 20, 2.0 * (16 << 20) as f64)
            .with_cf(8.0)
    }

    #[test]
    fn closed_forms() {
        let p = params();
        let m = p.m as f64;
        let want_hash =
            (12.0 * p.nnz_a as f64 + 4.0 * (m + 1.0)) + 6.0 * p.flops
                + (12.0 * p.nnz_c() + 4.0 * (m + 1.0));
        assert!((bytes_spgemm_hash(p) - want_hash).abs() < 1e-6);
        let passes = spgemm_spill_passes(p.flops);
        assert!(passes >= 1.0);
        let want_pb = passes
            * (16.0 * p.nnz_a as f64 + (12.0 * p.nnz_b as f64 + 4.0 * (p.p as f64 + 1.0)))
            + 16.0 * p.flops
            + (12.0 * p.nnz_c() + 4.0 * (m + 1.0));
        assert!((bytes_spgemm_pb(p) - want_pb).abs() < 1e-6);
    }

    #[test]
    fn spill_passes_track_the_arena_cap() {
        use crate::spgemm::SPGEMM_MAX_SPILL_BYTES;
        // under the cap: one pass
        let small = (SPGEMM_MAX_SPILL_BYTES / 16) as f64; // products
        assert_eq!(spgemm_spill_passes(2.0 * small), 1.0);
        // 4× the cap: four passes
        assert_eq!(spgemm_spill_passes(2.0 * 4.0 * small), 4.0);
        assert_eq!(spgemm_spill_passes(0.0), 1.0);
    }

    #[test]
    fn pb_ai_below_hash_ai_by_design() {
        let p = params();
        assert!(ai_spgemm(p, SpGemmImpl::PbMerge) < ai_spgemm(p, SpGemmImpl::Hash));
        assert!(bytes_spgemm_pb(p) > bytes_spgemm_hash(p));
    }

    #[test]
    fn higher_cf_means_fewer_output_bytes_and_higher_ai() {
        let lo = params().with_cf(2.0);
        let hi = params().with_cf(32.0);
        assert!(hi.nnz_c() < lo.nnz_c());
        for im in SpGemmImpl::ALL {
            assert!(bytes_spgemm(hi, im) < bytes_spgemm(lo, im), "{im}");
            assert!(ai_spgemm(hi, im) > ai_spgemm(lo, im), "{im}");
        }
    }

    #[test]
    fn cf_clamps_to_floor() {
        let p = params().with_cf(0.5);
        assert_eq!(p.cf, CF_FLOOR);
        assert!((p.nnz_c() - p.flops / CF_FLOOR).abs() < 1e-9);
        // degenerate empty problem: AI defined as 0
        let empty = SpGemmParams::new(0, 0, 0, 0, 0.0);
        assert_eq!(ai_spgemm(empty, SpGemmImpl::Hash), 0.0);
    }
}
