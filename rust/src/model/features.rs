//! Feature vector for the learned structure router.
//!
//! The learned router (`crate::coordinator::LearnedRouter`) predicts
//! the winning `(impl, reorder, dt)` triple directly from the same
//! structural statistics the classifier derives — this module fixes
//! the *encoding* of those statistics as a flat `f64` vector so the
//! model layer, the tree trainer, and the snapshot format all agree on
//! feature order and scaling.
//!
//! Scaling choices:
//!
//! - The four structural fractions (row-length CV, 1% hub mass,
//!   diagonal fraction, block-diagonal fraction) are used raw — they
//!   are already dimensionless and O(1).
//! - The three size-like quantities (`n`, `nnz`, `d`) are log2-scaled:
//!   tree splits are threshold comparisons, and a threshold in log
//!   space expresses "bigger than ~2^k" the way cache-capacity
//!   boundaries actually behave. `log2(x + 1)` keeps zero finite.
//!
//! Non-finite inputs (a NaN CV from a degenerate matrix, say) are
//! sanitized to 0.0 at construction: a feature vector must never carry
//! NaN into training, routing, or the persisted snapshot.

/// Number of features in a [`FeatureVec`]. Fixed by the snapshot
/// format (STATE_VERSION 4 stores `f0..f{N-1}` per route record).
pub const N_FEATURES: usize = 7;

/// Human-readable names, index-aligned with [`FeatureVec`] storage.
pub const FEATURE_NAMES: [&str; N_FEATURES] = [
    "row_len_cv",
    "hub_mass_1pct",
    "diag_fraction",
    "block_diag_fraction",
    "log2_n",
    "log2_nnz",
    "log2_d",
];

/// A point in the router's feature space.
///
/// Construct via [`FeatureVec::new`] (applies scaling + sanitization)
/// or [`FeatureVec::from_raw`] (trusts the caller, still sanitizes —
/// used when re-hydrating from a snapshot or a perf record that
/// already stores scaled values).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FeatureVec(pub [f64; N_FEATURES]);

impl FeatureVec {
    /// Build a feature vector from raw structural statistics.
    ///
    /// `cv`, `hub`, `diag`, `block` are the dimensionless fractions
    /// from `StructuralStats`; `n`, `nnz` are matrix dimensions; `d`
    /// is the dense operand width of the job being routed.
    pub fn new(cv: f64, hub: f64, diag: f64, block: f64, n: usize, nnz: usize, d: usize) -> Self {
        Self::from_raw([
            cv,
            hub,
            diag,
            block,
            (n as f64 + 1.0).log2(),
            (nnz as f64 + 1.0).log2(),
            (d as f64 + 1.0).log2(),
        ])
    }

    /// Wrap already-scaled values, replacing non-finite entries with
    /// 0.0 so NaN can never enter training or the snapshot.
    pub fn from_raw(values: [f64; N_FEATURES]) -> Self {
        let mut v = values;
        for x in v.iter_mut() {
            if !x.is_finite() {
                *x = 0.0;
            }
        }
        FeatureVec(v)
    }

    /// The all-zero vector (used for records that carry no features,
    /// e.g. SpGEMM rows in a perf log).
    pub fn zero() -> Self {
        FeatureVec([0.0; N_FEATURES])
    }

    /// True if any entry is non-zero — feature-less records store the
    /// zero vector, and the trainer skips them.
    pub fn is_present(&self) -> bool {
        self.0.iter().any(|&x| x != 0.0)
    }

    /// Invert the `log2(x + 1)` size encoding back to the integer
    /// count. Exact for any count that fits in an `f64` mantissa
    /// (rounding absorbs the ~ulp-level `exp2 ∘ log2` error), so a
    /// perf record emitted from a scaled decision vector re-derives
    /// the identical [`FeatureVec`] when re-trained on.
    pub fn count_of(scaled: f64) -> usize {
        if !scaled.is_finite() || scaled <= 0.0 {
            return 0;
        }
        (scaled.exp2() - 1.0).round() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_scaling_is_monotone_in_size() {
        let small = FeatureVec::new(0.5, 0.0, 0.0, 0.0, 1 << 10, 1 << 13, 4);
        let large = FeatureVec::new(0.5, 0.0, 0.0, 0.0, 1 << 20, 1 << 24, 64);
        assert!(small.0[4] < large.0[4]);
        assert!(small.0[5] < large.0[5]);
        assert!(small.0[6] < large.0[6]);
        // Fractions pass through unscaled.
        assert_eq!(small.0[0], 0.5);
    }

    #[test]
    fn non_finite_inputs_sanitize_to_zero() {
        let v = FeatureVec::new(f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.25, 8, 16, 4);
        assert_eq!(v.0[0], 0.0);
        assert_eq!(v.0[1], 0.0);
        assert_eq!(v.0[2], 0.0);
        assert_eq!(v.0[3], 0.25);
        assert!(v.0.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn zero_vector_is_not_present() {
        assert!(!FeatureVec::zero().is_present());
        assert!(FeatureVec::new(0.1, 0.0, 0.0, 0.0, 0, 0, 0).is_present());
    }

    #[test]
    fn names_align_with_width() {
        assert_eq!(FEATURE_NAMES.len(), N_FEATURES);
    }

    #[test]
    fn count_round_trips_through_the_log_encoding() {
        for n in [0usize, 1, 2, 7, 1023, 1 << 20, 3_141_592, (1 << 40) + 12345] {
            let v = FeatureVec::new(0.0, 0.0, 0.0, 0.0, n, n, 4);
            assert_eq!(FeatureVec::count_of(v.0[4]), n, "n = {n}");
            assert_eq!(FeatureVec::count_of(v.0[5]), n, "nnz = {n}");
        }
        assert_eq!(FeatureVec::count_of(f64::NAN), 0);
        assert_eq!(FeatureVec::count_of(-1.0), 0);
    }
}
