//! Traffic model for the propagation-blocking kernel
//! ([`crate::spmm::PbSpmm`]) — the first roofline line in this crate
//! whose byte count does **not** depend on the sparsity structure.
//!
//! The four paper models (Eqs. 2/3/4/6, [`crate::model::ai_random`]
//! and friends) differ only in how much of `B`'s random re-loading
//! they believe caching absorbs. PB removes the question: both phases
//! stream. Per execution (`C = A·B`, `A` is `n × n` with `nnz` stored
//! values, `B` is `n × d`):
//!
//! * **binned-structure stream** — phase A reads the column-band-major
//!   entry arrays (`col` 4 B + `val` 8 B + `pos` 4 B per nonzero) and
//!   phase B reads `arena_row` (4 B per slot):
//!   [`PB_STRUCT_BYTES_PER_NNZ`]` = 20` bytes per nonzero, paid once
//!   per column-tile pass (`⌈d/dt⌉` passes — the PB analog of the
//!   re-streamed `A` term in [`crate::model::SparsityModel::bytes_tiled`]);
//! * **bucket spill + gather** — every nonzero writes its `8·dt`-byte
//!   partial product to the arena in phase A and reads it back in
//!   phase B; summed over tiles this is width-linear:
//!   `2 · 8 · d · nnz` bytes total;
//! * **dense operands** — `B` is read exactly once (`8·n·d`; band
//!   panels are cache-resident, so there is no re-load term to model)
//!   and `C` is written once (`8·n·d`).
//!
//! All counts use the paper's storage model (8-byte values, 4-byte
//! indices). The spill arena itself never exceeds the kernel's scratch
//! budget; the *model* still charges its full DRAM round trip, which
//! is the honest worst case for `8·nnz·dt` working sets beyond cache.

use crate::model::AiParams;

/// Structural stream bytes per nonzero and per column-tile pass:
/// `col` (4) + `val` (8) + `pos` (4) in phase A, `arena_row` (4) in
/// phase B — the identifiers are the fields of
/// [`crate::spmm::PbSpmm`].
pub const PB_STRUCT_BYTES_PER_NNZ: f64 = 20.0;

/// Modeled DRAM bytes for a PB execution with `dt`-wide column tiles:
/// `⌈d/dt⌉·20·nnz + 16·d·nnz + 16·n·d`. Structure never enters;
/// tiling only re-streams the binned structure (spill/gather and the
/// dense operands are width-linear, so they telescope).
pub fn bytes_pb_tiled(p: AiParams, dt: usize) -> f64 {
    let dt = dt.clamp(1, p.d.max(1));
    let passes = p.d.div_ceil(dt).max(1) as f64;
    let (n, d, nnz) = (p.n as f64, p.d as f64, p.nnz as f64);
    passes * PB_STRUCT_BYTES_PER_NNZ * nnz + 16.0 * d * nnz + 16.0 * n * d
}

/// Untiled PB byte count: `20·nnz + 16·d·nnz + 16·n·d`
/// (= [`bytes_pb_tiled`] at `dt = d`).
pub fn bytes_pb(p: AiParams) -> f64 {
    bytes_pb_tiled(p, p.d)
}

/// PB arithmetic intensity at tile width `dt`.
pub fn ai_pb_tiled(p: AiParams, dt: usize) -> f64 {
    p.flops() / bytes_pb_tiled(p, dt)
}

/// Untiled PB arithmetic intensity — what the planner compares against
/// the structure-sensitive lines. PB pays for its immunity to
/// structure: its AI sits *below* even the random lower bound
/// (`16·d·nnz` of spill traffic vs random's `8·d·nnz` of re-loads),
/// but every one of its bytes moves at streaming bandwidth, which the
/// planner credits through the efficiency prior.
pub fn ai_pb(p: AiParams) -> f64 {
    p.flops() / bytes_pb(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ai_random, bytes_random};

    const P: AiParams = AiParams { n: 1 << 20, d: 16, nnz: 16 << 20 };

    #[test]
    fn closed_form() {
        let (n, d, nnz) = (P.n as f64, P.d as f64, P.nnz as f64);
        let want = 20.0 * nnz + 16.0 * d * nnz + 16.0 * n * d;
        assert!((bytes_pb(P) - want).abs() < 1e-6);
        assert!((ai_pb(P) - P.flops() / want).abs() < 1e-15);
    }

    #[test]
    fn tiled_at_full_width_is_flat_and_narrower_costs_structure_streams() {
        assert_eq!(bytes_pb_tiled(P, P.d), bytes_pb(P));
        // two passes add exactly one more structural stream
        let two = bytes_pb_tiled(P, P.d.div_ceil(2));
        assert!((two - (bytes_pb(P) + PB_STRUCT_BYTES_PER_NNZ * P.nnz as f64)).abs() < 1e-6);
        let mut last = ai_pb_tiled(P, P.d);
        for dt in [8usize, 4, 2, 1] {
            let ai = ai_pb_tiled(P, dt);
            assert!(ai <= last + 1e-15, "AI must not rise as tiles shrink (dt={dt})");
            last = ai;
        }
    }

    #[test]
    fn ai_below_random_lower_bound_by_design() {
        // the spill round trip costs 16·d per nonzero vs random's 8·d
        // re-load, so PB's AI is lower; its win comes from the prior
        // (streaming vs gathering), not from fewer bytes
        assert!(ai_pb(P) < ai_random(P));
        assert!(bytes_pb(P) > bytes_random(P));
    }

    #[test]
    fn tile_width_clamps() {
        assert_eq!(bytes_pb_tiled(P, 0), bytes_pb_tiled(P, 1));
        assert_eq!(bytes_pb_tiled(P, P.d * 10), bytes_pb(P));
    }
}
