//! Band-pass traffic term for out-of-core SpMM
//! ([`crate::sparse::OocSpmm`]) — MODELS.md §9.
//!
//! Band-by-band execution trades residency for passes, exactly like
//! the PB kernel trades random access for passes
//! ([`crate::model::bytes_pb_tiled`]'s `⌈d/dt⌉` re-streams): every row
//! band gathers from `B` independently, so whatever `B`-panel reuse
//! the structural model credited *within* the matrix is lost *between*
//! bands. The honest worst-case charge is one extra full read of the
//! `B` panel (`8·n·d` bytes) per band beyond the first:
//!
//! ```text
//! bytes_ooc(model, p, nb) = model.bytes(p) + (nb − 1) · 8 · n · d
//! ```
//!
//! With one band the term vanishes and the out-of-core line collapses
//! onto the in-memory structural line — the model analog of the
//! bitwise-identity contract in `tests/prop_ooc.rs`. As the budget
//! shrinks (`nb → nrows`), the AI decays toward `2·nnz·d` FLOPs over
//! `≈ 8·n·d·nb` bytes, which is the planner's signal that a matrix is
//! being executed under too small a residency budget.

use crate::model::{AiParams, SparsityModel};

/// Extra DRAM bytes band-by-band execution adds on top of the
/// structural model: one full `B`-panel read (`8·n·d`) per band beyond
/// the first. Zero for `n_bands ≤ 1`.
pub fn bytes_ooc_extra(p: AiParams, n_bands: usize) -> f64 {
    (n_bands.saturating_sub(1)) as f64 * 8.0 * p.n as f64 * p.d as f64
}

/// Modeled total DRAM bytes for an out-of-core execution in `n_bands`
/// passes under the given structural model.
pub fn bytes_ooc(model: &SparsityModel, p: AiParams, n_bands: usize) -> f64 {
    model.bytes(p) + bytes_ooc_extra(p, n_bands)
}

/// Out-of-core arithmetic intensity: the structural AI with the
/// band-pass penalty in the denominator.
pub fn ai_ooc(model: &SparsityModel, p: AiParams, n_bands: usize) -> f64 {
    p.flops() / bytes_ooc(model, p, n_bands)
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: AiParams = AiParams { n: 1 << 16, d: 16, nnz: 1 << 20 };

    #[test]
    fn one_band_collapses_to_in_memory() {
        for m in [SparsityModel::Random, SparsityModel::Diagonal] {
            assert_eq!(bytes_ooc(&m, P, 1), m.bytes(P));
            assert_eq!(ai_ooc(&m, P, 1), m.ai(P));
            assert_eq!(bytes_ooc_extra(P, 0), 0.0);
        }
    }

    #[test]
    fn each_extra_band_charges_one_b_panel() {
        let panel = 8.0 * P.n as f64 * P.d as f64;
        assert_eq!(bytes_ooc_extra(P, 2), panel);
        assert_eq!(bytes_ooc_extra(P, 5), 4.0 * panel);
        let m = SparsityModel::Diagonal;
        assert!(ai_ooc(&m, P, 5) < ai_ooc(&m, P, 2));
        assert!(ai_ooc(&m, P, 2) < m.ai(P));
    }

    #[test]
    fn monotone_in_bands_for_every_model() {
        for m in [
            SparsityModel::Random,
            SparsityModel::Diagonal,
            SparsityModel::Blocked { t: 8, n_blocks: 4096 },
            SparsityModel::ScaleFree { alpha: 2.1, f: 0.001 },
        ] {
            let mut last = f64::INFINITY;
            for nb in [1usize, 2, 4, 16, 256] {
                let ai = ai_ooc(&m, P, nb);
                assert!(ai.is_finite() && ai > 0.0);
                assert!(ai <= last, "{m:?}: AI must not rise with bands");
                last = ai;
            }
        }
    }
}
