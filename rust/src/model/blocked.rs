//! Blocked-sparsity occupancy model: the expected number of nonempty
//! columns per `t × t` block (paper §III-C).

/// `z ≈ E[z] = t·(1 − e^{−D/t})` — expected nonempty columns in a
/// `t`-wide block holding `D` uniformly placed nonzeros (Poisson
/// approximation of the binomial occupancy problem, Mitzenmacher &
/// Upfal).
pub fn expected_z(t: f64, d_per_block: f64) -> f64 {
    if t <= 0.0 {
        return 0.0;
    }
    t * (1.0 - (-d_per_block / t).exp())
}

/// Exact finite-t occupancy `t·(1 − (1 − 1/t)^D)` — used in tests to
/// bound the Poisson approximation error.
pub fn expected_z_exact(t: f64, d_per_block: f64) -> f64 {
    if t <= 1.0 {
        return t.min(d_per_block.min(1.0) * t);
    }
    t * (1.0 - (1.0 - 1.0 / t).powf(d_per_block))
}

/// Block statistics extracted from a concrete CSB matrix, in the form
/// Eq. 4 consumes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockStats {
    /// Block dimension `t`.
    pub t: usize,
    /// Number of nonzero blocks `N`.
    pub n_blocks: usize,
    /// Average nonzeros per nonzero block `D = nnz/N`.
    pub avg_density: f64,
    /// Modeled `z = t(1 − e^{−D/t})`.
    pub z_model: f64,
    /// Empirical mean occupied columns per block.
    pub z_measured: f64,
}

impl BlockStats {
    /// Extract the stats from a CSB matrix.
    pub fn of(csb: &crate::sparse::Csb) -> BlockStats {
        let t = csb.block_dim;
        let n_blocks = csb.n_nonzero_blocks();
        let d = csb.avg_block_density();
        BlockStats {
            t,
            n_blocks,
            avg_density: d,
            z_model: expected_z(t as f64, d),
            z_measured: csb.measured_z(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};
    use crate::sparse::Csb;

    #[test]
    fn z_limits() {
        // D << t: every nonzero lands in its own column -> z ≈ D
        assert!((expected_z(4096.0, 2.0) - 2.0).abs() < 0.01);
        // D >> t: all columns occupied -> z -> t
        assert!((expected_z(64.0, 10_000.0) - 64.0).abs() < 1e-6);
        // zero density
        assert_eq!(expected_z(64.0, 0.0), 0.0);
    }

    #[test]
    fn poisson_approx_close_to_exact() {
        for t in [16.0, 256.0, 4096.0] {
            for d in [1.0, 10.0, 100.0, 1000.0] {
                let a = expected_z(t, d);
                let e = expected_z_exact(t, d);
                assert!((a - e).abs() / e.max(1.0) < 0.05, "t={t} D={d}: {a} vs {e}");
            }
        }
    }

    #[test]
    fn z_monotone_in_density() {
        let mut last = 0.0;
        for d in [1.0, 2.0, 8.0, 64.0, 512.0] {
            let z = expected_z(256.0, d);
            assert!(z > last);
            last = z;
        }
    }

    #[test]
    fn model_matches_random_matrix_measurement() {
        // ER nonzeros are uniform within blocks, the model's exact
        // assumption — z_model should track z_measured tightly.
        let mut rng = Prng::new(100);
        let csr = erdos_renyi(2048, 2048, 16.0, &mut rng);
        let csb = Csb::from_csr_with_block(&csr, 256);
        let st = BlockStats::of(&csb);
        let rel = (st.z_model - st.z_measured).abs() / st.z_measured;
        assert!(rel < 0.05, "model {} vs measured {}", st.z_model, st.z_measured);
    }
}
