//! Scale-free hub-mass model (paper Eq. 5 and the appendix
//! derivation).

/// Fraction of nonzeros owned by the top-`f` fraction of nodes by
/// degree, for a power law with exponent `alpha`:
/// `nnz_hub / nnz = f^{(α−2)/(α−1)}` (appendix Eq. 17).
///
/// Valid for `alpha > 2` (finite mean degree); clamps `f` into
/// `[0, 1]`.
pub fn hub_mass_fraction(alpha: f64, f: f64) -> f64 {
    let f = f.clamp(0.0, 1.0);
    if f == 0.0 {
        return 0.0;
    }
    if alpha <= 2.0 {
        // α→2⁺: exponent → 0 ⇒ all edge mass concentrates in hubs.
        return 1.0;
    }
    f.powf((alpha - 2.0) / (alpha - 1.0))
}

/// Parameters of the hub model, bundled for reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HubParams {
    pub alpha: f64,
    /// Hub fraction of nodes (paper experiments: 0.001).
    pub f: f64,
}

impl HubParams {
    /// The paper's experimental setting: hubs = top 0.1% of nodes.
    pub const PAPER: HubParams = HubParams { alpha: 2.2, f: 0.001 };

    /// `nnz_hub` for a concrete nnz (Eq. 5).
    pub fn nnz_hub(&self, nnz: usize) -> f64 {
        nnz as f64 * hub_mass_fraction(self.alpha, self.f)
    }

    /// `n_hub = f·n`.
    pub fn n_hub(&self, n: usize) -> f64 {
        self.f * n as f64
    }
}

/// Empirical hub mass: sort degrees descending, take the top-`f`
/// fraction of nodes, return their share of total degree. Used to
/// validate Eq. 17 against generated matrices.
pub fn measured_hub_mass(degrees: &[usize], f: f64) -> f64 {
    if degrees.is_empty() {
        return 0.0;
    }
    let mut d: Vec<usize> = degrees.to_vec();
    d.sort_unstable_by(|a, b| b.cmp(a));
    let n_hub = ((d.len() as f64 * f).ceil() as usize).clamp(1, d.len());
    let total: f64 = d.iter().map(|&x| x as f64).sum();
    if total == 0.0 {
        return 0.0;
    }
    let hub: f64 = d[..n_hub].iter().map(|&x| x as f64).sum();
    hub / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, ChungLuParams, Prng};

    #[test]
    fn paper_appendix_example() {
        // α = 2.2, f = 1% ⇒ nnz_hub/nnz = 0.01^(0.2/1.2) ≈ 0.464
        let r = hub_mass_fraction(2.2, 0.01);
        assert!((r - 0.464).abs() < 0.005, "{r}");
    }

    #[test]
    fn limits() {
        assert_eq!(hub_mass_fraction(2.5, 0.0), 0.0);
        assert_eq!(hub_mass_fraction(2.5, 1.0), 1.0);
        // α ≤ 2 concentrates everything
        assert_eq!(hub_mass_fraction(2.0, 0.001), 1.0);
        // α large: hubs hold ~their node share
        let r = hub_mass_fraction(50.0, 0.01);
        assert!(r < 0.02, "{r}");
    }

    #[test]
    fn monotone_in_f_and_alpha() {
        assert!(hub_mass_fraction(2.3, 0.01) > hub_mass_fraction(2.3, 0.001));
        assert!(hub_mass_fraction(2.1, 0.01) > hub_mass_fraction(2.6, 0.01));
    }

    #[test]
    fn measured_mass_tracks_model_on_generated_graph() {
        let mut rng = Prng::new(110);
        let alpha = 2.2;
        let m = chung_lu(
            ChungLuParams { n: 20_000, alpha, avg_deg: 16.0, k_min: 2.0 },
            &mut rng,
        );
        let degrees: Vec<usize> = (0..m.nrows).map(|r| m.row_len(r)).collect();
        let f = 0.01;
        let measured = measured_hub_mass(&degrees, f);
        let modeled = hub_mass_fraction(alpha, f);
        // generation truncates the tail (weight cap), so allow slack;
        // the point is the order of magnitude and the concentration
        assert!(
            measured > modeled * 0.4 && measured < modeled * 1.8,
            "measured {measured} vs model {modeled}"
        );
    }

    #[test]
    fn measured_mass_uniform_graph_is_f() {
        let degrees = vec![10usize; 1000];
        let m = measured_hub_mass(&degrees, 0.05);
        assert!((m - 0.05).abs() < 0.01);
    }
}
