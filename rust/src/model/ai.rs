//! Arithmetic-intensity formulas (paper Eqs. 2, 3, 4, 6).
//!
//! All byte counts follow the paper's storage model: 8-byte values,
//! 4-byte indices (§III). `FLOP = 2·d·nnz` (Eq. 1).

use crate::model::blocked::expected_z;
use crate::model::scalefree::hub_mass_fraction;

/// Shared problem parameters: `A` is `n × n` with `nnz` stored values,
/// `B` is `n × d`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AiParams {
    pub n: usize,
    pub d: usize,
    pub nnz: usize,
}

impl AiParams {
    pub fn new(n: usize, d: usize, nnz: usize) -> Self {
        AiParams { n, d, nnz }
    }
    /// `FLOP = 2·d·nnz` (Eq. 1).
    pub fn flops(&self) -> f64 {
        2.0 * self.d as f64 * self.nnz as f64
    }
}

/// Which of the paper's four structural regimes a model invocation
/// refers to, with the regime-specific parameters attached.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SparsityModel {
    /// Eq. 2 — uniformly random nonzeros, zero reuse of B (lower
    /// bound).
    Random,
    /// Eq. 3 — banded/diagonal, perfect reuse of B (upper bound).
    Diagonal,
    /// Eq. 4 — block-structured; `n_blocks` nonzero `t × t` blocks.
    Blocked { t: usize, n_blocks: usize },
    /// Eq. 6 — power-law degree distribution with exponent `alpha`;
    /// hubs are the top `f` fraction of nodes (paper: f = 0.1%).
    ScaleFree { alpha: f64, f: f64 },
}

impl SparsityModel {
    /// Arithmetic intensity (FLOPs/byte) under this model.
    pub fn ai(&self, p: AiParams) -> f64 {
        match *self {
            SparsityModel::Random => ai_random(p),
            SparsityModel::Diagonal => ai_diagonal(p),
            SparsityModel::Blocked { t, n_blocks } => ai_blocked(p, t, n_blocks),
            SparsityModel::ScaleFree { alpha, f } => ai_scalefree(p, alpha, f),
        }
    }

    /// Modeled total DRAM bytes (the AI denominator).
    pub fn bytes(&self, p: AiParams) -> f64 {
        match *self {
            SparsityModel::Random => bytes_random(p),
            SparsityModel::Diagonal => bytes_diagonal(p),
            SparsityModel::Blocked { t, n_blocks } => bytes_blocked(p, t, n_blocks),
            SparsityModel::ScaleFree { alpha, f } => bytes_scalefree(p, alpha, f),
        }
    }

    /// The byte count split into `(A traffic, B traffic)` — `C` is
    /// always `8·n·d` on top. The split is what the tile-aware model
    /// below re-scales: tiling multiplies the A term (one pass per
    /// tile) and leaves B and C invariant (every model's B term is
    /// linear in the dense width, so per-tile traffic at width `dt`
    /// summed over `⌈d/dt⌉` tiles telescopes back to the full-width
    /// term). The pipeline model ([`crate::model::bytes_pipeline`])
    /// consumes the same split from the other side: when a chained
    /// op's `B` is the previous op's cache-resident output, the B term
    /// is the traffic that disappears.
    pub fn traffic_split(&self, p: AiParams) -> (f64, f64) {
        let (n, d, nnz) = (p.n as f64, p.d as f64, p.nnz as f64);
        match *self {
            SparsityModel::Random => (12.0 * nnz, 8.0 * d * nnz),
            SparsityModel::Diagonal => (12.0 * nnz, 8.0 * n * d),
            SparsityModel::Blocked { t, n_blocks } => {
                let nb = n_blocks.max(1) as f64;
                let z = expected_z(t as f64, nnz / nb);
                (8.0 * nnz, 2.0 * d * nb * z)
            }
            SparsityModel::ScaleFree { alpha, f } => {
                let nnz_hub = nnz * hub_mass_fraction(alpha, f);
                (12.0 * nnz, 8.0 * d * (nnz - nnz_hub) + 8.0 * d * f * n)
            }
        }
    }

    /// Modeled DRAM bytes when `B`/`C` are processed in `dt`-wide
    /// column tiles: `A` is re-streamed once per tile
    /// (`⌈d/dt⌉ ×` its term), `B` traffic is width-linear so tiling
    /// leaves it unchanged, and `C` is still written once. `dt = d`
    /// reproduces [`SparsityModel::bytes`] exactly. What tiling *buys*
    /// is not fewer modeled bytes but a smaller working set
    /// (`8·n·dt`), which the cache-aware roofline rewards with a
    /// faster bandwidth ceiling — see
    /// [`crate::model::CacheAwareRoofline`].
    pub fn bytes_tiled(&self, p: AiParams, dt: usize) -> f64 {
        let dt = dt.clamp(1, p.d.max(1));
        let tiles = p.d.div_ceil(dt).max(1) as f64;
        let (a_bytes, b_bytes) = self.traffic_split(p);
        tiles * a_bytes + b_bytes + 8.0 * p.n as f64 * p.d as f64
    }

    /// Arithmetic intensity at tile width `dt`
    /// (`ai_tiled(p, d) == ai(p)`). Monotone non-increasing as `dt`
    /// shrinks: narrower tiles re-stream `A` more often.
    pub fn ai_tiled(&self, p: AiParams, dt: usize) -> f64 {
        p.flops() / self.bytes_tiled(p, dt)
    }

    /// Human-readable name used in figures.
    pub fn name(&self) -> &'static str {
        match self {
            SparsityModel::Random => "Random",
            SparsityModel::Diagonal => "Diagonal",
            SparsityModel::Blocked { .. } => "Blocked",
            SparsityModel::ScaleFree { .. } => "Scale-free",
        }
    }
}

/// Eq. 2 denominator: `(12 + 8d)·nnz + 8nd`.
///
/// `A` costs ≈12 bytes/nonzero (8 value + 4 column index; the paper
/// folds the `(n+1)·4` row-pointer bytes into the ≈), every nonzero
/// re-loads a d-wide row of `B` (no reuse), and `C` is written once.
pub fn bytes_random(p: AiParams) -> f64 {
    let (n, d, nnz) = (p.n as f64, p.d as f64, p.nnz as f64);
    (12.0 + 8.0 * d) * nnz + 8.0 * n * d
}

/// Eq. 2 — AI under random sparsity (the paper's lower bound).
pub fn ai_random(p: AiParams) -> f64 {
    p.flops() / bytes_random(p)
}

/// Eq. 3 denominator: `12·nnz + 16nd` — `A` streamed once, `B` loaded
/// into cache exactly once (8nd) and fully reused, `C` written once
/// (8nd).
pub fn bytes_diagonal(p: AiParams) -> f64 {
    let (n, d, nnz) = (p.n as f64, p.d as f64, p.nnz as f64);
    12.0 * nnz + 16.0 * n * d
}

/// Eq. 3 — AI under diagonal/banded sparsity (the paper's upper
/// bound).
pub fn ai_diagonal(p: AiParams) -> f64 {
    p.flops() / bytes_diagonal(p)
}

/// Eq. 4 denominator: `8·nnz + 2·d·N·z + 8nd` with
/// `z = t(1 − e^{−D/t})`, `D = nnz/N`.
///
/// `B` traffic is `8·d·N·z` scaled by the paper's ¼ cache-reuse
/// heuristic → `2dNz`. Note the published equation charges `8·nnz`
/// for `A` even though the surrounding text derives `12·nnz`; we
/// implement the equation as printed (and expose
/// [`ai_blocked_text_variant`] with the 12-byte A term for the
/// ablation in EXPERIMENTS.md).
pub fn bytes_blocked(p: AiParams, t: usize, n_blocks: usize) -> f64 {
    let (n, d, nnz) = (p.n as f64, p.d as f64, p.nnz as f64);
    let nb = n_blocks.max(1) as f64;
    let z = expected_z(t as f64, nnz / nb);
    8.0 * nnz + 2.0 * d * nb * z + 8.0 * n * d
}

/// Eq. 4 — AI under block sparsity.
pub fn ai_blocked(p: AiParams, t: usize, n_blocks: usize) -> f64 {
    p.flops() / bytes_blocked(p, t, n_blocks)
}

/// Variant of Eq. 4 with the text's `12·nnz` A-traffic term (the
/// paper's prose and equation disagree; see EXPERIMENTS.md §Ablations).
pub fn ai_blocked_text_variant(p: AiParams, t: usize, n_blocks: usize) -> f64 {
    let (n, d, nnz) = (p.n as f64, p.d as f64, p.nnz as f64);
    let nb = n_blocks.max(1) as f64;
    let z = expected_z(t as f64, nnz / nb);
    p.flops() / (12.0 * nnz + 2.0 * d * nb * z + 8.0 * n * d)
}

/// Eq. 6 denominator:
/// `12nnz + 8d(nnz − nnz_hub) + 8d·n_hub + 8nd`, with
/// `nnz_hub = nnz·f^{(α−2)/(α−1)}` (Eq. 5) and `n_hub = f·n`.
///
/// Hub rows of `B` stay cached (paid once, `8d·n_hub`); the non-hub
/// remainder behaves like the random model.
pub fn bytes_scalefree(p: AiParams, alpha: f64, f: f64) -> f64 {
    let (n, d, nnz) = (p.n as f64, p.d as f64, p.nnz as f64);
    let nnz_hub = nnz * hub_mass_fraction(alpha, f);
    let n_hub = f * n;
    12.0 * nnz + 8.0 * d * (nnz - nnz_hub) + 8.0 * d * n_hub + 8.0 * n * d
}

/// Eq. 6 — AI under scale-free sparsity.
pub fn ai_scalefree(p: AiParams, alpha: f64, f: f64) -> f64 {
    p.flops() / bytes_scalefree(p, alpha, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: AiParams = AiParams { n: 1 << 22, d: 16, nnz: 41_942_990 };

    #[test]
    fn flops_eq1() {
        assert_eq!(P.flops(), 2.0 * 16.0 * 41_942_990.0);
    }

    #[test]
    fn random_matches_closed_form() {
        // AI(Random) = 2d·nnz / ((12+8d)nnz + 8nd)
        let ai = ai_random(P);
        let d = 16.0;
        let nnz = 41_942_990.0;
        let n = (1u64 << 22) as f64;
        let want = 2.0 * d * nnz / ((12.0 + 8.0 * d) * nnz + 8.0 * n * d);
        assert!((ai - want).abs() < 1e-15);
        // sanity: random AI is below 2/8 = 0.25 * d/(d+...) — always < 0.25·?
        assert!(ai < 0.25);
    }

    #[test]
    fn diagonal_exceeds_random() {
        assert!(ai_diagonal(P) > ai_random(P));
    }

    #[test]
    fn diagonal_d_scaling_saturates() {
        // as d → ∞ with nnz fixed, AI(Diagonal) → 2·nnz/(16n)... check monotone in d
        let lo = ai_diagonal(AiParams { d: 1, ..P });
        let hi = ai_diagonal(AiParams { d: 64, ..P });
        assert!(hi > lo);
        // limit: 2 d nnz/(12nnz + 16nd) -> 2nnz/(16n) as d->inf
        let limit = 2.0 * P.nnz as f64 / (16.0 * P.n as f64);
        let big = ai_diagonal(AiParams { d: 1 << 20, ..P });
        assert!((big - limit).abs() / limit < 0.01);
    }

    #[test]
    fn blocked_between_random_and_diagonal() {
        // dense-ish blocks: D large -> z ~ t -> big reuse
        let t = 4096usize;
        let n_blocks = P.nnz / 512; // D = 512
        let ai = ai_blocked(P, t, n_blocks);
        assert!(ai > ai_random(P), "blocked {ai} random {}", ai_random(P));
        assert!(ai < ai_diagonal(P), "blocked {ai} diagonal {}", ai_diagonal(P));
    }

    #[test]
    fn blocked_degenerate_single_entry_blocks() {
        // D = 1: z = t(1-e^{-1/t}) ≈ 1 → B traffic ≈ 2·d·nnz (the ¼ of
        // random's 8d·nnz); AI approaches (but beats) random
        let ai = ai_blocked(P, 1024, P.nnz);
        assert!(ai > ai_random(P));
        assert!(ai < ai_diagonal(P));
    }

    #[test]
    fn scalefree_between_random_and_diagonal() {
        let ai = ai_scalefree(P, 2.2, 0.001);
        assert!(ai > ai_random(P));
        assert!(ai < ai_diagonal(P));
    }

    #[test]
    fn scalefree_more_hubs_higher_ai() {
        let a = ai_scalefree(P, 2.2, 0.001);
        let b = ai_scalefree(P, 2.2, 0.01);
        assert!(b > a);
    }

    #[test]
    fn scalefree_alpha_near_2_concentrates() {
        // α→2: hub mass → 1 → less B traffic → higher AI
        let heavy = ai_scalefree(P, 2.05, 0.001);
        let light = ai_scalefree(P, 2.9, 0.001);
        assert!(heavy > light);
    }

    #[test]
    fn model_enum_dispatch() {
        assert_eq!(SparsityModel::Random.ai(P), ai_random(P));
        assert_eq!(SparsityModel::Diagonal.ai(P), ai_diagonal(P));
        let m = SparsityModel::Blocked { t: 1024, n_blocks: P.nnz / 64 };
        assert_eq!(m.ai(P), ai_blocked(P, 1024, P.nnz / 64));
        let m = SparsityModel::ScaleFree { alpha: 2.2, f: 0.001 };
        assert_eq!(m.ai(P), ai_scalefree(P, 2.2, 0.001));
        assert_eq!(m.name(), "Scale-free");
    }

    #[test]
    fn bytes_equal_flops_over_ai() {
        let b = bytes_random(P);
        assert!((P.flops() / ai_random(P) - b).abs() / b < 1e-12);
    }

    #[test]
    fn tiled_at_full_width_reproduces_flat_formulas() {
        let models = [
            SparsityModel::Random,
            SparsityModel::Diagonal,
            SparsityModel::Blocked { t: 1024, n_blocks: P.nnz / 64 },
            SparsityModel::ScaleFree { alpha: 2.2, f: 0.001 },
        ];
        for m in models {
            let flat = m.bytes(P);
            let tiled = m.bytes_tiled(P, P.d);
            assert!((flat - tiled).abs() / flat < 1e-12, "{:?}", m);
            assert!((m.ai(P) - m.ai_tiled(P, P.d)).abs() < 1e-12);
        }
    }

    #[test]
    fn narrower_tiles_cost_more_a_traffic() {
        let m = SparsityModel::Random;
        let mut last = m.ai_tiled(P, P.d);
        for dt in [8usize, 4, 2, 1] {
            let ai = m.ai_tiled(P, dt);
            assert!(ai <= last + 1e-15, "AI must not rise as tiles shrink (dt={dt})");
            last = ai;
        }
        // the extra traffic is exactly the repeated A streams
        let two_pass = m.bytes_tiled(P, P.d.div_ceil(2));
        assert!((two_pass - (m.bytes(P) + 12.0 * P.nnz as f64)).abs() < 1e-6);
    }

    #[test]
    fn tile_width_clamps_to_valid_range() {
        let m = SparsityModel::Diagonal;
        assert_eq!(m.bytes_tiled(P, 0), m.bytes_tiled(P, 1));
        assert_eq!(m.bytes_tiled(P, P.d * 10), m.bytes(P));
    }

    #[test]
    fn text_variant_lower_than_printed_eq4() {
        let ai_eq = ai_blocked(P, 1024, P.nnz / 100);
        let ai_txt = ai_blocked_text_variant(P, 1024, P.nnz / 100);
        assert!(ai_txt < ai_eq);
    }
}
