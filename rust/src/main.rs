//! `repro` — CLI entry point for the spmm-roofline reproduction.
//! See `repro --help` (or `cli::usage`) for commands.

fn main() {
    if let Err(e) = spmm_roofline::cli::run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
