//! Wall-clock timer.

use std::time::Instant;

/// A simple monotonic wall-clock timer.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since [`Timer::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed since [`Timer::start`].
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
