//! Measurement utilities: timers, summary statistics, GFLOP/s
//! computation, and a tiny benchmark loop used by the harness and the
//! `rust/benches/*` binaries (criterion is unavailable offline; this is
//! the stand-in).

mod stats;
mod timer;

pub use stats::{Summary, ci95_halfwidth, mean, median, stddev};
pub use timer::Timer;

/// FLOP count of an SpMM `C = A·B`: one multiply + one add per stored
/// nonzero per dense column (paper Eq. 1, `FLOP = 2·d·nnz`).
pub fn spmm_flops(nnz: usize, d: usize) -> f64 {
    2.0 * nnz as f64 * d as f64
}

/// Convert a FLOP count and elapsed seconds to GFLOP/s.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    flops / secs / 1e9
}

/// Result of [`bench_loop`]: per-iteration seconds plus the derived
/// summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Raw per-iteration wall-clock seconds (after warmup).
    pub samples: Vec<f64>,
    /// Summary statistics over `samples`.
    pub summary: Summary,
}

impl BenchResult {
    /// Median seconds per iteration — the robust statistic every report
    /// uses.
    pub fn median_secs(&self) -> f64 {
        self.summary.median
    }
    /// Minimum ("best") seconds per iteration.
    pub fn min_secs(&self) -> f64 {
        self.summary.min
    }
}

/// Run `f` for `warmup` untimed iterations then `iters` timed
/// iterations, returning per-iteration timings.
///
/// The closure receives the (0-based) timed-iteration index so callers
/// can rotate buffers if needed.
pub fn bench_loop<F: FnMut(usize)>(warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for i in 0..warmup {
        f(i);
    }
    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let t = Timer::start();
        f(i);
        samples.push(t.elapsed_secs());
    }
    let summary = Summary::of(&samples);
    BenchResult { samples, summary }
}

/// Adaptive variant: keeps iterating until at least `min_iters`
/// iterations *and* `min_secs` of cumulative measured time have
/// accumulated (capped at `max_iters`). Mirrors what criterion does,
/// cheaply.
pub fn bench_adaptive<F: FnMut(usize)>(
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    min_secs: f64,
    mut f: F,
) -> BenchResult {
    for i in 0..warmup {
        f(i);
    }
    let mut samples = Vec::new();
    let mut total = 0.0;
    let mut i = 0;
    while i < max_iters && (i < min_iters || total < min_secs) {
        let t = Timer::start();
        f(i);
        let dt = t.elapsed_secs();
        samples.push(dt);
        total += dt;
        i += 1;
    }
    let summary = Summary::of(&samples);
    BenchResult { samples, summary }
}

/// [`bench_adaptive`] over a *fallible* body: the first error stops
/// further work (remaining iterations no-op while the loop drains)
/// and is returned instead of the timings. This is the one place the
/// "capture the first `Err` inside a timing loop" pattern lives —
/// every measurement path (engine submit, autotune explore, harness
/// cells) goes through it, so a failing kernel surfaces as `Err`
/// rather than panicking through the shared worker pool.
pub fn bench_adaptive_checked<E, F>(
    warmup: usize,
    min_iters: usize,
    max_iters: usize,
    min_secs: f64,
    mut f: F,
) -> std::result::Result<BenchResult, E>
where
    F: FnMut(usize) -> std::result::Result<(), E>,
{
    let mut err: Option<E> = None;
    let r = bench_adaptive(warmup, min_iters, max_iters, min_secs, |i| {
        if err.is_none() {
            if let Err(e) = f(i) {
                err = Some(e);
            }
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok(r),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_matches_eq1() {
        // FLOP = 2 d nnz
        assert_eq!(spmm_flops(100, 4), 800.0);
        assert_eq!(spmm_flops(0, 64), 0.0);
    }

    #[test]
    fn gflops_basic() {
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(gflops(1.0, 0.0), 0.0);
    }

    #[test]
    fn bench_adaptive_checked_returns_first_error_and_stops_work() {
        // succeeds, then fails on the second timed iteration: the
        // error surfaces and no further body work runs
        let mut calls = 0usize;
        let r = bench_adaptive_checked(0, 4, 16, 0.0, |i| {
            calls += 1;
            if i >= 1 {
                Err(format!("boom at {i}"))
            } else {
                Ok(())
            }
        });
        assert_eq!(r.unwrap_err(), "boom at 1");
        assert_eq!(calls, 2, "after the first error the body must not re-run");
        // the all-Ok path hands back the timings unchanged
        let r = bench_adaptive_checked::<String, _>(1, 3, 12, 0.0, |_| Ok(()));
        assert!(r.unwrap().samples.len() >= 3);
    }

    #[test]
    fn bench_loop_counts() {
        let mut calls = 0usize;
        let r = bench_loop(2, 5, |_| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(r.samples.len(), 5);
        assert!(r.median_secs() >= 0.0);
    }

    #[test]
    fn bench_adaptive_bounds() {
        let mut calls = 0usize;
        let r = bench_adaptive(0, 3, 10, 0.0, |_| calls += 1);
        assert_eq!(r.samples.len(), 3);
        let r = bench_adaptive(0, 1, 4, f64::INFINITY, |_| calls += 1);
        assert_eq!(r.samples.len(), 4);
    }
}
