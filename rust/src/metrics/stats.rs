//! Summary statistics over benchmark samples.
//!
//! All entry points tolerate non-finite samples: a zero-elapsed timer
//! or a failed run can yield `NaN`/`inf` GFLOP/s, and a single such
//! sample must degrade one cell of a report, not kill a whole batch.
//! Sorting uses `f64::total_cmp` (never panics), and [`Summary::of`]
//! computes its statistics over the finite samples only, flagging how
//! many were dropped in [`Summary::n_nonfinite`].

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for fewer than two samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Median (0.0 for empty input). Uses the midpoint convention for even
/// lengths. Sorts with the IEEE total order, so `NaN` samples sort to
/// the ends instead of panicking the comparator; callers who need
/// NaN-free statistics should go through [`Summary::of`], which
/// filters them.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Half-width of an approximate 95% confidence interval on the mean
/// (normal approximation, `1.96·s/√n`).
pub fn ci95_halfwidth(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Five-number-ish summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Samples provided (finite and not).
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    /// 95% CI half-width on the mean.
    pub ci95: f64,
    /// Samples dropped for being `NaN`/`inf` — nonzero flags a
    /// degenerate measurement (zero-elapsed timer, failed run).
    pub n_nonfinite: usize,
}

impl Summary {
    /// Compute the summary of `xs`. Non-finite samples are excluded
    /// from every statistic and counted in `n_nonfinite`.
    pub fn of(xs: &[f64]) -> Self {
        let finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in &finite {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        if finite.is_empty() {
            mn = 0.0;
            mx = 0.0;
        }
        Summary {
            n: xs.len(),
            mean: mean(&finite),
            median: median(&finite),
            stddev: stddev(&finite),
            min: mn,
            max: mx,
            ci95: ci95_halfwidth(&finite),
            n_nonfinite: xs.len() - finite.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn mean_stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // sample stddev of this classic set is sqrt(32/7)
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_minmax() {
        let s = Summary::of(&[1.0, -2.0, 3.0]);
        assert_eq!(s.min, -2.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
        assert_eq!(s.n_nonfinite, 0);
    }

    #[test]
    fn degenerate_inputs() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(ci95_halfwidth(&[1.0]), 0.0);
    }

    #[test]
    fn median_tolerates_nan_without_panicking() {
        // regression: partial_cmp().unwrap() panicked here
        let m = median(&[2.0, f64::NAN, 1.0, 3.0]);
        assert!(m.is_finite() || m.is_nan()); // no panic is the contract
        // total order puts NaN last, so the finite median survives odd n
        assert_eq!(median(&[2.0, 1.0, f64::NAN, 3.0, 0.0]), 2.0);
    }

    #[test]
    fn summary_filters_and_flags_nonfinite() {
        let s = Summary::of(&[1.0, f64::NAN, 3.0, f64::INFINITY, 2.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.n_nonfinite, 2);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!((s.min, s.max), (1.0, 3.0));
        assert!(s.stddev.is_finite() && s.ci95.is_finite());
    }

    #[test]
    fn summary_of_all_nonfinite_is_zeroed() {
        let s = Summary::of(&[f64::NAN, f64::NEG_INFINITY]);
        assert_eq!(s.n, 2);
        assert_eq!(s.n_nonfinite, 2);
        assert_eq!((s.mean, s.median, s.min, s.max), (0.0, 0.0, 0.0, 0.0));
    }
}
