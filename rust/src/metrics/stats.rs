//! Summary statistics over benchmark samples.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for fewer than two samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Median (0.0 for empty input). Uses the midpoint convention for even
/// lengths.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Half-width of an approximate 95% confidence interval on the mean
/// (normal approximation, `1.96·s/√n`).
pub fn ci95_halfwidth(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Five-number-ish summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    /// 95% CI half-width on the mean.
    pub ci95: f64,
}

impl Summary {
    /// Compute the summary of `xs`.
    pub fn of(xs: &[f64]) -> Self {
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        if xs.is_empty() {
            mn = 0.0;
            mx = 0.0;
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            stddev: stddev(xs),
            min: mn,
            max: mx,
            ci95: ci95_halfwidth(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn mean_stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // sample stddev of this classic set is sqrt(32/7)
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_minmax() {
        let s = Summary::of(&[1.0, -2.0, 3.0]);
        assert_eq!(s.min, -2.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn degenerate_inputs() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(ci95_halfwidth(&[1.0]), 0.0);
    }
}
