//! The artifact manifest: which AOT-compiled modules exist, with the
//! static shapes they were lowered for.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.toml` in the
//! TOML-lite dialect `config::toml_lite` parses; each `[section]` is
//! one artifact.

use std::path::{Path, PathBuf};

use crate::config::TomlLite;
use crate::error::{Error, Result};

/// What a compiled module computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(cols s32[n,w], vals f64[n,w], b f64[n,d]) -> (f64[n,d],)`
    EllSpmm,
    /// `(cols, vals, b, w f64[d,dout]) -> (f64[n,dout],)`
    GcnLayer,
    /// Blocked-ELL: `(bcols s32[nbr,mb], blocks f64[nbr,mb,bs,bs],
    /// b f64[n,d]) -> (f64[n,d],)` with `n = nbr·bs`; `width` holds
    /// `mb` and `bs` the tile edge.
    BellSpmm,
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub n: usize,
    pub width: usize,
    pub d: usize,
    /// Output feature width (GCN only).
    pub dout: Option<usize>,
    /// Dense tile edge (blocked-ELL only).
    pub bs: Option<usize>,
    /// Absolute path to the HLO text file.
    pub path: PathBuf,
}

/// All artifacts found in a directory.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.toml`. A missing directory or manifest is
    /// an [`Error::MissingArtifact`] — callers treat the XLA backend
    /// as unavailable rather than failing hard.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<ArtifactManifest> {
        let dir = dir.as_ref();
        let mpath = dir.join("manifest.toml");
        if !mpath.exists() {
            return Err(Error::MissingArtifact(mpath.display().to_string()));
        }
        let text = std::fs::read_to_string(&mpath)?;
        let t = TomlLite::parse(&text)?;
        let mut artifacts = Vec::new();
        for sec in t.sections() {
            let get_num = |k: &str| -> Result<Option<usize>> {
                Ok(t.get_f64(&format!("{sec}.{k}"))?.map(|x| x as usize))
            };
            let kind = match t.get_str(&format!("{sec}.kind"))? {
                Some("ell_spmm") => ArtifactKind::EllSpmm,
                Some("gcn_layer") => ArtifactKind::GcnLayer,
                Some("bell_spmm") => ArtifactKind::BellSpmm,
                Some(other) => {
                    return Err(Error::Parse(format!("{sec}: unknown kind '{other}'")))
                }
                None => return Err(Error::Parse(format!("{sec}: missing kind"))),
            };
            let rel = t
                .get_str(&format!("{sec}.path"))?
                .ok_or_else(|| Error::Parse(format!("{sec}: missing path")))?;
            let path = dir.join(rel);
            if !path.exists() {
                return Err(Error::MissingArtifact(path.display().to_string()));
            }
            artifacts.push(ArtifactSpec {
                name: sec.clone(),
                kind,
                n: get_num("n")?.ok_or_else(|| Error::Parse(format!("{sec}: missing n")))?,
                width: get_num("width")?
                    .ok_or_else(|| Error::Parse(format!("{sec}: missing width")))?,
                d: get_num("d")?.ok_or_else(|| Error::Parse(format!("{sec}: missing d")))?,
                dout: get_num("dout")?,
                bs: get_num("bs")?,
                path,
            });
        }
        Ok(ArtifactManifest { artifacts })
    }

    /// Find the ELL-SpMM artifact for exact `(n, width, d)`.
    pub fn find_ell(&self, n: usize, width: usize, d: usize) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| {
            a.kind == ArtifactKind::EllSpmm && a.n == n && a.width == width && a.d == d
        })
    }

    /// Smallest ELL artifact that *fits* a problem: `n == a.n`,
    /// `width <= a.width`, `d == a.d` (rows cannot pad cheaply, slots
    /// can).
    pub fn find_ell_fitting(&self, n: usize, width: usize, d: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::EllSpmm && a.n == n && a.width >= width && a.d == d)
            .min_by_key(|a| a.width)
    }

    /// All artifacts of one kind.
    pub fn of_kind(&self, kind: ArtifactKind) -> impl Iterator<Item = &ArtifactSpec> {
        self.artifacts.iter().filter(move |a| a.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.toml"), body).unwrap();
        for f in files {
            let mut fh = std::fs::File::create(dir.join(f)).unwrap();
            writeln!(fh, "HloModule fake").unwrap();
        }
    }

    #[test]
    fn loads_entries() {
        let dir = std::env::temp_dir().join("spmm_manifest_test_a");
        write_manifest(
            &dir,
            "[ell_a]\nkind = \"ell_spmm\"\nn = 64\nwidth = 4\nd = 8\npath = \"a.hlo.txt\"\n\
             [gcn_b]\nkind = \"gcn_layer\"\nn = 64\nwidth = 4\nd = 8\ndout = 2\npath = \"b.hlo.txt\"\n",
            &["a.hlo.txt", "b.hlo.txt"],
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert!(m.find_ell(64, 4, 8).is_some());
        assert!(m.find_ell(64, 4, 9).is_none());
        assert_eq!(m.of_kind(ArtifactKind::GcnLayer).count(), 1);
        let g = &m.artifacts[1];
        assert_eq!(g.dout, Some(2));
    }

    #[test]
    fn fitting_prefers_smallest_width() {
        let dir = std::env::temp_dir().join("spmm_manifest_test_b");
        write_manifest(
            &dir,
            "[w8]\nkind = \"ell_spmm\"\nn = 64\nwidth = 8\nd = 4\npath = \"w8.hlo.txt\"\n\
             [w16]\nkind = \"ell_spmm\"\nn = 64\nwidth = 16\nd = 4\npath = \"w16.hlo.txt\"\n",
            &["w8.hlo.txt", "w16.hlo.txt"],
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.find_ell_fitting(64, 5, 4).unwrap().width, 8);
        assert_eq!(m.find_ell_fitting(64, 12, 4).unwrap().width, 16);
        assert!(m.find_ell_fitting(64, 20, 4).is_none());
    }

    #[test]
    fn missing_dir_is_missing_artifact() {
        let err = ArtifactManifest::load("/nonexistent/zzz").unwrap_err();
        assert!(matches!(err, Error::MissingArtifact(_)));
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("spmm_manifest_test_c");
        write_manifest(
            &dir,
            "[x]\nkind = \"ell_spmm\"\nn = 1\nwidth = 1\nd = 1\npath = \"gone.hlo.txt\"\n",
            &[],
        );
        assert!(matches!(
            ArtifactManifest::load(&dir).unwrap_err(),
            Error::MissingArtifact(_)
        ));
    }
}
