//! PJRT client wrapper: HLO text → compiled executable → execution.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Compiled modules are cached per path
//! so repeated engine runs pay compilation once.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

/// Process-wide PJRT CPU client plus a compilation cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<CompiledModule>>>,
}

/// A compiled HLO module ready to execute.
pub struct CompiledModule {
    exe: xla::PjRtLoadedExecutable,
    /// Path the module was loaded from (for diagnostics).
    pub source: PathBuf,
}

// The xla crate's raw pointers are not marked Send/Sync, but the PJRT
// CPU client is thread-safe for compile/execute; the engine serialises
// executions per module anyway (single-core testbed).
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}
unsafe impl Send for CompiledModule {}
unsafe impl Sync for CompiledModule {}

impl XlaRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaRuntime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform string (e.g. "cpu") — used in reports.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO **text** file (cached per canonical
    /// path).
    pub fn compile_hlo_file<P: AsRef<Path>>(&self, path: P) -> Result<Arc<CompiledModule>> {
        let path = path.as_ref();
        let key = path.canonicalize().unwrap_or_else(|_| path.to_path_buf());
        if let Some(m) = self.cache.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        if !path.exists() {
            return Err(Error::MissingArtifact(path.display().to_string()));
        }
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let module = Arc::new(CompiledModule { exe, source: key.clone() });
        self.cache.lock().unwrap().insert(key, module.clone());
        Ok(module)
    }
}

impl CompiledModule {
    /// Execute with literal inputs; returns the unwrapped single
    /// element of the (1-tuple) result — every aot.py entry point
    /// lowers with `return_tuple=True`.
    pub fn execute1(&self, inputs: &[&xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        let buffer = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Xla("empty execution result".into()))?;
        let tuple = buffer.to_literal_sync()?;
        Ok(tuple.to_tuple1()?)
    }
}

/// Build an `f64` literal of shape `[rows, cols]` from a row-major
/// slice.
pub fn literal_f64_2d(data: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Build an `s32` literal of shape `[rows, cols]` from a row-major
/// slice.
pub fn literal_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}
