//! XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client —
//! the request-path half of the three-layer architecture (Python never
//! runs here).

mod manifest;
mod pjrt;
mod xla_spmm;

pub use manifest::{ArtifactKind, ArtifactManifest, ArtifactSpec};
pub use pjrt::{CompiledModule, XlaRuntime};
pub use xla_spmm::XlaSpmm;
