//! XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client —
//! the request-path half of the three-layer architecture (Python never
//! runs here).
//!
//! The real client needs the `xla` crate, which is not vendored in the
//! offline image; it compiles only under `--features xla`. Without the
//! feature, a stub with the same surface compiles in:
//! [`XlaRuntime::cpu`] returns [`crate::Error::Xla`], so the engine and
//! every bench degrade gracefully to native-only mode (the same path
//! they take when no artifacts were built).

mod manifest;
#[cfg(feature = "xla")]
mod pjrt;
#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(feature = "xla")]
mod xla_spmm;

pub use manifest::{ArtifactKind, ArtifactManifest, ArtifactSpec};
#[cfg(feature = "xla")]
pub use pjrt::{CompiledModule, XlaRuntime};
#[cfg(not(feature = "xla"))]
pub use stub::{CompiledModule, XlaRuntime, XlaSpmm};
#[cfg(feature = "xla")]
pub use xla_spmm::XlaSpmm;
