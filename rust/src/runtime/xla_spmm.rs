//! The XLA-backed SpMM implementation: the compiled ELL-SpMM artifact
//! exposed through the same [`Spmm`] trait as the native kernels, so
//! the engine and every bench can route to it interchangeably.
//!
//! Execution cost includes host↔device literal transfers (B in, C
//! out); on the CPU plugin these are memcpys. The `bench_xla` bench
//! reports both the end-to-end time (what a request pays) and the
//! native-ELL time for the same arrays, which isolates the PJRT
//! overhead.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::runtime::pjrt::{literal_f64_2d, literal_i32_2d};
use crate::runtime::{ArtifactSpec, CompiledModule, XlaRuntime};
use crate::sparse::{Csr, Ell};
use crate::spmm::{check_dims, DenseMatrix, Impl, Spmm};

/// SpMM through a compiled XLA module.
pub struct XlaSpmm {
    module: Arc<CompiledModule>,
    /// Staged A operands (cols, vals) — uploaded once at build time.
    cols_lit: xla::Literal,
    vals_lit: xla::Literal,
    n: usize,
    d: usize,
    /// Logical (unpadded) nonzeros, for FLOP accounting.
    nnz: usize,
    /// Padded slot count `n × width` — the FLOPs the artifact actually
    /// executes.
    padded_len: usize,
}

// xla::Literal wraps a raw pointer without Send/Sync markers; the
// engine only executes a given XlaSpmm from one thread at a time.
unsafe impl Send for XlaSpmm {}
unsafe impl Sync for XlaSpmm {}

impl XlaSpmm {
    /// Stage a CSR matrix into the artifact described by `spec`
    /// (padding the ELL width up to the artifact's static width).
    ///
    /// Fails with [`Error::DimensionMismatch`] when the matrix cannot
    /// fit the artifact's static shape.
    pub fn from_csr(rt: &XlaRuntime, spec: &ArtifactSpec, csr: &Csr) -> Result<XlaSpmm> {
        if csr.nrows != spec.n || csr.ncols != spec.n {
            return Err(Error::DimensionMismatch(format!(
                "matrix is {}x{} but artifact {} is n={}",
                csr.nrows, csr.ncols, spec.name, spec.n
            )));
        }
        if csr.max_row_len() > spec.width {
            return Err(Error::DimensionMismatch(format!(
                "matrix max row {} exceeds artifact width {}",
                csr.max_row_len(),
                spec.width
            )));
        }
        let ell = Ell::from_csr_with_width(csr, spec.width);
        Self::from_ell(rt, spec, &ell)
    }

    /// Stage pre-built ELL arrays (must match the artifact exactly).
    pub fn from_ell(rt: &XlaRuntime, spec: &ArtifactSpec, ell: &Ell) -> Result<XlaSpmm> {
        if ell.nrows != spec.n || ell.width != spec.width {
            return Err(Error::DimensionMismatch(format!(
                "ell is {}x{} (w={}) but artifact {} wants n={} w={}",
                ell.nrows, ell.ncols, ell.width, spec.name, spec.n, spec.width
            )));
        }
        let module = rt.compile_hlo_file(&spec.path)?;
        let cols_i32: Vec<i32> = ell.col_idx.iter().map(|&c| c as i32).collect();
        let cols_lit = literal_i32_2d(&cols_i32, spec.n, spec.width)?;
        let vals_lit = literal_f64_2d(&ell.vals, spec.n, spec.width)?;
        Ok(XlaSpmm {
            module,
            cols_lit,
            vals_lit,
            n: spec.n,
            d: spec.d,
            nnz: ell.nnz(),
            padded_len: ell.padded_len(),
        })
    }

    /// The dense width this artifact was compiled for.
    pub fn artifact_d(&self) -> usize {
        self.d
    }

    /// Padded slots (the artifact's true FLOP basis: `2·padded·d`).
    pub fn padded_len(&self) -> usize {
        self.padded_len
    }
}

impl Spmm for XlaSpmm {
    fn id(&self) -> Impl {
        Impl::Xla
    }
    fn nrows(&self) -> usize {
        self.n
    }
    fn ncols(&self) -> usize {
        self.n
    }
    fn nnz(&self) -> usize {
        self.nnz
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        check_dims(self.n, self.n, b, c)?;
        if b.ncols != self.d {
            return Err(Error::DimensionMismatch(format!(
                "artifact compiled for d={} but B has d={}",
                self.d, b.ncols
            )));
        }
        let b_lit = literal_f64_2d(&b.data, b.nrows, b.ncols)?;
        // operand order matches model.spmm_entry(cols, vals, b)
        let out = self.module.execute1(&[&self.cols_lit, &self.vals_lit, &b_lit])?;
        let v = out.to_vec::<f64>()?;
        if v.len() != c.data.len() {
            return Err(Error::Xla(format!(
                "result has {} elements, expected {}",
                v.len(),
                c.data.len()
            )));
        }
        c.data.copy_from_slice(&v);
        Ok(())
    }
}
