//! Offline stand-ins for the PJRT runtime (compiled when the `xla`
//! feature is off).
//!
//! Same public surface as `pjrt.rs` + `xla_spmm.rs`, but every
//! constructor reports the backend as unavailable. Call sites
//! (engine, registry, `bench_xla`) already treat a failed
//! [`XlaRuntime::cpu`] as "run native-only", so no caller needs a
//! cfg of its own.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::runtime::ArtifactSpec;
use crate::sparse::{Csr, Ell};
use crate::spmm::{DenseMatrix, Impl, Spmm};

fn unavailable() -> Error {
    Error::Xla("built without the `xla` feature — PJRT runtime unavailable".into())
}

/// Stub PJRT client: construction always fails, so no instance can
/// exist at runtime.
pub struct XlaRuntime {
    _private: (),
}

/// Stub compiled module (never constructed).
pub struct CompiledModule {
    /// Path the module would have been loaded from.
    pub source: PathBuf,
}

impl XlaRuntime {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn cpu() -> Result<XlaRuntime> {
        Err(unavailable())
    }

    /// Platform string — used in reports.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Stub compile: always fails.
    pub fn compile_hlo_file<P: AsRef<Path>>(&self, _path: P) -> Result<Arc<CompiledModule>> {
        Err(unavailable())
    }
}

/// Stub XLA-backed SpMM (never constructed).
pub struct XlaSpmm {
    _private: (),
}

impl XlaSpmm {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn from_csr(_rt: &XlaRuntime, _spec: &ArtifactSpec, _csr: &Csr) -> Result<XlaSpmm> {
        Err(unavailable())
    }

    /// Always fails: the crate was built without the `xla` feature.
    pub fn from_ell(_rt: &XlaRuntime, _spec: &ArtifactSpec, _ell: &Ell) -> Result<XlaSpmm> {
        Err(unavailable())
    }

    /// The dense width this artifact was compiled for.
    pub fn artifact_d(&self) -> usize {
        0
    }

    /// Padded slots (the artifact's true FLOP basis).
    pub fn padded_len(&self) -> usize {
        0
    }
}

impl Spmm for XlaSpmm {
    fn id(&self) -> Impl {
        Impl::Xla
    }
    fn nrows(&self) -> usize {
        0
    }
    fn ncols(&self) -> usize {
        0
    }
    fn nnz(&self) -> usize {
        0
    }
    fn execute(&self, _b: &DenseMatrix, _c: &mut DenseMatrix) -> Result<()> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_reports_unavailable() {
        let err = XlaRuntime::cpu().unwrap_err();
        assert!(matches!(err, Error::Xla(_)));
        assert!(err.to_string().contains("xla"));
    }
}
