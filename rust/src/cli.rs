//! Command-line interface for the `repro` binary (clap is unavailable
//! offline; this is a small hand-rolled subcommand parser).
//!
//! ```text
//! repro <command> [--scale X] [--threads N] [--iters N] [--d 1,4,16,64]
//!                 [--impls CSR,MKL,CSB] [--out DIR] [--config FILE]
//!
//! commands:
//!   sysinfo        Table IV analog: CPU probe + measured β/π
//!   stream         STREAM bandwidth (Copy/Scale/Add/Triad)
//!   suite          Table III analog: the proxy dataset summary
//!   classify M     classify one proxy matrix, print stats + model
//!   table-v        Table V: full GFLOP/s grid
//!   fig1           Fig. 1: GFLOP/s vs d (4 representative matrices)
//!   fig2           Fig. 2: roofline overlays (SVG + table)
//!   validate-ai    V1: model bytes vs simulated DRAM bytes
//!   ablate-block   A1: CSB block-size sweep
//!   ablate-reuse   A2: effective B-reuse factor vs the 1/4 heuristic
//!   ablate-threads A3: thread scaling
//!   ablate-reorder A4: orderings move matrices between regimes
//!   ladder         cache-aware roofline: per-level bandwidth ceilings
//!   calib          measured calibration: per-level read/write/triad
//!                  bandwidth sweep + width-aware FMA peak probe,
//!                  cross-validated against the nominal ladder and a
//!                  cachesim triad replay; writes BENCH_calib.json and
//!                  (with --state FILE) persists the measured ladder
//!                  into the autotune snapshot
//!   hubs           appendix: hub mass, model vs generated graphs
//!   engine         route a job mix through the roofline-guided engine
//!                  (--autotune turns on the adaptive router)
//!   route          structure-adaptive routing demo: tune a suite
//!                  spanning all four classes, pin per-matrix
//!                  (format, reordering), compare vs always-CSR,
//!                  write BENCH_route.json (includes an SpGEMM leg:
//!                  hash vs PB-merge per pair)
//!   spgemm         sparse×sparse routing demo: route C = A·A over the
//!                  hash and PB-merge SpGEMM kernels per matrix, pin
//!                  the measured winner with its compression factor,
//!                  write BENCH_route.json records
//!   serve          concurrent serving front-end: N client threads
//!                  submit a tenant-scoped job mix through the bounded
//!                  queue; coalesced batches, admission stats, and
//!                  (with --state FILE) persisted autotune decisions;
//!                  writes BENCH_serve.json
//!   pipeline       pipeline-first workloads: route GCN / power
//!                  iteration / batched PageRank / SpGEMM→SpMM chains
//!                  through the engine as whole units (one schedule,
//!                  pooled intermediates, whole-chain tuning against
//!                  the inter-op roofline), prove pinned re-submission
//!                  explores nothing, and (with --state FILE) persist
//!                  the pinned chain plans
//!   corpus         out-of-core corpus harness: ingest every .mtx
//!                  under --mtx DIR via the streaming MatrixMarket
//!                  reader (or synthesize a proxy corpus), classify,
//!                  autotune-route, plan row bands under --budget
//!                  BYTES, report per structure group; writes
//!                  BENCH_corpus.json
//! ```

use crate::config::{parse_impl, ExperimentConfig};
use crate::error::{Error, Result};
use crate::spmm::Impl;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub cfg: ExperimentConfig,
}

/// Parse argv (after the binary name) into a [`Cli`], applying
/// `--config FILE` first and explicit flags on top.
pub fn parse_args<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
    let mut it = args.into_iter().peekable();
    let command = it.next().ok_or_else(|| Error::Usage(usage()))?;
    let mut positional = Vec::new();
    let mut flags: Vec<(String, String)> = Vec::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(), // bare flag
            };
            flags.push((name.to_string(), value));
        } else {
            positional.push(a);
        }
    }

    let mut cfg = ExperimentConfig::default();
    if let Some((_, path)) = flags.iter().find(|(k, _)| k == "config") {
        cfg = ExperimentConfig::from_file(path)?;
    }
    for (k, v) in &flags {
        match k.as_str() {
            "config" => {}
            "scale" => cfg.scale = v.parse().map_err(|_| bad(k, v))?,
            "threads" => cfg.threads = v.parse().map_err(|_| bad(k, v))?,
            "iters" => cfg.iters = v.parse().map_err(|_| bad(k, v))?,
            "warmup" => cfg.warmup = v.parse().map_err(|_| bad(k, v))?,
            "out" => cfg.out_dir = v.clone(),
            "artifacts" => cfg.artifacts_dir = v.clone(),
            "xla" => cfg.use_xla = v == "true",
            "autotune" => cfg.autotune = v == "true",
            "clients" => cfg.clients = v.parse().map_err(|_| bad(k, v))?,
            "queue" => cfg.queue_cap = v.parse().map_err(|_| bad(k, v))?,
            "state" => cfg.state_path = Some(v.clone()),
            "mtx" => cfg.mtx_dir = Some(v.clone()),
            "budget" => cfg.ooc_budget = v.parse().map_err(|_| bad(k, v))?,
            "d" => {
                cfg.d_values = v
                    .split(',')
                    .map(|s| s.trim().parse::<usize>().map_err(|_| bad(k, v)))
                    .collect::<Result<_>>()?;
            }
            "impls" => {
                // `--impls all` opts into every native kernel
                // (CSR,OPT,CSB,ELL,BSR); the default stays the paper
                // trio
                if v.trim().eq_ignore_ascii_case("all") {
                    cfg.impls = Impl::NATIVE.to_vec();
                } else {
                    cfg.impls = v
                        .split(',')
                        .map(|s| parse_impl(s.trim()))
                        .collect::<Result<_>>()?;
                }
            }
            other => return Err(Error::Usage(format!("unknown flag --{other}\n\n{}", usage()))),
        }
    }
    cfg.validate()?;
    Ok(Cli { command, positional, cfg })
}

fn bad(k: &str, v: &str) -> Error {
    Error::Usage(format!("bad value for --{k}: '{v}'"))
}

/// The usage string.
pub fn usage() -> String {
    "usage: repro <command> [flags] — commands: sysinfo stream suite classify \
     table-v fig1 fig2 validate-ai ablate-block ablate-reuse ablate-threads \
     ablate-reorder ladder calib hubs engine route spgemm serve pipeline \
     corpus\n\
     flags: --scale X --threads N --iters N --warmup N --d 1,4,16,64 \
     --impls CSR,MKL,CSB --out DIR --artifacts DIR --config FILE --autotune \
     --clients N --queue N --state FILE --mtx DIR --budget BYTES\n\
     --impls accepts any of CSR,MKL/OPT,CSB,ELL,BSR,PB,XLA or the shorthand \
     `all` (= the six native kernels); `engine` prepares exactly the \
     requested set, so ELL/BSR/PB are opt-in there\n\
     --autotune turns on the structure-adaptive router for `engine` \
     and adds the propagation-blocking kernel (PB) to the candidate \
     set; the `route` command always autotunes: it explores impl × \
     reordering (PB included) per matrix, pins the winner, and writes \
     BENCH_route.json\n\
     `spgemm` routes the sparse×sparse workload: both SpGEMM kernels \
     (HASH, PBMERGE) are measured per matrix pair and the winner is \
     pinned with the pair's measured compression factor\n\
     `serve` drives the concurrent front-end: --clients N client \
     threads (default 4), --queue N admission capacity (default 64), \
     --state FILE to load/save the autotune snapshot across runs; \
     throughput, queue-depth, and coalesce-rate land in \
     BENCH_serve.json\n\
     `calib` measures the bandwidth/peak ladder (scaled by --scale and \
     --iters), writes BENCH_calib.json, and with --state FILE persists \
     the measured ladder into the snapshot so a restarted server skips \
     re-calibration\n\
     `pipeline` routes whole multi-op chains (GCN, power iteration, \
     batched PageRank, SpGEMM→SpMM) through the engine: each chain is \
     tuned end-to-end against the inter-op roofline model and pinned; \
     a second submission serves the pin with zero new measurements; \
     --state FILE persists the pinned chain plans across runs\n\
     `corpus` ingests every .mtx under --mtx DIR through the streaming \
     MatrixMarket reader (no DIR: synthesizes a proxy corpus from the \
     generator suite), classifies each matrix, routes it through the \
     autotuner, plans out-of-core row bands under --budget BYTES, and \
     writes per-structure-group results to BENCH_corpus.json"
        .to_string()
}

/// Entry point used by `main.rs`.
pub fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        println!("{}", usage());
        return Ok(());
    }
    let cli = parse_args(args)?;
    dispatch(&cli)
}

/// Execute one parsed command (also the integration-test entry).
pub fn dispatch(cli: &Cli) -> Result<()> {
    let cfg = &cli.cfg;
    match cli.command.as_str() {
        "sysinfo" => cmd_sysinfo(cfg),
        "stream" => cmd_stream(cfg),
        "suite" => cmd_suite(cfg),
        "classify" => cmd_classify(cfg, cli.positional.first().map(|s| s.as_str())),
        "table-v" => cmd_table_v(cfg),
        "fig1" => cmd_fig1(cfg),
        "fig2" => cmd_fig2(cfg),
        "validate-ai" => cmd_validate(cfg),
        "ablate-block" => cmd_ablate_block(cfg, cli.positional.first().map(|s| s.as_str())),
        "ablate-reuse" => cmd_ablate_reuse(cfg),
        "ablate-threads" => cmd_ablate_threads(cfg, cli.positional.first().map(|s| s.as_str())),
        "ablate-reorder" => cmd_ablate_reorder(cfg),
        "ladder" => cmd_ladder(cfg),
        "calib" => cmd_calib(cfg),
        "hubs" => cmd_hubs(),
        "engine" => cmd_engine(cfg),
        "route" => cmd_route(cfg),
        "spgemm" => cmd_spgemm(cfg),
        "serve" => cmd_serve(cfg),
        "pipeline" => cmd_pipeline(cfg),
        "corpus" => cmd_corpus(cfg),
        other => Err(Error::Usage(format!("unknown command '{other}'\n\n{}", usage()))),
    }
}

fn cmd_sysinfo(cfg: &ExperimentConfig) -> Result<()> {
    let info = crate::report::probe_system();
    let machine = crate::harness::machine_params_cached(cfg.threads);
    println!("{}", info.to_table(Some(machine)).to_text());
    Ok(())
}

fn cmd_stream(cfg: &ExperimentConfig) -> Result<()> {
    let r = crate::membench::stream_benchmark(4 << 20, cfg.threads, 3);
    let mut t = crate::report::Table::new(
        format!("STREAM (len = {} doubles, {} threads)", r.len, cfg.threads),
        &["Kernel", "GB/s"],
    );
    t.row(vec!["Copy".into(), format!("{:.2}", r.copy_gbs)]);
    t.row(vec!["Scale".into(), format!("{:.2}", r.scale_gbs)]);
    t.row(vec!["Add".into(), format!("{:.2}", r.add_gbs)]);
    t.row(vec!["Triad".into(), format!("{:.2}", r.triad_gbs)]);
    t.row(vec!["β (max)".into(), format!("{:.2}", r.beta_gbs())]);
    println!("{}", t.to_text());
    println!("paper (1 EPYC-7763 socket, 64 threads): β = 122.6 GB/s");
    Ok(())
}

fn cmd_suite(cfg: &ExperimentConfig) -> Result<()> {
    let mut t = crate::report::Table::new(
        format!("Table III analog — proxy dataset (scale {})", cfg.scale),
        &["Pattern", "Proxy", "Paper matrix", "Rows", "Nonzeros", "nnz/row", "paper nnz/row"],
    );
    for p in crate::gen::proxy_suite() {
        let m = p.generate(cfg.scale);
        t.row(vec![
            p.class.to_string(),
            p.name.into(),
            p.paper_name.into(),
            m.nrows.to_string(),
            m.nnz().to_string(),
            format!("{:.2}", m.avg_row_len()),
            format!("{:.2}", p.paper_nnz as f64 / p.paper_rows as f64),
        ]);
    }
    println!("{}", t.to_text());
    Ok(())
}

fn cmd_classify(cfg: &ExperimentConfig, name: Option<&str>) -> Result<()> {
    let name = name.ok_or_else(|| Error::Usage("classify <proxy-matrix-name>".into()))?;
    let proxy = crate::gen::suite::find(name)
        .ok_or_else(|| Error::Usage(format!("unknown proxy '{name}' (see `repro suite`)")))?;
    let m = proxy.generate(cfg.scale);
    let c = crate::pattern::classify(&m);
    println!("matrix   : {name} ({} rows, {} nnz)", m.nrows, m.nnz());
    println!("expected : {}", proxy.class);
    println!("classified: {} — {}", c.class, c.rationale);
    println!("model    : {:?}", c.model);
    if let Some(pl) = c.power_law {
        println!(
            "power law: α̂={:.2} (k_min={}, tail={}, KS={:.3})",
            pl.alpha, pl.k_min, pl.n_tail, pl.ks_distance
        );
    }
    let s = &c.stats;
    println!(
        "stats    : avg_row={:.2} max_row={} cv={:.2} diag_frac={:.2} blockdiag_frac={:.2} hub01={:.3}",
        s.avg_row_len, s.max_row_len, s.row_len_cv, s.diag_fraction, s.block_diag_fraction,
        s.hub_mass_01pct
    );
    Ok(())
}

fn cmd_table_v(cfg: &ExperimentConfig) -> Result<()> {
    let data = crate::harness::run_table_v(cfg)?;
    println!("{}", data.render(cfg).to_text());
    for (desc, ok) in data.shape_checks(cfg) {
        println!("  [{}] {desc}", if ok { "PASS" } else { "FAIL" });
    }
    let csv = format!("{}/table_v.csv", cfg.out_dir);
    data.save_csv(&csv)?;
    println!("wrote {csv}");
    Ok(())
}

fn cmd_fig1(cfg: &ExperimentConfig) -> Result<()> {
    let data = crate::harness::run_fig1(cfg)?;
    println!("{}", data.render().to_text());
    let paths = data.save_svgs(&cfg.out_dir)?;
    data.save_csv(&format!("{}/fig1.csv", cfg.out_dir))?;
    for p in paths {
        println!("wrote {p}");
    }
    Ok(())
}

fn cmd_fig2(cfg: &ExperimentConfig) -> Result<()> {
    let data = crate::harness::run_fig2(cfg, None)?;
    println!("{}", data.render().to_text());
    for (desc, ok) in data.shape_checks() {
        println!("  [{}] {desc}", if ok { "PASS" } else { "FAIL" });
    }
    let paths = data.save_svgs(&cfg.out_dir)?;
    data.save_csv(&format!("{}/fig2.csv", cfg.out_dir))?;
    for p in paths {
        println!("wrote {p}");
    }
    Ok(())
}

fn cmd_validate(cfg: &ExperimentConfig) -> Result<()> {
    // the simulator replays every access — run at a reduced scale
    let mut small = cfg.clone();
    small.scale = (cfg.scale / 8.0).max(0.005);
    let rows = crate::harness::run_validate_ai(&small)?;
    println!("{}", crate::harness::validate::render(&rows).to_text());
    crate::harness::validate::save_csv(&rows, &format!("{}/validate_ai.csv", cfg.out_dir))?;
    Ok(())
}

fn cmd_ablate_block(cfg: &ExperimentConfig, matrix: Option<&str>) -> Result<()> {
    let matrix = matrix.unwrap_or("road_usa_p");
    let d = *cfg.d_values.last().unwrap_or(&16);
    let (t, _) =
        crate::harness::ablate_block_size(cfg, matrix, d, &[64, 256, 1024, 4096, 16384])?;
    println!("{}", t.to_text());
    Ok(())
}

fn cmd_ablate_reuse(cfg: &ExperimentConfig) -> Result<()> {
    let mut small = cfg.clone();
    small.scale = (cfg.scale / 8.0).max(0.005);
    let d = *cfg.d_values.get(2).unwrap_or(&16);
    println!("{}", crate::harness::ablate_reuse_factor(&small, d)?.to_text());
    println!("{}", crate::harness::z_model_grid().to_text());
    Ok(())
}

fn cmd_ablate_threads(cfg: &ExperimentConfig, matrix: Option<&str>) -> Result<()> {
    let matrix = matrix.unwrap_or("er_18_10");
    let d = *cfg.d_values.get(2).unwrap_or(&16);
    let t = crate::harness::ablate_threads(cfg, matrix, d, &[1, 2, 4, 8])?;
    println!("{}", t.to_text());
    println!("note: this testbed exposes {} hardware thread(s)", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    Ok(())
}

fn cmd_ablate_reorder(cfg: &ExperimentConfig) -> Result<()> {
    let d = *cfg.d_values.get(2).unwrap_or(&16);
    println!("{}", crate::harness::ablate_reorder(cfg, d)?.to_text());
    Ok(())
}

fn cmd_ladder(cfg: &ExperimentConfig) -> Result<()> {
    use crate::model::{CacheAwareRoofline, LatencyModel};
    let ceilings = crate::membench::bandwidth_ladder(cfg.threads);
    let pi = crate::membench::peak_flops_gflops(cfg.threads);
    let mut t = crate::report::Table::new(
        "Cache-aware bandwidth ladder (STREAM triad per level)",
        &["Level", "Capacity", "β (GB/s)"],
    );
    for c in &ceilings {
        let cap = if c.capacity_bytes == usize::MAX {
            "∞".to_string()
        } else {
            format!("{} KiB", c.capacity_bytes >> 10)
        };
        t.row(vec![c.level.clone(), cap, format!("{:.2}", c.beta_gbs)]);
    }
    println!("{}", t.to_text());
    let car = CacheAwareRoofline::new(ceilings, pi);
    let mut t2 = crate::report::Table::new(
        "SpMM attainable GFLOP/s: flat roof vs cache-aware vs latency-corrected (er_18_10 AI)",
        &["d", "working set B", "flat roof", "cache-aware", "latency (MLP=8)"],
    );
    let proxy = crate::gen::suite::find("er_18_10").unwrap();
    let m = proxy.generate(cfg.scale);
    let flat = crate::model::Roofline::new(car.flat());
    for &d in &cfg.d_values {
        let ai = crate::model::ai_random(crate::model::AiParams::new(m.nrows, d, m.nnz()));
        let ws = CacheAwareRoofline::spmm_working_set(m.nrows, d);
        let lat = LatencyModel {
            beta_gbs: car.flat().beta_gbs,
            latency_ns: 90.0,
            line_bytes: 64.0,
            mlp: 8.0,
        };
        t2.row(vec![
            d.to_string(),
            format!("{} KiB", ws >> 10),
            format!("{:.2}", flat.attainable_gflops(ai)),
            format!("{:.2}", car.attainable_gflops(ai, ws)),
            format!("{:.2}", lat.attainable_gflops(ai, pi)),
        ]);
    }
    println!("{}", t2.to_text());
    println!("the latency-corrected roof explains the random-pattern gap the paper");
    println!("attributes to unmodelled memory latency (§IV-D-1).");
    Ok(())
}

/// The `calib` command: run the measured calibration path — the
/// per-cache-level read/write/triad bandwidth sweep plus the
/// width-aware FMA peak probe ([`crate::membench::calibrate_with`]) —
/// and cross-validate each rung three ways: measured β vs the nominal
/// ladder's halved-per-level prior vs a cachesim triad replay's
/// DRAM/logical traffic ratio. Writes one `BENCH_calib.json` record
/// per rung (predicted = nominal β, measured = measured β) plus a peak
/// record; with `--state FILE` the measured ladder is persisted into
/// the autotune snapshot, so a restarted server installs it instead of
/// re-measuring.
fn cmd_calib(cfg: &ExperimentConfig) -> Result<()> {
    use crate::membench::{cache_levels, calibrate_with, CalibConfig};
    use crate::model::CacheAwareRoofline;
    use crate::report::{PerfLog, PerfRecord};

    let scale = cfg.scale.max(0.001);
    let ccfg = CalibConfig {
        reps: cfg.iters.max(1),
        max_len: (((64usize << 20) as f64 * scale) as usize).max(1 << 12),
        peak_iters: ((4_000_000f64 * scale) as usize).max(10_000),
    };
    println!(
        "calibrating: {} threads, {} reps, sweep cap {} doubles, peak iters {}",
        cfg.threads, ccfg.reps, ccfg.max_len, ccfg.peak_iters
    );
    let ml = calibrate_with(cfg.threads, ccfg);

    // the nominal ladder this machine would get without measurement —
    // same cache geometry, β halved per level upward from STREAM
    let machine = crate::harness::machine_params_cached(cfg.threads);
    let nominal = CacheAwareRoofline::nominal(machine, &cache_levels());

    let mut t = crate::report::Table::new(
        format!(
            "measured ladder — {} threads, simd {}, peak {:.1} GFLOP/s (nominal π {:.1})",
            ml.threads, ml.simd_level, ml.peak_gflops, machine.pi_gflops
        ),
        &["Level", "Capacity", "read GB/s", "write GB/s", "triad GB/s", "nominal β", "sim DRAM/logical"],
    );
    let mut log = PerfLog::new();
    for (i, l) in ml.levels.iter().enumerate() {
        let is_dram = l.capacity_bytes == usize::MAX;
        let cap = if is_dram {
            "∞".to_string()
        } else {
            format!("{} KiB", l.capacity_bytes >> 10)
        };
        let nom = nominal.ceilings.iter().find(|c| c.level == l.level).map(|c| c.beta_gbs);
        let ratio = calib_sim_ratio(i, is_dram);
        t.row(vec![
            l.level.clone(),
            cap,
            format!("{:.2}", l.read_gbs),
            format!("{:.2}", l.write_gbs),
            format!("{:.2}", l.triad_gbs),
            nom.map(|b| format!("{b:.2}")).unwrap_or_else(|| "—".into()),
            format!("{ratio:.2}"),
        ]);
        log.push(PerfRecord {
            predicted_gflops: nom.unwrap_or(0.0),
            ..PerfRecord::basic(
                "bench_calib",
                l.level.clone(),
                "calib".to_string(),
                ml.simd_level.clone(),
                ml.threads,
                0,
                l.beta_gbs(),
            )
        });
    }
    println!("{}", t.to_text());
    println!(
        "cross-check: the sim column is a shape test (tiny hierarchy, warmed \
         second triad pass) — cache rungs filter toward the streaming-store \
         floor of ~0.33, the DRAM rung streams at ~1"
    );
    log.push(PerfRecord {
        predicted_gflops: machine.pi_gflops,
        ..PerfRecord::basic(
            "bench_calib",
            "peak".to_string(),
            "calib".to_string(),
            ml.simd_level.clone(),
            ml.threads,
            0,
            ml.peak_gflops,
        )
    });
    log.merge_save("BENCH_calib.json")?;
    println!("wrote BENCH_calib.json ({} records)", log.records.len());

    if let Some(path) = &cfg.state_path {
        let mut state = crate::report::AutotuneState::load_or_cold(path).unwrap_or_default();
        state.ladder = Some(ml);
        state.save(path)?;
        println!("persisted measured ladder into {path} — restarts skip re-calibration");
    }
    Ok(())
}

/// Triad replay through the cache simulator, sized to rung `i` of the
/// deliberately tiny hierarchy ([`HierarchyConfig::tiny`]): the
/// modeled DRAM/logical ratio of a warmed second pass. A shape check
/// for the measured sweep, not a bandwidth number — a rung whose
/// working set fits filters read traffic to ~0 (the streaming-store
/// third of a triad always reaches DRAM), the DRAM rung streams at ~1.
fn calib_sim_ratio(rung: usize, is_dram: bool) -> f64 {
    use crate::cachesim::{Hierarchy, HierarchyConfig};
    let cfg = HierarchyConfig::tiny();
    let caps = [cfg.l1.size_bytes, cfg.l2.size_bytes, cfg.l3.size_bytes];
    // same 3-array sizing rule as the measured sweep, against sim caps
    let len = if is_dram {
        cfg.l3.size_bytes * 4 / 8
    } else {
        (caps[rung.min(2)] / (3 * 8 * 2)).max(8)
    };
    let mut h = Hierarchy::new(cfg);
    let b0 = 0u64;
    let c0 = (len * 8) as u64;
    let a0 = (2 * len * 8) as u64;
    let pass = |h: &mut Hierarchy| {
        for i in 0..len as u64 {
            h.load(b0 + i * 8, 8);
            h.load(c0 + i * 8, 8);
            h.store(a0 + i * 8, 8);
        }
    };
    pass(&mut h);
    let warm = h.report();
    pass(&mut h);
    let full = h.report();
    let dram = full.dram_bytes.saturating_sub(warm.dram_bytes) as f64;
    let logical = full.logical_bytes.saturating_sub(warm.logical_bytes) as f64;
    if logical == 0.0 {
        0.0
    } else {
        dram / logical
    }
}

fn cmd_hubs() -> Result<()> {
    let mut t = crate::report::Table::new(
        "Appendix — hub edge mass nnz_hub/nnz = f^{(α−2)/(α−1)}",
        &["α", "f=0.1%", "f=1%", "f=10%"],
    );
    for alpha in [2.1, 2.2, 2.5, 2.9] {
        t.row(vec![
            format!("{alpha}"),
            format!("{:.3}", crate::model::hub_mass_fraction(alpha, 0.001)),
            format!("{:.3}", crate::model::hub_mass_fraction(alpha, 0.01)),
            format!("{:.3}", crate::model::hub_mass_fraction(alpha, 0.10)),
        ]);
    }
    println!("{}", t.to_text());
    println!("paper check: α=2.2, f=1% → ≈0.46 (we compute {:.3})",
        crate::model::hub_mass_fraction(2.2, 0.01));
    Ok(())
}

fn cmd_engine(cfg: &ExperimentConfig) -> Result<()> {
    use crate::coordinator::{AutotunePolicy, Engine, EngineConfig, JobSpec};
    let mut impls: Vec<Impl> = cfg.impls.iter().copied().filter(|&i| i != Impl::Xla).collect();
    // the adaptive router always enumerates the propagation-blocking
    // kernel — the candidate whose predicted win/loss flips with
    // structure is exactly what the explore/exploit loop is for
    if cfg.autotune && !impls.contains(&Impl::Pb) {
        impls.push(Impl::Pb);
    }
    let mut engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: None,
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls,
        artifacts_dir: Some(cfg.artifacts_dir.clone()),
        autotune: if cfg.autotune {
            AutotunePolicy::enabled()
        } else {
            AutotunePolicy::default()
        },
    })?;
    println!(
        "engine up: β={:.1} GB/s π={:.0} GFLOP/s xla={}",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
        engine.has_xla()
    );
    for proxy in crate::gen::representative_suite() {
        engine.register(proxy.name, proxy.generate(cfg.scale))?;
    }
    let mut t = crate::report::Table::new(
        "engine — routed jobs (classify → predict → route → measure)",
        &["Matrix", "Class", "d", "Routed to", "Tile", "Pred GF/s", "Meas GF/s", "Meas/Pred"],
    );
    // the whole (matrix × d) sweep goes through the batched path: one
    // queue, pooled buffers, persistent workers
    let names: Vec<String> = engine.registry().names().iter().map(|s| s.to_string()).collect();
    let mut jobs = Vec::new();
    for name in &names {
        for &d in &cfg.d_values {
            jobs.push(JobSpec::new(name.clone(), d));
        }
    }
    let batch = engine.submit_batch(&jobs)?;
    for rec in &batch.records {
        let tile = if rec.dt >= rec.d { "—".to_string() } else { rec.dt.to_string() };
        t.row(vec![
            rec.matrix.clone(),
            rec.class.to_string(),
            rec.d.to_string(),
            rec.chosen.to_string(),
            tile,
            format!("{:.2}", rec.predicted_gflops),
            format!("{:.2}", rec.measured_gflops),
            format!("{:.2}", rec.prediction_ratio()),
        ]);
    }
    println!("{}", t.to_text());
    println!("{}", batch.summary_line());
    if cfg.autotune {
        for dec in &batch.routes {
            println!("  route: {}", dec.summary());
        }
    }
    let (shits, smisses) = engine.registry().schedule_cache_stats();
    println!(
        "schedules: {} planned, {} served from cache ({:.0}% hit rate)",
        smisses,
        shits,
        100.0 * engine.registry().schedule_hit_rate()
    );
    let rep = engine.prediction_report();
    println!(
        "prediction: n={} geomean(meas/pred)={:.2} mean|log err|={:.2}",
        rep.n_jobs, rep.geomean_ratio, rep.mean_abs_log_err
    );
    Ok(())
}

/// One perf record per pinned SpMM decision — the routing source and
/// the decision-time structural features ride along (raw fractions +
/// exactly un-log-scaled counts) so the learned router can train on
/// the accumulated artifact
/// ([`crate::coordinator::examples_from_log`]).
fn route_record(
    bench: &str,
    dec: &crate::coordinator::RouteDecision,
) -> crate::report::PerfRecord {
    use crate::model::FeatureVec;
    crate::report::PerfRecord {
        reorder: dec.reorder.to_string(),
        predicted_gflops: dec.predicted_gflops,
        source: dec.source.to_string(),
        cv: dec.features.0[0],
        hub: dec.features.0[1],
        diag: dec.features.0[2],
        block: dec.features.0[3],
        n: FeatureVec::count_of(dec.features.0[4]),
        nnz: FeatureVec::count_of(dec.features.0[5]),
        ..crate::report::PerfRecord::basic(
            bench,
            dec.matrix.clone(),
            dec.class.to_string(),
            dec.im.to_string(),
            dec.d,
            dec.dt.min(dec.d),
            dec.measured_gflops,
        )
    }
}

/// The `route` command: register a generated suite spanning all four
/// sparsity classes (plus a scrambled mesh, so the RCM lever has
/// something to recover), autotune every (matrix, d), print the pinned
/// decisions, compare the routed batch against an always-CSR baseline,
/// train the learned structure router on the accumulated artifact and
/// re-route (reporting per-structure-group regret-vs-analytic), and
/// write the `BENCH_route.json` artifact.
fn cmd_route(cfg: &ExperimentConfig) -> Result<()> {
    use crate::coordinator::{AutotunePolicy, Engine, EngineConfig, JobSpec};
    use crate::report::{PerfLog, PerfRecord};
    use crate::sparse::reorder::{permute_symmetric, random_permutation};

    let mut route_impls: Vec<Impl> =
        cfg.impls.iter().copied().filter(|&i| i != Impl::Xla).collect();
    // PB rides along as the structure-adversarial candidate (see
    // cmd_engine); `--impls` can still force a narrower set apart
    // from it
    if !route_impls.contains(&Impl::Pb) {
        route_impls.push(Impl::Pb);
    }
    let mut engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: None,
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls: route_impls.clone(),
        artifacts_dir: Some(cfg.artifacts_dir.clone()),
        autotune: AutotunePolicy::enabled(),
    })?;
    println!(
        "router up: β={:.1} GB/s π={:.0} GFLOP/s, exploring impl × {{none, rcm, degree}}",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
    );
    for proxy in crate::gen::representative_suite() {
        engine.register(proxy.name, proxy.generate(cfg.scale))?;
    }
    // a scrambled mesh: registered as "random-looking", recoverable by
    // RCM — the router should treat it differently from a true random
    let mut rng = crate::gen::Prng::new(0x0de7);
    let mesh = crate::gen::suite::find("road_usa_p")
        .expect("road_usa_p is in the suite")
        .generate(cfg.scale);
    let scrambled = permute_symmetric(&mesh, &random_permutation(mesh.nrows, &mut rng));
    engine.register("road_scrambled", scrambled)?;

    for name in engine.registry().names() {
        let e = engine.registry().get(name).unwrap();
        println!("  registered {name}: {}", e.classification.summary());
    }

    let names: Vec<String> = engine.registry().names().iter().map(|s| s.to_string()).collect();
    let jobs: Vec<JobSpec> = names
        .iter()
        .flat_map(|n| cfg.d_values.iter().map(|&d| JobSpec::new(n.clone(), d)))
        .collect();

    println!("\n— tuning batch (explores top-k candidates per matrix × d) —");
    let tuned = engine.submit_batch(&jobs)?;
    println!("  {}", tuned.summary_line());
    let mut t = crate::report::Table::new(
        "route — pinned decisions (format × reordering per matrix × d)",
        &[
            "Matrix", "Class", "d", "Impl", "Reorder", "dt", "Pred GF/s", "Meas GF/s", "Regret",
            "Source",
        ],
    );
    for dec in engine.autotuner().decisions() {
        t.row(vec![
            dec.matrix.clone(),
            dec.class.to_string(),
            dec.d.to_string(),
            dec.im.to_string(),
            dec.reorder.to_string(),
            if dec.dt >= dec.d { "—".into() } else { dec.dt.to_string() },
            format!("{:.2}", dec.predicted_gflops),
            format!("{:.2}", dec.measured_gflops),
            format!("{:.2}", dec.regret_gflops),
            dec.source.to_string(),
        ]);
    }
    println!("{}", t.to_text());

    println!("— pinned re-submission (decisions cached, nothing re-measured) —");
    let routed = engine.submit_batch(&jobs)?;
    println!("  {}", routed.summary_line());
    println!(
        "  explored this batch: {} (0 proves pinning), schedule hit rate {:.0}%",
        routed.explore_measurements,
        100.0 * routed.schedule_hit_rate()
    );

    // Baseline on a fresh engine holding the *original* layouts — the
    // tuned engine's matrices were permuted in place where a
    // reordering won, and a baseline on those would silently inherit
    // the router's gains. CSR when configured, else the first
    // configured impl (`--impls OPT,CSB` must not error after a full
    // tuning run).
    let impls: Vec<Impl> = cfg.impls.iter().copied().filter(|&i| i != Impl::Xla).collect();
    let base_im =
        if impls.contains(&Impl::Csr) { Impl::Csr } else { impls.first().copied().unwrap_or(Impl::Csr) };
    println!("— always-{base_im} baseline on the same jobs (original layouts) —");
    let mut base_engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: Some(engine.machine()), // reuse calibration
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls: vec![base_im],
        artifacts_dir: None,
        autotune: AutotunePolicy::default(),
    })?;
    for proxy in crate::gen::representative_suite() {
        base_engine.register(proxy.name, proxy.generate(cfg.scale))?;
    }
    let mut rng2 = crate::gen::Prng::new(0x0de7);
    let mesh2 = crate::gen::suite::find("road_usa_p")
        .expect("road_usa_p is in the suite")
        .generate(cfg.scale);
    base_engine
        .register("road_scrambled", permute_symmetric(&mesh2, &random_permutation(mesh2.nrows, &mut rng2)))?;
    let base_jobs: Vec<JobSpec> =
        jobs.iter().map(|j| j.clone().with_impl(base_im)).collect();
    base_engine.submit_batch(&base_jobs)?; // warm buffers + schedules
    let baseline = base_engine.submit_batch(&base_jobs)?;
    println!("  {}", baseline.summary_line());
    let speedup = routed.aggregate_gflops() / baseline.aggregate_gflops().max(1e-12);
    println!(
        "\nrouted {:.2} GFLOP/s vs always-{base_im} {:.2} GFLOP/s → {:.2}× on the batch total",
        routed.aggregate_gflops(),
        baseline.aggregate_gflops(),
        speedup
    );

    let mut pt = crate::report::Table::new(
        "learned priors after exploration (fraction of roof)",
        &["Class", "Impl", "Prior"],
    );
    for (class, im, prior) in engine.planner().priors_snapshot() {
        pt.row(vec![class.to_string(), im.to_string(), format!("{prior:.3}")]);
    }
    println!("{}", pt.to_text());

    // SpGEMM leg — the router's second workload: tune two
    // self-products spanning the structural contrast (random + mesh).
    // Each tune measures *both* candidate kernels, so the artifact
    // below carries predicted-vs-measured GFLOP/s for ≥ 2 candidates
    // per SpGEMM job.
    println!("— SpGEMM routing (HASH vs PBMERGE per pair) —");
    for name in ["er_18_1", "road_usa_p"] {
        let dec = engine.tune_spgemm(name, name)?;
        println!("  spgemm route: {}", dec.summary());
    }

    // machine-readable artifact: one record per pinned decision, with
    // predicted vs measured (regret analysis across PRs), the routing
    // source, and the decision-time structural features — the learned
    // router's training set
    let mut log = PerfLog::new();
    for dec in engine.autotuner().decisions() {
        log.push(route_record("bench_route", dec));
    }
    // SpGEMM rows: one record per measured candidate per pair
    // (impl ∈ {HASH, PBMERGE}; d = dt = 0 marks the sparse operand)
    for dec in engine.autotuner().spgemm_decisions() {
        for cand in &dec.candidates {
            log.push(PerfRecord {
                predicted_gflops: cand.predicted_gflops,
                ..PerfRecord::basic(
                    "bench_route",
                    format!("{}x{}", dec.a, dec.b),
                    dec.class.to_string(),
                    cand.im.to_string(),
                    0,
                    0,
                    cand.measured_gflops,
                )
            });
        }
    }
    log.merge_save("BENCH_route.json")?;
    println!("wrote BENCH_route.json ({} routing records)", log.records.len());

    // learned leg: train the structure router on the accumulated
    // artifact, re-route the identical queue on a fresh engine
    // (original layouts), and report per-structure-group
    // regret-vs-analytic — what trusting the forest cost against the
    // measured analytic pick (0 where the analytic model routed)
    println!("\n— learned re-route (forest trained on BENCH_route.json) —");
    let accumulated = std::fs::read_to_string("BENCH_route.json")
        .ok()
        .and_then(|t| PerfLog::parse(&t).ok())
        .unwrap_or_default();
    let mut learned_engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: Some(engine.machine()),
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls: route_impls,
        artifacts_dir: None,
        autotune: AutotunePolicy::enabled(),
    })?;
    for proxy in crate::gen::representative_suite() {
        learned_engine.register(proxy.name, proxy.generate(cfg.scale))?;
    }
    let mut rng3 = crate::gen::Prng::new(0x0de7);
    let mesh3 = crate::gen::suite::find("road_usa_p")
        .expect("road_usa_p is in the suite")
        .generate(cfg.scale);
    learned_engine.register(
        "road_scrambled",
        permute_symmetric(&mesh3, &random_permutation(mesh3.nrows, &mut rng3)),
    )?;
    // min_support 1: the generated suites are small, and a
    // single-example leaf at an exactly-reproduced training point is
    // the interpolation the gate should admit here
    let tc = crate::coordinator::TrainConfig {
        min_support: 1,
        ..crate::coordinator::TrainConfig::default()
    };
    match learned_engine.train_learned_router(&accumulated, &tc) {
        Ok(n) => println!(
            "  trained on {n} examples: {}",
            learned_engine.learned_router().expect("just installed").summary()
        ),
        Err(e) => println!("  learned leg skipped ({e})"),
    }
    let relearned = learned_engine.submit_batch(&jobs)?;
    println!("  {}", relearned.summary_line());
    let mut gt = crate::report::Table::new(
        "learned re-route — regret-vs-analytic by structure group",
        &["Class", "Routes", "Learned", "Mean regret GF/s"],
    );
    let mut groups: std::collections::BTreeMap<String, (usize, usize, f64)> =
        std::collections::BTreeMap::new();
    for dec in learned_engine.autotuner().decisions() {
        let g = groups.entry(dec.class.to_string()).or_insert((0, 0, 0.0));
        g.0 += 1;
        if dec.source == crate::coordinator::RouteSource::Learned {
            g.1 += 1;
        }
        g.2 += dec.regret_vs_analytic();
    }
    for (class, (routes, learned, regret)) in &groups {
        gt.row(vec![
            class.clone(),
            routes.to_string(),
            learned.to_string(),
            format!("{:.4}", regret / (*routes as f64).max(1.0)),
        ]);
    }
    println!("{}", gt.to_text());

    let mut learned_log = PerfLog::new();
    for dec in learned_engine.autotuner().decisions() {
        learned_log.push(route_record("bench_route_learned", dec));
    }
    learned_log.merge_save("BENCH_route.json")?;
    println!(
        "wrote BENCH_route.json ({} learned re-route records)",
        learned_log.records.len()
    );
    Ok(())
}

/// The `spgemm` command: sparse×sparse routing demo. Registers the
/// representative suite, routes the self-product `A·A` of every
/// matrix across the hash and PB-merge kernels (autotuned: both
/// measured, winner pinned per pair with its measured compression
/// factor), prints predicted vs measured, and writes per-candidate
/// records into `BENCH_route.json` (bench = `spgemm`, merge preserving
/// every other bench's records).
fn cmd_spgemm(cfg: &ExperimentConfig) -> Result<()> {
    use crate::coordinator::{AutotunePolicy, Engine, EngineConfig, SpGemmSpec};
    use crate::report::{PerfLog, PerfRecord};
    let mut engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: None,
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls: vec![Impl::Csr], // SpMM kernels are not exercised here
        artifacts_dir: None,
        autotune: AutotunePolicy::enabled(),
    })?;
    println!(
        "spgemm router up: β={:.1} GB/s π={:.0} GFLOP/s, candidates HASH × PBMERGE",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
    );
    for proxy in crate::gen::representative_suite() {
        engine.register(proxy.name, proxy.generate(cfg.scale))?;
    }
    let names: Vec<String> = engine.registry().names().iter().map(|s| s.to_string()).collect();
    let mut t = crate::report::Table::new(
        "spgemm — routed pairs (A·A per representative matrix)",
        &["Pair", "Class", "Impl", "cf", "nnz(C)", "Pred GF/s", "Meas GF/s", "Meas/Pred"],
    );
    for name in &names {
        let rec = engine.submit_spgemm(&SpGemmSpec::new(name.clone(), name.clone()))?;
        t.row(vec![
            format!("{name}×{name}"),
            rec.class.to_string(),
            rec.chosen.to_string(),
            format!("{:.1}", rec.cf),
            rec.nnz_c.to_string(),
            format!("{:.2}", rec.predicted_gflops),
            format!("{:.2}", rec.measured_gflops),
            format!("{:.2}", rec.prediction_ratio()),
        ]);
    }
    println!("{}", t.to_text());
    let mut log = PerfLog::new();
    for dec in engine.autotuner().spgemm_decisions() {
        println!("  {}", dec.summary());
        for cand in &dec.candidates {
            log.push(PerfRecord {
                predicted_gflops: cand.predicted_gflops,
                ..PerfRecord::basic(
                    "spgemm",
                    format!("{}x{}", dec.a, dec.b),
                    dec.class.to_string(),
                    cand.im.to_string(),
                    0,
                    0,
                    cand.measured_gflops,
                )
            });
        }
    }
    log.merge_save("BENCH_route.json")?;
    println!("wrote BENCH_route.json ({} spgemm records)", log.records.len());
    Ok(())
}

/// The `serve` command: stand up the concurrent serving front-end
/// over the representative suite registered under two tenants, drive
/// it with `--clients` threads submitting a mixed SpMM/SpGEMM load
/// through the bounded queue (retrying on backpressure), and report
/// throughput, queue depth, and the coalesce rate. With `--state FILE`
/// the autotune snapshot is loaded at startup — a second run pins the
/// first run's decisions without re-exploring — and saved at shutdown.
fn cmd_serve(cfg: &ExperimentConfig) -> Result<()> {
    use crate::coordinator::{
        AutotunePolicy, Engine, EngineConfig, JobSpec, ServeConfig, ServeRequest, Server,
        SpGemmSpec, Submit,
    };
    use std::sync::atomic::{AtomicUsize, Ordering};

    let impls: Vec<Impl> = cfg.impls.iter().copied().filter(|&i| i != Impl::Xla).collect();
    let mut engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: None,
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls,
        artifacts_dir: None,
        autotune: if cfg.autotune { AutotunePolicy::enabled() } else { AutotunePolicy::default() },
    })?;
    // two tenants over the same suite: same local names, isolated state
    let tenants = ["acme", "beta"];
    let mut names: Vec<String> = Vec::new();
    for proxy in crate::gen::representative_suite() {
        for t in tenants {
            engine.register_for(t, proxy.name, proxy.generate(cfg.scale))?;
        }
        names.push(proxy.name.to_string());
    }
    let mut server = Server::new(
        engine,
        ServeConfig {
            queue_capacity: cfg.queue_cap,
            state_path: cfg.state_path.clone(),
            ..ServeConfig::default()
        },
    );
    println!(
        "serve up: {} clients, queue {} deep, {} matrices × {} tenants, restored={}",
        cfg.clients,
        cfg.queue_cap,
        names.len(),
        tenants.len(),
        server.restored()
    );

    let handle = server.handle();
    let remaining = AtomicUsize::new(cfg.clients);
    let delivered = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..cfg.clients {
            let h = handle.clone();
            let remaining = &remaining;
            let delivered = &delivered;
            let names = &names;
            s.spawn(move || {
                let tenant = tenants[c % tenants.len()];
                let mut tickets = Vec::new();
                let mut tag = (c as u64) << 32;
                let mut enqueue = |req: ServeRequest, tickets: &mut Vec<_>| loop {
                    match h.submit(req.clone()) {
                        Ok(Submit::Accepted(t)) => {
                            tickets.push(t);
                            break;
                        }
                        // backpressure: the server is draining
                        // concurrently, so room opens up — retry
                        Ok(Submit::Rejected { .. }) => std::thread::yield_now(),
                        Err(_) => break, // queue closed underneath us
                    }
                };
                for (i, name) in names.iter().enumerate() {
                    for &d in &cfg.d_values {
                        let req = ServeRequest::spmm(tenant, JobSpec::new(name.clone(), d), tag)
                            .with_tag(tag);
                        tag += 1;
                        enqueue(req, &mut tickets);
                    }
                    if i == 0 {
                        let req = ServeRequest::spgemm(
                            tenant,
                            SpGemmSpec::new(name.clone(), name.clone()),
                        )
                        .with_tag(tag);
                        tag += 1;
                        enqueue(req, &mut tickets);
                    }
                }
                // the last client done submitting shuts the queue down
                if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    h.close();
                }
                for t in tickets {
                    if t.wait().is_ok() {
                        delivered.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        server.run();
    });

    let stats = server.stats();
    let mut t = crate::report::Table::new(
        "serve — concurrent front-end over the roofline-guided engine",
        &["Metric", "Value"],
    );
    t.row(vec!["jobs done".into(), stats.jobs_done.to_string()]);
    t.row(vec!["jobs failed".into(), stats.jobs_failed.to_string()]);
    t.row(vec!["replies delivered".into(), delivered.load(Ordering::Relaxed).to_string()]);
    t.row(vec!["serving cycles".into(), stats.batches.to_string()]);
    t.row(vec!["coalesced jobs".into(), stats.coalesced_jobs.to_string()]);
    t.row(vec!["coalesce rate".into(), format!("{:.2}", stats.coalesce_rate())]);
    t.row(vec!["rejected (backpressure)".into(), stats.rejected.to_string()]);
    t.row(vec!["peak queue depth".into(), stats.max_queue_depth.to_string()]);
    t.row(vec!["jobs/sec".into(), format!("{:.1}", stats.jobs_per_sec())]);
    println!("{}", t.to_text());
    if let Some(p) = &cfg.state_path {
        println!("autotune state persisted to {p} (re-run to serve from pinned decisions)");
    }
    crate::report::atomic_write("BENCH_serve.json", &stats.to_json("bench_serve", cfg.clients))?;
    println!("wrote BENCH_serve.json");
    Ok(())
}

/// The `pipeline` command: route whole multi-op chains through the
/// engine. Each chain (GCN forward pass, block power iteration,
/// batched PageRank, SpGEMM→SpMM) is tuned *end-to-end* — the router
/// measures full-chain throughput per candidate format against the
/// inter-op roofline ([`crate::model::ai_pipeline`]) and pins the
/// winner under `(matrix, chain)`. A second submission pass proves the
/// pin: zero new measurements, schedules served from cache. With
/// `--state FILE` the pinned chain plans persist across runs
/// (restored pins serve without any exploration at all).
fn cmd_pipeline(cfg: &ExperimentConfig) -> Result<()> {
    use crate::coordinator::{AutotunePolicy, Engine, EngineConfig, PipelineKind, PipelineSpec};

    let impls: Vec<Impl> = cfg.impls.iter().copied().filter(|&i| i != Impl::Xla).collect();
    let mut engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: None,
        iters: cfg.iters,
        warmup: cfg.warmup,
        impls,
        artifacts_dir: None,
        autotune: AutotunePolicy::enabled(),
    })?;
    println!(
        "pipeline engine up: β={:.1} GB/s π={:.0} GFLOP/s",
        engine.machine().beta_gbs,
        engine.machine().pi_gflops,
    );
    for proxy in crate::gen::representative_suite() {
        engine.register(proxy.name, proxy.generate(cfg.scale))?;
    }
    let restored = if let Some(path) = &cfg.state_path {
        match crate::report::AutotuneState::load(path) {
            Ok(state) => engine.restore_state(&state),
            Err(_) => 0, // cold start: no snapshot yet
        }
    } else {
        0
    };
    if restored > 0 {
        println!("restored {restored} pinned decisions — chains below serve without exploring");
    }

    // one chain of each kind per matrix; widths come from --d (head
    // width for GCN, block width elsewhere)
    let d = cfg.d_values.first().copied().unwrap_or(16);
    let names: Vec<String> = engine.registry().names().iter().map(|s| s.to_string()).collect();
    let mut specs: Vec<PipelineSpec> = Vec::new();
    for name in &names {
        specs.push(PipelineSpec::new(
            name.clone(),
            PipelineKind::Gcn { dims: vec![d, (d / 2).max(1), d] },
        ));
        specs.push(PipelineSpec::new(
            name.clone(),
            PipelineKind::PowerIteration { d, iters: 8 },
        ));
        specs.push(PipelineSpec::new(
            name.clone(),
            PipelineKind::PageRank {
                seeds: (0..d.min(8)).collect(),
                alpha: 0.85,
                tol: 1e-9,
                iters: 12,
            },
        ));
    }
    // one sparse×sparse chain: square the first matrix, then SpMM the
    // product — the SpGEMM leg routes through `ensure_spgemm`, the
    // SpMM leg is tuned on the *product's* structure
    if let Some(first) = names.first() {
        specs.push(PipelineSpec::new(
            first.clone(),
            PipelineKind::SpGemmSpMM { b: first.clone(), d },
        ));
    }

    println!("\n— tuning pass (each chain measured end-to-end per candidate) —");
    let mut t = crate::report::Table::new(
        "pipeline — whole-chain routing (one schedule, pooled intermediates)",
        &[
            "Matrix", "Chain", "Class", "Impl", "Ops", "Resident", "AI", "Pred GF/s",
            "Meas GF/s", "Meas/Pred",
        ],
    );
    let mut records = Vec::new();
    for spec in &specs {
        let rec = engine.submit_pipeline(spec)?;
        t.row(vec![
            rec.matrix.clone(),
            rec.chain.clone(),
            rec.class.to_string(),
            rec.chosen.to_string(),
            rec.ops.to_string(),
            if rec.resident { "yes".into() } else { "no".into() },
            format!("{:.2}", rec.ai),
            format!("{:.2}", rec.predicted_gflops),
            format!("{:.2}", rec.measured_gflops),
            format!("{:.2}", rec.prediction_ratio()),
        ]);
        records.push(rec);
    }
    println!("{}", t.to_text());
    for rec in &records {
        let ops: Vec<String> =
            rec.per_op.iter().map(|o| format!("{} {:.1}ms", o.op, o.secs * 1e3)).collect();
        println!("  {} {} per-op: {}", rec.matrix, rec.chain, ops.join(" → "));
    }
    for dec in engine.autotuner().pipeline_decisions() {
        println!("  pinned: {}", dec.summary());
    }

    println!("\n— pinned re-submission (whole-chain plans cached) —");
    let before = engine.autotuner().measurements();
    for spec in &specs {
        engine.submit_pipeline(spec)?;
    }
    let explored = engine.autotuner().measurements() - before;
    println!(
        "  explored this pass: {explored} (0 proves whole-chain pinning), schedule hit rate {:.0}%",
        100.0 * engine.registry().schedule_hit_rate()
    );

    if let Some(path) = &cfg.state_path {
        let state = engine.export_state();
        state.save(path)?;
        println!(
            "persisted {} pinned chain plans into {path} — restarts serve without exploring",
            state.pipelines.len()
        );
    }
    Ok(())
}

fn cmd_corpus(cfg: &ExperimentConfig) -> Result<()> {
    use crate::harness::{run_corpus, CorpusConfig};

    let ccfg = CorpusConfig {
        dir: cfg.mtx_dir.as_ref().map(std::path::PathBuf::from),
        scale: cfg.scale,
        threads: cfg.threads,
        iters: cfg.iters,
        warmup: cfg.warmup,
        d_values: cfg.d_values.clone(),
        machine: None,
        ooc_budget: cfg.ooc_budget,
    };
    let rep = run_corpus(&ccfg)?;
    if rep.synthesized {
        println!(
            "no .mtx corpus under {:?} — synthesized the proxy suite at scale {}",
            cfg.mtx_dir, cfg.scale
        );
    }
    println!("{}", rep.matrix_table().to_text());
    println!("{}", rep.group_table().to_text());
    println!(
        "pinned re-submission explored {} candidates (0 proves the routing held)",
        rep.pinned_explores
    );
    rep.save("BENCH_corpus.json")?;
    println!("wrote BENCH_corpus.json ({} records)", rep.rows.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let cli = parse_args(args("table-v --scale 0.5 --d 1,8 --impls CSR,MKL --iters 2")).unwrap();
        assert_eq!(cli.command, "table-v");
        assert_eq!(cli.cfg.scale, 0.5);
        assert_eq!(cli.cfg.d_values, vec![1, 8]);
        assert_eq!(cli.cfg.impls, vec![Impl::Csr, Impl::Opt]);
        assert_eq!(cli.cfg.iters, 2);
    }

    #[test]
    fn impls_all_expands_to_native_set() {
        let cli = parse_args(args("engine --impls all --scale 0.1")).unwrap();
        assert_eq!(cli.cfg.impls, Impl::NATIVE.to_vec());
        let cli = parse_args(args("engine --impls ELL,BSR --scale 0.1")).unwrap();
        assert_eq!(cli.cfg.impls, vec![Impl::Ell, Impl::Bsr]);
    }

    #[test]
    fn autotune_flag_parses() {
        let cli = parse_args(args("engine --autotune --scale 0.1")).unwrap();
        assert!(cli.cfg.autotune);
        // default off; the `route` command enables it internally
        let cli = parse_args(args("route --scale 0.1")).unwrap();
        assert!(!cli.cfg.autotune);
    }

    #[test]
    fn serve_flags_parse() {
        let cli = parse_args(args("serve --clients 6 --queue 8 --state tuned.json")).unwrap();
        assert_eq!(cli.cfg.clients, 6);
        assert_eq!(cli.cfg.queue_cap, 8);
        assert_eq!(cli.cfg.state_path.as_deref(), Some("tuned.json"));
        // defaults when unset
        let cli = parse_args(args("serve")).unwrap();
        assert_eq!((cli.cfg.clients, cli.cfg.queue_cap), (4, 64));
        assert!(cli.cfg.state_path.is_none());
        // validation catches zeros
        assert!(parse_args(args("serve --clients 0")).is_err());
        assert!(parse_args(args("serve --queue 0")).is_err());
    }

    #[test]
    fn pipeline_flags_parse() {
        let cli = parse_args(args("pipeline --scale 0.1 --d 8 --state pins.json")).unwrap();
        assert_eq!(cli.command, "pipeline");
        assert_eq!(cli.cfg.d_values, vec![8]);
        assert_eq!(cli.cfg.state_path.as_deref(), Some("pins.json"));
        assert!(usage().contains("pipeline"));
    }

    #[test]
    fn corpus_flags_parse() {
        let cli = parse_args(args("corpus --mtx data/ss --budget 1048576 --d 8")).unwrap();
        assert_eq!(cli.command, "corpus");
        assert_eq!(cli.cfg.mtx_dir.as_deref(), Some("data/ss"));
        assert_eq!(cli.cfg.ooc_budget, 1048576);
        // defaults when unset
        let cli = parse_args(args("corpus")).unwrap();
        assert!(cli.cfg.mtx_dir.is_none());
        assert_eq!(cli.cfg.ooc_budget, crate::harness::CORPUS_DEFAULT_BUDGET);
        assert!(parse_args(args("corpus --budget nope")).is_err());
        assert!(usage().contains("corpus"));
    }

    #[test]
    fn positional_args() {
        let cli = parse_args(args("classify er_18_1 --scale 0.1")).unwrap();
        assert_eq!(cli.positional, vec!["er_18_1"]);
    }

    #[test]
    fn rejects_unknown_flag_and_bad_values() {
        assert!(parse_args(args("table-v --bogus 1")).is_err());
        assert!(parse_args(args("table-v --scale nope")).is_err());
        assert!(parse_args(args("table-v --d 1,x")).is_err());
        assert!(parse_args(Vec::<String>::new()).is_err());
    }

    #[test]
    fn dispatch_cheap_commands() {
        // commands with no benchmarking run in tests
        dispatch(&parse_args(args("hubs")).unwrap()).unwrap();
        dispatch(&parse_args(args("suite --scale 0.02")).unwrap()).unwrap();
        dispatch(&parse_args(args("classify rajat31_p --scale 0.02")).unwrap()).unwrap();
        assert!(dispatch(&parse_args(args("nope")).unwrap()).is_err());
        assert!(dispatch(&parse_args(args("classify zzz --scale 0.02")).unwrap()).is_err());
    }
}
