//! A single set-associative LRU cache level.

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheConfig {
    /// Total capacity in bytes.
    pub size_bytes: usize,
    /// Line size in bytes (64 on every x86 part we care about).
    pub line_bytes: usize,
    /// Associativity (ways per set).
    pub ways: usize,
}

impl CacheConfig {
    /// Number of sets.
    pub fn sets(&self) -> usize {
        (self.size_bytes / (self.line_bytes * self.ways)).max(1)
    }
}

/// Hit/miss counters for one level.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub accesses: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Hit rate in [0, 1]; 0 for an untouched cache.
    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            1.0 - self.misses as f64 / self.accesses as f64
        }
    }
}

/// Set-associative cache with true-LRU replacement.
///
/// Tags are stored per set in recency order (index 0 = MRU), which
/// makes lookup a small linear scan — at ≤16 ways this beats fancier
/// structures and keeps the simulator allocation-free per access.
pub struct Cache {
    cfg: CacheConfig,
    sets: usize,
    set_shift: u32,
    /// `tags[set * ways + way]`; `u64::MAX` = invalid.
    tags: Vec<u64>,
    /// occupancy per set
    filled: Vec<u8>,
    pub stats: CacheStats,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Cache {
        assert!(cfg.line_bytes.is_power_of_two());
        let sets = cfg.sets();
        assert!(sets.is_power_of_two(), "sets must be a power of two (got {sets})");
        Cache {
            cfg,
            sets,
            set_shift: cfg.line_bytes.trailing_zeros(),
            tags: vec![u64::MAX; sets * cfg.ways],
            filled: vec![0; sets],
            stats: CacheStats::default(),
        }
    }

    pub fn config(&self) -> CacheConfig {
        self.cfg
    }

    /// Access the line containing `addr`. Returns `true` on hit; on
    /// miss the line is installed (evicting LRU).
    pub fn access(&mut self, addr: u64) -> bool {
        self.stats.accesses += 1;
        let line = addr >> self.set_shift;
        let set = (line as usize) & (self.sets - 1);
        let ways = self.cfg.ways;
        let base = set * ways;
        let n = self.filled[set] as usize;
        let slot = &mut self.tags[base..base + ways];
        // lookup
        for i in 0..n {
            if slot[i] == line {
                // move to MRU
                slot[..=i].rotate_right(1);
                return true;
            }
        }
        self.stats.misses += 1;
        // install at MRU, evict LRU if full
        if n < ways {
            slot[..=n].rotate_right(1);
            self.filled[set] = (n + 1) as u8;
        } else {
            slot.rotate_right(1);
        }
        slot[0] = line;
        false
    }

    /// Drop all contents and counters.
    pub fn reset(&mut self) {
        self.tags.iter_mut().for_each(|t| *t = u64::MAX);
        self.filled.iter_mut().for_each(|f| *f = 0);
        self.stats = CacheStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets × 2 ways × 64B = 512B
        Cache::new(CacheConfig { size_bytes: 512, line_bytes: 64, ways: 2 })
    }

    #[test]
    fn first_access_misses_second_hits() {
        let mut c = tiny();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.stats.misses, 2);
        assert_eq!(c.stats.accesses, 4);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = tiny();
        // set 0 holds lines with (line % 4 == 0): addresses 0, 256, 512...
        assert!(!c.access(0)); // A
        assert!(!c.access(256)); // B  (set full: A LRU)
        assert!(c.access(0)); // touch A -> B LRU
        assert!(!c.access(512)); // C evicts B
        assert!(c.access(0)); // A still resident
        assert!(!c.access(256)); // B was evicted
    }

    #[test]
    fn hit_rate_math() {
        let mut c = tiny();
        c.access(0);
        c.access(0);
        c.access(0);
        c.access(0);
        assert!((c.stats.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let mut c = tiny();
        c.access(0);
        c.reset();
        assert_eq!(c.stats, CacheStats::default());
        assert!(!c.access(0));
    }

    #[test]
    fn streaming_larger_than_cache_always_misses() {
        let mut c = tiny();
        for addr in (0..(1 << 16)).step_by(64) {
            c.access(addr);
        }
        assert_eq!(c.stats.misses, c.stats.accesses);
    }
}
