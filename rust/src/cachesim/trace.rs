//! Replay the exact memory-access streams of the CSR and CSB SpMM
//! kernels through a simulated hierarchy.
//!
//! Conventions (uniform across kernels so comparisons are fair, and
//! matching the paper's byte model):
//! * `A` arrays: 4-byte indices, 8-byte values, loaded in kernel order.
//! * `B` rows: d·8-byte loads at the row's address.
//! * `C` updates: read-modify-write loads (they hit while a row/block
//!   window is live); the final write-back is charged once at the end
//!   as `8·n·d` DRAM bytes (the paper's "C is written once").

use std::sync::Mutex;

use crate::cachesim::{Hierarchy, HierarchyConfig, TrafficReport};
use crate::sparse::{Csb, Csr};
use crate::spmm::pool;

/// Virtual address map for one SpMM invocation. Arrays are laid out
/// back-to-back at 4 KiB alignment, mirroring contiguous allocations.
#[derive(Debug, Clone, Copy)]
pub struct SpmmLayout {
    pub row_ptr: u64,
    pub col_idx: u64,
    pub vals: u64,
    pub b: u64,
    pub c: u64,
}

impl SpmmLayout {
    /// Lay out a CSR-shaped problem: `n` rows, `nnz` entries, `d`
    /// dense columns.
    pub fn for_problem(n: usize, nnz: usize, d: usize) -> SpmmLayout {
        let align = |x: u64| (x + 4095) & !4095;
        let row_ptr = 0u64;
        let col_idx = align(row_ptr + (n as u64 + 1) * 4);
        let vals = align(col_idx + nnz as u64 * 4);
        let b = align(vals + nnz as u64 * 8);
        let c = align(b + (n as u64) * (d as u64) * 8);
        SpmmLayout { row_ptr, col_idx, vals, b, c }
    }
}

/// Replay the row-major CSR SpMM access stream. Returns the hierarchy
/// for inspection (pass a fresh one in).
pub fn trace_csr_spmm(a: &Csr, d: usize, h: &mut Hierarchy) {
    let lay = SpmmLayout::for_problem(a.nrows, a.nnz(), d);
    let dw = (d * 8) as u32;
    for r in 0..a.nrows {
        // row_ptr[r], row_ptr[r+1] — one 8-byte touch covers both
        h.load(lay.row_ptr + r as u64 * 4, 8);
        let (start, end) = (a.row_ptr[r], a.row_ptr[r + 1]);
        for i in start..end {
            h.load(lay.col_idx + i as u64 * 4, 4);
            h.load(lay.vals + i as u64 * 8, 8);
            let col = a.col_idx[i] as u64;
            h.load(lay.b + col * d as u64 * 8, dw);
            // C row read-modify-write (hits while the row is live)
            h.load(lay.c + r as u64 * d as u64 * 8, dw);
        }
    }
    // final write-back of C
    h.charge_dram(a.nrows as u64 * d as u64 * 8);
}

/// Replay the block-row-major CSB SpMM access stream.
pub fn trace_csb_spmm(a: &Csb, d: usize, h: &mut Hierarchy) {
    let lay = SpmmLayout::for_problem(a.nrows, a.nnz(), d);
    let dw = (d * 8) as u32;
    let t = a.block_dim as u64;
    for br in 0..a.n_block_rows {
        let row_base = br as u64 * t;
        for blk in a.block_row(br) {
            let col_base = blk.bcol as u64 * t;
            for i in blk.start..blk.end {
                // rel_row+rel_col = 4 bytes/entry (2×u16)
                h.load(lay.col_idx + i as u64 * 4, 4);
                h.load(lay.vals + i as u64 * 8, 8);
                let r = row_base + a.rel_row[i] as u64;
                let c = col_base + a.rel_col[i] as u64;
                h.load(lay.b + c * d as u64 * 8, dw);
                h.load(lay.c + r * d as u64 * 8, dw);
            }
        }
    }
    h.charge_dram(a.nrows as u64 * d as u64 * 8);
}

/// One replay request for [`trace_spmm_batch`].
#[derive(Debug, Clone, Copy)]
pub enum TraceJob<'a> {
    /// Replay the CSR kernel's stream over matrix `.0` at width `.1`.
    Csr(&'a Csr, usize),
    /// Replay the CSB kernel's stream over matrix `.0` at width `.1`.
    Csb(&'a Csb, usize),
}

/// Replay many SpMM access streams concurrently on the shared worker
/// pool — each job gets a private simulated hierarchy (config `cfg`),
/// so replays are independent and the output order matches the input
/// order exactly.
///
/// The simulator walks every memory access, which makes single-stream
/// replay the slowest experiment in the harness; fanning the
/// (matrix, d) grid across the persistent pool recovers most of a
/// machine-width speedup without touching the simulator itself.
pub fn trace_spmm_batch(jobs: &[TraceJob<'_>], cfg: HierarchyConfig) -> Vec<TrafficReport> {
    let slots: Vec<Mutex<Option<TrafficReport>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    pool::parallel_chunks_dynamic(jobs.len(), pool::global_threads(), 1, |range| {
        for i in range {
            let mut h = Hierarchy::new(cfg);
            match jobs[i] {
                TraceJob::Csr(a, d) => trace_csr_spmm(a, d, &mut h),
                TraceJob::Csb(a, d) => trace_csb_spmm(a, d, &mut h),
            }
            *slots[i].lock().unwrap() = Some(h.report());
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every trace slot is filled exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, erdos_renyi, Prng};

    #[test]
    fn layout_is_disjoint_and_ordered() {
        let l = SpmmLayout::for_problem(1000, 5000, 16);
        assert!(l.row_ptr < l.col_idx);
        assert!(l.col_idx + 5000 * 4 <= l.vals);
        assert!(l.vals + 5000 * 8 <= l.b);
        assert!(l.b + 1000 * 16 * 8 <= l.c);
    }

    #[test]
    fn diagonal_traffic_below_random() {
        // Same n, nnz, d: the banded matrix must pull fewer DRAM bytes
        // for B than the random one — the paper's central claim.
        let n = 4096;
        let d = 16;
        let mut rng = Prng::new(150);
        let random = erdos_renyi(n, n, 9.0, &mut rng);
        let diag = banded(n, 4, 1.0, &mut rng); // ~9 per row, in-band
        let mut h1 = Hierarchy::new(HierarchyConfig::tiny());
        trace_csr_spmm(&random, d, &mut h1);
        let mut h2 = Hierarchy::new(HierarchyConfig::tiny());
        trace_csr_spmm(&diag, d, &mut h2);
        let r_rand = h1.report();
        let r_diag = h2.report();
        assert!(
            r_diag.dram_bytes * 2 < r_rand.dram_bytes,
            "diag {} vs random {}",
            r_diag.dram_bytes,
            r_rand.dram_bytes
        );
    }

    #[test]
    fn csb_trace_counts_all_entries() {
        let mut rng = Prng::new(151);
        let a = erdos_renyi(512, 512, 6.0, &mut rng);
        let csb = Csb::from_csr_with_block(&a, 128);
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        trace_csb_spmm(&csb, 4, &mut h);
        let r = h.report();
        // logical bytes: per entry 4 + 8 + 2·(4·8) loads
        let per_entry = 4 + 8 + 2 * 32;
        assert_eq!(r.logical_bytes, a.nnz() as u64 * per_entry as u64);
    }

    #[test]
    fn batch_matches_sequential_replay() {
        let mut rng = Prng::new(153);
        let a = erdos_renyi(512, 512, 5.0, &mut rng);
        let b = banded(512, 3, 1.0, &mut rng);
        let csb = Csb::from_csr_with_block(&a, 128);
        let cfg = HierarchyConfig::tiny();
        let jobs = vec![
            TraceJob::Csr(&a, 4),
            TraceJob::Csr(&b, 8),
            TraceJob::Csb(&csb, 4),
            TraceJob::Csr(&a, 16),
        ];
        let batch = trace_spmm_batch(&jobs, cfg);
        assert_eq!(batch.len(), 4);
        // replays are deterministic: pooled results must equal serial
        for (i, job) in jobs.iter().enumerate() {
            let mut h = Hierarchy::new(cfg);
            match *job {
                TraceJob::Csr(m, d) => trace_csr_spmm(m, d, &mut h),
                TraceJob::Csb(m, d) => trace_csb_spmm(m, d, &mut h),
            }
            let want = h.report();
            assert_eq!(batch[i].dram_bytes, want.dram_bytes, "job {i}");
            assert_eq!(batch[i].logical_bytes, want.logical_bytes, "job {i}");
        }
    }

    #[test]
    fn dram_bytes_at_least_compulsory() {
        let mut rng = Prng::new(152);
        let a = erdos_renyi(1024, 1024, 4.0, &mut rng);
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        trace_csr_spmm(&a, 8, &mut h);
        let r = h.report();
        // at minimum: A values once + C write-back
        let floor = a.nnz() as u64 * 8 + 1024 * 8 * 8;
        assert!(r.dram_bytes > floor, "{} <= {floor}", r.dram_bytes);
    }
}
