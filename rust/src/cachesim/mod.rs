//! Cache-hierarchy simulator.
//!
//! The paper *infers* memory traffic analytically; we additionally
//! *measure* it by replaying the exact address stream of each SpMM
//! kernel through a set-associative LRU L1/L2/L3 hierarchy and counting
//! DRAM-line fills. This is the V1 experiment of DESIGN.md: modeled
//! bytes (Eqs. 2–4 denominators) vs simulated DRAM bytes, per pattern —
//! which separates "model error" from "implementation inefficiency",
//! the confound the paper's §V limitations call out.

mod cache;
mod hierarchy;
mod trace;

pub use cache::{Cache, CacheConfig, CacheStats};
pub use hierarchy::{Hierarchy, HierarchyConfig, TrafficReport};
pub use trace::{trace_csb_spmm, trace_csr_spmm, trace_spmm_batch, SpmmLayout, TraceJob};
