//! Inclusive L1→L2→L3→DRAM hierarchy with per-level counters and a
//! DRAM-byte total.

use crate::cachesim::cache::{Cache, CacheConfig, CacheStats};

/// Geometry of the simulated hierarchy.
#[derive(Debug, Clone, Copy)]
pub struct HierarchyConfig {
    pub l1: CacheConfig,
    pub l2: CacheConfig,
    pub l3: CacheConfig,
}

impl HierarchyConfig {
    /// One EPYC-7763 core's slice of the paper's test system
    /// (Table IV): 32 KiB 8-way L1D, 512 KiB 8-way L2, and a
    /// per-core-appropriate 32 MiB 16-way slice of the 256 MiB L3.
    pub fn epyc7763_core() -> HierarchyConfig {
        HierarchyConfig {
            l1: CacheConfig { size_bytes: 32 << 10, line_bytes: 64, ways: 8 },
            l2: CacheConfig { size_bytes: 512 << 10, line_bytes: 64, ways: 8 },
            l3: CacheConfig { size_bytes: 32 << 20, line_bytes: 64, ways: 16 },
        }
    }

    /// Smaller hierarchy for fast simulation in tests.
    pub fn tiny() -> HierarchyConfig {
        HierarchyConfig {
            l1: CacheConfig { size_bytes: 4 << 10, line_bytes: 64, ways: 4 },
            l2: CacheConfig { size_bytes: 32 << 10, line_bytes: 64, ways: 8 },
            l3: CacheConfig { size_bytes: 256 << 10, line_bytes: 64, ways: 8 },
        }
    }
}

/// Traffic summary after a replay.
#[derive(Debug, Clone, Copy)]
pub struct TrafficReport {
    pub l1: CacheStats,
    pub l2: CacheStats,
    pub l3: CacheStats,
    /// Total bytes fetched from DRAM (L3-miss lines × line size +
    /// write-backs modeled as write-through streaming stores).
    pub dram_bytes: u64,
    /// Total bytes the kernel logically touched (accesses × access
    /// width).
    pub logical_bytes: u64,
}

impl TrafficReport {
    /// DRAM bytes / logical bytes — below 1.0 when caches filter
    /// traffic.
    pub fn traffic_ratio(&self) -> f64 {
        if self.logical_bytes == 0 {
            0.0
        } else {
            self.dram_bytes as f64 / self.logical_bytes as f64
        }
    }
}

/// The simulated hierarchy. Reads walk L1→L2→L3; a miss at every level
/// charges one DRAM line. Stores are modeled as write-allocate reads
/// plus a DRAM write-back charge per evicted... simplified: streaming
/// stores charge their bytes directly to DRAM once per line via a
/// dedicated store-line tracker (SpMM writes C exactly once, so
/// write-allocate vs streaming only shifts a constant).
pub struct Hierarchy {
    pub l1: Cache,
    pub l2: Cache,
    pub l3: Cache,
    line_bytes: u64,
    dram_bytes: u64,
    logical_bytes: u64,
    /// last store line, to coalesce sequential store traffic
    last_store_line: u64,
}

impl Hierarchy {
    pub fn new(cfg: HierarchyConfig) -> Hierarchy {
        assert_eq!(cfg.l1.line_bytes, cfg.l2.line_bytes);
        assert_eq!(cfg.l1.line_bytes, cfg.l3.line_bytes);
        Hierarchy {
            line_bytes: cfg.l1.line_bytes as u64,
            l1: Cache::new(cfg.l1),
            l2: Cache::new(cfg.l2),
            l3: Cache::new(cfg.l3),
            dram_bytes: 0,
            logical_bytes: 0,
            last_store_line: u64::MAX,
        }
    }

    /// Simulate a load of `bytes` starting at `addr` (split across
    /// lines as needed).
    #[inline]
    pub fn load(&mut self, addr: u64, bytes: u32) {
        self.logical_bytes += bytes as u64;
        let first = addr >> self.line_bytes.trailing_zeros();
        let last = (addr + bytes as u64 - 1) >> self.line_bytes.trailing_zeros();
        for line in first..=last {
            let a = line << self.line_bytes.trailing_zeros();
            if !self.l1.access(a) && !self.l2.access(a) && !self.l3.access(a) {
                self.dram_bytes += self.line_bytes;
            }
        }
    }

    /// Simulate a store of `bytes` at `addr`: charged to DRAM once per
    /// line (streaming-store model; C is written exactly once in SpMM).
    #[inline]
    pub fn store(&mut self, addr: u64, bytes: u32) {
        self.logical_bytes += bytes as u64;
        let shift = self.line_bytes.trailing_zeros();
        let first = addr >> shift;
        let last = (addr + bytes as u64 - 1) >> shift;
        for line in first..=last {
            if line != self.last_store_line {
                self.dram_bytes += self.line_bytes;
                self.last_store_line = line;
            }
        }
    }

    /// Charge bytes straight to DRAM without touching the caches
    /// (used for end-of-kernel write-back accounting).
    pub fn charge_dram(&mut self, bytes: u64) {
        self.dram_bytes += bytes;
    }

    /// Counters so far.
    pub fn report(&self) -> TrafficReport {
        TrafficReport {
            l1: self.l1.stats,
            l2: self.l2.stats,
            l3: self.l3.stats,
            dram_bytes: self.dram_bytes,
            logical_bytes: self.logical_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_cascade_charges_dram_once() {
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        h.load(0, 8);
        let r = h.report();
        assert_eq!(r.l1.misses, 1);
        assert_eq!(r.l2.misses, 1);
        assert_eq!(r.l3.misses, 1);
        assert_eq!(r.dram_bytes, 64);
        // second access: L1 hit, nothing moves
        h.load(8, 8);
        let r = h.report();
        assert_eq!(r.dram_bytes, 64);
        assert_eq!(r.l1.misses, 1);
    }

    #[test]
    fn straddling_load_touches_two_lines() {
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        h.load(60, 8); // crosses 64B boundary
        assert_eq!(h.report().dram_bytes, 128);
    }

    #[test]
    fn l2_catches_l1_evictions() {
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        // stream 8 KiB (2× L1) twice: second pass should hit L2
        for addr in (0..8192u64).step_by(64) {
            h.load(addr, 8);
        }
        let after_first = h.report().dram_bytes;
        for addr in (0..8192u64).step_by(64) {
            h.load(addr, 8);
        }
        let r = h.report();
        assert_eq!(r.dram_bytes, after_first, "second pass served from L2/L3");
        assert!(r.l2.hit_rate() > 0.0);
    }

    #[test]
    fn sequential_stores_coalesce() {
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        for i in 0..8u64 {
            h.store(i * 8, 8);
        }
        assert_eq!(h.report().dram_bytes, 64);
    }

    #[test]
    fn traffic_ratio() {
        let mut h = Hierarchy::new(HierarchyConfig::tiny());
        h.load(0, 64);
        let r = h.report();
        assert!((r.traffic_ratio() - 1.0).abs() < 1e-12);
    }
}
