//! Padded-ELL SpMM — the native twin of the XLA/Pallas artifact.
//!
//! Identical arithmetic to the JAX layer (`python/compile/model.py`):
//! every row owns `width` (column, value) slots including zero-valued
//! padding, so FLOPs are `2·n·width·d` regardless of nnz. The kernel
//! exists (a) to sanity-check the PJRT path against a native
//! implementation with the same memory behaviour and (b) to quantify
//! the padding tax the static-shape AOT route pays on skewed matrices.

use crate::error::Result;
use crate::sparse::{Csr, Ell};
use crate::spmm::csr_kernel::{axpy_row, RawRows};
use crate::spmm::pool::{default_chunk, parallel_chunks_dynamic};
use crate::spmm::{check_dims, DenseMatrix, Impl, Spmm};

/// Row-parallel padded-ELL SpMM kernel.
pub struct EllSpmm {
    a: Ell,
    threads: usize,
}

impl EllSpmm {
    /// Convert from CSR at the minimum padding width.
    pub fn from_csr(csr: &Csr, threads: usize) -> Self {
        EllSpmm { a: Ell::from_csr(csr), threads: threads.max(1) }
    }

    /// Wrap an existing ELL matrix (e.g. the exact array set shipped to
    /// the XLA artifact).
    pub fn new(a: Ell, threads: usize) -> Self {
        EllSpmm { a, threads: threads.max(1) }
    }

    /// Underlying ELL structure (padding statistics for reports).
    pub fn matrix(&self) -> &Ell {
        &self.a
    }
}

impl Spmm for EllSpmm {
    fn id(&self) -> Impl {
        Impl::Ell
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        let w = a.width;
        let chunk = default_chunk(a.nrows, self.threads);
        parallel_chunks_dynamic(a.nrows, self.threads, chunk, |range| {
            for r in range {
                // SAFETY: disjoint row ownership per chunk.
                let crow = unsafe { rows.row(r) };
                crow.iter_mut().for_each(|x| *x = 0.0);
                let base = r * w;
                for k in 0..w {
                    let v = a.vals[base + k];
                    // padding slots have v == 0.0; branch-free axpy is
                    // cheaper than a branch at ELL's typical widths
                    axpy_row(crow, b.row(a.col_idx[base + k] as usize), v);
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, erdos_renyi, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference() {
        let mut rng = Prng::new(90);
        let a = erdos_renyi(200, 200, 5.0, &mut rng);
        for d in [1usize, 4, 16, 64] {
            let b = DenseMatrix::random(200, d, &mut rng);
            let want = reference_spmm(&a, &b);
            let k = EllSpmm::from_csr(&a, 2);
            let mut c = DenseMatrix::zeros(200, d);
            k.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "d={d}");
        }
    }

    #[test]
    fn banded_low_padding() {
        let mut rng = Prng::new(91);
        let a = banded(500, 4, 0.5, &mut rng);
        let k = EllSpmm::from_csr(&a, 1);
        assert!(k.matrix().padding_ratio() < 3.0);
        let b = DenseMatrix::random(500, 8, &mut rng);
        let want = reference_spmm(&a, &b);
        let mut c = DenseMatrix::zeros(500, 8);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn nnz_excludes_padding() {
        let a = Csr::from_dense(3, 3, &[1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 4.0, 0.0, 0.0]);
        let k = EllSpmm::from_csr(&a, 1);
        assert_eq!(k.nnz(), 4);
        assert_eq!(k.matrix().padded_len(), 9);
    }
}
