//! Padded-ELL SpMM — the native twin of the XLA/Pallas artifact.
//!
//! Identical arithmetic to the JAX layer (`python/compile/model.py`):
//! every row owns `width` (column, value) slots including zero-valued
//! padding, so FLOPs are `2·n·width·d` regardless of nnz. The kernel
//! exists (a) to sanity-check the PJRT path against a native
//! implementation with the same memory behaviour and (b) to quantify
//! the padding tax the static-shape AOT route pays on skewed matrices.
//!
//! The schedule's partitions are uniform over rows — for padded ELL
//! every row does exactly `width` slots of work, so the uniform split
//! *is* the nnz-balanced one — and column tiles apply as in CSR.

use crate::error::Result;
use crate::sparse::{Csr, Ell};
use crate::spmm::simd::{axpy_row, RawRows};
use crate::spmm::schedule::{for_each_part, Schedule};
use crate::spmm::{check_dims, check_schedule, DenseMatrix, Impl, Spmm};

/// Row-parallel padded-ELL SpMM kernel.
pub struct EllSpmm {
    a: Ell,
    base: Schedule,
}

impl EllSpmm {
    /// Convert from CSR at the minimum padding width.
    pub fn from_csr(csr: &Csr, threads: usize) -> Self {
        Self::new(Ell::from_csr(csr), threads)
    }

    /// Wrap an existing ELL matrix (e.g. the exact array set shipped to
    /// the XLA artifact).
    pub fn new(a: Ell, threads: usize) -> Self {
        let base = Schedule::uniform(a.nrows, threads.max(1));
        EllSpmm { a, base }
    }

    /// Underlying ELL structure (padding statistics for reports).
    pub fn matrix(&self) -> &Ell {
        &self.a
    }
}

impl Spmm for EllSpmm {
    fn id(&self) -> Impl {
        Impl::Ell
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.base)
    }

    fn plan(&self, tile: Option<usize>) -> Schedule {
        self.base.clone().with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        check_schedule(self.a.nrows, s)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        let w = a.width;
        for_each_part(s, b.ncols, |range, cols| {
            for r in range {
                // SAFETY: disjoint (row, tile) ownership per cell.
                let crow = unsafe { rows.row(r) };
                let ct = &mut crow[cols.clone()];
                ct.fill(0.0);
                let base = r * w;
                for k in 0..w {
                    let v = a.vals[base + k];
                    // padding slots have v == 0.0; branch-free axpy is
                    // cheaper than a branch at ELL's typical widths
                    let brow = &b.row(a.col_idx[base + k] as usize)[cols.clone()];
                    axpy_row(ct, brow, v);
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, erdos_renyi, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference() {
        let mut rng = Prng::new(90);
        let a = erdos_renyi(200, 200, 5.0, &mut rng);
        for d in [1usize, 4, 16, 64] {
            let b = DenseMatrix::random(200, d, &mut rng);
            let want = reference_spmm(&a, &b);
            let k = EllSpmm::from_csr(&a, 2);
            let mut c = DenseMatrix::zeros(200, d);
            k.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "d={d}");
        }
    }

    #[test]
    fn tiled_schedule_matches_reference() {
        let mut rng = Prng::new(92);
        let a = erdos_renyi(150, 150, 4.0, &mut rng);
        let d = 10;
        let b = DenseMatrix::random(150, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = EllSpmm::from_csr(&a, 2);
        for dt in [1usize, 3, 9, 10] {
            let s = k.plan(Some(dt));
            let mut c = DenseMatrix::from_vec(150, d, vec![2.5; 150 * d]);
            k.execute_with(&b, &mut c, &s).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "dt={dt}");
        }
    }

    #[test]
    fn banded_low_padding() {
        let mut rng = Prng::new(91);
        let a = banded(500, 4, 0.5, &mut rng);
        let k = EllSpmm::from_csr(&a, 1);
        assert!(k.matrix().padding_ratio() < 3.0);
        let b = DenseMatrix::random(500, 8, &mut rng);
        let want = reference_spmm(&a, &b);
        let mut c = DenseMatrix::zeros(500, 8);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn nnz_excludes_padding() {
        let a = Csr::from_dense(3, 3, &[1.0, 2.0, 3.0, 0.0, 0.0, 0.0, 4.0, 0.0, 0.0]);
        let k = EllSpmm::from_csr(&a, 1);
        assert_eq!(k.nnz(), 4);
        assert_eq!(k.matrix().padded_len(), 9);
    }
}
