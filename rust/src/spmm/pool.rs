//! Persistent worker pool — every parallel loop in the crate runs here.
//!
//! The paper parallelises SpMM with OpenMP over 64 threads. Earlier
//! revisions of this crate spawned and joined fresh OS threads per
//! kernel call (`crossbeam_utils::thread::scope`); at the call rates the
//! engine and benches sustain, that per-call thread churn polluted
//! exactly the bandwidth-bound measurements the roofline models try to
//! predict. This module replaces it with a [`WorkerPool`]: long-lived
//! workers parked on a condvar, woken per submitted job, with two
//! scheduling disciplines —
//!
//! * **static ranges** ([`WorkerPool::ranges`]): `[0, n)` split into
//!   `parts` near-equal contiguous ranges, each executed exactly once
//!   (OpenMP `schedule(static)`), and
//! * **dynamic chunks** ([`WorkerPool::chunks_dynamic`]): workers
//!   repeatedly claim `chunk`-sized ranges from a shared atomic cursor
//!   (OpenMP `schedule(dynamic, chunk)`), for skewed row distributions
//!   where a static split leaves one thread holding every hub row.
//!
//! A process-wide pool ([`global`]) is created lazily on first use and
//! sized to `available_parallelism` (override with the
//! `SPMM_POOL_THREADS` env var; `0` pins it to inline serial
//! execution). All SpMM kernels, the STREAM microbenchmarks, and the
//! cache-simulator batch replay route through it via the free
//! functions [`parallel_ranges`] and [`parallel_chunks_dynamic`], so
//! steady state spawns **zero** threads.
//!
//! Submissions are serialised: concurrent submitters queue on an
//! internal lock, and a parallel call made *from inside* a pool job
//! (nested parallelism) runs inline on the calling worker rather than
//! deadlocking.
//!
//! **Panic containment:** a participant whose closure panics checks
//! out of the job (the submitter never hangs), the panic is re-raised
//! on the submitting thread with the original message, and the worker
//! retires. Every submission first reaps retired workers and respawns
//! replacements ([`WorkerPool::reap`]), so the process-global pool
//! survives a bad kernel indefinitely instead of poisoning every later
//! submit. The submitting thread participates in every job and
//! only as many workers as the job requests are woken (per-call
//! dispatch cost scales with the requested thread count, not the pool
//! size). A job requesting more parallelism than `workers + 1` grows
//! the pool once to that high-water mark — deliberate oversubscription
//! (thread-scaling ablations) behaves like the old spawn-per-call
//! implementation, but the grown workers persist.
//!
//! ```
//! use std::sync::atomic::{AtomicUsize, Ordering};
//! use spmm_roofline::spmm::pool;
//!
//! // Sum 0..1000 over 4-way static ranges on the shared pool.
//! let sum = AtomicUsize::new(0);
//! pool::parallel_ranges(1000, 4, |r| {
//!     sum.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
//! });
//! assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
//!
//! // Same total via dynamically claimed chunks of 64 rows.
//! let sum = AtomicUsize::new(0);
//! pool::parallel_chunks_dynamic(1000, 4, 64, |r| {
//!     sum.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
//! });
//! assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
//! ```

use std::cell::Cell;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

/// Lock that shrugs off poisoning: every mutex in this module guards
/// plain bookkeeping (counters, the job slot, join handles), which
/// stays consistent even if a thread panicked while holding it. A
/// poisoned lock must not cascade into killing the process-global pool
/// — a long-lived engine has to survive one bad kernel.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    // True while this thread is executing a pool job (worker or
    // participating submitter); nested parallel calls check it and run
    // inline instead of re-submitting.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// One submitted parallel loop, type-erased so persistent workers can
/// run borrowed closures. `func` points at the submitter's closure;
/// the submitter blocks until every worker has checked out of the job,
/// which keeps the borrow alive for every call made through it.
#[derive(Clone, Copy)]
struct JobDesc {
    func: *const (dyn Fn(Range<usize>) + Sync + 'static),
    n: usize,
    /// Static split count (`chunk == 0` selects static scheduling).
    parts: usize,
    /// Dynamic chunk size (`0` selects static scheduling).
    chunk: usize,
}

// SAFETY: the raw closure pointer is only dereferenced while the
// submitting thread is blocked in `execute`, which outlives all use.
unsafe impl Send for JobDesc {}

struct PoolState {
    /// Bumped once per published job; workers track the last epoch they
    /// examined so each considers every job exactly once.
    epoch: u64,
    job: Option<JobDesc>,
    /// Worker check-in slots still open for the current job. Only
    /// workers that claim a slot participate; the rest note the epoch
    /// and keep sleeping, so per-job cost scales with the *requested*
    /// parallelism, not the pool size.
    pending: usize,
    /// Participating workers that have not yet checked out.
    active: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work_ready: Condvar,
    /// The submitter parks here until `active == 0`.
    work_done: Condvar,
    /// Work-claim cursor: range index (static) or row start (dynamic).
    cursor: AtomicUsize,
    /// Set when any participant's closure panicked; the submitter
    /// re-raises after the job drains.
    panicked: AtomicBool,
    /// First panic payload of the current job (when stringlike), so the
    /// submitter's re-raise carries the original message.
    panic_msg: Mutex<Option<String>>,
}

/// A persistent pool of parked worker threads executing data-parallel
/// loops (see module docs for the scheduling disciplines and the
/// nesting/concurrency rules).
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serialises submissions; held for the full lifetime of a job.
    submit_lock: Mutex<()>,
    /// Worker threads; grows on demand (under `submit_lock`) up to the
    /// high-water requested parallelism.
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Cached `handles.len()` for lock-free reads.
    n_workers: AtomicUsize,
    /// Workers respawned after dying on a panicked job (observability;
    /// see [`WorkerPool::reap`]).
    n_respawned: AtomicUsize,
    /// A pool constructed with zero workers never grows: every call
    /// runs inline on the submitter (`SPMM_POOL_THREADS=0`).
    inline_only: bool,
}

impl WorkerPool {
    /// Spawn a pool with `workers` parked background threads. The
    /// submitting thread also executes work, and the pool grows on
    /// demand when a job requests more parallelism than `workers + 1`
    /// (grown workers persist — steady state never re-spawns).
    /// `workers == 0` pins the pool to inline serial execution.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                pending: 0,
                active: 0,
                shutdown: false,
            }),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
            cursor: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            panic_msg: Mutex::new(None),
        });
        let handles = (0..workers).map(|i| spawn_worker(&shared, i)).collect();
        WorkerPool {
            shared,
            submit_lock: Mutex::new(()),
            handles: Mutex::new(handles),
            n_workers: AtomicUsize::new(workers),
            n_respawned: AtomicUsize::new(0),
            inline_only: workers == 0,
        }
    }

    /// Number of background worker threads (excluding submitters).
    pub fn workers(&self) -> usize {
        self.n_workers.load(Ordering::Relaxed)
    }

    /// Workers respawned so far after dying on a panicked job.
    pub fn respawned(&self) -> usize {
        self.n_respawned.load(Ordering::Relaxed)
    }

    /// Detect and replace dead workers. A worker that ran a panicking
    /// closure checks out of its job (so the submitter never hangs) and
    /// then retires rather than trusting its own state; every
    /// submission calls this before publishing, so a long-lived engine
    /// survives a bad kernel at full strength. Public so callers can
    /// also heal the pool eagerly (tests, health checks). Returns how
    /// many workers were respawned by this call.
    pub fn reap(&self) -> usize {
        let _guard = plock(&self.submit_lock);
        self.reap_locked()
    }

    /// [`WorkerPool::reap`] body; caller must hold `submit_lock` so no
    /// job is in flight while handles are swapped.
    fn reap_locked(&self) -> usize {
        if self.inline_only {
            return 0;
        }
        let mut handles = plock(&self.handles);
        let mut respawned = 0;
        for (i, h) in handles.iter_mut().enumerate() {
            if h.is_finished() {
                let dead = std::mem::replace(h, spawn_worker(&self.shared, i));
                // the panic already surfaced to that job's submitter;
                // the join result is just the corpse
                let _ = dead.join();
                respawned += 1;
            }
        }
        self.n_respawned.fetch_add(respawned, Ordering::Relaxed);
        respawned
    }

    /// Run `f(range)` over a static split of `[0, n)` into `parts`
    /// near-equal contiguous ranges, each executed exactly once. `f`
    /// must be safe to run concurrently on disjoint ranges.
    pub fn ranges<F>(&self, n: usize, parts: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let parts = parts.max(1);
        if n == 0 {
            return;
        }
        if parts == 1 || self.inline_only || IN_POOL.with(|c| c.get()) {
            for r in split_ranges(n, parts) {
                f(r);
            }
            return;
        }
        self.execute(n, parts, 0, parts, &f);
    }

    /// Dynamically scheduled: participants repeatedly claim
    /// `chunk`-sized ranges of `[0, n)` from a shared cursor until
    /// exhausted, with at most `threads` claiming concurrently.
    pub fn chunks_dynamic<F>(&self, n: usize, threads: usize, chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let threads = threads.max(1);
        let chunk = chunk.max(1);
        if n == 0 {
            return;
        }
        if threads == 1 || self.inline_only || IN_POOL.with(|c| c.get()) {
            let mut start = 0;
            while start < n {
                let end = (start + chunk).min(n);
                f(start..end);
                start = end;
            }
            return;
        }
        self.execute(n, 0, chunk, threads, &f);
    }

    /// Publish one job to the parked workers, participate in it, and
    /// block until every worker has checked out. Re-raises any
    /// participant panic as "worker thread panicked" (the contract the
    /// scoped-thread implementation had).
    fn execute(
        &self,
        n: usize,
        parts: usize,
        chunk: usize,
        max_participants: usize,
        f: &(dyn Fn(Range<usize>) + Sync),
    ) {
        let guard = plock(&self.submit_lock);
        // heal before publishing: workers that died on a previous
        // panicked job are replaced so this job runs at full strength
        self.reap_locked();
        // the submitter takes one participant seat; grow the pool so
        // the remaining seats have a worker each (old scoped-thread
        // semantics: oversubscription beyond the core count is the
        // caller's explicit choice, e.g. thread-scaling ablations)
        let wanted = max_participants - 1;
        let have = self.n_workers.load(Ordering::Relaxed);
        if wanted > have {
            let mut handles = plock(&self.handles);
            for i in have..wanted {
                handles.push(spawn_worker(&self.shared, i));
            }
            self.n_workers.store(wanted, Ordering::Relaxed);
        }
        let desc = JobDesc { func: erase(f), n, parts, chunk };
        {
            let mut st = plock(&self.shared.state);
            self.shared.cursor.store(0, Ordering::SeqCst);
            self.shared.panicked.store(false, Ordering::SeqCst);
            *plock(&self.shared.panic_msg) = None;
            st.job = Some(desc);
            st.epoch = st.epoch.wrapping_add(1);
            st.pending = wanted;
            st.active = wanted;
        }
        // wake only as many workers as the job has seats for; a woken
        // worker that finds the seats gone just notes the epoch and
        // parks again
        for _ in 0..wanted {
            self.shared.work_ready.notify_one();
        }

        // The submitter claims work like any worker.
        IN_POOL.with(|c| c.set(true));
        let r = catch_unwind(AssertUnwindSafe(|| run_job(&self.shared, &desc)));
        IN_POOL.with(|c| c.set(false));
        if let Err(payload) = r {
            note_panic(&self.shared, payload.as_ref());
        }

        let mut st = plock(&self.shared.state);
        // Cancel seats nobody claimed: the submitter's own claim loop
        // exhausted the cursor, so an unclaimed seat just means that
        // worker wasn't needed (or its wakeup raced a faster sibling
        // that re-parked and absorbed the notify). Without this the
        // wait below could hang on a worker that never saw the job.
        st.active = st.active.saturating_sub(st.pending);
        st.pending = 0;
        while st.active > 0 {
            st = self
                .shared
                .work_done
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        drop(st);
        drop(guard);
        if self.shared.panicked.load(Ordering::SeqCst) {
            match plock(&self.shared.panic_msg).take() {
                Some(msg) => panic!("worker thread panicked: {msg}"),
                None => panic!("worker thread panicked"),
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = plock(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        for h in plock(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

/// Record a participant panic: set the sticky flag and keep the first
/// stringlike payload so the submitter's re-raise names the cause.
fn note_panic(shared: &Shared, payload: &(dyn std::any::Any + Send)) {
    shared.panicked.store(true, Ordering::SeqCst);
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()));
    if let Some(msg) = msg {
        plock(&shared.panic_msg).get_or_insert(msg);
    }
}

fn spawn_worker(shared: &Arc<Shared>, i: usize) -> JoinHandle<()> {
    let shared = shared.clone();
    std::thread::Builder::new()
        .name(format!("spmm-worker-{i}"))
        .spawn(move || worker_loop(&shared))
        .expect("failed to spawn pool worker")
}

/// Erase the closure's borrow lifetime so it can cross into persistent
/// workers. SAFETY: callers (only [`WorkerPool::execute`]) must not
/// return until no worker can still call through the pointer.
fn erase<'a>(
    f: &'a (dyn Fn(Range<usize>) + Sync + 'a),
) -> *const (dyn Fn(Range<usize>) + Sync + 'static) {
    // A fat-pointer lifetime transmute, the same erasure every scoped
    // thread-pool performs.
    unsafe {
        std::mem::transmute::<
            &'a (dyn Fn(Range<usize>) + Sync + 'a),
            *const (dyn Fn(Range<usize>) + Sync + 'static),
        >(f)
    }
}

fn worker_loop(shared: &Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = plock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    // claim a participant seat if any remain; a fully
                    // staffed (or already completed) job is just noted
                    if st.pending > 0 {
                        if let Some(job) = st.job {
                            st.pending -= 1;
                            break job;
                        }
                    }
                }
                st = shared.work_ready.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        IN_POOL.with(|c| c.set(true));
        let r = catch_unwind(AssertUnwindSafe(|| run_job(shared, &job)));
        IN_POOL.with(|c| c.set(false));
        if let Err(payload) = &r {
            note_panic(shared, payload.as_ref());
        }
        // check out BEFORE retiring — the submitter is blocked on
        // `active` draining to zero and must never hang on a dead worker
        let mut st = plock(&shared.state);
        st.active = st.active.saturating_sub(1);
        if st.active == 0 {
            shared.work_done.notify_all();
        }
        drop(st);
        if r.is_err() {
            // a panicking closure may have left this thread's stack in
            // a state the kernel authors never reasoned about (the
            // closure is not unwind-safe by contract) — retire and let
            // the next submission respawn a clean replacement.
            // Pre-respawn revisions kept looping here, and a poisoned
            // shared mutex then turned one bad kernel into
            // `panic!("worker thread panicked")` on every later submit.
            return;
        }
    }
}

/// Claim and execute work items for `job` until the cursor is
/// exhausted. Callers hold a participant seat (workers claim one in
/// `worker_loop`; the submitter implicitly owns the extra seat), so at
/// most `max_participants` threads run here concurrently.
fn run_job(shared: &Shared, job: &JobDesc) {
    // SAFETY: the submitting thread blocks in `execute` until every
    // participant has checked out of this job, so the borrow behind
    // `func` is alive for every call made here.
    let f = unsafe { &*job.func };
    if job.chunk == 0 {
        loop {
            let i = shared.cursor.fetch_add(1, Ordering::Relaxed);
            if i >= job.parts {
                break;
            }
            let r = nth_range(job.n, job.parts, i);
            if !r.is_empty() {
                f(r);
            }
        }
    } else {
        loop {
            let start = shared.cursor.fetch_add(job.chunk, Ordering::Relaxed);
            if start >= job.n {
                break;
            }
            f(start..(start + job.chunk).min(job.n));
        }
    }
}

/// The `i`-th range of the static split of `[0, n)` into `parts`
/// pieces — consistent with [`split_ranges`] (the first `n % parts`
/// pieces absorb the remainder).
fn nth_range(n: usize, parts: usize, i: usize) -> Range<usize> {
    let base = n / parts;
    let rem = n % parts;
    let start = i * base + i.min(rem);
    let len = base + usize::from(i < rem);
    start..start + len
}

/// Split `[0, n)` into `parts` near-equal contiguous ranges (the first
/// ranges absorb the remainder; empty ranges are skipped).
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1);
    let mut out = Vec::with_capacity(parts);
    for i in 0..parts {
        let r = nth_range(n, parts, i);
        if !r.is_empty() {
            out.push(r);
        }
    }
    out
}

/// Heuristic chunk size: ~8 chunks per thread, at least 64 rows, so the
/// claim cursor stays cold.
pub fn default_chunk(n: usize, threads: usize) -> usize {
    (n / (threads.max(1) * 8)).max(64).min(n.max(1))
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide shared pool, created on first use. Sized to
/// `available_parallelism` background workers unless the
/// `SPMM_POOL_THREADS` env var overrides it (`0` forces everything
/// inline — useful when profiling single-threaded).
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| {
        let workers = std::env::var("SPMM_POOL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        WorkerPool::new(workers)
    })
}

/// Maximum useful parallelism of the shared pool (workers + the
/// submitting thread).
pub fn global_threads() -> usize {
    global().workers() + 1
}

/// Run `f(range)` over a static split of `[0, n)` on up to `threads`
/// participants of the shared pool. `f` must be safe to run
/// concurrently on disjoint ranges.
pub fn parallel_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    global().ranges(n, threads, f);
}

/// Dynamically scheduled on the shared pool: participants repeatedly
/// claim `chunk`-sized ranges of `[0, n)` until exhausted. Use for
/// skewed row distributions (scale-free matrices) where a static split
/// leaves one thread holding every hub row.
pub fn parallel_chunks_dynamic<F>(n: usize, threads: usize, chunk: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    global().chunks_dynamic(n, threads, chunk, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn split_covers_everything() {
        for n in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 2, 3, 8, 200] {
                let rs = split_ranges(n, p);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n, "n={n} p={p}");
                // contiguous and ordered
                let mut expect = 0;
                for r in &rs {
                    assert_eq!(r.start, expect);
                    expect = r.end;
                }
            }
        }
    }

    #[test]
    fn nth_range_matches_split() {
        for n in [0usize, 1, 10, 97] {
            for p in [1usize, 3, 8] {
                let whole = split_ranges(n, p);
                let by_index: Vec<_> =
                    (0..p).map(|i| nth_range(n, p, i)).filter(|r| !r.is_empty()).collect();
                assert_eq!(whole, by_index, "n={n} p={p}");
            }
        }
    }

    #[test]
    fn parallel_ranges_visits_all() {
        let sum = AtomicU64::new(0);
        parallel_ranges(1000, 4, |r| {
            let local: u64 = r.map(|i| i as u64).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn dynamic_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks_dynamic(500, 3, 17, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_path() {
        let sum = AtomicU64::new(0);
        parallel_chunks_dynamic(100, 1, 7, |r| {
            sum.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn default_chunk_reasonable() {
        assert!(default_chunk(1_000_000, 8) >= 64);
        assert!(default_chunk(10, 8) <= 10_usize.max(64));
    }

    #[test]
    fn dedicated_pool_reuses_threads_across_jobs() {
        let pool = WorkerPool::new(3);
        let ids = Mutex::new(HashSet::new());
        for _ in 0..25 {
            pool.ranges(64, 4, |_r| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
        }
        // every job ran on the same small persistent set: at most the 3
        // workers plus the submitting test thread
        assert!(ids.lock().unwrap().len() <= 4);
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        let sum = AtomicU64::new(0);
        pool.chunks_dynamic(100, 8, 9, |r| {
            sum.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 100);
        assert_eq!(pool.workers(), 0);
    }

    #[test]
    fn nested_submission_runs_inline() {
        let pool = WorkerPool::new(2);
        let sum = AtomicU64::new(0);
        pool.ranges(8, 4, |outer| {
            for _ in outer {
                // nested parallel call from inside a pool job: must not
                // deadlock, must still cover everything
                pool.ranges(10, 4, |inner| {
                    sum.fetch_add(inner.len() as u64, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 80);
    }

    #[test]
    fn participant_cap_respected() {
        let pool = WorkerPool::new(3);
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        pool.chunks_dynamic(64, 2, 1, |_r| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(200));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {}", peak.load(Ordering::SeqCst));
    }

    #[test]
    fn pool_grows_to_requested_parallelism() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.workers(), 1);
        let hits: Vec<AtomicU64> = (0..60).map(|_| AtomicU64::new(0)).collect();
        pool.ranges(60, 6, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        // grown once to the high-water request (5 workers + submitter)
        assert_eq!(pool.workers(), 5);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // a smaller follow-up job doesn't shrink it
        pool.ranges(10, 2, |_r| {});
        assert_eq!(pool.workers(), 5);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.ranges(16, 4, |r| {
                if r.contains(&9) {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the submitter");
        // the original message survives the re-raise
        let msg = r.unwrap_err();
        let msg = msg.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("boom"), "payload was '{msg}'");
        // the pool is still usable afterwards
        let sum = AtomicU64::new(0);
        pool.ranges(100, 4, |r| {
            sum.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn dead_workers_are_respawned_and_submissions_keep_working() {
        let pool = WorkerPool::new(2);
        // Induce worker deaths: the closure panics only on pool worker
        // threads; the submitting test thread paces itself so the
        // workers get a chance to claim chunks before the cursor drains.
        let on_worker = || {
            std::thread::current().name().is_some_and(|n| n.starts_with("spmm-worker"))
        };
        let mut killed_some = false;
        for _ in 0..5 {
            let r = catch_unwind(AssertUnwindSafe(|| {
                pool.chunks_dynamic(40, 3, 1, |_r| {
                    if on_worker() {
                        panic!("induced worker panic");
                    }
                    std::thread::sleep(std::time::Duration::from_millis(2));
                });
            }));
            if r.is_err() {
                killed_some = true;
                break;
            }
        }
        assert!(killed_some, "no worker ever claimed a chunk (scheduling fluke ×5)");
        // the dead worker is detected and replaced — poll reap() until
        // the OS reports the thread finished
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while pool.respawned() == 0 && std::time::Instant::now() < deadline {
            pool.reap();
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(pool.respawned() >= 1, "dead worker never respawned");
        assert_eq!(pool.workers(), 2, "pool strength must be restored");
        // and the healed pool still computes correct full coverage
        let hits: Vec<AtomicU64> = (0..200).map(|_| AtomicU64::new(0)).collect();
        pool.ranges(200, 3, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
