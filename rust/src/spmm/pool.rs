//! Minimal data-parallel helpers over `crossbeam_utils::thread::scope`.
//!
//! The paper parallelises SpMM with OpenMP over 64 threads; rayon is
//! unavailable offline, so this module provides the two primitives the
//! kernels need: a static row-range split (`parallel_ranges`) and a
//! dynamically load-balanced chunk queue (`parallel_chunks_dynamic`)
//! for skewed matrices where static splits starve.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Split `[0, n)` into `parts` near-equal contiguous ranges (the last
/// ranges absorb the remainder; empty ranges are skipped).
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        if len > 0 {
            out.push(start..start + len);
            start += len;
        }
    }
    out
}

/// Run `f(range)` over a static split of `[0, n)` on `threads` scoped
/// threads. `f` must be safe to run concurrently on disjoint ranges.
pub fn parallel_ranges<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let ranges = split_ranges(n, threads);
    if ranges.len() <= 1 {
        for r in ranges {
            f(r);
        }
        return;
    }
    crossbeam_utils::thread::scope(|s| {
        for r in ranges {
            let f = &f;
            s.spawn(move |_| f(r));
        }
    })
    .expect("worker thread panicked");
}

/// Dynamically scheduled: workers repeatedly claim `chunk`-sized ranges
/// of `[0, n)` from a shared atomic counter until exhausted. Use for
/// skewed row distributions (scale-free matrices) where a static split
/// leaves one thread holding every hub row.
pub fn parallel_chunks_dynamic<F>(n: usize, threads: usize, chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1);
    let chunk = chunk.max(1);
    if threads == 1 {
        let mut start = 0;
        while start < n {
            let end = (start + chunk).min(n);
            f(start..end);
            start = end;
        }
        return;
    }
    let next = AtomicUsize::new(0);
    crossbeam_utils::thread::scope(|s| {
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            s.spawn(move |_| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                f(start..end);
            });
        }
    })
    .expect("worker thread panicked");
}

/// Heuristic chunk size: ~8 chunks per thread, at least 64 rows, so the
/// atomic counter stays cold.
pub fn default_chunk(n: usize, threads: usize) -> usize {
    (n / (threads.max(1) * 8)).max(64).min(n.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn split_covers_everything() {
        for n in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 2, 3, 8, 200] {
                let rs = split_ranges(n, p);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n, "n={n} p={p}");
                // contiguous and ordered
                let mut expect = 0;
                for r in &rs {
                    assert_eq!(r.start, expect);
                    expect = r.end;
                }
            }
        }
    }

    #[test]
    fn parallel_ranges_visits_all() {
        let sum = AtomicU64::new(0);
        parallel_ranges(1000, 4, |r| {
            let local: u64 = r.map(|i| i as u64).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn dynamic_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks_dynamic(500, 3, 17, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_path() {
        let sum = AtomicU64::new(0);
        parallel_chunks_dynamic(100, 1, 7, |r| {
            sum.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn default_chunk_reasonable() {
        assert!(default_chunk(1_000_000, 8) >= 64);
        assert!(default_chunk(10, 8) <= 10_usize.max(64));
    }
}
