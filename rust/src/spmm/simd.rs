//! The SpMM micro-kernel layer: the **one** place the per-row
//! primitives live, in scalar and explicitly vectorized form, behind a
//! runtime dispatch that is probed once and cached.
//!
//! Before this module existed, `axpy_row` and the `RawRows` aliasing
//! shim were private to `csr_kernel.rs` and the CSB/ELL/OPT/BSR/PB
//! kernels reached into it for them. They are now defined here with
//! one documented `pub(crate)` surface, and each primitive exists in
//! up to three variants:
//!
//! * **scalar** — the portable fallback (and the only variant compiled
//!   off x86_64),
//! * **SSE2** (2 × f64 lanes) — baseline on every x86_64, and
//! * **AVX** (4 × f64 lanes) — used when the one-time CPUID probe
//!   ([`level`]) reports it. AVX-512 is deliberately absent: its f64
//!   intrinsics are not stable at this crate's MSRV (1.70), and the
//!   8-wide path would add a third ordering to audit for no measured
//!   win on the paper's testbed.
//!
//! # Bitwise identity across variants
//!
//! Every variant of every primitive performs **exactly one rounded
//! multiply followed by one rounded add per element, in the same
//! order** — no `vfmadd`, no horizontal reassociation. IEEE-754
//! vector `mul`/`add` round each lane exactly like the scalar ops, so
//! the scalar and SIMD variants are bitwise identical at every length
//! (including every `len % lane_width` remainder — the remainder loop
//! uses the same multiply-then-add expression as the main loop).
//! This is load-bearing: `tests/prop_pb.rs` pins the PB kernel
//! bitwise-equal to CSR, and PB's spill/gather split rounds the
//! product and the add *separately* ([`scale_row`], [`add_row`]) — a
//! fused variant anywhere would break that chain. `tests/prop_simd.rs`
//! pins forced-scalar ≡ dispatched for every kernel.
//!
//! # Dispatch
//!
//! [`level`] resolves once (env `SPMM_FORCE_SCALAR=1` wins, then
//! `is_x86_feature_detected!`) and caches the answer in an atomic, so
//! the per-call cost on the hot path is a single relaxed load.
//! [`force_scalar`] re-resolves at runtime — the seam the property
//! suite uses to run both legs in one process. The cached decision is
//! reported by the engine and persisted in the autotune snapshot
//! ([`crate::report::AutotuneState`]) alongside the measured ladder.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::spmm::DenseMatrix;

/// The instruction-set tier the dispatcher selected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar loops (forced, or non-x86_64).
    Scalar,
    /// 2 × f64 lanes — baseline on every x86_64.
    Sse2,
    /// 4 × f64 lanes.
    Avx,
}

impl SimdLevel {
    /// f64 lanes per vector op.
    pub fn lanes(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Sse2 => 2,
            SimdLevel::Avx => 4,
        }
    }

    /// Stable lowercase name (used in reports and the persisted
    /// snapshot).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx => "avx",
        }
    }

    /// Inverse of [`SimdLevel::name`].
    pub fn parse(s: &str) -> Option<SimdLevel> {
        match s {
            "scalar" => Some(SimdLevel::Scalar),
            "sse2" => Some(SimdLevel::Sse2),
            "avx" => Some(SimdLevel::Avx),
            _ => None,
        }
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// 0 = unresolved; 1/2/3 = Scalar/Sse2/Avx.
static LEVEL: AtomicU8 = AtomicU8::new(0);

fn code(l: SimdLevel) -> u8 {
    match l {
        SimdLevel::Scalar => 1,
        SimdLevel::Sse2 => 2,
        SimdLevel::Avx => 3,
    }
}

/// What the hardware supports, ignoring any forced override.
pub fn detected() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx") {
            SimdLevel::Avx
        } else {
            // SSE2 is architecturally guaranteed on x86_64
            SimdLevel::Sse2
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        SimdLevel::Scalar
    }
}

#[cold]
fn resolve() -> SimdLevel {
    let forced = std::env::var("SPMM_FORCE_SCALAR")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false);
    let l = if forced { SimdLevel::Scalar } else { detected() };
    LEVEL.store(code(l), Ordering::Relaxed);
    l
}

/// The dispatch decision in force: resolved once (env override, then
/// CPUID) and cached — one relaxed atomic load per call after that.
#[inline(always)]
pub fn level() -> SimdLevel {
    match LEVEL.load(Ordering::Relaxed) {
        1 => SimdLevel::Scalar,
        2 => SimdLevel::Sse2,
        3 => SimdLevel::Avx,
        _ => resolve(),
    }
}

/// Override the cached dispatch at runtime: `true` pins the scalar
/// variants, `false` re-probes the hardware (overriding any
/// `SPMM_FORCE_SCALAR` from the environment). Because every variant is
/// bitwise-identical, toggling mid-computation changes timing only,
/// never results — but tests that *compare* the legs should still
/// serialise their toggles (see `tests/prop_simd.rs`).
pub fn force_scalar(on: bool) {
    let l = if on { SimdLevel::Scalar } else { detected() };
    LEVEL.store(code(l), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// axpy_row: c[i] += v * b[i]
// ---------------------------------------------------------------------------

/// Scalar `c[i] += v * b[i]`. The 4-wide unrolled main loop and the
/// remainder loop use the *same* multiply-then-add expression per
/// element, so every `len % 4` tail rounds identically to the main
/// body — and identically to the SIMD lanes.
#[inline(always)]
pub(crate) fn axpy_row_scalar(c: &mut [f64], b: &[f64], v: f64) {
    debug_assert_eq!(c.len(), b.len());
    let mut cq = c.chunks_exact_mut(4);
    let mut bq = b.chunks_exact(4);
    for (cc, bb) in (&mut cq).zip(&mut bq) {
        cc[0] += v * bb[0];
        cc[1] += v * bb[1];
        cc[2] += v * bb[2];
        cc[3] += v * bb[3];
    }
    for (cc, bb) in cq.into_remainder().iter_mut().zip(bq.remainder()) {
        *cc += v * bb;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn axpy_row_sse2(c: &mut [f64], b: &[f64], v: f64) {
    use std::arch::x86_64::*;
    let n = c.len();
    let (cp, bp) = (c.as_mut_ptr(), b.as_ptr());
    let vv = _mm_set1_pd(v);
    let pairs = n & !1;
    let mut i = 0;
    while i < pairs {
        let acc = _mm_loadu_pd(cp.add(i));
        let prod = _mm_mul_pd(vv, _mm_loadu_pd(bp.add(i)));
        _mm_storeu_pd(cp.add(i), _mm_add_pd(acc, prod));
        i += 2;
    }
    if i < n {
        *cp.add(i) += v * *bp.add(i);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn axpy_row_avx(c: &mut [f64], b: &[f64], v: f64) {
    use std::arch::x86_64::*;
    let n = c.len();
    let (cp, bp) = (c.as_mut_ptr(), b.as_ptr());
    let vv = _mm256_set1_pd(v);
    let quads = n & !3;
    let mut i = 0;
    while i < quads {
        let acc = _mm256_loadu_pd(cp.add(i));
        let prod = _mm256_mul_pd(vv, _mm256_loadu_pd(bp.add(i)));
        _mm256_storeu_pd(cp.add(i), _mm256_add_pd(acc, prod));
        i += 4;
    }
    while i < n {
        *cp.add(i) += v * *bp.add(i);
        i += 1;
    }
}

/// `c[i] += v * b[i]` — the workhorse of every row-parallel kernel,
/// dispatched to the widest available variant.
#[inline(always)]
pub(crate) fn axpy_row(c: &mut [f64], b: &[f64], v: f64) {
    debug_assert_eq!(c.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    match level() {
        // safety: variants only read/write within the equal-length
        // slices, and the target features were verified by `level()`
        SimdLevel::Avx => unsafe { axpy_row_avx(c, b, v) },
        SimdLevel::Sse2 => unsafe { axpy_row_sse2(c, b, v) },
        SimdLevel::Scalar => axpy_row_scalar(c, b, v),
    }
    #[cfg(not(target_arch = "x86_64"))]
    axpy_row_scalar(c, b, v);
}

// ---------------------------------------------------------------------------
// axpy2_row: c[i] += v0 * b0[i]; c[i] += v1 * b1[i]
// ---------------------------------------------------------------------------

/// Scalar two-nonzero step: per element, the product of the *first*
/// nonzero is rounded and added, then the second — two separate adds,
/// bitwise-equal to two consecutive [`axpy_row`] calls (the property
/// the long-row bin variant relies on).
#[inline(always)]
pub(crate) fn axpy2_row_scalar(c: &mut [f64], b0: &[f64], v0: f64, b1: &[f64], v1: f64) {
    debug_assert_eq!(c.len(), b0.len());
    debug_assert_eq!(c.len(), b1.len());
    for i in 0..c.len() {
        c[i] += v0 * b0[i];
        c[i] += v1 * b1[i];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn axpy2_row_avx(c: &mut [f64], b0: &[f64], v0: f64, b1: &[f64], v1: f64) {
    use std::arch::x86_64::*;
    let n = c.len();
    let cp = c.as_mut_ptr();
    let (p0, p1) = (b0.as_ptr(), b1.as_ptr());
    let (w0, w1) = (_mm256_set1_pd(v0), _mm256_set1_pd(v1));
    let quads = n & !3;
    let mut i = 0;
    while i < quads {
        let mut acc = _mm256_loadu_pd(cp.add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(w0, _mm256_loadu_pd(p0.add(i))));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(w1, _mm256_loadu_pd(p1.add(i))));
        _mm256_storeu_pd(cp.add(i), acc);
        i += 4;
    }
    while i < n {
        *cp.add(i) += v0 * *p0.add(i);
        *cp.add(i) += v1 * *p1.add(i);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn axpy2_row_sse2(c: &mut [f64], b0: &[f64], v0: f64, b1: &[f64], v1: f64) {
    use std::arch::x86_64::*;
    let n = c.len();
    let cp = c.as_mut_ptr();
    let (p0, p1) = (b0.as_ptr(), b1.as_ptr());
    let (w0, w1) = (_mm_set1_pd(v0), _mm_set1_pd(v1));
    let pairs = n & !1;
    let mut i = 0;
    while i < pairs {
        let mut acc = _mm_loadu_pd(cp.add(i));
        acc = _mm_add_pd(acc, _mm_mul_pd(w0, _mm_loadu_pd(p0.add(i))));
        acc = _mm_add_pd(acc, _mm_mul_pd(w1, _mm_loadu_pd(p1.add(i))));
        _mm_storeu_pd(cp.add(i), acc);
        i += 2;
    }
    if i < n {
        *cp.add(i) += v0 * *p0.add(i);
        *cp.add(i) += v1 * *p1.add(i);
    }
}

/// Two-nonzero fused *loop* (never fused *arithmetic*): processes a
/// pair of nonzeros per pass over the row slice, halving the
/// load/store traffic on `c` for long rows while keeping the
/// per-element rounding sequence identical to two [`axpy_row`] calls.
#[inline(always)]
pub(crate) fn axpy2_row(c: &mut [f64], b0: &[f64], v0: f64, b1: &[f64], v1: f64) {
    debug_assert_eq!(c.len(), b0.len());
    debug_assert_eq!(c.len(), b1.len());
    #[cfg(target_arch = "x86_64")]
    match level() {
        SimdLevel::Avx => unsafe { axpy2_row_avx(c, b0, v0, b1, v1) },
        SimdLevel::Sse2 => unsafe { axpy2_row_sse2(c, b0, v0, b1, v1) },
        SimdLevel::Scalar => axpy2_row_scalar(c, b0, v0, b1, v1),
    }
    #[cfg(not(target_arch = "x86_64"))]
    axpy2_row_scalar(c, b0, v0, b1, v1);
}

// ---------------------------------------------------------------------------
// scale_row: out[i] = v * b[i]   (PB spill phase)
// ---------------------------------------------------------------------------

#[inline(always)]
pub(crate) fn scale_row_scalar(out: &mut [f64], b: &[f64], v: f64) {
    debug_assert_eq!(out.len(), b.len());
    for (o, &x) in out.iter_mut().zip(b) {
        *o = v * x;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn scale_row_sse2(out: &mut [f64], b: &[f64], v: f64) {
    use std::arch::x86_64::*;
    let n = out.len();
    let (op, bp) = (out.as_mut_ptr(), b.as_ptr());
    let vv = _mm_set1_pd(v);
    let pairs = n & !1;
    let mut i = 0;
    while i < pairs {
        _mm_storeu_pd(op.add(i), _mm_mul_pd(vv, _mm_loadu_pd(bp.add(i))));
        i += 2;
    }
    if i < n {
        *op.add(i) = v * *bp.add(i);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn scale_row_avx(out: &mut [f64], b: &[f64], v: f64) {
    use std::arch::x86_64::*;
    let n = out.len();
    let (op, bp) = (out.as_mut_ptr(), b.as_ptr());
    let vv = _mm256_set1_pd(v);
    let quads = n & !3;
    let mut i = 0;
    while i < quads {
        _mm256_storeu_pd(op.add(i), _mm256_mul_pd(vv, _mm256_loadu_pd(bp.add(i))));
        i += 4;
    }
    while i < n {
        *op.add(i) = v * *bp.add(i);
        i += 1;
    }
}

/// `out[i] = v * b[i]` — the PB spill write: the product is rounded
/// *here* and the add happens later in [`add_row`], which is exactly
/// the separately-rounded sequence the other kernels produce inline.
#[inline(always)]
pub(crate) fn scale_row(out: &mut [f64], b: &[f64], v: f64) {
    debug_assert_eq!(out.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    match level() {
        SimdLevel::Avx => unsafe { scale_row_avx(out, b, v) },
        SimdLevel::Sse2 => unsafe { scale_row_sse2(out, b, v) },
        SimdLevel::Scalar => scale_row_scalar(out, b, v),
    }
    #[cfg(not(target_arch = "x86_64"))]
    scale_row_scalar(out, b, v);
}

// ---------------------------------------------------------------------------
// add_row: c[i] += x[i]   (PB gather phase)
// ---------------------------------------------------------------------------

#[inline(always)]
pub(crate) fn add_row_scalar(c: &mut [f64], x: &[f64]) {
    debug_assert_eq!(c.len(), x.len());
    for (cc, &xx) in c.iter_mut().zip(x) {
        *cc += xx;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn add_row_sse2(c: &mut [f64], x: &[f64]) {
    use std::arch::x86_64::*;
    let n = c.len();
    let (cp, xp) = (c.as_mut_ptr(), x.as_ptr());
    let pairs = n & !1;
    let mut i = 0;
    while i < pairs {
        let acc = _mm_add_pd(_mm_loadu_pd(cp.add(i)), _mm_loadu_pd(xp.add(i)));
        _mm_storeu_pd(cp.add(i), acc);
        i += 2;
    }
    if i < n {
        *cp.add(i) += *xp.add(i);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn add_row_avx(c: &mut [f64], x: &[f64]) {
    use std::arch::x86_64::*;
    let n = c.len();
    let (cp, xp) = (c.as_mut_ptr(), x.as_ptr());
    let quads = n & !3;
    let mut i = 0;
    while i < quads {
        let acc = _mm256_add_pd(_mm256_loadu_pd(cp.add(i)), _mm256_loadu_pd(xp.add(i)));
        _mm256_storeu_pd(cp.add(i), acc);
        i += 4;
    }
    while i < n {
        *cp.add(i) += *xp.add(i);
        i += 1;
    }
}

/// `c[i] += x[i]` — the PB gather accumulate over spilled products.
#[inline(always)]
pub(crate) fn add_row(c: &mut [f64], x: &[f64]) {
    debug_assert_eq!(c.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    match level() {
        SimdLevel::Avx => unsafe { add_row_avx(c, x) },
        SimdLevel::Sse2 => unsafe { add_row_sse2(c, x) },
        SimdLevel::Scalar => add_row_scalar(c, x),
    }
    #[cfg(not(target_arch = "x86_64"))]
    add_row_scalar(c, x);
}

// ---------------------------------------------------------------------------
// RawRows: the shared disjoint-row aliasing shim
// ---------------------------------------------------------------------------

/// Raw-pointer view of a dense output's rows, `Send + Sync` so a
/// kernel can hand disjoint row ranges to the worker pool.
///
/// Safety contract (every kernel upholds it via its [`crate::spmm::Schedule`]):
/// concurrent callers must touch **disjoint** row sets — the schedule
/// partitions rows, so no two partitions alias.
#[derive(Clone, Copy)]
pub(crate) struct RawRows {
    ptr: *mut f64,
    ncols: usize,
}

unsafe impl Send for RawRows {}
unsafe impl Sync for RawRows {}

impl RawRows {
    pub(crate) fn new(c: &mut DenseMatrix) -> Self {
        RawRows { ptr: c.data.as_mut_ptr(), ncols: c.ncols }
    }

    /// Mutable view of row `r`. Caller guarantees `r` is in range and
    /// no concurrent caller touches the same row.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn row(&self, r: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(r * self.ncols), self.ncols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::Prng;
    use std::sync::Mutex;

    // force_scalar flips process-global dispatch state; tests that
    // toggle it serialise here so they never observe each other's legs
    static FORCE_LOCK: Mutex<()> = Mutex::new(());

    fn rand_vec(n: usize, rng: &mut Prng) -> Vec<f64> {
        (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect()
    }

    /// Satellite: the remainder path must round exactly like the main
    /// loop at every `d % lane_width` — pinned against a per-element
    /// reference and across forced-scalar vs dispatched legs.
    #[test]
    fn axpy_row_remainders() {
        let _g = FORCE_LOCK.lock().unwrap();
        let mut rng = Prng::new(0x51);
        for d in 0..20 {
            let b = rand_vec(d, &mut rng);
            let base = rand_vec(d, &mut rng);
            let v = 1.7f64;
            // per-element reference: one rounded mul, one rounded add
            let want: Vec<f64> = base.iter().zip(&b).map(|(c, x)| c + v * x).collect();

            let mut scalar = base.clone();
            axpy_row_scalar(&mut scalar, &b, v);
            assert_eq!(scalar, want, "scalar main+remainder ordering at d={d}");

            force_scalar(true);
            let mut forced = base.clone();
            axpy_row(&mut forced, &b, v);
            force_scalar(false);
            let mut auto = base.clone();
            axpy_row(&mut auto, &b, v);
            assert_eq!(forced, want, "forced-scalar dispatch at d={d}");
            assert_eq!(auto, want, "dispatched variant must match bitwise at d={d}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn simd_variants_bitwise_match_scalar() {
        let mut rng = Prng::new(0x52);
        for d in [1usize, 2, 3, 4, 5, 7, 8, 15, 16, 33, 100] {
            let b = rand_vec(d, &mut rng);
            let base = rand_vec(d, &mut rng);
            let v = rng.range_f64(-3.0, 3.0);
            let mut want = base.clone();
            axpy_row_scalar(&mut want, &b, v);
            let mut got = base.clone();
            unsafe { axpy_row_sse2(&mut got, &b, v) };
            assert_eq!(got, want, "sse2 axpy d={d}");
            if is_x86_feature_detected!("avx") {
                let mut got = base.clone();
                unsafe { axpy_row_avx(&mut got, &b, v) };
                assert_eq!(got, want, "avx axpy d={d}");
            }

            let mut sw = vec![0.0; d];
            scale_row_scalar(&mut sw, &b, v);
            let mut sg = vec![0.0; d];
            unsafe { scale_row_sse2(&mut sg, &b, v) };
            assert_eq!(sg, sw, "sse2 scale d={d}");
            if is_x86_feature_detected!("avx") {
                let mut sg = vec![0.0; d];
                unsafe { scale_row_avx(&mut sg, &b, v) };
                assert_eq!(sg, sw, "avx scale d={d}");
            }

            let mut aw = base.clone();
            add_row_scalar(&mut aw, &b);
            let mut ag = base.clone();
            unsafe { add_row_sse2(&mut ag, &b) };
            assert_eq!(ag, aw, "sse2 add d={d}");
            if is_x86_feature_detected!("avx") {
                let mut ag = base.clone();
                unsafe { add_row_avx(&mut ag, &b) };
                assert_eq!(ag, aw, "avx add d={d}");
            }
        }
    }

    #[test]
    fn axpy2_equals_two_axpy_bitwise() {
        let _g = FORCE_LOCK.lock().unwrap();
        let mut rng = Prng::new(0x53);
        for d in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 13, 32, 65] {
            let b0 = rand_vec(d, &mut rng);
            let b1 = rand_vec(d, &mut rng);
            let base = rand_vec(d, &mut rng);
            let (v0, v1) = (rng.range_f64(-2.0, 2.0), rng.range_f64(-2.0, 2.0));
            let mut want = base.clone();
            axpy_row(&mut want, &b0, v0);
            axpy_row(&mut want, &b1, v1);
            for forced in [true, false] {
                force_scalar(forced);
                let mut got = base.clone();
                axpy2_row(&mut got, &b0, v0, &b1, v1);
                assert_eq!(got, want, "axpy2 (forced={forced}) d={d}");
            }
            force_scalar(false);
        }
    }

    /// PB's spill/gather split (`out = v*x` then `c += out`) must
    /// reproduce the inline `c += v*x` sequence bit for bit — the
    /// foundation of the PB ≡ CSR bitwise pin.
    #[test]
    fn scale_then_add_matches_axpy_bitwise() {
        let _g = FORCE_LOCK.lock().unwrap();
        let mut rng = Prng::new(0x54);
        for d in [1usize, 3, 4, 7, 16, 31] {
            let b = rand_vec(d, &mut rng);
            let base = rand_vec(d, &mut rng);
            let v = rng.range_f64(-2.0, 2.0);
            let mut want = base.clone();
            axpy_row(&mut want, &b, v);
            for forced in [true, false] {
                force_scalar(forced);
                let mut spill = vec![0.0; d];
                scale_row(&mut spill, &b, v);
                let mut got = base.clone();
                add_row(&mut got, &spill);
                assert_eq!(got, want, "spill/gather (forced={forced}) d={d}");
            }
            force_scalar(false);
        }
    }

    #[test]
    fn dispatch_resolves_and_force_round_trips() {
        let _g = FORCE_LOCK.lock().unwrap();
        let auto = detected();
        #[cfg(target_arch = "x86_64")]
        assert!(auto == SimdLevel::Sse2 || auto == SimdLevel::Avx);
        force_scalar(true);
        assert_eq!(level(), SimdLevel::Scalar);
        force_scalar(false);
        assert_eq!(level(), auto);
        assert!(auto.lanes() >= 1);
        assert_eq!(SimdLevel::parse(auto.name()), Some(auto));
        assert_eq!(SimdLevel::parse("mmx"), None);
    }
}
