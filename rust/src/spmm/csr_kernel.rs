//! Textbook row-parallel CSR SpMM — the paper's "CSR" column.
//!
//! One pass over the rows; each nonzero `(r, c, v)` does
//! `C[r, :] += v * B[c, :]`. Rows are distributed over threads in
//! dynamically claimed chunks so skewed matrices stay balanced.

use crate::error::Result;
use crate::sparse::Csr;
use crate::spmm::pool::{default_chunk, parallel_chunks_dynamic};
use crate::spmm::{check_dims, DenseMatrix, Impl, Spmm};

/// `C[r,:] += v * B[c,:]` over a d-wide row. Manual 4-way unroll; LLVM
/// vectorises the remainder-free body with AVX2 on this target.
#[inline(always)]
pub(crate) fn axpy_row(c: &mut [f64], b: &[f64], v: f64) {
    let d = c.len();
    debug_assert_eq!(d, b.len());
    let mut k = 0;
    while k + 4 <= d {
        c[k] += v * b[k];
        c[k + 1] += v * b[k + 1];
        c[k + 2] += v * b[k + 2];
        c[k + 3] += v * b[k + 3];
        k += 4;
    }
    while k < d {
        c[k] += v * b[k];
        k += 1;
    }
}

/// Shared-pointer shim: lets scoped worker threads write *disjoint* row
/// ranges of `C` without locks. Soundness argument: every scheduling
/// primitive in [`crate::spmm::pool`] hands each index range to exactly
/// one worker, and kernels only write `C` rows inside their range.
#[derive(Clone, Copy)]
pub(crate) struct RawRows {
    ptr: *mut f64,
    ncols: usize,
}
unsafe impl Send for RawRows {}
unsafe impl Sync for RawRows {}

impl RawRows {
    pub(crate) fn new(c: &mut DenseMatrix) -> Self {
        RawRows { ptr: c.data.as_mut_ptr(), ncols: c.ncols }
    }
    /// Mutable view of row `r`. Caller must hold exclusive logical
    /// ownership of row `r`.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn row(&self, r: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(r * self.ncols), self.ncols)
    }
}

/// Row-parallel CSR SpMM kernel.
pub struct CsrSpmm {
    a: Csr,
    threads: usize,
}

impl CsrSpmm {
    /// Wrap a CSR matrix; `threads` worker threads at execute time.
    pub fn new(a: Csr, threads: usize) -> Self {
        CsrSpmm { a, threads: threads.max(1) }
    }

    /// Borrow the underlying matrix (used by the planner for stats).
    pub fn matrix(&self) -> &Csr {
        &self.a
    }
}

impl Spmm for CsrSpmm {
    fn id(&self) -> Impl {
        Impl::Csr
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        let chunk = default_chunk(a.nrows, self.threads);
        parallel_chunks_dynamic(a.nrows, self.threads, chunk, |range| {
            for r in range {
                // SAFETY: each row index is claimed by exactly one chunk.
                let crow = unsafe { rows.row(r) };
                crow.iter_mut().for_each(|x| *x = 0.0);
                for (ci, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
                    axpy_row(crow, b.row(*ci as usize), *v);
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference_various_d() {
        let mut rng = Prng::new(60);
        let a = erdos_renyi(300, 300, 7.0, &mut rng);
        for d in [1usize, 2, 3, 4, 7, 16, 64] {
            let b = DenseMatrix::random(300, d, &mut rng);
            let want = reference_spmm(&a, &b);
            for threads in [1usize, 3] {
                let k = CsrSpmm::new(a.clone(), threads);
                let mut c = DenseMatrix::zeros(300, d);
                k.execute(&b, &mut c).unwrap();
                assert!(c.max_abs_diff(&want) < 1e-12, "d={d} threads={threads}");
            }
        }
    }

    #[test]
    fn overwrites_stale_c() {
        let mut rng = Prng::new(61);
        let a = erdos_renyi(50, 50, 3.0, &mut rng);
        let b = DenseMatrix::random(50, 4, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsrSpmm::new(a, 2);
        let mut c = DenseMatrix::from_vec(50, 4, vec![42.0; 200]);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn dimension_errors() {
        let a = erdos_renyi(10, 10, 2.0, &mut Prng::new(62));
        let k = CsrSpmm::new(a, 1);
        let b = DenseMatrix::zeros(11, 4);
        let mut c = DenseMatrix::zeros(10, 4);
        assert!(k.execute(&b, &mut c).is_err());
        let b = DenseMatrix::zeros(10, 4);
        let mut c = DenseMatrix::zeros(10, 5);
        assert!(k.execute(&b, &mut c).is_err());
    }

    #[test]
    fn axpy_row_remainders() {
        for d in 0..9usize {
            let b: Vec<f64> = (0..d).map(|i| i as f64).collect();
            let mut c = vec![1.0; d];
            axpy_row(&mut c, &b, 2.0);
            for (i, &x) in c.iter().enumerate() {
                assert_eq!(x, 1.0 + 2.0 * i as f64);
            }
        }
    }
}
