//! Textbook row-parallel CSR SpMM — the paper's "CSR" column.
//!
//! One pass over the rows; each nonzero `(r, c, v)` does
//! `C[r, :] += v * B[c, :]` through the dispatched micro-kernels in
//! [`crate::spmm::simd`]. Execution consumes a precomputed
//! [`Schedule`]: partitions are nnz-balanced over `row_ptr` and claimed
//! dynamically, so skewed matrices stay balanced, and the dense
//! operands are processed in column tiles when the schedule carries
//! one.
//!
//! When the schedule also carries [`RowBins`] (the base schedule built
//! at construction always does), each partition's rows run in three
//! nnz classes — short rows fully unrolled, medium rows through the
//! plain per-nonzero loop, long rows two nonzeros per pass
//! ([`crate::spmm::simd::axpy2_row`]) — so the branch pattern matches
//! the row shape instead of one generic loop mispredicting on all of
//! them. Every variant keeps the same per-element rounded
//! multiply-then-add sequence, so binned, unbinned, scalar and SIMD
//! executions are all bitwise identical.

use std::ops::Range;

use crate::error::Result;
use crate::sparse::Csr;
use crate::spmm::schedule::{for_each_part, for_each_part_indexed, RowBins, Schedule};
use crate::spmm::simd::{axpy2_row, axpy_row, RawRows};
use crate::spmm::{check_dims, check_schedule, DenseMatrix, Impl, Spmm};

/// One row's nonzeros into its zeroed tile: generic per-nonzero loop
/// (the medium-bin and unbinned body).
#[inline(always)]
fn row_generic(ct: &mut [f64], b: &DenseMatrix, cols: &Range<usize>, cis: &[u32], vs: &[f64]) {
    for (ci, v) in cis.iter().zip(vs) {
        axpy_row(ct, &b.row(*ci as usize)[cols.clone()], *v);
    }
}

/// Long-bin body: two nonzeros per pass over the tile (halves the `C`
/// tile load/store traffic), odd tail through the single-step kernel.
/// Bitwise-equal to [`row_generic`] — `axpy2_row` rounds each nonzero's
/// contribution separately, in order.
#[inline(always)]
fn row_paired(ct: &mut [f64], b: &DenseMatrix, cols: &Range<usize>, cis: &[u32], vs: &[f64]) {
    let mut c2 = cis.chunks_exact(2);
    let mut v2 = vs.chunks_exact(2);
    for (cc, vv) in (&mut c2).zip(&mut v2) {
        axpy2_row(
            ct,
            &b.row(cc[0] as usize)[cols.clone()],
            vv[0],
            &b.row(cc[1] as usize)[cols.clone()],
            vv[1],
        );
    }
    for (ci, v) in c2.remainder().iter().zip(v2.remainder()) {
        axpy_row(ct, &b.row(*ci as usize)[cols.clone()], *v);
    }
}

/// Short-bin body: the nonzero count is branched on **once per row**
/// and each arm is straight-line. The `0` arm still exists because an
/// empty row must keep its (already zeroed) tile. Falls back to the
/// paired loop if a row longer than [`crate::spmm::schedule::SHORT_ROW_NNZ`]
/// ever lands here — correct for any length, so a foreign bins table
/// cannot corrupt results.
#[inline(always)]
fn row_short(ct: &mut [f64], b: &DenseMatrix, cols: &Range<usize>, cis: &[u32], vs: &[f64]) {
    let bt = |i: usize| &b.row(cis[i] as usize)[cols.clone()];
    match cis.len() {
        0 => {}
        1 => axpy_row(ct, bt(0), vs[0]),
        2 => axpy2_row(ct, bt(0), vs[0], bt(1), vs[1]),
        3 => {
            axpy2_row(ct, bt(0), vs[0], bt(1), vs[1]);
            axpy_row(ct, bt(2), vs[2]);
        }
        4 => {
            axpy2_row(ct, bt(0), vs[0], bt(1), vs[1]);
            axpy2_row(ct, bt(2), vs[2], bt(3), vs[3]);
        }
        _ => row_paired(ct, b, cols, cis, vs),
    }
}

/// Row-parallel CSR SpMM kernel.
pub struct CsrSpmm {
    a: Csr,
    /// Untiled nnz-balanced base schedule with row bins, precomputed at
    /// construction (carries the thread count).
    base: Schedule,
}

impl CsrSpmm {
    /// Wrap a CSR matrix; `threads` worker threads at execute time.
    pub fn new(a: Csr, threads: usize) -> Self {
        let base =
            Schedule::nnz_balanced(&a.row_ptr, threads.max(1)).with_row_bins(&a.row_ptr);
        CsrSpmm { a, base }
    }

    /// Borrow the underlying matrix (used by the planner for stats).
    pub fn matrix(&self) -> &Csr {
        &self.a
    }

    /// The binned execute body for one (partition × column tile) cell.
    #[inline(always)]
    fn run_binned(
        &self,
        bins: &RowBins,
        pi: usize,
        cols: &Range<usize>,
        b: &DenseMatrix,
        rows: &RawRows,
    ) {
        let a = &self.a;
        let (short, medium, long) = bins.part(pi);
        for &r in short {
            let r = r as usize;
            // SAFETY: each (row, tile) cell is claimed exactly once.
            let ct = unsafe { &mut rows.row(r)[cols.clone()] };
            ct.fill(0.0);
            row_short(ct, b, cols, a.row_cols(r), a.row_vals(r));
        }
        for &r in medium {
            let r = r as usize;
            let ct = unsafe { &mut rows.row(r)[cols.clone()] };
            ct.fill(0.0);
            row_generic(ct, b, cols, a.row_cols(r), a.row_vals(r));
        }
        for &r in long {
            let r = r as usize;
            let ct = unsafe { &mut rows.row(r)[cols.clone()] };
            ct.fill(0.0);
            row_paired(ct, b, cols, a.row_cols(r), a.row_vals(r));
        }
    }
}

impl Spmm for CsrSpmm {
    fn id(&self) -> Impl {
        Impl::Csr
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.base)
    }

    fn plan(&self, tile: Option<usize>) -> Schedule {
        self.base.clone().with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        check_schedule(self.a.nrows, s)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        // Only honour bins whose shape matches this schedule AND this
        // matrix (a hand-built schedule may carry neither or foreign
        // ones); otherwise run the row-ascending loop. Both paths are
        // bitwise identical — rows own their C slices independently.
        let bins = s
            .row_bins()
            .filter(|bb| bb.n_parts() == s.n_parts() && bb.n_rows() == a.nrows);
        match bins {
            Some(bins) => for_each_part_indexed(s, b.ncols, |pi, _units, cols| {
                self.run_binned(bins, pi, &cols, b, &rows);
            }),
            None => for_each_part(s, b.ncols, |range, cols| {
                for r in range {
                    // SAFETY: each (row, tile) cell is claimed exactly once.
                    let ct = unsafe { &mut rows.row(r)[cols.clone()] };
                    ct.fill(0.0);
                    row_generic(ct, b, &cols, a.row_cols(r), a.row_vals(r));
                }
            }),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference_various_d() {
        let mut rng = Prng::new(60);
        let a = erdos_renyi(300, 300, 7.0, &mut rng);
        for d in [1usize, 2, 3, 4, 7, 16, 64] {
            let b = DenseMatrix::random(300, d, &mut rng);
            let want = reference_spmm(&a, &b);
            for threads in [1usize, 3] {
                let k = CsrSpmm::new(a.clone(), threads);
                let mut c = DenseMatrix::zeros(300, d);
                k.execute(&b, &mut c).unwrap();
                assert!(c.max_abs_diff(&want) < 1e-12, "d={d} threads={threads}");
            }
        }
    }

    #[test]
    fn tiled_schedule_matches_reference() {
        let mut rng = Prng::new(63);
        let a = erdos_renyi(200, 200, 5.0, &mut rng);
        let d = 13;
        let b = DenseMatrix::random(200, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsrSpmm::new(a, 2);
        for dt in [1usize, 3, 4, 12, 13, 64] {
            let s = k.plan(Some(dt));
            let mut c = DenseMatrix::from_vec(200, d, vec![7.0; 200 * d]);
            k.execute_with(&b, &mut c, &s).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "dt={dt}");
        }
    }

    #[test]
    fn binned_and_unbinned_schedules_match_bitwise() {
        // the base schedule is binned; a hand-built nnz_balanced one is
        // not — both must produce the identical byte stream
        let mut rng = Prng::new(66);
        let a = erdos_renyi(150, 150, 6.0, &mut rng);
        let b = DenseMatrix::random(150, 9, &mut rng);
        let k = CsrSpmm::new(a.clone(), 3);
        assert!(k.plan(None).row_bins().is_some(), "base plan carries bins");
        let bare = Schedule::nnz_balanced(&a.row_ptr, 3).with_tile(Some(4));
        assert!(bare.row_bins().is_none());
        let mut c_binned = DenseMatrix::zeros(150, 9);
        k.execute_with(&b, &mut c_binned, &k.plan(Some(4))).unwrap();
        let mut c_bare = DenseMatrix::zeros(150, 9);
        k.execute_with(&b, &mut c_bare, &bare).unwrap();
        assert_eq!(c_binned.data, c_bare.data, "binned visit order must be bitwise-neutral");
    }

    #[test]
    fn adversarial_row_mixes_hit_every_bin() {
        // rows: one giant (row 0), alternating empty/singleton, a run of
        // medium rows — stresses all three bin classes in one matrix
        let n = 64usize;
        let mut coo = crate::sparse::Coo::new(n, n);
        let mut rng = Prng::new(67);
        for r in 0..n {
            let len = if r == 0 {
                n // giant row: every column
            } else if r < 32 {
                r % 2 // alternating empty / singleton
            } else {
                8 // medium
            };
            for j in 0..len {
                let c = if len == n { j } else { (r * 7 + j * 5) % n };
                coo.push(r, c, rng.range_f64(-1.0, 1.0));
            }
        }
        let a = Csr::from_coo(coo);
        assert_eq!(a.nnz(), n + 16 + 32 * 8, "generator rows must not collide");
        let b = DenseMatrix::random(n, 5, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsrSpmm::new(a.clone(), 4);
        let bins = k.plan(None);
        let bins = bins.row_bins().unwrap();
        let (mut ns, mut nm, mut nl) = (0, 0, 0);
        for p in 0..bins.n_parts() {
            let (s, m, l) = bins.part(p);
            ns += s.len();
            nm += m.len();
            nl += l.len();
        }
        assert!(ns > 0 && nm > 0 && nl > 0, "all classes populated: {ns}/{nm}/{nl}");
        let mut c = DenseMatrix::zeros(n, 5);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn overwrites_stale_c() {
        let mut rng = Prng::new(61);
        let a = erdos_renyi(50, 50, 3.0, &mut rng);
        let b = DenseMatrix::random(50, 4, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsrSpmm::new(a, 2);
        let mut c = DenseMatrix::from_vec(50, 4, vec![42.0; 200]);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn dimension_errors() {
        let a = erdos_renyi(10, 10, 2.0, &mut Prng::new(62));
        let k = CsrSpmm::new(a, 1);
        let b = DenseMatrix::zeros(11, 4);
        let mut c = DenseMatrix::zeros(10, 4);
        assert!(k.execute(&b, &mut c).is_err());
        let b = DenseMatrix::zeros(10, 4);
        let mut c = DenseMatrix::zeros(10, 5);
        assert!(k.execute(&b, &mut c).is_err());
    }

    #[test]
    fn mismatched_schedule_rejected() {
        let a = erdos_renyi(10, 10, 2.0, &mut Prng::new(64));
        let k = CsrSpmm::new(a, 1);
        let b = DenseMatrix::zeros(10, 4);
        let mut c = DenseMatrix::zeros(10, 4);
        let foreign = Schedule::uniform(11, 1);
        assert!(k.execute_with(&b, &mut c, &foreign).is_err());
    }
}
