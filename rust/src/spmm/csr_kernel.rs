//! Textbook row-parallel CSR SpMM — the paper's "CSR" column.
//!
//! One pass over the rows; each nonzero `(r, c, v)` does
//! `C[r, :] += v * B[c, :]`. Execution consumes a precomputed
//! [`Schedule`]: partitions are nnz-balanced over `row_ptr` and claimed
//! dynamically, so skewed matrices stay balanced, and the dense
//! operands are processed in column tiles when the schedule carries
//! one.

use crate::error::Result;
use crate::sparse::Csr;
use crate::spmm::schedule::{for_each_part, Schedule};
use crate::spmm::{check_dims, check_schedule, DenseMatrix, Impl, Spmm};

/// `C[r,:] += v * B[c,:]` over a d-wide row (or row tile). 4-wide
/// chunks with a scalar remainder; LLVM vectorises the chunked body
/// with AVX2 on this target.
#[inline(always)]
pub(crate) fn axpy_row(c: &mut [f64], b: &[f64], v: f64) {
    debug_assert_eq!(c.len(), b.len());
    let mut cq = c.chunks_exact_mut(4);
    let mut bq = b.chunks_exact(4);
    for (cc, bb) in (&mut cq).zip(&mut bq) {
        cc[0] += v * bb[0];
        cc[1] += v * bb[1];
        cc[2] += v * bb[2];
        cc[3] += v * bb[3];
    }
    for (cc, bb) in cq.into_remainder().iter_mut().zip(bq.remainder()) {
        *cc += v * bb;
    }
}

/// Shared-pointer shim: lets scoped worker threads write *disjoint*
/// regions of `C` without locks. Soundness argument: the schedule
/// executor ([`for_each_part`]) hands each (partition × column tile)
/// cell to exactly one worker, with a barrier between tiles, and
/// kernels only write `C` rows inside their partition (and, when
/// tiled, only the tile's column range).
#[derive(Clone, Copy)]
pub(crate) struct RawRows {
    ptr: *mut f64,
    ncols: usize,
}
unsafe impl Send for RawRows {}
unsafe impl Sync for RawRows {}

impl RawRows {
    pub(crate) fn new(c: &mut DenseMatrix) -> Self {
        RawRows { ptr: c.data.as_mut_ptr(), ncols: c.ncols }
    }
    /// Mutable view of row `r`. Caller must hold exclusive logical
    /// ownership of row `r` (or of the slice of it it writes).
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn row(&self, r: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(r * self.ncols), self.ncols)
    }
}

/// Row-parallel CSR SpMM kernel.
pub struct CsrSpmm {
    a: Csr,
    /// Untiled nnz-balanced base schedule, precomputed at construction
    /// (carries the thread count).
    base: Schedule,
}

impl CsrSpmm {
    /// Wrap a CSR matrix; `threads` worker threads at execute time.
    pub fn new(a: Csr, threads: usize) -> Self {
        let base = Schedule::nnz_balanced(&a.row_ptr, threads.max(1));
        CsrSpmm { a, base }
    }

    /// Borrow the underlying matrix (used by the planner for stats).
    pub fn matrix(&self) -> &Csr {
        &self.a
    }
}

impl Spmm for CsrSpmm {
    fn id(&self) -> Impl {
        Impl::Csr
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.base)
    }

    fn plan(&self, tile: Option<usize>) -> Schedule {
        self.base.clone().with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        check_schedule(self.a.nrows, s)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        for_each_part(s, b.ncols, |range, cols| {
            for r in range {
                // SAFETY: each (row, tile) cell is claimed exactly once.
                let crow = unsafe { rows.row(r) };
                let ct = &mut crow[cols.clone()];
                ct.fill(0.0);
                for (ci, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
                    axpy_row(ct, &b.row(*ci as usize)[cols.clone()], *v);
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference_various_d() {
        let mut rng = Prng::new(60);
        let a = erdos_renyi(300, 300, 7.0, &mut rng);
        for d in [1usize, 2, 3, 4, 7, 16, 64] {
            let b = DenseMatrix::random(300, d, &mut rng);
            let want = reference_spmm(&a, &b);
            for threads in [1usize, 3] {
                let k = CsrSpmm::new(a.clone(), threads);
                let mut c = DenseMatrix::zeros(300, d);
                k.execute(&b, &mut c).unwrap();
                assert!(c.max_abs_diff(&want) < 1e-12, "d={d} threads={threads}");
            }
        }
    }

    #[test]
    fn tiled_schedule_matches_reference() {
        let mut rng = Prng::new(63);
        let a = erdos_renyi(200, 200, 5.0, &mut rng);
        let d = 13;
        let b = DenseMatrix::random(200, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsrSpmm::new(a, 2);
        for dt in [1usize, 3, 4, 12, 13, 64] {
            let s = k.plan(Some(dt));
            let mut c = DenseMatrix::from_vec(200, d, vec![7.0; 200 * d]);
            k.execute_with(&b, &mut c, &s).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "dt={dt}");
        }
    }

    #[test]
    fn overwrites_stale_c() {
        let mut rng = Prng::new(61);
        let a = erdos_renyi(50, 50, 3.0, &mut rng);
        let b = DenseMatrix::random(50, 4, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsrSpmm::new(a, 2);
        let mut c = DenseMatrix::from_vec(50, 4, vec![42.0; 200]);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn dimension_errors() {
        let a = erdos_renyi(10, 10, 2.0, &mut Prng::new(62));
        let k = CsrSpmm::new(a, 1);
        let b = DenseMatrix::zeros(11, 4);
        let mut c = DenseMatrix::zeros(10, 4);
        assert!(k.execute(&b, &mut c).is_err());
        let b = DenseMatrix::zeros(10, 4);
        let mut c = DenseMatrix::zeros(10, 5);
        assert!(k.execute(&b, &mut c).is_err());
    }

    #[test]
    fn mismatched_schedule_rejected() {
        let a = erdos_renyi(10, 10, 2.0, &mut Prng::new(64));
        let k = CsrSpmm::new(a, 1);
        let b = DenseMatrix::zeros(10, 4);
        let mut c = DenseMatrix::zeros(10, 4);
        let foreign = Schedule::uniform(11, 1);
        assert!(k.execute_with(&b, &mut c, &foreign).is_err());
    }

    #[test]
    fn axpy_row_remainders() {
        for d in 0..9usize {
            let b: Vec<f64> = (0..d).map(|i| i as f64).collect();
            let mut c = vec![1.0; d];
            axpy_row(&mut c, &b, 2.0);
            for (i, &x) in c.iter().enumerate() {
                assert_eq!(x, 1.0 + 2.0 * i as f64);
            }
        }
    }
}
