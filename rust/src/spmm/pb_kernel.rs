//! Propagation-blocking SpMM — the sixth native implementation, after
//! Gu et al.'s propagation blocking (PAPERS.md, arXiv:2002.11302)
//! adapted from SpMV to the tall-and-skinny SpMM this crate serves.
//!
//! Every other native kernel streams `A` and *gathers* rows of `B` in
//! whatever order `A`'s column indices dictate — the random access the
//! sparsity-aware models charge for. PB eliminates the random access
//! entirely by trading it for extra **sequential** traffic, in two
//! phases per column tile of the dense operands:
//!
//! 1. **Spill** ([`PbSpmm`] phase A): the nonzeros, re-binned at
//!    construction into *column bands* of [`PbSpmm::col_band`]
//!    consecutive `A`-columns, are streamed band by band. Within one
//!    band every `B` access lands in an `8·col_band·dt`-byte panel
//!    that stays cache-resident, so the partial products
//!    `v·B[c, tile]` read `B` from DRAM exactly once overall. Each
//!    product is appended to the *bucket* (a `row_band`-row window of
//!    destination rows) owning its `C` row — sequential writes into a
//!    precomputed arena slot.
//! 2. **Gather** (phase B): each bucket's slots are streamed back in
//!    order and accumulated into `C`; the random writes are confined
//!    to the bucket's `8·row_band·dt`-byte window of `C`, which is
//!    cache-resident by construction.
//!
//! The traffic is therefore **structure-independent** — see
//! [`crate::model::bytes_pb`] for the byte model the planner compares
//! against the structure-sensitive CSR/CSB lines: PB wins exactly
//! where the structure models collapse to the random lower bound
//! (uniform/scale-free patterns, DRAM-resident `B`) and loses where
//! structure already makes `B` cache-resident (banded, blocked).
//!
//! Parallelism runs on the shared worker pool and consumes a
//! [`Schedule`] like every other kernel: the schedule's units are
//! rows (the same nnz-balanced `row_ptr` split CSR uses), its column
//! tiles bound the spill width, and phase B maps schedule partitions
//! onto buckets by *first-row ownership* — bucket `j` (rows
//! `[j·row_band, (j+1)·row_band)`) is processed by the one partition
//! containing row `j·row_band`, i.e. partition `[lo, hi)` owns buckets
//! `⌈lo/row_band⌉ ≤ j < ⌈hi/row_band⌉`. Both bounds round *up*: a
//! plain `hi / row_band` upper bound would hand a bucket straddling
//! the boundary to both neighbouring partitions and double-count its
//! contributions (regression-tested with a one-row-per-partition
//! schedule below).
//!
//! Accumulation order per `C` element is globally column-ascending
//! (bands partition the columns in ascending ranges and entries are
//! row-stable within a band), i.e. the exact floating-point sequence
//! of [`crate::spmm::CsrSpmm`] — the two kernels agree bit for bit,
//! which `tests/prop_pb.rs` pins across every generator.

use std::ops::Range;
use std::sync::Mutex;

use crate::error::Result;
use crate::sparse::Csr;
use crate::spmm::simd::{add_row, scale_row, RawRows};
use crate::spmm::pool::parallel_chunks_dynamic;
use crate::spmm::schedule::Schedule;
use crate::spmm::{check_dims, check_schedule, DenseMatrix, Impl, Spmm};

/// Default column-band width: the phase-A `B` panel is
/// `8 · 2048 · dt` bytes (1 MiB at `dt = 64`) — sized to stay inside
/// a conventional L2 slice.
pub const PB_DEFAULT_COL_BAND: usize = 2048;

/// Default bucket height: the phase-B `C` window is
/// `8 · 2048 · dt` bytes, the same L2 budget as the spill panel.
pub const PB_DEFAULT_ROW_BAND: usize = 2048;

/// Spill-arena budget. A full-width pass needs `8 · nnz · dt` bytes of
/// scratch; wider tiles are processed in internal sub-tiles of at most
/// [`pb_spill_tile`] columns so the arena stays bounded. Each extra
/// sub-pass re-streams only the binned structure (`20` bytes per
/// nonzero — see [`crate::model::bytes_pb_tiled`]).
pub const PB_MAX_SPILL_BYTES: usize = 1 << 26;

/// The widest spill tile the arena budget admits for a matrix with
/// `nnz` stored values at dense width `d` — the effective column-tile
/// width a PB execution runs with, whatever the schedule requests
/// wider. The planner charges PB's traffic at exactly this width
/// ([`crate::model::ai_pb_tiled`]), so predicted and executed pass
/// counts agree.
pub fn pb_spill_tile(nnz: usize, d: usize) -> usize {
    (PB_MAX_SPILL_BYTES / (8 * nnz.max(1))).clamp(1, d.max(1))
}

/// Column-band binning of a CSR matrix's entries: a counting sort by
/// `col / col_band`, row-stable within each band. This is the shared
/// phase-A machinery of [`PbSpmm`] and the propagation-blocking SpGEMM
/// merge kernel ([`crate::spgemm::PbMergeSpGemm`]):
/// `band_ptr[β]..band_ptr[β+1]` indexes band β's entries in
/// `col`/`val`/`src`, ordered by source row (and by column within a
/// row, since CSR rows are column-sorted).
pub(crate) struct ColBandBins {
    /// Entry range per column band.
    pub band_ptr: Vec<usize>,
    /// Absolute `A` column (= right-operand row) per binned entry.
    pub col: Vec<u32>,
    /// Value per binned entry.
    pub val: Vec<f64>,
    /// Source (`A`/`C`) row per binned entry.
    pub src: Vec<u32>,
}

/// Bin a CSR matrix's entries into column bands of `col_band`
/// consecutive columns (see [`ColBandBins`]). Structural work done
/// once at kernel construction, so execution never re-reads the CSR.
pub(crate) fn bin_col_bands(csr: &Csr, col_band: usize) -> ColBandBins {
    let col_band = col_band.max(1);
    let nnz = csr.nnz();
    let nb = csr.ncols.div_ceil(col_band);
    let mut band_ptr = vec![0usize; nb + 1];
    for &c in &csr.col_idx {
        band_ptr[c as usize / col_band + 1] += 1;
    }
    for i in 0..nb {
        band_ptr[i + 1] += band_ptr[i];
    }
    let mut cursor: Vec<usize> = band_ptr[..nb].to_vec();
    let mut col = vec![0u32; nnz];
    let mut val = vec![0.0f64; nnz];
    let mut src = vec![0u32; nnz];
    for r in 0..csr.nrows {
        for (&c, &v) in csr.row_cols(r).iter().zip(csr.row_vals(r)) {
            let b = c as usize / col_band;
            let k = cursor[b];
            cursor[b] += 1;
            col[k] = c;
            val[k] = v;
            src[k] = r as u32;
        }
    }
    ColBandBins { band_ptr, col, val, src }
}

/// Shared-pointer shim over the spill arena: phase-A workers write
/// *disjoint* slots without locks. Soundness: `PbSpmm::pos` assigns
/// every binned entry a unique arena slot, and each entry is processed
/// by exactly one worker (its column band is claimed exactly once).
#[derive(Clone, Copy)]
struct RawSlots {
    ptr: *mut f64,
    width: usize,
}
unsafe impl Send for RawSlots {}
unsafe impl Sync for RawSlots {}

impl RawSlots {
    /// Mutable view of slot `k`. Caller must hold exclusive logical
    /// ownership of the slot.
    #[inline(always)]
    #[allow(clippy::mut_from_ref)]
    unsafe fn slot(&self, k: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(k * self.width), self.width)
    }
}

/// Propagation-blocking SpMM kernel (see module docs).
pub struct PbSpmm {
    nrows: usize,
    ncols: usize,
    /// Column-band width (bins `A`'s columns / `B`'s rows).
    col_band: usize,
    /// Bucket height (bins `C`'s rows).
    row_band: usize,
    /// Binned entries, column-band-major and row-stable within a band:
    /// absolute `A` column (= `B` row) per entry.
    col: Vec<u32>,
    /// Value per binned entry.
    val: Vec<f64>,
    /// Arena slot per binned entry (phase A's scatter destination).
    pos: Vec<u32>,
    /// Entry range per column band (`band_ptr[β]..band_ptr[β+1]`).
    band_ptr: Vec<usize>,
    /// Destination `C` row per arena slot, in bucket-major order
    /// (phase B's stream).
    arena_row: Vec<u32>,
    /// Arena-slot range per bucket (`bucket_ptr[j]..bucket_ptr[j+1]`).
    bucket_ptr: Vec<usize>,
    /// Untiled nnz-balanced base schedule over rows (same split CSR
    /// uses).
    base: Schedule,
    /// Recycled spill arena (grow-only). A concurrent execute on the
    /// same kernel finds it taken and allocates its own.
    scratch: Mutex<Vec<f64>>,
}

impl PbSpmm {
    /// Bin a CSR matrix with the default band geometry, shrunk where
    /// the matrix is small: phase A's parallelism is band-granular and
    /// phase B's is bucket-granular, so both bins are capped at
    /// `⌈units/(8·threads)⌉` — ≈8 claimable bins per worker, the same
    /// granularity the schedule layer targets — and at the
    /// cache-sized [`PB_DEFAULT_COL_BAND`]/[`PB_DEFAULT_ROW_BAND`]
    /// otherwise. (A 2048-row matrix with one 2048-row bucket would
    /// run its entire gather phase on one worker.)
    pub fn from_csr(csr: &Csr, threads: usize) -> Self {
        let t = threads.max(1);
        let col_band = PB_DEFAULT_COL_BAND.min(csr.ncols.div_ceil(8 * t).max(1));
        let row_band = PB_DEFAULT_ROW_BAND.min(csr.nrows.div_ceil(8 * t).max(1));
        Self::from_csr_with_bands(csr, col_band, row_band, threads)
    }

    /// Bin with explicit band geometry (ablation / adversarial-test
    /// hook): `col_band` columns per spill bin, `row_band` rows per
    /// gather bucket.
    pub fn from_csr_with_bands(
        csr: &Csr,
        col_band: usize,
        row_band: usize,
        threads: usize,
    ) -> Self {
        let col_band = col_band.max(1);
        let row_band = row_band.max(1);
        let (nrows, ncols) = (csr.nrows, csr.ncols);
        let nnz = csr.nnz();
        assert!(nnz <= u32::MAX as usize, "PB arena slots are u32-indexed");
        let nb = ncols.div_ceil(col_band);
        let n_buckets = nrows.div_ceil(row_band);

        // 1) counting-sort entries by column band, row-stable — the
        //    spill stream (shared with the SpGEMM merge kernel)
        let ColBandBins { band_ptr, col, val, src } = bin_col_bands(csr, col_band);

        // 2) per-(bucket, band) segment sizes, laid out bucket-major so
        //    each bucket's slots are one contiguous arena run
        let mut seg = vec![0usize; n_buckets * nb + 1];
        for beta in 0..nb {
            for k in band_ptr[beta]..band_ptr[beta + 1] {
                seg[(src[k] as usize / row_band) * nb + beta + 1] += 1;
            }
        }
        for i in 0..n_buckets * nb {
            seg[i + 1] += seg[i];
        }

        // 3) arena slot per entry + destination row per slot. Within a
        //    (bucket, band) segment slots follow band order (row-major,
        //    columns ascending); across bands a row's contributions are
        //    column-ascending overall — the CSR accumulation order.
        let mut segcur: Vec<usize> = seg[..n_buckets * nb].to_vec();
        let mut pos = vec![0u32; nnz];
        let mut arena_row = vec![0u32; nnz];
        for beta in 0..nb {
            for k in band_ptr[beta]..band_ptr[beta + 1] {
                let cell = (src[k] as usize / row_band) * nb + beta;
                let s = segcur[cell];
                segcur[cell] += 1;
                pos[k] = s as u32;
                arena_row[s] = src[k];
            }
        }

        let bucket_ptr: Vec<usize> = (0..=n_buckets).map(|j| seg[j * nb]).collect();
        let base = Schedule::nnz_balanced(&csr.row_ptr, threads.max(1));
        PbSpmm {
            nrows,
            ncols,
            col_band,
            row_band,
            col,
            val,
            pos,
            band_ptr,
            arena_row,
            bucket_ptr,
            base,
            scratch: Mutex::new(Vec::new()),
        }
    }

    /// The column-band width entries were binned with.
    pub fn col_band(&self) -> usize {
        self.col_band
    }

    /// The bucket height (destination-row bin size).
    pub fn row_band(&self) -> usize {
        self.row_band
    }

    /// Phase A: stream the binned entries band by band, writing each
    /// partial product `val·B[col, sub]` into its precomputed arena
    /// slot. Bands are claimed dynamically; slot disjointness makes the
    /// raw writes sound.
    fn spill(&self, b: &DenseMatrix, sub: &Range<usize>, arena: &mut [f64], threads: usize) {
        let nb = self.band_ptr.len() - 1;
        if nb == 0 {
            return;
        }
        let slots = RawSlots { ptr: arena.as_mut_ptr(), width: sub.len() };
        parallel_chunks_dynamic(nb, threads, 1, |bands| {
            for beta in bands {
                for k in self.band_ptr[beta]..self.band_ptr[beta + 1] {
                    let brow = &b.row(self.col[k] as usize)[sub.clone()];
                    let v = self.val[k];
                    // SAFETY: pos maps entries to unique slots, and
                    // band β is claimed by exactly one worker.
                    let slot = unsafe { slots.slot(self.pos[k] as usize) };
                    // product rounded here, the add in gather: the same
                    // separately-rounded sequence CSR produces inline
                    scale_row(slot, brow, v);
                }
            }
        });
    }

    /// Phase B: each schedule partition accumulates the buckets it
    /// owns (first-row ownership — see module docs) from the arena
    /// into `C`, zeroing each bucket's `C` window first.
    fn gather(&self, rows: &RawRows, sub: &Range<usize>, arena: &[f64], s: &Schedule) {
        let w = sub.len();
        let rb = self.row_band;
        let n_buckets = self.bucket_ptr.len() - 1;
        parallel_chunks_dynamic(s.n_parts(), s.threads, 1, |parts| {
            for pi in parts {
                let part = s.part(pi);
                if part.is_empty() {
                    continue;
                }
                // both bounds round up: bucket j belongs to the
                // partition containing row j·rb, never to the one a
                // straddling boundary merely clips
                let j_lo = part.start.div_ceil(rb);
                let j_hi = part.end.div_ceil(rb).min(n_buckets);
                for j in j_lo..j_hi {
                    let r_hi = ((j + 1) * rb).min(self.nrows);
                    for r in j * rb..r_hi {
                        // SAFETY: bucket j has exactly one owner.
                        unsafe { rows.row(r) }[sub.clone()].fill(0.0);
                    }
                    for k in self.bucket_ptr[j]..self.bucket_ptr[j + 1] {
                        let slot = &arena[k * w..k * w + w];
                        // SAFETY: arena_row[k] is inside bucket j.
                        let crow = unsafe { rows.row(self.arena_row[k] as usize) };
                        add_row(&mut crow[sub.clone()], slot);
                    }
                }
            }
        });
    }
}

impl Spmm for PbSpmm {
    fn id(&self) -> Impl {
        Impl::Pb
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.col.len()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.base)
    }

    fn plan(&self, tile: Option<usize>) -> Schedule {
        self.base.clone().with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.nrows, self.ncols, b, c)?;
        check_schedule(self.nrows, s)?;
        let d = b.ncols;
        if d == 0 {
            return Ok(());
        }
        let nnz = self.col.len();
        let mut arena =
            std::mem::take(&mut *self.scratch.lock().unwrap_or_else(|e| e.into_inner()));
        let cap_w = pb_spill_tile(nnz, d);
        let rows = RawRows::new(c);
        for cols in s.col_tiles(d) {
            // internal sub-tiling keeps the arena under the scratch
            // budget; a sub-pass is a full spill+gather pair, so the
            // schedule's tile semantics (serial tiles, full barrier)
            // are preserved
            let mut p = cols.start;
            while p < cols.end {
                let sub = p..(p + cap_w).min(cols.end);
                let need = nnz * sub.len();
                if arena.len() < need {
                    arena.resize(need, 0.0);
                }
                self.spill(b, &sub, &mut arena, s.threads);
                self.gather(&rows, &sub, &arena, s);
                p = sub.end;
            }
        }
        *self.scratch.lock().unwrap_or_else(|e| e.into_inner()) = arena;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, chung_lu, erdos_renyi, ChungLuParams, Prng};
    use crate::spmm::{reference_spmm, CsrSpmm};

    #[test]
    fn matches_reference_various_d_and_threads() {
        let mut rng = Prng::new(90);
        let a = erdos_renyi(300, 300, 7.0, &mut rng);
        for d in [1usize, 2, 3, 4, 7, 16, 64] {
            let b = DenseMatrix::random(300, d, &mut rng);
            let want = reference_spmm(&a, &b);
            for threads in [1usize, 3] {
                let k = PbSpmm::from_csr(&a, threads);
                let mut c = DenseMatrix::zeros(300, d);
                k.execute(&b, &mut c).unwrap();
                assert!(c.max_abs_diff(&want) < 1e-12, "d={d} threads={threads}");
            }
        }
    }

    #[test]
    fn bitwise_identical_to_csr_kernel() {
        let mut rng = Prng::new(91);
        let a = erdos_renyi(200, 200, 6.0, &mut rng);
        let d = 9;
        let b = DenseMatrix::random(200, d, &mut rng);
        let csr = CsrSpmm::new(a.clone(), 2);
        let mut c_csr = DenseMatrix::zeros(200, d);
        csr.execute(&b, &mut c_csr).unwrap();
        // adversarially small bands: accumulation order must still be
        // globally column-ascending per row
        for (cb, rb) in [(2048usize, 2048usize), (7, 5), (1, 1)] {
            let pb = PbSpmm::from_csr_with_bands(&a, cb, rb, 3);
            let mut c_pb = DenseMatrix::zeros(200, d);
            pb.execute(&b, &mut c_pb).unwrap();
            assert_eq!(c_pb.data, c_csr.data, "cb={cb} rb={rb}");
        }
    }

    #[test]
    fn tiled_schedule_matches_reference() {
        let mut rng = Prng::new(92);
        let a = banded(150, 6, 0.4, &mut rng);
        let d = 13;
        let b = DenseMatrix::random(150, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = PbSpmm::from_csr_with_bands(&a, 16, 16, 2);
        for dt in [1usize, 3, 4, 12, 13, 64] {
            let s = k.plan(Some(dt));
            let mut c = DenseMatrix::from_vec(150, d, vec![7.0; 150 * d]);
            k.execute_with(&b, &mut c, &s).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "dt={dt}");
        }
    }

    #[test]
    fn one_row_per_partition_schedule_does_not_double_count() {
        // Regression: bucket ownership under a schedule whose partition
        // boundaries split every bucket. With 1-row partitions and
        // 3-row buckets, a `hi / rb` upper bound would assign bucket j
        // to several partitions and double-accumulate its entries.
        let mut rng = Prng::new(93);
        let a = erdos_renyi(16, 16, 4.0, &mut rng);
        let b = DenseMatrix::random(16, 5, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = PbSpmm::from_csr_with_bands(&a, 4, 3, 2);
        // uniform(16, 2) → min(2·8, 16) = 16 partitions of one row each
        let s = Schedule::uniform(16, 2);
        assert_eq!(s.n_parts(), 16);
        for i in 0..s.n_parts() {
            assert_eq!(s.part(i).len(), 1);
        }
        let mut c = DenseMatrix::from_vec(16, 5, vec![42.0; 80]);
        k.execute_with(&b, &mut c, &s).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
        // and with a column tile, so every (sub-pass × bucket) pair is
        // exercised under the adversarial partitioning too
        let st = Schedule::uniform(16, 2).with_tile(Some(2));
        let mut c2 = DenseMatrix::from_vec(16, 5, vec![-3.0; 80]);
        k.execute_with(&b, &mut c2, &st).unwrap();
        assert!(c2.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn rectangular_and_degenerate_shapes() {
        let mut rng = Prng::new(94);
        for (nr, nc) in [(1usize, 1usize), (1, 40), (40, 1), (30, 70), (70, 30)] {
            let a = erdos_renyi(nr, nc, 3.0, &mut rng);
            let b = DenseMatrix::random(nc, 3, &mut rng);
            let want = reference_spmm(&a, &b);
            let k = PbSpmm::from_csr_with_bands(&a, 8, 8, 2);
            let mut c = DenseMatrix::zeros(nr, 3);
            k.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "{nr}x{nc}");
        }
    }

    #[test]
    fn zero_matrix_overwrites_stale_c() {
        let a = Csr::from_dense(12, 12, &[0.0; 144]);
        let b = DenseMatrix::random(12, 4, &mut Prng::new(95));
        let k = PbSpmm::from_csr_with_bands(&a, 5, 5, 2);
        let mut c = DenseMatrix::from_vec(12, 4, vec![9.0; 48]);
        k.execute(&b, &mut c).unwrap();
        assert!(c.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scale_free_hubs_correct() {
        let mut rng = Prng::new(96);
        let a =
            chung_lu(ChungLuParams { n: 500, alpha: 2.2, avg_deg: 10.0, k_min: 2.0 }, &mut rng);
        let b = DenseMatrix::random(500, 16, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = PbSpmm::from_csr_with_bands(&a, 64, 64, 4);
        let mut c = DenseMatrix::zeros(500, 16);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn dimension_and_schedule_errors() {
        let a = erdos_renyi(10, 10, 2.0, &mut Prng::new(97));
        let k = PbSpmm::from_csr(&a, 1);
        let b = DenseMatrix::zeros(11, 4);
        let mut c = DenseMatrix::zeros(10, 4);
        assert!(k.execute(&b, &mut c).is_err());
        let b = DenseMatrix::zeros(10, 4);
        let mut c = DenseMatrix::zeros(10, 5);
        assert!(k.execute(&b, &mut c).is_err());
        let mut c = DenseMatrix::zeros(10, 4);
        let foreign = Schedule::uniform(11, 1);
        assert!(k.execute_with(&b, &mut c, &foreign).is_err());
    }

    #[test]
    fn spill_tile_caps_at_the_arena_budget() {
        // small matrices: the budget admits any width
        assert_eq!(pb_spill_tile(1000, 16), 16);
        assert_eq!(pb_spill_tile(0, 4), 4);
        // 4M nonzeros: 8·nnz bytes per column → 2 columns fit 64 MiB
        let nnz = 4 << 20;
        assert_eq!(pb_spill_tile(nnz, 64), PB_MAX_SPILL_BYTES / (8 * nnz));
        assert_eq!(pb_spill_tile(nnz, 64), 2);
        // never zero, never wider than d
        assert_eq!(pb_spill_tile(usize::MAX / 16, 8), 1);
        assert_eq!(pb_spill_tile(nnz, 1), 1);
    }

    #[test]
    fn scratch_arena_is_recycled() {
        let mut rng = Prng::new(98);
        let a = erdos_renyi(100, 100, 5.0, &mut rng);
        let b = DenseMatrix::random(100, 8, &mut rng);
        let k = PbSpmm::from_csr(&a, 2);
        let mut c = DenseMatrix::zeros(100, 8);
        k.execute(&b, &mut c).unwrap();
        let len_after_first = k.scratch.lock().unwrap().len();
        assert!(len_after_first >= k.nnz() * 8);
        let ptr = k.scratch.lock().unwrap().as_ptr();
        k.execute(&b, &mut c).unwrap();
        assert_eq!(k.scratch.lock().unwrap().as_ptr(), ptr, "arena must be reused");
    }
}
