//! CSB SpMM — block-row-parallel compressed-sparse-blocks kernel, the
//! paper's "CSB" column.
//!
//! Each worker claims whole block rows: every block in a block row
//! reads a `t`-row window of `B` (the cache tile the paper's blocked
//! model charges `z` accesses for) and accumulates into the same
//! `t`-row window of `C`, which stays hot in L2 across the whole block
//! row. No atomics: block rows own disjoint `C` windows. The schedule
//! partitions block rows by their nnz (a prefix sum over the block-row
//! structure), so a dense block row no longer weighs the same as an
//! empty one, and column tiles bound the dense working set per pass.

use crate::error::Result;
use crate::sparse::{Csb, Csr};
use crate::spmm::simd::{axpy_row, RawRows};
use crate::spmm::schedule::{for_each_part, Schedule};
use crate::spmm::{check_dims, check_schedule, DenseMatrix, Impl, Spmm};

/// Block-parallel CSB SpMM kernel.
pub struct CsbSpmm {
    a: Csb,
    base: Schedule,
}

/// nnz prefix sum over block rows — the balance weights for the
/// schedule.
fn block_row_nnz_prefix(a: &Csb) -> Vec<usize> {
    let mut prefix = vec![0usize; a.n_block_rows + 1];
    for br in 0..a.n_block_rows {
        let blk_nnz: usize = a.block_row(br).iter().map(|b| b.len()).sum();
        prefix[br + 1] = prefix[br] + blk_nnz;
    }
    prefix
}

impl CsbSpmm {
    /// Convert from CSR with the default block size heuristic.
    pub fn from_csr(csr: &Csr, threads: usize) -> Self {
        Self::new(Csb::from_csr(csr), threads)
    }

    /// Convert with an explicit block dimension (ablation hook).
    pub fn from_csr_with_block(csr: &Csr, block_dim: usize, threads: usize) -> Self {
        Self::new(Csb::from_csr_with_block(csr, block_dim), threads)
    }

    /// Wrap an existing CSB matrix.
    pub fn new(a: Csb, threads: usize) -> Self {
        let base = Schedule::nnz_balanced(&block_row_nnz_prefix(&a), threads.max(1));
        CsbSpmm { a, base }
    }

    /// The underlying CSB structure (planner / model hooks: `D`, `z`,
    /// block count).
    pub fn matrix(&self) -> &Csb {
        &self.a
    }
}

impl Spmm for CsbSpmm {
    fn id(&self) -> Impl {
        Impl::Csb
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.base)
    }

    fn plan(&self, tile: Option<usize>) -> Schedule {
        self.base.clone().with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        check_schedule(self.a.n_block_rows, s)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        let t = a.block_dim;
        // schedule units are block rows: a block row is already t rows
        // of C, and its tile slice is owned by exactly one cell
        for_each_part(s, b.ncols, |brange, cols| {
            let w = cols.len();
            let p = cols.start;
            for br in brange {
                let row_base = br * t;
                let row_end = ((br + 1) * t).min(a.nrows);
                // zero this block row's slice of C
                for r in row_base..row_end {
                    // SAFETY: block rows own disjoint C row windows,
                    // and tiles are barrier-separated.
                    unsafe { rows.row(r) }[cols.clone()].fill(0.0);
                }
                for blk in a.block_row(br) {
                    let col_base = blk.bcol as usize * t;
                    // Entries are (rel_row, rel_col)-sorted: process runs
                    // of equal rel_row with register accumulators (the
                    // same trick as OPT), monomorphised per small tile.
                    match w {
                        1 => block_kernel_const::<1>(a, blk, row_base, col_base, b, &rows, p),
                        2 => block_kernel_const::<2>(a, blk, row_base, col_base, b, &rows, p),
                        4 => block_kernel_const::<4>(a, blk, row_base, col_base, b, &rows, p),
                        8 => block_kernel_const::<8>(a, blk, row_base, col_base, b, &rows, p),
                        16 => block_kernel_const::<16>(a, blk, row_base, col_base, b, &rows, p),
                        _ => block_kernel_general(a, blk, row_base, col_base, b, &rows, &cols),
                    }
                }
            }
        });
        Ok(())
    }
}

/// Run-accumulating block kernel for a compile-time tile width `D`
/// starting at dense column `p`: C's row tile stays in `D` registers
/// across a run of same-row entries and is flushed once per run.
#[inline(always)]
fn block_kernel_const<const D: usize>(
    a: &Csb,
    blk: &crate::sparse::CsbBlock,
    row_base: usize,
    col_base: usize,
    b: &DenseMatrix,
    rows: &RawRows,
    p: usize,
) {
    let mut i = blk.start;
    while i < blk.end {
        let r = a.rel_row[i];
        let mut acc = [0.0f64; D];
        while i < blk.end && a.rel_row[i] == r {
            let v = a.vals[i];
            let brow = &b.row(col_base + a.rel_col[i] as usize)[p..p + D];
            for k in 0..D {
                acc[k] += v * brow[k];
            }
            i += 1;
        }
        // SAFETY: r is inside this block row's window.
        let crow = unsafe { rows.row(row_base + r as usize) };
        for k in 0..D {
            crow[p + k] += acc[k];
        }
    }
}

/// General-width fallback: same run detection, panel accumulators over
/// the tile's column range.
#[inline(always)]
fn block_kernel_general(
    a: &Csb,
    blk: &crate::sparse::CsbBlock,
    row_base: usize,
    col_base: usize,
    b: &DenseMatrix,
    rows: &RawRows,
    cols: &std::ops::Range<usize>,
) {
    const PANEL: usize = 16;
    let mut i = blk.start;
    while i < blk.end {
        let r = a.rel_row[i];
        let run_start = i;
        while i < blk.end && a.rel_row[i] == r {
            i += 1;
        }
        // SAFETY: r is inside this block row's window.
        let crow = unsafe { rows.row(row_base + r as usize) };
        let mut p = cols.start;
        while p < cols.end {
            let w = PANEL.min(cols.end - p);
            if w == PANEL {
                let mut acc = [0.0f64; PANEL];
                for j in run_start..i {
                    let v = a.vals[j];
                    let brow = &b.row(col_base + a.rel_col[j] as usize)[p..p + PANEL];
                    for k in 0..PANEL {
                        acc[k] += v * brow[k];
                    }
                }
                for k in 0..PANEL {
                    crow[p + k] += acc[k];
                }
            } else {
                for j in run_start..i {
                    let v = a.vals[j];
                    axpy_row(
                        &mut crow[p..p + w],
                        &b.row(col_base + a.rel_col[j] as usize)[p..p + w],
                        v,
                    );
                }
            }
            p += w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, erdos_renyi, mesh2d, ChungLuParams, MeshKind, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference_over_block_sizes() {
        let mut rng = Prng::new(80);
        let a = erdos_renyi(400, 400, 6.0, &mut rng);
        let b = DenseMatrix::random(400, 8, &mut rng);
        let want = reference_spmm(&a, &b);
        for t in [16usize, 64, 128, 1024] {
            let k = CsbSpmm::from_csr_with_block(&a, t, 3);
            let mut c = DenseMatrix::zeros(400, 8);
            k.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "t={t}");
        }
    }

    #[test]
    fn blocked_matrix_all_d() {
        let mut rng = Prng::new(81);
        let a = mesh2d(24, MeshKind::Triangular, 0.8, &mut rng);
        for d in [1usize, 4, 16, 64] {
            let b = DenseMatrix::random(a.ncols, d, &mut rng);
            let want = reference_spmm(&a, &b);
            let k = CsbSpmm::from_csr(&a, 2);
            let mut c = DenseMatrix::zeros(a.nrows, d);
            k.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "d={d}");
        }
    }

    #[test]
    fn tiled_schedule_matches_reference() {
        let mut rng = Prng::new(84);
        let a = mesh2d(20, MeshKind::Triangular, 0.9, &mut rng);
        let d = 33;
        let b = DenseMatrix::random(a.ncols, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsbSpmm::from_csr_with_block(&a, 64, 3);
        for dt in [1usize, 2, 5, 8, 16, 32, 33] {
            let s = k.plan(Some(dt));
            let mut c = DenseMatrix::from_vec(a.nrows, d, vec![11.0; a.nrows * d]);
            k.execute_with(&b, &mut c, &s).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "dt={dt}");
        }
    }

    #[test]
    fn scale_free_hubs_correct() {
        let mut rng = Prng::new(82);
        let a = chung_lu(ChungLuParams { n: 600, alpha: 2.2, avg_deg: 12.0, k_min: 2.0 }, &mut rng);
        let b = DenseMatrix::random(600, 16, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = CsbSpmm::from_csr_with_block(&a, 64, 4);
        let mut c = DenseMatrix::zeros(600, 16);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn stale_c_overwritten() {
        let a = Csr::from_dense(4, 4, &[0.0; 16]);
        let b = DenseMatrix::random(4, 2, &mut Prng::new(83));
        let k = CsbSpmm::from_csr_with_block(&a, 2, 1);
        let mut c = DenseMatrix::from_vec(4, 2, vec![5.0; 8]);
        k.execute(&b, &mut c).unwrap();
        assert!(c.data.iter().all(|&x| x == 0.0));
    }
}
