//! The schedule layer: plan/execute split for every native SpMM
//! kernel.
//!
//! The paper's core claim is that blocking and data layout change the
//! *effective* arithmetic intensity of SpMM — so execution, not just
//! analysis, must be structure-aware. Before this layer existed every
//! kernel re-derived a uniform row chunking per call
//! (`pool::default_chunk`) and streamed the full `n × d` dense `B`,
//! falling off the cache cliff the cache-aware model predicts at large
//! `d`. A [`Schedule`] precomputes the two decisions that matter:
//!
//! * **Row partitions balanced by nnz** — a prefix-sum split over
//!   `row_ptr` (or the block-row equivalent), not row count, so one hub
//!   row of a scale-free matrix can no longer serialise a thread while
//!   its siblings finish early. Partitions are claimed dynamically, so
//!   the balance target is per-claim granularity, not per-thread
//!   totals.
//! * **Column tiles of `B`/`C`** — the dense operands are processed in
//!   `dt`-wide column panels so each panel's `B` working set
//!   (`8·n·dt` bytes) fits the calibrated cache level. `dt` is chosen
//!   by the planner from the tile-aware AI model
//!   ([`crate::model::SparsityModel::ai_tiled`]); `dt = d` (untiled)
//!   reproduces the pre-schedule behaviour exactly.
//!
//! Kernels *consume* a `&Schedule` ([`crate::spmm::Spmm::execute_with`])
//! instead of chunking ad hoc; `Spmm::execute` runs over a base
//! schedule precomputed at kernel construction (untiled, nnz-balanced),
//! and the coordinator caches tiled schedules per
//! `(matrix, impl, threads, d, dt)` so repeated and batched submissions pay
//! planning cost once (see `coordinator/registry.rs`).
//!
//! A schedule can additionally carry **nnz-length row bins**
//! ([`RowBins`], attached via [`Schedule::with_row_bins`]): each
//! partition's rows split into short (≤ [`SHORT_ROW_NNZ`]), medium,
//! and long (> [`LONG_ROW_NNZ`]) classes, so a row-parallel kernel can
//! run a width-matched micro-kernel variant per class instead of one
//! generic loop — the customized-storage idea of Shi et al.
//! (arXiv:2005.14469) adapted to CPU scheduling. Rows within a
//! partition are independent (each owns its `C` row), so the binned
//! visit order is bitwise-identical to the row-ascending one.

use std::ops::Range;

use crate::spmm::pool::{parallel_chunks_dynamic, split_ranges};

/// Target partitions per thread: matches the ~8-chunks-per-thread
/// granularity `pool::default_chunk` used, but with nnz-balanced
/// boundaries instead of uniform row counts.
const PARTS_PER_THREAD: usize = 8;

/// Rows with at most this many nonzeros fall in the *short* bin: the
/// consuming kernel fully unrolls their nonzero loop (one branch per
/// row instead of one per nonzero).
pub const SHORT_ROW_NNZ: usize = 4;

/// Rows with more than this many nonzeros fall in the *long* bin:
/// worth the two-nonzero-per-pass micro-kernel that halves `C`
/// load/store traffic. Rows in between are *medium* and run the plain
/// per-nonzero loop.
pub const LONG_ROW_NNZ: usize = 32;

/// Per-partition nnz-length row classes (see module docs). Bin `i`
/// holds the rows of partition `i`, partitioned by stored row length;
/// every row of the partition appears in exactly one class (empty rows
/// are short — they still must zero their `C` row).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowBins {
    short: Vec<Vec<u32>>,
    medium: Vec<Vec<u32>>,
    long: Vec<Vec<u32>>,
}

impl RowBins {
    /// Bin every partition of `schedule` by the work prefix sum
    /// (`row_ptr` for CSR: `prefix.len() == units + 1`).
    pub fn from_prefix(schedule: &Schedule, prefix: &[usize]) -> RowBins {
        assert_eq!(
            prefix.len(),
            schedule.units() + 1,
            "row bins need one prefix entry per scheduled unit (+1)"
        );
        let n = schedule.n_parts();
        let mut bins = RowBins {
            short: vec![Vec::new(); n],
            medium: vec![Vec::new(); n],
            long: vec![Vec::new(); n],
        };
        for p in 0..n {
            for r in schedule.part(p) {
                let nnz = prefix[r + 1] - prefix[r];
                let class = if nnz <= SHORT_ROW_NNZ {
                    &mut bins.short[p]
                } else if nnz <= LONG_ROW_NNZ {
                    &mut bins.medium[p]
                } else {
                    &mut bins.long[p]
                };
                class.push(r as u32);
            }
        }
        bins
    }

    /// Number of partitions binned (equals the owning schedule's).
    pub fn n_parts(&self) -> usize {
        self.short.len()
    }

    /// The (short, medium, long) row ids of partition `p`.
    pub fn part(&self, p: usize) -> (&[u32], &[u32], &[u32]) {
        (&self.short[p], &self.medium[p], &self.long[p])
    }

    /// Total rows binned across all partitions and classes.
    pub fn n_rows(&self) -> usize {
        self.short.iter().chain(&self.medium).chain(&self.long).map(|v| v.len()).sum()
    }
}

/// A precomputed SpMM execution schedule: nnz-balanced partitions over
/// the kernel's parallel units (rows, or block rows for CSB/BSR) plus
/// an optional column-tile width for the dense operands.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    /// Partition boundaries: partition `i` covers units
    /// `parts[i]..parts[i+1]`. Always `parts[0] == 0` and
    /// `parts.last() == units`; empty partitions are legal (a hub unit
    /// heavier than the balance target leaves its neighbours empty) and
    /// are skipped at execute time.
    parts: Vec<usize>,
    /// Column-tile width `dt` for `B`/`C`; `None` executes the full
    /// dense width in one pass (the pre-schedule behaviour).
    pub tile: Option<usize>,
    /// Worker threads the schedule was planned for.
    pub threads: usize,
    /// Optional nnz-length row classes per partition (see [`RowBins`]);
    /// only meaningful for row-parallel kernels whose units are matrix
    /// rows, and ignored by kernels that don't opt in.
    row_bins: Option<RowBins>,
}

impl Schedule {
    /// Partition `[0, units)` by the work prefix sum `prefix`
    /// (`prefix.len() == units + 1`, monotone; `row_ptr` is exactly
    /// this shape): each partition receives ≈ `total / n_parts` work
    /// units of nnz. Falls back to a uniform split when the matrix has
    /// no stored work (`total == 0`).
    pub fn nnz_balanced(prefix: &[usize], threads: usize) -> Schedule {
        assert!(!prefix.is_empty(), "prefix must have len units+1");
        let units = prefix.len() - 1;
        let threads = threads.max(1);
        let total = prefix[units];
        if total == 0 {
            return Schedule::uniform(units, threads);
        }
        let n_parts = (threads * PARTS_PER_THREAD).min(units).max(1);
        let mut parts = Vec::with_capacity(n_parts + 1);
        parts.push(0usize);
        for k in 1..n_parts {
            // smallest boundary whose prefix reaches the k-th work
            // quantile, clamped monotone so coverage stays exact
            let target = ((total as u128 * k as u128) / n_parts as u128) as usize;
            let b = prefix.partition_point(|&x| x < target);
            let prev = *parts.last().unwrap();
            parts.push(b.clamp(prev, units));
        }
        parts.push(units);
        Schedule { parts, tile: None, threads, row_bins: None }
    }

    /// Uniform partition of `[0, units)` — the right "nnz balance" for
    /// formats whose per-unit work is constant by construction (padded
    /// ELL rows). Boundaries come from the pool's canonical near-equal
    /// split ([`split_ranges`]), so the two conventions cannot diverge.
    pub fn uniform(units: usize, threads: usize) -> Schedule {
        let threads = threads.max(1);
        let n_parts = (threads * PARTS_PER_THREAD).min(units).max(1);
        let mut parts = Vec::with_capacity(n_parts + 1);
        parts.push(0usize);
        // n_parts ≤ units, so every range is non-empty and contiguous
        for r in split_ranges(units, n_parts) {
            parts.push(r.end);
        }
        if parts.len() == 1 {
            parts.push(units); // units == 0: keep the [0, 0] shape
        }
        Schedule { parts, tile: None, threads, row_bins: None }
    }

    /// Attach (or clear) a column-tile width. Widths ≥ the dense width
    /// at execute time behave as untiled.
    pub fn with_tile(mut self, tile: Option<usize>) -> Schedule {
        self.tile = tile.filter(|&t| t > 0);
        self
    }

    /// Attach nnz-length row bins derived from the work prefix sum
    /// (`row_ptr` for CSR). Panics if `prefix.len() != units + 1`.
    pub fn with_row_bins(mut self, prefix: &[usize]) -> Schedule {
        self.row_bins = Some(RowBins::from_prefix(&self, prefix));
        self
    }

    /// The attached row bins, if any.
    pub fn row_bins(&self) -> Option<&RowBins> {
        self.row_bins.as_ref()
    }

    /// Number of partitions.
    pub fn n_parts(&self) -> usize {
        self.parts.len() - 1
    }

    /// Unit range of partition `i`.
    pub fn part(&self, i: usize) -> Range<usize> {
        self.parts[i]..self.parts[i + 1]
    }

    /// Total units covered (`nrows` for row kernels, `n_block_rows`
    /// for block kernels).
    pub fn units(&self) -> usize {
        *self.parts.last().unwrap()
    }

    /// Effective column-tile width at dense width `d`.
    pub fn tile_width(&self, d: usize) -> usize {
        self.tile.unwrap_or(d).clamp(1, d.max(1))
    }

    /// Number of column tiles at dense width `d`.
    pub fn n_tiles(&self, d: usize) -> usize {
        if d == 0 {
            0
        } else {
            d.div_ceil(self.tile_width(d))
        }
    }

    /// The column ranges the tiles cover at dense width `d`.
    pub fn col_tiles(&self, d: usize) -> Vec<Range<usize>> {
        let tw = self.tile_width(d);
        let mut out = Vec::with_capacity(self.n_tiles(d));
        let mut p = 0;
        while p < d {
            let end = (p + tw).min(d);
            out.push(p..end);
            p = end;
        }
        out
    }
}

/// Execute `f(unit_range, col_range)` over every (partition × column
/// tile) cell of the schedule at dense width `d`.
///
/// Tiles run serially with a full barrier between them (each tile is
/// one pool job); partitions within a tile are claimed dynamically by
/// up to `schedule.threads` workers. Consequently two concurrent `f`
/// calls always carry the *same* `col_range` and **disjoint**
/// `unit_range`s — the disjointness contract kernels rely on to write
/// `C` without synchronisation. Empty partitions are skipped.
pub fn for_each_part<F>(schedule: &Schedule, d: usize, f: F)
where
    F: Fn(Range<usize>, Range<usize>) + Sync,
{
    for_each_part_indexed(schedule, d, |_pi, units, cols| f(units, cols));
}

/// [`for_each_part`] with the partition index passed through, so a
/// kernel can look up per-partition side tables (the [`RowBins`]
/// classes) for the cell it was handed. Same disjointness contract.
pub fn for_each_part_indexed<F>(schedule: &Schedule, d: usize, f: F)
where
    F: Fn(usize, Range<usize>, Range<usize>) + Sync,
{
    let n_parts = schedule.n_parts();
    for cols in schedule.col_tiles(d) {
        parallel_chunks_dynamic(n_parts, schedule.threads, 1, |claimed| {
            for pi in claimed {
                let units = schedule.part(pi);
                if !units.is_empty() {
                    f(pi, units, cols.clone());
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_covers(s: &Schedule, units: usize) {
        assert_eq!(s.units(), units);
        let mut expect = 0;
        for i in 0..s.n_parts() {
            let r = s.part(i);
            assert_eq!(r.start, expect, "partitions must be contiguous");
            assert!(r.end >= r.start);
            expect = r.end;
        }
        assert_eq!(expect, units, "partitions must cover every unit");
    }

    #[test]
    fn uniform_covers_and_balances() {
        for units in [0usize, 1, 7, 100, 1001] {
            for threads in [1usize, 3, 8] {
                let s = Schedule::uniform(units, threads);
                assert_covers(&s, units);
                if units >= threads * PARTS_PER_THREAD {
                    let lens: Vec<usize> = (0..s.n_parts()).map(|i| s.part(i).len()).collect();
                    let (min, max) =
                        (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(max - min <= 1, "uniform split must be near-equal");
                }
            }
        }
    }

    #[test]
    fn nnz_balanced_even_prefix_matches_uniform() {
        // constant row length → boundaries land on the uniform split
        let prefix: Vec<usize> = (0..=64).map(|i| i * 5).collect();
        let s = Schedule::nnz_balanced(&prefix, 2);
        assert_covers(&s, 64);
        for i in 0..s.n_parts() {
            assert_eq!(s.part(i).len(), 4);
        }
    }

    #[test]
    fn nnz_balanced_hub_isolates_heavy_row() {
        // row 10 holds 90% of the nnz; it must sit alone in a partition
        let mut prefix = vec![0usize; 101];
        let mut acc = 0;
        for r in 0..100 {
            acc += if r == 10 { 900 } else { 1 };
            prefix[r + 1] = acc;
        }
        let s = Schedule::nnz_balanced(&prefix, 4);
        assert_covers(&s, 100);
        let nnz_of = |r: Range<usize>| prefix[r.end] - prefix[r.start];
        let hub_part = (0..s.n_parts()).find(|&i| s.part(i).contains(&10)).unwrap();
        // the hub's partition carries the hub and (at most) the light
        // rows before it — never a big share of the remaining mass
        assert!(s.part(hub_part).len() <= 11, "{:?}", s.part(hub_part));
        // every other partition stays near the per-claim balance target
        for i in 0..s.n_parts() {
            if i != hub_part {
                assert!(nnz_of(s.part(i)) <= 64, "part {i} overloaded");
            }
        }
        // the light mass is spread over several claimable partitions
        let nonempty = (0..s.n_parts()).filter(|&i| !s.part(i).is_empty()).count();
        assert!(nonempty >= 4, "light rows must stay claimable: {nonempty}");
    }

    #[test]
    fn nnz_balanced_zero_work_falls_back_to_uniform() {
        let prefix = vec![0usize; 33];
        let s = Schedule::nnz_balanced(&prefix, 2);
        assert_covers(&s, 32);
        assert_eq!(s, Schedule::uniform(32, 2));
    }

    #[test]
    fn tile_width_clamps() {
        let s = Schedule::uniform(10, 1).with_tile(Some(4));
        assert_eq!(s.tile_width(16), 4);
        assert_eq!(s.tile_width(3), 3); // wider-than-d tiles collapse
        assert_eq!(s.n_tiles(16), 4);
        assert_eq!(s.n_tiles(0), 0);
        let untiled = Schedule::uniform(10, 1);
        assert_eq!(untiled.tile_width(16), 16);
        assert_eq!(untiled.n_tiles(16), 1);
        // zero-width tile request behaves as untiled
        assert_eq!(Schedule::uniform(10, 1).with_tile(Some(0)).tile, None);
    }

    #[test]
    fn col_tiles_partition_the_width() {
        let s = Schedule::uniform(4, 1).with_tile(Some(5));
        let tiles = s.col_tiles(12);
        assert_eq!(tiles, vec![0..5, 5..10, 10..12]);
    }

    #[test]
    fn row_bins_cover_every_row_with_correct_classes() {
        // rows 0..48 with lengths cycling 0, 1, 4, 5, 32, 33: exercises
        // empty rows, both thresholds, and both off-by-one neighbours
        let lens = [0usize, 1, 4, 5, 32, 33];
        let units = 48;
        let mut prefix = vec![0usize; units + 1];
        for r in 0..units {
            prefix[r + 1] = prefix[r] + lens[r % lens.len()];
        }
        let s = Schedule::nnz_balanced(&prefix, 2).with_row_bins(&prefix);
        let bins = s.row_bins().expect("bins attached");
        assert_eq!(bins.n_parts(), s.n_parts());
        assert_eq!(bins.n_rows(), units, "every row binned exactly once");
        for p in 0..bins.n_parts() {
            let part = s.part(p);
            let (short, medium, long) = bins.part(p);
            for &r in short {
                assert!(part.contains(&(r as usize)));
                assert!(prefix[r as usize + 1] - prefix[r as usize] <= SHORT_ROW_NNZ);
            }
            for &r in medium {
                assert!(part.contains(&(r as usize)));
                let nnz = prefix[r as usize + 1] - prefix[r as usize];
                assert!(nnz > SHORT_ROW_NNZ && nnz <= LONG_ROW_NNZ);
            }
            for &r in long {
                assert!(part.contains(&(r as usize)));
                assert!(prefix[r as usize + 1] - prefix[r as usize] > LONG_ROW_NNZ);
            }
        }
    }

    #[test]
    fn row_bins_do_not_change_schedule_equality_semantics() {
        // the zero-work fallback test relies on bin-free schedules
        // comparing equal; binned vs bin-free must differ
        let prefix: Vec<usize> = (0..=16).collect();
        let bare = Schedule::nnz_balanced(&prefix, 2);
        assert_eq!(bare, Schedule::nnz_balanced(&prefix, 2));
        let binned = bare.clone().with_row_bins(&prefix);
        assert_ne!(bare, binned);
        assert_eq!(binned, Schedule::nnz_balanced(&prefix, 2).with_row_bins(&prefix));
    }

    #[test]
    #[should_panic(expected = "prefix entry")]
    fn row_bins_reject_mismatched_prefix() {
        let prefix: Vec<usize> = (0..=16).collect();
        let _ = Schedule::nnz_balanced(&prefix, 2).with_row_bins(&prefix[..10]);
    }

    #[test]
    fn for_each_part_indexed_passes_matching_partition() {
        let s = Schedule::uniform(40, 3).with_tile(Some(4));
        for_each_part_indexed(&s, 8, |pi, units, _cols| {
            assert_eq!(units, s.part(pi), "index must match the handed range");
        });
    }

    #[test]
    fn for_each_part_visits_every_cell_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let s = Schedule::uniform(50, 3).with_tile(Some(3));
        let d = 8;
        let hits: Vec<AtomicUsize> = (0..50 * d).map(|_| AtomicUsize::new(0)).collect();
        for_each_part(&s, d, |units, cols| {
            for u in units {
                for c in cols.clone() {
                    hits[u * d + c].fetch_add(1, Ordering::Relaxed);
                }
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
