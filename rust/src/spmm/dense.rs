//! Row-major dense matrix — `B` and `C` in every SpMM.
//!
//! Row-major is the layout the paper's traffic models assume: "a row of
//! B" (the d values a nonzero of A touches) is one contiguous cache-line
//! run.

use crate::gen::Prng;

/// Row-major `nrows × ncols` dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    pub nrows: usize,
    pub ncols: usize,
    pub data: Vec<f64>,
}

impl DenseMatrix {
    /// All-zero matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        DenseMatrix { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    /// Uniform-random matrix in `[-1, 1)`.
    pub fn random(nrows: usize, ncols: usize, rng: &mut Prng) -> Self {
        let data = (0..nrows * ncols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        DenseMatrix { nrows, ncols, data }
    }

    /// Build from an explicit row-major buffer.
    pub fn from_vec(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols);
        DenseMatrix { nrows, ncols, data }
    }

    /// Row `r` as a slice of length `ncols`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.ncols..(r + 1) * self.ncols]
    }

    /// Mutable row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.ncols..(r + 1) * self.ncols]
    }

    /// Element accessor (tests / reports).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.ncols + c]
    }

    /// Set one element.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.ncols + c] = v;
    }

    /// Zero the buffer in place (hot-loop friendly: keeps the
    /// allocation).
    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Max absolute elementwise difference against another matrix.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Relative max-abs error vs a reference (guards against zero
    /// reference with an absolute floor).
    pub fn rel_err(&self, reference: &DenseMatrix) -> f64 {
        let scale = reference.frob_norm().max(1e-30);
        self.max_abs_diff(reference) / scale * (reference.data.len() as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_contiguous() {
        let mut m = DenseMatrix::zeros(3, 4);
        m.set(1, 2, 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0, 0.0]);
        m.row_mut(2)[0] = 1.0;
        assert_eq!(m.get(2, 0), 1.0);
    }

    #[test]
    fn diff_and_norm() {
        let a = DenseMatrix::from_vec(1, 3, vec![3.0, 0.0, 4.0]);
        let b = DenseMatrix::from_vec(1, 3, vec![3.0, 1.0, 4.0]);
        assert_eq!(a.frob_norm(), 5.0);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn fill_zero_keeps_capacity() {
        let mut m = DenseMatrix::random(5, 5, &mut Prng::new(1));
        let ptr = m.data.as_ptr();
        m.fill_zero();
        assert_eq!(m.data.as_ptr(), ptr);
        assert!(m.data.iter().all(|&x| x == 0.0));
    }
}
