//! SpMM kernels: `C = A · B` with sparse `A (n×n)` and dense
//! tall-and-skinny `B (n×d)`.
//!
//! Six native implementations; the first three mirror the paper's
//! comparison set:
//!
//! | Kernel | Paper counterpart | Strategy |
//! |---|---|---|
//! | [`CsrSpmm`]  | "CSR" | textbook row-parallel CSR |
//! | [`OptSpmm`]  | "MKL" | register-blocked, d-specialised inner loops |
//! | [`CsbSpmm`]  | "CSB" | block-row-parallel compressed sparse blocks |
//! | [`EllSpmm`]  | —     | padded ELL (native twin of the XLA artifact) |
//! | [`BsrSpmm`]  | —     | dense-tile block sparse row (the matrix-unit mapping) |
//! | [`PbSpmm`]   | —     | propagation blocking: two-phase spill/gather, random access traded for sequential bucket traffic |
//!
//! All native kernels parallelise over the persistent, process-wide
//! worker pool ([`pool`]): threads are spawned once and parked between
//! calls, so the hot path pays no spawn/join churn (see `DESIGN.md`
//! §Execution-Model). Execution is plan/execute split: kernels consume
//! a precomputed [`Schedule`] (nnz-balanced partitions + model-chosen
//! column tiles, see [`schedule`]) instead of chunking ad hoc, and
//! every inner loop runs through the dispatched micro-kernels in
//! [`simd`] (scalar/SSE2/AVX, probed once, bitwise-identical across
//! variants).
//!
//! **Hand-off** (classify → predict → schedule → route → execute):
//! this module is the *execute* stage (and, via [`Spmm::plan`], the
//! mechanical half of *schedule*). Upstream, the coordinator
//! ([`crate::coordinator`]) has already classified the matrix,
//! predicted per-implementation performance from the traffic models
//! ([`crate::model`], derived in `MODELS.md`), chosen a kernel and a
//! tile width; what arrives here is a prepared kernel, a dense
//! operand pair, and a [`Schedule`] to run them over.
//!
//! One more implementation, `runtime::XlaSpmm`, executes the
//! AOT-compiled JAX/Pallas artifact through PJRT and plugs into the
//! same [`Spmm`] trait via the coordinator.

mod bsr_kernel;
mod csb_kernel;
mod csr_kernel;
mod dense;
mod ell_kernel;
mod opt_kernel;
mod pb_kernel;
pub mod pool;
pub mod schedule;
pub mod simd;

pub use bsr_kernel::BsrSpmm;
pub use csb_kernel::CsbSpmm;
pub use csr_kernel::CsrSpmm;
pub use dense::DenseMatrix;
pub use ell_kernel::EllSpmm;
pub use opt_kernel::OptSpmm;
pub use pb_kernel::{pb_spill_tile, PbSpmm, PB_DEFAULT_COL_BAND, PB_DEFAULT_ROW_BAND};
pub(crate) use pb_kernel::{bin_col_bands, ColBandBins};
pub use schedule::Schedule;

use crate::error::{Error, Result};
use crate::sparse::Csr;

/// Identifier for every SpMM implementation the engine can route to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Impl {
    Csr,
    Opt,
    Csb,
    Ell,
    Bsr,
    /// Propagation blocking ([`PbSpmm`]): the only kernel whose
    /// predicted traffic is structure-*independent*.
    Pb,
    Xla,
}

impl Impl {
    /// All native (always-available) implementations.
    pub const NATIVE: [Impl; 6] =
        [Impl::Csr, Impl::Opt, Impl::Csb, Impl::Ell, Impl::Bsr, Impl::Pb];

    /// Paper column name this implementation corresponds to.
    pub fn paper_name(&self) -> &'static str {
        match self {
            Impl::Csr => "CSR",
            Impl::Opt => "MKL", // our register-blocked stand-in
            Impl::Csb => "CSB",
            Impl::Ell => "ELL",
            Impl::Bsr => "BSR",
            Impl::Pb => "PB",
            Impl::Xla => "XLA",
        }
    }
}

impl std::fmt::Display for Impl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Impl::Csr => "CSR",
            Impl::Opt => "OPT",
            Impl::Csb => "CSB",
            Impl::Ell => "ELL",
            Impl::Bsr => "BSR",
            Impl::Pb => "PB",
            Impl::Xla => "XLA",
        };
        write!(f, "{s}")
    }
}

/// An SpMM kernel over a prepared (format-converted) matrix.
///
/// `prepare` is the one-time format conversion (outside the timed
/// region, as in the paper, which excludes loading and initialization);
/// `execute` is the hot path. Execution is split plan/execute: native
/// kernels precompute an nnz-balanced [`Schedule`] at construction and
/// consume a `&Schedule` at execute time ([`Spmm::execute_with`]);
/// `execute` runs over the kernel's own base (untiled) schedule. The
/// coordinator caches tiled schedules per `(matrix, impl, threads, d,
/// dt)` and calls `execute_with` directly.
pub trait Spmm: Send + Sync {
    /// Which implementation this is.
    fn id(&self) -> Impl;
    /// Rows of A (== rows of C).
    fn nrows(&self) -> usize;
    /// Cols of A (== rows of B).
    fn ncols(&self) -> usize;
    /// Stored nonzeros (FLOPs = 2·nnz·d).
    fn nnz(&self) -> usize;
    /// Compute `C = A·B`. `B.nrows == self.ncols`, `C` is overwritten.
    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()>;

    /// Build an execution schedule for this kernel with an optional
    /// forced column-tile width (`None` = untiled). Native kernels
    /// return their precomputed nnz-balanced partitions; the default
    /// (backends that manage their own execution, e.g. XLA) is a
    /// serial untiled row schedule.
    fn plan(&self, tile: Option<usize>) -> Schedule {
        Schedule::uniform(self.nrows(), 1).with_tile(tile)
    }

    /// Compute `C = A·B` over a precomputed schedule. The default
    /// ignores the schedule and defers to [`Spmm::execute`] (backends
    /// whose execution is opaque, e.g. XLA artifacts).
    fn execute_with(
        &self,
        b: &DenseMatrix,
        c: &mut DenseMatrix,
        _schedule: &Schedule,
    ) -> Result<()> {
        self.execute(b, c)
    }
}

/// Shared guard for schedule-consuming kernels: the schedule must
/// partition exactly this kernel's parallel units.
pub(crate) fn check_schedule(units: usize, s: &Schedule) -> Result<()> {
    if s.units() != units {
        return Err(Error::DimensionMismatch(format!(
            "schedule covers {} units but kernel has {units}",
            s.units()
        )));
    }
    Ok(())
}

/// Shape-check shared by all kernels.
pub(crate) fn check_dims(
    nrows: usize,
    ncols: usize,
    b: &DenseMatrix,
    c: &DenseMatrix,
) -> Result<()> {
    if b.nrows != ncols {
        return Err(Error::DimensionMismatch(format!(
            "A is {nrows}x{ncols} but B has {} rows",
            b.nrows
        )));
    }
    if c.nrows != nrows || c.ncols != b.ncols {
        return Err(Error::DimensionMismatch(format!(
            "C is {}x{} but should be {nrows}x{}",
            c.nrows, c.ncols, b.ncols
        )));
    }
    Ok(())
}

/// Construct the requested native kernel from a CSR matrix with default
/// tuning. Returns a boxed trait object the coordinator can route to.
pub fn build_native(im: Impl, csr: &Csr, threads: usize) -> Result<Box<dyn Spmm>> {
    Ok(match im {
        Impl::Csr => Box::new(CsrSpmm::new(csr.clone(), threads)),
        Impl::Opt => Box::new(OptSpmm::new(csr.clone(), threads)),
        Impl::Csb => Box::new(CsbSpmm::from_csr(csr, threads)),
        Impl::Ell => Box::new(EllSpmm::from_csr(csr, threads)),
        // bs=4: good AVX fill/padding balance; ablations sweep it
        Impl::Bsr => Box::new(BsrSpmm::from_csr(csr, 4, threads)),
        Impl::Pb => Box::new(PbSpmm::from_csr(csr, threads)),
        Impl::Xla => {
            return Err(Error::Usage("XLA kernel is built through runtime::XlaSpmm".into()))
        }
    })
}

/// Reference (serial, obviously-correct) SpMM used as the oracle in
/// every kernel test: straightforward row-major CSR traversal.
pub fn reference_spmm(a: &Csr, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.ncols, b.nrows);
    let mut c = DenseMatrix::zeros(a.nrows, b.ncols);
    for r in 0..a.nrows {
        let crow = c.row_mut(r);
        for (ci, v) in a.row_cols(r).iter().zip(a.row_vals(r)) {
            let brow = b.row(*ci as usize);
            for k in 0..brow.len() {
                crow[k] += v * brow[k];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};

    #[test]
    fn reference_matches_dense_matmul() {
        let mut rng = Prng::new(50);
        let a = erdos_renyi(30, 30, 4.0, &mut rng);
        let b = DenseMatrix::random(30, 5, &mut rng);
        let c = reference_spmm(&a, &b);
        // dense check
        let ad = a.to_dense();
        for r in 0..30 {
            for k in 0..5 {
                let mut want = 0.0;
                for j in 0..30 {
                    want += ad[r * 30 + j] * b.get(j, k);
                }
                assert!((c.get(r, k) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn build_native_all() {
        let mut rng = Prng::new(51);
        let a = erdos_renyi(40, 40, 3.0, &mut rng);
        for im in Impl::NATIVE {
            let k = build_native(im, &a, 2).unwrap();
            assert_eq!(k.id(), im);
            assert_eq!(k.nrows(), 40);
        }
        assert!(build_native(Impl::Xla, &a, 1).is_err());
    }
}
