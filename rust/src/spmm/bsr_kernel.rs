//! BSR SpMM — dense-tile kernel: each nonzero `bs × bs` block does a
//! small dense `bs × bs · bs × d` multiply-accumulate.
//!
//! The regular tiles make the inner loops branch-free and fully
//! vectorisable (this is the CPU shadow of mapping CSB onto a matrix
//! unit — MXU/AMX — see DESIGN.md §Hardware-Adaptation and the Pallas
//! twin `bsr_spmm.py`). The cost is the padding FLOPs on zeros inside
//! tiles: throughput in *useful* GFLOP/s is `fill_ratio ×` the dense
//! rate, which the A1 ablation quantifies per structure. The schedule
//! balances block rows by stored-block count (the per-block work is
//! constant) and applies the dense column tiles as everywhere else.

use crate::error::Result;
use crate::sparse::{Bsr, Csr};
use crate::spmm::simd::RawRows;
use crate::spmm::schedule::{for_each_part, Schedule};
use crate::spmm::{check_dims, check_schedule, DenseMatrix, Impl, Spmm};

/// Block-row-parallel BSR SpMM kernel.
pub struct BsrSpmm {
    a: Bsr,
    base: Schedule,
}

impl BsrSpmm {
    /// Convert from CSR with tile edge `bs` (4 or 8 are the sweet
    /// spots on AVX-512).
    pub fn from_csr(csr: &Csr, bs: usize, threads: usize) -> Self {
        Self::new(Bsr::from_csr(csr, bs), threads)
    }

    /// Wrap an existing BSR matrix.
    pub fn new(a: Bsr, threads: usize) -> Self {
        // block_row_ptr is already the work prefix sum: every stored
        // block costs the same bs×bs×d multiply-accumulate
        let base = Schedule::nnz_balanced(&a.block_row_ptr, threads.max(1));
        BsrSpmm { a, base }
    }

    /// The underlying structure (fill statistics for reports).
    pub fn matrix(&self) -> &Bsr {
        &self.a
    }
}

impl Spmm for BsrSpmm {
    fn id(&self) -> Impl {
        Impl::Bsr
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.base)
    }

    fn plan(&self, tile: Option<usize>) -> Schedule {
        self.base.clone().with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        check_schedule(self.a.n_block_rows, s)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        let bs = a.block_size;
        for_each_part(s, b.ncols, |brange, cols| {
            for br in brange {
                let row_lo = br * bs;
                let row_hi = ((br + 1) * bs).min(a.nrows);
                for r in row_lo..row_hi {
                    // SAFETY: block rows own disjoint C windows, and
                    // tiles are barrier-separated.
                    unsafe { rows.row(r) }[cols.clone()].fill(0.0);
                }
                for k in a.block_row_ptr[br]..a.block_row_ptr[br + 1] {
                    let col_lo = a.block_col[k] as usize * bs;
                    let tile = a.block(k);
                    // dense (bs×bs)·(bs×dt): for each tile row, FMA over
                    // tile cols into the C row's column tile
                    for rr in 0..(row_hi - row_lo) {
                        // SAFETY: in this block row's window.
                        let crow = unsafe { rows.row(row_lo + rr) };
                        for cc in 0..bs {
                            let v = tile[rr * bs + cc];
                            if v == 0.0 {
                                continue; // skip padding FLOPs on very sparse tiles
                            }
                            let bcol = col_lo + cc;
                            if bcol >= a.ncols {
                                break;
                            }
                            let brow = b.row(bcol);
                            for x in cols.clone() {
                                crow[x] += v * brow[x];
                            }
                        }
                    }
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, mesh2d, MeshKind, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference() {
        let mut rng = Prng::new(220);
        let a = erdos_renyi(300, 300, 6.0, &mut rng);
        for bs in [2usize, 4, 8] {
            for d in [1usize, 4, 16] {
                let b = DenseMatrix::random(300, d, &mut rng);
                let want = reference_spmm(&a, &b);
                let k = BsrSpmm::from_csr(&a, bs, 2);
                let mut c = DenseMatrix::zeros(300, d);
                k.execute(&b, &mut c).unwrap();
                assert!(c.max_abs_diff(&want) < 1e-12, "bs={bs} d={d}");
            }
        }
    }

    #[test]
    fn tiled_schedule_matches_reference() {
        let mut rng = Prng::new(223);
        let a = mesh2d(16, MeshKind::Triangular, 0.9, &mut rng);
        let d = 18;
        let b = DenseMatrix::random(a.ncols, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = BsrSpmm::from_csr(&a, 4, 2);
        for dt in [1usize, 3, 8, 17, 18] {
            let s = k.plan(Some(dt));
            let mut c = DenseMatrix::from_vec(a.nrows, d, vec![-1.0; a.nrows * d]);
            k.execute_with(&b, &mut c, &s).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "dt={dt}");
        }
    }

    #[test]
    fn mesh_matrix_correct() {
        let mut rng = Prng::new(221);
        let a = mesh2d(20, MeshKind::Triangular, 0.9, &mut rng);
        let b = DenseMatrix::random(a.ncols, 8, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = BsrSpmm::from_csr(&a, 4, 3);
        let mut c = DenseMatrix::zeros(a.nrows, 8);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
        assert!(k.matrix().fill_ratio() > 0.1);
    }

    #[test]
    fn nonmultiple_dims() {
        // nrows/ncols not a multiple of bs
        let a = Csr::from_dense(5, 7, &{
            let mut d = vec![0.0; 35];
            d[0] = 1.0;
            d[6] = 2.0;
            d[34] = 3.0;
            d
        });
        let mut rng = Prng::new(222);
        let b = DenseMatrix::random(7, 3, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = BsrSpmm::from_csr(&a, 4, 1);
        let mut c = DenseMatrix::zeros(5, 3);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }
}
