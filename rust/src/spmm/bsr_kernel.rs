//! BSR SpMM — dense-tile kernel: each nonzero `bs × bs` block does a
//! small dense `bs × bs · bs × d` multiply-accumulate.
//!
//! The regular tiles make the inner loops branch-free and fully
//! vectorisable (this is the CPU shadow of mapping CSB onto a matrix
//! unit — MXU/AMX — see DESIGN.md §Hardware-Adaptation and the Pallas
//! twin `bsr_spmm.py`). The cost is the padding FLOPs on zeros inside
//! tiles: throughput in *useful* GFLOP/s is `fill_ratio ×` the dense
//! rate, which the A1 ablation quantifies per structure.

use crate::error::Result;
use crate::sparse::{Bsr, Csr};
use crate::spmm::csr_kernel::RawRows;
use crate::spmm::pool::parallel_chunks_dynamic;
use crate::spmm::{check_dims, DenseMatrix, Impl, Spmm};

/// Block-row-parallel BSR SpMM kernel.
pub struct BsrSpmm {
    a: Bsr,
    threads: usize,
}

impl BsrSpmm {
    /// Convert from CSR with tile edge `bs` (4 or 8 are the sweet
    /// spots on AVX-512).
    pub fn from_csr(csr: &Csr, bs: usize, threads: usize) -> Self {
        BsrSpmm { a: Bsr::from_csr(csr, bs), threads: threads.max(1) }
    }

    /// Wrap an existing BSR matrix.
    pub fn new(a: Bsr, threads: usize) -> Self {
        BsrSpmm { a, threads: threads.max(1) }
    }

    /// The underlying structure (fill statistics for reports).
    pub fn matrix(&self) -> &Bsr {
        &self.a
    }
}

impl Spmm for BsrSpmm {
    fn id(&self) -> Impl {
        Impl::Bsr
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        let bs = a.block_size;
        let d = b.ncols;
        parallel_chunks_dynamic(a.n_block_rows, self.threads, 1, |brange| {
            for br in brange {
                let row_lo = br * bs;
                let row_hi = ((br + 1) * bs).min(a.nrows);
                for r in row_lo..row_hi {
                    // SAFETY: block rows own disjoint C windows.
                    unsafe { rows.row(r) }.iter_mut().for_each(|x| *x = 0.0);
                }
                for k in a.block_row_ptr[br]..a.block_row_ptr[br + 1] {
                    let col_lo = a.block_col[k] as usize * bs;
                    let tile = a.block(k);
                    // dense (bs×bs)·(bs×d): for each tile row, FMA over
                    // tile cols into the C row
                    for rr in 0..(row_hi - row_lo) {
                        // SAFETY: in this block row's window.
                        let crow = unsafe { rows.row(row_lo + rr) };
                        for cc in 0..bs {
                            let v = tile[rr * bs + cc];
                            if v == 0.0 {
                                continue; // skip padding FLOPs on very sparse tiles
                            }
                            let bcol = col_lo + cc;
                            if bcol >= a.ncols {
                                break;
                            }
                            let brow = b.row(bcol);
                            for x in 0..d {
                                crow[x] += v * brow[x];
                            }
                        }
                    }
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, mesh2d, MeshKind, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference() {
        let mut rng = Prng::new(220);
        let a = erdos_renyi(300, 300, 6.0, &mut rng);
        for bs in [2usize, 4, 8] {
            for d in [1usize, 4, 16] {
                let b = DenseMatrix::random(300, d, &mut rng);
                let want = reference_spmm(&a, &b);
                let k = BsrSpmm::from_csr(&a, bs, 2);
                let mut c = DenseMatrix::zeros(300, d);
                k.execute(&b, &mut c).unwrap();
                assert!(c.max_abs_diff(&want) < 1e-12, "bs={bs} d={d}");
            }
        }
    }

    #[test]
    fn mesh_matrix_correct() {
        let mut rng = Prng::new(221);
        let a = mesh2d(20, MeshKind::Triangular, 0.9, &mut rng);
        let b = DenseMatrix::random(a.ncols, 8, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = BsrSpmm::from_csr(&a, 4, 3);
        let mut c = DenseMatrix::zeros(a.nrows, 8);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
        assert!(k.matrix().fill_ratio() > 0.1);
    }

    #[test]
    fn nonmultiple_dims() {
        // nrows/ncols not a multiple of bs
        let a = Csr::from_dense(5, 7, &{
            let mut d = vec![0.0; 35];
            d[0] = 1.0;
            d[6] = 2.0;
            d[34] = 3.0;
            d
        });
        let mut rng = Prng::new(222);
        let b = DenseMatrix::random(7, 3, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = BsrSpmm::from_csr(&a, 4, 1);
        let mut c = DenseMatrix::zeros(5, 3);
        k.execute(&b, &mut c).unwrap();
        assert!(c.max_abs_diff(&want) < 1e-12);
    }
}
