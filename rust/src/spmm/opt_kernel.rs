//! "OPT" — the register-blocked, d-specialised CSR kernel standing in
//! for Intel MKL (DESIGN.md §2).
//!
//! MKL's edge over textbook CSR in the paper's Table V comes from
//! (a) keeping the C row in registers across a row's nonzeros instead
//! of streaming through memory, (b) specialised code paths per dense
//! width, and (c) 2-way nonzero unrolling to hide load latency. This
//! kernel implements the same three techniques:
//!
//! * tile width `∈ {1, 2, 4, 8}`: fixed-size register accumulator
//!   arrays, fully unrolled (monomorphised through `const D: usize`).
//! * larger widths: column panels of 16 with a register-resident
//!   accumulator tile per panel (A row values re-read from L1, B rows
//!   re-gathered per panel — the classic MKL/`mkl_sparse_d_mm` column
//!   blocking).
//!
//! Execution consumes a precomputed [`Schedule`]: the register kernels
//! are dispatched on the *tile* width, so a schedule whose tile is 4 or
//! 8 wide runs the fully unrolled path even at large `d`.

use crate::error::Result;
use crate::sparse::Csr;
use crate::spmm::simd::RawRows;
use crate::spmm::schedule::{for_each_part, Schedule};
use crate::spmm::{check_dims, check_schedule, DenseMatrix, Impl, Spmm};

/// Register-blocked CSR SpMM (the MKL stand-in).
pub struct OptSpmm {
    a: Csr,
    base: Schedule,
}

impl OptSpmm {
    /// Wrap a CSR matrix.
    pub fn new(a: Csr, threads: usize) -> Self {
        let base = Schedule::nnz_balanced(&a.row_ptr, threads.max(1));
        OptSpmm { a, base }
    }
}

/// Fully unrolled row kernel for a compile-time width `D`: the
/// `D`-wide tile of the C row (starting at dense column `p`) lives in
/// `D` registers for the whole row.
#[inline(always)]
fn row_kernel_const<const D: usize>(
    a: &Csr,
    r: usize,
    b: &DenseMatrix,
    ct: &mut [f64],
    p: usize,
) {
    let mut acc = [0.0f64; D];
    let cols = a.row_cols(r);
    let vals = a.row_vals(r);
    let mut i = 0;
    // 2-way unroll over nonzeros to overlap the two B-row gathers
    while i + 2 <= cols.len() {
        let v0 = vals[i];
        let v1 = vals[i + 1];
        let b0 = &b.row(cols[i] as usize)[p..p + D];
        let b1 = &b.row(cols[i + 1] as usize)[p..p + D];
        for k in 0..D {
            acc[k] += v0 * b0[k] + v1 * b1[k];
        }
        i += 2;
    }
    if i < cols.len() {
        let v = vals[i];
        let brow = &b.row(cols[i] as usize)[p..p + D];
        for k in 0..D {
            acc[k] += v * brow[k];
        }
    }
    ct[..D].copy_from_slice(&acc);
}

/// Panelled kernel for an arbitrary-width tile: process `PANEL`-wide
/// column panels with a register accumulator tile; A's row entries
/// replay from L1. `ct` is the tile of the C row starting at dense
/// column `p`.
#[inline(always)]
fn row_kernel_panel(a: &Csr, r: usize, b: &DenseMatrix, ct: &mut [f64], p: usize) {
    const PANEL: usize = 16;
    let w_total = ct.len();
    let cols = a.row_cols(r);
    let vals = a.row_vals(r);
    let mut q = 0;
    while q < w_total {
        let w = PANEL.min(w_total - q);
        let mut acc = [0.0f64; PANEL];
        for (ci, v) in cols.iter().zip(vals) {
            let brow = &b.row(*ci as usize)[p + q..p + q + w];
            if w == PANEL {
                for k in 0..PANEL {
                    acc[k] += v * brow[k];
                }
            } else {
                // ragged tail panel
                for (k, bv) in brow.iter().enumerate() {
                    acc[k] += v * bv;
                }
            }
        }
        ct[q..q + w].copy_from_slice(&acc[..w]);
        q += w;
    }
}

impl Spmm for OptSpmm {
    fn id(&self) -> Impl {
        Impl::Opt
    }
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn nnz(&self) -> usize {
        self.a.nnz()
    }

    fn execute(&self, b: &DenseMatrix, c: &mut DenseMatrix) -> Result<()> {
        self.execute_with(b, c, &self.base)
    }

    fn plan(&self, tile: Option<usize>) -> Schedule {
        self.base.clone().with_tile(tile)
    }

    fn execute_with(&self, b: &DenseMatrix, c: &mut DenseMatrix, s: &Schedule) -> Result<()> {
        check_dims(self.a.nrows, self.a.ncols, b, c)?;
        check_schedule(self.a.nrows, s)?;
        let rows = RawRows::new(c);
        let a = &self.a;
        for_each_part(s, b.ncols, |range, cols| {
            let w = cols.len();
            for r in range {
                // SAFETY: disjoint (row, tile) ownership per cell.
                let crow = unsafe { rows.row(r) };
                let ct = &mut crow[cols.clone()];
                match w {
                    1 => row_kernel_const::<1>(a, r, b, ct, cols.start),
                    2 => row_kernel_const::<2>(a, r, b, ct, cols.start),
                    4 => row_kernel_const::<4>(a, r, b, ct, cols.start),
                    8 => row_kernel_const::<8>(a, r, b, ct, cols.start),
                    _ => row_kernel_panel(a, r, b, ct, cols.start),
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, erdos_renyi, ChungLuParams, Prng};
    use crate::spmm::reference_spmm;

    #[test]
    fn matches_reference_all_widths() {
        let mut rng = Prng::new(70);
        let a = erdos_renyi(257, 257, 6.0, &mut rng);
        for d in [1usize, 2, 3, 4, 5, 8, 15, 16, 17, 33, 64] {
            let b = DenseMatrix::random(257, d, &mut rng);
            let want = reference_spmm(&a, &b);
            let k = OptSpmm::new(a.clone(), 2);
            let mut c = DenseMatrix::zeros(257, d);
            k.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "d={d}");
        }
    }

    #[test]
    fn tiled_register_paths_match_reference() {
        // tile widths hit every dispatch arm: const 1/2/4/8 and panel
        let mut rng = Prng::new(73);
        let a = erdos_renyi(150, 150, 6.0, &mut rng);
        let d = 21;
        let b = DenseMatrix::random(150, d, &mut rng);
        let want = reference_spmm(&a, &b);
        let k = OptSpmm::new(a, 2);
        for dt in [1usize, 2, 4, 8, 16, 20, 21] {
            let s = k.plan(Some(dt));
            let mut c = DenseMatrix::from_vec(150, d, vec![-3.0; 150 * d]);
            k.execute_with(&b, &mut c, &s).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12, "dt={dt}");
        }
    }

    #[test]
    fn skewed_matrix_balanced_correctly() {
        let mut rng = Prng::new(71);
        let a = chung_lu(ChungLuParams { n: 500, alpha: 2.1, avg_deg: 10.0, k_min: 2.0 }, &mut rng);
        let b = DenseMatrix::random(500, 16, &mut rng);
        let want = reference_spmm(&a, &b);
        for threads in [1usize, 4] {
            let k = OptSpmm::new(a.clone(), threads);
            let mut c = DenseMatrix::zeros(500, 16);
            k.execute(&b, &mut c).unwrap();
            assert!(c.max_abs_diff(&want) < 1e-12);
        }
    }

    #[test]
    fn empty_rows_zeroed() {
        // row 1 empty; stale C must still be overwritten
        let a = Csr::from_dense(3, 3, &[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 2.0, 0.0]);
        let b = DenseMatrix::random(3, 4, &mut Prng::new(72));
        let k = OptSpmm::new(a, 1);
        let mut c = DenseMatrix::from_vec(3, 4, vec![9.0; 12]);
        k.execute(&b, &mut c).unwrap();
        assert!(c.row(1).iter().all(|&x| x == 0.0));
    }
}
