//! The matrix registry: prepared kernels, classification, and cached
//! execution schedules per registered matrix.
//!
//! Preparation (format conversion, classification, artifact staging)
//! happens once at registration — mirroring the paper's methodology,
//! which excludes loading and data-structure construction from the
//! timed region. Execution *schedules* (nnz-balanced partitions +
//! column tiles, `spmm::Schedule`) are built lazily on first use and
//! cached per `(matrix, impl, threads, d)`, so repeated and batched
//! submissions pay planning cost once; hit/miss counters make the
//! reuse observable in batch reports.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::pattern::{classify, Classification};
use crate::runtime::{ArtifactManifest, XlaRuntime, XlaSpmm};
use crate::sparse::Csr;
use crate::spmm::{build_native, Impl, Schedule, Spmm};

/// One registered matrix with its prepared kernels.
pub struct MatrixEntry {
    pub name: String,
    pub classification: Classification,
    /// Prepared kernels by implementation. XLA kernels are per-d, so
    /// they key on (impl, d); native kernels use d = 0 (any width).
    kernels: HashMap<(Impl, usize), Box<dyn Spmm>>,
    /// The CSR source (kept for late kernel construction).
    csr: Csr,
    threads: usize,
}

impl MatrixEntry {
    /// Kernel lookup: native kernels serve any d; XLA kernels must
    /// match exactly.
    pub fn kernel(&self, im: Impl, d: usize) -> Option<&dyn Spmm> {
        let key = if im == Impl::Xla { (im, d) } else { (im, 0) };
        self.kernels.get(&key).map(|b| b.as_ref())
    }

    /// Which implementations can serve width `d` right now.
    pub fn available(&self, d: usize) -> Vec<Impl> {
        let mut v: Vec<Impl> = Vec::new();
        for &(im, kd) in self.kernels.keys() {
            if (im != Impl::Xla && kd == 0) || (im == Impl::Xla && kd == d) {
                if !v.contains(&im) {
                    v.push(im);
                }
            }
        }
        v.sort_by_key(|im| format!("{im}"));
        v
    }

    /// Rows of the matrix.
    pub fn n(&self) -> usize {
        self.csr.nrows
    }

    /// Nonzeros of the matrix.
    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }
}

/// Registry of prepared matrices.
pub struct MatrixRegistry {
    entries: HashMap<String, MatrixEntry>,
    threads: usize,
    /// Execution schedules keyed by `(matrix, impl, threads, d)`.
    /// Interior-mutable so lookups work through `&self` while kernels
    /// are borrowed.
    schedules: Mutex<HashMap<(String, Impl, usize, usize), Arc<Schedule>>>,
    sched_hits: AtomicUsize,
    sched_misses: AtomicUsize,
}

impl MatrixRegistry {
    pub fn new(threads: usize) -> MatrixRegistry {
        MatrixRegistry {
            entries: HashMap::new(),
            threads: threads.max(1),
            schedules: Mutex::new(HashMap::new()),
            sched_hits: AtomicUsize::new(0),
            sched_misses: AtomicUsize::new(0),
        }
    }

    /// Register a matrix: classify it and prepare the requested native
    /// kernels.
    pub fn register(&mut self, name: impl Into<String>, csr: Csr, impls: &[Impl]) -> Result<()> {
        let name = name.into();
        let classification = classify(&csr);
        let mut kernels: HashMap<(Impl, usize), Box<dyn Spmm>> = HashMap::new();
        for &im in impls {
            if im == Impl::Xla {
                continue; // staged separately via attach_xla
            }
            kernels.insert((im, 0), build_native(im, &csr, self.threads)?);
        }
        // re-registering a name invalidates its cached schedules
        self.schedules.lock().unwrap().retain(|k, _| k.0 != name);
        self.entries.insert(
            name.clone(),
            MatrixEntry { name, classification, kernels, csr, threads: self.threads },
        );
        Ok(())
    }

    /// The cached execution schedule for `(name, im, threads, d)`,
    /// building it (with column-tile width `dt`) on first use. `dt ≥ d`
    /// plans untiled. Returns `None` when the matrix or kernel is
    /// unknown. The cache key deliberately excludes `dt` — the
    /// planner's tile choice is a pure function of `(matrix, d)` — but
    /// a cached entry whose tile disagrees with the request (a caller
    /// violating that purity, or a planner whose ladder changed) is
    /// replanned and replaced rather than silently served stale.
    pub fn schedule(&self, name: &str, im: Impl, d: usize, dt: usize) -> Option<Arc<Schedule>> {
        let entry = self.entries.get(name)?;
        let kernel = entry.kernel(im, d)?;
        let tile = if dt >= d { None } else { Some(dt) };
        let key = (name.to_string(), im, self.threads, d);
        let mut map = self.schedules.lock().unwrap();
        if let Some(s) = map.get(&key) {
            if s.tile == tile {
                self.sched_hits.fetch_add(1, Ordering::Relaxed);
                return Some(s.clone());
            }
        }
        self.sched_misses.fetch_add(1, Ordering::Relaxed);
        let s = Arc::new(kernel.plan(tile));
        map.insert(key, s.clone());
        Some(s)
    }

    /// Schedule-cache counters: `(hits, misses)` since construction.
    pub fn schedule_cache_stats(&self) -> (usize, usize) {
        (self.sched_hits.load(Ordering::Relaxed), self.sched_misses.load(Ordering::Relaxed))
    }

    /// Fraction of schedule lookups served from the cache.
    pub fn schedule_hit_rate(&self) -> f64 {
        let (h, m) = self.schedule_cache_stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Stage XLA kernels for every artifact in the manifest whose
    /// static shape fits the named matrix. Returns how many artifacts
    /// were staged.
    pub fn attach_xla(
        &mut self,
        name: &str,
        rt: &XlaRuntime,
        manifest: &ArtifactManifest,
    ) -> Result<usize> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| Error::Usage(format!("matrix '{name}' not registered")))?;
        let mut staged = 0;
        let width = entry.csr.max_row_len();
        for spec in manifest.of_kind(crate::runtime::ArtifactKind::EllSpmm) {
            if spec.n == entry.csr.nrows && spec.width >= width.max(1) {
                let k = XlaSpmm::from_csr(rt, spec, &entry.csr)?;
                entry.kernels.insert((Impl::Xla, spec.d), Box::new(k));
                staged += 1;
            }
        }
        Ok(staged)
    }

    /// Prepare one extra native kernel after registration.
    pub fn add_native(&mut self, name: &str, im: Impl) -> Result<()> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| Error::Usage(format!("matrix '{name}' not registered")))?;
        let k = build_native(im, &entry.csr, entry.threads)?;
        entry.kernels.insert((im, 0), k);
        Ok(())
    }

    /// Lookup.
    pub fn get(&self, name: &str) -> Option<&MatrixEntry> {
        self.entries.get(name)
    }

    /// Registered names (sorted).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};

    #[test]
    fn register_and_lookup() {
        let mut reg = MatrixRegistry::new(2);
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(170));
        reg.register("er", a, &[Impl::Csr, Impl::Csb]).unwrap();
        let e = reg.get("er").unwrap();
        assert!(e.kernel(Impl::Csr, 16).is_some());
        assert!(e.kernel(Impl::Csb, 1).is_some());
        assert!(e.kernel(Impl::Opt, 4).is_none());
        assert_eq!(e.available(4), vec![Impl::Csb, Impl::Csr]);
        assert_eq!(reg.names(), vec!["er"]);
    }

    #[test]
    fn schedule_cache_hits_on_reuse() {
        let mut reg = MatrixRegistry::new(2);
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(172));
        reg.register("m", a, &[Impl::Csr, Impl::Csb]).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (0, 0));
        let s1 = reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (0, 1));
        let s2 = reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (1, 1));
        assert!(Arc::ptr_eq(&s1, &s2), "cache must hand out the same schedule");
        assert_eq!(s1.tile, Some(8));
        // a different (impl, d) is its own entry; dt ≥ d plans untiled
        let s3 = reg.schedule("m", Impl::Csb, 4, 4).unwrap();
        assert_eq!(s3.tile, None);
        assert_eq!(reg.schedule_cache_stats(), (1, 2));
        // unknown matrix / unprepared kernel
        assert!(reg.schedule("ghost", Impl::Csr, 4, 4).is_none());
        assert!(reg.schedule("m", Impl::Opt, 4, 4).is_none());
        // a conflicting tile request replans instead of serving stale
        let s4 = reg.schedule("m", Impl::Csr, 16, 4).unwrap();
        assert_eq!(s4.tile, Some(4));
        assert_eq!(reg.schedule_cache_stats(), (1, 3));
        // re-registration invalidates
        let a2 = erdos_renyi(300, 300, 5.0, &mut Prng::new(173));
        reg.register("m", a2, &[Impl::Csr]).unwrap();
        reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (1, 4));
        assert!(reg.schedule_hit_rate() > 0.15);
    }

    #[test]
    fn add_native_later() {
        let mut reg = MatrixRegistry::new(1);
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(171));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        reg.add_native("m", Impl::Opt).unwrap();
        assert!(reg.get("m").unwrap().kernel(Impl::Opt, 8).is_some());
        assert!(reg.add_native("missing", Impl::Opt).is_err());
    }
}
