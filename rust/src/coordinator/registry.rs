//! The matrix registry: prepared kernels, classification, and cached
//! execution schedules per registered matrix.
//!
//! Preparation (format conversion, classification, artifact staging)
//! happens once at registration — mirroring the paper's methodology,
//! which excludes loading and data-structure construction from the
//! timed region. Execution *schedules* (nnz-balanced partitions +
//! column tiles, `spmm::Schedule`) are built lazily on first use and
//! cached per `(matrix, impl, threads, d, dt)`, so repeated and
//! batched submissions pay planning cost once; hit/miss counters make
//! the reuse observable in batch reports.
//!
//! # Tenancy
//!
//! Registry keys are **tenant-scoped**: `"acme/web"` lives in tenant
//! `acme`'s namespace, a bare `"web"` in the default (empty) tenant —
//! [`MatrixRegistry::scoped`] builds such keys. Every method keeps
//! taking full keys, so single-tenant callers (the CLI, the benches,
//! the whole pre-serve test suite) are unchanged. Internally each
//! tenant gets its own shard — entries *and* schedule cache — so one
//! tenant's reorder (which invalidates its schedules under the shard's
//! lock) cannot stall another tenant's schedule lookups, and names
//! can never collide across tenants.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::pattern::{classify, Classification};
use crate::runtime::{ArtifactManifest, XlaRuntime, XlaSpmm};
use crate::sparse::{reorder::permute_symmetric, Csr, Reordering};
use crate::spgemm::{build_spgemm, SpGemm, SpGemmImpl};
use crate::spmm::{build_native, Impl, Schedule, Spmm};

/// One registered matrix with its prepared kernels.
///
/// Storage is permutation-aware: the autotuner may pin a reordering
/// (`P·A·Pᵀ`), in which case `csr` holds the *active* permuted matrix
/// (all kernels and schedules are built from it), `base` keeps the
/// matrix as registered, and `perm` records the row/column map
/// (`perm[old] = new`) so callers can translate between the registered
/// and the served row space.
pub struct MatrixEntry {
    pub name: String,
    /// Classification of the **active** (possibly permuted) matrix —
    /// reordering can legitimately move a matrix between classes;
    /// that is the router's whole lever.
    pub classification: Classification,
    /// Prepared kernels by implementation. XLA kernels are per-d, so
    /// they key on (impl, d); native kernels use d = 0 (any width).
    kernels: HashMap<(Impl, usize), Box<dyn Spmm>>,
    /// Prepared SpGEMM kernels over this matrix as the *left* operand.
    /// Built lazily on first SpGEMM submission
    /// ([`MatrixRegistry::ensure_spgemm`]) so SpMM-only registrations
    /// pay nothing; dropped (and lazily rebuilt) on conversion.
    spgemm_kernels: HashMap<SpGemmImpl, Box<dyn SpGemm>>,
    /// The active CSR (kept for late kernel construction).
    csr: Csr,
    /// The matrix as registered; populated on first conversion.
    base: Option<Csr>,
    /// Active reordering strategy.
    reorder: Reordering,
    /// Active permutation (`perm[old] = new`); `None` for identity.
    perm: Option<Vec<u32>>,
    /// Native implementations prepared at registration (rebuilt on
    /// conversion).
    impls: Vec<Impl>,
    threads: usize,
}

impl MatrixEntry {
    /// Kernel lookup: native kernels serve any d; XLA kernels must
    /// match exactly.
    pub fn kernel(&self, im: Impl, d: usize) -> Option<&dyn Spmm> {
        let key = if im == Impl::Xla { (im, d) } else { (im, 0) };
        self.kernels.get(&key).map(|b| b.as_ref())
    }

    /// Prepared SpGEMM kernel lookup (left operand = this matrix).
    pub fn spgemm_kernel(&self, im: SpGemmImpl) -> Option<&dyn SpGemm> {
        self.spgemm_kernels.get(&im).map(|b| b.as_ref())
    }

    /// Which implementations can serve width `d` right now.
    pub fn available(&self, d: usize) -> Vec<Impl> {
        let mut v: Vec<Impl> = Vec::new();
        for &(im, kd) in self.kernels.keys() {
            if (im != Impl::Xla && kd == 0) || (im == Impl::Xla && kd == d) {
                if !v.contains(&im) {
                    v.push(im);
                }
            }
        }
        v.sort_by_key(|im| format!("{im}"));
        v
    }

    /// Rows of the matrix.
    pub fn n(&self) -> usize {
        self.csr.nrows
    }

    /// Nonzeros of the matrix.
    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    /// The active (possibly permuted) matrix kernels execute on.
    pub fn csr(&self) -> &Csr {
        &self.csr
    }

    /// The matrix as registered, before any pinned reordering.
    pub fn base_csr(&self) -> &Csr {
        self.base.as_ref().unwrap_or(&self.csr)
    }

    /// The active reordering strategy.
    pub fn reordering(&self) -> Reordering {
        self.reorder
    }

    /// The active permutation (`perm[old] = new`), if any. Callers
    /// serving results back in the registered row order apply the
    /// inverse ([`crate::sparse::reorder::invert_permutation`]).
    pub fn permutation(&self) -> Option<&[u32]> {
        self.perm.as_deref()
    }

    /// Native implementations prepared for this entry.
    pub fn native_impls(&self) -> &[Impl] {
        &self.impls
    }
}

/// One tenant's slice of the registry: its entries plus its own
/// schedule cache (and the cache's lock), so reorders and schedule
/// lookups in different tenants never contend.
#[derive(Default)]
struct TenantShard {
    entries: HashMap<String, MatrixEntry>,
    /// Execution schedules keyed by `(matrix, impl, threads, d, dt)`
    /// (`dt` normalised: untiled stores `d`). Interior-mutable so
    /// lookups work through `&self` while kernels are borrowed.
    schedules: Mutex<HashMap<(String, Impl, usize, usize, usize), Arc<Schedule>>>,
}

/// Registry of prepared matrices, sharded by tenant (see the module
/// docs for the key scheme).
pub struct MatrixRegistry {
    shards: HashMap<String, TenantShard>,
    threads: usize,
    sched_hits: AtomicUsize,
    sched_misses: AtomicUsize,
}

/// The tenant part of a scoped key (`""` for unscoped names).
fn tenant_of(name: &str) -> &str {
    name.split_once('/').map(|(t, _)| t).unwrap_or("")
}

impl MatrixRegistry {
    pub fn new(threads: usize) -> MatrixRegistry {
        MatrixRegistry {
            shards: HashMap::new(),
            threads: threads.max(1),
            sched_hits: AtomicUsize::new(0),
            sched_misses: AtomicUsize::new(0),
        }
    }

    /// Build a tenant-scoped registry key: `scoped("acme", "web")` is
    /// `"acme/web"`; the empty tenant keeps the bare name.
    pub fn scoped(tenant: &str, name: &str) -> String {
        if tenant.is_empty() {
            name.to_string()
        } else {
            format!("{tenant}/{name}")
        }
    }

    fn shard(&self, name: &str) -> Option<&TenantShard> {
        self.shards.get(tenant_of(name))
    }

    fn shard_mut(&mut self, name: &str) -> Option<&mut TenantShard> {
        self.shards.get_mut(tenant_of(name))
    }

    fn entry(&self, name: &str) -> Option<&MatrixEntry> {
        self.shard(name)?.entries.get(name)
    }

    fn entry_mut(&mut self, name: &str) -> Result<&mut MatrixEntry> {
        self.shard_mut(name)
            .and_then(|s| s.entries.get_mut(name))
            .ok_or_else(|| Error::Usage(format!("matrix '{name}' not registered")))
    }

    /// Register a matrix: classify it and prepare the requested native
    /// kernels. The name may be tenant-scoped ([`MatrixRegistry::scoped`]).
    pub fn register(&mut self, name: impl Into<String>, csr: Csr, impls: &[Impl]) -> Result<()> {
        let name = name.into();
        let classification = classify(&csr);
        let native: Vec<Impl> = impls.iter().copied().filter(|&im| im != Impl::Xla).collect();
        let mut kernels: HashMap<(Impl, usize), Box<dyn Spmm>> = HashMap::new();
        for &im in &native {
            kernels.insert((im, 0), build_native(im, &csr, self.threads)?);
        }
        let threads = self.threads;
        let shard = self.shards.entry(tenant_of(&name).to_string()).or_default();
        // re-registering a name invalidates its cached schedules
        shard.schedules.lock().unwrap().retain(|k, _| k.0 != name);
        shard.entries.insert(
            name.clone(),
            MatrixEntry {
                name,
                classification,
                kernels,
                spgemm_kernels: HashMap::new(),
                csr,
                base: None,
                reorder: Reordering::None,
                perm: None,
                impls: native,
                threads,
            },
        );
        Ok(())
    }

    /// Worker threads kernels are prepared with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Convert the stored matrix to a reordering: permute `P·A·Pᵀ`
    /// from the *registered* matrix, rebuild every native kernel on the
    /// permuted layout, reclassify, and invalidate the entry's cached
    /// schedules (they partition the old row order). Staged XLA
    /// kernels are dropped — the AOT artifact embeds the old structure
    /// — and must be re-attached if wanted. `Reordering::None` restores
    /// the registered ordering. Returns `false` when the requested
    /// reordering was already active (nothing rebuilt).
    pub fn apply_reordering(&mut self, name: &str, r: Reordering) -> Result<bool> {
        let threads = self.threads;
        let entry = self.entry_mut(name)?;
        if entry.reorder == r {
            return Ok(false);
        }
        if r != Reordering::None && entry.csr.nrows != entry.csr.ncols {
            return Err(Error::Usage(format!(
                "reordering {r} needs a square matrix; '{name}' is {}x{}",
                entry.csr.nrows, entry.csr.ncols
            )));
        }
        let base = entry.base.take().unwrap_or_else(|| entry.csr.clone());
        let perm = r.permutation(&base);
        let csr = match &perm {
            Some(p) => permute_symmetric(&base, p),
            None => base.clone(),
        };
        let mut kernels: HashMap<(Impl, usize), Box<dyn Spmm>> = HashMap::new();
        for &im in &entry.impls {
            kernels.insert((im, 0), build_native(im, &csr, threads)?);
        }
        entry.classification = classify(&csr);
        entry.kernels = kernels;
        // SpGEMM kernels embed the old layout's binning — drop them;
        // the next SpGEMM submission rebuilds from the permuted matrix
        entry.spgemm_kernels = HashMap::new();
        entry.csr = csr;
        entry.base = if r == Reordering::None { None } else { Some(base) };
        entry.reorder = r;
        entry.perm = perm;
        // cached schedules partition the old ordering — drop them
        // (only this tenant's shard locks; other tenants keep serving)
        if let Some(shard) = self.shard(name) {
            shard.schedules.lock().unwrap().retain(|k, _| k.0 != name);
        }
        Ok(true)
    }

    /// The cached execution schedule for `(name, im, threads, d, dt)`,
    /// building it (with column-tile width `dt`) on first use. `dt ≥ d`
    /// plans untiled and is normalised to `d` in the key, so every
    /// untiled spelling shares one entry. Returns `None` when the
    /// matrix or kernel is unknown.
    ///
    /// The key includes the tile width: two plans for the same
    /// `(matrix, impl, d)` with different `dt` (the autotuner measures
    /// exactly such pairs) are distinct cache entries — an earlier
    /// revision keyed on `(matrix, impl, threads, d)` only, so the
    /// second tile width evicted the first and alternating requests
    /// replanned every time.
    pub fn schedule(&self, name: &str, im: Impl, d: usize, dt: usize) -> Option<Arc<Schedule>> {
        let shard = self.shard(name)?;
        let entry = shard.entries.get(name)?;
        let kernel = entry.kernel(im, d)?;
        let tile = if dt >= d { None } else { Some(dt) };
        let key = (name.to_string(), im, self.threads, d, tile.unwrap_or(d));
        let mut map = shard.schedules.lock().unwrap();
        if let Some(s) = map.get(&key) {
            self.sched_hits.fetch_add(1, Ordering::Relaxed);
            return Some(s.clone());
        }
        self.sched_misses.fetch_add(1, Ordering::Relaxed);
        let s = Arc::new(kernel.plan(tile));
        map.insert(key, s.clone());
        Some(s)
    }

    /// Schedule-cache counters: `(hits, misses)` since construction.
    pub fn schedule_cache_stats(&self) -> (usize, usize) {
        (self.sched_hits.load(Ordering::Relaxed), self.sched_misses.load(Ordering::Relaxed))
    }

    /// Fraction of schedule lookups served from the cache.
    pub fn schedule_hit_rate(&self) -> f64 {
        let (h, m) = self.schedule_cache_stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Stage XLA kernels for every artifact in the manifest whose
    /// static shape fits the named matrix. Returns how many artifacts
    /// were staged.
    pub fn attach_xla(
        &mut self,
        name: &str,
        rt: &XlaRuntime,
        manifest: &ArtifactManifest,
    ) -> Result<usize> {
        let entry = self.entry_mut(name)?;
        let mut staged = 0;
        let width = entry.csr.max_row_len();
        for spec in manifest.of_kind(crate::runtime::ArtifactKind::EllSpmm) {
            if spec.n == entry.csr.nrows && spec.width >= width.max(1) {
                let k = XlaSpmm::from_csr(rt, spec, &entry.csr)?;
                entry.kernels.insert((Impl::Xla, spec.d), Box::new(k));
                staged += 1;
            }
        }
        Ok(staged)
    }

    /// Resolve an SpGEMM operand pair: both names registered and the
    /// inner dimensions agreeing (`cols(a) == rows(b)`). Shared by the
    /// router and the engine so the validation — and its error wording
    /// — lives in one place.
    pub fn spgemm_pair(&self, a: &str, b: &str) -> Result<(&MatrixEntry, &MatrixEntry)> {
        let entry_a = self
            .entry(a)
            .ok_or_else(|| Error::Usage(format!("matrix '{a}' not registered")))?;
        let entry_b = self
            .entry(b)
            .ok_or_else(|| Error::Usage(format!("matrix '{b}' not registered")))?;
        let (acsr, bcsr) = (entry_a.csr(), entry_b.csr());
        if bcsr.nrows != acsr.ncols {
            return Err(Error::DimensionMismatch(format!(
                "'{a}' is {}x{} but '{b}' has {} rows",
                acsr.nrows, acsr.ncols, bcsr.nrows
            )));
        }
        Ok((entry_a, entry_b))
    }

    /// Ensure an SpGEMM kernel (left operand = `name`'s active matrix)
    /// is prepared, building it lazily on first use. Idempotent.
    pub fn ensure_spgemm(&mut self, name: &str, im: SpGemmImpl) -> Result<()> {
        let threads = self.threads;
        let entry = self.entry_mut(name)?;
        if !entry.spgemm_kernels.contains_key(&im) {
            let k = build_spgemm(im, &entry.csr, threads);
            entry.spgemm_kernels.insert(im, k);
        }
        Ok(())
    }

    /// Prepare one extra native kernel after registration.
    pub fn add_native(&mut self, name: &str, im: Impl) -> Result<()> {
        let entry = self.entry_mut(name)?;
        let k = build_native(im, &entry.csr, entry.threads)?;
        entry.kernels.insert((im, 0), k);
        // conversions rebuild from `impls` — keep it in sync
        if !entry.impls.contains(&im) {
            entry.impls.push(im);
        }
        Ok(())
    }

    /// Install a caller-built SpMM kernel under `im` for `name` —
    /// the instrumentation / fault-injection seam the serve tests use
    /// to plant panicking kernels. The kernel's shape must match the
    /// entry's active matrix. Its schedules are invalidated (the old
    /// kernel planned them); a later [`MatrixRegistry::apply_reordering`]
    /// rebuilds natively and drops the installed kernel.
    pub fn install_kernel(&mut self, name: &str, im: Impl, k: Box<dyn Spmm>) -> Result<()> {
        let entry = self.entry_mut(name)?;
        if k.nrows() != entry.csr.nrows || k.ncols() != entry.csr.ncols {
            return Err(Error::DimensionMismatch(format!(
                "installed kernel is {}x{} but '{name}' is {}x{}",
                k.nrows(),
                k.ncols(),
                entry.csr.nrows,
                entry.csr.ncols
            )));
        }
        entry.kernels.insert((im, 0), k);
        if let Some(shard) = self.shard(name) {
            shard.schedules.lock().unwrap().retain(|key, _| key.0 != name);
        }
        Ok(())
    }

    /// Lookup (full tenant-scoped key).
    pub fn get(&self, name: &str) -> Option<&MatrixEntry> {
        self.entry(name)
    }

    /// Registered names across all tenants (sorted full keys).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> =
            self.shards.values().flat_map(|s| s.entries.keys().map(|k| k.as_str())).collect();
        v.sort();
        v
    }

    /// Tenants with at least one registered matrix (sorted; the
    /// default tenant appears as `""`).
    pub fn tenants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .shards
            .iter()
            .filter(|(_, s)| !s.entries.is_empty())
            .map(|(t, _)| t.as_str())
            .collect();
        v.sort();
        v
    }

    /// Registered names (sorted full keys) inside one tenant.
    pub fn names_in(&self, tenant: &str) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .shards
            .get(tenant)
            .map(|s| s.entries.keys().map(|k| k.as_str()).collect())
            .unwrap_or_default();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};

    #[test]
    fn register_and_lookup() {
        let mut reg = MatrixRegistry::new(2);
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(170));
        reg.register("er", a, &[Impl::Csr, Impl::Csb]).unwrap();
        let e = reg.get("er").unwrap();
        assert!(e.kernel(Impl::Csr, 16).is_some());
        assert!(e.kernel(Impl::Csb, 1).is_some());
        assert!(e.kernel(Impl::Opt, 4).is_none());
        assert_eq!(e.available(4), vec![Impl::Csb, Impl::Csr]);
        assert_eq!(reg.names(), vec!["er"]);
    }

    #[test]
    fn schedule_cache_hits_on_reuse() {
        let mut reg = MatrixRegistry::new(2);
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(172));
        reg.register("m", a, &[Impl::Csr, Impl::Csb]).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (0, 0));
        let s1 = reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (0, 1));
        let s2 = reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (1, 1));
        assert!(Arc::ptr_eq(&s1, &s2), "cache must hand out the same schedule");
        assert_eq!(s1.tile, Some(8));
        // a different (impl, d) is its own entry; dt ≥ d plans untiled
        let s3 = reg.schedule("m", Impl::Csb, 4, 4).unwrap();
        assert_eq!(s3.tile, None);
        assert_eq!(reg.schedule_cache_stats(), (1, 2));
        // unknown matrix / unprepared kernel
        assert!(reg.schedule("ghost", Impl::Csr, 4, 4).is_none());
        assert!(reg.schedule("m", Impl::Opt, 4, 4).is_none());
        // re-registration invalidates
        let a2 = erdos_renyi(300, 300, 5.0, &mut Prng::new(173));
        reg.register("m", a2, &[Impl::Csr]).unwrap();
        reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (1, 3));
        assert!(reg.schedule_hit_rate() > 0.15);
    }

    #[test]
    fn two_tile_widths_for_one_impl_and_d_coexist() {
        // regression: the cache key used to omit dt, so these two plans
        // collided — the second evicted the first and alternating
        // requests replanned (and, before the tile check, one silently
        // executed with the other's tiling)
        let mut reg = MatrixRegistry::new(2);
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(174));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        let s8 = reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        let s4 = reg.schedule("m", Impl::Csr, 16, 4).unwrap();
        assert_eq!(s8.tile, Some(8));
        assert_eq!(s4.tile, Some(4));
        assert_eq!(reg.schedule_cache_stats(), (0, 2));
        // both entries live: re-requesting either is a hit on its own plan
        let s8b = reg.schedule("m", Impl::Csr, 16, 8).unwrap();
        let s4b = reg.schedule("m", Impl::Csr, 16, 4).unwrap();
        assert!(Arc::ptr_eq(&s8, &s8b));
        assert!(Arc::ptr_eq(&s4, &s4b));
        assert_eq!(reg.schedule_cache_stats(), (2, 2));
        // every untiled spelling (dt ≥ d) normalises to one entry
        let u1 = reg.schedule("m", Impl::Csr, 16, 16).unwrap();
        let u2 = reg.schedule("m", Impl::Csr, 16, 999).unwrap();
        assert!(Arc::ptr_eq(&u1, &u2));
        assert_eq!(u1.tile, None);
        assert_eq!(reg.schedule_cache_stats(), (3, 3));
    }

    #[test]
    fn apply_reordering_converts_reclassifies_and_invalidates() {
        use crate::gen::{mesh2d, MeshKind};
        use crate::sparse::reorder::{bandwidth, permute_symmetric, random_permutation};
        use crate::sparse::Reordering;
        let mut reg = MatrixRegistry::new(2);
        let mut rng = Prng::new(175);
        let a = mesh2d(16, MeshKind::Triangular, 0.9, &mut rng);
        let p = random_permutation(a.nrows, &mut rng);
        let scrambled = permute_symmetric(&a, &p);
        reg.register("m", scrambled.clone(), &[Impl::Csr, Impl::Csb]).unwrap();
        reg.schedule("m", Impl::Csr, 8, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats().1, 1);

        assert!(reg.apply_reordering("m", Reordering::Rcm).unwrap());
        let e = reg.get("m").unwrap();
        assert_eq!(e.reordering(), Reordering::Rcm);
        assert!(e.permutation().is_some());
        assert_eq!(e.nnz(), scrambled.nnz());
        assert_eq!(e.base_csr().to_dense(), scrambled.to_dense());
        assert!(bandwidth(e.csr()) < bandwidth(&scrambled), "RCM must tighten the band");
        // kernels rebuilt on the permuted layout for every prepared impl
        assert!(e.kernel(Impl::Csr, 4).is_some());
        assert!(e.kernel(Impl::Csb, 4).is_some());
        // schedules were invalidated: the same request plans again
        reg.schedule("m", Impl::Csr, 8, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats().1, 2);

        // re-applying the active reordering is a no-op
        assert!(!reg.apply_reordering("m", Reordering::Rcm).unwrap());
        // None restores the registered ordering exactly
        assert!(reg.apply_reordering("m", Reordering::None).unwrap());
        let e = reg.get("m").unwrap();
        assert_eq!(e.reordering(), Reordering::None);
        assert!(e.permutation().is_none());
        assert_eq!(e.csr().to_dense(), scrambled.to_dense());

        assert!(reg.apply_reordering("ghost", Reordering::Rcm).is_err());
    }

    #[test]
    fn spgemm_kernels_build_lazily_and_drop_on_reorder() {
        use crate::spgemm::SpGemmImpl;
        let mut reg = MatrixRegistry::new(2);
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(176));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        assert!(reg.get("m").unwrap().spgemm_kernel(SpGemmImpl::Hash).is_none());
        reg.ensure_spgemm("m", SpGemmImpl::Hash).unwrap();
        reg.ensure_spgemm("m", SpGemmImpl::Hash).unwrap(); // idempotent
        assert!(reg.get("m").unwrap().spgemm_kernel(SpGemmImpl::Hash).is_some());
        assert!(reg.get("m").unwrap().spgemm_kernel(SpGemmImpl::PbMerge).is_none());
        // conversion drops the SpGEMM kernels (the binning embeds the
        // old layout); the next ensure rebuilds from the permuted matrix
        reg.apply_reordering("m", crate::sparse::Reordering::DegreeSort).unwrap();
        assert!(reg.get("m").unwrap().spgemm_kernel(SpGemmImpl::Hash).is_none());
        reg.ensure_spgemm("m", SpGemmImpl::Hash).unwrap();
        assert!(reg.get("m").unwrap().spgemm_kernel(SpGemmImpl::Hash).is_some());
        assert!(reg.ensure_spgemm("ghost", SpGemmImpl::Hash).is_err());
    }

    #[test]
    fn add_native_later() {
        let mut reg = MatrixRegistry::new(1);
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(171));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        reg.add_native("m", Impl::Opt).unwrap();
        assert!(reg.get("m").unwrap().kernel(Impl::Opt, 8).is_some());
        assert!(reg.add_native("missing", Impl::Opt).is_err());
    }

    #[test]
    fn scoped_keys_and_tenant_listing() {
        assert_eq!(MatrixRegistry::scoped("acme", "web"), "acme/web");
        assert_eq!(MatrixRegistry::scoped("", "web"), "web");
        let mut reg = MatrixRegistry::new(2);
        let mut rng = Prng::new(177);
        reg.register("web", erdos_renyi(60, 60, 3.0, &mut rng), &[Impl::Csr]).unwrap();
        reg.register("acme/web", erdos_renyi(80, 80, 3.0, &mut rng), &[Impl::Csr]).unwrap();
        reg.register("beta/road", erdos_renyi(50, 50, 3.0, &mut rng), &[Impl::Csr]).unwrap();
        // same local name, different tenants, no collision
        assert_eq!(reg.get("web").unwrap().n(), 60);
        assert_eq!(reg.get("acme/web").unwrap().n(), 80);
        assert_eq!(reg.names(), vec!["acme/web", "beta/road", "web"]);
        assert_eq!(reg.tenants(), vec!["", "acme", "beta"]);
        assert_eq!(reg.names_in("acme"), vec!["acme/web"]);
        assert_eq!(reg.names_in(""), vec!["web"]);
        assert!(reg.names_in("ghost").is_empty());
    }

    #[test]
    fn tenant_reorder_does_not_invalidate_other_tenants_schedules() {
        let mut reg = MatrixRegistry::new(2);
        let mut rng = Prng::new(178);
        reg.register("acme/m", erdos_renyi(120, 120, 4.0, &mut rng), &[Impl::Csr]).unwrap();
        reg.register("beta/m", erdos_renyi(120, 120, 4.0, &mut rng), &[Impl::Csr]).unwrap();
        let s_beta = reg.schedule("beta/m", Impl::Csr, 8, 8).unwrap();
        reg.schedule("acme/m", Impl::Csr, 8, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (0, 2));
        // acme's reorder only empties acme's shard cache
        assert!(reg.apply_reordering("acme/m", Reordering::DegreeSort).unwrap());
        let s_beta2 = reg.schedule("beta/m", Impl::Csr, 8, 8).unwrap();
        assert!(Arc::ptr_eq(&s_beta, &s_beta2), "beta's schedule must survive acme's reorder");
        reg.schedule("acme/m", Impl::Csr, 8, 8).unwrap();
        assert_eq!(reg.schedule_cache_stats(), (1, 3));
    }

    #[test]
    fn install_kernel_replaces_and_validates_shape() {
        struct Fake {
            n: usize,
        }
        impl crate::spmm::Spmm for Fake {
            fn id(&self) -> Impl {
                Impl::Csb
            }
            fn nrows(&self) -> usize {
                self.n
            }
            fn ncols(&self) -> usize {
                self.n
            }
            fn nnz(&self) -> usize {
                0
            }
            fn execute(
                &self,
                _b: &crate::spmm::DenseMatrix,
                c: &mut crate::spmm::DenseMatrix,
            ) -> crate::error::Result<()> {
                c.data.fill(42.0);
                Ok(())
            }
        }
        let mut reg = MatrixRegistry::new(1);
        let a = erdos_renyi(30, 30, 2.0, &mut Prng::new(179));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        reg.schedule("m", Impl::Csr, 4, 4).unwrap();
        // wrong shape rejected
        assert!(reg.install_kernel("m", Impl::Csb, Box::new(Fake { n: 31 })).is_err());
        assert!(reg.install_kernel("ghost", Impl::Csb, Box::new(Fake { n: 30 })).is_err());
        // install under a previously-unprepared impl
        reg.install_kernel("m", Impl::Csb, Box::new(Fake { n: 30 })).unwrap();
        let e = reg.get("m").unwrap();
        // the installed fake (nnz 0) serves lookups, not a real CSB build
        assert_eq!(e.kernel(Impl::Csb, 4).unwrap().nnz(), 0);
        assert!(e.available(4).contains(&Impl::Csb));
        // installing invalidated the name's schedules: next lookup replans
        let (_, misses_before) = reg.schedule_cache_stats();
        reg.schedule("m", Impl::Csr, 4, 4).unwrap();
        assert_eq!(reg.schedule_cache_stats().1, misses_before + 1);
    }
}
