//! The matrix registry: prepared kernels + classification per
//! registered matrix.
//!
//! Preparation (format conversion, classification, artifact staging)
//! happens once at registration — mirroring the paper's methodology,
//! which excludes loading and data-structure construction from the
//! timed region.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::pattern::{classify, Classification};
use crate::runtime::{ArtifactManifest, XlaRuntime, XlaSpmm};
use crate::sparse::Csr;
use crate::spmm::{build_native, Impl, Spmm};

/// One registered matrix with its prepared kernels.
pub struct MatrixEntry {
    pub name: String,
    pub classification: Classification,
    /// Prepared kernels by implementation. XLA kernels are per-d, so
    /// they key on (impl, d); native kernels use d = 0 (any width).
    kernels: HashMap<(Impl, usize), Box<dyn Spmm>>,
    /// The CSR source (kept for late kernel construction).
    csr: Csr,
    threads: usize,
}

impl MatrixEntry {
    /// Kernel lookup: native kernels serve any d; XLA kernels must
    /// match exactly.
    pub fn kernel(&self, im: Impl, d: usize) -> Option<&dyn Spmm> {
        let key = if im == Impl::Xla { (im, d) } else { (im, 0) };
        self.kernels.get(&key).map(|b| b.as_ref())
    }

    /// Which implementations can serve width `d` right now.
    pub fn available(&self, d: usize) -> Vec<Impl> {
        let mut v: Vec<Impl> = Vec::new();
        for &(im, kd) in self.kernels.keys() {
            if (im != Impl::Xla && kd == 0) || (im == Impl::Xla && kd == d) {
                if !v.contains(&im) {
                    v.push(im);
                }
            }
        }
        v.sort_by_key(|im| format!("{im}"));
        v
    }

    /// Rows of the matrix.
    pub fn n(&self) -> usize {
        self.csr.nrows
    }

    /// Nonzeros of the matrix.
    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }
}

/// Registry of prepared matrices.
pub struct MatrixRegistry {
    entries: HashMap<String, MatrixEntry>,
    threads: usize,
}

impl MatrixRegistry {
    pub fn new(threads: usize) -> MatrixRegistry {
        MatrixRegistry { entries: HashMap::new(), threads: threads.max(1) }
    }

    /// Register a matrix: classify it and prepare the requested native
    /// kernels.
    pub fn register(&mut self, name: impl Into<String>, csr: Csr, impls: &[Impl]) -> Result<()> {
        let name = name.into();
        let classification = classify(&csr);
        let mut kernels: HashMap<(Impl, usize), Box<dyn Spmm>> = HashMap::new();
        for &im in impls {
            if im == Impl::Xla {
                continue; // staged separately via attach_xla
            }
            kernels.insert((im, 0), build_native(im, &csr, self.threads)?);
        }
        self.entries.insert(
            name.clone(),
            MatrixEntry { name, classification, kernels, csr, threads: self.threads },
        );
        Ok(())
    }

    /// Stage XLA kernels for every artifact in the manifest whose
    /// static shape fits the named matrix. Returns how many artifacts
    /// were staged.
    pub fn attach_xla(
        &mut self,
        name: &str,
        rt: &XlaRuntime,
        manifest: &ArtifactManifest,
    ) -> Result<usize> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| Error::Usage(format!("matrix '{name}' not registered")))?;
        let mut staged = 0;
        let width = entry.csr.max_row_len();
        for spec in manifest.of_kind(crate::runtime::ArtifactKind::EllSpmm) {
            if spec.n == entry.csr.nrows && spec.width >= width.max(1) {
                let k = XlaSpmm::from_csr(rt, spec, &entry.csr)?;
                entry.kernels.insert((Impl::Xla, spec.d), Box::new(k));
                staged += 1;
            }
        }
        Ok(staged)
    }

    /// Prepare one extra native kernel after registration.
    pub fn add_native(&mut self, name: &str, im: Impl) -> Result<()> {
        let entry = self
            .entries
            .get_mut(name)
            .ok_or_else(|| Error::Usage(format!("matrix '{name}' not registered")))?;
        let k = build_native(im, &entry.csr, entry.threads)?;
        entry.kernels.insert((im, 0), k);
        Ok(())
    }

    /// Lookup.
    pub fn get(&self, name: &str) -> Option<&MatrixEntry> {
        self.entries.get(name)
    }

    /// Registered names (sorted).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, Prng};

    #[test]
    fn register_and_lookup() {
        let mut reg = MatrixRegistry::new(2);
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(170));
        reg.register("er", a, &[Impl::Csr, Impl::Csb]).unwrap();
        let e = reg.get("er").unwrap();
        assert!(e.kernel(Impl::Csr, 16).is_some());
        assert!(e.kernel(Impl::Csb, 1).is_some());
        assert!(e.kernel(Impl::Opt, 4).is_none());
        assert_eq!(e.available(4), vec![Impl::Csb, Impl::Csr]);
        assert_eq!(reg.names(), vec!["er"]);
    }

    #[test]
    fn add_native_later() {
        let mut reg = MatrixRegistry::new(1);
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(171));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        reg.add_native("m", Impl::Opt).unwrap();
        assert!(reg.get("m").unwrap().kernel(Impl::Opt, 8).is_some());
        assert!(reg.add_native("missing", Impl::Opt).is_err());
    }
}
