//! The learned structure router: a pure-Rust decision forest over the
//! accumulated `BENCH_route.json` records.
//!
//! The analytic router (PR 3 onward) ranks candidates with
//! hand-derived roofline formulas and per-structure priors. Every tune
//! it runs emits a `PerfRecord` pairing the matrix's *structural
//! features* with the *measured winner* — nine PRs of those records
//! are a labeled training set. Following SpChar's observation that
//! decision trees over structure features characterise sparse-kernel
//! behaviour well (PAPERS.md, arXiv:2304.06944), this module trains a
//! small CART forest mapping a [`FeatureVec`] (row-length CV, hub
//! mass, diagonal/block fractions, log-scaled n/nnz/d) to the winning
//! `(impl, reorder, dt)` triple — a [`RouteLabel`].
//!
//! **The learned router advises; measurement still decides.** When
//! installed on the [`crate::coordinator::Autotuner`], a confident
//! in-distribution prediction *promotes* its candidate to the top of
//! the explore order (and supplies its tile width); the measured-best
//! candidate still wins the pin. Off-distribution queries — any
//! feature outside the training ranges (± a 10% span margin) — and
//! low-confidence leaves return `None`, falling back to the analytic
//! ranking unchanged. [`RouteSource`] on the decision records which
//! path fired, so `bench_route` can report regret-vs-analytic per
//! structure group.
//!
//! **Confidence** is the purity-weighted vote share: each tree's leaf
//! votes for its majority label with weight = leaf purity (majority
//! fraction), and the winner's share of the total weight must clear
//! `min_confidence`, with the winner's aggregate leaf support (total
//! training examples in its voting leaves) clearing `min_support`.
//! A forest split 2-vs-1 over pure leaves scores 2/3; an impure
//! unanimous forest scores its mean purity — both must beat the gate
//! or the analytic model routes.
//!
//! Zero dependencies, deterministic: trees split on Gini impurity with
//! ascending feature/threshold tie-breaking, bootstrap resampling uses
//! the repo's seeded [`Prng`], and equal training sets train to equal
//! forests — which is what lets the trained forest round-trip
//! byte-identically through the STATE_VERSION 4 snapshot.

use std::collections::HashMap;
use std::fmt;

use crate::error::{Error, Result};
use crate::gen::Prng;
use crate::model::{FeatureVec, N_FEATURES};
use crate::pattern::Classification;
use crate::report::PerfLog;
use crate::sparse::Reordering;
use crate::spmm::Impl;

/// Which model produced a routing decision's candidate ranking.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RouteSource {
    /// The hand-derived roofline ranking (the default).
    Analytic,
    /// The learned forest promoted its predicted winner.
    Learned,
}

impl fmt::Display for RouteSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteSource::Analytic => write!(f, "analytic"),
            RouteSource::Learned => write!(f, "learned"),
        }
    }
}

/// The prediction target: the winning plan triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RouteLabel {
    pub im: Impl,
    pub reorder: Reordering,
    /// Column-tile width of the winning plan.
    pub dt: usize,
}

impl RouteLabel {
    /// Deterministic ordering key (display names — the enums
    /// deliberately don't implement `Ord`).
    fn key(&self) -> (String, String, usize) {
        (format!("{}", self.im), format!("{}", self.reorder), self.dt)
    }
}

/// One labeled training point: features at tune time → measured winner.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub features: FeatureVec,
    pub label: RouteLabel,
}

/// Training knobs. The defaults are sized for the record volumes the
/// benches actually produce (tens of decisions): shallow trees, leaves
/// down to single examples, and gates that hand anything ambiguous
/// back to the analytic model.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Trees in the forest: tree 0 trains on the full set, the rest on
    /// seeded bootstrap resamples.
    pub n_trees: usize,
    /// Maximum split depth.
    pub max_depth: usize,
    /// Minimum examples per leaf.
    pub min_leaf: usize,
    /// Minimum purity-weighted vote share for a learned route.
    pub min_confidence: f64,
    /// Minimum aggregate leaf support behind the winning vote.
    pub min_support: usize,
    /// Bootstrap PRNG seed — fixed so training is reproducible.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            n_trees: 3,
            max_depth: 6,
            min_leaf: 1,
            min_confidence: 0.65,
            min_support: 3,
            seed: 0x1ea7_ed,
        }
    }
}

/// A tree node, stored flat in [`DecisionTree::nodes`]. Children
/// always have a larger index than their parent (pre-order emission),
/// so traversal terminates by construction and [`DecisionTree::validate`]
/// can reject cyclic or dangling snapshots structurally.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    Split { feature: usize, threshold: f64, left: usize, right: usize },
    Leaf { label: RouteLabel, count: usize, purity: f64 },
}

/// One CART tree: Gini-impurity splits, majority-label leaves.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DecisionTree {
    pub nodes: Vec<Node>,
}

fn gini(counts: &HashMap<RouteLabel, usize>, total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let mut g = 1.0;
    for &c in counts.values() {
        let p = c as f64 / total as f64;
        g -= p * p;
    }
    g
}

/// Majority label with deterministic tie-breaking (count desc, then
/// display-name key asc), plus its purity.
fn majority(counts: &HashMap<RouteLabel, usize>, total: usize) -> (RouteLabel, f64) {
    let mut items: Vec<(&RouteLabel, &usize)> = counts.iter().collect();
    items.sort_by(|a, b| b.1.cmp(a.1).then_with(|| a.0.key().cmp(&b.0.key())));
    let (label, &count) = items[0];
    (*label, count as f64 / total as f64)
}

fn label_counts(examples: &[Example], idx: &[usize]) -> HashMap<RouteLabel, usize> {
    let mut counts = HashMap::new();
    for &i in idx {
        *counts.entry(examples[i].label).or_insert(0) += 1;
    }
    counts
}

impl DecisionTree {
    /// Train one tree on `idx` (indices into `examples`).
    fn fit(examples: &[Example], idx: &[usize], cfg: &TrainConfig) -> DecisionTree {
        let mut nodes = Vec::new();
        grow(&mut nodes, examples, idx.to_vec(), 0, cfg);
        DecisionTree { nodes }
    }

    /// Descend to the leaf for `x`. `None` only on a malformed tree
    /// (never after [`DecisionTree::validate`]).
    pub fn route(&self, x: &FeatureVec) -> Option<(RouteLabel, f64, usize)> {
        let mut i = 0usize;
        // children strictly outrank parents, so the walk is bounded
        for _ in 0..=self.nodes.len() {
            match self.nodes.get(i)? {
                Node::Split { feature, threshold, left, right } => {
                    i = if x.0[*feature] <= *threshold { *left } else { *right };
                }
                Node::Leaf { label, count, purity } => return Some((*label, *purity, *count)),
            }
        }
        None
    }

    /// Structural validation for snapshot restore: indices in range,
    /// children strictly after their parent (acyclic by construction),
    /// every non-root node referenced exactly once, finite thresholds,
    /// sane leaf statistics. A tree failing any check rejects the
    /// whole snapshot — cold start beats routing through garbage.
    pub fn validate(&self) -> Result<()> {
        let n = self.nodes.len();
        if n == 0 {
            return Err(Error::Parse("learned tree has no nodes".into()));
        }
        let mut refs = vec![0usize; n];
        for (i, node) in self.nodes.iter().enumerate() {
            match node {
                Node::Split { feature, threshold, left, right } => {
                    if *feature >= N_FEATURES {
                        return Err(Error::Parse(format!(
                            "learned tree split on unknown feature {feature}"
                        )));
                    }
                    if !threshold.is_finite() {
                        return Err(Error::Parse("learned tree threshold not finite".into()));
                    }
                    for &child in [left, right] {
                        if child <= i || child >= n {
                            return Err(Error::Parse(format!(
                                "learned tree child {child} does not follow parent {i}"
                            )));
                        }
                        refs[child] += 1;
                    }
                    if left == right {
                        return Err(Error::Parse("learned tree split with equal children".into()));
                    }
                }
                Node::Leaf { count, purity, label } => {
                    if *count == 0 {
                        return Err(Error::Parse("learned tree leaf with zero support".into()));
                    }
                    if !purity.is_finite() || *purity <= 0.0 || *purity > 1.0 {
                        return Err(Error::Parse("learned tree leaf purity out of range".into()));
                    }
                    if label.dt == 0 {
                        return Err(Error::Parse("learned tree leaf with dt = 0".into()));
                    }
                }
            }
        }
        if refs[0] != 0 {
            return Err(Error::Parse("learned tree root is someone's child".into()));
        }
        if refs.iter().skip(1).any(|&r| r != 1) {
            return Err(Error::Parse("learned tree has unreachable or shared nodes".into()));
        }
        Ok(())
    }
}

/// Recursive split search; returns the new node's index. Children are
/// emitted after their parent, preserving the index invariant
/// `validate` checks.
fn grow(
    nodes: &mut Vec<Node>,
    examples: &[Example],
    idx: Vec<usize>,
    depth: usize,
    cfg: &TrainConfig,
) -> usize {
    let counts = label_counts(examples, &idx);
    let total = idx.len();
    let make_leaf = |nodes: &mut Vec<Node>| {
        let (label, purity) = majority(&counts, total);
        nodes.push(Node::Leaf { label, count: total, purity });
        nodes.len() - 1
    };
    if counts.len() <= 1 || depth >= cfg.max_depth || total < 2 * cfg.min_leaf.max(1) {
        return make_leaf(nodes);
    }

    // exhaustive threshold search: per feature, candidate thresholds
    // are midpoints between adjacent distinct sorted values
    let parent_gini = gini(&counts, total);
    let mut best: Option<(f64, usize, f64)> = None; // (gain, feature, threshold)
    for f in 0..N_FEATURES {
        let mut sorted = idx.clone();
        sorted.sort_by(|&a, &b| examples[a].features.0[f].total_cmp(&examples[b].features.0[f]));
        let mut left_counts: HashMap<RouteLabel, usize> = HashMap::new();
        for i in 0..total - 1 {
            *left_counts.entry(examples[sorted[i]].label).or_insert(0) += 1;
            let (va, vb) =
                (examples[sorted[i]].features.0[f], examples[sorted[i + 1]].features.0[f]);
            if va == vb {
                continue;
            }
            let nl = i + 1;
            let nr = total - nl;
            if nl < cfg.min_leaf.max(1) || nr < cfg.min_leaf.max(1) {
                continue;
            }
            let mut right_counts = counts.clone();
            for (l, c) in &left_counts {
                let r = right_counts.get_mut(l).expect("left labels ⊆ parent labels");
                *r -= c;
                if *r == 0 {
                    right_counts.remove(l);
                }
            }
            let weighted = (nl as f64 * gini(&left_counts, nl)
                + nr as f64 * gini(&right_counts, nr))
                / total as f64;
            let gain = parent_gini - weighted;
            // strict improvement keeps the first (lowest feature,
            // lowest threshold) of any tie — deterministic training
            if gain > best.map_or(1e-12, |(g, _, _)| g + 1e-12) {
                best = Some((gain, f, (va + vb) / 2.0));
            }
        }
    }
    let Some((_, feature, threshold)) = best else {
        // no feature separates the labels (duplicate points with
        // conflicting winners): an impure leaf, gated by confidence
        return make_leaf(nodes);
    };

    let (left_idx, right_idx): (Vec<usize>, Vec<usize>) =
        idx.iter().partition(|&&i| examples[i].features.0[feature] <= threshold);
    let at = nodes.len();
    // placeholder, patched once the children know their indices
    nodes.push(Node::Split { feature, threshold, left: 0, right: 0 });
    let left = grow(nodes, examples, left_idx, depth + 1, cfg);
    let right = grow(nodes, examples, right_idx, depth + 1, cfg);
    nodes[at] = Node::Split { feature, threshold, left, right };
    at
}

/// A confident in-distribution prediction from the forest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LearnedRoute {
    pub im: Impl,
    pub reorder: Reordering,
    pub dt: usize,
    /// Purity-weighted vote share of the winning label, in (0, 1].
    pub confidence: f64,
}

/// The trained forest plus everything needed to gate its answers:
/// per-feature training ranges (off-distribution detection) and the
/// confidence/support thresholds baked at train time.
#[derive(Debug, Clone, PartialEq)]
pub struct LearnedRouter {
    pub trees: Vec<DecisionTree>,
    /// Per-feature `(min, max)` over the training set.
    pub ranges: Vec<(f64, f64)>,
    /// Training-set size (observability; also persisted).
    pub n_examples: usize,
    pub min_confidence: f64,
    pub min_support: usize,
}

impl LearnedRouter {
    /// Train a forest. Errors (`Error::Usage`) on a training set too
    /// small to ever clear the support gate.
    pub fn train(examples: &[Example], cfg: &TrainConfig) -> Result<LearnedRouter> {
        let n = examples.len();
        if n < cfg.min_support.max(2) {
            return Err(Error::Usage(format!(
                "learned router needs ≥ {} examples, got {n}",
                cfg.min_support.max(2)
            )));
        }
        let mut ranges = vec![(f64::INFINITY, f64::NEG_INFINITY); N_FEATURES];
        for ex in examples {
            for (f, r) in ranges.iter_mut().enumerate() {
                r.0 = r.0.min(ex.features.0[f]);
                r.1 = r.1.max(ex.features.0[f]);
            }
        }
        let mut rng = Prng::new(cfg.seed);
        let full: Vec<usize> = (0..n).collect();
        let mut trees = Vec::with_capacity(cfg.n_trees.max(1));
        trees.push(DecisionTree::fit(examples, &full, cfg));
        for _ in 1..cfg.n_trees.max(1) {
            let sample: Vec<usize> = (0..n).map(|_| rng.below_usize(n)).collect();
            trees.push(DecisionTree::fit(examples, &sample, cfg));
        }
        Ok(LearnedRouter {
            trees,
            ranges,
            n_examples: n,
            min_confidence: cfg.min_confidence,
            min_support: cfg.min_support,
        })
    }

    /// True when every feature lies inside its training range extended
    /// by a 10%-of-span margin — the forest only interpolates; asking
    /// it to extrapolate falls back to the analytic model.
    pub fn in_distribution(&self, x: &FeatureVec) -> bool {
        self.ranges.iter().enumerate().all(|(f, &(lo, hi))| {
            let margin = (0.1 * (hi - lo)).max(1e-9);
            x.0[f] >= lo - margin && x.0[f] <= hi + margin
        })
    }

    /// Predict the winning plan for `x`, or `None` when the forest has
    /// no confident in-distribution answer (the analytic fallback).
    pub fn route(&self, x: &FeatureVec) -> Option<LearnedRoute> {
        if self.ranges.len() != N_FEATURES || !self.in_distribution(x) {
            return None;
        }
        let mut votes: HashMap<RouteLabel, (f64, usize)> = HashMap::new();
        let mut total_weight = 0.0;
        for t in &self.trees {
            let (label, purity, count) = t.route(x)?;
            let v = votes.entry(label).or_insert((0.0, 0));
            v.0 += purity;
            v.1 += count;
            total_weight += purity;
        }
        if total_weight <= 0.0 {
            return None;
        }
        let mut items: Vec<(&RouteLabel, &(f64, usize))> = votes.iter().collect();
        items.sort_by(|a, b| {
            b.1 .0.total_cmp(&a.1 .0).then_with(|| a.0.key().cmp(&b.0.key()))
        });
        let (label, &(weight, support)) = items[0];
        let confidence = weight / total_weight;
        if confidence < self.min_confidence || support < self.min_support {
            return None;
        }
        Some(LearnedRoute {
            im: label.im,
            reorder: label.reorder,
            dt: label.dt,
            confidence,
        })
    }

    /// Structural validation of a restored forest (see
    /// [`DecisionTree::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.trees.is_empty() {
            return Err(Error::Parse("learned router with no trees".into()));
        }
        if self.ranges.len() != N_FEATURES {
            return Err(Error::Parse(format!(
                "learned router carries {} feature ranges (this build has {N_FEATURES})",
                self.ranges.len()
            )));
        }
        if self.n_examples == 0 {
            return Err(Error::Parse("learned router trained on zero examples".into()));
        }
        if !self.min_confidence.is_finite() || !(0.0..=1.0).contains(&self.min_confidence) {
            return Err(Error::Parse("learned router confidence gate out of range".into()));
        }
        for (lo, hi) in &self.ranges {
            if !lo.is_finite() || !hi.is_finite() || lo > hi {
                return Err(Error::Parse("learned router feature range malformed".into()));
            }
        }
        for t in &self.trees {
            t.validate()?;
        }
        Ok(())
    }

    /// One-line human rendering for tables and logs.
    pub fn summary(&self) -> String {
        let nodes: usize = self.trees.iter().map(|t| t.nodes.len()).sum();
        format!(
            "{} trees / {} nodes over {} examples (conf ≥ {:.2}, support ≥ {})",
            self.trees.len(),
            nodes,
            self.n_examples,
            self.min_confidence,
            self.min_support,
        )
    }
}

/// The feature encoding of a classified matrix at dense width `d` —
/// the single definition every caller (tuner, benches, CLI, trainer)
/// shares, so train-time and route-time features cannot drift.
pub fn features_of(cls: &Classification, d: usize) -> FeatureVec {
    let s = &cls.stats;
    FeatureVec::new(
        s.row_len_cv,
        s.hub_mass_1pct,
        s.diag_fraction,
        s.block_diag_fraction,
        s.n,
        s.nnz,
        d,
    )
}

/// Extract training examples from a perf log: every record that
/// carries structural features (`n > 0`), a positive measurement, and
/// a parsable winning plan. Records from pre-feature artifacts, SpGEMM
/// rows (no dense width), and malformed rows are skipped — the trainer
/// never errors on a dirty log, it just learns from less.
pub fn examples_from_log(log: &PerfLog) -> Vec<Example> {
    let mut out = Vec::new();
    for r in &log.records {
        if r.n == 0 || r.d == 0 || r.dt == 0 || !(r.gflops > 0.0) {
            continue;
        }
        let Ok(im) = crate::config::parse_impl(&r.impl_name) else { continue };
        let Ok(reorder) = crate::report::parse_reordering(&r.reorder) else { continue };
        out.push(Example {
            features: FeatureVec::new(r.cv, r.hub, r.diag, r.block, r.n, r.nnz, r.d),
            label: RouteLabel { im, reorder, dt: r.dt },
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lab(im: Impl, reorder: Reordering, dt: usize) -> RouteLabel {
        RouteLabel { im, reorder, dt }
    }

    /// Two well-separated clusters in feature space with distinct
    /// winners, plus a third distinguished by width.
    fn clustered() -> Vec<Example> {
        let mut ex = Vec::new();
        for i in 0..6 {
            // banded-ish: high diag fraction, low cv
            ex.push(Example {
                features: FeatureVec::new(0.2 + 0.01 * i as f64, 0.01, 0.95, 0.6, 4096, 40960, 16),
                label: lab(Impl::Csr, Reordering::Rcm, 16),
            });
            // scale-free-ish: high cv, high hub mass
            ex.push(Example {
                features: FeatureVec::new(2.5 + 0.1 * i as f64, 0.4, 0.05, 0.1, 8192, 131072, 16),
                label: lab(Impl::Pb, Reordering::DegreeSort, 8),
            });
            // same structure as the first cluster, wider: tiles
            ex.push(Example {
                features: FeatureVec::new(0.2 + 0.01 * i as f64, 0.01, 0.95, 0.6, 4096, 40960, 64),
                label: lab(Impl::Csb, Reordering::Rcm, 16),
            });
        }
        ex
    }

    #[test]
    fn forest_reproduces_separable_winners() {
        let ex = clustered();
        let router = LearnedRouter::train(&ex, &TrainConfig::default()).unwrap();
        router.validate().unwrap();
        for e in &ex {
            let r = router.route(&e.features).expect("in-distribution training point");
            assert_eq!((r.im, r.reorder, r.dt), (e.label.im, e.label.reorder, e.label.dt));
            assert!(r.confidence >= 0.65, "confidence {}", r.confidence);
        }
    }

    #[test]
    fn off_distribution_falls_back_to_none() {
        let router = LearnedRouter::train(&clustered(), &TrainConfig::default()).unwrap();
        // cv far beyond anything trained on
        let far = FeatureVec::new(250.0, 0.4, 0.05, 0.1, 8192, 131072, 16);
        assert!(!router.in_distribution(&far));
        assert!(router.route(&far).is_none());
        // n far beyond the trained range
        let huge = FeatureVec::new(0.2, 0.01, 0.95, 0.6, 1 << 30, 1 << 33, 16);
        assert!(router.route(&huge).is_none());
    }

    #[test]
    fn conflicting_labels_fail_the_confidence_gate() {
        // identical features, three different winners: no split can
        // separate them, the leaf is 1/3-pure everywhere
        let f = FeatureVec::new(1.0, 0.1, 0.3, 0.2, 1024, 8192, 16);
        let ex = vec![
            Example { features: f, label: lab(Impl::Csr, Reordering::None, 16) },
            Example { features: f, label: lab(Impl::Opt, Reordering::None, 16) },
            Example { features: f, label: lab(Impl::Csb, Reordering::None, 16) },
        ];
        let router = LearnedRouter::train(&ex, &TrainConfig::default()).unwrap();
        assert!(router.in_distribution(&f));
        assert!(router.route(&f).is_none(), "ambiguous leaf must fall back");
    }

    #[test]
    fn training_is_deterministic() {
        let ex = clustered();
        let a = LearnedRouter::train(&ex, &TrainConfig::default()).unwrap();
        let b = LearnedRouter::train(&ex, &TrainConfig::default()).unwrap();
        assert_eq!(a, b, "same data + same seed must train the same forest");
    }

    #[test]
    fn too_few_examples_error() {
        let ex = clustered();
        assert!(LearnedRouter::train(&ex[..1], &TrainConfig::default()).is_err());
        assert!(LearnedRouter::train(&[], &TrainConfig::default()).is_err());
    }

    #[test]
    fn validate_rejects_structural_garbage() {
        let good = LearnedRouter::train(&clustered(), &TrainConfig::default()).unwrap();
        // child pointing at (or before) its parent
        let mut bad = good.clone();
        if let Node::Split { left, .. } = &mut bad.trees[0].nodes[0] {
            *left = 0;
        }
        assert!(bad.validate().is_err(), "self-referential child must reject");
        // unknown feature index
        let mut bad = good.clone();
        if let Node::Split { feature, .. } = &mut bad.trees[0].nodes[0] {
            *feature = N_FEATURES;
        }
        assert!(bad.validate().is_err());
        // leaf purity out of range
        let mut bad = good.clone();
        for n in bad.trees[0].nodes.iter_mut() {
            if let Node::Leaf { purity, .. } = n {
                *purity = 1.5;
            }
        }
        assert!(bad.validate().is_err());
        // wrong feature-range arity (a snapshot from a different build)
        let mut bad = good.clone();
        bad.ranges.pop();
        assert!(bad.validate().is_err());
        assert!(bad.route(&FeatureVec::zero()).is_none(), "invalid router must not route");
        // empty forest
        let bad = LearnedRouter { trees: Vec::new(), ..good.clone() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn examples_come_only_from_featureful_records() {
        use crate::report::PerfRecord;
        let mut log = PerfLog::new();
        // featureful winner record
        log.push(PerfRecord {
            reorder: "rcm".into(),
            source: "analytic".into(),
            cv: 0.3,
            hub: 0.02,
            diag: 0.9,
            block: 0.5,
            n: 4096,
            nnz: 40000,
            ..PerfRecord::basic("bench_route", "m", "Diagonal", "CSR", 16, 8, 2.5)
        });
        // pre-feature record (n = 0): skipped
        log.push(PerfRecord::basic("bench_route", "old", "Random", "CSR", 16, 16, 1.0));
        // unparsable impl: skipped, not an error
        log.push(PerfRecord {
            n: 64,
            nnz: 256,
            ..PerfRecord::basic("bench_x", "weird", "Random", "WAT", 4, 4, 1.0)
        });
        let ex = examples_from_log(&log);
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].label, lab(Impl::Csr, Reordering::Rcm, 8));
        assert!(ex[0].features.is_present());
    }
}
