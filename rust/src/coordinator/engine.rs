//! The engine: classify → predict → route → execute → learn.

use std::collections::HashSet;

use crate::coordinator::autotune::{
    Autotuner, AutotunePolicy, PipelineDecision, RouteDecision, SpGemmDecision,
};
use crate::coordinator::batch::{BatchReport, BufferPool};
use crate::coordinator::learned::{examples_from_log, LearnedRouter, TrainConfig};
use crate::coordinator::job::{
    JobRecord, JobSpec, PipelineKind, PipelineRecord, PipelineSpec, PredictionReport,
    SpGemmRecord, SpGemmSpec, Workload,
};
use crate::coordinator::planner::Planner;
use crate::coordinator::registry::MatrixRegistry;
use crate::error::{Error, Result};
use crate::gen::Prng;
use crate::membench::{self, MeasuredLadder};
use crate::metrics::{bench_adaptive_checked, gflops, spmm_flops, Timer};
use crate::model::{MachineParams, Roofline, SpGemmParams};
use crate::report::AutotuneState;
use crate::runtime::{ArtifactManifest, XlaRuntime};
use crate::sparse::Csr;
use crate::spgemm::{compression_factor, spgemm_flops, SpGemmImpl};
use crate::spmm::{build_native, Impl, Schedule, Spmm};
use crate::workloads::{
    gcn_chain, gcn_random_inputs, pagerank_chain, power_chain, power_random_input,
    transition_matrix, OpSecs,
};

/// Fixed input seed for exploration measurements: tuning draws the
/// same chain inputs for every candidate (and every process), so the
/// ranking is apples-to-apples and replayable.
const TUNE_SEED: u64 = 0x7e57_c4a1;

/// Engine construction options.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads per kernel execution.
    pub threads: usize,
    /// Calibrate β/π by measurement (`None`) or inject known machine
    /// parameters (tests; avoids a multi-second STREAM run).
    pub machine: Option<MachineParams>,
    /// Timed iterations per job (median reported).
    pub iters: usize,
    /// Warmup iterations per job.
    pub warmup: usize,
    /// Native implementations prepared at registration. Defaults to
    /// the paper trio (CSR/OPT/CSB); ELL and BSR are opt-in — the CLI
    /// wires them through `--impls ELL,BSR` or `--impls all`.
    pub impls: Vec<Impl>,
    /// Attach XLA artifacts from this directory when present.
    pub artifacts_dir: Option<String>,
    /// Structure-adaptive routing policy. Disabled by default: jobs
    /// route on predictions alone (and `force_impl` always wins).
    /// When enabled, the first submission per `(matrix, d)` explores
    /// the candidate space (impl × reordering), pins the measured-best
    /// plan, and may permute the registered matrix in place.
    pub autotune: AutotunePolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            machine: None,
            iters: 3,
            warmup: 1,
            impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
            artifacts_dir: Some("artifacts".into()),
            autotune: AutotunePolicy::default(),
        }
    }
}

/// Outcome of a workload-dispatched submission
/// ([`Engine::submit_workload`]).
#[derive(Debug, Clone)]
pub enum WorkloadOutcome {
    SpMM(JobRecord),
    SpGemm(SpGemmRecord),
    Pipeline(PipelineRecord),
}

/// The computed result of a pipeline submission
/// ([`Engine::submit_pipeline_collect`]).
#[derive(Debug, Clone)]
pub enum PipelineOutput {
    /// Final dense block, row-major `n × d` (GCN output features; the
    /// SpMM block of an SpGEMM+SpMM chain).
    Dense(Vec<f64>),
    /// Final block plus convergence stats of the power iteration.
    Power { block: Vec<f64>, lambda_max: f64, residual: f64 },
    /// PageRank scores (`n × seeds`, row-major) plus convergence.
    PageRank { scores: Vec<f64>, iterations: usize, delta: f64 },
}

impl PipelineOutput {
    /// The dense payload, whichever arm carries it — what the
    /// differential tests compare bitwise.
    pub fn data(&self) -> &[f64] {
        match self {
            PipelineOutput::Dense(d) => d,
            PipelineOutput::Power { block, .. } => block,
            PipelineOutput::PageRank { scores, .. } => scores,
        }
    }
}

/// The roofline-guided SpMM engine (see module docs).
pub struct Engine {
    registry: MatrixRegistry,
    planner: Planner,
    config: EngineConfig,
    xla: Option<(XlaRuntime, ArtifactManifest)>,
    history: Vec<JobRecord>,
    /// SpGEMM records, kept separately — their axes (pair, cf) do not
    /// fit the SpMM record shape.
    spgemm_history: Vec<SpGemmRecord>,
    rng: Prng,
    /// Recycled dense `B`/`C` operands, shared by every submission.
    buffers: BufferPool,
    /// The adaptive router (pinned per-(matrix, d) decisions).
    tuner: Autotuner,
    /// The measured calibration ladder, when one was run or restored —
    /// kept so `export_state` can persist exactly what the planner is
    /// using.
    ladder: Option<MeasuredLadder>,
    /// Pipeline records, kept separately — their axes (chain, per-op
    /// breakdown) do not fit the SpMM record shape.
    pipeline_history: Vec<PipelineRecord>,
    /// Graph names whose derived PageRank operator (`{name}::pr`) is
    /// registered and current; re-registering the graph evicts it.
    pr_derived: HashSet<String>,
}

impl Engine {
    /// Build an engine: calibrates the machine roofline unless one was
    /// injected, and probes the artifact directory for the XLA
    /// backend.
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let machine = match config.machine {
            Some(m) => m,
            None => membench::measure_machine(config.threads),
        };
        let planner = Planner::new(Roofline::new(machine));
        let xla = match &config.artifacts_dir {
            Some(dir) => match ArtifactManifest::load(dir) {
                Ok(manifest) => match XlaRuntime::cpu() {
                    Ok(rt) => Some((rt, manifest)),
                    Err(_) => None,
                },
                Err(_) => None, // artifacts not built — native-only mode
            },
            None => None,
        };
        let tuner = Autotuner::new(config.autotune.clone());
        Ok(Engine {
            registry: MatrixRegistry::new(config.threads),
            planner,
            config,
            xla,
            history: Vec::new(),
            spgemm_history: Vec::new(),
            rng: Prng::new(0x5eed),
            buffers: BufferPool::new(),
            tuner,
            ladder: None,
            pipeline_history: Vec::new(),
            pr_derived: HashSet::new(),
        })
    }

    /// Install a measured calibration ladder: the planner's tiled
    /// roofline switches from the nominal prior to the measured one
    /// ([`Planner::install_measured`]) and the ladder is kept for
    /// [`Engine::export_state`], so a restarted engine re-installs it
    /// instead of re-measuring.
    pub fn install_measured_ladder(&mut self, ml: MeasuredLadder) {
        self.planner.install_measured(ml.to_roofline());
        self.ladder = Some(ml);
    }

    /// Run the full calibration sweep ([`membench::calibrate`]) on this
    /// engine's thread count and install the result. Seconds of
    /// wall-clock — call once, persist via [`Engine::save_state`].
    pub fn calibrate_ladder(&mut self) -> MeasuredLadder {
        let ml = membench::calibrate(self.config.threads);
        self.install_measured_ladder(ml.clone());
        ml
    }

    /// The installed measured ladder, if any.
    pub fn measured_ladder(&self) -> Option<&MeasuredLadder> {
        self.ladder.as_ref()
    }

    /// The machine parameters the roofline uses.
    pub fn machine(&self) -> MachineParams {
        self.planner.roofline().machine
    }

    /// Whether the XLA backend is live.
    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    /// Register a matrix under a name; prepares the configured native
    /// kernels and stages matching XLA artifacts.
    pub fn register(&mut self, name: &str, csr: Csr) -> Result<()> {
        let impls = self.config.impls.clone();
        self.registry.register(name, csr, &impls)?;
        // a re-registered matrix invalidates its routing decisions —
        // its structure may be entirely different — and any derived
        // PageRank operator built from the old structure
        self.tuner.forget(name);
        self.pr_derived.remove(name);
        if let Some((rt, manifest)) = &self.xla {
            // staging failure (no fitting artifact) is not an error
            let _ = self.registry.attach_xla(name, rt, manifest);
        }
        Ok(())
    }

    /// Planner access (reports).
    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// Registry access (reports).
    pub fn registry(&self) -> &MatrixRegistry {
        &self.registry
    }

    /// Execute a job: route to the pinned autotune decision (when
    /// enabled), the predicted-best implementation, or the forced one;
    /// measure; and fold the measurement back into the planner's
    /// priors.
    pub fn submit(&mut self, job: &JobSpec) -> Result<JobRecord> {
        self.submit_inner(job, None).map(|(rec, _)| rec)
    }

    /// [`Engine::submit`] with a deterministic dense operand and the
    /// product returned: `B` is drawn from a job-local PRNG seeded with
    /// `seed` (never the engine's shared stream), so the same
    /// `(matrix, d, seed)` sees the same `B` no matter how jobs
    /// interleave — the property the serve layer's
    /// concurrent-vs-sequential differential test is built on.
    pub fn submit_collect(&mut self, job: &JobSpec, seed: u64) -> Result<(JobRecord, Vec<f64>)> {
        let (rec, out) = self.submit_inner(job, Some(seed))?;
        Ok((rec, out.expect("seeded submission always captures its output")))
    }

    fn submit_inner(
        &mut self,
        job: &JobSpec,
        seed: Option<u64>,
    ) -> Result<(JobRecord, Option<Vec<f64>>)> {
        // adaptive routing first: tuning may permute the stored matrix
        // and rebuild kernels, so it must run before the entry borrow
        let routed: Option<RouteDecision> =
            if self.config.autotune.enabled && job.force_impl.is_none() {
                Some(match self.tuner.decision(&job.matrix, job.d) {
                    Some(dec) => dec.clone(),
                    None => self.tuner.tune(
                        &job.matrix,
                        job.d,
                        &mut self.registry,
                        &self.planner,
                        &mut self.buffers,
                        &mut self.rng,
                    )?,
                })
            } else {
                None
            };
        let entry = self
            .registry
            .get(&job.matrix)
            .ok_or_else(|| Error::Usage(format!("matrix '{}' not registered", job.matrix)))?;
        let cls = entry.classification.clone();
        let reorder = entry.reordering();
        let available = entry.available(job.d);
        if available.is_empty() {
            return Err(Error::Usage(format!(
                "no kernels available for '{}' at d={}",
                job.matrix, job.d
            )));
        }
        let chosen = match (job.force_impl, &routed) {
            (Some(im), _) => {
                if !available.contains(&im) {
                    return Err(Error::Usage(format!(
                        "impl {im} not prepared for '{}' at d={} (have {:?})",
                        job.matrix, job.d, available
                    )));
                }
                self.planner.predict(&cls, job.d, im)
            }
            (None, Some(dec)) => {
                // pinned decision: the registry already stores the
                // winning layout, so predicting the decided impl on the
                // current classification reflects the refined priors
                if !available.contains(&dec.im) {
                    return Err(Error::Usage(format!(
                        "pinned impl {} not prepared for '{}' at d={}",
                        dec.im, job.matrix, job.d
                    )));
                }
                self.planner.predict(&cls, job.d, dec.im)
            }
            (None, None) => self.planner.rank(&cls, job.d, &available)[0],
        };

        let kernel = entry.kernel(chosen.im, job.d).expect("available impl must have kernel");
        // the execution schedule (nnz-balanced partitions + the
        // planner's column tile) is cached per (matrix, impl, threads,
        // d, dt): repeated and batched submissions plan once
        let sched = self
            .registry
            .schedule(&job.matrix, chosen.im, job.d, chosen.dt)
            .expect("kernel was just resolved");
        let n = kernel.ncols();
        // dense operands come from the recycled buffer pool: across a
        // batch (or any repeated submission) each distinct size is
        // allocated once and reused
        let b = match seed {
            // seeded submissions draw B from their own PRNG — identical
            // content for identical (n, d, seed), independent of every
            // other job's draws (the pool hands back cleared storage)
            Some(s) => self.buffers.acquire_random(n, job.d, &mut Prng::new(s)),
            None => self.buffers.acquire_random(n, job.d, &mut self.rng),
        };
        let mut c = self.buffers.acquire(kernel.nrows(), job.d);
        // surface kernel errors before timing (returning the buffers —
        // a failed job must not bleed the pool's largest allocations)
        if let Err(e) = kernel.execute_with(&b, &mut c, &sched) {
            self.buffers.release(b);
            self.buffers.release(c);
            return Err(e);
        }
        // mid-benchmark failures surface as Err too (the buffers still
        // return to the pool, and nothing panics through the workers)
        let r = bench_adaptive_checked(
            self.config.warmup,
            self.config.iters,
            self.config.iters * 4,
            0.2,
            |_| kernel.execute_with(&b, &mut c, &sched),
        );
        // every execution overwrites C in full, so after a successful
        // benchmark it holds exactly A·B — clone it for seeded callers
        // before the storage returns to the pool
        let output = match (&r, seed) {
            (Ok(_), Some(_)) => Some(c.data.clone()),
            _ => None,
        };
        self.buffers.release(b);
        self.buffers.release(c);
        let r = r?;
        let secs = r.median_secs();
        let flops = spmm_flops(kernel.nnz(), job.d);
        let measured = gflops(flops, secs);

        self.planner.observe(cls.class, chosen.im, chosen.roof_gflops, measured);
        let record = JobRecord {
            matrix: job.matrix.clone(),
            class: cls.class,
            d: job.d,
            chosen: chosen.im,
            reorder,
            dt: chosen.dt,
            predicted_gflops: chosen.predicted_gflops,
            ai: chosen.ai,
            secs,
            measured_gflops: measured,
        };
        self.history.push(record.clone());
        Ok((record, output))
    }

    /// Execute an SpGEMM job — the `Workload::SpGemm` arm of the
    /// router ([`crate::coordinator::Workload`]): `C = A·B` with both
    /// operands registered. Routing mirrors [`Engine::submit`]: the
    /// pinned autotune decision per (a, b) pair when enabled, the
    /// predicted-best kernel otherwise, or the forced one; the
    /// measurement feeds the planner's SpGEMM priors, and the record
    /// carries the measured compression factor.
    ///
    /// Both operands execute in their *active* layouts. A reordering
    /// pinned by SpMM tuning changes the product (`P·A·Pᵀ·B` is a
    /// different matrix than `P·(A·B)`), which is why SpGEMM tuning
    /// never enumerates reorderings.
    pub fn submit_spgemm(&mut self, spec: &SpGemmSpec) -> Result<SpGemmRecord> {
        self.submit_spgemm_inner(spec, false).map(|(rec, _)| rec)
    }

    /// [`Engine::submit_spgemm`] returning the product `C = A·B`
    /// alongside the record. SpGEMM has no random operand, so unlike
    /// [`Engine::submit_collect`] no seed is involved — the product is
    /// a pure function of the two registered matrices and the kernel.
    pub fn submit_spgemm_collect(&mut self, spec: &SpGemmSpec) -> Result<(SpGemmRecord, Csr)> {
        let (rec, out) = self.submit_spgemm_inner(spec, true)?;
        Ok((rec, out.expect("capture requested")))
    }

    fn submit_spgemm_inner(
        &mut self,
        spec: &SpGemmSpec,
        capture: bool,
    ) -> Result<(SpGemmRecord, Option<Csr>)> {
        // adaptive routing first: tuning lazily builds kernels through
        // a mutable registry borrow, so it must precede the entry reads
        let routed: Option<SpGemmDecision> =
            if self.config.autotune.enabled && spec.force_impl.is_none() {
                Some(match self.tuner.spgemm_decision(&spec.a, &spec.b) {
                    Some(dec) => dec.clone(),
                    None => self.tuner.tune_spgemm(
                        &spec.a,
                        &spec.b,
                        &mut self.registry,
                        &self.planner,
                    )?,
                })
            } else {
                None
            };
        // resolve the pair and pick the kernel *before* building any:
        // predictions need no kernels, so only the chosen
        // implementation is ever constructed (a forced or pinned job
        // never pays the other kernel's binning time or memory)
        let (cls, params, chosen_im) = {
            let (entry_a, entry_b) = self.registry.spgemm_pair(&spec.a, &spec.b)?;
            let (acsr, bcsr) = (entry_a.csr(), entry_b.csr());
            let cls = entry_a.classification.clone();
            let flops = spgemm_flops(acsr, bcsr);
            let mut params =
                SpGemmParams::new(acsr.nrows, bcsr.nrows, acsr.nnz(), bcsr.nnz(), flops);
            if let Some(dec) = &routed {
                // the pinned decision carries the pair's measured cf —
                // predict at it rather than the conservative floor
                params = params.with_cf(dec.cf);
            }
            let chosen_im = match (spec.force_impl, &routed) {
                (Some(im), _) => im,
                (None, Some(dec)) => dec.im,
                (None, None) => self.planner.rank_spgemm(&cls, params)[0].im,
            };
            (cls, params, chosen_im)
        };
        self.registry.ensure_spgemm(&spec.a, chosen_im)?;
        let entry_a = self.registry.get(&spec.a).expect("resolved above");
        let bcsr = self.registry.get(&spec.b).expect("resolved above").csr();
        let pred = self.planner.predict_spgemm(&cls, params, chosen_im);
        let kernel = entry_a.spgemm_kernel(chosen_im).expect("ensured above");
        let sched = kernel.plan();
        // first execution surfaces kernel errors before the timing
        // loop and yields nnz(C) for the measured compression factor
        let c = kernel.execute_with(bcsr, &sched)?;
        let nnz_c = c.nnz();
        let captured = if capture {
            Some(c)
        } else {
            drop(c);
            None
        };
        // the timed region includes output allocation — SpGEMM's
        // output is data-dependent, so allocation is part of the work
        let r = bench_adaptive_checked(
            self.config.warmup,
            self.config.iters,
            self.config.iters * 4,
            0.2,
            |_| kernel.execute_with(bcsr, &sched).map(|_| ()),
        )?;
        let secs = r.median_secs();
        let measured = gflops(params.flops, secs);
        self.planner.observe_spgemm(cls.class, chosen_im, pred.roof_gflops, measured);
        let record = SpGemmRecord {
            a: spec.a.clone(),
            b: spec.b.clone(),
            class: cls.class,
            chosen: chosen_im,
            flops: params.flops,
            nnz_c,
            cf: compression_factor(params.flops, nnz_c),
            predicted_gflops: pred.predicted_gflops,
            ai: pred.ai,
            secs,
            measured_gflops: measured,
        };
        self.spgemm_history.push(record.clone());
        Ok((record, captured))
    }

    /// Execute a multi-op pipeline: route the whole chain to one
    /// implementation (the pinned whole-chain decision when autotune
    /// is on, the pipeline-model-best otherwise, or the forced one),
    /// run it over **one** cached schedule with pooled ping-pong
    /// intermediates, measure it end-to-end, and fold the measurement
    /// back into the planner's priors at the chain roof.
    ///
    /// The chain executes exactly the shared cores in
    /// [`crate::workloads`] — the same code the standalone wrappers
    /// run — over the registry's cached untiled schedule (`dt = d`,
    /// which is what `kernel.plan(None)` builds), so an engine-routed
    /// chain is bitwise-identical to its standalone counterpart.
    pub fn submit_pipeline(&mut self, spec: &PipelineSpec) -> Result<PipelineRecord> {
        self.submit_pipeline_inner(spec, None).map(|(rec, _)| rec)
    }

    /// [`Engine::submit_pipeline`] with deterministic dense inputs and
    /// the chain's result returned: inputs are drawn from a job-local
    /// PRNG seeded with `seed` via the shared generators
    /// ([`crate::workloads::gcn_random_inputs`] and friends), so the
    /// same `(matrix, kind, seed)` computes the same answer no matter
    /// how jobs interleave.
    pub fn submit_pipeline_collect(
        &mut self,
        spec: &PipelineSpec,
        seed: u64,
    ) -> Result<(PipelineRecord, PipelineOutput)> {
        self.submit_pipeline_inner(spec, Some(seed))
    }

    fn submit_pipeline_inner(
        &mut self,
        spec: &PipelineSpec,
        seed: Option<u64>,
    ) -> Result<(PipelineRecord, PipelineOutput)> {
        if let PipelineKind::SpGemmSpMM { b, d } = &spec.kind {
            let (b, d) = (b.clone(), *d);
            return self.submit_chain_spgemm_spmm(spec, &b, d, seed);
        }
        let chain_key = spec.workload().to_string();
        let d = spec.kind.d();
        // derived-operator resolution: PageRank runs over the
        // transition matrix of the *registered* graph (scores are
        // indexed by the caller's row ids)
        let (exec_name, dangling) = match &spec.kind {
            PipelineKind::PageRank { .. } => self.ensure_pagerank_operator(&spec.matrix)?,
            _ => {
                if self.registry.get(&spec.matrix).is_none() {
                    return Err(Error::Usage(format!(
                        "matrix '{}' not registered",
                        spec.matrix
                    )));
                }
                (spec.matrix.clone(), Vec::new())
            }
        };
        let entry = self.registry.get(&exec_name).expect("resolved above");
        let cls = entry.classification.clone();
        let reorder = entry.reordering();
        let (n, nnz) = (entry.n(), entry.nnz());
        // chained widths vary mid-pipeline (GCN), so only the
        // width-agnostic native kernels are candidates
        let candidates: Vec<Impl> =
            entry.available(d).into_iter().filter(|&im| im != Impl::Xla).collect();
        if candidates.is_empty() {
            return Err(Error::Usage(format!(
                "no native kernels available for '{exec_name}' at d={d}"
            )));
        }
        let pp = spec.kind.pipeline_params(n, nnz, spec.kind.ops());

        // adaptive routing: serve (or tune) the whole-chain pin; the
        // measure closure runs the *full* chain per candidate, so the
        // decision optimizes the pipeline, not its hottest op
        let routed: Option<PipelineDecision> =
            if self.config.autotune.enabled && spec.force_impl.is_none() {
                let kind = &spec.kind;
                let registry = &self.registry;
                let buffers = &mut self.buffers;
                let explore_iters = self.config.autotune.explore_iters;
                let dang = &dangling;
                let exec = exec_name.as_str();
                let mut measure = |im: Impl| -> Result<f64> {
                    let kernel = registry
                        .get(exec)
                        .expect("resolved above")
                        .kernel(im, d)
                        .ok_or_else(|| Error::Usage(format!("kernel {im} vanished")))?;
                    let sched =
                        registry.schedule(exec, im, d, d).expect("kernel exists");
                    let (secs, _, ops, _) = measure_chain(0, explore_iters, || {
                        run_chain(kind, kernel, &sched, dang, TUNE_SEED, buffers)
                    })?;
                    Ok(gflops(kind.pipeline_params(n, nnz, ops).flops(), secs))
                };
                Some(self.tuner.tune_pipeline(
                    &spec.matrix,
                    &chain_key,
                    d,
                    &cls,
                    pp,
                    &candidates,
                    reorder,
                    &self.planner,
                    &mut measure,
                )?)
            } else {
                None
            };

        let chosen_im = match (spec.force_impl, &routed) {
            (Some(im), _) => {
                if !candidates.contains(&im) {
                    return Err(Error::Usage(format!(
                        "impl {im} not prepared for '{exec_name}' (native chain \
                         candidates: {candidates:?})"
                    )));
                }
                im
            }
            (None, Some(dec)) => {
                if !candidates.contains(&dec.im) {
                    return Err(Error::Usage(format!(
                        "pinned impl {} not prepared for '{exec_name}'",
                        dec.im
                    )));
                }
                dec.im
            }
            (None, None) => self.planner.rank_pipeline(&cls, pp, &candidates)[0].im,
        };
        let prediction = self.planner.predict_pipeline(&cls, pp, chosen_im);

        let kernel = self
            .registry
            .get(&exec_name)
            .expect("resolved above")
            .kernel(chosen_im, d)
            .expect("candidate impl has a kernel");
        // ONE schedule for the whole chain, served from the registry
        // cache; dt = d plans untiled — the width-independent plan
        // every chained op shares, and the one `kernel.plan(None)`
        // (the standalone wrappers' schedule) builds, which is what
        // keeps both paths bitwise-identical
        let sched = self
            .registry
            .schedule(&exec_name, chosen_im, d, d)
            .expect("kernel was just resolved");
        let input_seed = match seed {
            Some(s) => s,
            None => self.rng.next_u64(),
        };
        let kind = &spec.kind;
        let dang = &dangling;
        let buffers = &mut self.buffers;
        let (secs, per_op, ops, output) =
            measure_chain(self.config.warmup, self.config.iters, || {
                run_chain(kind, kernel, &sched, dang, input_seed, buffers)
            })?;
        let flops = spec.kind.pipeline_params(n, nnz, ops).flops();
        let measured = gflops(flops, secs);
        self.planner.observe(cls.class, chosen_im, prediction.roof_gflops, measured);
        let record = PipelineRecord {
            matrix: spec.matrix.clone(),
            class: cls.class,
            chain: chain_key,
            chosen: chosen_im,
            reorder,
            dt: prediction.dt,
            ops,
            resident: prediction.resident,
            predicted_gflops: prediction.predicted_gflops,
            ai: prediction.ai,
            secs,
            measured_gflops: measured,
            per_op,
        };
        self.pipeline_history.push(record.clone());
        Ok((record, output))
    }

    /// Resolve (and lazily register) the derived PageRank operator for
    /// `graph`: the column-stochastic transition matrix of the
    /// **registered** graph under the scoped name `{graph}::pr`, plus
    /// the dangling-row mask. Derived from the base (unreordered)
    /// matrix — a reordering pinned on the graph by SpMM tuning must
    /// not leak into user-visible score indices. The operator entry
    /// gets the engine's full kernel preparation, so chained
    /// submissions serve its kernels and schedules from cache.
    fn ensure_pagerank_operator(&mut self, graph: &str) -> Result<(String, Vec<bool>)> {
        let derived = format!("{graph}::pr");
        let (fresh, dangling) = {
            let entry = self
                .registry
                .get(graph)
                .ok_or_else(|| Error::Usage(format!("matrix '{graph}' not registered")))?;
            let base = entry.base_csr();
            let dangling: Vec<bool> =
                (0..base.nrows).map(|r| base.row_len(r) == 0).collect();
            if self.pr_derived.contains(graph) && self.registry.get(&derived).is_some() {
                (None, dangling)
            } else {
                let (m, _) = transition_matrix(base)?;
                (Some(m), dangling)
            }
        };
        if let Some(m) = fresh {
            self.register(&derived, m)?;
            self.pr_derived.insert(graph.to_string());
        }
        Ok((derived, dangling))
    }

    /// The SpGEMM→SpMM chain: `C = A·B` through the registry's
    /// prepared Hash kernel (every SpGEMM kernel agrees bitwise — see
    /// [`crate::spgemm`]), then the routed SpMM of the data-dependent
    /// product against a seeded dense block. The SpMM leg's kernel is
    /// built on the product per submission — the product is not a
    /// registered matrix — so candidates are the engine's configured
    /// native impls, ranked on the chain model with `nnz(A)` standing
    /// in for the unknown `nnz(C)`.
    fn submit_chain_spgemm_spmm(
        &mut self,
        spec: &PipelineSpec,
        bname: &str,
        d: usize,
        seed: Option<u64>,
    ) -> Result<(PipelineRecord, PipelineOutput)> {
        let chain_key = spec.workload().to_string();
        self.registry.ensure_spgemm(&spec.matrix, SpGemmImpl::Hash)?;
        let (entry_a, entry_b) = self.registry.spgemm_pair(&spec.matrix, bname)?;
        let cls = entry_a.classification.clone();
        let reorder = entry_a.reordering();
        let (n, nnz) = (entry_a.n(), entry_a.nnz());
        let spgemm_leg_flops = spgemm_flops(entry_a.csr(), entry_b.csr());
        let pp = spec.kind.pipeline_params(n, nnz, 1);
        let candidates: Vec<Impl> =
            self.config.impls.iter().copied().filter(|&im| im != Impl::Xla).collect();
        if candidates.is_empty() {
            return Err(Error::Usage("no native impls configured".into()));
        }

        // SpGEMM leg once — timed, and its product feeds every SpMM
        // candidate (the leg is impl-independent, so ranking by the
        // SpMM leg ranks the whole chain)
        let threads = self.config.threads;
        let (product, spgemm_secs) = {
            let entry_a = self.registry.get(&spec.matrix).expect("resolved above");
            let bcsr = self.registry.get(bname).expect("resolved above").csr();
            let gk = entry_a.spgemm_kernel(SpGemmImpl::Hash).expect("ensured above");
            let gsched = gk.plan();
            let t = Timer::start();
            let c = gk.execute_with(bcsr, &gsched)?;
            (c, t.elapsed_secs())
        };
        let spmm_leg_flops = spmm_flops(product.nnz(), d);

        let routed: Option<PipelineDecision> =
            if self.config.autotune.enabled && spec.force_impl.is_none() {
                let buffers = &mut self.buffers;
                let explore_iters = self.config.autotune.explore_iters;
                let productr = &product;
                let mut measure = |im: Impl| -> Result<f64> {
                    let kernel = build_native(im, productr, threads)?;
                    let sched = kernel.plan(None);
                    let b =
                        buffers.acquire_random(kernel.ncols(), d, &mut Prng::new(TUNE_SEED));
                    let mut c = buffers.acquire(kernel.nrows(), d);
                    let gf = (|| -> Result<f64> {
                        kernel.execute_with(&b, &mut c, &sched)?;
                        let iters = explore_iters.max(1);
                        let r = bench_adaptive_checked(0, iters, iters * 4, 0.0, |_| {
                            kernel.execute_with(&b, &mut c, &sched)
                        })?;
                        Ok(gflops(spmm_leg_flops, r.median_secs()))
                    })();
                    buffers.release(b);
                    buffers.release(c);
                    gf
                };
                Some(self.tuner.tune_pipeline(
                    &spec.matrix,
                    &chain_key,
                    d,
                    &cls,
                    pp,
                    &candidates,
                    reorder,
                    &self.planner,
                    &mut measure,
                )?)
            } else {
                None
            };

        let chosen_im = match (spec.force_impl, &routed) {
            (Some(im), _) => {
                if im == Impl::Xla {
                    return Err(Error::Usage(
                        "SpGEMM+SpMM chains route native SpMM kernels only".into(),
                    ));
                }
                im
            }
            (None, Some(dec)) => dec.im,
            (None, None) => self.planner.rank_pipeline(&cls, pp, &candidates)[0].im,
        };
        let prediction = self.planner.predict_pipeline(&cls, pp, chosen_im);

        // SpMM leg on the product with the chosen impl
        let kernel = build_native(chosen_im, &product, threads)?;
        let sched = kernel.plan(None);
        let input_seed = match seed {
            Some(s) => s,
            None => self.rng.next_u64(),
        };
        let b = self.buffers.acquire_random(kernel.ncols(), d, &mut Prng::new(input_seed));
        let mut c = self.buffers.acquire(kernel.nrows(), d);
        if let Err(e) = kernel.execute_with(&b, &mut c, &sched) {
            self.buffers.release(b);
            self.buffers.release(c);
            return Err(e);
        }
        let r = bench_adaptive_checked(
            self.config.warmup,
            self.config.iters,
            self.config.iters * 4,
            0.2,
            |_| kernel.execute_with(&b, &mut c, &sched),
        );
        let output = match &r {
            Ok(_) => Some(c.data.clone()),
            Err(_) => None,
        };
        self.buffers.release(b);
        self.buffers.release(c);
        let r = r?;
        let spmm_secs = r.median_secs();
        let secs = spgemm_secs + spmm_secs;
        let flops = spgemm_leg_flops + spmm_leg_flops;
        let measured = gflops(flops, secs);
        self.planner.observe(cls.class, chosen_im, prediction.roof_gflops, measured);
        let record = PipelineRecord {
            matrix: spec.matrix.clone(),
            class: cls.class,
            chain: chain_key,
            chosen: chosen_im,
            reorder,
            dt: prediction.dt,
            ops: 1,
            resident: prediction.resident,
            predicted_gflops: prediction.predicted_gflops,
            ai: prediction.ai,
            secs,
            measured_gflops: measured,
            per_op: vec![
                OpSecs { op: "spgemm", secs: spgemm_secs },
                OpSecs { op: "spmm", secs: spmm_secs },
            ],
        };
        self.pipeline_history.push(record.clone());
        Ok((record, PipelineOutput::Dense(output.expect("benchmark succeeded"))))
    }

    /// Every pipeline record executed so far.
    pub fn pipeline_history(&self) -> &[PipelineRecord] {
        &self.pipeline_history
    }

    /// Dispatch on the [`Workload`] dimension: `SpMM` jobs go through
    /// [`Engine::submit`], `SpGemm` jobs through
    /// [`Engine::submit_spgemm`], and the pipeline workloads through
    /// [`Engine::submit_pipeline`] — the single entry point for
    /// callers holding a `(matrix, workload)` pair rather than a
    /// concrete spec. The pipeline workloads use canonical chain
    /// parameters (uniform GCN widths, PageRank seeds `0..k` at
    /// `α = 0.85`, `tol = 1e-9`); callers wanting full control build a
    /// [`PipelineSpec`] directly.
    pub fn submit_workload(&mut self, matrix: &str, w: &Workload) -> Result<WorkloadOutcome> {
        match w {
            Workload::SpMM { d } => {
                Ok(WorkloadOutcome::SpMM(self.submit(&JobSpec::new(matrix, *d))?))
            }
            Workload::SpGemm { b } => Ok(WorkloadOutcome::SpGemm(
                self.submit_spgemm(&SpGemmSpec::new(matrix, b.clone()))?,
            )),
            Workload::GcnLayer { layers, d } => {
                Ok(WorkloadOutcome::Pipeline(self.submit_pipeline(&PipelineSpec::new(
                    matrix,
                    PipelineKind::Gcn { dims: vec![*d; layers + 1] },
                ))?))
            }
            Workload::PowerIteration { d, iters } => {
                Ok(WorkloadOutcome::Pipeline(self.submit_pipeline(&PipelineSpec::new(
                    matrix,
                    PipelineKind::PowerIteration { d: *d, iters: *iters },
                ))?))
            }
            Workload::BatchedPageRank { seeds, iters } => {
                Ok(WorkloadOutcome::Pipeline(self.submit_pipeline(&PipelineSpec::new(
                    matrix,
                    PipelineKind::PageRank {
                        seeds: (0..*seeds).collect(),
                        alpha: 0.85,
                        tol: 1e-9,
                        iters: *iters,
                    },
                ))?))
            }
            Workload::SpGemmSpMM { b, d } => {
                Ok(WorkloadOutcome::Pipeline(self.submit_pipeline(&PipelineSpec::new(
                    matrix,
                    PipelineKind::SpGemmSpMM { b: b.clone(), d: *d },
                ))?))
            }
        }
    }

    /// Eagerly tune one SpGEMM pair (normally tuning happens lazily on
    /// first submission). Returns the pinned decision.
    pub fn tune_spgemm(&mut self, a: &str, b: &str) -> Result<SpGemmDecision> {
        self.tuner.tune_spgemm(a, b, &mut self.registry, &self.planner)
    }

    /// Every SpGEMM record executed so far.
    pub fn spgemm_history(&self) -> &[SpGemmRecord] {
        &self.spgemm_history
    }

    /// Run a batch of jobs in order, stopping at the first hard error.
    pub fn run_batch(&mut self, jobs: &[JobSpec]) -> Result<Vec<JobRecord>> {
        jobs.iter().map(|j| self.submit(j)).collect()
    }

    /// Execute a queue of jobs as one batch: classify → predict →
    /// route each job exactly as [`Engine::submit`] does, but with the
    /// persistent worker pool and the recycled dense buffers staying
    /// warm across the whole queue. Returns the per-batch aggregate
    /// report (throughput, model error, buffer reuse); per-job records
    /// are also appended to [`Engine::history`] as usual. Stops at the
    /// first hard error.
    pub fn submit_batch(&mut self, jobs: &[JobSpec]) -> Result<BatchReport> {
        let t = Timer::start();
        let (hits0, misses0) = (self.buffers.hits, self.buffers.misses);
        let (shits0, smisses0) = self.registry.schedule_cache_stats();
        let explore0 = self.tuner.measurements();
        let records = self.run_batch(jobs)?;
        let (shits, smisses) = self.registry.schedule_cache_stats();
        // routing context: the decision in force for each distinct
        // (matrix, d) the batch actually routed — forced-impl jobs
        // bypass the router and must not claim its decisions
        let mut routes: Vec<RouteDecision> = Vec::new();
        for job in jobs.iter().filter(|j| j.force_impl.is_none()) {
            if let Some(dec) = self.tuner.decision(&job.matrix, job.d) {
                if !routes.iter().any(|r| r.matrix == dec.matrix && r.d == dec.d) {
                    routes.push(dec.clone());
                }
            }
        }
        Ok(BatchReport::of(
            records,
            t.elapsed_secs(),
            self.buffers.hits - hits0,
            self.buffers.misses - misses0,
            shits - shits0,
            smisses - smisses0,
        )
        .with_routing(routes, self.tuner.measurements() - explore0))
    }

    /// [`Engine::submit_batch`] over seeded jobs, returning each job's
    /// product alongside the aggregate report — what the serve layer's
    /// batch coalescing runs, so a coalesced group keeps per-job
    /// outputs to hand back through the tickets.
    pub fn submit_batch_collect(
        &mut self,
        jobs: &[(JobSpec, u64)],
    ) -> Result<(BatchReport, Vec<Vec<f64>>)> {
        let t = Timer::start();
        let (hits0, misses0) = (self.buffers.hits, self.buffers.misses);
        let (shits0, smisses0) = self.registry.schedule_cache_stats();
        let explore0 = self.tuner.measurements();
        let mut records = Vec::with_capacity(jobs.len());
        let mut outputs = Vec::with_capacity(jobs.len());
        for (job, seed) in jobs {
            let (rec, out) = self.submit_collect(job, *seed)?;
            records.push(rec);
            outputs.push(out);
        }
        let (shits, smisses) = self.registry.schedule_cache_stats();
        let mut routes: Vec<RouteDecision> = Vec::new();
        for (job, _) in jobs.iter().filter(|(j, _)| j.force_impl.is_none()) {
            if let Some(dec) = self.tuner.decision(&job.matrix, job.d) {
                if !routes.iter().any(|r| r.matrix == dec.matrix && r.d == dec.d) {
                    routes.push(dec.clone());
                }
            }
        }
        let rep = BatchReport::of(
            records,
            t.elapsed_secs(),
            self.buffers.hits - hits0,
            self.buffers.misses - misses0,
            shits - shits0,
            smisses - smisses0,
        )
        .with_routing(routes, self.tuner.measurements() - explore0);
        Ok((rep, outputs))
    }

    /// Register a matrix inside a tenant's namespace (the serve
    /// layer's multi-tenant entry point) — equivalent to
    /// [`Engine::register`] under the scoped key
    /// [`MatrixRegistry::scoped`]`(tenant, name)`.
    pub fn register_for(&mut self, tenant: &str, name: &str, csr: Csr) -> Result<()> {
        self.register(&MatrixRegistry::scoped(tenant, name), csr)
    }

    /// Install a caller-built kernel for a registered matrix — the
    /// fault-injection / instrumentation seam
    /// ([`MatrixRegistry::install_kernel`]).
    pub fn install_kernel(
        &mut self,
        name: &str,
        im: Impl,
        k: Box<dyn crate::spmm::Spmm>,
    ) -> Result<()> {
        self.registry.install_kernel(name, im, k)
    }

    /// Snapshot everything the router learned: pinned SpMM/SpGEMM
    /// decisions, the planner's materialised priors, the measured
    /// calibration ladder, and the trained learned router (when
    /// installed).
    pub fn export_state(&self) -> AutotuneState {
        AutotuneState {
            routes: self.tuner.decisions().into_iter().cloned().collect(),
            spgemm: self.tuner.spgemm_decisions().into_iter().cloned().collect(),
            pipelines: self.tuner.pipeline_decisions().into_iter().cloned().collect(),
            spmm_priors: self.planner.priors_snapshot(),
            spgemm_priors: self.planner.spgemm_priors_snapshot(),
            ladder: self.ladder.clone(),
            learned: self.tuner.learned().cloned(),
        }
    }

    /// Re-adopt a snapshot: priors are restored wholesale; each pinned
    /// decision is adopted when its matrices are registered (its
    /// reordering is re-applied so the stored layout matches what the
    /// decision measured), and silently skipped otherwise — a snapshot
    /// may mention matrices this process never registered. Call
    /// **after** registering (registration forgets a name's
    /// decisions). Returns how many decisions were adopted; adopted
    /// decisions serve with zero new exploration measurements.
    pub fn restore_state(&mut self, state: &AutotuneState) -> usize {
        // the measured ladder restores first: it is machine state, not
        // matrix state, so it applies regardless of what is registered
        // — and skipping the re-measurement is the whole point
        if let Some(ml) = &state.ladder {
            self.install_measured_ladder(ml.clone());
        }
        // likewise the trained forest: learned routing knowledge, not
        // matrix state — a restored engine routes learned-vs-analytic
        // without retraining (the snapshot parser already validated it)
        if let Some(lr) = &state.learned {
            self.tuner.install_learned(lr.clone());
        }
        for &(c, i, v) in &state.spmm_priors {
            self.planner.set_prior(c, i, v);
        }
        for &(c, i, v) in &state.spgemm_priors {
            self.planner.set_spgemm_prior(c, i, v);
        }
        let mut adopted = 0;
        for dec in &state.routes {
            if self.registry.get(&dec.matrix).is_none() {
                continue;
            }
            if self.registry.apply_reordering(&dec.matrix, dec.reorder).is_err() {
                continue;
            }
            self.tuner.adopt(dec.clone());
            adopted += 1;
        }
        for dec in &state.spgemm {
            if self.registry.get(&dec.a).is_none() || self.registry.get(&dec.b).is_none() {
                continue;
            }
            self.tuner.adopt_spgemm(dec.clone());
            adopted += 1;
        }
        // pipeline pins adopt only when the matrix's *current* layout
        // matches the one the pin measured: pipelines never reorder
        // (chain outputs are row-indexed user data), so a pin must not
        // fight a route decision that restored a different layout —
        // routes restore above, then compatible pipeline pins follow
        for dec in &state.pipelines {
            match self.registry.get(&dec.matrix) {
                Some(e) if e.reordering() == dec.reorder => {
                    self.tuner.adopt_pipeline(dec.clone());
                    adopted += 1;
                }
                _ => {}
            }
        }
        adopted
    }

    /// Persist the current autotune state atomically
    /// ([`AutotuneState::save`]).
    pub fn save_state(&self, path: &str) -> Result<()> {
        self.export_state().save(path)
    }

    /// Load and adopt a persisted snapshot; `false` is a cold start
    /// (missing or — with a warning — corrupted file).
    pub fn load_state(&mut self, path: &str) -> bool {
        match AutotuneState::load_or_cold(path) {
            Some(state) => {
                self.restore_state(&state);
                true
            }
            None => false,
        }
    }

    /// The engine's dense-operand buffer pool (reuse statistics).
    pub fn buffer_pool(&self) -> &BufferPool {
        &self.buffers
    }

    /// The adaptive router (pinned decisions, exploration counters).
    pub fn autotuner(&self) -> &Autotuner {
        &self.tuner
    }

    /// Install a trained learned router: future tunes consult the
    /// forest first and fall back to the analytic model off
    /// distribution (see [`crate::coordinator::LearnedRouter`]).
    pub fn install_learned_router(&mut self, router: LearnedRouter) {
        self.tuner.install_learned(router);
    }

    /// The installed learned router, if any.
    pub fn learned_router(&self) -> Option<&LearnedRouter> {
        self.tuner.learned()
    }

    /// Train a learned router from an accumulated perf log
    /// (`BENCH_route.json` records carry the winning plan *and* the
    /// structural features it was chosen on) and install it. Returns
    /// how many usable examples the log yielded; errors
    /// (`Error::Usage`) when the log holds too few featureful records
    /// to train on.
    pub fn train_learned_router(
        &mut self,
        log: &crate::report::PerfLog,
        cfg: &TrainConfig,
    ) -> Result<usize> {
        let examples = examples_from_log(log);
        let router = LearnedRouter::train(&examples, cfg)?;
        self.tuner.install_learned(router);
        Ok(examples.len())
    }

    /// Eagerly tune one `(matrix, d)` (normally tuning happens lazily
    /// on first submission). Returns the pinned decision.
    pub fn tune(&mut self, matrix: &str, d: usize) -> Result<RouteDecision> {
        self.tuner.tune(
            matrix,
            d,
            &mut self.registry,
            &self.planner,
            &mut self.buffers,
            &mut self.rng,
        )
    }

    /// Every record executed so far.
    pub fn history(&self) -> &[JobRecord] {
        &self.history
    }

    /// Prediction-accuracy summary, including the routing hit rate
    /// over (matrix, d) groups where multiple impls were measured.
    pub fn prediction_report(&self) -> PredictionReport {
        let mut rep = PredictionReport::of(&self.history);
        // routing hit rate: for groups with >1 impls, did the planner's
        // choice (first non-forced record) match the measured best?
        use std::collections::HashMap;
        let mut groups: HashMap<(String, usize), Vec<&JobRecord>> = HashMap::new();
        for r in &self.history {
            groups.entry((r.matrix.clone(), r.d)).or_default().push(r);
        }
        let mut hits = 0usize;
        let mut total = 0usize;
        for (_, rs) in groups {
            if rs.len() < 2 {
                continue;
            }
            let best = rs
                .iter()
                .max_by(|a, b| a.measured_gflops.total_cmp(&b.measured_gflops))
                .unwrap();
            // what would the planner pick now?
            let impls: Vec<Impl> = rs.iter().map(|r| r.chosen).collect();
            if let Some(entry) = self.registry.get(&best.matrix) {
                let pick = self.planner.rank(&entry.classification, best.d, &impls)[0].im;
                total += 1;
                if pick == best.chosen {
                    hits += 1;
                }
            }
        }
        if total > 0 {
            rep.routing_hit_rate = Some(hits as f64 / total as f64);
        }
        rep
    }
}

/// Execute one full chain through the shared workload cores: inputs
/// come from the shared seeded generators (the same ones standalone
/// callers and tests use, so identical seeds mean identical answers),
/// intermediates ping-pong through `pool`, and the chain's dense
/// output is copied out and its storage released back to the pool so
/// repeated timing-loop runs are pool hits. Returns
/// `(per_op timings, executed op count, output)` — the op count is
/// runtime-resolved for iterative chains (PageRank converges early).
fn run_chain(
    kind: &PipelineKind,
    kernel: &dyn Spmm,
    sched: &Schedule,
    dangling: &[bool],
    seed: u64,
    pool: &mut BufferPool,
) -> Result<(Vec<OpSecs>, usize, PipelineOutput)> {
    match kind {
        PipelineKind::Gcn { dims } => {
            let (h0, layers) = gcn_random_inputs(kernel.ncols(), dims, seed);
            let (out, per_op) = gcn_chain(kernel, sched, &h0, &layers, pool)?;
            let ops = layers.len();
            let data = out.data.clone();
            pool.release(out);
            Ok((per_op, ops, PipelineOutput::Dense(data)))
        }
        PipelineKind::PowerIteration { d, iters } => {
            let x0 = power_random_input(kernel.ncols(), *d, seed);
            let (out, stats, per_op) = power_chain(kernel, sched, &x0, *iters, pool)?;
            let ops = stats.iters;
            let block = out.data.clone();
            pool.release(out);
            Ok((
                per_op,
                ops,
                PipelineOutput::Power {
                    block,
                    lambda_max: stats.lambda_max,
                    residual: stats.residual,
                },
            ))
        }
        PipelineKind::PageRank { seeds, alpha, tol, iters } => {
            let (r, per_op) =
                pagerank_chain(kernel, sched, dangling, seeds, *alpha, *tol, *iters, pool)?;
            let ops = r.iterations;
            let scores = r.scores.data.clone();
            pool.release(r.scores);
            Ok((
                per_op,
                ops,
                PipelineOutput::PageRank { scores, iterations: r.iterations, delta: r.delta },
            ))
        }
        PipelineKind::SpGemmSpMM { .. } => Err(Error::Usage(
            "SpGEMM+SpMM chains run through their own path".into(),
        )),
    }
}

/// Time a chain end-to-end: `warmup` unrecorded runs, then
/// `iters.max(1)` timed runs, reporting the median wall-clock and the
/// per-op breakdown / op count / output of the **last** run (every
/// run computes the same answer — inputs are re-drawn from the same
/// seed each time).
fn measure_chain<F>(
    warmup: usize,
    iters: usize,
    mut f: F,
) -> Result<(f64, Vec<OpSecs>, usize, PipelineOutput)>
where
    F: FnMut() -> Result<(Vec<OpSecs>, usize, PipelineOutput)>,
{
    for _ in 0..warmup {
        f()?;
    }
    let mut times = Vec::new();
    let mut last = None;
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        let out = f()?;
        times.push(t.elapsed_secs());
        last = Some(out);
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let (per_op, ops, output) = last.expect("at least one timed run");
    Ok((times[times.len() / 2], per_op, ops, output))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, mesh2d, MeshKind, Prng};
    use crate::spgemm::SpGemmImpl;
    use crate::workloads::{batched_pagerank, block_power_iteration, gcn_forward};

    fn test_engine() -> Engine {
        test_engine_with(AutotunePolicy::default())
    }

    fn test_engine_with(autotune: AutotunePolicy) -> Engine {
        Engine::new(EngineConfig {
            threads: 2,
            machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
            iters: 2,
            warmup: 0,
            impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
            artifacts_dir: None,
            autotune,
        })
        .unwrap()
    }

    fn quick_autotune() -> AutotunePolicy {
        AutotunePolicy { explore_iters: 1, explore_min_secs: 0.0, ..AutotunePolicy::enabled() }
    }

    #[test]
    fn submit_routes_and_measures() {
        let mut e = test_engine();
        let a = erdos_renyi(500, 500, 6.0, &mut Prng::new(180));
        e.register("er", a).unwrap();
        let rec = e.submit(&JobSpec::new("er", 8)).unwrap();
        assert!(rec.measured_gflops > 0.0);
        assert!(rec.ai > 0.0);
        assert_eq!(rec.matrix, "er");
        assert_eq!(e.history().len(), 1);
    }

    #[test]
    fn forced_impl_respected() {
        let mut e = test_engine();
        let a = mesh2d(32, MeshKind::Road, 0.6, &mut Prng::new(181));
        e.register("mesh", a).unwrap();
        for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
            let rec = e.submit(&JobSpec::new("mesh", 4).with_impl(im)).unwrap();
            assert_eq!(rec.chosen, im);
        }
        let rep = e.prediction_report();
        assert_eq!(rep.n_jobs, 3);
        assert!(rep.routing_hit_rate.is_some());
    }

    #[test]
    fn unknown_matrix_and_impl_errors() {
        let mut e = test_engine();
        assert!(e.submit(&JobSpec::new("ghost", 4)).is_err());
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(182));
        e.register("m", a).unwrap();
        assert!(e.submit(&JobSpec::new("m", 4).with_impl(Impl::Xla)).is_err());
    }

    #[test]
    fn submit_batch_aggregates_and_reuses_buffers() {
        let mut e = test_engine();
        let a = erdos_renyi(400, 400, 5.0, &mut Prng::new(184));
        e.register("m", a).unwrap();
        let jobs: Vec<JobSpec> = (0..4).map(|_| JobSpec::new("m", 8)).collect();
        let rep = e.submit_batch(&jobs).unwrap();
        assert_eq!(rep.n_jobs(), 4);
        assert_eq!(e.history().len(), 4);
        assert!(rep.aggregate_gflops() > 0.0);
        assert!(rep.wall_secs >= rep.exec_secs);
        // job 1 allocates B and C; jobs 2–4 recycle both
        assert_eq!(rep.buffer_misses, 2);
        assert_eq!(rep.buffer_hits, 6);
        assert!(e.buffer_pool().hit_rate() > 0.7);
        // job 1 plans the schedule; jobs 2–4 reuse it
        assert_eq!(rep.schedule_misses, 1);
        assert_eq!(rep.schedule_hits, 3);
        // a second batch starts fully warm
        let rep2 = e.submit_batch(&jobs[..2]).unwrap();
        assert_eq!(rep2.buffer_misses, 0);
        assert_eq!(rep2.schedule_misses, 0);
        assert_eq!(rep2.schedule_hits, 2);
        assert!(e.registry().schedule_hit_rate() > 0.7);
    }

    #[test]
    fn records_carry_the_planned_tile() {
        let mut e = test_engine();
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(186));
        e.register("m", a).unwrap();
        for d in [1usize, 8, 64] {
            let rec = e.submit(&JobSpec::new("m", d)).unwrap();
            assert!(rec.dt >= 1 && rec.dt <= d, "d={d} dt={}", rec.dt);
        }
    }

    #[test]
    fn batch_error_stops_at_first_bad_job() {
        let mut e = test_engine();
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(185));
        e.register("m", a).unwrap();
        let jobs = vec![JobSpec::new("m", 4), JobSpec::new("ghost", 4), JobSpec::new("m", 4)];
        assert!(e.submit_batch(&jobs).is_err());
        // the job before the failure still landed in history
        assert_eq!(e.history().len(), 1);
    }

    #[test]
    fn autotuned_submit_pins_then_serves_from_cache() {
        let mut e = test_engine_with(quick_autotune());
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(187));
        e.register("m", a).unwrap();
        let jobs: Vec<JobSpec> = (0..3).map(|_| JobSpec::new("m", 8)).collect();
        let cold = e.submit_batch(&jobs).unwrap();
        assert_eq!(cold.routes.len(), 1, "one (matrix, d) → one decision");
        assert!(cold.explore_measurements >= 1, "first batch must explore");
        let dec = cold.routes[0].clone();
        assert_eq!((dec.matrix.as_str(), dec.d), ("m", 8));
        // every job in the batch ran on the pinned impl
        assert!(cold.records.iter().all(|r| r.chosen == dec.im));
        // re-submitting measures nothing new and reuses schedules
        let warm = e.submit_batch(&jobs).unwrap();
        assert_eq!(warm.explore_measurements, 0, "decisions are pinned");
        assert_eq!(warm.schedule_misses, 0);
        assert!(warm.records.iter().all(|r| r.chosen == dec.im));
        // forced jobs bypass the router
        let rec = e.submit(&JobSpec::new("m", 8).with_impl(Impl::Opt)).unwrap();
        assert_eq!(rec.chosen, Impl::Opt);
    }

    #[test]
    fn autotune_reorders_registry_and_records_follow() {
        use crate::sparse::reorder::{permute_symmetric, random_permutation};
        let mut e = test_engine_with(quick_autotune());
        let mut g = Prng::new(188);
        let mesh = mesh2d(14, MeshKind::Triangular, 0.9, &mut g);
        let scrambled = permute_symmetric(&mesh, &random_permutation(mesh.nrows, &mut g));
        e.register("mesh", scrambled).unwrap();
        let rec = e.submit(&JobSpec::new("mesh", 8)).unwrap();
        let dec = e.autotuner().decision("mesh", 8).unwrap().clone();
        // the record reports the layout it actually executed under
        assert_eq!(rec.reorder, dec.reorder);
        assert_eq!(e.registry().get("mesh").unwrap().reordering(), dec.reorder);
        assert_eq!(rec.chosen, dec.im);
        assert!(dec.measured_gflops > 0.0 && dec.enumerated >= 6);
        // re-registration forgets the decision
        let a2 = erdos_renyi(100, 100, 3.0, &mut Prng::new(189));
        e.register("mesh", a2).unwrap();
        assert!(e.autotuner().decision("mesh", 8).is_none());
    }

    #[test]
    fn spgemm_submit_routes_and_measures() {
        let mut e = test_engine();
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(190));
        let b = erdos_renyi(200, 200, 4.0, &mut Prng::new(191));
        e.register("a", a).unwrap();
        e.register("b", b).unwrap();
        let rec = e.submit_spgemm(&SpGemmSpec::new("a", "b")).unwrap();
        assert!(rec.measured_gflops > 0.0);
        assert!(rec.cf >= 2.0);
        assert!(rec.nnz_c > 0);
        assert!(rec.flops >= 2.0 * rec.nnz_c as f64);
        assert_eq!(e.spgemm_history().len(), 1);
        // forced kernel respected for both candidates
        for im in SpGemmImpl::ALL {
            let rec = e.submit_spgemm(&SpGemmSpec::new("a", "b").with_impl(im)).unwrap();
            assert_eq!(rec.chosen, im);
        }
        // unknown operands error
        assert!(e.submit_spgemm(&SpGemmSpec::new("ghost", "b")).is_err());
        assert!(e.submit_spgemm(&SpGemmSpec::new("a", "ghost")).is_err());
    }

    #[test]
    fn workload_dispatch_covers_both_arms() {
        let mut e = test_engine();
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(194));
        e.register("m", a).unwrap();
        match e.submit_workload("m", &Workload::SpMM { d: 8 }).unwrap() {
            WorkloadOutcome::SpMM(rec) => {
                assert_eq!(rec.d, 8);
                assert!(rec.measured_gflops > 0.0);
            }
            other => panic!("SpMM workload dispatched wrong: {other:?}"),
        }
        match e.submit_workload("m", &Workload::SpGemm { b: "m".into() }).unwrap() {
            WorkloadOutcome::SpGemm(rec) => {
                assert_eq!((rec.a.as_str(), rec.b.as_str()), ("m", "m"));
                assert!(rec.cf >= 2.0);
            }
            other => panic!("SpGemm workload dispatched wrong: {other:?}"),
        }
        assert_eq!(e.history().len(), 1);
        assert_eq!(e.spgemm_history().len(), 1);
    }

    #[test]
    fn autotuned_spgemm_pins_then_serves_from_cache() {
        let mut e = test_engine_with(quick_autotune());
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(192));
        e.register("m", a).unwrap();
        // A·A: the classic SpGEMM self-product
        let r1 = e.submit_spgemm(&SpGemmSpec::new("m", "m")).unwrap();
        let dec = e.autotuner().spgemm_decision("m", "m").unwrap().clone();
        assert_eq!(r1.chosen, dec.im);
        assert_eq!(dec.explored, 2, "both kernels explored");
        assert_eq!(dec.candidates.len(), 2);
        let n = e.autotuner().measurements();
        let r2 = e.submit_spgemm(&SpGemmSpec::new("m", "m")).unwrap();
        assert_eq!(e.autotuner().measurements(), n, "decision is pinned");
        assert_eq!(r2.chosen, dec.im);
        // re-registration forgets the pair decision
        let a2 = erdos_renyi(150, 150, 3.0, &mut Prng::new(193));
        e.register("m", a2).unwrap();
        assert!(e.autotuner().spgemm_decision("m", "m").is_none());
    }

    #[test]
    fn priors_learn_from_history() {
        let mut e = test_engine();
        let a = erdos_renyi(400, 400, 5.0, &mut Prng::new(183));
        e.register("m", a).unwrap();
        let cls = e.registry().get("m").unwrap().classification.clone();
        let before = e.planner().prior(cls.class, Impl::Csr);
        for _ in 0..4 {
            e.submit(&JobSpec::new("m", 4).with_impl(Impl::Csr)).unwrap();
        }
        let after = e.planner().prior(cls.class, Impl::Csr);
        assert_ne!(before, after);
    }

    #[test]
    fn seeded_submissions_are_order_independent() {
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(195));
        // engine 1: seeds 7 then 8; engine 2: interleaves other work
        // before replaying seed 8 then 7 — outputs must match bitwise
        // impl is forced so only the seeding is under test (routing
        // drift across submissions may legitimately pick another impl)
        let job = JobSpec::new("m", 8).with_impl(Impl::Csr);
        let mut e1 = test_engine();
        e1.register("m", a.clone()).unwrap();
        let (_, out7) = e1.submit_collect(&job, 7).unwrap();
        let (_, out8) = e1.submit_collect(&job, 8).unwrap();
        assert_ne!(out7, out8, "different seeds must draw different B");

        let mut e2 = test_engine();
        e2.register("m", a).unwrap();
        e2.submit(&JobSpec::new("m", 4)).unwrap(); // perturb the shared rng + pool
        let (_, out8b) = e2.submit_collect(&job, 8).unwrap();
        let (_, out7b) = e2.submit_collect(&job, 7).unwrap();
        assert_eq!(out7, out7b, "seed 7 output must not depend on submission order");
        assert_eq!(out8, out8b);
    }

    #[test]
    fn batch_collect_returns_per_job_outputs() {
        let mut e = test_engine();
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(196));
        e.register("m", a).unwrap();
        let jobs: Vec<(JobSpec, u64)> = (0..3)
            .map(|i| (JobSpec::new("m", 8).with_impl(Impl::Csr), 100 + i as u64))
            .collect();
        let (rep, outs) = e.submit_batch_collect(&jobs).unwrap();
        assert_eq!(rep.n_jobs(), 3);
        assert_eq!(outs.len(), 3);
        assert!(outs.iter().all(|o| o.len() == 150 * 8));
        // identical (matrix, d, seed) → identical output via submit_collect
        let (_, single) = e.submit_collect(&jobs[0].0, 100).unwrap();
        assert_eq!(single, outs[0]);
    }

    #[test]
    fn spgemm_collect_matches_plain_submission() {
        let mut e = test_engine();
        let a = erdos_renyi(120, 120, 3.0, &mut Prng::new(197));
        e.register("m", a).unwrap();
        // forced kernel: the repeat must reproduce bitwise, which only
        // holds kernel-for-kernel (routing may drift between runs)
        let spec = SpGemmSpec::new("m", "m").with_impl(SpGemmImpl::Hash);
        let (rec, c) = e.submit_spgemm_collect(&spec).unwrap();
        assert_eq!(c.nnz(), rec.nnz_c);
        assert_eq!(c.nrows, 120);
        let (_, c2) = e.submit_spgemm_collect(&spec).unwrap();
        crate::testutil::assert_csr_eq(&c, &c2, 0.0);
    }

    #[test]
    fn register_for_scopes_by_tenant() {
        let mut e = test_engine();
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(198));
        e.register_for("acme", "m", a.clone()).unwrap();
        e.register_for("", "m", a).unwrap();
        assert!(e.registry().get("acme/m").is_some());
        assert!(e.registry().get("m").is_some());
        let rec = e.submit(&JobSpec::new("acme/m", 4)).unwrap();
        assert_eq!(rec.matrix, "acme/m");
    }

    #[test]
    fn state_round_trip_restores_decisions_without_exploring() {
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(199));
        let b = erdos_renyi(300, 300, 4.0, &mut Prng::new(200));
        let mut e1 = test_engine_with(quick_autotune());
        e1.register("m", a.clone()).unwrap();
        e1.register("n", b.clone()).unwrap();
        e1.submit(&JobSpec::new("m", 8)).unwrap();
        e1.submit_spgemm(&SpGemmSpec::new("m", "n")).unwrap();
        let state = e1.export_state();
        assert_eq!(state.routes.len(), 1);
        assert_eq!(state.spgemm.len(), 1);
        assert!(!state.spmm_priors.is_empty());
        let dec = state.routes[0].clone();

        // a restarted engine adopts the snapshot and explores nothing
        let mut e2 = test_engine_with(quick_autotune());
        e2.register("m", a).unwrap();
        e2.register("n", b).unwrap();
        assert_eq!(e2.restore_state(&state), 2);
        assert_eq!(e2.registry().get("m").unwrap().reordering(), dec.reorder);
        let jobs = vec![JobSpec::new("m", 8), JobSpec::new("m", 8)];
        let rep = e2.submit_batch(&jobs).unwrap();
        assert_eq!(rep.explore_measurements, 0, "restored decisions must not re-explore");
        assert!(rep.records.iter().all(|r| r.chosen == dec.im));
        assert_eq!(e2.autotuner().measurements(), 0);
        let n0 = e2.autotuner().measurements();
        e2.submit_spgemm(&SpGemmSpec::new("m", "n")).unwrap();
        assert_eq!(e2.autotuner().measurements(), n0, "spgemm pin restored too");

        // decisions for unregistered matrices are skipped, not errors
        let mut e3 = test_engine_with(quick_autotune());
        assert_eq!(e3.restore_state(&state), 0);
    }

    #[test]
    fn restored_ladder_installs_without_remeasuring() {
        use crate::coordinator::LadderSource;
        use crate::membench::{LadderLevel, MeasuredLadder};
        // a hand-built ladder: both engines use injected machine params,
        // so no bandwidth sweep or peak probe ever runs in this test
        let ml = MeasuredLadder {
            levels: vec![
                LadderLevel {
                    level: "L1".into(),
                    capacity_bytes: 32 * 1024,
                    read_gbs: 400.0,
                    write_gbs: 280.0,
                    triad_gbs: 390.0,
                },
                LadderLevel {
                    level: "DRAM".into(),
                    capacity_bytes: usize::MAX,
                    read_gbs: 18.0,
                    write_gbs: 13.0,
                    triad_gbs: 19.0,
                },
            ],
            peak_gflops: 64.0,
            simd_level: "avx".into(),
            threads: 2,
        };
        let mut e1 = test_engine();
        assert_eq!(e1.planner().ladder_source(), LadderSource::Nominal);
        e1.install_measured_ladder(ml.clone());
        assert_eq!(e1.planner().ladder_source(), LadderSource::Measured);
        let state = e1.export_state();
        assert_eq!(state.ladder.as_ref(), Some(&ml));

        // a restarted engine adopts the measured ladder from the
        // snapshot — the planner prefers it over the nominal prior and
        // no re-calibration happens
        let mut e2 = test_engine();
        assert_eq!(e2.planner().ladder_source(), LadderSource::Nominal);
        e2.restore_state(&state);
        assert_eq!(e2.planner().ladder_source(), LadderSource::Measured);
        assert_eq!(e2.measured_ladder(), Some(&ml));
        assert_eq!(e2.planner().ladder().pi_gflops, 64.0);
        // routing still flows end-to-end through the measured ladder
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(202));
        e2.register("m", a).unwrap();
        let rec = e2.submit(&JobSpec::new("m", 8)).unwrap();
        assert!(rec.predicted_gflops > 0.0);
    }

    #[test]
    fn save_and_load_state_via_disk() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("engine_state_{}.json", std::process::id()));
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);

        let a = erdos_renyi(250, 250, 4.0, &mut Prng::new(201));
        let mut e1 = test_engine_with(quick_autotune());
        e1.register("m", a.clone()).unwrap();
        e1.submit(&JobSpec::new("m", 8)).unwrap();
        e1.save_state(path).unwrap();

        let mut e2 = test_engine_with(quick_autotune());
        e2.register("m", a).unwrap();
        assert!(e2.load_state(path), "healthy snapshot must load");
        let rep = e2.submit_batch(&[JobSpec::new("m", 8)]).unwrap();
        assert_eq!(rep.explore_measurements, 0);

        // missing → cold start, no panic
        let _ = std::fs::remove_file(path);
        let mut e3 = test_engine_with(quick_autotune());
        assert!(!e3.load_state(path));
    }

    #[test]
    fn pipeline_gcn_matches_standalone_bitwise() {
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(210));
        let dims = vec![8usize, 4, 8];
        let seed = 77u64;
        // standalone: thin wrapper over the shared chain core
        let kernel = build_native(Impl::Csr, &a, 2).unwrap();
        let (h0, layers) = gcn_random_inputs(150, &dims, seed);
        let want = gcn_forward(kernel.as_ref(), &h0, &layers).unwrap();
        // engine: same chain over the cached schedule + shared pool
        let mut e = test_engine();
        e.register("m", a).unwrap();
        let spec = PipelineSpec::new("m", PipelineKind::Gcn { dims }).with_impl(Impl::Csr);
        let (rec, out) = e.submit_pipeline_collect(&spec, seed).unwrap();
        assert_eq!(rec.ops, 2);
        assert_eq!(rec.per_op.len(), 2);
        assert_eq!(rec.chain, "GCN(layers=2,d=8)");
        assert!(rec.measured_gflops > 0.0);
        let got = out.data();
        assert_eq!(got.len(), want.data.len());
        assert!(
            got.iter().zip(&want.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "engine-routed GCN must be bitwise-identical to gcn_forward"
        );
        assert_eq!(e.pipeline_history().len(), 1);
    }

    #[test]
    fn pipeline_power_matches_standalone_bitwise() {
        let a = mesh2d(14, MeshKind::Triangular, 0.9, &mut Prng::new(211));
        let n = a.nrows;
        let seed = 31u64;
        let kernel = build_native(Impl::Opt, &a, 2).unwrap();
        let x0 = power_random_input(n, 4, seed);
        let (want, stats) = block_power_iteration(kernel.as_ref(), &x0, 5).unwrap();
        let mut e = test_engine();
        e.register("m", a).unwrap();
        let spec = PipelineSpec::new("m", PipelineKind::PowerIteration { d: 4, iters: 5 })
            .with_impl(Impl::Opt);
        let (rec, out) = e.submit_pipeline_collect(&spec, seed).unwrap();
        assert_eq!(rec.ops, stats.iters);
        match out {
            PipelineOutput::Power { block, lambda_max, residual } => {
                assert!(block.iter().zip(&want.data).all(|(x, y)| x.to_bits() == y.to_bits()));
                assert_eq!(lambda_max.to_bits(), stats.lambda_max.to_bits());
                assert_eq!(residual.to_bits(), stats.residual.to_bits());
            }
            other => panic!("power pipeline returned wrong output kind: {other:?}"),
        }
    }

    #[test]
    fn pipeline_pagerank_matches_standalone_and_refreshes_operator() {
        let g = erdos_renyi(120, 120, 3.0, &mut Prng::new(212));
        let seeds = vec![0usize, 1, 2];
        let want = batched_pagerank(&g, &seeds, 0.85, 1e-9, 30, Impl::Csr, 2).unwrap();
        let mut e = test_engine();
        e.register("g", g).unwrap();
        let kind = PipelineKind::PageRank {
            seeds: seeds.clone(),
            alpha: 0.85,
            tol: 1e-9,
            iters: 30,
        };
        let spec = PipelineSpec::new("g", kind.clone()).with_impl(Impl::Csr);
        let (rec, out) = e.submit_pipeline_collect(&spec, 0).unwrap();
        assert_eq!(rec.matrix, "g", "record names the user's graph, not the operator");
        assert_eq!(rec.ops, want.iterations);
        match out {
            PipelineOutput::PageRank { scores, iterations, delta } => {
                assert_eq!(iterations, want.iterations);
                assert_eq!(delta.to_bits(), want.delta.to_bits());
                assert!(
                    scores.iter().zip(&want.scores.data).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "engine-routed PageRank must match batched_pagerank bitwise"
                );
            }
            other => panic!("pagerank pipeline returned wrong output kind: {other:?}"),
        }
        // the derived transition operator is registered under a scoped
        // name and refreshed when the graph is re-registered
        assert!(e.registry().get("g::pr").is_some());
        let g2 = erdos_renyi(80, 80, 3.0, &mut Prng::new(218));
        e.register("g", g2).unwrap();
        let (_, out2) = e.submit_pipeline_collect(&spec, 0).unwrap();
        match out2 {
            PipelineOutput::PageRank { scores, .. } => {
                assert_eq!(scores.len(), 80 * 3, "operator must track the new graph");
            }
            other => panic!("pagerank pipeline returned wrong output kind: {other:?}"),
        }
    }

    #[test]
    fn autotuned_pipeline_pins_whole_chain_then_serves() {
        let mut e = test_engine_with(quick_autotune());
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(213));
        e.register("m", a).unwrap();
        let spec = PipelineSpec::new("m", PipelineKind::Gcn { dims: vec![8, 8, 8] });
        let r1 = e.submit_pipeline(&spec).unwrap();
        let dec = e.autotuner().pipeline_decision("m", "GCN(layers=2,d=8)").unwrap().clone();
        assert_eq!(r1.chosen, dec.im);
        assert_eq!(dec.explored, 3, "every native candidate measured on the whole chain");
        assert_eq!(dec.reorder, crate::sparse::reorder::Reordering::None);
        let n = e.autotuner().measurements();
        let r2 = e.submit_pipeline(&spec).unwrap();
        assert_eq!(e.autotuner().measurements(), n, "pinned chain explores nothing");
        assert_eq!(r2.chosen, dec.im);
        // one schedule serves every op of every run: after the first
        // plan, chained submissions hit the registry cache
        assert!(e.registry().schedule_hit_rate() > 0.5);
        // re-registration forgets the pipeline pin
        let a2 = erdos_renyi(200, 200, 3.0, &mut Prng::new(214));
        e.register("m", a2).unwrap();
        assert!(e.autotuner().pipeline_decision("m", "GCN(layers=2,d=8)").is_none());
    }

    #[test]
    fn pipeline_state_round_trip_serves_without_exploring() {
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(215));
        let mut e1 = test_engine_with(quick_autotune());
        e1.register("m", a.clone()).unwrap();
        let spec = PipelineSpec::new("m", PipelineKind::PowerIteration { d: 4, iters: 3 });
        e1.submit_pipeline(&spec).unwrap();
        let state = e1.export_state();
        assert_eq!(state.pipelines.len(), 1);
        let dec = state.pipelines[0].clone();

        let mut e2 = test_engine_with(quick_autotune());
        e2.register("m", a).unwrap();
        assert_eq!(e2.restore_state(&state), 1);
        let r = e2.submit_pipeline(&spec).unwrap();
        assert_eq!(r.chosen, dec.im);
        assert_eq!(e2.autotuner().measurements(), 0, "restored pipeline pin explores nothing");

        // pins for unregistered matrices are skipped, not errors
        let mut e3 = test_engine_with(quick_autotune());
        assert_eq!(e3.restore_state(&state), 0);
    }

    #[test]
    fn workload_dispatch_covers_pipeline_arms() {
        let mut e = test_engine();
        let a = erdos_renyi(120, 120, 3.0, &mut Prng::new(216));
        e.register("m", a).unwrap();
        match e.submit_workload("m", &Workload::GcnLayer { layers: 2, d: 4 }).unwrap() {
            WorkloadOutcome::Pipeline(rec) => {
                assert_eq!(rec.chain, "GCN(layers=2,d=4)");
                assert_eq!(rec.ops, 2);
                assert!(rec.measured_gflops > 0.0);
            }
            other => panic!("GCN workload dispatched wrong: {other:?}"),
        }
        match e.submit_workload("m", &Workload::BatchedPageRank { seeds: 2, iters: 10 }).unwrap() {
            WorkloadOutcome::Pipeline(rec) => {
                assert_eq!(rec.chain, "PageRank(seeds=2,iters=10)");
                assert!(rec.ops >= 1 && rec.ops <= 10);
            }
            other => panic!("PageRank workload dispatched wrong: {other:?}"),
        }
        match e.submit_workload("m", &Workload::SpGemmSpMM { b: "m".into(), d: 4 }).unwrap() {
            WorkloadOutcome::Pipeline(rec) => {
                assert_eq!(rec.per_op.len(), 2);
                assert_eq!(rec.per_op[0].op, "spgemm");
                assert_eq!(rec.per_op[1].op, "spmm");
                assert_eq!(rec.ops, 1);
            }
            other => panic!("SpGEMM+SpMM workload dispatched wrong: {other:?}"),
        }
        assert_eq!(e.pipeline_history().len(), 3);
        // unknown matrices error instead of panicking
        let ghost = PipelineSpec::new("ghost", PipelineKind::PowerIteration { d: 4, iters: 2 });
        assert!(e.submit_pipeline(&ghost).is_err());
    }

    #[test]
    fn spgemm_spmm_chain_is_seeded_and_rejects_xla() {
        let mut e = test_engine();
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(217));
        e.register("a", a).unwrap();
        let spec = PipelineSpec::new("a", PipelineKind::SpGemmSpMM { b: "a".into(), d: 4 })
            .with_impl(Impl::Csr);
        let (rec, out) = e.submit_pipeline_collect(&spec, 5).unwrap();
        assert_eq!(out.data().len(), 100 * 4);
        assert_eq!(rec.ops, 1);
        assert!(rec.secs > 0.0 && rec.measured_gflops > 0.0);
        // same (chain, seed, impl) reproduces bitwise
        let (_, out2) = e.submit_pipeline_collect(&spec, 5).unwrap();
        assert!(out.data().iter().zip(out2.data()).all(|(x, y)| x.to_bits() == y.to_bits()));
        // the SpMM leg runs on a data-dependent product — only native
        // kernels can serve it
        let bad = PipelineSpec::new("a", PipelineKind::SpGemmSpMM { b: "a".into(), d: 4 })
            .with_impl(Impl::Xla);
        assert!(e.submit_pipeline(&bad).is_err());
    }
}
