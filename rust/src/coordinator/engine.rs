//! The engine: classify → predict → route → execute → learn.

use crate::coordinator::autotune::{Autotuner, AutotunePolicy, RouteDecision, SpGemmDecision};
use crate::coordinator::batch::{BatchReport, BufferPool};
use crate::coordinator::job::{
    JobRecord, JobSpec, PredictionReport, SpGemmRecord, SpGemmSpec, Workload,
};
use crate::coordinator::planner::Planner;
use crate::coordinator::registry::MatrixRegistry;
use crate::error::{Error, Result};
use crate::gen::Prng;
use crate::membench;
use crate::metrics::{bench_adaptive_checked, gflops, spmm_flops, Timer};
use crate::model::{MachineParams, Roofline, SpGemmParams};
use crate::runtime::{ArtifactManifest, XlaRuntime};
use crate::sparse::Csr;
use crate::spgemm::{compression_factor, spgemm_flops};
use crate::spmm::Impl;

/// Engine construction options.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads per kernel execution.
    pub threads: usize,
    /// Calibrate β/π by measurement (`None`) or inject known machine
    /// parameters (tests; avoids a multi-second STREAM run).
    pub machine: Option<MachineParams>,
    /// Timed iterations per job (median reported).
    pub iters: usize,
    /// Warmup iterations per job.
    pub warmup: usize,
    /// Native implementations prepared at registration. Defaults to
    /// the paper trio (CSR/OPT/CSB); ELL and BSR are opt-in — the CLI
    /// wires them through `--impls ELL,BSR` or `--impls all`.
    pub impls: Vec<Impl>,
    /// Attach XLA artifacts from this directory when present.
    pub artifacts_dir: Option<String>,
    /// Structure-adaptive routing policy. Disabled by default: jobs
    /// route on predictions alone (and `force_impl` always wins).
    /// When enabled, the first submission per `(matrix, d)` explores
    /// the candidate space (impl × reordering), pins the measured-best
    /// plan, and may permute the registered matrix in place.
    pub autotune: AutotunePolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            machine: None,
            iters: 3,
            warmup: 1,
            impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
            artifacts_dir: Some("artifacts".into()),
            autotune: AutotunePolicy::default(),
        }
    }
}

/// Outcome of a workload-dispatched submission
/// ([`Engine::submit_workload`]).
#[derive(Debug, Clone)]
pub enum WorkloadOutcome {
    SpMM(JobRecord),
    SpGemm(SpGemmRecord),
}

/// The roofline-guided SpMM engine (see module docs).
pub struct Engine {
    registry: MatrixRegistry,
    planner: Planner,
    config: EngineConfig,
    xla: Option<(XlaRuntime, ArtifactManifest)>,
    history: Vec<JobRecord>,
    /// SpGEMM records, kept separately — their axes (pair, cf) do not
    /// fit the SpMM record shape.
    spgemm_history: Vec<SpGemmRecord>,
    rng: Prng,
    /// Recycled dense `B`/`C` operands, shared by every submission.
    buffers: BufferPool,
    /// The adaptive router (pinned per-(matrix, d) decisions).
    tuner: Autotuner,
}

impl Engine {
    /// Build an engine: calibrates the machine roofline unless one was
    /// injected, and probes the artifact directory for the XLA
    /// backend.
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let machine = match config.machine {
            Some(m) => m,
            None => membench::measure_machine(config.threads),
        };
        let planner = Planner::new(Roofline::new(machine));
        let xla = match &config.artifacts_dir {
            Some(dir) => match ArtifactManifest::load(dir) {
                Ok(manifest) => match XlaRuntime::cpu() {
                    Ok(rt) => Some((rt, manifest)),
                    Err(_) => None,
                },
                Err(_) => None, // artifacts not built — native-only mode
            },
            None => None,
        };
        let tuner = Autotuner::new(config.autotune.clone());
        Ok(Engine {
            registry: MatrixRegistry::new(config.threads),
            planner,
            config,
            xla,
            history: Vec::new(),
            spgemm_history: Vec::new(),
            rng: Prng::new(0x5eed),
            buffers: BufferPool::new(),
            tuner,
        })
    }

    /// The machine parameters the roofline uses.
    pub fn machine(&self) -> MachineParams {
        self.planner.roofline().machine
    }

    /// Whether the XLA backend is live.
    pub fn has_xla(&self) -> bool {
        self.xla.is_some()
    }

    /// Register a matrix under a name; prepares the configured native
    /// kernels and stages matching XLA artifacts.
    pub fn register(&mut self, name: &str, csr: Csr) -> Result<()> {
        let impls = self.config.impls.clone();
        self.registry.register(name, csr, &impls)?;
        // a re-registered matrix invalidates its routing decisions —
        // its structure may be entirely different
        self.tuner.forget(name);
        if let Some((rt, manifest)) = &self.xla {
            // staging failure (no fitting artifact) is not an error
            let _ = self.registry.attach_xla(name, rt, manifest);
        }
        Ok(())
    }

    /// Planner access (reports).
    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// Registry access (reports).
    pub fn registry(&self) -> &MatrixRegistry {
        &self.registry
    }

    /// Execute a job: route to the pinned autotune decision (when
    /// enabled), the predicted-best implementation, or the forced one;
    /// measure; and fold the measurement back into the planner's
    /// priors.
    pub fn submit(&mut self, job: &JobSpec) -> Result<JobRecord> {
        // adaptive routing first: tuning may permute the stored matrix
        // and rebuild kernels, so it must run before the entry borrow
        let routed: Option<RouteDecision> =
            if self.config.autotune.enabled && job.force_impl.is_none() {
                Some(match self.tuner.decision(&job.matrix, job.d) {
                    Some(dec) => dec.clone(),
                    None => self.tuner.tune(
                        &job.matrix,
                        job.d,
                        &mut self.registry,
                        &self.planner,
                        &mut self.buffers,
                        &mut self.rng,
                    )?,
                })
            } else {
                None
            };
        let entry = self
            .registry
            .get(&job.matrix)
            .ok_or_else(|| Error::Usage(format!("matrix '{}' not registered", job.matrix)))?;
        let cls = entry.classification.clone();
        let reorder = entry.reordering();
        let available = entry.available(job.d);
        if available.is_empty() {
            return Err(Error::Usage(format!(
                "no kernels available for '{}' at d={}",
                job.matrix, job.d
            )));
        }
        let chosen = match (job.force_impl, &routed) {
            (Some(im), _) => {
                if !available.contains(&im) {
                    return Err(Error::Usage(format!(
                        "impl {im} not prepared for '{}' at d={} (have {:?})",
                        job.matrix, job.d, available
                    )));
                }
                self.planner.predict(&cls, job.d, im)
            }
            (None, Some(dec)) => {
                // pinned decision: the registry already stores the
                // winning layout, so predicting the decided impl on the
                // current classification reflects the refined priors
                if !available.contains(&dec.im) {
                    return Err(Error::Usage(format!(
                        "pinned impl {} not prepared for '{}' at d={}",
                        dec.im, job.matrix, job.d
                    )));
                }
                self.planner.predict(&cls, job.d, dec.im)
            }
            (None, None) => self.planner.rank(&cls, job.d, &available)[0],
        };

        let kernel = entry.kernel(chosen.im, job.d).expect("available impl must have kernel");
        // the execution schedule (nnz-balanced partitions + the
        // planner's column tile) is cached per (matrix, impl, threads,
        // d, dt): repeated and batched submissions plan once
        let sched = self
            .registry
            .schedule(&job.matrix, chosen.im, job.d, chosen.dt)
            .expect("kernel was just resolved");
        let n = kernel.ncols();
        // dense operands come from the recycled buffer pool: across a
        // batch (or any repeated submission) each distinct size is
        // allocated once and reused
        let b = self.buffers.acquire_random(n, job.d, &mut self.rng);
        let mut c = self.buffers.acquire(kernel.nrows(), job.d);
        // surface kernel errors before timing (returning the buffers —
        // a failed job must not bleed the pool's largest allocations)
        if let Err(e) = kernel.execute_with(&b, &mut c, &sched) {
            self.buffers.release(b);
            self.buffers.release(c);
            return Err(e);
        }
        // mid-benchmark failures surface as Err too (the buffers still
        // return to the pool, and nothing panics through the workers)
        let r = bench_adaptive_checked(
            self.config.warmup,
            self.config.iters,
            self.config.iters * 4,
            0.2,
            |_| kernel.execute_with(&b, &mut c, &sched),
        );
        self.buffers.release(b);
        self.buffers.release(c);
        let r = r?;
        let secs = r.median_secs();
        let flops = spmm_flops(kernel.nnz(), job.d);
        let measured = gflops(flops, secs);

        self.planner.observe(cls.class, chosen.im, chosen.roof_gflops, measured);
        let record = JobRecord {
            matrix: job.matrix.clone(),
            class: cls.class,
            d: job.d,
            chosen: chosen.im,
            reorder,
            dt: chosen.dt,
            predicted_gflops: chosen.predicted_gflops,
            ai: chosen.ai,
            secs,
            measured_gflops: measured,
        };
        self.history.push(record.clone());
        Ok(record)
    }

    /// Execute an SpGEMM job — the `Workload::SpGemm` arm of the
    /// router ([`crate::coordinator::Workload`]): `C = A·B` with both
    /// operands registered. Routing mirrors [`Engine::submit`]: the
    /// pinned autotune decision per (a, b) pair when enabled, the
    /// predicted-best kernel otherwise, or the forced one; the
    /// measurement feeds the planner's SpGEMM priors, and the record
    /// carries the measured compression factor.
    ///
    /// Both operands execute in their *active* layouts. A reordering
    /// pinned by SpMM tuning changes the product (`P·A·Pᵀ·B` is a
    /// different matrix than `P·(A·B)`), which is why SpGEMM tuning
    /// never enumerates reorderings.
    pub fn submit_spgemm(&mut self, spec: &SpGemmSpec) -> Result<SpGemmRecord> {
        // adaptive routing first: tuning lazily builds kernels through
        // a mutable registry borrow, so it must precede the entry reads
        let routed: Option<SpGemmDecision> =
            if self.config.autotune.enabled && spec.force_impl.is_none() {
                Some(match self.tuner.spgemm_decision(&spec.a, &spec.b) {
                    Some(dec) => dec.clone(),
                    None => self.tuner.tune_spgemm(
                        &spec.a,
                        &spec.b,
                        &mut self.registry,
                        &self.planner,
                    )?,
                })
            } else {
                None
            };
        // resolve the pair and pick the kernel *before* building any:
        // predictions need no kernels, so only the chosen
        // implementation is ever constructed (a forced or pinned job
        // never pays the other kernel's binning time or memory)
        let (cls, params, chosen_im) = {
            let (entry_a, entry_b) = self.registry.spgemm_pair(&spec.a, &spec.b)?;
            let (acsr, bcsr) = (entry_a.csr(), entry_b.csr());
            let cls = entry_a.classification.clone();
            let flops = spgemm_flops(acsr, bcsr);
            let mut params =
                SpGemmParams::new(acsr.nrows, bcsr.nrows, acsr.nnz(), bcsr.nnz(), flops);
            if let Some(dec) = &routed {
                // the pinned decision carries the pair's measured cf —
                // predict at it rather than the conservative floor
                params = params.with_cf(dec.cf);
            }
            let chosen_im = match (spec.force_impl, &routed) {
                (Some(im), _) => im,
                (None, Some(dec)) => dec.im,
                (None, None) => self.planner.rank_spgemm(&cls, params)[0].im,
            };
            (cls, params, chosen_im)
        };
        self.registry.ensure_spgemm(&spec.a, chosen_im)?;
        let entry_a = self.registry.get(&spec.a).expect("resolved above");
        let bcsr = self.registry.get(&spec.b).expect("resolved above").csr();
        let pred = self.planner.predict_spgemm(&cls, params, chosen_im);
        let kernel = entry_a.spgemm_kernel(chosen_im).expect("ensured above");
        let sched = kernel.plan();
        // first execution surfaces kernel errors before the timing
        // loop and yields nnz(C) for the measured compression factor
        let c = kernel.execute_with(bcsr, &sched)?;
        let nnz_c = c.nnz();
        drop(c);
        // the timed region includes output allocation — SpGEMM's
        // output is data-dependent, so allocation is part of the work
        let r = bench_adaptive_checked(
            self.config.warmup,
            self.config.iters,
            self.config.iters * 4,
            0.2,
            |_| kernel.execute_with(bcsr, &sched).map(|_| ()),
        )?;
        let secs = r.median_secs();
        let measured = gflops(params.flops, secs);
        self.planner.observe_spgemm(cls.class, chosen_im, pred.roof_gflops, measured);
        let record = SpGemmRecord {
            a: spec.a.clone(),
            b: spec.b.clone(),
            class: cls.class,
            chosen: chosen_im,
            flops: params.flops,
            nnz_c,
            cf: compression_factor(params.flops, nnz_c),
            predicted_gflops: pred.predicted_gflops,
            ai: pred.ai,
            secs,
            measured_gflops: measured,
        };
        self.spgemm_history.push(record.clone());
        Ok(record)
    }

    /// Dispatch on the [`Workload`] dimension: `SpMM` jobs go through
    /// [`Engine::submit`], `SpGemm` jobs through
    /// [`Engine::submit_spgemm`] — the single entry point for callers
    /// holding a `(matrix, workload)` pair rather than a concrete
    /// spec.
    pub fn submit_workload(&mut self, matrix: &str, w: &Workload) -> Result<WorkloadOutcome> {
        match w {
            Workload::SpMM { d } => {
                Ok(WorkloadOutcome::SpMM(self.submit(&JobSpec::new(matrix, *d))?))
            }
            Workload::SpGemm { b } => Ok(WorkloadOutcome::SpGemm(
                self.submit_spgemm(&SpGemmSpec::new(matrix, b.clone()))?,
            )),
        }
    }

    /// Eagerly tune one SpGEMM pair (normally tuning happens lazily on
    /// first submission). Returns the pinned decision.
    pub fn tune_spgemm(&mut self, a: &str, b: &str) -> Result<SpGemmDecision> {
        self.tuner.tune_spgemm(a, b, &mut self.registry, &self.planner)
    }

    /// Every SpGEMM record executed so far.
    pub fn spgemm_history(&self) -> &[SpGemmRecord] {
        &self.spgemm_history
    }

    /// Run a batch of jobs in order, stopping at the first hard error.
    pub fn run_batch(&mut self, jobs: &[JobSpec]) -> Result<Vec<JobRecord>> {
        jobs.iter().map(|j| self.submit(j)).collect()
    }

    /// Execute a queue of jobs as one batch: classify → predict →
    /// route each job exactly as [`Engine::submit`] does, but with the
    /// persistent worker pool and the recycled dense buffers staying
    /// warm across the whole queue. Returns the per-batch aggregate
    /// report (throughput, model error, buffer reuse); per-job records
    /// are also appended to [`Engine::history`] as usual. Stops at the
    /// first hard error.
    pub fn submit_batch(&mut self, jobs: &[JobSpec]) -> Result<BatchReport> {
        let t = Timer::start();
        let (hits0, misses0) = (self.buffers.hits, self.buffers.misses);
        let (shits0, smisses0) = self.registry.schedule_cache_stats();
        let explore0 = self.tuner.measurements();
        let records = self.run_batch(jobs)?;
        let (shits, smisses) = self.registry.schedule_cache_stats();
        // routing context: the decision in force for each distinct
        // (matrix, d) the batch actually routed — forced-impl jobs
        // bypass the router and must not claim its decisions
        let mut routes: Vec<RouteDecision> = Vec::new();
        for job in jobs.iter().filter(|j| j.force_impl.is_none()) {
            if let Some(dec) = self.tuner.decision(&job.matrix, job.d) {
                if !routes.iter().any(|r| r.matrix == dec.matrix && r.d == dec.d) {
                    routes.push(dec.clone());
                }
            }
        }
        Ok(BatchReport::of(
            records,
            t.elapsed_secs(),
            self.buffers.hits - hits0,
            self.buffers.misses - misses0,
            shits - shits0,
            smisses - smisses0,
        )
        .with_routing(routes, self.tuner.measurements() - explore0))
    }

    /// The engine's dense-operand buffer pool (reuse statistics).
    pub fn buffer_pool(&self) -> &BufferPool {
        &self.buffers
    }

    /// The adaptive router (pinned decisions, exploration counters).
    pub fn autotuner(&self) -> &Autotuner {
        &self.tuner
    }

    /// Eagerly tune one `(matrix, d)` (normally tuning happens lazily
    /// on first submission). Returns the pinned decision.
    pub fn tune(&mut self, matrix: &str, d: usize) -> Result<RouteDecision> {
        self.tuner.tune(
            matrix,
            d,
            &mut self.registry,
            &self.planner,
            &mut self.buffers,
            &mut self.rng,
        )
    }

    /// Every record executed so far.
    pub fn history(&self) -> &[JobRecord] {
        &self.history
    }

    /// Prediction-accuracy summary, including the routing hit rate
    /// over (matrix, d) groups where multiple impls were measured.
    pub fn prediction_report(&self) -> PredictionReport {
        let mut rep = PredictionReport::of(&self.history);
        // routing hit rate: for groups with >1 impls, did the planner's
        // choice (first non-forced record) match the measured best?
        use std::collections::HashMap;
        let mut groups: HashMap<(String, usize), Vec<&JobRecord>> = HashMap::new();
        for r in &self.history {
            groups.entry((r.matrix.clone(), r.d)).or_default().push(r);
        }
        let mut hits = 0usize;
        let mut total = 0usize;
        for (_, rs) in groups {
            if rs.len() < 2 {
                continue;
            }
            let best = rs
                .iter()
                .max_by(|a, b| a.measured_gflops.total_cmp(&b.measured_gflops))
                .unwrap();
            // what would the planner pick now?
            let impls: Vec<Impl> = rs.iter().map(|r| r.chosen).collect();
            if let Some(entry) = self.registry.get(&best.matrix) {
                let pick = self.planner.rank(&entry.classification, best.d, &impls)[0].im;
                total += 1;
                if pick == best.chosen {
                    hits += 1;
                }
            }
        }
        if total > 0 {
            rep.routing_hit_rate = Some(hits as f64 / total as f64);
        }
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, mesh2d, MeshKind, Prng};
    use crate::spgemm::SpGemmImpl;

    fn test_engine() -> Engine {
        test_engine_with(AutotunePolicy::default())
    }

    fn test_engine_with(autotune: AutotunePolicy) -> Engine {
        Engine::new(EngineConfig {
            threads: 2,
            machine: Some(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }),
            iters: 2,
            warmup: 0,
            impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
            artifacts_dir: None,
            autotune,
        })
        .unwrap()
    }

    fn quick_autotune() -> AutotunePolicy {
        AutotunePolicy { explore_iters: 1, explore_min_secs: 0.0, ..AutotunePolicy::enabled() }
    }

    #[test]
    fn submit_routes_and_measures() {
        let mut e = test_engine();
        let a = erdos_renyi(500, 500, 6.0, &mut Prng::new(180));
        e.register("er", a).unwrap();
        let rec = e.submit(&JobSpec::new("er", 8)).unwrap();
        assert!(rec.measured_gflops > 0.0);
        assert!(rec.ai > 0.0);
        assert_eq!(rec.matrix, "er");
        assert_eq!(e.history().len(), 1);
    }

    #[test]
    fn forced_impl_respected() {
        let mut e = test_engine();
        let a = mesh2d(32, MeshKind::Road, 0.6, &mut Prng::new(181));
        e.register("mesh", a).unwrap();
        for im in [Impl::Csr, Impl::Opt, Impl::Csb] {
            let rec = e.submit(&JobSpec::new("mesh", 4).with_impl(im)).unwrap();
            assert_eq!(rec.chosen, im);
        }
        let rep = e.prediction_report();
        assert_eq!(rep.n_jobs, 3);
        assert!(rep.routing_hit_rate.is_some());
    }

    #[test]
    fn unknown_matrix_and_impl_errors() {
        let mut e = test_engine();
        assert!(e.submit(&JobSpec::new("ghost", 4)).is_err());
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(182));
        e.register("m", a).unwrap();
        assert!(e.submit(&JobSpec::new("m", 4).with_impl(Impl::Xla)).is_err());
    }

    #[test]
    fn submit_batch_aggregates_and_reuses_buffers() {
        let mut e = test_engine();
        let a = erdos_renyi(400, 400, 5.0, &mut Prng::new(184));
        e.register("m", a).unwrap();
        let jobs: Vec<JobSpec> = (0..4).map(|_| JobSpec::new("m", 8)).collect();
        let rep = e.submit_batch(&jobs).unwrap();
        assert_eq!(rep.n_jobs(), 4);
        assert_eq!(e.history().len(), 4);
        assert!(rep.aggregate_gflops() > 0.0);
        assert!(rep.wall_secs >= rep.exec_secs);
        // job 1 allocates B and C; jobs 2–4 recycle both
        assert_eq!(rep.buffer_misses, 2);
        assert_eq!(rep.buffer_hits, 6);
        assert!(e.buffer_pool().hit_rate() > 0.7);
        // job 1 plans the schedule; jobs 2–4 reuse it
        assert_eq!(rep.schedule_misses, 1);
        assert_eq!(rep.schedule_hits, 3);
        // a second batch starts fully warm
        let rep2 = e.submit_batch(&jobs[..2]).unwrap();
        assert_eq!(rep2.buffer_misses, 0);
        assert_eq!(rep2.schedule_misses, 0);
        assert_eq!(rep2.schedule_hits, 2);
        assert!(e.registry().schedule_hit_rate() > 0.7);
    }

    #[test]
    fn records_carry_the_planned_tile() {
        let mut e = test_engine();
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(186));
        e.register("m", a).unwrap();
        for d in [1usize, 8, 64] {
            let rec = e.submit(&JobSpec::new("m", d)).unwrap();
            assert!(rec.dt >= 1 && rec.dt <= d, "d={d} dt={}", rec.dt);
        }
    }

    #[test]
    fn batch_error_stops_at_first_bad_job() {
        let mut e = test_engine();
        let a = erdos_renyi(100, 100, 3.0, &mut Prng::new(185));
        e.register("m", a).unwrap();
        let jobs = vec![JobSpec::new("m", 4), JobSpec::new("ghost", 4), JobSpec::new("m", 4)];
        assert!(e.submit_batch(&jobs).is_err());
        // the job before the failure still landed in history
        assert_eq!(e.history().len(), 1);
    }

    #[test]
    fn autotuned_submit_pins_then_serves_from_cache() {
        let mut e = test_engine_with(quick_autotune());
        let a = erdos_renyi(300, 300, 5.0, &mut Prng::new(187));
        e.register("m", a).unwrap();
        let jobs: Vec<JobSpec> = (0..3).map(|_| JobSpec::new("m", 8)).collect();
        let cold = e.submit_batch(&jobs).unwrap();
        assert_eq!(cold.routes.len(), 1, "one (matrix, d) → one decision");
        assert!(cold.explore_measurements >= 1, "first batch must explore");
        let dec = cold.routes[0].clone();
        assert_eq!((dec.matrix.as_str(), dec.d), ("m", 8));
        // every job in the batch ran on the pinned impl
        assert!(cold.records.iter().all(|r| r.chosen == dec.im));
        // re-submitting measures nothing new and reuses schedules
        let warm = e.submit_batch(&jobs).unwrap();
        assert_eq!(warm.explore_measurements, 0, "decisions are pinned");
        assert_eq!(warm.schedule_misses, 0);
        assert!(warm.records.iter().all(|r| r.chosen == dec.im));
        // forced jobs bypass the router
        let rec = e.submit(&JobSpec::new("m", 8).with_impl(Impl::Opt)).unwrap();
        assert_eq!(rec.chosen, Impl::Opt);
    }

    #[test]
    fn autotune_reorders_registry_and_records_follow() {
        use crate::sparse::reorder::{permute_symmetric, random_permutation};
        let mut e = test_engine_with(quick_autotune());
        let mut g = Prng::new(188);
        let mesh = mesh2d(14, MeshKind::Triangular, 0.9, &mut g);
        let scrambled = permute_symmetric(&mesh, &random_permutation(mesh.nrows, &mut g));
        e.register("mesh", scrambled).unwrap();
        let rec = e.submit(&JobSpec::new("mesh", 8)).unwrap();
        let dec = e.autotuner().decision("mesh", 8).unwrap().clone();
        // the record reports the layout it actually executed under
        assert_eq!(rec.reorder, dec.reorder);
        assert_eq!(e.registry().get("mesh").unwrap().reordering(), dec.reorder);
        assert_eq!(rec.chosen, dec.im);
        assert!(dec.measured_gflops > 0.0 && dec.enumerated >= 6);
        // re-registration forgets the decision
        let a2 = erdos_renyi(100, 100, 3.0, &mut Prng::new(189));
        e.register("mesh", a2).unwrap();
        assert!(e.autotuner().decision("mesh", 8).is_none());
    }

    #[test]
    fn spgemm_submit_routes_and_measures() {
        let mut e = test_engine();
        let a = erdos_renyi(200, 200, 4.0, &mut Prng::new(190));
        let b = erdos_renyi(200, 200, 4.0, &mut Prng::new(191));
        e.register("a", a).unwrap();
        e.register("b", b).unwrap();
        let rec = e.submit_spgemm(&SpGemmSpec::new("a", "b")).unwrap();
        assert!(rec.measured_gflops > 0.0);
        assert!(rec.cf >= 2.0);
        assert!(rec.nnz_c > 0);
        assert!(rec.flops >= 2.0 * rec.nnz_c as f64);
        assert_eq!(e.spgemm_history().len(), 1);
        // forced kernel respected for both candidates
        for im in SpGemmImpl::ALL {
            let rec = e.submit_spgemm(&SpGemmSpec::new("a", "b").with_impl(im)).unwrap();
            assert_eq!(rec.chosen, im);
        }
        // unknown operands error
        assert!(e.submit_spgemm(&SpGemmSpec::new("ghost", "b")).is_err());
        assert!(e.submit_spgemm(&SpGemmSpec::new("a", "ghost")).is_err());
    }

    #[test]
    fn workload_dispatch_covers_both_arms() {
        let mut e = test_engine();
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(194));
        e.register("m", a).unwrap();
        match e.submit_workload("m", &Workload::SpMM { d: 8 }).unwrap() {
            WorkloadOutcome::SpMM(rec) => {
                assert_eq!(rec.d, 8);
                assert!(rec.measured_gflops > 0.0);
            }
            other => panic!("SpMM workload dispatched wrong: {other:?}"),
        }
        match e.submit_workload("m", &Workload::SpGemm { b: "m".into() }).unwrap() {
            WorkloadOutcome::SpGemm(rec) => {
                assert_eq!((rec.a.as_str(), rec.b.as_str()), ("m", "m"));
                assert!(rec.cf >= 2.0);
            }
            other => panic!("SpGemm workload dispatched wrong: {other:?}"),
        }
        assert_eq!(e.history().len(), 1);
        assert_eq!(e.spgemm_history().len(), 1);
    }

    #[test]
    fn autotuned_spgemm_pins_then_serves_from_cache() {
        let mut e = test_engine_with(quick_autotune());
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(192));
        e.register("m", a).unwrap();
        // A·A: the classic SpGEMM self-product
        let r1 = e.submit_spgemm(&SpGemmSpec::new("m", "m")).unwrap();
        let dec = e.autotuner().spgemm_decision("m", "m").unwrap().clone();
        assert_eq!(r1.chosen, dec.im);
        assert_eq!(dec.explored, 2, "both kernels explored");
        assert_eq!(dec.candidates.len(), 2);
        let n = e.autotuner().measurements();
        let r2 = e.submit_spgemm(&SpGemmSpec::new("m", "m")).unwrap();
        assert_eq!(e.autotuner().measurements(), n, "decision is pinned");
        assert_eq!(r2.chosen, dec.im);
        // re-registration forgets the pair decision
        let a2 = erdos_renyi(150, 150, 3.0, &mut Prng::new(193));
        e.register("m", a2).unwrap();
        assert!(e.autotuner().spgemm_decision("m", "m").is_none());
    }

    #[test]
    fn priors_learn_from_history() {
        let mut e = test_engine();
        let a = erdos_renyi(400, 400, 5.0, &mut Prng::new(183));
        e.register("m", a).unwrap();
        let cls = e.registry().get("m").unwrap().classification.clone();
        let before = e.planner().prior(cls.class, Impl::Csr);
        for _ in 0..4 {
            e.submit(&JobSpec::new("m", 4).with_impl(Impl::Csr)).unwrap();
        }
        let after = e.planner().prior(cls.class, Impl::Csr);
        assert_ne!(before, after);
    }
}
