//! The concurrent serving front-end: a bounded MPMC job queue over
//! the engine, with admission control, batch coalescing, per-tenant
//! namespaces, and persisted autotune state.
//!
//! The paper's models pick the *plan*; this layer makes the intake
//! worthy of the north-star serving scenario. Client threads submit
//! [`ServeRequest`]s through a cloneable [`ServeHandle`]; each
//! accepted request yields a [`Ticket`] the client blocks on. A
//! single serving loop ([`Server::run`]) drains the queue in slices
//! and, when coalescing is on, merges queued SpMM jobs that share a
//! matrix into one [`Engine::submit_batch_collect`] call — the pooled
//! dense buffers and the cached execution schedule stay warm across
//! the whole group, which is exactly the engine's batch fast path.
//! Pipeline jobs ([`ServeWork::Pipeline`]) ride the same queue and
//! run as singles inside a coalescing cycle — a pipeline is already
//! the engine's multi-op fast path (one schedule, pooled
//! intermediates), so there is nothing further to merge.
//!
//! Design decisions, each pinned by a test:
//!
//! * **Bounded queue, explicit backpressure.** The ring has fixed
//!   capacity; a full queue answers [`Submit::Rejected`] with the
//!   observed depth instead of blocking the producer
//!   (`tests/integration_serve.rs`). `std`-only: one `Mutex` around
//!   the ring + a `Condvar` for the consumer — no external crates,
//!   matching the offline build.
//! * **Determinism under concurrency.** Every SpMM request carries
//!   its own operand seed, so results are a pure function of
//!   `(matrix, d, impl, seed)` no matter how client threads
//!   interleave or how jobs coalesce. `tests/prop_serve.rs` replays
//!   every served mix sequentially and demands bitwise equality.
//! * **Panic containment.** Kernel panics are caught at this layer
//!   ([`Error::Panic`]); a panicking job inside a coalesced group
//!   fails alone — the group falls back to per-job isolation — and
//!   the engine keeps serving (extends the worker pool's
//!   panic-reaping guarantee up through the front-end).
//! * **Tenant isolation.** Requests name a tenant; the server scopes
//!   matrix names with [`MatrixRegistry::scoped`], so tenants cannot
//!   observe (or collide with) each other's matrices, and the
//!   registry's per-tenant shards keep one tenant's reorder from
//!   stalling another's lookups.
//! * **Restart-cheap.** With a `state_path` configured the server
//!   loads the persisted [`crate::report::AutotuneState`] at
//!   construction (after the caller registered its matrices) and
//!   saves on shutdown — a restarted server pins the same decisions
//!   with zero new exploration measurements.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::engine::{Engine, PipelineOutput, WorkloadOutcome};
use crate::coordinator::job::{JobSpec, PipelineKind, PipelineSpec, SpGemmSpec};
use crate::coordinator::registry::MatrixRegistry;
use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::sparse::Csr;

/// Serving-loop options.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Ring capacity; a submission finding the ring full is rejected.
    pub queue_capacity: usize,
    /// Most jobs drained per serving cycle (bounds coalesced-batch
    /// size and keeps admission latency bounded under load).
    pub max_drain: usize,
    /// Merge queued SpMM jobs sharing a matrix into one engine batch.
    pub coalesce: bool,
    /// Load the autotune snapshot from here at construction and save
    /// it back on shutdown (`None` = in-memory only).
    pub state_path: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { queue_capacity: 64, max_drain: 32, coalesce: true, state_path: None }
    }
}

/// The work inside a request. SpMM carries the seed its dense operand
/// is drawn from ([`Engine::submit_collect`]); SpGEMM's operands are
/// both registered matrices, so it needs none.
#[derive(Debug, Clone)]
pub enum ServeWork {
    /// Dense-operand multiply (`C = A·B`, `B` seeded).
    SpMM {
        /// The job, with matrix named *tenant-locally*.
        spec: JobSpec,
        /// Seed for the dense operand.
        seed: u64,
    },
    /// Sparse-sparse multiply (`C = A·B`, both registered).
    SpGemm {
        /// The pair, named tenant-locally.
        spec: SpGemmSpec,
    },
    /// Multi-op pipeline ([`Engine::submit_pipeline_collect`]); dense
    /// inputs are drawn from `seed` by the shared generators, so a
    /// pipeline reply is a pure function of `(matrix, kind, impl,
    /// seed)` like every other served job.
    Pipeline {
        /// The chain, with matrix named tenant-locally.
        spec: PipelineSpec,
        /// Seed for the chain's dense inputs.
        seed: u64,
    },
}

/// One queued unit of work. Matrix names inside are tenant-local; the
/// server scopes them ([`MatrixRegistry::scoped`]) before touching the
/// engine.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Namespace the request's matrix names live in (`""` = default).
    pub tenant: String,
    /// Caller-chosen correlation id, echoed in the reply.
    pub tag: u64,
    /// The work itself.
    pub work: ServeWork,
}

impl ServeRequest {
    /// An SpMM request (tag 0 — see [`ServeRequest::with_tag`]).
    pub fn spmm(tenant: impl Into<String>, spec: JobSpec, seed: u64) -> ServeRequest {
        ServeRequest { tenant: tenant.into(), tag: 0, work: ServeWork::SpMM { spec, seed } }
    }

    /// An SpGEMM request.
    pub fn spgemm(tenant: impl Into<String>, spec: SpGemmSpec) -> ServeRequest {
        ServeRequest { tenant: tenant.into(), tag: 0, work: ServeWork::SpGemm { spec } }
    }

    /// A pipeline request.
    pub fn pipeline(tenant: impl Into<String>, spec: PipelineSpec, seed: u64) -> ServeRequest {
        ServeRequest { tenant: tenant.into(), tag: 0, work: ServeWork::Pipeline { spec, seed } }
    }

    /// Set the correlation tag.
    pub fn with_tag(mut self, tag: u64) -> ServeRequest {
        self.tag = tag;
        self
    }
}

/// A served product: dense row-major `C` for SpMM, CSR `C` for
/// SpGEMM.
#[derive(Debug, Clone)]
pub enum ServeOutput {
    /// Row-major `nrows × d` product.
    Dense(Vec<f64>),
    /// Sparse product.
    Sparse(Csr),
    /// Pipeline result (final features / power block + spectral stats
    /// / PageRank scores).
    Pipeline(PipelineOutput),
}

impl ServeOutput {
    /// The dense product, if this was an SpMM job.
    pub fn dense(&self) -> Option<&[f64]> {
        match self {
            ServeOutput::Dense(v) => Some(v),
            _ => None,
        }
    }

    /// The sparse product, if this was an SpGEMM job.
    pub fn sparse(&self) -> Option<&Csr> {
        match self {
            ServeOutput::Sparse(c) => Some(c),
            _ => None,
        }
    }

    /// The chain result, if this was a pipeline job.
    pub fn pipeline(&self) -> Option<&PipelineOutput> {
        match self {
            ServeOutput::Pipeline(p) => Some(p),
            _ => None,
        }
    }
}

/// What a fulfilled ticket carries back to the client.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// The request's correlation tag.
    pub tag: u64,
    /// The engine's measurement record for the job.
    pub outcome: WorkloadOutcome,
    /// The product itself.
    pub output: ServeOutput,
    /// Whether the job executed inside a coalesced batch.
    pub coalesced: bool,
}

struct TicketInner {
    slot: Mutex<Option<Result<ServeReply>>>,
    ready: Condvar,
}

/// A claim on one submitted job's eventual result. Cloneable (the
/// queue keeps one clone); the result itself is take-once — whichever
/// caller `wait`s (or `try_take`s) first gets it.
#[derive(Clone)]
pub struct Ticket(Arc<TicketInner>);

impl Ticket {
    fn new() -> Ticket {
        Ticket(Arc::new(TicketInner { slot: Mutex::new(None), ready: Condvar::new() }))
    }

    fn fulfill(&self, r: Result<ServeReply>) {
        let mut slot = self.0.slot.lock().unwrap();
        *slot = Some(r);
        self.0.ready.notify_all();
    }

    /// Block until the job completes and take its result.
    pub fn wait(&self) -> Result<ServeReply> {
        let mut slot = self.0.slot.lock().unwrap();
        loop {
            match slot.take() {
                Some(r) => return r,
                None => slot = self.0.ready.wait(slot).unwrap(),
            }
        }
    }

    /// Non-blocking: the result if the job already completed (and
    /// nobody took it yet).
    pub fn try_take(&self) -> Option<Result<ServeReply>> {
        self.0.slot.lock().unwrap().take()
    }
}

/// Admission-control outcome: a ticket, or explicit backpressure.
pub enum Submit {
    /// Queued; wait on the ticket.
    Accepted(Ticket),
    /// Ring full — retry later (the producer is *not* blocked).
    Rejected {
        /// Queue depth observed at rejection (== capacity).
        queue_depth: usize,
    },
}

impl Submit {
    /// True when the job was queued.
    pub fn is_accepted(&self) -> bool {
        matches!(self, Submit::Accepted(_))
    }

    /// The ticket, if accepted.
    pub fn ticket(self) -> Option<Ticket> {
        match self {
            Submit::Accepted(t) => Some(t),
            Submit::Rejected { .. } => None,
        }
    }
}

struct QueuedJob {
    req: ServeRequest,
    ticket: Ticket,
}

/// Fixed-capacity ring of queued jobs. `slots` never grows — the
/// bound is structural, not a checked counter.
struct Ring {
    slots: Vec<Option<QueuedJob>>,
    head: usize,
    len: usize,
    closed: bool,
}

impl Ring {
    fn push(&mut self, j: QueuedJob) -> bool {
        if self.len == self.slots.len() {
            return false;
        }
        let tail = (self.head + self.len) % self.slots.len();
        self.slots[tail] = Some(j);
        self.len += 1;
        true
    }

    fn pop(&mut self) -> Option<QueuedJob> {
        if self.len == 0 {
            return None;
        }
        let j = self.slots[self.head].take();
        self.head = (self.head + 1) % self.slots.len();
        self.len -= 1;
        j
    }
}

/// The bounded MPMC job queue: `Mutex` + `Condvar` over a fixed ring,
/// `std`-only. Producers ([`ServeHandle`]) never block — a full ring
/// rejects; the consumer ([`Server::run`]) blocks on the condvar until
/// jobs arrive or the queue closes.
pub struct JobQueue {
    ring: Mutex<Ring>,
    not_empty: Condvar,
    submitted: AtomicUsize,
    rejected: AtomicUsize,
    peak_depth: AtomicUsize,
}

impl JobQueue {
    fn new(capacity: usize) -> JobQueue {
        let capacity = capacity.max(1);
        JobQueue {
            ring: Mutex::new(Ring {
                slots: (0..capacity).map(|_| None).collect(),
                head: 0,
                len: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            submitted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            peak_depth: AtomicUsize::new(0),
        }
    }

    /// Admit a request: a ticket when there is room, explicit
    /// [`Submit::Rejected`] backpressure when the ring is full, `Err`
    /// once the queue has closed. Never blocks.
    pub fn try_submit(&self, req: ServeRequest) -> Result<Submit> {
        let mut ring = self.ring.lock().unwrap();
        if ring.closed {
            return Err(Error::Usage("serve queue is closed".into()));
        }
        if ring.len == ring.slots.len() {
            let depth = ring.len;
            drop(ring);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Ok(Submit::Rejected { queue_depth: depth });
        }
        let ticket = Ticket::new();
        ring.push(QueuedJob { req, ticket: ticket.clone() });
        let depth = ring.len;
        drop(ring);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.peak_depth.fetch_max(depth, Ordering::Relaxed);
        self.not_empty.notify_one();
        Ok(Submit::Accepted(ticket))
    }

    /// Close the queue: new submissions fail, the serving loop drains
    /// what is already queued and then returns.
    pub fn close(&self) {
        self.ring.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// Jobs currently queued.
    pub fn depth(&self) -> usize {
        self.ring.lock().unwrap().len
    }

    /// Lifetime counters: `(submitted, rejected, peak_depth)`.
    pub fn counters(&self) -> (usize, usize, usize) {
        (
            self.submitted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.peak_depth.load(Ordering::Relaxed),
        )
    }

    /// Take up to `max` jobs, blocking while the queue is empty and
    /// open. `None` = closed and fully drained (shutdown).
    fn drain(&self, max: usize) -> Option<Vec<QueuedJob>> {
        let mut ring = self.ring.lock().unwrap();
        loop {
            if ring.len > 0 {
                let mut out = Vec::new();
                while out.len() < max.max(1) {
                    match ring.pop() {
                        Some(j) => out.push(j),
                        None => break,
                    }
                }
                return Some(out);
            }
            if ring.closed {
                return None;
            }
            ring = self.not_empty.wait(ring).unwrap();
        }
    }
}

/// A cloneable producer handle onto the server's queue — one per
/// client thread.
#[derive(Clone)]
pub struct ServeHandle {
    queue: Arc<JobQueue>,
}

impl ServeHandle {
    /// Submit a request ([`JobQueue::try_submit`] semantics).
    pub fn submit(&self, req: ServeRequest) -> Result<Submit> {
        self.queue.try_submit(req)
    }

    /// Close the queue (typically: the last client finishing).
    pub fn close(&self) {
        self.queue.close()
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.queue.depth()
    }
}

/// Counters the serving loop accumulates; rendered into
/// `BENCH_serve.json` by [`ServeStats::to_json`].
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Jobs completed successfully.
    pub jobs_done: usize,
    /// Jobs that returned `Err` (including contained panics).
    pub jobs_failed: usize,
    /// Serving cycles (queue drains) run.
    pub batches: usize,
    /// Jobs that executed inside a coalesced engine batch.
    pub coalesced_jobs: usize,
    /// Lifetime submissions accepted by the queue.
    pub submitted: usize,
    /// Submissions rejected by backpressure.
    pub rejected: usize,
    /// Deepest the queue ever got.
    pub max_queue_depth: usize,
    /// Wall time spent inside [`Server::run`].
    pub wall_secs: f64,
}

impl ServeStats {
    /// Fraction of completed jobs that rode a coalesced batch.
    pub fn coalesce_rate(&self) -> f64 {
        if self.jobs_done == 0 {
            0.0
        } else {
            self.coalesced_jobs as f64 / self.jobs_done as f64
        }
    }

    /// Completed jobs per wall second.
    pub fn jobs_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.jobs_done as f64 / self.wall_secs
        }
    }

    /// One flat `BENCH_serve.json`-style record (same wrapper shape as
    /// the other perf artifacts, so the CI greps stay uniform).
    pub fn to_json(&self, bench: &str, clients: usize) -> String {
        format!(
            "{{\"records\": [\n  {{\"bench\": \"{}\", \"clients\": {}, \"jobs_done\": {}, \
             \"jobs_failed\": {}, \"batches\": {}, \"coalesced_jobs\": {}, \
             \"coalesce_rate\": {:.4}, \"submitted\": {}, \"rejected\": {}, \
             \"max_queue_depth\": {}, \"wall_secs\": {:.4}, \"jobs_per_sec\": {:.4}}}\n]}}\n",
            bench,
            clients,
            self.jobs_done,
            self.jobs_failed,
            self.batches,
            self.coalesced_jobs,
            self.coalesce_rate(),
            self.submitted,
            self.rejected,
            self.max_queue_depth,
            self.wall_secs,
            self.jobs_per_sec(),
        )
    }
}

/// The serving loop: owns the engine, drains the queue, coalesces,
/// contains panics, and persists autotune state (module docs).
pub struct Server {
    engine: Engine,
    queue: Arc<JobQueue>,
    config: ServeConfig,
    stats: ServeStats,
    /// Successfully executed requests, in execution order — the
    /// replay script for the differential property test.
    log: Vec<ServeRequest>,
    restored: bool,
}

impl Server {
    /// Wrap an engine. Register matrices on the engine *first*: when
    /// `state_path` is configured the snapshot is adopted here, and
    /// decisions for unregistered matrices are skipped (registration
    /// also forgets a name's decisions).
    pub fn new(mut engine: Engine, config: ServeConfig) -> Server {
        let restored = match &config.state_path {
            Some(p) => engine.load_state(p),
            None => false,
        };
        let queue = Arc::new(JobQueue::new(config.queue_capacity));
        Server { engine, queue, config, stats: ServeStats::default(), log: Vec::new(), restored }
    }

    /// A producer handle for client threads.
    pub fn handle(&self) -> ServeHandle {
        ServeHandle { queue: Arc::clone(&self.queue) }
    }

    /// Whether construction adopted a persisted snapshot.
    pub fn restored(&self) -> bool {
        self.restored
    }

    /// The wrapped engine.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Mutable engine access (registration between runs, tests).
    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// Counters so far.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Successfully executed requests in execution order.
    pub fn execution_log(&self) -> &[ServeRequest] {
        &self.log
    }

    /// Close the queue (equivalent to any handle's `close`).
    pub fn close(&self) {
        self.queue.close()
    }

    /// Scope a request's SpMM spec into its tenant's namespace.
    pub fn scoped_spmm(tenant: &str, spec: &JobSpec) -> JobSpec {
        JobSpec { matrix: MatrixRegistry::scoped(tenant, &spec.matrix), ..spec.clone() }
    }

    /// Scope a request's SpGEMM spec into its tenant's namespace.
    pub fn scoped_spgemm(tenant: &str, spec: &SpGemmSpec) -> SpGemmSpec {
        SpGemmSpec {
            a: MatrixRegistry::scoped(tenant, &spec.a),
            b: MatrixRegistry::scoped(tenant, &spec.b),
            force_impl: spec.force_impl,
        }
    }

    /// Scope a request's pipeline spec into its tenant's namespace —
    /// including the SpGEMM→SpMM chain's right operand, which is a
    /// registered name too.
    pub fn scoped_pipeline(tenant: &str, spec: &PipelineSpec) -> PipelineSpec {
        let kind = match &spec.kind {
            PipelineKind::SpGemmSpMM { b, d } => {
                PipelineKind::SpGemmSpMM { b: MatrixRegistry::scoped(tenant, b), d: *d }
            }
            other => other.clone(),
        };
        PipelineSpec {
            matrix: MatrixRegistry::scoped(tenant, &spec.matrix),
            kind,
            force_impl: spec.force_impl,
        }
    }

    /// Serve until the queue closes and drains: each cycle takes up to
    /// `max_drain` queued jobs, coalesces SpMM jobs sharing a (scoped)
    /// matrix into one engine batch, runs the rest individually, and
    /// fulfills every ticket. On return (shutdown) the autotune state
    /// is persisted when configured.
    pub fn run(&mut self) {
        let t = Timer::start();
        while let Some(jobs) = self.queue.drain(self.config.max_drain) {
            self.cycle(jobs);
        }
        self.stats.wall_secs += t.elapsed_secs();
        let (submitted, rejected, peak) = self.queue.counters();
        self.stats.submitted = submitted;
        self.stats.rejected = rejected;
        self.stats.max_queue_depth = peak;
        if let Some(p) = &self.config.state_path {
            if let Err(e) = self.engine.save_state(p) {
                eprintln!("warning: could not persist autotune state to {p}: {e}");
            }
        }
    }

    fn cycle(&mut self, jobs: Vec<QueuedJob>) {
        self.stats.batches += 1;
        let mut singles: Vec<QueuedJob> = Vec::new();
        // group SpMM jobs by scoped matrix, preserving drain order
        // within each group; group insertion order is kept too so the
        // execution log stays deterministic for a deterministic queue
        let mut keys: Vec<String> = Vec::new();
        let mut groups: HashMap<String, Vec<QueuedJob>> = HashMap::new();
        for j in jobs {
            match &j.req.work {
                ServeWork::SpMM { spec, .. } if self.config.coalesce => {
                    let key = MatrixRegistry::scoped(&j.req.tenant, &spec.matrix);
                    if !groups.contains_key(&key) {
                        keys.push(key.clone());
                    }
                    groups.entry(key).or_default().push(j);
                }
                _ => singles.push(j),
            }
        }
        for key in keys {
            let group = groups.remove(&key).expect("keyed above");
            if group.len() < 2 {
                singles.extend(group);
                continue;
            }
            self.run_coalesced(group);
        }
        for j in singles {
            self.run_single(j);
        }
    }

    /// Run a same-matrix group as one engine batch. If the batch
    /// fails (Err or contained panic), fall back to per-job isolation
    /// so only the offending jobs fail.
    fn run_coalesced(&mut self, group: Vec<QueuedJob>) {
        let specs: Vec<(JobSpec, u64)> = group
            .iter()
            .map(|j| match &j.req.work {
                ServeWork::SpMM { spec, seed } => {
                    (Server::scoped_spmm(&j.req.tenant, spec), *seed)
                }
                ServeWork::SpGemm { .. } => unreachable!("coalesced groups are SpMM-only"),
            })
            .collect();
        let engine = &mut self.engine;
        let res = contain(catch_unwind(AssertUnwindSafe(|| engine.submit_batch_collect(&specs))));
        match res {
            Ok((rep, outs)) => {
                for (j, (rec, out)) in
                    group.into_iter().zip(rep.records.into_iter().zip(outs.into_iter()))
                {
                    self.log.push(j.req.clone());
                    self.stats.jobs_done += 1;
                    self.stats.coalesced_jobs += 1;
                    j.ticket.fulfill(Ok(ServeReply {
                        tag: j.req.tag,
                        outcome: WorkloadOutcome::SpMM(rec),
                        output: ServeOutput::Dense(out),
                        coalesced: true,
                    }));
                }
            }
            Err(_) => {
                for j in group {
                    self.run_single(j);
                }
            }
        }
    }

    fn run_single(&mut self, j: QueuedJob) {
        let req = j.req;
        let engine = &mut self.engine;
        let result: Result<ServeReply> = match &req.work {
            ServeWork::SpMM { spec, seed } => {
                let scoped = Server::scoped_spmm(&req.tenant, spec);
                let seed = *seed;
                contain(catch_unwind(AssertUnwindSafe(|| engine.submit_collect(&scoped, seed))))
                    .map(|(rec, out)| ServeReply {
                        tag: req.tag,
                        outcome: WorkloadOutcome::SpMM(rec),
                        output: ServeOutput::Dense(out),
                        coalesced: false,
                    })
            }
            ServeWork::SpGemm { spec } => {
                let scoped = Server::scoped_spgemm(&req.tenant, spec);
                contain(catch_unwind(AssertUnwindSafe(|| engine.submit_spgemm_collect(&scoped))))
                    .map(|(rec, c)| ServeReply {
                        tag: req.tag,
                        outcome: WorkloadOutcome::SpGemm(rec),
                        output: ServeOutput::Sparse(c),
                        coalesced: false,
                    })
            }
            ServeWork::Pipeline { spec, seed } => {
                let scoped = Server::scoped_pipeline(&req.tenant, spec);
                let seed = *seed;
                contain(catch_unwind(AssertUnwindSafe(|| {
                    engine.submit_pipeline_collect(&scoped, seed)
                })))
                .map(|(rec, out)| ServeReply {
                    tag: req.tag,
                    outcome: WorkloadOutcome::Pipeline(rec),
                    output: ServeOutput::Pipeline(out),
                    coalesced: false,
                })
            }
        };
        match &result {
            Ok(_) => {
                self.log.push(req);
                self.stats.jobs_done += 1;
            }
            Err(_) => self.stats.jobs_failed += 1,
        }
        j.ticket.fulfill(result);
    }
}

/// Flatten a `catch_unwind` result: a panic becomes [`Error::Panic`]
/// carrying the payload's message, so one poisoned kernel reads as an
/// ordinary failed job.
fn contain<T>(r: std::thread::Result<Result<T>>) -> Result<T> {
    match r {
        Ok(inner) => inner,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(Error::Panic(msg))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(tag: u64) -> ServeRequest {
        ServeRequest::spmm("", JobSpec::new("m", 4), tag).with_tag(tag)
    }

    #[test]
    fn queue_accepts_until_full_then_rejects_without_blocking() {
        let q = JobQueue::new(2);
        assert!(q.try_submit(req(1)).unwrap().is_accepted());
        assert!(q.try_submit(req(2)).unwrap().is_accepted());
        match q.try_submit(req(3)).unwrap() {
            Submit::Rejected { queue_depth } => assert_eq!(queue_depth, 2),
            Submit::Accepted(_) => panic!("full ring must reject"),
        }
        assert_eq!(q.depth(), 2);
        let (submitted, rejected, peak) = q.counters();
        assert_eq!((submitted, rejected, peak), (2, 1, 2));
        // draining opens room again
        let jobs = q.drain(1).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].req.tag, 1, "FIFO");
        assert!(q.try_submit(req(4)).unwrap().is_accepted());
    }

    #[test]
    fn ring_wraps_and_preserves_fifo() {
        let q = JobQueue::new(3);
        for tag in 1..=3 {
            assert!(q.try_submit(req(tag)).unwrap().is_accepted());
        }
        let first = q.drain(2).unwrap();
        assert_eq!(first.iter().map(|j| j.req.tag).collect::<Vec<_>>(), vec![1, 2]);
        // head has advanced; these pushes wrap around the slot array
        assert!(q.try_submit(req(4)).unwrap().is_accepted());
        assert!(q.try_submit(req(5)).unwrap().is_accepted());
        let rest = q.drain(10).unwrap();
        assert_eq!(rest.iter().map(|j| j.req.tag).collect::<Vec<_>>(), vec![3, 4, 5]);
    }

    #[test]
    fn closed_queue_errors_and_drain_returns_none() {
        let q = JobQueue::new(2);
        assert!(q.try_submit(req(1)).unwrap().is_accepted());
        q.close();
        assert!(q.try_submit(req(2)).is_err(), "closed queue must refuse new work");
        // what was queued before the close still drains
        assert_eq!(q.drain(8).unwrap().len(), 1);
        assert!(q.drain(8).is_none(), "closed + empty = shutdown");
    }

    #[test]
    fn ticket_try_take_then_wait_semantics() {
        let t = Ticket::new();
        assert!(t.try_take().is_none(), "unfulfilled ticket has nothing to take");
        t.fulfill(Err(Error::Panic("boom".into())));
        let taken = t.try_take().expect("fulfilled");
        assert!(matches!(taken, Err(Error::Panic(_))));
        assert!(t.try_take().is_none(), "results are take-once");
    }

    #[test]
    fn ticket_wait_blocks_across_threads() {
        let t = Ticket::new();
        let t2 = t.clone();
        let waiter = std::thread::spawn(move || t2.wait());
        // fulfil from this side; the waiter must wake and see it
        t.fulfill(Err(Error::Usage("x".into())));
        let got = waiter.join().unwrap();
        assert!(matches!(got, Err(Error::Usage(_))));
    }

    #[test]
    fn stats_json_carries_the_coalesce_rate() {
        let stats = ServeStats {
            jobs_done: 8,
            coalesced_jobs: 6,
            batches: 2,
            wall_secs: 2.0,
            ..ServeStats::default()
        };
        assert!((stats.coalesce_rate() - 0.75).abs() < 1e-12);
        assert!((stats.jobs_per_sec() - 4.0).abs() < 1e-12);
        let json = stats.to_json("bench_serve", 4);
        assert!(json.contains("\"coalesce_rate\": 0.7500"), "{json}");
        assert!(json.contains("\"bench\": \"bench_serve\""));
        assert!(json.contains("\"clients\": 4"));
        // empty stats divide nothing by zero
        assert_eq!(ServeStats::default().coalesce_rate(), 0.0);
        assert_eq!(ServeStats::default().jobs_per_sec(), 0.0);
    }

    #[test]
    fn serve_output_accessors() {
        let d = ServeOutput::Dense(vec![1.0, 2.0]);
        assert_eq!(d.dense().unwrap().len(), 2);
        assert!(d.sparse().is_none());
        assert!(d.pipeline().is_none());
        let s = ServeOutput::Sparse(Csr::from_dense(1, 1, &[3.0]));
        assert!(s.dense().is_none());
        assert_eq!(s.sparse().unwrap().nnz(), 1);
        let p = ServeOutput::Pipeline(PipelineOutput::Dense(vec![4.0]));
        assert!(p.dense().is_none());
        assert_eq!(p.pipeline().unwrap().data(), &[4.0]);
    }

    #[test]
    fn scoped_pipeline_scopes_every_registered_name() {
        let spec = PipelineSpec::new("m", PipelineKind::SpGemmSpMM { b: "w".into(), d: 4 });
        let scoped = Server::scoped_pipeline("acme", &spec);
        assert_eq!(scoped.matrix, "acme/m");
        match scoped.kind {
            PipelineKind::SpGemmSpMM { ref b, d } => {
                assert_eq!(b, "acme/w");
                assert_eq!(d, 4);
            }
            ref other => panic!("kind must survive scoping: {other:?}"),
        }
        // non-SpGEMM kinds carry no second registered name
        let gcn = PipelineSpec::new("g", PipelineKind::Gcn { dims: vec![8, 8] });
        assert_eq!(Server::scoped_pipeline("t", &gcn).matrix, "t/g");
    }
}
