//! Job and result types for the engine.

use crate::gen::SparsityClass;
use crate::sparse::Reordering;
use crate::spgemm::SpGemmImpl;
use crate::spmm::Impl;

/// Which multiply a job performs — the routing dimension the planner
/// and autotuner branch on. SpMM jobs multiply by a dense `n × d`
/// operand ([`JobSpec`]); SpGEMM jobs multiply by another *registered
/// sparse matrix* ([`SpGemmSpec`]), where output fill-in and the
/// compression factor — not a dense width — drive the traffic models.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Workload {
    /// Multiply by a dense operand of width `d`.
    SpMM { d: usize },
    /// Multiply by the sparse matrix registered under this name.
    SpGemm { b: String },
}

impl std::fmt::Display for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Workload::SpMM { d } => write!(f, "SpMM(d={d})"),
            Workload::SpGemm { b } => write!(f, "SpGEMM(×{b})"),
        }
    }
}

/// A unit of work: multiply registered matrix `matrix` by a dense
/// matrix with `d` columns.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Name the matrix was registered under.
    pub matrix: String,
    /// Dense width.
    pub d: usize,
    /// Force a specific implementation (None = let the planner
    /// route).
    pub force_impl: Option<Impl>,
}

impl JobSpec {
    pub fn new(matrix: impl Into<String>, d: usize) -> JobSpec {
        JobSpec { matrix: matrix.into(), d, force_impl: None }
    }

    pub fn with_impl(mut self, im: Impl) -> JobSpec {
        self.force_impl = Some(im);
        self
    }

    /// This job's workload dimension.
    pub fn workload(&self) -> Workload {
        Workload::SpMM { d: self.d }
    }
}

/// A unit of SpGEMM work: `C = A·B` over two registered matrices.
#[derive(Debug, Clone)]
pub struct SpGemmSpec {
    /// Left operand (registered name).
    pub a: String,
    /// Right operand (registered name).
    pub b: String,
    /// Force a specific kernel (None = let the router decide).
    pub force_impl: Option<SpGemmImpl>,
}

impl SpGemmSpec {
    pub fn new(a: impl Into<String>, b: impl Into<String>) -> SpGemmSpec {
        SpGemmSpec { a: a.into(), b: b.into(), force_impl: None }
    }

    pub fn with_impl(mut self, im: SpGemmImpl) -> SpGemmSpec {
        self.force_impl = Some(im);
        self
    }

    /// This job's workload dimension.
    pub fn workload(&self) -> Workload {
        Workload::SpGemm { b: self.b.clone() }
    }
}

/// Outcome of one executed SpGEMM job.
#[derive(Debug, Clone)]
pub struct SpGemmRecord {
    pub a: String,
    pub b: String,
    /// Class of the left operand's active layout.
    pub class: SparsityClass,
    /// Kernel the job ran on.
    pub chosen: SpGemmImpl,
    /// Exact FLOP count ([`crate::spgemm::spgemm_flops`]).
    pub flops: f64,
    /// Stored nonzeros of the product.
    pub nnz_c: usize,
    /// Measured compression factor `flops / nnz(C)`.
    pub cf: f64,
    /// Planner's predicted GFLOP/s for the chosen kernel (at the cf
    /// the router predicted with).
    pub predicted_gflops: f64,
    /// Model arithmetic intensity used for the prediction.
    pub ai: f64,
    /// Measured wall-clock seconds (median).
    pub secs: f64,
    /// Measured GFLOP/s.
    pub measured_gflops: f64,
}

impl SpGemmRecord {
    /// measured / predicted — 1.0 is a perfect prediction.
    pub fn prediction_ratio(&self) -> f64 {
        if self.predicted_gflops <= 0.0 {
            0.0
        } else {
            self.measured_gflops / self.predicted_gflops
        }
    }
}

/// Outcome of one executed job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub matrix: String,
    pub class: SparsityClass,
    pub d: usize,
    /// Implementation the job ran on.
    pub chosen: Impl,
    /// Matrix ordering the job executed under (non-identity only when
    /// the autotuner pinned a reordering).
    pub reorder: Reordering,
    /// Column-tile width the schedule executed with (`dt == d` means
    /// untiled).
    pub dt: usize,
    /// Planner's predicted GFLOP/s for the chosen implementation.
    pub predicted_gflops: f64,
    /// Model arithmetic intensity used for the prediction.
    pub ai: f64,
    /// Measured wall-clock seconds (median over the job's
    /// iterations).
    pub secs: f64,
    /// Measured GFLOP/s.
    pub measured_gflops: f64,
}

impl JobRecord {
    /// measured / predicted — 1.0 is a perfect prediction.
    pub fn prediction_ratio(&self) -> f64 {
        if self.predicted_gflops <= 0.0 {
            0.0
        } else {
            self.measured_gflops / self.predicted_gflops
        }
    }
}

/// Aggregate prediction accuracy over a set of records.
#[derive(Debug, Clone)]
pub struct PredictionReport {
    pub n_jobs: usize,
    /// Geometric mean of measured/predicted.
    pub geomean_ratio: f64,
    /// Mean absolute relative error of log-ratio.
    pub mean_abs_log_err: f64,
    /// Fraction of jobs where the chosen impl was measured-best among
    /// the impls actually tried for the same (matrix, d). Only
    /// meaningful when jobs sweep impls.
    pub routing_hit_rate: Option<f64>,
}

impl PredictionReport {
    /// Summarise a slice of job records.
    pub fn of(records: &[JobRecord]) -> PredictionReport {
        let n = records.len();
        if n == 0 {
            return PredictionReport {
                n_jobs: 0,
                geomean_ratio: 0.0,
                mean_abs_log_err: 0.0,
                routing_hit_rate: None,
            };
        }
        let mut log_sum = 0.0;
        let mut abs_log = 0.0;
        for r in records {
            let ratio = r.prediction_ratio().max(1e-12);
            log_sum += ratio.ln();
            abs_log += ratio.ln().abs();
        }
        PredictionReport {
            n_jobs: n,
            geomean_ratio: (log_sum / n as f64).exp(),
            mean_abs_log_err: abs_log / n as f64,
            routing_hit_rate: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(pred: f64, meas: f64) -> JobRecord {
        JobRecord {
            matrix: "m".into(),
            class: SparsityClass::Random,
            d: 4,
            chosen: Impl::Csr,
            reorder: Reordering::None,
            dt: 4,
            predicted_gflops: pred,
            ai: 0.1,
            secs: 0.01,
            measured_gflops: meas,
        }
    }

    #[test]
    fn ratio_and_geomean() {
        let records = vec![rec(2.0, 1.0), rec(1.0, 2.0)];
        assert_eq!(records[0].prediction_ratio(), 0.5);
        let rep = PredictionReport::of(&records);
        assert!((rep.geomean_ratio - 1.0).abs() < 1e-12);
        assert!((rep.mean_abs_log_err - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn empty_report() {
        let rep = PredictionReport::of(&[]);
        assert_eq!(rep.n_jobs, 0);
    }

    #[test]
    fn jobspec_builder() {
        let j = JobSpec::new("x", 16).with_impl(Impl::Csb);
        assert_eq!(j.force_impl, Some(Impl::Csb));
        assert_eq!(j.d, 16);
        assert_eq!(j.workload(), Workload::SpMM { d: 16 });
    }

    #[test]
    fn spgemm_spec_and_record() {
        let s = SpGemmSpec::new("a", "b").with_impl(SpGemmImpl::PbMerge);
        assert_eq!(s.force_impl, Some(SpGemmImpl::PbMerge));
        assert_eq!(s.workload(), Workload::SpGemm { b: "b".into() });
        assert_eq!(format!("{}", s.workload()), "SpGEMM(×b)");
        let r = SpGemmRecord {
            a: "a".into(),
            b: "b".into(),
            class: SparsityClass::Random,
            chosen: SpGemmImpl::Hash,
            flops: 100.0,
            nnz_c: 10,
            cf: 10.0,
            predicted_gflops: 2.0,
            ai: 0.1,
            secs: 0.01,
            measured_gflops: 1.0,
        };
        assert_eq!(r.prediction_ratio(), 0.5);
    }
}
