//! Job and result types for the engine.

use crate::gen::SparsityClass;
use crate::model::{AiParams, PipelineParams};
use crate::sparse::Reordering;
use crate::spgemm::SpGemmImpl;
use crate::spmm::Impl;
use crate::workloads::OpSecs;

/// Which multiply a job performs — the routing dimension the planner
/// and autotuner branch on. SpMM jobs multiply by a dense `n × d`
/// operand ([`JobSpec`]); SpGEMM jobs multiply by another *registered
/// sparse matrix* ([`SpGemmSpec`]), where output fill-in and the
/// compression factor — not a dense width — drive the traffic models.
/// The pipeline variants name multi-op chains ([`PipelineSpec`]),
/// where *inter-op* reuse joins the traffic model
/// ([`crate::model::bytes_pipeline`]) and the router tunes the whole
/// chain, not each op.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Workload {
    /// Multiply by a dense operand of width `d`.
    SpMM { d: usize },
    /// Multiply by the sparse matrix registered under this name.
    SpGemm { b: String },
    /// Pipeline: `layers` chained GCN layers (SpMM then dense
    /// transform + ReLU), input feature width `d`.
    GcnLayer { layers: usize, d: usize },
    /// Pipeline: `iters` chained block power iterations (SpMM then
    /// normalize) over a `d`-wide block.
    PowerIteration { d: usize, iters: usize },
    /// Pipeline: batched PageRank, one dense column per
    /// personalization seed, up to `iters` chained iterations.
    BatchedPageRank { seeds: usize, iters: usize },
    /// Pipeline: SpGEMM against the sparse matrix registered as `b`,
    /// then SpMM of the product by a `d`-wide dense block.
    SpGemmSpMM { b: String, d: usize },
}

impl std::fmt::Display for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Workload::SpMM { d } => write!(f, "SpMM(d={d})"),
            Workload::SpGemm { b } => write!(f, "SpGEMM(×{b})"),
            Workload::GcnLayer { layers, d } => write!(f, "GCN(layers={layers},d={d})"),
            Workload::PowerIteration { d, iters } => write!(f, "Power(d={d},iters={iters})"),
            Workload::BatchedPageRank { seeds, iters } => {
                write!(f, "PageRank(seeds={seeds},iters={iters})")
            }
            Workload::SpGemmSpMM { b, d } => write!(f, "SpGEMM+SpMM(×{b},d={d})"),
        }
    }
}

/// A unit of work: multiply registered matrix `matrix` by a dense
/// matrix with `d` columns.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Name the matrix was registered under.
    pub matrix: String,
    /// Dense width.
    pub d: usize,
    /// Force a specific implementation (None = let the planner
    /// route).
    pub force_impl: Option<Impl>,
}

impl JobSpec {
    pub fn new(matrix: impl Into<String>, d: usize) -> JobSpec {
        JobSpec { matrix: matrix.into(), d, force_impl: None }
    }

    pub fn with_impl(mut self, im: Impl) -> JobSpec {
        self.force_impl = Some(im);
        self
    }

    /// This job's workload dimension.
    pub fn workload(&self) -> Workload {
        Workload::SpMM { d: self.d }
    }
}

/// A unit of SpGEMM work: `C = A·B` over two registered matrices.
#[derive(Debug, Clone)]
pub struct SpGemmSpec {
    /// Left operand (registered name).
    pub a: String,
    /// Right operand (registered name).
    pub b: String,
    /// Force a specific kernel (None = let the router decide).
    pub force_impl: Option<SpGemmImpl>,
}

impl SpGemmSpec {
    pub fn new(a: impl Into<String>, b: impl Into<String>) -> SpGemmSpec {
        SpGemmSpec { a: a.into(), b: b.into(), force_impl: None }
    }

    pub fn with_impl(mut self, im: SpGemmImpl) -> SpGemmSpec {
        self.force_impl = Some(im);
        self
    }

    /// This job's workload dimension.
    pub fn workload(&self) -> Workload {
        Workload::SpGemm { b: self.b.clone() }
    }
}

/// Shape of a multi-op pipeline: which chain to run and its
/// per-chain parameters. Dense inputs (feature blocks, weights, start
/// vectors) are *not* stored here — the engine draws them
/// deterministically from the job seed via the shared generators in
/// [`crate::workloads`] (`gcn_random_inputs`, `power_random_input`),
/// so a pipeline spec stays cheap to clone, coalesce, and persist.
#[derive(Debug, Clone, PartialEq)]
pub enum PipelineKind {
    /// GCN forward pass: `dims` is the width chain `d0 → d1 → …`
    /// (`dims.len() − 1` layers; `dims[0]` is the input feature
    /// width).
    Gcn { dims: Vec<usize> },
    /// Block power iteration: `iters` rounds over an `n × d` block.
    PowerIteration { d: usize, iters: usize },
    /// Batched personalized PageRank over the transition operator
    /// derived from the registered graph
    /// ([`crate::workloads::transition_matrix`]).
    PageRank { seeds: Vec<usize>, alpha: f64, tol: f64, iters: usize },
    /// SpGEMM against registered matrix `b`, then SpMM of the product
    /// by a `d`-wide dense block.
    SpGemmSpMM { b: String, d: usize },
}

impl PipelineKind {
    /// This chain's workload dimension (the shape key decisions and
    /// persisted plans are pinned under).
    pub fn workload(&self) -> Workload {
        match self {
            PipelineKind::Gcn { dims } => {
                Workload::GcnLayer { layers: dims.len().saturating_sub(1), d: dims[0] }
            }
            PipelineKind::PowerIteration { d, iters } => {
                Workload::PowerIteration { d: *d, iters: *iters }
            }
            PipelineKind::PageRank { seeds, iters, .. } => {
                Workload::BatchedPageRank { seeds: seeds.len(), iters: *iters }
            }
            PipelineKind::SpGemmSpMM { b, d } => Workload::SpGemmSpMM { b: b.clone(), d: *d },
        }
    }

    /// The dense width the chain's cached schedule and kernel are
    /// keyed on (the intermediate block's width at the chain head).
    pub fn d(&self) -> usize {
        match self {
            PipelineKind::Gcn { dims } => dims[0],
            PipelineKind::PowerIteration { d, .. } => *d,
            PipelineKind::PageRank { seeds, .. } => seeds.len(),
            PipelineKind::SpGemmSpMM { d, .. } => *d,
        }
    }

    /// Chained SpMM applications at full length (PageRank may stop
    /// earlier on convergence — records carry the executed count).
    pub fn ops(&self) -> usize {
        match self {
            PipelineKind::Gcn { dims } => dims.len().saturating_sub(1),
            PipelineKind::PowerIteration { iters, .. } => *iters,
            PipelineKind::PageRank { iters, .. } => *iters,
            PipelineKind::SpGemmSpMM { .. } => 1,
        }
    }

    /// Model-side shape of this chain for a matrix with `n` rows and
    /// `nnz` stored values, at an executed chain length of `ops`
    /// (pass [`PipelineKind::ops`] for predictions). The SpMM term
    /// uses the chain-head width ([`PipelineKind::d`]; for GCN the
    /// mean layer input width, since widths change per layer); the
    /// non-SpMM stages ride along as `extra_flops`/`extra_bytes`:
    ///
    /// * GCN — dense transforms `Σ 2·n·d_in·d_out` FLOPs with their
    ///   weight panels `Σ 8·d_in·d_out` streamed once each (the
    ///   intermediate feature blocks are already charged by the SpMM
    ///   terms).
    /// * Power iteration — per round: normalize + residual sweeps of
    ///   the block (`≈ 6·n·d`) and the first-column Rayleigh dots
    ///   (`≈ 4·n`); no extra DRAM streams beyond the resident block.
    /// * PageRank — per round: the rank-one update sweep
    ///   (`≈ 4·n·d`); same residency argument.
    /// * SpGEMM+SpMM — the SpMM leg only; the SpGEMM leg's FLOPs are
    ///   data-dependent and recorded separately.
    pub fn pipeline_params(&self, n: usize, nnz: usize, ops: usize) -> PipelineParams {
        let nf = n as f64;
        match self {
            PipelineKind::Gcn { dims } => {
                let widths = &dims[..dims.len().saturating_sub(1)];
                let mean_d = (widths.iter().sum::<usize>() / widths.len().max(1)).max(1);
                let (mut xf, mut xb) = (0.0, 0.0);
                for w in dims.windows(2) {
                    xf += 2.0 * nf * w[0] as f64 * w[1] as f64;
                    xb += 8.0 * w[0] as f64 * w[1] as f64;
                }
                PipelineParams::new(AiParams::new(n, mean_d, nnz), ops).with_extra(xf, xb)
            }
            PipelineKind::PowerIteration { d, .. } => {
                let df = *d as f64;
                PipelineParams::new(AiParams::new(n, *d, nnz), ops)
                    .with_extra(ops as f64 * (6.0 * nf * df + 4.0 * nf), 0.0)
            }
            PipelineKind::PageRank { seeds, .. } => {
                let d = seeds.len();
                PipelineParams::new(AiParams::new(n, d, nnz), ops)
                    .with_extra(ops as f64 * 4.0 * nf * d as f64, 0.0)
            }
            PipelineKind::SpGemmSpMM { d, .. } => {
                PipelineParams::new(AiParams::new(n, *d, nnz), ops)
            }
        }
    }
}

/// A unit of pipeline work: run a multi-op chain over a registered
/// matrix, routed and tuned as one whole ([`PipelineKind`]).
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    /// Name the matrix was registered under.
    pub matrix: String,
    /// Which chain to run.
    pub kind: PipelineKind,
    /// Force a specific implementation (None = let the planner /
    /// pinned pipeline plan route).
    pub force_impl: Option<Impl>,
}

impl PipelineSpec {
    pub fn new(matrix: impl Into<String>, kind: PipelineKind) -> PipelineSpec {
        PipelineSpec { matrix: matrix.into(), kind, force_impl: None }
    }

    pub fn with_impl(mut self, im: Impl) -> PipelineSpec {
        self.force_impl = Some(im);
        self
    }

    /// This job's workload dimension.
    pub fn workload(&self) -> Workload {
        self.kind.workload()
    }
}

/// Outcome of one executed pipeline job: whole-chain numbers plus the
/// per-op wall-time breakdown (the fix for the old `bench_workloads`
/// accounting bug, which divided SpMM-only FLOPs by whole-pipeline
/// time).
#[derive(Debug, Clone)]
pub struct PipelineRecord {
    pub matrix: String,
    pub class: SparsityClass,
    /// Workload display key, e.g. `GCN(layers=2,d=16)` — the string
    /// pinned pipeline plans persist under.
    pub chain: String,
    /// Implementation every chained SpMM ran on.
    pub chosen: Impl,
    /// Matrix ordering the chain executed under.
    pub reorder: Reordering,
    /// Column-tile width (pipelines pin `dt == d`: the chained
    /// operand is the previous op's cache-resident output, so tiling
    /// has no residency left to buy — see
    /// [`crate::coordinator::Planner::predict_pipeline`]).
    pub dt: usize,
    /// Chained SpMM applications actually executed (PageRank may
    /// converge before its iteration cap).
    pub ops: usize,
    /// Was the inter-op block cache-resident under the active ladder
    /// (the reuse term charged once)?
    pub resident: bool,
    /// Planner's whole-chain predicted GFLOP/s.
    pub predicted_gflops: f64,
    /// Whole-chain model arithmetic intensity.
    pub ai: f64,
    /// Whole-chain wall seconds (median over the job's iterations).
    pub secs: f64,
    /// Whole-chain measured GFLOP/s.
    pub measured_gflops: f64,
    /// Per-op wall-time breakdown from one representative run.
    pub per_op: Vec<OpSecs>,
}

impl PipelineRecord {
    /// measured / predicted — 1.0 is a perfect prediction.
    pub fn prediction_ratio(&self) -> f64 {
        if self.predicted_gflops <= 0.0 {
            0.0
        } else {
            self.measured_gflops / self.predicted_gflops
        }
    }
}

/// Outcome of one executed SpGEMM job.
#[derive(Debug, Clone)]
pub struct SpGemmRecord {
    pub a: String,
    pub b: String,
    /// Class of the left operand's active layout.
    pub class: SparsityClass,
    /// Kernel the job ran on.
    pub chosen: SpGemmImpl,
    /// Exact FLOP count ([`crate::spgemm::spgemm_flops`]).
    pub flops: f64,
    /// Stored nonzeros of the product.
    pub nnz_c: usize,
    /// Measured compression factor `flops / nnz(C)`.
    pub cf: f64,
    /// Planner's predicted GFLOP/s for the chosen kernel (at the cf
    /// the router predicted with).
    pub predicted_gflops: f64,
    /// Model arithmetic intensity used for the prediction.
    pub ai: f64,
    /// Measured wall-clock seconds (median).
    pub secs: f64,
    /// Measured GFLOP/s.
    pub measured_gflops: f64,
}

impl SpGemmRecord {
    /// measured / predicted — 1.0 is a perfect prediction.
    pub fn prediction_ratio(&self) -> f64 {
        if self.predicted_gflops <= 0.0 {
            0.0
        } else {
            self.measured_gflops / self.predicted_gflops
        }
    }
}

/// Outcome of one executed job.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub matrix: String,
    pub class: SparsityClass,
    pub d: usize,
    /// Implementation the job ran on.
    pub chosen: Impl,
    /// Matrix ordering the job executed under (non-identity only when
    /// the autotuner pinned a reordering).
    pub reorder: Reordering,
    /// Column-tile width the schedule executed with (`dt == d` means
    /// untiled).
    pub dt: usize,
    /// Planner's predicted GFLOP/s for the chosen implementation.
    pub predicted_gflops: f64,
    /// Model arithmetic intensity used for the prediction.
    pub ai: f64,
    /// Measured wall-clock seconds (median over the job's
    /// iterations).
    pub secs: f64,
    /// Measured GFLOP/s.
    pub measured_gflops: f64,
}

impl JobRecord {
    /// measured / predicted — 1.0 is a perfect prediction.
    pub fn prediction_ratio(&self) -> f64 {
        if self.predicted_gflops <= 0.0 {
            0.0
        } else {
            self.measured_gflops / self.predicted_gflops
        }
    }
}

/// Aggregate prediction accuracy over a set of records.
#[derive(Debug, Clone)]
pub struct PredictionReport {
    pub n_jobs: usize,
    /// Geometric mean of measured/predicted.
    pub geomean_ratio: f64,
    /// Mean absolute relative error of log-ratio.
    pub mean_abs_log_err: f64,
    /// Fraction of jobs where the chosen impl was measured-best among
    /// the impls actually tried for the same (matrix, d). Only
    /// meaningful when jobs sweep impls.
    pub routing_hit_rate: Option<f64>,
}

impl PredictionReport {
    /// Summarise a slice of job records.
    pub fn of(records: &[JobRecord]) -> PredictionReport {
        let n = records.len();
        if n == 0 {
            return PredictionReport {
                n_jobs: 0,
                geomean_ratio: 0.0,
                mean_abs_log_err: 0.0,
                routing_hit_rate: None,
            };
        }
        let mut log_sum = 0.0;
        let mut abs_log = 0.0;
        for r in records {
            let ratio = r.prediction_ratio().max(1e-12);
            log_sum += ratio.ln();
            abs_log += ratio.ln().abs();
        }
        PredictionReport {
            n_jobs: n,
            geomean_ratio: (log_sum / n as f64).exp(),
            mean_abs_log_err: abs_log / n as f64,
            routing_hit_rate: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(pred: f64, meas: f64) -> JobRecord {
        JobRecord {
            matrix: "m".into(),
            class: SparsityClass::Random,
            d: 4,
            chosen: Impl::Csr,
            reorder: Reordering::None,
            dt: 4,
            predicted_gflops: pred,
            ai: 0.1,
            secs: 0.01,
            measured_gflops: meas,
        }
    }

    #[test]
    fn ratio_and_geomean() {
        let records = vec![rec(2.0, 1.0), rec(1.0, 2.0)];
        assert_eq!(records[0].prediction_ratio(), 0.5);
        let rep = PredictionReport::of(&records);
        assert!((rep.geomean_ratio - 1.0).abs() < 1e-12);
        assert!((rep.mean_abs_log_err - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn empty_report() {
        let rep = PredictionReport::of(&[]);
        assert_eq!(rep.n_jobs, 0);
    }

    #[test]
    fn jobspec_builder() {
        let j = JobSpec::new("x", 16).with_impl(Impl::Csb);
        assert_eq!(j.force_impl, Some(Impl::Csb));
        assert_eq!(j.d, 16);
        assert_eq!(j.workload(), Workload::SpMM { d: 16 });
    }

    #[test]
    fn pipeline_kind_shapes() {
        let gcn = PipelineKind::Gcn { dims: vec![16, 8, 4] };
        assert_eq!(gcn.workload(), Workload::GcnLayer { layers: 2, d: 16 });
        assert_eq!(format!("{}", gcn.workload()), "GCN(layers=2,d=16)");
        assert_eq!(gcn.d(), 16);
        assert_eq!(gcn.ops(), 2);
        let pp = gcn.pipeline_params(100, 500, 2);
        assert_eq!(pp.ops, 2);
        assert_eq!(pp.p.d, 12, "mean of the layer input widths 16 and 8");
        // dense transforms: 2·100·16·8 + 2·100·8·4 FLOPs
        assert_eq!(pp.extra_flops, 25_600.0 + 6_400.0);
        assert_eq!(pp.extra_bytes, 8.0 * (128.0 + 32.0));

        let pr = PipelineKind::PageRank { seeds: vec![0, 3], alpha: 0.85, tol: 1e-9, iters: 20 };
        assert_eq!(pr.workload(), Workload::BatchedPageRank { seeds: 2, iters: 20 });
        assert_eq!(pr.d(), 2);
        // executed length overrides the cap in the params
        assert_eq!(pr.pipeline_params(100, 500, 7).ops, 7);

        let pw = PipelineKind::PowerIteration { d: 8, iters: 5 };
        assert_eq!(format!("{}", pw.workload()), "Power(d=8,iters=5)");
        assert_eq!(pw.ops(), 5);

        let gg = PipelineKind::SpGemmSpMM { b: "b".into(), d: 4 };
        assert_eq!(gg.workload(), Workload::SpGemmSpMM { b: "b".into(), d: 4 });
        assert_eq!(format!("{}", gg.workload()), "SpGEMM+SpMM(×b,d=4)");
        assert_eq!(gg.ops(), 1);
    }

    #[test]
    fn pipeline_spec_builder() {
        let s = PipelineSpec::new("m", PipelineKind::PowerIteration { d: 4, iters: 3 })
            .with_impl(Impl::Csb);
        assert_eq!(s.force_impl, Some(Impl::Csb));
        assert_eq!(s.workload(), Workload::PowerIteration { d: 4, iters: 3 });
    }

    #[test]
    fn pipeline_record_ratio() {
        let r = PipelineRecord {
            matrix: "m".into(),
            class: SparsityClass::Random,
            chain: "Power(d=4,iters=3)".into(),
            chosen: Impl::Csr,
            reorder: Reordering::None,
            dt: 4,
            ops: 3,
            resident: true,
            predicted_gflops: 2.0,
            ai: 0.2,
            secs: 0.01,
            measured_gflops: 1.0,
            per_op: vec![],
        };
        assert_eq!(r.prediction_ratio(), 0.5);
    }

    #[test]
    fn spgemm_spec_and_record() {
        let s = SpGemmSpec::new("a", "b").with_impl(SpGemmImpl::PbMerge);
        assert_eq!(s.force_impl, Some(SpGemmImpl::PbMerge));
        assert_eq!(s.workload(), Workload::SpGemm { b: "b".into() });
        assert_eq!(format!("{}", s.workload()), "SpGEMM(×b)");
        let r = SpGemmRecord {
            a: "a".into(),
            b: "b".into(),
            class: SparsityClass::Random,
            chosen: SpGemmImpl::Hash,
            flops: 100.0,
            nnz_c: 10,
            cf: 10.0,
            predicted_gflops: 2.0,
            ai: 0.1,
            secs: 0.01,
            measured_gflops: 1.0,
        };
        assert_eq!(r.prediction_ratio(), 0.5);
    }
}
