//! The L3 coordinator: a **roofline-guided SpMM engine**.
//!
//! The paper's thesis is that the right performance model — and
//! therefore the right data structure — depends on the matrix's
//! sparsity structure. The engine operationalises that: for each
//! registered matrix it
//!
//! 1. **classifies** the sparsity pattern ([`crate::pattern`]),
//! 2. **predicts** attainable GFLOP/s per implementation from the
//!    matching sparsity-aware roofline model ([`crate::model`]) and a
//!    per-(class, impl) efficiency prior calibrated from the paper's
//!    Table V,
//! 3. **routes** each SpMM job to the predicted-best kernel, and
//! 4. **records** prediction vs measurement, so the planner's accuracy
//!    is itself a measurable output (`prediction_report`).
//!
//! The XLA/PJRT artifact slots in as one more backend when an artifact
//! matching the job's static shape exists.

mod engine;
mod job;
mod planner;
mod registry;

pub use engine::{Engine, EngineConfig};
pub use job::{JobRecord, JobSpec, PredictionReport};
pub use planner::{Planner, Prediction};
pub use registry::{MatrixEntry, MatrixRegistry};
