//! The L3 coordinator: a **roofline-guided SpMM engine**.
//!
//! The paper's thesis is that the right performance model — and
//! therefore the right data structure — depends on the matrix's
//! sparsity structure. The engine operationalises that: for each
//! registered matrix it
//!
//! 1. **classifies** the sparsity pattern ([`crate::pattern`]),
//! 2. **predicts** attainable GFLOP/s per implementation from the
//!    matching sparsity-aware roofline model ([`crate::model`]) and a
//!    per-(class, impl) efficiency prior calibrated from the paper's
//!    Table V,
//! 3. **routes** each SpMM job to the predicted-best kernel, and
//! 4. **records** prediction vs measurement, so the planner's accuracy
//!    is itself a measurable output (`prediction_report`).
//!
//! Execution is batched by default: [`Engine::submit_batch`] runs a
//! queue of jobs over the persistent worker pool with dense operands
//! recycled through a [`BufferPool`], and reports per-batch aggregate
//! throughput and model error ([`BatchReport`]). [`Engine::submit`] is
//! the single-job special case and shares the same pooled buffers.
//!
//! The XLA/PJRT artifact slots in as one more backend when an artifact
//! matching the job's static shape exists (and the crate was built
//! with the `xla` feature).
//!
//! With autotuning enabled ([`AutotunePolicy`]), step 3 becomes a full
//! explore/exploit loop: the first submission per `(matrix, d)`
//! *measures* the top predicted candidates across formats **and**
//! reorderings (RCM / degree-sort / as-registered), feeds every
//! measurement back into the priors, and pins the measured winner —
//! converting the stored matrix in the registry so later submissions
//! execute the winning layout from cache (see [`Autotuner`]). The
//! propagation-blocking kernel ([`crate::spmm::PbSpmm`]) is the
//! router's structure-adversarial candidate: its predicted line
//! ([`crate::model::ai_pb`]) ignores structure entirely, so it enters
//! the explored top-k exactly where the structural models collapse to
//! the random floor.
//!
//! The explore *order* itself has two sources: the analytic roofline
//! ranking above, and — once enough routing records have accumulated —
//! a **learned structure router** ([`LearnedRouter`], a pure-Rust
//! decision forest trained on the features those records carry via
//! [`examples_from_log`]). A confident, supported, in-distribution
//! prediction is promoted to the front of the explore order; anything
//! else falls back to the analytic ranking, and every pinned
//! [`RouteDecision`] records which source ranked it ([`RouteSource`])
//! plus the regret of the learned pick against the measured analytic
//! top candidate.
//!
//! The engine is **workload-aware**: [`Workload`] names the two
//! multiply dimensions and [`Engine::submit_workload`] dispatches on
//! it. SpMM jobs ([`JobSpec`]) route across the dense-operand kernel
//! family; SpGEMM jobs ([`SpGemmSpec`], [`Engine::submit_spgemm`])
//! route across the sparse×sparse pair ([`crate::spgemm`]) —
//! predicted from the compression-factor-parameterized models,
//! explored and pinned per (left, right) matrix pair
//! ([`Autotuner::tune_spgemm`]), with the measured `cf` cached on the
//! decision so later predictions tighten past the conservative floor.
//!
//! **Hand-off** (classify → predict → schedule → route → execute):
//! this module owns the three middle stages and the loop around them.
//! [`MatrixRegistry`] caches the *classify* output and the planned
//! [`crate::spmm::Schedule`]s; [`Planner`] is *predict*;
//! [`Engine::submit`]/[`Engine::submit_batch`] perform *route* and
//! drive *execute* on the kernels ([`crate::spmm`]), then feed the
//! measurement back into the priors.
//!
//! Multi-op **pipelines** are first-class workloads: a
//! [`PipelineSpec`] names a whole chain (GCN forward pass, block power
//! iteration, batched PageRank, SpGEMM→SpMM) and
//! [`Engine::submit_pipeline`] routes it as one unit — one cached
//! [`crate::spmm::Schedule`] serves every chained op, intermediates
//! ping-pong through the shared [`BufferPool`], and the router's
//! decision ([`Autotuner::tune_pipeline`]) is measured on the *full*
//! chain against the inter-op roofline model
//! ([`crate::model::ai_pipeline`], [`Planner::predict_pipeline`])
//! rather than on the hottest op in isolation. Pinned whole-chain
//! plans persist and restore with the rest of the autotune state.
//!
//! On top of the engine sits the **serving front-end** ([`Server`]):
//! a bounded job queue with explicit admission control, concurrent
//! batch coalescing (queued SpMM jobs sharing a matrix merge into one
//! pooled-buffer engine batch), per-tenant matrix namespaces, and
//! autotune state persisted across restarts
//! ([`crate::report::AutotuneState`]). [`Server::run`] is the serving
//! loop; client threads talk to it through cloneable [`ServeHandle`]s
//! and block on per-job [`Ticket`]s.

mod autotune;
mod batch;
mod engine;
mod job;
mod learned;
mod planner;
mod registry;
mod serve;

pub use autotune::{
    Autotuner, AutotunePolicy, Candidate, PipelineDecision, RouteDecision, SpGemmCandidate,
    SpGemmDecision,
};
pub use learned::{
    examples_from_log, features_of, DecisionTree, Example, LearnedRoute, LearnedRouter, Node,
    RouteLabel, RouteSource, TrainConfig,
};
pub use batch::{BatchReport, BufferPool};
pub use engine::{Engine, EngineConfig, PipelineOutput, WorkloadOutcome};
pub use job::{
    JobRecord, JobSpec, PipelineKind, PipelineRecord, PipelineSpec, PredictionReport, SpGemmRecord,
    SpGemmSpec, Workload,
};
pub use planner::{LadderSource, Planner, PipelinePrediction, Prediction, SpGemmPrediction};
pub use registry::{MatrixEntry, MatrixRegistry};
pub use serve::{
    JobQueue, Server, ServeConfig, ServeHandle, ServeOutput, ServeReply, ServeRequest, ServeStats,
    ServeWork, Submit, Ticket,
};
