//! The planner: sparsity-aware roofline prediction per implementation.
//!
//! Prediction = `roofline(model AI) × prior(class, impl)`. The prior
//! encodes the paper's Table V / Fig. 2 findings as fractions of the
//! per-pattern roof each implementation historically reaches — e.g.
//! CSB sits nearest the roof on blocked matrices, CSR/MKL lead on
//! banded ones, everything lands far under the roof on random
//! matrices (the model is a lower bound on AI, not on achieved
//! fraction). Priors start from the paper's measured ratios and are
//! refined online: after each job the engine updates the prior with an
//! exponential moving average of measured/roof.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::gen::SparsityClass;
use crate::model::{AiParams, Roofline, SparsityModel};
use crate::pattern::Classification;
use crate::spmm::Impl;

/// A prediction for one implementation.
#[derive(Debug, Clone, Copy)]
pub struct Prediction {
    pub im: Impl,
    /// Model arithmetic intensity (FLOPs/byte).
    pub ai: f64,
    /// Bandwidth-roof performance at that AI.
    pub roof_gflops: f64,
    /// Prior efficiency fraction applied.
    pub prior: f64,
    /// Predicted GFLOP/s = roof × prior.
    pub predicted_gflops: f64,
}

/// Roofline-guided planner with online prior refinement.
pub struct Planner {
    roofline: Roofline,
    /// (class, impl) → efficiency prior (fraction of roof).
    priors: Mutex<HashMap<(SparsityClass, Impl), f64>>,
    /// EMA weight for online updates.
    ema: f64,
}

/// Initial efficiency priors, read off the paper's Fig. 2 (fraction of
/// the per-pattern bandwidth roof each implementation attains) and
/// Table V orderings. The XLA/ELL backends are seeded at CSR-like
/// fractions scaled by their padding overhead at execute time.
fn seed_prior(class: SparsityClass, im: Impl) -> f64 {
    use Impl::*;
    use SparsityClass::*;
    match (class, im) {
        // Fig. 2(a): all impls well below the random roof; CSB closest
        (Random, Csr) => 0.35,
        (Random, Opt) => 0.42,
        (Random, Csb) => 0.60,
        // Fig. 2(b): diagonal roof is an upper bound nobody reaches;
        // CSR/OPT lead, CSB's block machinery only pays off at high d
        (Diagonal, Csr) => 0.45,
        (Diagonal, Opt) => 0.50,
        (Diagonal, Csb) => 0.35,
        // Fig. 2(c): CSB approaches the blocked roof
        (Blocked, Csr) => 0.55,
        (Blocked, Opt) => 0.60,
        (Blocked, Csb) => 0.85,
        // Fig. 2(d): CSR/MKL near the roof at small d; CSB can exceed
        // it (effective-bandwidth effect) — seed slightly above OPT
        (ScaleFree, Csr) => 0.70,
        (ScaleFree, Opt) => 0.80,
        (ScaleFree, Csb) => 0.85,
        // BSR: dense tiles pay off only where blocks fill (meshes)
        (Blocked, Bsr) => 0.7,
        (_, Bsr) => 0.25,
        // ELL ~ CSR minus padding tax (charged separately);
        // XLA ~ ELL minus transfer overhead
        (_, Ell) => 0.9 * seed_prior(class, Csr),
        (_, Xla) => 0.6 * seed_prior(class, Csr),
    }
}

impl Planner {
    /// Planner over a calibrated roofline.
    pub fn new(roofline: Roofline) -> Planner {
        Planner { roofline, priors: Mutex::new(HashMap::new()), ema: 0.3 }
    }

    /// The roofline used for predictions.
    pub fn roofline(&self) -> &Roofline {
        &self.roofline
    }

    /// Current prior for (class, impl).
    pub fn prior(&self, class: SparsityClass, im: Impl) -> f64 {
        *self
            .priors
            .lock()
            .unwrap()
            .entry((class, im))
            .or_insert_with(|| seed_prior(class, im))
    }

    /// Predict attainable GFLOP/s for one implementation on a
    /// classified matrix.
    pub fn predict(&self, cls: &Classification, d: usize, im: Impl) -> Prediction {
        let p = AiParams::new(cls.stats.n, d, cls.stats.nnz);
        let ai = cls.model.ai(p);
        let roof = self.roofline.attainable_gflops(ai);
        let prior = self.prior(cls.class, im);
        Prediction { im, ai, roof_gflops: roof, prior, predicted_gflops: roof * prior }
    }

    /// Rank the candidate implementations, best predicted first.
    pub fn rank(&self, cls: &Classification, d: usize, candidates: &[Impl]) -> Vec<Prediction> {
        let mut preds: Vec<Prediction> =
            candidates.iter().map(|&im| self.predict(cls, d, im)).collect();
        preds.sort_by(|a, b| b.predicted_gflops.partial_cmp(&a.predicted_gflops).unwrap());
        preds
    }

    /// Online refinement: fold a measured efficiency (measured /
    /// roof) into the prior with an EMA.
    pub fn observe(&self, class: SparsityClass, im: Impl, ai: f64, measured_gflops: f64) {
        let roof = self.roofline.attainable_gflops(ai);
        if roof <= 0.0 {
            return;
        }
        let eff = (measured_gflops / roof).clamp(0.0, 2.0);
        let mut priors = self.priors.lock().unwrap();
        let slot = priors.entry((class, im)).or_insert_with(|| seed_prior(class, im));
        *slot = (1.0 - self.ema) * *slot + self.ema * eff;
    }

    /// The model AI the planner would use for a classified matrix at
    /// width `d` (exposed for reports).
    pub fn model_ai(&self, cls: &Classification, d: usize) -> f64 {
        cls.model.ai(AiParams::new(cls.stats.n, d, cls.stats.nnz))
    }

    /// The parameterised model itself (for figure annotations).
    pub fn model_of(&self, cls: &Classification) -> SparsityModel {
        cls.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, erdos_renyi, mesh2d, ChungLuParams, MeshKind, Prng};
    use crate::model::MachineParams;
    use crate::pattern::classify;

    fn planner() -> Planner {
        Planner::new(Roofline::new(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }))
    }

    #[test]
    fn blocked_routes_to_csb() {
        let a = mesh2d(64, MeshKind::Road, 0.62, &mut Prng::new(160));
        let cls = classify(&a);
        let p = planner();
        let ranked = p.rank(&cls, 16, &Impl::NATIVE);
        assert_eq!(ranked[0].im, Impl::Csb, "{:?}", ranked);
    }

    #[test]
    fn scalefree_prediction_monotone_in_d_roof() {
        let a = chung_lu(
            ChungLuParams { n: 4000, alpha: 2.2, avg_deg: 12.0, k_min: 2.0 },
            &mut Prng::new(161),
        );
        let cls = classify(&a);
        let p = planner();
        let p1 = p.predict(&cls, 1, Impl::Opt);
        let p16 = p.predict(&cls, 16, Impl::Opt);
        assert!(p16.ai > p1.ai);
        assert!(p16.predicted_gflops > p1.predicted_gflops);
    }

    #[test]
    fn observe_moves_prior_toward_measurement() {
        let a = erdos_renyi(2000, 2000, 6.0, &mut Prng::new(162));
        let cls = classify(&a);
        let p = planner();
        let before = p.predict(&cls, 4, Impl::Csr);
        // report a measurement far above the prediction
        for _ in 0..10 {
            p.observe(cls.class, Impl::Csr, before.ai, before.roof_gflops);
        }
        let after = p.predict(&cls, 4, Impl::Csr);
        assert!(after.predicted_gflops > before.predicted_gflops);
        assert!(after.prior > before.prior);
    }

    #[test]
    fn rank_is_sorted() {
        let a = erdos_renyi(1000, 1000, 4.0, &mut Prng::new(163));
        let cls = classify(&a);
        let p = planner();
        let ranked = p.rank(&cls, 64, &Impl::NATIVE);
        for w in ranked.windows(2) {
            assert!(w[0].predicted_gflops >= w[1].predicted_gflops);
        }
    }
}
