//! The planner: sparsity-aware roofline prediction per implementation,
//! including the model-driven column-tile width.
//!
//! Prediction = `roof(model AI at the chosen tile) × prior(class,
//! impl)`. The roof comes from the cache-aware ladder: for each
//! candidate tile width `dt` the model's tile-aware AI
//! ([`SparsityModel::ai_tiled`]) pays the extra `A` streams tiling
//! costs, while the `B` panel working set (`8·n·dt`) selects the
//! bandwidth ceiling it earns; the planner picks the `dt` maximizing
//! predicted GFLOP/s (preferring wider tiles on ties — fewer passes,
//! less scheduling overhead). `dt = d` reproduces the flat untiled
//! prediction.
//!
//! The prior encodes the paper's Table V / Fig. 2 findings as fractions
//! of the per-pattern roof each implementation historically reaches —
//! e.g. CSB sits nearest the roof on blocked matrices, CSR/MKL lead on
//! banded ones, everything lands far under the roof on random matrices
//! (the model is a lower bound on AI, not on achieved fraction). Priors
//! start from the paper's measured ratios and are refined online: after
//! each job the engine updates the prior with an exponential moving
//! average of measured/roof.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::gen::SparsityClass;
use crate::membench;
use crate::model::{
    ai_pb_tiled, ai_pipeline, ai_pipeline_pb, ai_spgemm, csr_bytes, AiParams, CacheAwareRoofline,
    PipelineParams, Roofline, SparsityModel, SpGemmParams,
};
use crate::spgemm::SpGemmImpl;
use crate::spmm::pb_spill_tile;
use crate::pattern::Classification;
use crate::spmm::Impl;

/// A prediction for one implementation.
#[derive(Debug, Clone, Copy)]
pub struct Prediction {
    pub im: Impl,
    /// Model arithmetic intensity (FLOPs/byte) at the chosen tile.
    pub ai: f64,
    /// Ladder-roof performance at that AI and tile working set.
    pub roof_gflops: f64,
    /// Prior efficiency fraction applied.
    pub prior: f64,
    /// Predicted GFLOP/s = roof × prior.
    pub predicted_gflops: f64,
    /// Chosen column-tile width (`dt == d` means untiled).
    pub dt: usize,
}

/// A prediction for one SpGEMM implementation — the planner's
/// `Workload::SpGemm` dimension ([`crate::coordinator::Workload`]).
#[derive(Debug, Clone, Copy)]
pub struct SpGemmPrediction {
    pub im: SpGemmImpl,
    /// Model arithmetic intensity (FLOPs/byte) at the given `cf`.
    pub ai: f64,
    /// Roof performance at that AI.
    pub roof_gflops: f64,
    /// Prior efficiency fraction applied.
    pub prior: f64,
    /// Predicted GFLOP/s = roof × prior.
    pub predicted_gflops: f64,
    /// Compression factor the prediction used
    /// ([`crate::model::SpGemmParams::cf`]).
    pub cf: f64,
}

/// A whole-chain prediction for one implementation — the pipeline
/// workloads' analog of [`Prediction`]
/// ([`Planner::predict_pipeline`]).
#[derive(Debug, Clone, Copy)]
pub struct PipelinePrediction {
    pub im: Impl,
    /// Whole-chain arithmetic intensity
    /// ([`crate::model::ai_pipeline`]).
    pub ai: f64,
    /// Was the inter-op `n×d` block cache-resident (its re-stream
    /// charged once, not per op)?
    pub resident: bool,
    /// Ladder-roof performance at the chain AI and the intermediate
    /// block's working set.
    pub roof_gflops: f64,
    /// Prior efficiency fraction applied.
    pub prior: f64,
    /// Predicted GFLOP/s = roof × prior.
    pub predicted_gflops: f64,
    /// Column-tile width — always the untiled `d` for pipelines (see
    /// [`Planner::predict_pipeline`]).
    pub dt: usize,
}

/// Where the planner's bandwidth ladder came from — the nominal
/// scaled-β prior, or a real [`crate::membench::MeasuredLadder`]
/// sweep. A measured ladder always wins: `install_measured` replaces
/// the nominal one, and a restored autotune snapshot re-installs it
/// without re-measuring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LadderSource {
    /// `CacheAwareRoofline::nominal` — DRAM β scaled 2× per level.
    Nominal,
    /// `membench::calibrate` — per-level read/write/triad sweep.
    Measured,
}

impl std::fmt::Display for LadderSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            LadderSource::Nominal => "nominal",
            LadderSource::Measured => "measured",
        })
    }
}

/// Roofline-guided planner with online prior refinement.
pub struct Planner {
    roofline: Roofline,
    /// Per-level bandwidth ceilings used for tile-width selection.
    ladder: CacheAwareRoofline,
    /// Provenance of `ladder` (measured beats nominal).
    ladder_source: LadderSource,
    /// (class, impl) → efficiency prior (fraction of roof).
    priors: Mutex<HashMap<(SparsityClass, Impl), f64>>,
    /// (class, SpGEMM impl) → efficiency prior — the same learning
    /// loop, keyed on the SpGEMM candidate set.
    spgemm_priors: Mutex<HashMap<(SparsityClass, SpGemmImpl), f64>>,
    /// EMA weight for online updates.
    ema: f64,
}

/// Initial efficiency priors, read off the paper's Fig. 2 (fraction of
/// the per-pattern bandwidth roof each implementation attains) and
/// Table V orderings. The XLA/ELL backends are seeded at CSR-like
/// fractions scaled by their padding overhead at execute time.
fn seed_prior(class: SparsityClass, im: Impl) -> f64 {
    use Impl::*;
    use SparsityClass::*;
    match (class, im) {
        // Fig. 2(a): all impls well below the random roof; CSB closest
        (Random, Csr) => 0.35,
        (Random, Opt) => 0.42,
        (Random, Csb) => 0.60,
        // Fig. 2(b): diagonal roof is an upper bound nobody reaches;
        // CSR/OPT lead, CSB's block machinery only pays off at high d
        (Diagonal, Csr) => 0.45,
        (Diagonal, Opt) => 0.50,
        (Diagonal, Csb) => 0.35,
        // Fig. 2(c): CSB approaches the blocked roof
        (Blocked, Csr) => 0.55,
        (Blocked, Opt) => 0.60,
        (Blocked, Csb) => 0.85,
        // Fig. 2(d): CSR/MKL near the roof at small d; CSB can exceed
        // it (effective-bandwidth effect) — seed slightly above OPT
        (ScaleFree, Csr) => 0.70,
        (ScaleFree, Opt) => 0.80,
        (ScaleFree, Csb) => 0.85,
        // BSR: dense tiles pay off only where blocks fill (meshes)
        (Blocked, Bsr) => 0.7,
        (_, Bsr) => 0.25,
        // PB: both phases stream sequentially, so it runs a STREAM-like
        // fraction of its (flat) roof on every structure — the whole
        // point of propagation blocking. Its AI is the lowest of any
        // kernel (model/pb.rs: the spill round trip costs 16·d bytes
        // per nonzero vs random's 8·d re-load), so this high prior
        // only wins where the structural models collapse to the random
        // lower bound and the gathering kernels' priors are low.
        (_, Pb) => 0.85,
        // ELL ~ CSR minus padding tax (charged separately);
        // XLA ~ ELL minus transfer overhead
        (_, Ell) => 0.9 * seed_prior(class, Csr),
        (_, Xla) => 0.6 * seed_prior(class, Csr),
    }
}

/// Initial SpGEMM efficiency priors. The hash kernel is the
/// *gathering* implementation: its achieved fraction collapses on
/// random structure exactly like CSR's SpMM line (Fig. 2(a)) and
/// recovers where structure keeps the gathered `B` rows resident. The
/// PB merge streams every byte, so — like the SpMM PB prior — it runs
/// a STREAM-like fraction of its (lower-AI) roof on every structure;
/// it wins exactly where the hash kernel's prior collapses.
fn seed_spgemm_prior(class: SparsityClass, im: SpGemmImpl) -> f64 {
    use SparsityClass::*;
    match im {
        SpGemmImpl::Hash => match class {
            Random => 0.35,
            Diagonal => 0.60,
            Blocked => 0.55,
            ScaleFree => 0.45,
        },
        SpGemmImpl::PbMerge => 0.80,
    }
}

/// Candidate tile widths at dense width `d`, widest first: the
/// untiled `d` itself, then powers of two below it down to 8. Widths
/// below 8 never pay — the extra `A` streams always beat one ceiling
/// hop at that index overhead. Descending order makes the planner's
/// strictly-greater comparison keep the *widest* tile on roof ties
/// (fewer passes, fewer barriers).
fn tile_candidates(d: usize) -> Vec<usize> {
    let mut v = vec![d];
    let mut t = 8usize;
    while t < d {
        v.push(t);
        t *= 2;
    }
    v[1..].reverse();
    v
}

impl Planner {
    /// Planner over a calibrated flat roofline; tile selection uses the
    /// calibration-free nominal ladder over this host's cache levels
    /// ([`CacheAwareRoofline::nominal`]).
    pub fn new(roofline: Roofline) -> Planner {
        let ladder = CacheAwareRoofline::nominal(roofline.machine, &membench::cache_levels());
        Planner::with_ladder(roofline, ladder)
    }

    /// Planner over an explicit bandwidth ladder (e.g. a measured
    /// `membench::bandwidth_ladder`).
    pub fn with_ladder(roofline: Roofline, ladder: CacheAwareRoofline) -> Planner {
        Planner {
            roofline,
            ladder,
            ladder_source: LadderSource::Nominal,
            priors: Mutex::new(HashMap::new()),
            spgemm_priors: Mutex::new(HashMap::new()),
            ema: 0.3,
        }
    }

    /// Install a measured bandwidth/peak ladder
    /// ([`crate::membench::MeasuredLadder::to_roofline`]): it replaces
    /// the nominal prior for every subsequent tile-width selection and
    /// ceiling lookup, and [`Planner::ladder_source`] reports
    /// `Measured` so reports (and tests) can pin the preference.
    pub fn install_measured(&mut self, ladder: CacheAwareRoofline) {
        self.ladder = ladder;
        self.ladder_source = LadderSource::Measured;
    }

    /// The flat roofline used for reports.
    pub fn roofline(&self) -> &Roofline {
        &self.roofline
    }

    /// The bandwidth ladder used for tile selection.
    pub fn ladder(&self) -> &CacheAwareRoofline {
        &self.ladder
    }

    /// Provenance of the active ladder.
    pub fn ladder_source(&self) -> LadderSource {
        self.ladder_source
    }

    /// Current prior for (class, impl).
    pub fn prior(&self, class: SparsityClass, im: Impl) -> f64 {
        *self
            .priors
            .lock()
            .unwrap()
            .entry((class, im))
            .or_insert_with(|| seed_prior(class, im))
    }

    /// The tile width maximizing roof performance for this matrix at
    /// width `d`, with the AI and roof it earns. Ties go to the wider
    /// tile.
    fn best_tile(&self, model: SparsityModel, p: AiParams) -> (usize, f64, f64) {
        let mut best = (p.d, 0.0, f64::MIN);
        for dt in tile_candidates(p.d) {
            let ai = model.ai_tiled(p, dt);
            let ws = CacheAwareRoofline::spmm_working_set(p.n, dt);
            let roof = self.ladder.attainable_gflops(ai, ws);
            // candidates are widest-first and the comparison is
            // strictly-greater, so roof ties keep the widest tile
            if roof > best.2 {
                best = (dt, ai, roof);
            }
        }
        best
    }

    /// Predict attainable GFLOP/s for one implementation on a
    /// classified matrix, including the model-chosen tile width.
    pub fn predict(&self, cls: &Classification, d: usize, im: Impl) -> Prediction {
        let p = AiParams::new(cls.stats.n, d, cls.stats.nnz);
        let (dt, ai, roof) = if im == Impl::Xla {
            // the AOT artifact executes its own static loop nest —
            // column tiling does not apply
            let ai = cls.model.ai(p);
            let ws = CacheAwareRoofline::spmm_working_set(p.n, d);
            (d, ai, self.ladder.attainable_gflops(ai, ws))
        } else if im == Impl::Pb {
            // propagation blocking: traffic is structure-independent
            // (model/pb.rs) and every byte streams, so the roof is the
            // flat DRAM line regardless of the B working set — the
            // band/bucket panels are cache-resident by construction.
            // Tiling buys PB no ceiling hop, but the kernel's spill
            // arena caps the pass width (`pb_spill_tile`), so the
            // traffic is charged at exactly the width the execution
            // will run with — predicted and executed pass counts
            // agree.
            let dt = pb_spill_tile(p.nnz, d);
            let ai = ai_pb_tiled(p, dt);
            (dt, ai, self.roofline.attainable_gflops(ai))
        } else {
            self.best_tile(cls.model, p)
        };
        let prior = self.prior(cls.class, im);
        Prediction { im, ai, roof_gflops: roof, prior, predicted_gflops: roof * prior, dt }
    }

    /// Predict whole-chain attainable GFLOP/s for one implementation
    /// on a classified matrix — the pipeline workloads' predict stage,
    /// fed by the inter-op reuse term ([`crate::model::ai_pipeline`]):
    /// when the intermediate `n×d` block fits a cache rung of the
    /// ladder, every chained op past the first drops its `B` re-stream
    /// from the DRAM byte count, so the chain AI rises above the
    /// single-op AI and earns a higher roof.
    ///
    /// Pipelines always predict (and execute) **untiled** (`dt = d`):
    /// column tiling exists to manufacture residency for a *streamed*
    /// dense operand, but a chained op's operand is the previous op's
    /// output — already the hottest block in cache — so a narrower
    /// tile buys no ceiling hop and only pays extra `A` streams.
    /// Executing untiled also keeps the engine's pipeline route
    /// bitwise-identical to the standalone workload functions (the
    /// register-tiled kernels fuse accumulation differently per tile
    /// width).
    ///
    /// [`Impl::Pb`] is the usual streaming exception: its bin/spill
    /// traffic re-streams the block regardless of residency, so its
    /// chain line ([`crate::model::ai_pipeline_pb`]) charges full
    /// per-op bytes on the flat DRAM roof.
    pub fn predict_pipeline(
        &self,
        cls: &Classification,
        pp: PipelineParams,
        im: Impl,
    ) -> PipelinePrediction {
        let ws = CacheAwareRoofline::spmm_working_set(pp.p.n, pp.p.d);
        let (ai, resident, roof) = if im == Impl::Pb {
            let ai = ai_pipeline_pb(pp);
            (ai, false, self.roofline.attainable_gflops(ai))
        } else {
            let resident = self.ladder.cache_resident(ws);
            let ai = ai_pipeline(cls.model, pp, resident);
            (ai, resident, self.ladder.attainable_gflops(ai, ws))
        };
        let prior = self.prior(cls.class, im);
        PipelinePrediction {
            im,
            ai,
            resident,
            roof_gflops: roof,
            prior,
            predicted_gflops: roof * prior,
            dt: pp.p.d,
        }
    }

    /// Rank the candidate implementations on a whole chain, best
    /// predicted first.
    pub fn rank_pipeline(
        &self,
        cls: &Classification,
        pp: PipelineParams,
        candidates: &[Impl],
    ) -> Vec<PipelinePrediction> {
        let mut preds: Vec<PipelinePrediction> =
            candidates.iter().map(|&im| self.predict_pipeline(cls, pp, im)).collect();
        preds.sort_by(|a, b| b.predicted_gflops.total_cmp(&a.predicted_gflops));
        preds
    }

    /// Rank the candidate implementations, best predicted first.
    pub fn rank(&self, cls: &Classification, d: usize, candidates: &[Impl]) -> Vec<Prediction> {
        let mut preds: Vec<Prediction> =
            candidates.iter().map(|&im| self.predict(cls, d, im)).collect();
        preds.sort_by(|a, b| b.predicted_gflops.total_cmp(&a.predicted_gflops));
        preds
    }

    /// Current SpGEMM prior for (class, impl).
    pub fn spgemm_prior(&self, class: SparsityClass, im: SpGemmImpl) -> f64 {
        *self
            .spgemm_priors
            .lock()
            .unwrap()
            .entry((class, im))
            .or_insert_with(|| seed_spgemm_prior(class, im))
    }

    /// Predict attainable GFLOP/s for one SpGEMM implementation on a
    /// classified left operand — the `Workload::SpGemm` arm of the
    /// predict stage. The hash kernel's gathered working set is `B`
    /// itself, so it earns the cache-aware ceiling of `B`'s resident
    /// bytes; the PB merge streams everything and sits on the flat
    /// DRAM roof (the same gathering/streaming split as SpMM's
    /// [`Impl::Pb`] special case).
    pub fn predict_spgemm(
        &self,
        cls: &Classification,
        p: SpGemmParams,
        im: SpGemmImpl,
    ) -> SpGemmPrediction {
        let ai = ai_spgemm(p, im);
        let roof = match im {
            SpGemmImpl::Hash => {
                let ws = csr_bytes(p.nnz_b as f64, p.p) as usize;
                self.ladder.attainable_gflops(ai, ws)
            }
            SpGemmImpl::PbMerge => self.roofline.attainable_gflops(ai),
        };
        let prior = self.spgemm_prior(cls.class, im);
        SpGemmPrediction {
            im,
            ai,
            roof_gflops: roof,
            prior,
            predicted_gflops: roof * prior,
            cf: p.cf,
        }
    }

    /// Rank the SpGEMM candidate set, best predicted first.
    pub fn rank_spgemm(&self, cls: &Classification, p: SpGemmParams) -> Vec<SpGemmPrediction> {
        let mut preds: Vec<SpGemmPrediction> =
            SpGemmImpl::ALL.iter().map(|&im| self.predict_spgemm(cls, p, im)).collect();
        preds.sort_by(|a, b| b.predicted_gflops.total_cmp(&a.predicted_gflops));
        preds
    }

    /// Online refinement for the SpGEMM priors — the same EMA loop as
    /// [`Planner::observe`], keyed on the SpGEMM candidate set.
    pub fn observe_spgemm(
        &self,
        class: SparsityClass,
        im: SpGemmImpl,
        roof_gflops: f64,
        measured_gflops: f64,
    ) {
        // a non-finite measurement (NaN from a zero-length timing, or
        // an inf from a zero-flop degenerate) must not enter the EMA:
        // clamp is identity on NaN, so one poisoned sample would stick
        // in the prior forever and persist into the snapshot
        if roof_gflops <= 0.0 || !roof_gflops.is_finite() || !measured_gflops.is_finite() {
            return;
        }
        let eff = (measured_gflops / roof_gflops).clamp(0.0, 2.0);
        let mut priors = self.spgemm_priors.lock().unwrap();
        let slot =
            priors.entry((class, im)).or_insert_with(|| seed_spgemm_prior(class, im));
        *slot = (1.0 - self.ema) * *slot + self.ema * eff;
    }

    /// Online refinement: fold a measured efficiency (measured /
    /// roof) into the prior with an EMA. `roof_gflops` is the roof the
    /// prediction used ([`Prediction::roof_gflops`]), so the learned
    /// fraction matches what `predict` multiplies by.
    pub fn observe(&self, class: SparsityClass, im: Impl, roof_gflops: f64, measured_gflops: f64) {
        // see observe_spgemm: NaN survives `.clamp` (identity on NaN)
        // and would poison the EMA permanently — drop the sample
        if roof_gflops <= 0.0 || !roof_gflops.is_finite() || !measured_gflops.is_finite() {
            return;
        }
        let eff = (measured_gflops / roof_gflops).clamp(0.0, 2.0);
        let mut priors = self.priors.lock().unwrap();
        let slot = priors.entry((class, im)).or_insert_with(|| seed_prior(class, im));
        *slot = (1.0 - self.ema) * *slot + self.ema * eff;
    }

    /// Snapshot of every materialised `(class, impl)` prior, sorted
    /// for stable rendering — the `route` report prints this so the
    /// effect of autotune feedback on the priors is visible.
    pub fn priors_snapshot(&self) -> Vec<(SparsityClass, Impl, f64)> {
        let priors = self.priors.lock().unwrap();
        let mut v: Vec<(SparsityClass, Impl, f64)> =
            priors.iter().map(|(&(c, i), &p)| (c, i, p)).collect();
        v.sort_by_key(|(c, i, _)| (format!("{c}"), format!("{i}")));
        v
    }

    /// Snapshot of every materialised SpGEMM prior, same sorting as
    /// [`Planner::priors_snapshot`] — what the autotune snapshot
    /// persists.
    pub fn spgemm_priors_snapshot(&self) -> Vec<(SparsityClass, SpGemmImpl, f64)> {
        let priors = self.spgemm_priors.lock().unwrap();
        let mut v: Vec<(SparsityClass, SpGemmImpl, f64)> =
            priors.iter().map(|(&(c, i), &p)| (c, i, p)).collect();
        v.sort_by_key(|(c, i, _)| (format!("{c}"), format!("{i}")));
        v
    }

    /// Overwrite one `(class, impl)` prior — restoring a persisted
    /// snapshot. Clamped to the same `[0, 2]` band `observe` enforces,
    /// so a hand-edited snapshot cannot plant an unbounded prior; a
    /// non-finite value (an already-poisoned snapshot, which `.clamp`
    /// passes through) is ignored entirely so the slot cold-starts
    /// from its seed prior instead of re-poisoning.
    pub fn set_prior(&self, class: SparsityClass, im: Impl, value: f64) {
        if !value.is_finite() {
            return;
        }
        self.priors.lock().unwrap().insert((class, im), value.clamp(0.0, 2.0));
    }

    /// Overwrite one SpGEMM prior (snapshot restore; clamped and
    /// NaN-rejected like [`Planner::set_prior`]).
    pub fn set_spgemm_prior(&self, class: SparsityClass, im: SpGemmImpl, value: f64) {
        if !value.is_finite() {
            return;
        }
        self.spgemm_priors.lock().unwrap().insert((class, im), value.clamp(0.0, 2.0));
    }

    /// The untiled model AI the planner would use for a classified
    /// matrix at width `d` (exposed for reports).
    pub fn model_ai(&self, cls: &Classification, d: usize) -> f64 {
        cls.model.ai(AiParams::new(cls.stats.n, d, cls.stats.nnz))
    }

    /// The parameterised model itself (for figure annotations).
    pub fn model_of(&self, cls: &Classification) -> SparsityModel {
        cls.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{chung_lu, erdos_renyi, mesh2d, ChungLuParams, MeshKind, Prng};
    use crate::model::MachineParams;
    use crate::pattern::classify;

    fn planner() -> Planner {
        Planner::new(Roofline::new(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }))
    }

    #[test]
    fn blocked_routes_to_csb() {
        let a = mesh2d(64, MeshKind::Road, 0.62, &mut Prng::new(160));
        let cls = classify(&a);
        let p = planner();
        let ranked = p.rank(&cls, 16, &Impl::NATIVE);
        assert_eq!(ranked[0].im, Impl::Csb, "{:?}", ranked);
    }

    #[test]
    fn scalefree_prediction_monotone_in_d_roof() {
        let a = chung_lu(
            ChungLuParams { n: 4000, alpha: 2.2, avg_deg: 12.0, k_min: 2.0 },
            &mut Prng::new(161),
        );
        let cls = classify(&a);
        let p = planner();
        let p1 = p.predict(&cls, 1, Impl::Opt);
        let p16 = p.predict(&cls, 16, Impl::Opt);
        assert!(p16.ai > p1.ai);
        assert_eq!(p1.dt, 1);
        assert!(p16.dt >= 8, "candidates are d and powers of two ≥ 8: {}", p16.dt);
    }

    #[test]
    fn chosen_tile_never_loses_to_untiled_on_its_own_model() {
        // by construction: dt=d is always a candidate, so the chosen
        // tile's predicted roof ≥ the untiled roof
        let a = mesh2d(80, MeshKind::Road, 0.62, &mut Prng::new(164));
        let cls = classify(&a);
        let p = planner();
        for d in [4usize, 16, 64, 256] {
            let pred = p.predict(&cls, d, Impl::Csb);
            let params = AiParams::new(cls.stats.n, d, cls.stats.nnz);
            let ai_untiled = cls.model.ai(params);
            let ws = CacheAwareRoofline::spmm_working_set(cls.stats.n, d);
            let roof_untiled = p.ladder().attainable_gflops(ai_untiled, ws);
            assert!(pred.roof_gflops >= roof_untiled - 1e-12, "d={d}");
            assert!(pred.dt >= 1 && pred.dt <= d);
        }
    }

    #[test]
    fn large_d_small_cache_prefers_tiling() {
        // a ladder with a tiny fast level and slow DRAM: at large d the
        // B panel only fits when tiled, so the planner must tile
        let machine = MachineParams { beta_gbs: 10.0, pi_gflops: 10_000.0 };
        let levels = vec![("L2".to_string(), 8 << 20)];
        let ladder = CacheAwareRoofline::nominal(machine, &levels);
        let p = Planner::with_ladder(Roofline::new(machine), ladder);
        let a = mesh2d(64, MeshKind::Road, 0.62, &mut Prng::new(165));
        let cls = classify(&a);
        // n ≈ 4096 rows: the full B at d=4096 is 128 MiB (DRAM-bound)
        // but a dt=128 panel is 4 MiB — exactly the halved L2
        // threshold — so the planner must tile to earn the 2β ceiling
        let n = cls.stats.n;
        let d = 4096;
        let pred = p.predict(&cls, d, Impl::Csb);
        assert!(pred.dt < d, "tiled width expected, got dt={}", pred.dt);
        assert!(CacheAwareRoofline::spmm_working_set(n, pred.dt) <= (8 << 20) / 2);
        // and the tiled prediction beats the untiled roof outright
        let params = AiParams::new(n, d, cls.stats.nnz);
        let untiled = p
            .ladder()
            .attainable_gflops(cls.model.ai(params), CacheAwareRoofline::spmm_working_set(n, d));
        assert!(pred.roof_gflops > untiled);
    }

    #[test]
    fn observe_moves_prior_toward_measurement() {
        let a = erdos_renyi(2000, 2000, 6.0, &mut Prng::new(162));
        let cls = classify(&a);
        let p = planner();
        let before = p.predict(&cls, 4, Impl::Csr);
        // report a measurement far above the prediction
        for _ in 0..10 {
            p.observe(cls.class, Impl::Csr, before.roof_gflops, before.roof_gflops);
        }
        let after = p.predict(&cls, 4, Impl::Csr);
        assert!(after.predicted_gflops > before.predicted_gflops);
        assert!(after.prior > before.prior);
    }

    #[test]
    fn rank_is_sorted() {
        let a = erdos_renyi(1000, 1000, 4.0, &mut Prng::new(163));
        let cls = classify(&a);
        let p = planner();
        let ranked = p.rank(&cls, 64, &Impl::NATIVE);
        for w in ranked.windows(2) {
            assert!(w[0].predicted_gflops >= w[1].predicted_gflops);
        }
    }

    #[test]
    fn pb_prediction_is_structure_independent_and_untiled() {
        use crate::model::ai_pb;
        let a = erdos_renyi(2000, 2000, 6.0, &mut Prng::new(167));
        let cls = classify(&a);
        let p = planner();
        let d = 16;
        let pred = p.predict(&cls, d, Impl::Pb);
        let params = AiParams::new(cls.stats.n, d, cls.stats.nnz);
        // small nnz: the spill arena admits the full width, so the
        // charged tile is the untiled d (pb_spill_tile caps it only
        // when 8·nnz·d outgrows the arena budget)
        assert_eq!(pred.dt, d, "arena budget admits the full width here");
        assert_eq!(pred.dt, pb_spill_tile(cls.stats.nnz, d));
        assert!((pred.ai - ai_pb(params)).abs() < 1e-15);
        // the same stats under any other classification predict the
        // same AI and roof — PB's traffic model ignores structure
        let mut relabeled = cls.clone();
        relabeled.class = SparsityClass::Diagonal;
        relabeled.model = SparsityModel::Diagonal;
        let pred2 = p.predict(&relabeled, d, Impl::Pb);
        assert_eq!(pred.ai, pred2.ai);
        assert_eq!(pred.roof_gflops, pred2.roof_gflops);
    }

    #[test]
    fn pb_rank_flips_with_structure() {
        use crate::model::BandwidthCeiling;
        // A DRAM-only ladder models the serving regime the router
        // cares about: B too large for any cache, so every gathering
        // kernel sits on the flat roof where its low random-class
        // prior bites. There PB must land in the explored top-3
        // (beating the gathering CSR/OPT outright; CSB's paper prior
        // keeps it the predicted leader — measurement arbitrates), and
        // on a banded matrix it must fall out of the top-3 entirely:
        // the adversarial candidate whose predicted win/loss flips
        // with structure.
        let machine = MachineParams { beta_gbs: 10.0, pi_gflops: 10_000.0 };
        let dram = vec![BandwidthCeiling {
            level: "DRAM".into(),
            capacity_bytes: usize::MAX,
            beta_gbs: machine.beta_gbs,
        }];
        let ladder = CacheAwareRoofline::new(dram, machine.pi_gflops);
        let p = Planner::with_ladder(Roofline::new(machine), ladder);
        let a = erdos_renyi(3000, 3000, 8.0, &mut Prng::new(168));
        let cls = classify(&a);
        assert_eq!(cls.class, SparsityClass::Random, "{}", cls.rationale);
        let ranked = p.rank(&cls, 16, &Impl::NATIVE);
        let pb_at = ranked.iter().position(|r| r.im == Impl::Pb).unwrap();
        assert!(pb_at < 3, "PB must be explored on random structure: {ranked:?}");
        let of = |im: Impl| ranked.iter().find(|r| r.im == im).unwrap().predicted_gflops;
        assert!(of(Impl::Pb) > of(Impl::Csr));
        assert!(of(Impl::Pb) > of(Impl::Opt));
        // a banded matrix keeps its structure-sensitive winners: the
        // diagonal model's AI dwarfs PB's structure-independent line
        let banded = crate::gen::banded(3000, 8, 0.3, &mut Prng::new(169));
        let bcls = classify(&banded);
        assert_eq!(bcls.class, SparsityClass::Diagonal, "{}", bcls.rationale);
        let branked = p.rank(&bcls, 16, &Impl::NATIVE);
        let pb_banded = branked.iter().position(|r| r.im == Impl::Pb).unwrap();
        assert!(pb_banded >= 3, "PB must not be explored on banded structure: {branked:?}");
    }

    #[test]
    fn spgemm_prediction_flips_with_structure() {
        use crate::model::BandwidthCeiling;
        use crate::spgemm::SpGemmImpl;
        // DRAM-only ladder: B too large for any cache, so the hash
        // kernel sits on the flat roof where its low random-class
        // prior bites — the SpGEMM analog of pb_rank_flips_with_structure
        let machine = MachineParams { beta_gbs: 10.0, pi_gflops: 10_000.0 };
        let dram = vec![BandwidthCeiling {
            level: "DRAM".into(),
            capacity_bytes: usize::MAX,
            beta_gbs: machine.beta_gbs,
        }];
        let ladder = CacheAwareRoofline::new(dram, machine.pi_gflops);
        let p = Planner::with_ladder(Roofline::new(machine), ladder);
        let a = erdos_renyi(3000, 3000, 8.0, &mut Prng::new(0x5d0));
        let cls = classify(&a);
        assert_eq!(cls.class, SparsityClass::Random, "{}", cls.rationale);
        let nnz = cls.stats.nnz;
        // square self-product shape: flops ≈ 2 · avg_row(B) · nnz(A)
        let params = SpGemmParams::new(3000, 3000, nnz, nnz, 2.0 * 8.0 * nnz as f64);
        let ranked = p.rank_spgemm(&cls, params);
        assert_eq!(ranked[0].im, SpGemmImpl::PbMerge, "{ranked:?}");
        assert!(ranked[0].predicted_gflops >= ranked[1].predicted_gflops);
        // the merge kernel's AI is lower by design; its win is the prior
        assert!(ranked[0].ai < ranked[1].ai);
        // a banded operand keeps the gathering kernel on top
        let banded_m = crate::gen::banded(3000, 8, 0.3, &mut Prng::new(0x5d1));
        let bcls = classify(&banded_m);
        assert_eq!(bcls.class, SparsityClass::Diagonal, "{}", bcls.rationale);
        let branked = p.rank_spgemm(&bcls, params);
        assert_eq!(branked[0].im, SpGemmImpl::Hash, "{branked:?}");
    }

    #[test]
    fn observe_spgemm_moves_prior_toward_measurement() {
        use crate::spgemm::SpGemmImpl;
        let a = erdos_renyi(2000, 2000, 6.0, &mut Prng::new(0x5d2));
        let cls = classify(&a);
        let p = planner();
        let nnz = cls.stats.nnz;
        let params = SpGemmParams::new(2000, 2000, nnz, nnz, 2.0 * 6.0 * nnz as f64);
        let before = p.predict_spgemm(&cls, params, SpGemmImpl::Hash);
        for _ in 0..10 {
            p.observe_spgemm(cls.class, SpGemmImpl::Hash, before.roof_gflops, before.roof_gflops);
        }
        let after = p.predict_spgemm(&cls, params, SpGemmImpl::Hash);
        assert!(after.prior > before.prior);
        assert!(after.predicted_gflops > before.predicted_gflops);
        // a measured cf above the floor raises the predicted AI
        let tighter = p.predict_spgemm(&cls, params.with_cf(16.0), SpGemmImpl::Hash);
        assert!(tighter.ai > after.ai);
        assert_eq!(tighter.cf, 16.0);
    }

    #[test]
    fn measured_ladder_is_preferred_over_nominal() {
        use crate::membench::{LadderLevel, MeasuredLadder};
        let machine = MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 };
        let mut p = Planner::new(Roofline::new(machine));
        assert_eq!(p.ladder_source(), LadderSource::Nominal);
        // a measured ladder whose DRAM rung disagrees hard with the
        // nominal β: installation must swap both the ceilings and π
        let ml = MeasuredLadder {
            levels: vec![
                LadderLevel {
                    level: "L1".into(),
                    capacity_bytes: 32 << 10,
                    read_gbs: 250.0,
                    write_gbs: 180.0,
                    triad_gbs: 240.0,
                },
                LadderLevel {
                    level: "DRAM".into(),
                    capacity_bytes: usize::MAX,
                    read_gbs: 17.0,
                    write_gbs: 12.0,
                    triad_gbs: 18.5,
                },
            ],
            peak_gflops: 77.0,
            simd_level: "avx".into(),
            threads: 2,
        };
        p.install_measured(ml.to_roofline());
        assert_eq!(p.ladder_source(), LadderSource::Measured);
        assert_eq!(p.ladder().pi_gflops, 77.0);
        // working set in the fast rung earns the measured 250, not
        // the nominal scaled β; DRAM earns the measured 18.5, not 10
        assert_eq!(p.ladder().attainable_gflops(0.1, 1 << 10), 25.0);
        assert_eq!(p.ladder().attainable_gflops(0.1, 1 << 30), 1.85);
        // predictions flow through the measured ladder
        let a = erdos_renyi(500, 500, 5.0, &mut Prng::new(0x5e0));
        let cls = classify(&a);
        let pred = p.predict(&cls, 8, Impl::Csr);
        assert!(pred.roof_gflops > 0.0);
    }

    #[test]
    fn resident_pipeline_beats_its_single_op_prediction() {
        use crate::model::PipelineParams;
        let a = erdos_renyi(2000, 2000, 6.0, &mut Prng::new(0x5f0));
        let cls = classify(&a);
        let p = planner();
        let d = 8;
        let params = AiParams::new(cls.stats.n, d, cls.stats.nnz);
        let ws = CacheAwareRoofline::spmm_working_set(cls.stats.n, d);
        assert!(p.ladder().cache_resident(ws), "small block must sit in a cache rung");
        let single = p.predict(&cls, d, Impl::Csr);
        let chain = p.predict_pipeline(&cls, PipelineParams::new(params, 8), Impl::Csr);
        assert!(chain.resident);
        assert!(chain.ai > single.ai, "chain {} vs single {}", chain.ai, single.ai);
        assert!(chain.predicted_gflops >= single.predicted_gflops);
        assert_eq!(chain.dt, d, "pipelines pin the untiled width");
    }

    #[test]
    fn streamed_pipeline_collapses_to_the_per_op_ai() {
        use crate::model::{BandwidthCeiling, PipelineParams};
        let machine = MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 };
        let dram = vec![BandwidthCeiling {
            level: "DRAM".into(),
            capacity_bytes: usize::MAX,
            beta_gbs: machine.beta_gbs,
        }];
        let ladder = CacheAwareRoofline::new(dram, machine.pi_gflops);
        let p = Planner::with_ladder(Roofline::new(machine), ladder);
        let a = erdos_renyi(1000, 1000, 5.0, &mut Prng::new(0x5f1));
        let cls = classify(&a);
        let params = AiParams::new(cls.stats.n, 16, cls.stats.nnz);
        let chain = p.predict_pipeline(&cls, PipelineParams::new(params, 6), Impl::Csr);
        assert!(!chain.resident, "DRAM-only ladder: nothing is resident");
        assert!((chain.ai - cls.model.ai(params)).abs() < 1e-12);
    }

    #[test]
    fn pipeline_rank_is_sorted_and_pb_stays_on_the_flat_roof() {
        use crate::model::{ai_pb, PipelineParams};
        let a = erdos_renyi(1500, 1500, 6.0, &mut Prng::new(0x5f2));
        let cls = classify(&a);
        let p = planner();
        let params = AiParams::new(cls.stats.n, 8, cls.stats.nnz);
        let pp = PipelineParams::new(params, 10);
        let ranked = p.rank_pipeline(&cls, pp, &Impl::NATIVE);
        for w in ranked.windows(2) {
            assert!(w[0].predicted_gflops >= w[1].predicted_gflops);
        }
        let pb = ranked.iter().find(|r| r.im == Impl::Pb).unwrap();
        assert!(!pb.resident, "PB streams regardless of residency");
        assert!((pb.ai - ai_pb(params)).abs() < 1e-12);
    }

    #[test]
    fn tile_candidates_cover_d_and_powers_widest_first() {
        assert_eq!(tile_candidates(1), vec![1]);
        assert_eq!(tile_candidates(8), vec![8]);
        assert_eq!(tile_candidates(64), vec![64, 32, 16, 8]);
        assert_eq!(tile_candidates(100), vec![100, 64, 32, 16, 8]);
    }

    #[test]
    fn non_finite_observations_never_poison_the_priors() {
        use crate::spgemm::SpGemmImpl;
        let p = planner();
        let before = p.prior(SparsityClass::Random, Impl::Csr);
        assert!(before.is_finite());
        // regression: NaN survives `.clamp(0.0, 2.0)` (clamp is
        // identity on NaN) — before the guard, one NaN measurement
        // stuck in the EMA forever and persisted into the snapshot
        p.observe(SparsityClass::Random, Impl::Csr, 10.0, f64::NAN);
        p.observe(SparsityClass::Random, Impl::Csr, 10.0, f64::INFINITY);
        p.observe(SparsityClass::Random, Impl::Csr, f64::NAN, 5.0);
        assert_eq!(p.prior(SparsityClass::Random, Impl::Csr), before);
        p.observe_spgemm(SparsityClass::Random, SpGemmImpl::Hash, 10.0, f64::NAN);
        assert!(p.spgemm_prior(SparsityClass::Random, SpGemmImpl::Hash).is_finite());
        // a healthy observation still moves the prior
        p.observe(SparsityClass::Random, Impl::Csr, 10.0, 9.0);
        assert_ne!(p.prior(SparsityClass::Random, Impl::Csr), before);
        assert!(p.prior(SparsityClass::Random, Impl::Csr).is_finite());
    }

    #[test]
    fn restoring_a_poisoned_prior_cold_starts_the_slot() {
        use crate::spgemm::SpGemmImpl;
        let p = planner();
        let seed = p.prior(SparsityClass::Blocked, Impl::Csb);
        // an already-poisoned snapshot (written before the observe
        // guard existed) must not re-poison on restore: the slot keeps
        // its seed prior instead
        p.set_prior(SparsityClass::Blocked, Impl::Csb, f64::NAN);
        assert_eq!(p.prior(SparsityClass::Blocked, Impl::Csb), seed);
        p.set_spgemm_prior(SparsityClass::Blocked, SpGemmImpl::Hash, f64::INFINITY);
        assert!(p.spgemm_prior(SparsityClass::Blocked, SpGemmImpl::Hash).is_finite());
        // finite values still restore, clamped to the observe band
        p.set_prior(SparsityClass::Blocked, Impl::Csb, 5.0);
        assert_eq!(p.prior(SparsityClass::Blocked, Impl::Csb), 2.0);
        p.set_prior(SparsityClass::Blocked, Impl::Csb, 0.37);
        assert_eq!(p.prior(SparsityClass::Blocked, Impl::Csb), 0.37);
    }

    #[test]
    fn roof_ties_keep_the_widest_tile() {
        // compute-roof regime: every fitting tile hits π, so roofs tie
        // and the planner must keep the widest candidate
        let machine = MachineParams { beta_gbs: 1000.0, pi_gflops: 1.0 };
        let levels = vec![("L2".to_string(), 1 << 30)];
        let ladder = CacheAwareRoofline::nominal(machine, &levels);
        let p = Planner::with_ladder(Roofline::new(machine), ladder);
        let a = mesh2d(40, MeshKind::Road, 0.62, &mut Prng::new(166));
        let cls = classify(&a);
        let pred = p.predict(&cls, 64, Impl::Csb);
        assert_eq!(pred.dt, 64, "π-capped roofs tie → widest (untiled) wins");
    }
}
