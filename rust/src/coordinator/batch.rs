//! Batched SpMM submission: buffer pooling and per-batch aggregate
//! reporting for [`crate::coordinator::Engine::submit_batch`].
//!
//! The paper's evaluation is sustained-throughput SpMM over many
//! matrices × many dense widths. Submitting those jobs one at a time
//! re-allocates the dense `B`/`C` operands per job and re-pays
//! allocator + page-fault traffic inside the measured region. The
//! batched path keeps two things warm across jobs:
//!
//! * the **persistent worker pool** (`spmm::pool`) — threads are parked
//!   between kernel calls, never re-spawned, and
//! * a [`BufferPool`] of dense `f64` allocations — `B`/`C` operands are
//!   recycled best-fit across jobs, so a (matrix, d) sweep allocates
//!   each distinct size once.
//!
//! The per-batch [`BatchReport`] aggregates throughput (total FLOPs /
//! kernel-execution seconds), model-prediction error over the batch,
//! and buffer-pool hit rates, so the dispatch overhead the batch path
//! removes stays measurable (`wall_secs` vs `exec_secs`).
//!
//! ```
//! use spmm_roofline::coordinator::BufferPool;
//!
//! let mut pool = BufferPool::new();
//! let b = pool.acquire(8, 4); // fresh allocation
//! pool.release(b);
//! let c = pool.acquire(4, 4); // recycles the 8×4 buffer
//! assert_eq!((pool.hits, pool.misses), (1, 1));
//! assert_eq!((c.nrows, c.ncols), (4, 4));
//! ```

use crate::coordinator::autotune::RouteDecision;
use crate::coordinator::job::{JobRecord, PredictionReport};
use crate::gen::Prng;
use crate::spmm::DenseMatrix;

/// Upper bound on retained free buffers; beyond it the smallest are
/// dropped (largest allocations are the expensive ones to rebuild).
const MAX_FREE: usize = 16;

/// A recycling pool of dense `f64` buffers keyed by capacity.
///
/// `acquire` hands out the smallest free allocation that fits
/// (best-fit) or allocates fresh; `release` returns a matrix's backing
/// storage for reuse. Hit/miss counters make reuse observable in batch
/// reports.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Vec<Vec<f64>>,
    /// Acquisitions served from a recycled allocation.
    pub hits: usize,
    /// Acquisitions that had to allocate.
    pub misses: usize,
}

impl BufferPool {
    /// An empty pool.
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Best-fit recycled allocation with capacity ≥ `len`, cleared to
    /// length 0 (hit/miss counters updated either way).
    fn take_free(&mut self, len: usize) -> Option<Vec<f64>> {
        let mut best: Option<usize> = None;
        for (i, buf) in self.free.iter().enumerate() {
            if buf.capacity() < len {
                continue;
            }
            match best {
                Some(j) if self.free[j].capacity() <= buf.capacity() => {}
                _ => best = Some(i),
            }
        }
        match best {
            Some(i) => {
                self.hits += 1;
                let mut data = self.free.swap_remove(i);
                data.clear();
                Some(data)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// A `rows × cols` matrix backed by a recycled allocation when one
    /// is large enough. Contents are zeroed.
    pub fn acquire(&mut self, rows: usize, cols: usize) -> DenseMatrix {
        let len = rows * cols;
        let mut data = self.take_free(len).unwrap_or_default();
        data.resize(len, 0.0);
        DenseMatrix::from_vec(rows, cols, data)
    }

    /// Like [`BufferPool::acquire`], but filled with uniform-random
    /// values in `[-1, 1)` in a single pass — no intermediate
    /// zero-fill for operands the caller would overwrite anyway (the
    /// `B` side of every engine job).
    pub fn acquire_random(&mut self, rows: usize, cols: usize, rng: &mut Prng) -> DenseMatrix {
        let len = rows * cols;
        let mut data = self.take_free(len).unwrap_or_else(|| Vec::with_capacity(len));
        data.extend((0..len).map(|_| rng.range_f64(-1.0, 1.0)));
        DenseMatrix::from_vec(rows, cols, data)
    }

    /// Return a matrix's backing storage to the pool.
    pub fn release(&mut self, m: DenseMatrix) {
        if m.data.capacity() == 0 {
            return;
        }
        self.free.push(m.data);
        if self.free.len() > MAX_FREE {
            // keep the largest allocations
            self.free.sort_by_key(|b| std::cmp::Reverse(b.capacity()));
            self.free.truncate(MAX_FREE);
        }
    }

    /// Free buffers currently retained.
    pub fn retained(&self) -> usize {
        self.free.len()
    }

    /// Fraction of acquisitions served from recycled storage.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Aggregate outcome of one [`Engine::submit_batch`] call.
///
/// [`Engine::submit_batch`]: crate::coordinator::Engine::submit_batch
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// Per-job records, in submission order.
    pub records: Vec<JobRecord>,
    /// End-to-end wall time of the batch (routing + buffer management
    /// + measurement loops).
    pub wall_secs: f64,
    /// Sum of the per-job median kernel-execution times — the portion
    /// the roofline models predict.
    pub exec_secs: f64,
    /// Total FLOPs executed per measured iteration (Σ 2·d·nnz).
    pub total_flops: f64,
    /// Prediction-accuracy summary over the batch.
    pub prediction: PredictionReport,
    /// Dense-buffer reuses during the batch.
    pub buffer_hits: usize,
    /// Dense-buffer allocations during the batch.
    pub buffer_misses: usize,
    /// Execution schedules served from the per-(matrix, impl, threads,
    /// d, dt) cache during the batch.
    pub schedule_hits: usize,
    /// Execution schedules that had to be planned during the batch.
    pub schedule_misses: usize,
    /// Routing decisions in force for this batch's (matrix, d) pairs
    /// (empty when autotuning is off). Filled by the engine after
    /// aggregation.
    pub routes: Vec<RouteDecision>,
    /// Exploration measurements the autotuner ran *during* this batch
    /// — 0 proves a re-submitted batch was served entirely from pinned
    /// decisions.
    pub explore_measurements: usize,
}

impl BatchReport {
    /// Summarise `records` (wall/buffer/schedule stats supplied by the
    /// engine).
    pub fn of(
        records: Vec<JobRecord>,
        wall_secs: f64,
        buffer_hits: usize,
        buffer_misses: usize,
        schedule_hits: usize,
        schedule_misses: usize,
    ) -> BatchReport {
        let exec_secs = records.iter().map(|r| r.secs).sum();
        // per-iteration FLOPs recovered exactly from GFLOP/s × seconds
        let total_flops = records.iter().map(|r| r.measured_gflops * r.secs * 1e9).sum();
        let prediction = PredictionReport::of(&records);
        BatchReport {
            records,
            wall_secs,
            exec_secs,
            total_flops,
            prediction,
            buffer_hits,
            buffer_misses,
            schedule_hits,
            schedule_misses,
            routes: Vec::new(),
            explore_measurements: 0,
        }
    }

    /// Attach the routing context (builder-style; used by the engine).
    pub fn with_routing(
        mut self,
        routes: Vec<RouteDecision>,
        explore_measurements: usize,
    ) -> BatchReport {
        self.routes = routes;
        self.explore_measurements = explore_measurements;
        self
    }

    /// Jobs in the batch.
    pub fn n_jobs(&self) -> usize {
        self.records.len()
    }

    /// Aggregate throughput over kernel-execution time (GFLOP/s).
    pub fn aggregate_gflops(&self) -> f64 {
        if self.exec_secs <= 0.0 {
            0.0
        } else {
            self.total_flops / self.exec_secs / 1e9
        }
    }

    /// Fraction of batch wall time spent outside kernel execution
    /// (routing, buffer management, measurement bookkeeping). The
    /// overhead the batched path exists to amortise.
    pub fn dispatch_overhead(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        // timed loops run ≥ the median per sample, so exec_secs can
        // only underestimate the in-kernel share; clamp at 0
        (1.0 - self.exec_secs / self.wall_secs).max(0.0)
    }

    /// Buffer-pool hit rate during the batch.
    pub fn buffer_hit_rate(&self) -> f64 {
        let total = self.buffer_hits + self.buffer_misses;
        if total == 0 {
            0.0
        } else {
            self.buffer_hits as f64 / total as f64
        }
    }

    /// Schedule-cache hit rate during the batch (planning amortised
    /// across repeated/batched submissions).
    pub fn schedule_hit_rate(&self) -> f64 {
        let total = self.schedule_hits + self.schedule_misses;
        if total == 0 {
            0.0
        } else {
            self.schedule_hits as f64 / total as f64
        }
    }

    /// One-line human summary.
    pub fn summary_line(&self) -> String {
        let routing = if self.routes.is_empty() {
            String::new()
        } else {
            format!(
                ", {} routed decisions ({} explored this batch)",
                self.routes.len(),
                self.explore_measurements
            )
        };
        format!(
            "batch: {} jobs, {:.2} GFLOP/s aggregate, geomean(meas/pred)={:.2}, \
             buffer hit rate {:.0}%, schedule hit rate {:.0}%, wall {:.1} ms{routing}",
            self.n_jobs(),
            self.aggregate_gflops(),
            self.prediction.geomean_ratio,
            100.0 * self.buffer_hit_rate(),
            100.0 * self.schedule_hit_rate(),
            self.wall_secs * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SparsityClass;
    use crate::spmm::Impl;

    fn rec(d: usize, secs: f64, gf: f64) -> JobRecord {
        JobRecord {
            matrix: "m".into(),
            class: SparsityClass::Random,
            d,
            chosen: Impl::Csr,
            reorder: crate::sparse::Reordering::None,
            dt: d,
            predicted_gflops: gf,
            ai: 0.1,
            secs,
            measured_gflops: gf,
        }
    }

    #[test]
    fn buffer_pool_recycles_best_fit() {
        let mut p = BufferPool::new();
        let a = p.acquire(10, 10); // 100
        let b = p.acquire(4, 4); // 16
        assert_eq!(p.misses, 2);
        p.release(a);
        p.release(b);
        // wants 16 → best fit is the 16-capacity buffer, not the 100
        let c = p.acquire(2, 8);
        assert_eq!(p.hits, 1);
        assert!(c.data.capacity() < 100);
        // everything zeroed
        assert!(c.data.iter().all(|&x| x == 0.0));
        assert_eq!(p.retained(), 1);
    }

    #[test]
    fn acquire_random_recycles_and_fills() {
        let mut p = BufferPool::new();
        let mut rng = Prng::new(9);
        let a = p.acquire(6, 6);
        p.release(a);
        let b = p.acquire_random(5, 5, &mut rng);
        assert_eq!((p.hits, p.misses), (1, 1));
        assert_eq!(b.data.len(), 25);
        // actually randomised, within the generator's range
        assert!(b.data.iter().any(|&x| x != 0.0));
        assert!(b.data.iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn buffer_pool_grows_when_nothing_fits() {
        let mut p = BufferPool::new();
        let a = p.acquire(2, 2);
        p.release(a);
        let big = p.acquire(100, 100);
        assert_eq!((p.hits, p.misses), (0, 2));
        assert_eq!(big.data.len(), 10_000);
    }

    #[test]
    fn buffer_pool_caps_retention() {
        let mut p = BufferPool::new();
        for i in 1..=(MAX_FREE + 8) {
            let m = p.acquire(i, 7);
            p.release(m);
        }
        assert!(p.retained() <= MAX_FREE);
    }

    #[test]
    fn report_aggregates() {
        // two jobs: 1 GFLOP in 0.5 s + 3 GFLOP in 0.5 s → 4 GFLOP/s over 1 s
        let records = vec![rec(4, 0.5, 2.0), rec(8, 0.5, 6.0)];
        let rep = BatchReport::of(records, 2.0, 3, 1, 1, 1);
        assert_eq!(rep.n_jobs(), 2);
        assert!((rep.exec_secs - 1.0).abs() < 1e-12);
        assert!((rep.aggregate_gflops() - 4.0).abs() < 1e-9);
        assert!((rep.dispatch_overhead() - 0.5).abs() < 1e-9);
        assert!((rep.buffer_hit_rate() - 0.75).abs() < 1e-12);
        assert!((rep.schedule_hit_rate() - 0.5).abs() < 1e-12);
        assert!(rep.summary_line().contains("2 jobs"));
        assert!(rep.summary_line().contains("schedule hit rate"));
    }

    #[test]
    fn empty_report() {
        let rep = BatchReport::of(Vec::new(), 0.0, 0, 0, 0, 0);
        assert_eq!(rep.n_jobs(), 0);
        assert_eq!(rep.aggregate_gflops(), 0.0);
        assert_eq!(rep.buffer_hit_rate(), 0.0);
        assert_eq!(rep.schedule_hit_rate(), 0.0);
    }
}
