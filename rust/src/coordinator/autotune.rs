//! The structure-adaptive autotuning router: close the
//! classify → predict → **measure** loop.
//!
//! The planner (PR 2) predicts; this module makes the engine *act* on
//! the prediction and *learn* from the measurement. For each
//! `(matrix, d)` the tuner
//!
//! 1. **enumerates** candidate plans = {prepared implementation ×
//!    reordering strategy} — reordering ([`Reordering`]) is the
//!    paper's "structure decides performance" lever: RCM can turn a
//!    scrambled mesh back into a banded matrix, degree-sort
//!    concentrates scale-free hubs into a dense corner,
//! 2. **scores** every candidate with the tile-aware planner, where a
//!    candidate's prediction uses the classification of its *reordered*
//!    matrix (the whole point: the class can change under `P·A·Pᵀ`),
//! 3. **explores**: measures the top-`k` predicted candidates once
//!    each, feeding every measurement back through
//!    [`Planner::observe`] so the priors sharpen for future
//!    predictions, and
//! 4. **exploits**: pins the measured-best candidate as a
//!    [`RouteDecision`]. Pinning converts the stored matrix in the
//!    [`MatrixRegistry`] (permute + rebuild kernels + invalidate
//!    cached schedules) so every later submission executes the winning
//!    layout straight from cache — re-submitting the same batch
//!    explores nothing.
//!
//! The decision records predicted and measured GFLOP/s plus the
//! *regret* of trusting the prediction alone (how much the measured
//! winner beat the predictor's top pick), so the router's value over
//! pure model-driven routing is itself a reported quantity
//! (`BENCH_route.json`).

use std::collections::HashMap;

use crate::coordinator::batch::BufferPool;
use crate::coordinator::learned::{features_of, LearnedRouter, RouteSource};
use crate::coordinator::planner::{Planner, PipelinePrediction, Prediction};
use crate::coordinator::registry::MatrixRegistry;
use crate::error::{Error, Result};
use crate::gen::{Prng, SparsityClass};
use crate::metrics::{bench_adaptive_checked, gflops, spmm_flops};
use crate::model::{FeatureVec, PipelineParams, SpGemmParams};
use crate::pattern::{classify, Classification};
use crate::sparse::{reorder::permute_symmetric, Csr, Reordering};
use crate::spgemm::{compression_factor, spgemm_flops, SpGemm, SpGemmImpl};
use crate::spmm::{build_native, Impl, Schedule, Spmm};

/// Knobs for the explore/exploit policy.
#[derive(Debug, Clone)]
pub struct AutotunePolicy {
    /// Master switch: when off, the engine routes purely on
    /// predictions (PR 2 behaviour).
    pub enabled: bool,
    /// Candidates measured per `(matrix, d)` decision, best-predicted
    /// first. 1 = trust the prediction outright (pure exploit).
    pub top_k: usize,
    /// Reordering strategies enumerated per matrix.
    pub reorderings: Vec<Reordering>,
    /// Timed iterations per exploration measurement (kept low — the
    /// point of exploring is a cheap ranking, not a publication
    /// number).
    pub explore_iters: usize,
    /// Minimum cumulative measured seconds per exploration sample.
    pub explore_min_secs: f64,
}

impl Default for AutotunePolicy {
    fn default() -> Self {
        AutotunePolicy {
            enabled: false,
            top_k: 3,
            reorderings: Reordering::ALL.to_vec(),
            explore_iters: 2,
            explore_min_secs: 0.05,
        }
    }
}

impl AutotunePolicy {
    /// The default policy with the master switch on.
    pub fn enabled() -> AutotunePolicy {
        AutotunePolicy { enabled: true, ..AutotunePolicy::default() }
    }
}

/// One scored (and possibly measured) candidate plan.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub im: Impl,
    pub reorder: Reordering,
    /// Class of the matrix *under this candidate's reordering*.
    pub class: SparsityClass,
    /// Planner prediction on the reordered classification.
    pub prediction: Prediction,
    /// Exploration measurement, when this candidate made the top-k.
    pub measured_gflops: Option<f64>,
}

/// A pinned routing decision for one `(matrix, d)`.
#[derive(Debug, Clone)]
pub struct RouteDecision {
    pub matrix: String,
    pub d: usize,
    /// Winning implementation.
    pub im: Impl,
    /// Winning reordering (pinned into the registry).
    pub reorder: Reordering,
    /// Column-tile width of the winning plan.
    pub dt: usize,
    /// Class of the winning layout.
    pub class: SparsityClass,
    /// Planner prediction for the winner at decision time.
    pub predicted_gflops: f64,
    /// Exploration measurement of the winner.
    pub measured_gflops: f64,
    /// Candidates enumerated (scored) for this decision.
    pub enumerated: usize,
    /// Candidates measured for this decision (≤ `top_k`).
    pub explored: usize,
    /// Measured winner minus the measured throughput of the
    /// predictor's top-ranked candidate — what measuring top-k bought
    /// over predict-and-commit (0 when the prediction was already
    /// right).
    pub regret_gflops: f64,
    /// Which router ranked the explore order: the analytic roofline
    /// model, or the learned forest promoting its prediction to the
    /// top (measurement still decides the pin either way).
    pub source: RouteSource,
    /// Forest confidence behind a learned promotion (0 for analytic).
    pub confidence: f64,
    /// Measured GFLOP/s of the *analytic* top-ranked candidate — the
    /// regret-vs-analytic baseline. 0 when that candidate was not
    /// measured (only possible with `top_k = 1` and a learned
    /// promotion that disagreed with it).
    pub analytic_gflops: f64,
    /// Structural features of the matrix (active layout) at decision
    /// time — what the learned router was (or would have been) asked,
    /// and what future training sets are built from.
    pub features: FeatureVec,
}

impl RouteDecision {
    /// One-line human rendering for tables and logs.
    pub fn summary(&self) -> String {
        format!(
            "{} d={} → {} / {} (dt={}, class {}, {} pred {:.2} meas {:.2} GFLOP/s, \
             regret {:.2}, {}/{} measured)",
            self.matrix,
            self.d,
            self.im,
            self.reorder,
            self.dt,
            self.class,
            self.source,
            self.predicted_gflops,
            self.measured_gflops,
            self.regret_gflops,
            self.explored,
            self.enumerated,
        )
    }

    /// Measured shortfall of the routed top pick against the analytic
    /// top pick — the learned router's regret-vs-analytic. 0 for
    /// analytic decisions (the baseline is itself) and when the
    /// analytic pick went unmeasured.
    pub fn regret_vs_analytic(&self) -> f64 {
        if self.source == RouteSource::Analytic || self.analytic_gflops <= 0.0 {
            return 0.0;
        }
        // the routed top pick's measurement: the winner minus what
        // measuring top-k bought over trusting the top pick
        let routed_pick = self.measured_gflops - self.regret_gflops;
        (self.analytic_gflops - routed_pick).max(0.0)
    }
}

/// One measured SpGEMM candidate, kept on the decision so reports and
/// `BENCH_route.json` can render the full predicted-vs-measured line
/// (≥ 2 candidates per tuned pair).
#[derive(Debug, Clone)]
pub struct SpGemmCandidate {
    pub im: SpGemmImpl,
    /// Planner prediction (at the conservative pre-execution cf).
    pub predicted_gflops: f64,
    /// Exploration measurement.
    pub measured_gflops: f64,
    /// Model AI the prediction used.
    pub ai: f64,
}

/// A pinned SpGEMM routing decision for one `(left, right)` matrix
/// pair — the `Workload::SpGemm` dimension of the router
/// ([`crate::coordinator::Workload`]).
#[derive(Debug, Clone)]
pub struct SpGemmDecision {
    /// Left operand (registered name).
    pub a: String,
    /// Right operand (registered name).
    pub b: String,
    /// Winning kernel.
    pub im: SpGemmImpl,
    /// Class of the left operand's active layout.
    pub class: SparsityClass,
    /// Measured compression factor `flops / nnz(C)` of the pair —
    /// cached here so later submissions predict at the measured cf
    /// instead of the conservative floor.
    pub cf: f64,
    /// Planner prediction for the winner at decision time.
    pub predicted_gflops: f64,
    /// Exploration measurement of the winner.
    pub measured_gflops: f64,
    /// Candidates measured for this decision.
    pub explored: usize,
    /// Measured winner minus the predictor's top pick (0 when the
    /// prediction was already right).
    pub regret_gflops: f64,
    /// Every measured candidate, predicted order.
    pub candidates: Vec<SpGemmCandidate>,
}

impl SpGemmDecision {
    /// One-line human rendering for tables and logs.
    pub fn summary(&self) -> String {
        format!(
            "{}×{} → {} (class {}, cf {:.1}, pred {:.2} meas {:.2} GFLOP/s, \
             regret {:.2}, {} measured)",
            self.a,
            self.b,
            self.im,
            self.class,
            self.cf,
            self.predicted_gflops,
            self.measured_gflops,
            self.regret_gflops,
            self.explored,
        )
    }
}

/// A pinned whole-chain decision for one `(matrix, chain)` — the
/// pipeline dimension of the router
/// ([`crate::coordinator::PipelineSpec`]). Unlike [`RouteDecision`]
/// this is keyed by the chain's display string (e.g.
/// `"GCN(layers=2,d=16)"`), because the winning kernel for a chained
/// workload depends on the whole chain — op count, widths, dense
/// epilogues — not just one `(matrix, d)`.
///
/// The candidate set is implementations on the **active layout only**:
/// pipeline outputs are row-indexed user data (PageRank scores, GCN
/// features), so permuting the operand under a chain would silently
/// permute the answer. `reorder` records the layout the measurement
/// was taken on, and the chain is measured end-to-end — the decision
/// optimizes the pipeline, not its hottest op.
#[derive(Debug, Clone)]
pub struct PipelineDecision {
    pub matrix: String,
    /// Chain identity: the `Workload` display string.
    pub chain: String,
    /// Block width of the chain's first op.
    pub d: usize,
    /// Winning implementation, shared by every chained op.
    pub im: Impl,
    /// Active layout the chain was measured on (never changed by a
    /// pipeline tune — see above).
    pub reorder: Reordering,
    /// Column-tile width: pinned to `d` (untiled) so one schedule
    /// replays bitwise across every chained width.
    pub dt: usize,
    pub class: SparsityClass,
    /// Whether the inter-op model found the `n × d` intermediate
    /// cache-resident at decision time.
    pub resident: bool,
    /// Whole-chain planner prediction for the winner.
    pub predicted_gflops: f64,
    /// Whole-chain exploration measurement of the winner.
    pub measured_gflops: f64,
    /// Candidates measured for this decision (≤ `top_k`).
    pub explored: usize,
    /// Measured winner minus the predictor's top pick (0 when the
    /// prediction was already right).
    pub regret_gflops: f64,
}

impl PipelineDecision {
    /// One-line human rendering for tables and logs.
    pub fn summary(&self) -> String {
        format!(
            "{} {} → {} / {} (class {}, {}, pred {:.2} meas {:.2} GFLOP/s, \
             regret {:.2}, {} measured)",
            self.matrix,
            self.chain,
            self.im,
            self.reorder,
            self.class,
            if self.resident { "resident" } else { "streamed" },
            self.predicted_gflops,
            self.measured_gflops,
            self.regret_gflops,
            self.explored,
        )
    }
}

/// The router: pinned decisions plus the explore bookkeeping.
///
/// Owned by the engine; all heavyweight collaborators (registry,
/// planner, buffer pool, RNG) are passed in per call so the borrow
/// structure stays flat.
pub struct Autotuner {
    policy: AutotunePolicy,
    decisions: HashMap<(String, usize), RouteDecision>,
    /// Pinned SpGEMM decisions, keyed by (left, right) operand names.
    spgemm_decisions: HashMap<(String, String), SpGemmDecision>,
    /// Pinned whole-chain decisions, keyed by (matrix, chain string).
    pipeline_decisions: HashMap<(String, String), PipelineDecision>,
    /// Total exploration measurements ever run (observability: batch
    /// reports diff this to prove re-submission measures nothing).
    measurements: usize,
    /// The learned structure router, when one is installed: a
    /// confident in-distribution prediction promotes its candidate to
    /// the top of the explore order ([`Autotuner::tune`]).
    learned: Option<LearnedRouter>,
}

impl Autotuner {
    pub fn new(policy: AutotunePolicy) -> Autotuner {
        Autotuner {
            policy,
            decisions: HashMap::new(),
            spgemm_decisions: HashMap::new(),
            pipeline_decisions: HashMap::new(),
            measurements: 0,
            learned: None,
        }
    }

    pub fn policy(&self) -> &AutotunePolicy {
        &self.policy
    }

    /// Install (or replace) the learned structure router. Pinned
    /// decisions are untouched — the forest only influences *future*
    /// tunes.
    pub fn install_learned(&mut self, router: LearnedRouter) {
        self.learned = Some(router);
    }

    /// The installed learned router, if any.
    pub fn learned(&self) -> Option<&LearnedRouter> {
        self.learned.as_ref()
    }

    /// Remove the learned router; tunes fall back to pure analytic
    /// ranking.
    pub fn clear_learned(&mut self) {
        self.learned = None;
    }

    /// The pinned decision for `(matrix, d)`, if one exists.
    pub fn decision(&self, matrix: &str, d: usize) -> Option<&RouteDecision> {
        self.decisions.get(&(matrix.to_string(), d))
    }

    /// Every pinned decision, sorted by (matrix, d).
    pub fn decisions(&self) -> Vec<&RouteDecision> {
        let mut v: Vec<&RouteDecision> = self.decisions.values().collect();
        v.sort_by(|a, b| (a.matrix.as_str(), a.d).cmp(&(b.matrix.as_str(), b.d)));
        v
    }

    /// Exploration measurements run so far.
    pub fn measurements(&self) -> usize {
        self.measurements
    }

    /// The pinned SpGEMM decision for the `(a, b)` pair, if one
    /// exists.
    pub fn spgemm_decision(&self, a: &str, b: &str) -> Option<&SpGemmDecision> {
        self.spgemm_decisions.get(&(a.to_string(), b.to_string()))
    }

    /// Every pinned SpGEMM decision, sorted by (a, b).
    pub fn spgemm_decisions(&self) -> Vec<&SpGemmDecision> {
        let mut v: Vec<&SpGemmDecision> = self.spgemm_decisions.values().collect();
        v.sort_by(|x, y| (x.a.as_str(), x.b.as_str()).cmp(&(y.a.as_str(), y.b.as_str())));
        v
    }

    /// The pinned pipeline decision for `(matrix, chain)`, if one
    /// exists. `chain` is the workload's display string.
    pub fn pipeline_decision(&self, matrix: &str, chain: &str) -> Option<&PipelineDecision> {
        self.pipeline_decisions.get(&(matrix.to_string(), chain.to_string()))
    }

    /// Every pinned pipeline decision, sorted by (matrix, chain).
    pub fn pipeline_decisions(&self) -> Vec<&PipelineDecision> {
        let mut v: Vec<&PipelineDecision> = self.pipeline_decisions.values().collect();
        v.sort_by(|x, y| {
            (x.matrix.as_str(), x.chain.as_str()).cmp(&(y.matrix.as_str(), y.chain.as_str()))
        });
        v
    }

    /// Adopt a decision from a persisted snapshot: it pins exactly
    /// like one tuned in-process — later submissions serve from it
    /// with **no** exploration — but the measurement counter is
    /// untouched (this process did not run those measurements).
    pub fn adopt(&mut self, dec: RouteDecision) {
        self.decisions.insert((dec.matrix.clone(), dec.d), dec);
    }

    /// Adopt a persisted SpGEMM pair decision (see [`Autotuner::adopt`]).
    pub fn adopt_spgemm(&mut self, dec: SpGemmDecision) {
        self.spgemm_decisions.insert((dec.a.clone(), dec.b.clone()), dec);
    }

    /// Adopt a persisted pipeline decision (see [`Autotuner::adopt`]).
    pub fn adopt_pipeline(&mut self, dec: PipelineDecision) {
        self.pipeline_decisions.insert((dec.matrix.clone(), dec.chain.clone()), dec);
    }

    /// Drop every decision for `matrix` (the matrix was re-registered;
    /// its structure may have changed). SpGEMM decisions go whether the
    /// matrix was the left or the right operand; pipeline decisions go
    /// with their operand.
    pub fn forget(&mut self, matrix: &str) {
        self.decisions.retain(|k, _| k.0 != matrix);
        self.invalidate_spgemm(matrix);
        self.invalidate_pipelines(matrix);
    }

    /// Drop every pipeline decision over `matrix`. Called when the
    /// matrix's active layout changes: chain measurements (and the
    /// row-indexed outputs they describe) were taken on the old
    /// layout.
    fn invalidate_pipelines(&mut self, matrix: &str) {
        self.pipeline_decisions.retain(|k, _| k.0 != matrix);
    }

    /// Drop every SpGEMM pair decision involving `matrix` as either
    /// operand. Called when the matrix's active layout changes
    /// (re-registration, or an SpMM tune pinning a reordering): the
    /// permuted matrix yields a *different product*, so a pin measured
    /// on the old layout — its winner and its cached cf — is stale.
    fn invalidate_spgemm(&mut self, matrix: &str) {
        self.spgemm_decisions.retain(|k, _| k.0 != matrix && k.1 != matrix);
    }

    /// Resolve the decision for `(matrix, d)`, running the
    /// explore/exploit policy if none is pinned yet. On a fresh
    /// decision this measures up to `top_k` candidates, feeds each
    /// measurement into the planner's priors, and converts the
    /// registry entry to the winning reordering.
    #[allow(clippy::too_many_arguments)]
    pub fn tune(
        &mut self,
        matrix: &str,
        d: usize,
        registry: &mut MatrixRegistry,
        planner: &Planner,
        buffers: &mut BufferPool,
        rng: &mut Prng,
    ) -> Result<RouteDecision> {
        if let Some(dec) = self.decision(matrix, d) {
            return Ok(dec.clone());
        }
        let entry = registry
            .get(matrix)
            .ok_or_else(|| Error::Usage(format!("matrix '{matrix}' not registered")))?;
        let impls = entry.native_impls().to_vec();
        if impls.is_empty() {
            return Err(Error::Usage(format!("no native kernels prepared for '{matrix}'")));
        }
        let active = entry.reordering();
        // decision-time features come from the *active* layout — the
        // same view a future submit (and the learned router) sees
        let feats = features_of(&entry.classification, d);
        let base = entry.base_csr();
        let square = base.nrows == base.ncols;

        // the physical layout is per-*matrix* while decisions are
        // per-(matrix, d): once any decision pinned a layout, later
        // tunes for other widths explore formats only, on that layout —
        // otherwise a d=64 tune could permute the matrix out from
        // under the d=4 decision (and invalidate its cached schedules)
        let layout_pinned = self.decisions.keys().any(|(m, _)| m == matrix);
        let reorder_candidates: Vec<Reordering> =
            if layout_pinned { vec![active] } else { self.policy.reorderings.clone() };

        // one layout per reordering strategy: its classification, and
        // the permuted matrix itself for non-active layouts (the
        // active one is served straight from the registry)
        let mut layouts: Vec<(Reordering, Classification, Option<Csr>)> = Vec::new();
        for &r in &reorder_candidates {
            if r != Reordering::None && !square {
                continue;
            }
            if layouts.iter().any(|(lr, _, _)| *lr == r) {
                continue;
            }
            if r == active {
                layouts.push((r, entry.classification.clone(), None));
            } else {
                let permuted = match r.permutation(base) {
                    Some(p) => permute_symmetric(base, &p),
                    None => base.clone(),
                };
                let cls = classify(&permuted);
                layouts.push((r, cls, Some(permuted)));
            }
        }
        if layouts.is_empty() {
            // policy listed no applicable reordering — fall back to the
            // active layout so format choice still gets tuned
            layouts.push((active, entry.classification.clone(), None));
        }

        // score the full candidate cross-product with the planner
        let mut scored: Vec<(usize, Candidate)> = Vec::new();
        for (li, (r, cls, _)) in layouts.iter().enumerate() {
            for &im in &impls {
                let prediction = planner.predict(cls, d, im);
                scored.push((
                    li,
                    Candidate {
                        im,
                        reorder: *r,
                        class: cls.class,
                        prediction,
                        measured_gflops: None,
                    },
                ));
            }
        }
        let enumerated = scored.len();
        scored.sort_by(|a, b| {
            b.1.prediction.predicted_gflops.total_cmp(&a.1.prediction.predicted_gflops)
        });

        // remember the analytic top pick before any learned promotion:
        // it is the regret-vs-analytic baseline
        let analytic_top = (scored[0].1.im, scored[0].1.reorder);

        // learned promotion: a confident in-distribution forest
        // prediction moves its candidate to the top of the explore
        // order and supplies its tile width — the analytic ranking is
        // otherwise untouched, and the measured best still wins the
        // pin. Off-distribution / low-confidence queries return None
        // and the analytic order stands (the fallback rule).
        let mut source = RouteSource::Analytic;
        let mut confidence = 0.0;
        if let Some(lr) = self.learned.as_ref().and_then(|l| l.route(&feats)) {
            if let Some(pos) = scored
                .iter()
                .position(|(_, c)| c.im == lr.im && c.reorder == lr.reorder)
            {
                let (li, mut cand) = scored.remove(pos);
                // the forest's tile width, bounded by this job's d
                cand.prediction.dt = lr.dt.clamp(1, d);
                scored.insert(0, (li, cand));
                source = RouteSource::Learned;
                confidence = lr.confidence;
            }
            // a predicted (impl, reorder) outside the enumerated set
            // (kernel not prepared, reordering not applicable) cannot
            // be promoted: analytic order stands
        }

        // explore: measure the top-k predicted candidates once each.
        // A candidate whose measurement errors is *skipped*, not
        // fatal — one flaky kernel must not kill the whole tune; only
        // an all-failed explore errors (as Usage, never a panic).
        let k = self.policy.top_k.clamp(1, scored.len());
        let mut measured: Vec<Candidate> = Vec::new();
        let mut last_err: Option<Error> = None;
        for (li, mut cand) in scored.into_iter().take(k) {
            let dt = cand.prediction.dt;
            let gf_res = match &layouts[li].2 {
                None => {
                    // active layout: prepared kernel + cached schedule
                    let entry = registry.get(matrix).expect("entry resolved above");
                    match entry.kernel(cand.im, d) {
                        Some(kernel) => {
                            let sched = registry
                                .schedule(matrix, cand.im, d, dt)
                                .expect("kernel exists");
                            measure(kernel, &sched, d, buffers, rng, &self.policy)
                        }
                        None => Err(Error::Usage(format!("kernel {} vanished", cand.im))),
                    }
                }
                Some(csr) => {
                    // candidate layout: throwaway kernel on the
                    // permuted matrix (pinning rebuilds it for keeps)
                    build_native(cand.im, csr, registry.threads()).and_then(|kernel| {
                        let sched = kernel.plan(Some(dt).filter(|&dt| dt < d));
                        measure(kernel.as_ref(), &sched, d, buffers, rng, &self.policy)
                    })
                }
            };
            let gf = match gf_res {
                Ok(gf) => gf,
                Err(e) => {
                    eprintln!(
                        "warning: explore candidate {} / {} failed for '{matrix}' d={d}: \
                         {e} — skipping",
                        cand.im, cand.reorder
                    );
                    last_err = Some(e);
                    continue;
                }
            };
            planner.observe(cand.class, cand.im, cand.prediction.roof_gflops, gf);
            self.measurements += 1;
            cand.measured_gflops = Some(gf);
            measured.push(cand);
        }
        if measured.is_empty() {
            // every candidate errored: nothing to pin (the old code
            // `expect`ed here and panicked)
            return Err(Error::Usage(format!(
                "every explored candidate failed for '{matrix}' d={d}: {}",
                last_err.map_or_else(|| "no candidates".into(), |e| e.to_string())
            )));
        }

        // exploit: pin the measured-best candidate
        let best = measured
            .iter()
            .max_by(|a, b| {
                a.measured_gflops
                    .unwrap_or(f64::NEG_INFINITY)
                    .total_cmp(&b.measured_gflops.unwrap_or(f64::NEG_INFINITY))
            })
            .expect("measured is non-empty (checked above)")
            .clone();
        // `measured` is in explore order, so [0] is the routed top pick
        let predictor_pick_gf = measured[0].measured_gflops.unwrap_or(0.0);
        let best_gf = best.measured_gflops.unwrap_or(0.0);
        // the analytic baseline's own measurement, wherever it landed
        // in the explore order (0 when it was not measured)
        let analytic_gflops = measured
            .iter()
            .find(|c| (c.im, c.reorder) == analytic_top)
            .and_then(|c| c.measured_gflops)
            .unwrap_or(0.0);
        if best.reorder != active {
            registry.apply_reordering(matrix, best.reorder)?;
            // the permuted layout computes a *different* product —
            // any pinned SpGEMM decision involving this matrix was
            // measured (winner, cf) on the old layout and must go;
            // likewise chains, whose row-indexed outputs would move
            self.invalidate_spgemm(matrix);
            self.invalidate_pipelines(matrix);
        }
        let decision = RouteDecision {
            matrix: matrix.to_string(),
            d,
            im: best.im,
            reorder: best.reorder,
            dt: best.prediction.dt,
            class: best.class,
            predicted_gflops: best.prediction.predicted_gflops,
            measured_gflops: best_gf,
            enumerated,
            explored: measured.len(),
            regret_gflops: (best_gf - predictor_pick_gf).max(0.0),
            source,
            confidence,
            analytic_gflops,
            features: feats,
        };
        self.decisions.insert((matrix.to_string(), d), decision.clone());
        Ok(decision)
    }

    /// Resolve the SpGEMM decision for the `(a, b)` pair, running the
    /// explore/exploit policy if none is pinned yet: prepare both
    /// kernels over `a`'s active layout, rank them with the
    /// cf-parameterized planner (at the conservative pre-execution
    /// floor — `nnz(C)` is unknown until the first run), measure up to
    /// `top_k` candidates, feed every measurement into the SpGEMM
    /// priors, and pin the measured best along with the pair's
    /// measured compression factor. Reorderings are not enumerated:
    /// `P·A·Pᵀ·B` is a different product, not a different layout of
    /// the same one.
    pub fn tune_spgemm(
        &mut self,
        a: &str,
        b: &str,
        registry: &mut MatrixRegistry,
        planner: &Planner,
    ) -> Result<SpGemmDecision> {
        if let Some(dec) = self.spgemm_decision(a, b) {
            return Ok(dec.clone());
        }
        // validate the pair before building any kernel: a mismatched
        // pair must not pay (and retain) the binning of either
        registry.spgemm_pair(a, b)?;
        for im in SpGemmImpl::ALL {
            registry.ensure_spgemm(a, im)?;
        }
        let (entry_a, entry_b) = registry.spgemm_pair(a, b).expect("validated above");
        let (acsr, bcsr) = (entry_a.csr(), entry_b.csr());
        let flops = spgemm_flops(acsr, bcsr);
        let params =
            SpGemmParams::new(acsr.nrows, bcsr.nrows, acsr.nnz(), bcsr.nnz(), flops);
        let cls = entry_a.classification.clone();
        let ranked = planner.rank_spgemm(&cls, params);
        let k = self.policy.top_k.clamp(1, ranked.len());

        let mut measured: Vec<SpGemmCandidate> = Vec::new();
        let mut cf_measured: Option<f64> = None;
        let mut last_err: Option<Error> = None;
        for pred in ranked.into_iter().take(k) {
            let kernel = entry_a.spgemm_kernel(pred.im).expect("ensured above");
            let sched = kernel.plan();
            // first execution surfaces kernel errors before the timing
            // loop and yields nnz(C) for the measured cf; a failing
            // candidate is skipped, not fatal — the healthy kernel can
            // still win the pin
            let c = match kernel.execute_with(bcsr, &sched) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!(
                        "warning: SpGEMM candidate {} failed for {a}×{b}: {e} — skipping",
                        pred.im
                    );
                    last_err = Some(e);
                    continue;
                }
            };
            cf_measured = Some(compression_factor(flops, c.nnz()));
            drop(c);
            let iters = self.policy.explore_iters.max(1);
            let r = match bench_adaptive_checked(
                0,
                iters,
                iters * 4,
                self.policy.explore_min_secs,
                |_| kernel.execute_with(bcsr, &sched).map(|_| ()),
            ) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!(
                        "warning: SpGEMM candidate {} failed mid-loop for {a}×{b}: {e} \
                         — skipping",
                        pred.im
                    );
                    last_err = Some(e);
                    continue;
                }
            };
            let gf = gflops(flops, r.median_secs());
            planner.observe_spgemm(cls.class, pred.im, pred.roof_gflops, gf);
            self.measurements += 1;
            measured.push(SpGemmCandidate {
                im: pred.im,
                predicted_gflops: pred.predicted_gflops,
                measured_gflops: gf,
                ai: pred.ai,
            });
        }
        if measured.is_empty() {
            // every kernel errored: nothing to pin (the old code
            // `expect`ed here and panicked)
            return Err(Error::Usage(format!(
                "every SpGEMM candidate failed for {a}×{b}: {}",
                last_err.map_or_else(|| "no candidates".into(), |e| e.to_string())
            )));
        }

        let best = measured
            .iter()
            .max_by(|x, y| x.measured_gflops.total_cmp(&y.measured_gflops))
            .expect("measured is non-empty (checked above)")
            .clone();
        // `measured` keeps predicted order, so [0] is the predictor's
        // best *surviving* pick
        let predictor_pick = measured[0].measured_gflops;
        let decision = SpGemmDecision {
            a: a.to_string(),
            b: b.to_string(),
            im: best.im,
            class: cls.class,
            cf: cf_measured.unwrap_or(params.cf),
            predicted_gflops: best.predicted_gflops,
            measured_gflops: best.measured_gflops,
            explored: measured.len(),
            regret_gflops: (best.measured_gflops - predictor_pick).max(0.0),
            candidates: measured,
        };
        self.spgemm_decisions
            .insert((a.to_string(), b.to_string()), decision.clone());
        Ok(decision)
    }

    /// Resolve the whole-chain decision for `(matrix, chain)`, running
    /// the explore/exploit policy if none is pinned yet: rank
    /// `candidates` (implementations prepared on the **active**
    /// layout) with the inter-op pipeline model
    /// ([`Planner::rank_pipeline`]), measure the top-`k` end-to-end
    /// through the caller-supplied `measure` closure — the closure
    /// owns the chain's actual execution (the engine routes it through
    /// its cached schedule and shared pool), so the tuner stays
    /// decoupled from how a chain runs — feed each measurement into
    /// the per-op priors at the chain roof, and pin the measured best.
    ///
    /// Reorderings are deliberately **not** enumerated: chain outputs
    /// are row-indexed user data, so a permuted layout is a different
    /// answer, not a faster route to the same one.
    #[allow(clippy::too_many_arguments)]
    pub fn tune_pipeline(
        &mut self,
        matrix: &str,
        chain: &str,
        d: usize,
        cls: &Classification,
        pp: PipelineParams,
        candidates: &[Impl],
        active: Reordering,
        planner: &Planner,
        measure: &mut dyn FnMut(Impl) -> Result<f64>,
    ) -> Result<PipelineDecision> {
        if let Some(dec) = self.pipeline_decision(matrix, chain) {
            return Ok(dec.clone());
        }
        if candidates.is_empty() {
            return Err(Error::Usage(format!(
                "no native kernels prepared for '{matrix}'"
            )));
        }
        let ranked = planner.rank_pipeline(cls, pp, candidates);
        let k = self.policy.top_k.clamp(1, ranked.len());

        let mut measured: Vec<(PipelinePrediction, f64)> = Vec::new();
        let mut last_err: Option<Error> = None;
        for pred in ranked.into_iter().take(k) {
            // a failing chain candidate is skipped, not fatal
            let gf = match measure(pred.im) {
                Ok(gf) => gf,
                Err(e) => {
                    eprintln!(
                        "warning: pipeline candidate {} failed for '{matrix}' {chain}: \
                         {e} — skipping",
                        pred.im
                    );
                    last_err = Some(e);
                    continue;
                }
            };
            planner.observe(cls.class, pred.im, pred.roof_gflops, gf);
            self.measurements += 1;
            measured.push((pred, gf));
        }
        if measured.is_empty() {
            // every candidate errored: nothing to pin (the old code
            // `expect`ed here and panicked)
            return Err(Error::Usage(format!(
                "every pipeline candidate failed for '{matrix}' {chain}: {}",
                last_err.map_or_else(|| "no candidates".into(), |e| e.to_string())
            )));
        }

        let &(best, best_gf) = measured
            .iter()
            .max_by(|x, y| x.1.total_cmp(&y.1))
            .expect("measured is non-empty (checked above)");
        // `measured` keeps predicted order, so [0] is the predictor's
        // best *surviving* pick
        let predictor_pick = measured[0].1;
        let decision = PipelineDecision {
            matrix: matrix.to_string(),
            chain: chain.to_string(),
            d,
            im: best.im,
            reorder: active,
            dt: best.dt,
            class: cls.class,
            resident: best.resident,
            predicted_gflops: best.predicted_gflops,
            measured_gflops: best_gf,
            explored: measured.len(),
            regret_gflops: (best_gf - predictor_pick).max(0.0),
        };
        self.pipeline_decisions
            .insert((matrix.to_string(), chain.to_string()), decision.clone());
        Ok(decision)
    }
}

/// One exploration measurement: run the kernel over its schedule with
/// pooled operands and return GFLOP/s. Kernel errors — before *or*
/// mid-way through the timing loop — surface as `Err`, so a broken
/// candidate fails the tune cleanly instead of panicking through the
/// worker pool (an earlier revision `expect`ed mid-loop and a flaky
/// kernel poisoned the whole tune; regression-tested below).
fn measure(
    kernel: &dyn Spmm,
    sched: &Schedule,
    d: usize,
    buffers: &mut BufferPool,
    rng: &mut Prng,
    policy: &AutotunePolicy,
) -> Result<f64> {
    let b = buffers.acquire_random(kernel.ncols(), d, rng);
    let mut c = buffers.acquire(kernel.nrows(), d);
    if let Err(e) = kernel.execute_with(&b, &mut c, sched) {
        buffers.release(b);
        buffers.release(c);
        return Err(e);
    }
    let iters = policy.explore_iters.max(1);
    let r = bench_adaptive_checked(0, iters, iters * 4, policy.explore_min_secs, |_| {
        kernel.execute_with(&b, &mut c, sched)
    });
    buffers.release(b);
    buffers.release(c);
    let r = r?;
    Ok(gflops(spmm_flops(kernel.nnz(), d), r.median_secs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{erdos_renyi, mesh2d, MeshKind, Prng};
    use crate::model::{MachineParams, Roofline};
    use crate::sparse::reorder::random_permutation;

    fn fixture() -> (MatrixRegistry, Planner, BufferPool, Prng) {
        let reg = MatrixRegistry::new(2);
        let planner =
            Planner::new(Roofline::new(MachineParams { beta_gbs: 10.0, pi_gflops: 100.0 }));
        (reg, planner, BufferPool::new(), Prng::new(0x7e57))
    }

    fn quick_policy() -> AutotunePolicy {
        AutotunePolicy {
            explore_iters: 1,
            explore_min_secs: 0.0,
            ..AutotunePolicy::enabled()
        }
    }

    #[test]
    fn tune_pins_a_decision_and_reuses_it() {
        let (mut reg, planner, mut buf, mut rng) = fixture();
        let a = erdos_renyi(250, 250, 5.0, &mut Prng::new(0xF00));
        reg.register("er", a, &[Impl::Csr, Impl::Csb]).unwrap();
        let mut tuner = Autotuner::new(quick_policy());
        let dec = tuner.tune("er", 8, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(dec.matrix, "er");
        assert!(dec.measured_gflops > 0.0);
        assert!(dec.explored >= 1 && dec.explored <= 3);
        assert!(dec.enumerated >= 2, "impls × reorderings must be enumerated");
        assert!(dec.regret_gflops >= 0.0);
        let n = tuner.measurements();
        assert_eq!(n, dec.explored);
        // second tune for the same (matrix, d): pinned, no re-measure
        let dec2 = tuner.tune("er", 8, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(tuner.measurements(), n);
        assert_eq!(dec2.im, dec.im);
        assert_eq!(dec2.reorder, dec.reorder);
        // and the decision is listed
        assert_eq!(tuner.decisions().len(), 1);
        assert!(tuner.decision("er", 8).is_some());
        assert!(tuner.decision("er", 16).is_none());
    }

    #[test]
    fn winner_is_measured_best_and_registry_follows_the_reorder() {
        let (mut reg, planner, mut buf, mut rng) = fixture();
        // a scrambled mesh — RCM is a live candidate here
        let mut g = Prng::new(0xF01);
        let a = mesh2d(14, MeshKind::Triangular, 0.9, &mut g);
        let scrambled =
            permute_symmetric(&a, &random_permutation(a.nrows, &mut g));
        reg.register("mesh", scrambled, &[Impl::Csr, Impl::Csb]).unwrap();
        let mut tuner = Autotuner::new(quick_policy());
        let dec = tuner.tune("mesh", 8, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        let e = reg.get("mesh").unwrap();
        assert_eq!(e.reordering(), dec.reorder, "registry must pin the winner's layout");
        assert_eq!(e.classification.class, dec.class);
        if dec.reorder != Reordering::None {
            assert!(e.permutation().is_some());
        }
        // the pinned impl is servable right now
        assert!(e.kernel(dec.im, 8).is_some());
    }

    #[test]
    fn later_widths_explore_formats_on_the_frozen_layout() {
        let (mut reg, planner, mut buf, mut rng) = fixture();
        let a = erdos_renyi(200, 200, 5.0, &mut Prng::new(0xF04));
        reg.register("m", a, &[Impl::Csr, Impl::Opt, Impl::Csb]).unwrap();
        let mut tuner = Autotuner::new(quick_policy());
        let d1 = tuner.tune("m", 4, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(d1.enumerated, 9, "first tune: 3 impls × 3 reorderings");
        let d2 = tuner.tune("m", 16, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(d2.reorder, d1.reorder, "layout is frozen after the first decision");
        assert_eq!(d2.enumerated, 3, "later widths explore formats only");
        assert_eq!(reg.get("m").unwrap().reordering(), d1.reorder);
    }

    #[test]
    fn forget_unpins_and_unknown_matrix_errors() {
        let (mut reg, planner, mut buf, mut rng) = fixture();
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(0xF02));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        let mut tuner = Autotuner::new(quick_policy());
        tuner.tune("m", 4, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert!(tuner.decision("m", 4).is_some());
        tuner.forget("m");
        assert!(tuner.decision("m", 4).is_none());
        assert!(tuner.tune("ghost", 4, &mut reg, &planner, &mut buf, &mut rng).is_err());
    }

    #[test]
    fn tune_spgemm_pins_both_kernels_and_reuses() {
        let (mut reg, planner, _buf, _rng) = fixture();
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(0xF10));
        let b = erdos_renyi(150, 150, 4.0, &mut Prng::new(0xF11));
        reg.register("a", a, &[Impl::Csr]).unwrap();
        reg.register("b", b, &[Impl::Csr]).unwrap();
        let mut tuner = Autotuner::new(quick_policy());
        let dec = tuner.tune_spgemm("a", "b", &mut reg, &planner).unwrap();
        assert_eq!((dec.a.as_str(), dec.b.as_str()), ("a", "b"));
        assert!(dec.measured_gflops > 0.0);
        assert_eq!(dec.explored, 2, "both SpGEMM kernels must be measured");
        assert_eq!(dec.candidates.len(), 2);
        assert!(dec.cf >= 2.0, "cf={}", dec.cf);
        assert!(dec.regret_gflops >= 0.0);
        let n = tuner.measurements();
        // second tune for the same pair: pinned, no re-measure
        let dec2 = tuner.tune_spgemm("a", "b", &mut reg, &planner).unwrap();
        assert_eq!(tuner.measurements(), n);
        assert_eq!(dec2.im, dec.im);
        assert_eq!(tuner.spgemm_decisions().len(), 1);
        // forgetting the *right* operand unpins the pair too
        tuner.forget("b");
        assert!(tuner.spgemm_decision("a", "b").is_none());
        // a layout conversion invalidates pins involving the matrix as
        // either operand — the permuted matrix is a different product
        tuner.tune_spgemm("a", "b", &mut reg, &planner).unwrap();
        assert!(tuner.spgemm_decision("a", "b").is_some());
        tuner.invalidate_spgemm("b");
        assert!(tuner.spgemm_decision("a", "b").is_none());
        // unknown operands error
        assert!(tuner.tune_spgemm("ghost", "b", &mut reg, &planner).is_err());
        assert!(tuner.tune_spgemm("a", "ghost", &mut reg, &planner).is_err());
        // dimension mismatch caught before any measurement
        let rect = erdos_renyi(150, 80, 3.0, &mut Prng::new(0xF12));
        reg.register("rect", rect, &[Impl::Csr]).unwrap();
        assert!(tuner.tune_spgemm("rect", "b", &mut reg, &planner).is_err());
    }

    #[test]
    fn measure_surfaces_midloop_kernel_errors_as_err() {
        use crate::spmm::{CsrSpmm, DenseMatrix};
        use std::sync::atomic::{AtomicUsize, Ordering};
        // fails on every call after the first — the pre-check passes,
        // so only the in-loop capture can catch it (the old `expect`
        // panicked here and poisoned the tune through the pool)
        struct Flaky {
            calls: AtomicUsize,
        }
        impl Spmm for Flaky {
            fn id(&self) -> Impl {
                Impl::Csr
            }
            fn nrows(&self) -> usize {
                4
            }
            fn ncols(&self) -> usize {
                4
            }
            fn nnz(&self) -> usize {
                4
            }
            fn execute(&self, _b: &DenseMatrix, _c: &mut DenseMatrix) -> crate::error::Result<()> {
                if self.calls.fetch_add(1, Ordering::SeqCst) == 0 {
                    Ok(())
                } else {
                    Err(Error::InvalidStructure("flaky kernel".into()))
                }
            }
        }
        let k = Flaky { calls: AtomicUsize::new(0) };
        let sched = k.plan(None);
        let mut buffers = BufferPool::new();
        let mut rng = Prng::new(0xF13);
        let policy = quick_policy();
        let r = measure(&k, &sched, 4, &mut buffers, &mut rng, &policy);
        assert!(r.is_err(), "mid-loop kernel failure must surface as Err");
        // the pool is not poisoned: a healthy measurement still works
        let a = erdos_renyi(60, 60, 3.0, &mut Prng::new(0xF14));
        let real = CsrSpmm::new(a, 2);
        let sched = real.plan(None);
        let gf = measure(&real, &sched, 4, &mut buffers, &mut rng, &policy).unwrap();
        assert!(gf > 0.0);
    }

    #[test]
    fn tune_pipeline_pins_the_measured_best_whole_chain() {
        use crate::model::AiParams;
        let (_reg, planner, _buf, _rng) = fixture();
        let a = erdos_renyi(200, 200, 5.0, &mut Prng::new(0xF20));
        let cls = classify(&a);
        let pp = PipelineParams::new(AiParams { n: 200, d: 8, nnz: a.nnz() }, 3);
        let impls = [Impl::Csr, Impl::Opt, Impl::Csb];
        let mut tuner = Autotuner::new(quick_policy());
        let mut calls = 0usize;
        {
            let mut measure = |im: Impl| {
                calls += 1;
                Ok(match im {
                    Impl::Opt => 9.0,
                    Impl::Csr => 5.0,
                    _ => 1.0,
                })
            };
            let dec = tuner
                .tune_pipeline(
                    "er",
                    "GCN(layers=3,d=8)",
                    8,
                    &cls,
                    pp,
                    &impls,
                    Reordering::None,
                    &planner,
                    &mut measure,
                )
                .unwrap();
            assert_eq!(dec.im, Impl::Opt, "measured best must win: {}", dec.summary());
            assert_eq!(dec.dt, 8, "chain plans are pinned untiled (dt = d)");
            assert_eq!(dec.explored, 3);
            assert!(dec.regret_gflops >= 0.0);
            assert!(dec.predicted_gflops > 0.0);
        }
        assert_eq!(calls, 3);
        assert_eq!(tuner.measurements(), 3);
        // pinned: the second resolve must not call the closure at all
        let mut poison = |_im: Impl| -> Result<f64> { panic!("pinned chain re-measured") };
        let dec2 = tuner
            .tune_pipeline(
                "er",
                "GCN(layers=3,d=8)",
                8,
                &cls,
                pp,
                &impls,
                Reordering::None,
                &planner,
                &mut poison,
            )
            .unwrap();
        assert_eq!(dec2.im, Impl::Opt);
        assert_eq!(tuner.measurements(), 3);
        // a *different* chain over the same matrix is its own decision
        let mut flat = |_im: Impl| Ok(2.0);
        tuner
            .tune_pipeline(
                "er",
                "Power(d=8,iters=4)",
                8,
                &cls,
                pp,
                &impls,
                Reordering::None,
                &planner,
                &mut flat,
            )
            .unwrap();
        assert_eq!(tuner.pipeline_decisions().len(), 2);
        assert_eq!(tuner.measurements(), 6);
    }

    #[test]
    fn pipeline_pins_adopt_without_counting_and_forget_drops() {
        use crate::model::AiParams;
        let (_reg, planner, _buf, _rng) = fixture();
        let a = erdos_renyi(120, 120, 4.0, &mut Prng::new(0xF21));
        let cls = classify(&a);
        let pp = PipelineParams::new(AiParams { n: 120, d: 4, nnz: a.nnz() }, 2);
        let mut tuner = Autotuner::new(quick_policy());
        let dec = PipelineDecision {
            matrix: "m".into(),
            chain: "PageRank(seeds=4,iters=10)".into(),
            d: 4,
            im: Impl::Csr,
            reorder: Reordering::None,
            dt: 4,
            class: cls.class,
            resident: true,
            predicted_gflops: 3.0,
            measured_gflops: 2.5,
            explored: 2,
            regret_gflops: 0.0,
        };
        tuner.adopt_pipeline(dec.clone());
        assert_eq!(tuner.measurements(), 0, "adoption is not a measurement");
        // an adopted pin serves without touching the closure
        let mut poison = |_im: Impl| -> Result<f64> { panic!("adopted pin re-measured") };
        let got = tuner
            .tune_pipeline(
                "m",
                &dec.chain,
                4,
                &cls,
                pp,
                &[Impl::Csr],
                Reordering::None,
                &planner,
                &mut poison,
            )
            .unwrap();
        assert_eq!(got.im, Impl::Csr);
        assert_eq!(got.measured_gflops, 2.5);
        tuner.forget("m");
        assert!(tuner.pipeline_decision("m", &dec.chain).is_none());
        // empty candidate set errors instead of pinning garbage
        let mut flat = |_im: Impl| Ok(1.0);
        assert!(tuner
            .tune_pipeline(
                "m",
                &dec.chain,
                4,
                &cls,
                pp,
                &[],
                Reordering::None,
                &planner,
                &mut flat,
            )
            .is_err());
    }

    /// A kernel that errors on every execution — planted through the
    /// `install_kernel` seam to exercise the all-candidates-failed
    /// path.
    struct AlwaysFail {
        n: usize,
        im: Impl,
    }
    impl Spmm for AlwaysFail {
        fn id(&self) -> Impl {
            self.im
        }
        fn nrows(&self) -> usize {
            self.n
        }
        fn ncols(&self) -> usize {
            self.n
        }
        fn nnz(&self) -> usize {
            0
        }
        fn execute(
            &self,
            _b: &crate::spmm::DenseMatrix,
            _c: &mut crate::spmm::DenseMatrix,
        ) -> crate::error::Result<()> {
            Err(Error::InvalidStructure("planted failure".into()))
        }
    }

    #[test]
    fn all_candidates_failing_is_usage_error_not_panic() {
        // regression: the old `.expect("k ≥ 1")` chain panicked when
        // every exploration measurement errored — now it's Err(Usage)
        let (mut reg, planner, mut buf, mut rng) = fixture();
        let a = erdos_renyi(80, 80, 3.0, &mut Prng::new(0xF40));
        reg.register("m", a, &[Impl::Csr]).unwrap();
        reg.install_kernel("m", Impl::Csr, Box::new(AlwaysFail { n: 80, im: Impl::Csr }))
            .unwrap();
        // active layout only: the planted kernel is the whole field
        let mut tuner = Autotuner::new(AutotunePolicy {
            reorderings: vec![Reordering::None],
            ..quick_policy()
        });
        let err = tuner.tune("m", 4, &mut reg, &planner, &mut buf, &mut rng).unwrap_err();
        assert!(matches!(err, Error::Usage(_)), "got {err:?}");
        assert!(tuner.decision("m", 4).is_none(), "a failed tune must pin nothing");
        assert_eq!(tuner.measurements(), 0);
    }

    #[test]
    fn flaky_candidate_is_skipped_and_the_healthy_one_pins() {
        let (mut reg, planner, mut buf, mut rng) = fixture();
        let a = erdos_renyi(80, 80, 3.0, &mut Prng::new(0xF41));
        reg.register("m", a, &[Impl::Csr, Impl::Opt]).unwrap();
        reg.install_kernel("m", Impl::Csr, Box::new(AlwaysFail { n: 80, im: Impl::Csr }))
            .unwrap();
        let mut tuner = Autotuner::new(AutotunePolicy {
            reorderings: vec![Reordering::None],
            ..quick_policy()
        });
        let dec = tuner.tune("m", 4, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(dec.im, Impl::Opt, "the healthy kernel must win: {}", dec.summary());
        assert_eq!(dec.explored, 1, "the flaky candidate is skipped, not measured");
        assert!(dec.measured_gflops > 0.0);
        assert_eq!(tuner.measurements(), 1);
    }

    #[test]
    fn learned_router_promotes_in_distribution_and_falls_back_off() {
        use crate::coordinator::learned::{Example, RouteLabel, TrainConfig};
        let (mut reg, planner, mut buf, mut rng) = fixture();
        let a = erdos_renyi(250, 250, 5.0, &mut Prng::new(0xF42));
        let cls = classify(&a);
        reg.register("er", a, &[Impl::Csr, Impl::Csb]).unwrap();
        let feats = features_of(&cls, 8);
        // a forest trained on this exact feature point, unanimous for
        // (CSB, none, 8)
        let examples: Vec<Example> = (0..6)
            .map(|_| Example {
                features: feats,
                label: RouteLabel { im: Impl::Csb, reorder: Reordering::None, dt: 8 },
            })
            .collect();
        let router = LearnedRouter::train(&examples, &TrainConfig::default()).unwrap();
        let mut tuner = Autotuner::new(quick_policy());
        tuner.install_learned(router);
        assert!(tuner.learned().is_some());
        let dec = tuner.tune("er", 8, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(dec.source, RouteSource::Learned, "{}", dec.summary());
        assert!(dec.confidence >= 0.65);
        assert_eq!(dec.features, feats);
        // top_k = 3 measures the analytic pick too, so the
        // regret-vs-analytic baseline is populated and consistent
        assert!(dec.analytic_gflops > 0.0);
        assert!(dec.regret_vs_analytic() >= 0.0);
        // the pin is still the measured best, whatever the promotion
        assert!(dec.measured_gflops > 0.0);
        // a different matrix at a different width: off the forest's
        // (degenerate) training distribution → analytic fallback
        let b = erdos_renyi(500, 500, 8.0, &mut Prng::new(0xF43));
        reg.register("er2", b, &[Impl::Csr, Impl::Csb]).unwrap();
        let dec2 = tuner.tune("er2", 16, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(dec2.source, RouteSource::Analytic, "{}", dec2.summary());
        assert_eq!(dec2.confidence, 0.0);
        assert_eq!(dec2.regret_vs_analytic(), 0.0, "analytic is its own baseline");
        // clearing the router restores pure analytic routing
        tuner.clear_learned();
        assert!(tuner.learned().is_none());
    }

    #[test]
    fn top_k_one_is_pure_predict_and_commit() {
        let (mut reg, planner, mut buf, mut rng) = fixture();
        let a = erdos_renyi(150, 150, 4.0, &mut Prng::new(0xF03));
        reg.register("m", a, &[Impl::Csr, Impl::Opt, Impl::Csb]).unwrap();
        let mut tuner = Autotuner::new(AutotunePolicy { top_k: 1, ..quick_policy() });
        let dec = tuner.tune("m", 8, &mut reg, &planner, &mut buf, &mut rng).unwrap();
        assert_eq!(dec.explored, 1);
        assert_eq!(dec.regret_gflops, 0.0, "nothing to regret with one sample");
        assert_eq!(tuner.measurements(), 1);
    }
}
