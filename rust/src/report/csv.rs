//! Minimal CSV writer (RFC-4180 quoting).

use crate::error::Result;
use std::io::Write;
use std::path::Path;

fn quote(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Write headers + rows to a CSV file, creating parent directories.
pub fn write_csv<P: AsRef<Path>>(path: P, headers: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", headers.iter().map(|h| quote(h)).collect::<Vec<_>>().join(","))?;
    for row in rows {
        writeln!(f, "{}", row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let dir = std::env::temp_dir().join("spmm_csv_test");
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &["a", "b,with comma"],
            &[vec!["1".into(), "say \"hi\"".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,\"b,with comma\"\n1,\"say \"\"hi\"\"\"\n");
    }
}
