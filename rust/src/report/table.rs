//! Aligned text / markdown tables.

/// A simple table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Column widths for aligned rendering.
    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with 3 significant-ish decimals (the paper's Table V
/// style).
pub fn fmt3(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else if x.abs() >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bee"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["10".into(), "200".into()]);
        let s = t.to_text();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("", &["x"]);
        t.row(vec!["7".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("| x |"));
        assert!(md.contains("|---|"));
        assert!(md.contains("| 7 |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt3_ranges() {
        assert_eq!(fmt3(0.0), "0");
        assert_eq!(fmt3(1.23456), "1.235");
        assert_eq!(fmt3(12.3456), "12.35");
        assert_eq!(fmt3(123.456), "123.5");
    }
}
