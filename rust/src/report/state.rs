//! Persisted autotune state and crash-safe merged-JSON writes.
//!
//! Two concerns live here because they share one mechanism:
//!
//! * **Atomic artifact writes** ([`atomic_write`], [`FileLock`]): every
//!   merged-JSON artifact (`BENCH_route.json`, the autotune snapshot)
//!   is written to a unique temporary sibling and `rename`d into
//!   place, so readers never observe a torn file; read-modify-write
//!   merges additionally serialise through a sibling `.lock` file so
//!   two writers cannot interleave (the `PerfLog::merge_save` race —
//!   regression-tested in `tests/integration_serve.rs`).
//! * **The autotune snapshot** ([`AutotuneState`]): a versioned JSON
//!   rendering of everything the router learned — pinned
//!   [`RouteDecision`]s, pinned [`SpGemmDecision`]s with their
//!   measured compression factors and per-candidate measurements, and
//!   the planner's refined `(class, impl)` efficiency priors. A
//!   restarted server loads the snapshot and *skips re-exploration*:
//!   restored decisions serve from the pin exactly like decisions
//!   tuned in-process (`tests/prop_serve.rs` asserts zero exploration
//!   measurements after a restore).
//!
//! The snapshot also carries the **measured calibration ladder**
//! ([`crate::membench::MeasuredLadder`], kinds `calib` +
//! `ladder_level`): calibration is seconds of wall-clock sweep, so a
//! restarted server re-installs the measured ladder into the planner
//! exactly as it re-adopts routing decisions — no re-measurement, no
//! re-exploration.
//!
//! Since v4 the snapshot also carries the **trained learned router**
//! ([`crate::coordinator::LearnedRouter`], kinds `learned_meta` +
//! `learned_range` + `learned_node`): training is deterministic but
//! needs the accumulated `BENCH_route.json` records, so a restarted
//! server re-installs the forest and routes learned-vs-analytic
//! exactly as before the restart — no retraining. A restored forest
//! is structurally validated ([`crate::coordinator::LearnedRouter::validate`])
//! before it is accepted; a malformed tree rejects the whole snapshot.
//!
//! The format is the repo's usual flat-record JSON (the crate builds
//! offline; serde is unavailable): one top-level object
//! `{"version": 4, "records": [...]}` whose records are discriminated
//! by a `"kind"` key (`calib`, `ladder_level`, `route`, `spgemm`,
//! `spgemm_candidate`, `pipeline`, `learned_meta`, `learned_range`,
//! `learned_node`, `spmm_prior`, `spgemm_prior`).
//! Floats are rendered with Rust's
//! shortest-round-trip `Display`, and records are emitted in sorted
//! key order, so save → load → save is **byte-identical** — the
//! property test's definition of a lossless snapshot. A corrupted or
//! version-skewed snapshot parses as `Err`; [`AutotuneState::load_or_cold`]
//! turns that into a warned cold start instead of a panic.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::parse_impl;
use crate::coordinator::{
    DecisionTree, LearnedRouter, Node, PipelineDecision, RouteDecision, RouteLabel, RouteSource,
    SpGemmCandidate, SpGemmDecision,
};
use crate::error::{Error, Result};
use crate::gen::SparsityClass;
use crate::membench::{LadderLevel, MeasuredLadder};
use crate::model::{FeatureVec, N_FEATURES};
use crate::sparse::Reordering;
use crate::spgemm::SpGemmImpl;
use crate::spmm::Impl;

/// Snapshot format version. Bumped on any schema change; a loader
/// refuses mismatched versions (cold start beats misread state).
/// v2 added the measured calibration ladder (`calib` / `ladder_level`
/// records); v3 added pinned whole-chain pipeline plans (`pipeline`
/// records); v4 added the trained learned router (`learned_meta` /
/// `learned_range` / `learned_node` records) and the route records'
/// source / confidence / analytic-baseline / feature columns.
pub const STATE_VERSION: u64 = 4;

/// How long a writer waits on a held [`FileLock`] before assuming the
/// holder crashed and stealing it.
const LOCK_TIMEOUT_MS: u64 = 5_000;
const LOCK_POLL_MS: u64 = 5;

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Write `contents` to `path` atomically: the bytes land in a unique
/// temporary sibling (`<path>.tmp.<pid>.<n>`) and are `rename`d into
/// place, so a concurrent reader sees either the old file or the new
/// one — never a prefix.
pub fn atomic_write(path: &str, contents: &str) -> Result<()> {
    let n = TMP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let tmp = format!("{path}.tmp.{}.{n}", std::process::id());
    std::fs::write(&tmp, contents)?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    Ok(())
}

/// An advisory cross-process lock serialising read-modify-write cycles
/// on one artifact: `acquire` spins until it can create `<path>.lock`
/// exclusively, `Drop` removes it. After [`LOCK_TIMEOUT_MS`] the lock
/// is presumed orphaned (holder crashed between create and drop) and
/// stolen once, with a warning.
pub struct FileLock {
    lock_path: PathBuf,
}

impl FileLock {
    /// Acquire the lock guarding `path` (not the lock file itself).
    pub fn acquire(path: &str) -> Result<FileLock> {
        let lock_path = PathBuf::from(format!("{path}.lock"));
        let mut stolen = false;
        let mut waited_ms = 0u64;
        loop {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&lock_path)
            {
                Ok(_) => return Ok(FileLock { lock_path }),
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if waited_ms >= LOCK_TIMEOUT_MS {
                        if stolen {
                            return Err(Error::Io(e));
                        }
                        eprintln!(
                            "warning: lock {} held past {LOCK_TIMEOUT_MS}ms — \
                             presuming its holder crashed and stealing it",
                            lock_path.display()
                        );
                        let _ = std::fs::remove_file(&lock_path);
                        stolen = true;
                        waited_ms = 0;
                    } else {
                        std::thread::sleep(std::time::Duration::from_millis(LOCK_POLL_MS));
                        waited_ms += LOCK_POLL_MS;
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

impl Drop for FileLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.lock_path);
    }
}

/// Everything the autotuning router learned, in snapshot form — see
/// the module docs for the on-disk format.
#[derive(Debug, Clone, Default)]
pub struct AutotuneState {
    /// Pinned SpMM routing decisions.
    pub routes: Vec<RouteDecision>,
    /// Pinned SpGEMM pair decisions (with measured cf and candidates).
    pub spgemm: Vec<SpGemmDecision>,
    /// Pinned whole-chain pipeline plans, keyed `(matrix, chain)` — a
    /// restored engine serves pipelines from these with zero
    /// re-exploration.
    pub pipelines: Vec<PipelineDecision>,
    /// Materialised `(class, impl)` SpMM efficiency priors.
    pub spmm_priors: Vec<(SparsityClass, Impl, f64)>,
    /// Materialised `(class, impl)` SpGEMM efficiency priors.
    pub spgemm_priors: Vec<(SparsityClass, SpGemmImpl, f64)>,
    /// Measured calibration ladder (bandwidth sweep + peak probe +
    /// dispatch decision), if one was run — a restored engine installs
    /// it without re-measuring.
    pub ladder: Option<MeasuredLadder>,
    /// Trained learned router, if one was installed — a restored
    /// engine routes learned-vs-analytic without retraining.
    pub learned: Option<LearnedRouter>,
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Shortest-round-trip float rendering (`Display` on `f64`), with
/// non-finite values — never produced by a healthy tune, not JSON —
/// clamped to 0 like the perf artifacts do.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".into()
    }
}

fn class_name(c: SparsityClass) -> String {
    format!("{c}")
}

fn parse_class(s: &str) -> Result<SparsityClass> {
    match s {
        "Blocking" => Ok(SparsityClass::Blocked),
        "Scale-free" => Ok(SparsityClass::ScaleFree),
        "Diagonal" => Ok(SparsityClass::Diagonal),
        "Uniform Random" => Ok(SparsityClass::Random),
        other => Err(Error::Parse(format!("unknown sparsity class '{other}'"))),
    }
}

pub(crate) fn parse_reordering(s: &str) -> Result<Reordering> {
    match s {
        "none" => Ok(Reordering::None),
        "rcm" => Ok(Reordering::Rcm),
        "degree" => Ok(Reordering::DegreeSort),
        other => Err(Error::Parse(format!("unknown reordering '{other}'"))),
    }
}

fn parse_source(s: &str) -> Result<RouteSource> {
    match s {
        "analytic" => Ok(RouteSource::Analytic),
        "learned" => Ok(RouteSource::Learned),
        other => Err(Error::Parse(format!("unknown route source '{other}'"))),
    }
}

fn parse_spgemm_impl(s: &str) -> Result<SpGemmImpl> {
    match s {
        "HASH" => Ok(SpGemmImpl::Hash),
        "PBMERGE" => Ok(SpGemmImpl::PbMerge),
        other => Err(Error::Parse(format!("unknown SpGEMM impl '{other}'"))),
    }
}

impl AutotuneState {
    /// True when there is nothing to persist.
    pub fn is_empty(&self) -> bool {
        self.routes.is_empty()
            && self.spgemm.is_empty()
            && self.pipelines.is_empty()
            && self.spmm_priors.is_empty()
            && self.spgemm_priors.is_empty()
            && self.ladder.is_none()
            && self.learned.is_none()
    }

    /// Serialise to the versioned snapshot format. Deterministic:
    /// records are sorted by their keys, floats render
    /// shortest-round-trip, so equal states serialise to equal bytes.
    pub fn to_json(&self) -> String {
        let mut routes: Vec<&RouteDecision> = self.routes.iter().collect();
        routes.sort_by(|a, b| (a.matrix.as_str(), a.d).cmp(&(b.matrix.as_str(), b.d)));
        let mut spgemm: Vec<&SpGemmDecision> = self.spgemm.iter().collect();
        spgemm.sort_by(|x, y| (x.a.as_str(), x.b.as_str()).cmp(&(y.a.as_str(), y.b.as_str())));
        let mut pipelines: Vec<&PipelineDecision> = self.pipelines.iter().collect();
        pipelines.sort_by(|x, y| {
            (x.matrix.as_str(), x.chain.as_str()).cmp(&(y.matrix.as_str(), y.chain.as_str()))
        });
        let mut spmm_priors = self.spmm_priors.clone();
        spmm_priors.sort_by_key(|(c, i, _)| (class_name(*c), format!("{i}")));
        let mut spgemm_priors = self.spgemm_priors.clone();
        spgemm_priors.sort_by_key(|(c, i, _)| (class_name(*c), format!("{i}")));

        let mut recs: Vec<String> = Vec::new();
        // the calib record precedes its ladder_level records so the
        // single-pass parser can attach levels to it (same ordering
        // contract spgemm_candidate has with its spgemm decision)
        if let Some(ml) = &self.ladder {
            recs.push(format!(
                "{{\"kind\": \"calib\", \"peak\": {}, \"simd\": \"{}\", \"threads\": {}}}",
                num(ml.peak_gflops),
                esc(&ml.simd_level),
                ml.threads,
            ));
            for l in &ml.levels {
                // capacity is a byte count, not an f64: rendered as the
                // integer usize. The DRAM rung's usize::MAX survives the
                // f64 parse because float→int `as` casts saturate.
                recs.push(format!(
                    "{{\"kind\": \"ladder_level\", \"level\": \"{}\", \"capacity\": {}, \
                     \"read\": {}, \"write\": {}, \"triad\": {}}}",
                    esc(&l.level),
                    l.capacity_bytes,
                    num(l.read_gbs),
                    num(l.write_gbs),
                    num(l.triad_gbs),
                ));
            }
        }
        for r in routes {
            // the decision-time feature vector rides along (f0..f6 in
            // FEATURE_NAMES order) so a restored decision can still be
            // audited against the learned router that ranked it
            let feats: String = r
                .features
                .0
                .iter()
                .enumerate()
                .map(|(i, v)| format!(", \"f{i}\": {}", num(*v)))
                .collect();
            recs.push(format!(
                "{{\"kind\": \"route\", \"matrix\": \"{}\", \"d\": {}, \"impl\": \"{}\", \
                 \"reorder\": \"{}\", \"dt\": {}, \"class\": \"{}\", \"predicted\": {}, \
                 \"measured\": {}, \"enumerated\": {}, \"explored\": {}, \"regret\": {}, \
                 \"source\": \"{}\", \"conf\": {}, \"analytic_gf\": {}{}}}",
                esc(&r.matrix),
                r.d,
                r.im,
                r.reorder,
                r.dt,
                r.class,
                num(r.predicted_gflops),
                num(r.measured_gflops),
                r.enumerated,
                r.explored,
                num(r.regret_gflops),
                r.source,
                num(r.confidence),
                num(r.analytic_gflops),
                feats,
            ));
        }
        for s in spgemm {
            recs.push(format!(
                "{{\"kind\": \"spgemm\", \"a\": \"{}\", \"b\": \"{}\", \"impl\": \"{}\", \
                 \"class\": \"{}\", \"cf\": {}, \"predicted\": {}, \"measured\": {}, \
                 \"explored\": {}, \"regret\": {}}}",
                esc(&s.a),
                esc(&s.b),
                s.im,
                s.class,
                num(s.cf),
                num(s.predicted_gflops),
                num(s.measured_gflops),
                s.explored,
                num(s.regret_gflops),
            ));
            for c in &s.candidates {
                recs.push(format!(
                    "{{\"kind\": \"spgemm_candidate\", \"a\": \"{}\", \"b\": \"{}\", \
                     \"impl\": \"{}\", \"predicted\": {}, \"measured\": {}, \"ai\": {}}}",
                    esc(&s.a),
                    esc(&s.b),
                    c.im,
                    num(c.predicted_gflops),
                    num(c.measured_gflops),
                    num(c.ai),
                ));
            }
        }
        for p in pipelines {
            recs.push(format!(
                "{{\"kind\": \"pipeline\", \"matrix\": \"{}\", \"chain\": \"{}\", \"d\": {}, \
                 \"impl\": \"{}\", \"reorder\": \"{}\", \"dt\": {}, \"class\": \"{}\", \
                 \"resident\": {}, \"predicted\": {}, \"measured\": {}, \"explored\": {}, \
                 \"regret\": {}}}",
                esc(&p.matrix),
                esc(&p.chain),
                p.d,
                p.im,
                p.reorder,
                p.dt,
                p.class,
                p.resident,
                num(p.predicted_gflops),
                num(p.measured_gflops),
                p.explored,
                num(p.regret_gflops),
            ));
        }
        if let Some(lr) = &self.learned {
            // the meta record precedes its range/node records so the
            // single-pass parser can attach them (the calib /
            // ladder_level ordering contract); ranges in feature-index
            // order, nodes in (tree, node) order — positional, so the
            // parser verifies indices as it re-assembles the forest
            recs.push(format!(
                "{{\"kind\": \"learned_meta\", \"examples\": {}, \"min_conf\": {}, \
                 \"min_support\": {}, \"trees\": {}}}",
                lr.n_examples,
                num(lr.min_confidence),
                lr.min_support,
                lr.trees.len(),
            ));
            for (f, (lo, hi)) in lr.ranges.iter().enumerate() {
                recs.push(format!(
                    "{{\"kind\": \"learned_range\", \"feature\": {f}, \"lo\": {}, \"hi\": {}}}",
                    num(*lo),
                    num(*hi),
                ));
            }
            for (t, tree) in lr.trees.iter().enumerate() {
                for (n, node) in tree.nodes.iter().enumerate() {
                    match node {
                        Node::Split { feature, threshold, left, right } => recs.push(format!(
                            "{{\"kind\": \"learned_node\", \"tree\": {t}, \"node\": {n}, \
                             \"split\": {feature}, \"thresh\": {}, \"left\": {left}, \
                             \"right\": {right}}}",
                            num(*threshold),
                        )),
                        Node::Leaf { label, count, purity } => recs.push(format!(
                            "{{\"kind\": \"learned_node\", \"tree\": {t}, \"node\": {n}, \
                             \"impl\": \"{}\", \"reorder\": \"{}\", \"dt\": {}, \
                             \"count\": {count}, \"purity\": {}}}",
                            label.im,
                            label.reorder,
                            label.dt,
                            num(*purity),
                        )),
                    }
                }
            }
        }
        for (c, i, v) in &spmm_priors {
            recs.push(format!(
                "{{\"kind\": \"spmm_prior\", \"class\": \"{c}\", \"impl\": \"{i}\", \
                 \"value\": {}}}",
                num(*v)
            ));
        }
        for (c, i, v) in &spgemm_priors {
            recs.push(format!(
                "{{\"kind\": \"spgemm_prior\", \"class\": \"{c}\", \"impl\": \"{i}\", \
                 \"value\": {}}}",
                num(*v)
            ));
        }

        let mut out = format!("{{\"version\": {STATE_VERSION}, \"records\": [\n");
        for (i, r) in recs.iter().enumerate() {
            out.push_str("  ");
            out.push_str(r);
            if i + 1 < recs.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }

    /// Parse a snapshot. Strict about the version and every record's
    /// schema — a snapshot that cannot be fully understood is rejected
    /// whole (the caller cold-starts) rather than half-applied.
    pub fn parse(text: &str) -> Result<AutotuneState> {
        let version = field_num(text, "version")? as u64;
        if version != STATE_VERSION {
            return Err(Error::Parse(format!(
                "autotune snapshot version {version} (this build reads {STATE_VERSION})"
            )));
        }
        // a healthy snapshot always ends with the wrapper's `]}` — a
        // truncated file must reject whole, not load as a shorter state
        if !text.trim_end().ends_with("]}") {
            return Err(Error::Parse("truncated autotune snapshot".into()));
        }
        let mut state = AutotuneState::default();
        let mut rest = text;
        while let Some(start) = rest.find('{') {
            rest = &rest[start + 1..];
            let end = match rest.find('}') {
                Some(e) => e,
                None => return Err(Error::Parse("truncated snapshot record".into())),
            };
            let body = &rest[..end];
            rest = &rest[end + 1..];
            // the wrapper prefix (and any non-record object) carries no
            // "kind" key in its body slice — skip it
            if !body.contains("\"kind\"") {
                continue;
            }
            match field_str(body, "kind")?.as_str() {
                "calib" => {
                    state.ladder = Some(MeasuredLadder {
                        levels: Vec::new(),
                        peak_gflops: field_num(body, "peak")?,
                        simd_level: field_str(body, "simd")?,
                        threads: field_num(body, "threads")? as usize,
                    })
                }
                "ladder_level" => {
                    let ml = state.ladder.as_mut().ok_or_else(|| {
                        Error::Parse("ladder_level record before its calib record".into())
                    })?;
                    ml.levels.push(LadderLevel {
                        level: field_str(body, "level")?,
                        // saturating cast maps the DRAM rung's rendered
                        // usize::MAX back to usize::MAX exactly
                        capacity_bytes: field_num(body, "capacity")? as usize,
                        read_gbs: field_num(body, "read")?,
                        write_gbs: field_num(body, "write")?,
                        triad_gbs: field_num(body, "triad")?,
                    });
                }
                "route" => {
                    let mut feats = [0.0; N_FEATURES];
                    for (i, f) in feats.iter_mut().enumerate() {
                        *f = field_num(body, &format!("f{i}"))?;
                    }
                    state.routes.push(RouteDecision {
                        matrix: field_str(body, "matrix")?,
                        d: field_num(body, "d")? as usize,
                        im: parse_impl(&field_str(body, "impl")?)
                            .map_err(|e| Error::Parse(e.to_string()))?,
                        reorder: parse_reordering(&field_str(body, "reorder")?)?,
                        dt: field_num(body, "dt")? as usize,
                        class: parse_class(&field_str(body, "class")?)?,
                        predicted_gflops: field_num(body, "predicted")?,
                        measured_gflops: field_num(body, "measured")?,
                        enumerated: field_num(body, "enumerated")? as usize,
                        explored: field_num(body, "explored")? as usize,
                        regret_gflops: field_num(body, "regret")?,
                        source: parse_source(&field_str(body, "source")?)?,
                        confidence: field_num(body, "conf")?,
                        // key deliberately NOT "analytic": the substring
                        // field lookup would first hit the *value* of
                        // `"source": "analytic"` and misparse
                        analytic_gflops: field_num(body, "analytic_gf")?,
                        // from_raw sanitises: a hand-edited snapshot
                        // cannot smuggle non-finite features in
                        features: FeatureVec::from_raw(feats),
                    });
                }
                "spgemm" => state.spgemm.push(SpGemmDecision {
                    a: field_str(body, "a")?,
                    b: field_str(body, "b")?,
                    im: parse_spgemm_impl(&field_str(body, "impl")?)?,
                    class: parse_class(&field_str(body, "class")?)?,
                    cf: field_num(body, "cf")?,
                    predicted_gflops: field_num(body, "predicted")?,
                    measured_gflops: field_num(body, "measured")?,
                    explored: field_num(body, "explored")? as usize,
                    regret_gflops: field_num(body, "regret")?,
                    candidates: Vec::new(),
                }),
                "spgemm_candidate" => {
                    let (a, b) = (field_str(body, "a")?, field_str(body, "b")?);
                    let cand = SpGemmCandidate {
                        im: parse_spgemm_impl(&field_str(body, "impl")?)?,
                        predicted_gflops: field_num(body, "predicted")?,
                        measured_gflops: field_num(body, "measured")?,
                        ai: field_num(body, "ai")?,
                    };
                    let dec = state
                        .spgemm
                        .iter_mut()
                        .find(|d| d.a == a && d.b == b)
                        .ok_or_else(|| {
                            Error::Parse(format!("candidate for unknown pair {a}×{b}"))
                        })?;
                    dec.candidates.push(cand);
                }
                "pipeline" => state.pipelines.push(PipelineDecision {
                    matrix: field_str(body, "matrix")?,
                    chain: field_str(body, "chain")?,
                    d: field_num(body, "d")? as usize,
                    im: parse_impl(&field_str(body, "impl")?)
                        .map_err(|e| Error::Parse(e.to_string()))?,
                    reorder: parse_reordering(&field_str(body, "reorder")?)?,
                    dt: field_num(body, "dt")? as usize,
                    class: parse_class(&field_str(body, "class")?)?,
                    resident: field_bool(body, "resident")?,
                    predicted_gflops: field_num(body, "predicted")?,
                    measured_gflops: field_num(body, "measured")?,
                    explored: field_num(body, "explored")? as usize,
                    regret_gflops: field_num(body, "regret")?,
                }),
                "learned_meta" => {
                    let n_trees = field_num(body, "trees")? as usize;
                    state.learned = Some(LearnedRouter {
                        // trees fill positionally from the learned_node
                        // records that follow; an unfilled tree fails
                        // the final validate (no nodes)
                        trees: vec![DecisionTree::default(); n_trees],
                        ranges: Vec::new(),
                        n_examples: field_num(body, "examples")? as usize,
                        min_confidence: field_num(body, "min_conf")?,
                        min_support: field_num(body, "min_support")? as usize,
                    });
                }
                "learned_range" => {
                    let lr = state.learned.as_mut().ok_or_else(|| {
                        Error::Parse("learned_range record before its learned_meta".into())
                    })?;
                    // ranges are emitted in feature-index order: a
                    // skipped or repeated index is a mangled snapshot
                    if field_num(body, "feature")? as usize != lr.ranges.len() {
                        return Err(Error::Parse("learned_range out of order".into()));
                    }
                    lr.ranges.push((field_num(body, "lo")?, field_num(body, "hi")?));
                }
                "learned_node" => {
                    let lr = state.learned.as_mut().ok_or_else(|| {
                        Error::Parse("learned_node record before its learned_meta".into())
                    })?;
                    let t = field_num(body, "tree")? as usize;
                    let tree = lr.trees.get_mut(t).ok_or_else(|| {
                        Error::Parse(format!("learned_node for unknown tree {t}"))
                    })?;
                    // nodes are emitted in index order within a tree
                    if field_num(body, "node")? as usize != tree.nodes.len() {
                        return Err(Error::Parse("learned_node out of order".into()));
                    }
                    // a split node carries a "split" key, a leaf an
                    // "impl" key — the discriminator
                    tree.nodes.push(if body.contains("\"split\"") {
                        Node::Split {
                            feature: field_num(body, "split")? as usize,
                            threshold: field_num(body, "thresh")?,
                            left: field_num(body, "left")? as usize,
                            right: field_num(body, "right")? as usize,
                        }
                    } else {
                        Node::Leaf {
                            label: RouteLabel {
                                im: parse_impl(&field_str(body, "impl")?)
                                    .map_err(|e| Error::Parse(e.to_string()))?,
                                reorder: parse_reordering(&field_str(body, "reorder")?)?,
                                dt: field_num(body, "dt")? as usize,
                            },
                            count: field_num(body, "count")? as usize,
                            purity: field_num(body, "purity")?,
                        }
                    });
                }
                "spmm_prior" => state.spmm_priors.push((
                    parse_class(&field_str(body, "class")?)?,
                    parse_impl(&field_str(body, "impl")?)
                        .map_err(|e| Error::Parse(e.to_string()))?,
                    field_num(body, "value")?,
                )),
                "spgemm_prior" => state.spgemm_priors.push((
                    parse_class(&field_str(body, "class")?)?,
                    parse_spgemm_impl(&field_str(body, "impl")?)?,
                    field_num(body, "value")?,
                )),
                other => {
                    return Err(Error::Parse(format!("unknown snapshot record kind '{other}'")))
                }
            }
        }
        // a restored forest must be structurally sound before it gets
        // anywhere near routing: truncated trees, dangling child
        // indices, out-of-range purities all reject the whole snapshot
        if let Some(lr) = &state.learned {
            lr.validate()?;
        }
        Ok(state)
    }

    /// Persist atomically (lock + temp sibling + rename).
    pub fn save(&self, path: &str) -> Result<()> {
        let _lock = FileLock::acquire(path)?;
        atomic_write(path, &self.to_json())
    }

    /// Load a snapshot, strictly.
    pub fn load(path: &str) -> Result<AutotuneState> {
        AutotuneState::parse(&std::fs::read_to_string(path)?)
    }

    /// Load a snapshot for serving: a missing file is a silent cold
    /// start (`None`), a corrupted or version-skewed one is a *warned*
    /// cold start — never a panic, never a half-applied state.
    pub fn load_or_cold(path: &str) -> Option<AutotuneState> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => return None,
        };
        match AutotuneState::parse(&text) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("warning: ignoring autotune snapshot {path}: {e} — cold start");
                None
            }
        }
    }
}

fn field<'a>(body: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("\"{key}\"");
    let at = body
        .find(&pat)
        .ok_or_else(|| Error::Parse(format!("snapshot record missing key '{key}'")))?;
    let after = &body[at + pat.len()..];
    let colon = after
        .find(':')
        .ok_or_else(|| Error::Parse(format!("snapshot key '{key}' has no value")))?;
    Ok(after[colon + 1..].trim_start())
}

fn field_str(body: &str, key: &str) -> Result<String> {
    let v = field(body, key)?;
    let v = v
        .strip_prefix('"')
        .ok_or_else(|| Error::Parse(format!("'{key}' is not a string")))?;
    let end = v
        .find('"')
        .ok_or_else(|| Error::Parse(format!("'{key}' string unterminated")))?;
    Ok(v[..end].to_string())
}

fn field_bool(body: &str, key: &str) -> Result<bool> {
    let v = field(body, key)?;
    if v.starts_with("true") {
        Ok(true)
    } else if v.starts_with("false") {
        Ok(false)
    } else {
        Err(Error::Parse(format!("'{key}' is not a bool")))
    }
}

fn field_num(body: &str, key: &str) -> Result<f64> {
    let v = field(body, key)?;
    let end = v
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == '+'))
        .unwrap_or(v.len());
    v[..end]
        .parse::<f64>()
        .map_err(|_| Error::Parse(format!("'{key}' is not a number: '{}'", &v[..end])))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn route(matrix: &str, d: usize) -> RouteDecision {
        RouteDecision {
            matrix: matrix.into(),
            d,
            im: Impl::Csb,
            reorder: Reordering::Rcm,
            dt: 8,
            class: SparsityClass::Blocked,
            predicted_gflops: 0.1 + 0.2, // deliberately awkward binary fraction
            measured_gflops: std::f64::consts::PI,
            enumerated: 9,
            explored: 3,
            regret_gflops: 0.0,
            source: RouteSource::Learned,
            confidence: 0.8125,
            analytic_gflops: 2.5 + 0.0625,
            features: FeatureVec::from_raw([0.5, 0.1 + 0.2, 0.0, 0.25, 10.0, 14.5, 3.0]),
        }
    }

    fn forest() -> LearnedRouter {
        LearnedRouter {
            trees: vec![
                DecisionTree {
                    nodes: vec![
                        Node::Split { feature: 4, threshold: 7.5, left: 1, right: 2 },
                        Node::Leaf {
                            label: RouteLabel {
                                im: Impl::Csr,
                                reorder: Reordering::None,
                                dt: 8,
                            },
                            count: 3,
                            purity: 1.0,
                        },
                        Node::Leaf {
                            label: RouteLabel {
                                im: Impl::Pb,
                                reorder: Reordering::DegreeSort,
                                dt: 16,
                            },
                            count: 2,
                            purity: 0.1 + 0.7, // awkward binary fraction
                        },
                    ],
                },
                DecisionTree {
                    nodes: vec![Node::Leaf {
                        label: RouteLabel { im: Impl::Csr, reorder: Reordering::None, dt: 8 },
                        count: 5,
                        purity: 0.6,
                    }],
                },
            ],
            ranges: vec![
                (0.0, 1.5),
                (0.0, 0.25),
                (0.0, 0.0),
                (0.0, 1.0),
                (5.0, 12.0),
                (8.0, 20.0),
                (2.0, 6.0),
            ],
            n_examples: 5,
            min_confidence: 0.65,
            min_support: 3,
        }
    }

    fn sample() -> AutotuneState {
        AutotuneState {
            routes: vec![route("m1", 8), route("m0", 4)],
            spgemm: vec![SpGemmDecision {
                a: "a".into(),
                b: "b".into(),
                im: SpGemmImpl::Hash,
                class: SparsityClass::Random,
                cf: 7.123456789123,
                predicted_gflops: 1.5,
                measured_gflops: 2.5,
                explored: 2,
                regret_gflops: 0.25,
                candidates: vec![
                    SpGemmCandidate {
                        im: SpGemmImpl::Hash,
                        predicted_gflops: 1.5,
                        measured_gflops: 2.5,
                        ai: 0.3,
                    },
                    SpGemmCandidate {
                        im: SpGemmImpl::PbMerge,
                        predicted_gflops: 1.25,
                        measured_gflops: 2.0,
                        ai: 0.2,
                    },
                ],
            }],
            pipelines: vec![PipelineDecision {
                matrix: "m1".into(),
                chain: "GCN(layers=2,d=16)".into(),
                d: 16,
                im: Impl::Opt,
                reorder: Reordering::None,
                dt: 16,
                class: SparsityClass::ScaleFree,
                resident: true,
                predicted_gflops: 3.75,
                measured_gflops: 4.0 + 0.4, // awkward binary fraction
                explored: 3,
                regret_gflops: 0.125,
            }],
            spmm_priors: vec![
                (SparsityClass::Random, Impl::Csr, 0.351234567890123),
                (SparsityClass::Blocked, Impl::Csb, 0.85),
            ],
            spgemm_priors: vec![(SparsityClass::Random, SpGemmImpl::PbMerge, 0.8)],
            ladder: Some(MeasuredLadder {
                levels: vec![
                    LadderLevel {
                        level: "L1".into(),
                        capacity_bytes: 32 * 1024,
                        read_gbs: 412.5,
                        write_gbs: 300.0 + 0.2, // awkward binary fraction
                        triad_gbs: 398.0,
                    },
                    LadderLevel {
                        level: "DRAM".into(),
                        capacity_bytes: usize::MAX,
                        read_gbs: 17.25,
                        write_gbs: 12.5,
                        triad_gbs: 18.625,
                    },
                ],
                peak_gflops: 77.125,
                simd_level: "avx".into(),
                threads: 4,
            }),
            learned: Some(forest()),
        }
    }

    #[test]
    fn round_trip_preserves_bytes_and_values() {
        let s = sample();
        let j1 = s.to_json();
        let back = AutotuneState::parse(&j1).unwrap();
        let j2 = back.to_json();
        assert_eq!(j1, j2, "save → load → save must be byte-identical");
        assert_eq!(back.routes.len(), 2);
        // sorted on save: m0 before m1
        assert_eq!(back.routes[0].matrix, "m0");
        assert_eq!(back.routes[0].predicted_gflops, 0.1 + 0.2);
        assert_eq!(back.routes[0].measured_gflops, std::f64::consts::PI);
        assert_eq!(back.spgemm[0].cf, 7.123456789123);
        assert_eq!(back.spgemm[0].candidates.len(), 2);
        assert_eq!(back.spgemm[0].candidates[1].im, SpGemmImpl::PbMerge);
        assert_eq!(back.pipelines.len(), 1);
        assert_eq!(back.pipelines[0].chain, "GCN(layers=2,d=16)");
        assert_eq!(back.pipelines[0].im, Impl::Opt);
        assert!(back.pipelines[0].resident, "bool field survives the round trip");
        assert_eq!(back.pipelines[0].measured_gflops, 4.0 + 0.4);
        assert_eq!(back.spmm_priors.len(), 2);
        assert_eq!(back.spgemm_priors.len(), 1);
        let ml = back.ladder.expect("ladder survives the round trip");
        assert_eq!(ml.peak_gflops, 77.125);
        assert_eq!(ml.simd_level, "avx");
        assert_eq!(ml.threads, 4);
        assert_eq!(ml.levels.len(), 2);
        assert_eq!(ml.levels[0].level, "L1");
        assert_eq!(ml.levels[0].write_gbs, 300.0 + 0.2);
        // the DRAM rung's unbounded capacity sentinel must survive the
        // f64-based field parser exactly
        assert_eq!(ml.levels[1].capacity_bytes, usize::MAX);
        // the route's learned columns round-trip exactly
        assert_eq!(back.routes[0].source, RouteSource::Learned);
        assert_eq!(back.routes[0].confidence, 0.8125);
        assert_eq!(back.routes[0].analytic_gflops, 2.5 + 0.0625);
        assert_eq!(back.routes[0].features.0[1], 0.1 + 0.2);
        // the trained forest restores node-for-node and validates
        let lr = back.learned.expect("forest survives the round trip");
        assert_eq!(lr, forest());
        lr.validate().unwrap();
    }

    #[test]
    fn empty_state_round_trips() {
        let s = AutotuneState::default();
        assert!(s.is_empty());
        let back = AutotuneState::parse(&s.to_json()).unwrap();
        assert!(back.is_empty());
        assert_eq!(s.to_json(), back.to_json());
    }

    #[test]
    fn corrupt_truncated_and_version_skew_reject() {
        let full = sample().to_json();
        let truncated = &full[..full.len() / 2];
        assert!(AutotuneState::parse(truncated).is_err());
        assert!(AutotuneState::parse("not json at all").is_err());
        let skewed = full.replace("\"version\": 4", "\"version\": 99");
        assert!(AutotuneState::parse(&skewed).is_err());
        // unknown record kinds are rejected, not skipped — a snapshot
        // this build cannot fully understand must cold-start
        let alien = full.replace("\"kind\": \"spmm_prior\"", "\"kind\": \"mystery\"");
        assert!(AutotuneState::parse(&alien).is_err());
        // a ladder_level whose calib record went missing is an orphan:
        // reject whole rather than silently dropping measurements
        // (renaming the key leaves the record kind-less, so it is
        // skipped and the levels that follow have nothing to attach to)
        let orphan = full.replace("\"kind\": \"calib\"", "\"kinb\": \"calib\"");
        assert!(AutotuneState::parse(&orphan).is_err());
    }

    #[test]
    fn malformed_learned_forest_rejects_the_whole_snapshot() {
        let full = sample().to_json();
        // a leaf purity outside (0, 1] fails the structural validate
        let bad_purity = full.replace("\"purity\": 0.6", "\"purity\": 7.5");
        assert!(AutotuneState::parse(&bad_purity).is_err());
        // a node pointing at a tree the meta record never declared
        let bad_tree = full.replace("\"tree\": 1, \"node\": 0", "\"tree\": 9, \"node\": 0");
        assert!(AutotuneState::parse(&bad_tree).is_err());
        // losing a tree's nodes entirely (truncated forest): the
        // declared second tree restores empty and validate rejects it
        let missing = full.replace("\"trees\": 2", "\"trees\": 3");
        assert!(AutotuneState::parse(&missing).is_err());
        // a split whose child does not strictly follow its parent
        // (self-reference / cycle) is structurally rejected
        let cyclic = full.replace("\"left\": 1, \"right\": 2", "\"left\": 0, \"right\": 2");
        assert!(AutotuneState::parse(&cyclic).is_err());
        // a range record out of feature order is a mangled snapshot
        let skewed_range = full.replace("\"feature\": 3", "\"feature\": 5");
        assert!(AutotuneState::parse(&skewed_range).is_err());
        // orphaned learned records (meta went missing) reject whole
        let orphan = full.replace("\"kind\": \"learned_meta\"", "\"kinb\": \"learned_meta\"");
        assert!(AutotuneState::parse(&orphan).is_err());
        // and the healthy original still parses, of course
        assert!(AutotuneState::parse(&full).is_ok());
    }

    #[test]
    fn load_or_cold_warns_instead_of_panicking() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("state_cold_{}.json", std::process::id()));
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        // missing file: silent cold start
        assert!(AutotuneState::load_or_cold(path).is_none());
        // corrupted file: warned cold start, no panic
        std::fs::write(path, "{\"version\": 4, \"records\": [{\"kind\": \"route\"").unwrap();
        assert!(AutotuneState::load_or_cold(path).is_none());
        // healthy file loads
        sample().save(path).unwrap();
        let s = AutotuneState::load_or_cold(path).unwrap();
        assert_eq!(s.routes.len(), 2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_leaves_no_temp_or_lock_droppings() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("state_tmp_{}.json", std::process::id()));
        let path = path.to_str().unwrap();
        sample().save(path).unwrap();
        assert!(!std::path::Path::new(&format!("{path}.lock")).exists());
        let loaded = AutotuneState::load(path).unwrap();
        assert_eq!(loaded.to_json(), sample().to_json());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn file_lock_serialises_read_modify_write() {
        use std::sync::Arc;
        let dir = std::env::temp_dir();
        let path = dir.join(format!("state_lock_{}.txt", std::process::id()));
        let path: Arc<String> = Arc::new(path.to_str().unwrap().to_string());
        let _ = std::fs::remove_file(path.as_str());
        atomic_write(&path, "0").unwrap();
        let threads = 4;
        let iters = 25;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let path = Arc::clone(&path);
                s.spawn(move || {
                    for _ in 0..iters {
                        let _lock = FileLock::acquire(&path).unwrap();
                        let v: u64 =
                            std::fs::read_to_string(path.as_str()).unwrap().parse().unwrap();
                        atomic_write(&path, &format!("{}", v + 1)).unwrap();
                    }
                });
            }
        });
        let total: u64 = std::fs::read_to_string(path.as_str()).unwrap().parse().unwrap();
        assert_eq!(total, (threads * iters) as u64, "lost update under the lock");
        let _ = std::fs::remove_file(path.as_str());
    }
}
