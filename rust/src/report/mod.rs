//! Report rendering: aligned text/markdown tables, CSV, SVG plots,
//! machine-readable perf artifacts (`BENCH_schedule.json`), and the
//! system-info probe (the paper's Table IV analog).

mod csv;
mod perf;
mod svg;
mod sysinfo;
mod table;

pub use csv::write_csv;
pub use perf::{PerfLog, PerfRecord};
pub use svg::{Marker, Series, SvgPlot, VLine, PALETTE};
pub use sysinfo::{probe_system, SystemInfo};
pub use table::{fmt3, Table};
