//! Report rendering: aligned text/markdown tables, CSV, SVG plots,
//! machine-readable perf artifacts (`BENCH_schedule.json`), the
//! system-info probe (the paper's Table IV analog), and the persisted
//! autotune snapshot + crash-safe artifact writes ([`AutotuneState`],
//! [`atomic_write`], [`FileLock`]).

mod csv;
mod perf;
mod state;
mod svg;
mod sysinfo;
mod table;

pub use csv::write_csv;
pub use perf::{PerfLog, PerfRecord};
pub use state::{atomic_write, AutotuneState, FileLock, STATE_VERSION};
pub(crate) use state::parse_reordering;
pub use svg::{Marker, Series, SvgPlot, VLine, PALETTE};
pub use sysinfo::{probe_system, SystemInfo};
pub use table::{fmt3, Table};
