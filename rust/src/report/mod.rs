//! Report rendering: aligned text/markdown tables, CSV, SVG plots, and
//! the system-info probe (the paper's Table IV analog).

mod csv;
mod svg;
mod sysinfo;
mod table;

pub use csv::write_csv;
pub use svg::{Marker, Series, SvgPlot, VLine, PALETTE};
pub use sysinfo::{probe_system, SystemInfo};
pub use table::{fmt3, Table};
