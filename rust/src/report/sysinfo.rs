//! System probe — regenerates the paper's Table IV for *this* testbed
//! (CPU model, cores, cache sizes, vector extensions) plus the
//! measured machine parameters.

use crate::model::MachineParams;
use crate::report::Table;

/// Hardware summary of the machine the experiments run on.
#[derive(Debug, Clone, Default)]
pub struct SystemInfo {
    pub arch: String,
    pub cpu_model: String,
    pub cores: usize,
    pub l1d: String,
    pub l2: String,
    pub l3: String,
    pub flags: Vec<String>,
}

fn read_cache(path: &str) -> Option<String> {
    std::fs::read_to_string(path).ok().map(|s| s.trim().to_string())
}

/// Probe /proc and /sys. Every field degrades gracefully to
/// "unknown" on exotic systems.
pub fn probe_system() -> SystemInfo {
    let mut info = SystemInfo {
        arch: std::env::consts::ARCH.to_string(),
        cpu_model: "unknown".into(),
        cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        ..Default::default()
    };
    if let Ok(cpuinfo) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in cpuinfo.lines() {
            if let Some(v) = line.strip_prefix("model name") {
                info.cpu_model = v.trim_start_matches([' ', '\t', ':']).to_string();
            }
            if line.starts_with("flags") && info.flags.is_empty() {
                let interesting = ["avx2", "avx512f", "fma", "sse4_2"];
                info.flags = line
                    .split_whitespace()
                    .filter(|f| interesting.contains(f))
                    .map(|s| s.to_string())
                    .collect();
            }
        }
    }
    let base = "/sys/devices/system/cpu/cpu0/cache";
    for idx in 0..5 {
        let level = read_cache(&format!("{base}/index{idx}/level"));
        let typ = read_cache(&format!("{base}/index{idx}/type"));
        let size = read_cache(&format!("{base}/index{idx}/size"));
        if let (Some(level), Some(typ), Some(size)) = (level, typ, size) {
            match (level.as_str(), typ.as_str()) {
                ("1", "Data") => info.l1d = size,
                ("2", _) => info.l2 = size,
                ("3", _) => info.l3 = size,
                _ => {}
            }
        }
    }
    info
}

impl SystemInfo {
    /// Render as the paper's Table IV, side-by-side with the paper's
    /// values.
    pub fn to_table(&self, machine: Option<MachineParams>) -> Table {
        let mut t = Table::new(
            "Table IV — test system (this testbed vs paper's Perlmutter node)",
            &["Property", "This testbed", "Paper (EPYC 7763)"],
        );
        let row = |t: &mut Table, k: &str, a: String, b: &str| {
            t.row(vec![k.into(), a, b.into()]);
        };
        row(&mut t, "Architecture", self.arch.clone(), "x86_64");
        row(&mut t, "CPU model", self.cpu_model.clone(), "AMD EPYC 7763 (Milan)");
        row(&mut t, "Cores used", self.cores.to_string(), "64");
        row(&mut t, "L1d", self.or_unknown(&self.l1d), "32 KiB/core");
        row(&mut t, "L2", self.or_unknown(&self.l2), "512 KiB/core");
        row(&mut t, "L3", self.or_unknown(&self.l3), "256 MiB/socket");
        row(&mut t, "Vector ext", self.flags.join(" "), "AVX2, FMA");
        if let Some(m) = machine {
            row(&mut t, "β measured (GB/s)", format!("{:.1}", m.beta_gbs), "122.6 (STREAM)");
            row(&mut t, "π measured (GFLOP/s)", format!("{:.1}", m.pi_gflops), "≈2509 (peak)");
            row(&mut t, "ridge AI (FLOP/B)", format!("{:.2}", m.ridge_ai()), "≈20.5");
        }
        t
    }

    fn or_unknown(&self, s: &str) -> String {
        if s.is_empty() {
            "unknown".into()
        } else {
            s.to_string()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_runs_everywhere() {
        let info = probe_system();
        assert!(info.cores >= 1);
        assert!(!info.arch.is_empty());
    }

    #[test]
    fn table_includes_machine_params() {
        let info = probe_system();
        let t = info.to_table(Some(MachineParams { beta_gbs: 10.0, pi_gflops: 50.0 }));
        let text = t.to_text();
        assert!(text.contains("β measured"));
        assert!(text.contains("10.0"));
        assert!(text.contains("122.6"));
    }
}
