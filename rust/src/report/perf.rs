//! Machine-readable perf artifacts: `BENCH_schedule.json`,
//! `BENCH_route.json`.
//!
//! The benches (`bench_schedule`, `bench_batch`, `bench_workloads`)
//! used to report throughput as prose only, so the repo's perf
//! trajectory across PRs lived in commit messages. This module gives
//! them a shared flat record schema and a merge-on-save JSON file: each
//! bench replaces *its own* records and leaves the other benches'
//! latest numbers in place, so one artifact accumulates the current
//! state of every bench.
//!
//! The format is a single top-level object
//! `{"records": [ {...}, ... ]}` with flat records (no nesting), so the
//! hand-rolled parser below — the crate builds offline, serde is
//! unavailable — stays trivial and total. Tiled-vs-untiled comparisons
//! are encoded as record pairs sharing (bench, matrix, impl, d) and
//! differing in `dt` (`dt == d` is the untiled run).

use crate::error::{Error, Result};
use crate::report::state::{atomic_write, FileLock};

/// One measured cell: a bench × matrix × implementation × dense-width
/// point at a specific column-tile width and matrix ordering.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfRecord {
    /// Which bench produced the record (e.g. `bench_schedule`).
    pub bench: String,
    /// Matrix / workload name.
    pub matrix: String,
    /// Sparsity class (or workload kind).
    pub class: String,
    /// Implementation name (`CSR`, `OPT`, ...).
    pub impl_name: String,
    /// Dense width.
    pub d: usize,
    /// Column-tile width the run executed with (`dt == d` = untiled).
    pub dt: usize,
    /// Matrix ordering the run executed under (`none`, `rcm`,
    /// `degree`). Routing records carry the router's pinned choice;
    /// older artifacts without the key parse as `none`.
    pub reorder: String,
    /// Model-predicted GFLOP/s for this cell (0 when the bench does
    /// not predict; optional in the artifact for back-compat).
    pub predicted_gflops: f64,
    /// Measured GFLOP/s.
    pub gflops: f64,
    /// Which router produced the decision this record describes
    /// (`analytic` / `learned`); benches without a router emit
    /// `analytic`, and older artifacts parse with that default.
    pub source: String,
    /// Structural features of the routed matrix at decision time —
    /// the learned router's training inputs (`examples_from_log`).
    /// Raw fractions plus raw sizes; all-zero (`n == 0`) marks a
    /// record without features (pre-feature artifacts, SpGEMM rows),
    /// which the trainer skips.
    pub cv: f64,
    pub hub: f64,
    pub diag: f64,
    pub block: f64,
    pub n: usize,
    pub nnz: usize,
}

impl PerfRecord {
    /// A record with the routing extras defaulted (`reorder = "none"`,
    /// no prediction) — what the pre-routing benches emit.
    pub fn basic(
        bench: impl Into<String>,
        matrix: impl Into<String>,
        class: impl Into<String>,
        impl_name: impl Into<String>,
        d: usize,
        dt: usize,
        gflops: f64,
    ) -> PerfRecord {
        PerfRecord {
            bench: bench.into(),
            matrix: matrix.into(),
            class: class.into(),
            impl_name: impl_name.into(),
            d,
            dt,
            reorder: "none".into(),
            predicted_gflops: 0.0,
            gflops,
            source: "analytic".into(),
            cv: 0.0,
            hub: 0.0,
            diag: 0.0,
            block: 0.0,
            n: 0,
            nnz: 0,
        }
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Shortest-round-trip float rendering with the same non-finite guard
/// the throughput fields get: NaN/inf is not JSON and a single bad
/// value must not cost the whole artifact.
fn fnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".into()
    }
}

impl PerfRecord {
    fn to_json(&self) -> String {
        // non-finite throughput (a degenerate zero-length timing)
        // would serialise as `inf`/`NaN`, which is not JSON and would
        // poison the whole artifact on the next parse — record 0
        let gf = if self.gflops.is_finite() { self.gflops } else { 0.0 };
        let pred = if self.predicted_gflops.is_finite() { self.predicted_gflops } else { 0.0 };
        format!(
            "{{\"bench\": \"{}\", \"matrix\": \"{}\", \"class\": \"{}\", \
             \"impl\": \"{}\", \"d\": {}, \"dt\": {}, \"reorder\": \"{}\", \
             \"predicted\": {:.4}, \"gflops\": {:.4}, \"source\": \"{}\", \
             \"cv\": {}, \"hub\": {}, \"diag\": {}, \"block\": {}, \
             \"n\": {}, \"nnz\": {}}}",
            esc(&self.bench),
            esc(&self.matrix),
            esc(&self.class),
            esc(&self.impl_name),
            self.d,
            self.dt,
            esc(&self.reorder),
            pred,
            gf,
            esc(&self.source),
            fnum(self.cv),
            fnum(self.hub),
            fnum(self.diag),
            fnum(self.block),
            self.n,
            self.nnz,
        )
    }
}

/// A collection of perf records with JSON round-tripping and
/// per-bench merge semantics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PerfLog {
    pub records: Vec<PerfRecord>,
}

impl PerfLog {
    pub fn new() -> PerfLog {
        PerfLog::default()
    }

    /// Append one record.
    pub fn push(&mut self, rec: PerfRecord) {
        self.records.push(rec);
    }

    /// Serialise to the artifact format.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&r.to_json());
            if i + 1 < self.records.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }

    /// Parse the artifact format back (tolerant of whitespace; strict
    /// about the flat schema).
    pub fn parse(text: &str) -> Result<PerfLog> {
        let mut records = Vec::new();
        let mut rest = text;
        while let Some(start) = rest.find('{') {
            rest = &rest[start + 1..];
            // skip the top-level wrapper: objects without a "bench" key
            let end = match rest.find('}') {
                Some(e) => e,
                None => break,
            };
            let body = &rest[..end];
            if !body.contains("\"bench\"") {
                continue;
            }
            // a single malformed record (hand-edited artifact, or one
            // written by a buggy tool) is skipped with a warning — the
            // artifact is a build product, and the learned router
            // trains on whatever healthy records remain; losing the
            // whole log to one bad row was the old behaviour and it
            // turned a cosmetic corruption into an empty training set
            match parse_record(body) {
                Ok(r) => records.push(r),
                Err(e) => {
                    eprintln!("warning: skipping malformed perf record: {e}");
                }
            }
            rest = &rest[end + 1..];
        }
        Ok(PerfLog { records })
    }

    /// Write `path`, replacing any previous records from the same
    /// benches while keeping other benches' records. A missing or
    /// unparsable existing file is treated as empty (the artifact is a
    /// build product, not a source of truth).
    ///
    /// The read-modify-write cycle holds a [`FileLock`] and lands via
    /// [`atomic_write`], so two benches merging into the same artifact
    /// concurrently cannot interleave and drop each other's records
    /// (regression-tested in `tests/integration_serve.rs`).
    pub fn merge_save(&self, path: &str) -> Result<()> {
        let _lock = FileLock::acquire(path)?;
        let mut merged = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| PerfLog::parse(&t).ok())
            .unwrap_or_default();
        merged.records.retain(|r| !self.records.iter().any(|n| n.bench == r.bench));
        merged.records.extend(self.records.iter().cloned());
        atomic_write(path, &merged.to_json())
    }
}

fn field<'a>(body: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("\"{key}\"");
    let at = body
        .find(&pat)
        .ok_or_else(|| Error::Parse(format!("perf record missing key '{key}'")))?;
    let after = &body[at + pat.len()..];
    let colon = after
        .find(':')
        .ok_or_else(|| Error::Parse(format!("perf record key '{key}' has no value")))?;
    Ok(after[colon + 1..].trim_start())
}

fn field_str(body: &str, key: &str) -> Result<String> {
    let v = field(body, key)?;
    let v = v
        .strip_prefix('"')
        .ok_or_else(|| Error::Parse(format!("'{key}' is not a string")))?;
    let end = v
        .find('"')
        .ok_or_else(|| Error::Parse(format!("'{key}' string unterminated")))?;
    Ok(v[..end].to_string())
}

fn field_num(body: &str, key: &str) -> Result<f64> {
    let v = field(body, key)?;
    let end = v
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == '+'))
        .unwrap_or(v.len());
    v[..end]
        .parse::<f64>()
        .map_err(|_| Error::Parse(format!("'{key}' is not a number: '{}'", &v[..end])))
}

fn parse_record(body: &str) -> Result<PerfRecord> {
    Ok(PerfRecord {
        bench: field_str(body, "bench")?,
        matrix: field_str(body, "matrix")?,
        class: field_str(body, "class")?,
        impl_name: field_str(body, "impl")?,
        d: field_num(body, "d")? as usize,
        dt: field_num(body, "dt")? as usize,
        // routing extras are optional: artifacts written before the
        // router existed parse with the defaults
        reorder: field_str(body, "reorder").unwrap_or_else(|_| "none".into()),
        predicted_gflops: field_num(body, "predicted").unwrap_or(0.0),
        gflops: field_num(body, "gflops")?,
        // learned-router extras (PR 10): source tag + structural
        // features; pre-feature artifacts parse with the defaults
        source: field_str(body, "source").unwrap_or_else(|_| "analytic".into()),
        cv: field_num(body, "cv").unwrap_or(0.0),
        hub: field_num(body, "hub").unwrap_or(0.0),
        diag: field_num(body, "diag").unwrap_or(0.0),
        block: field_num(body, "block").unwrap_or(0.0),
        n: field_num(body, "n").unwrap_or(0.0) as usize,
        nnz: field_num(body, "nnz").unwrap_or(0.0) as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(bench: &str, im: &str, d: usize, dt: usize, gf: f64) -> PerfRecord {
        PerfRecord::basic(bench, "er_18_10", "Random", im, d, dt, gf)
    }

    #[test]
    fn json_round_trips() {
        let mut log = PerfLog::new();
        log.push(rec("bench_schedule", "CSR", 64, 16, 3.25));
        log.push(rec("bench_schedule", "CSR", 64, 64, 2.75));
        // a routing record with the extras populated
        log.push(PerfRecord {
            reorder: "rcm".into(),
            predicted_gflops: 4.5,
            ..rec("bench_route", "CSB", 16, 8, 5.25)
        });
        // a learned-routed record with structural features — awkward
        // binary fractions must survive exactly (shortest-round-trip
        // rendering), since the learned router trains on these
        log.push(PerfRecord {
            reorder: "degree".into(),
            source: "learned".into(),
            cv: 0.1 + 0.2,
            hub: 0.371234567890123,
            diag: 0.0625,
            block: std::f64::consts::FRAC_1_SQRT_2,
            n: 262144,
            nnz: 4194304,
            ..rec("bench_route_learned", "PB", 64, 16, 7.5)
        });
        let text = log.to_json();
        let back = PerfLog::parse(&text).unwrap();
        assert_eq!(back, log);
        assert_eq!(back.records[2].reorder, "rcm");
        assert!((back.records[2].predicted_gflops - 4.5).abs() < 1e-9);
        assert_eq!(back.records[3].source, "learned");
        assert_eq!(back.records[3].cv, 0.1 + 0.2, "features must round-trip exactly");
        assert_eq!(back.records[3].block, std::f64::consts::FRAC_1_SQRT_2);
        assert_eq!(back.records[3].n, 262144);
        assert_eq!(back.records[3].nnz, 4194304);
    }

    #[test]
    fn pre_routing_artifacts_parse_with_defaults() {
        // an artifact written before the reorder/predicted keys existed
        let text = "{\"records\": [\n  {\"bench\": \"bench_batch\", \"matrix\": \"m\", \
                    \"class\": \"Random\", \"impl\": \"CSR\", \"d\": 4, \"dt\": 4, \
                    \"gflops\": 1.2500}\n]}\n";
        let log = PerfLog::parse(text).unwrap();
        assert_eq!(log.records.len(), 1);
        assert_eq!(log.records[0].reorder, "none");
        assert_eq!(log.records[0].predicted_gflops, 0.0);
        assert!((log.records[0].gflops - 1.25).abs() < 1e-9);
        // learned-router extras default too: analytic, no features
        assert_eq!(log.records[0].source, "analytic");
        assert_eq!(log.records[0].n, 0);
        assert_eq!(log.records[0].cv, 0.0);
    }

    #[test]
    fn non_finite_gflops_serialises_as_zero() {
        let mut log = PerfLog::new();
        log.push(rec("bench_batch", "CSR", 4, 4, f64::INFINITY));
        log.push(rec("bench_batch", "OPT", 4, 4, f64::NAN));
        let back = PerfLog::parse(&log.to_json()).unwrap();
        assert!(back.records.iter().all(|r| r.gflops == 0.0));
    }

    #[test]
    fn parse_skips_malformed_records_and_keeps_the_rest() {
        // a malformed record no longer costs the whole artifact: it is
        // skipped (with a warning) and every healthy record survives —
        // the learned router trains on what remains
        let mut log = PerfLog::new();
        log.push(rec("bench_batch", "CSR", 4, 4, 1.5));
        let mut text = log.to_json();
        text = text.replace("]}", ", {\"bench\": \"x\"}\n]}");
        let back = PerfLog::parse(&text).unwrap();
        assert_eq!(back.records.len(), 1, "healthy record must survive the bad row");
        assert_eq!(back.records[0].impl_name, "CSR");
        // all-malformed parses as empty, not Err
        assert!(PerfLog::parse("{\"records\": [{\"bench\": \"x\"}]}")
            .unwrap()
            .records
            .is_empty());
        // no records at all is fine (empty artifact)
        assert!(PerfLog::parse("{\"records\": []}").unwrap().records.is_empty());
        assert!(PerfLog::parse("").unwrap().records.is_empty());
    }

    #[test]
    fn non_finite_features_serialise_as_zero() {
        // same guard the throughput fields have: a NaN row-length CV
        // (degenerate matrix) must not emit a bare `NaN` token and
        // corrupt the training artifact
        let mut log = PerfLog::new();
        log.push(PerfRecord {
            cv: f64::NAN,
            hub: f64::INFINITY,
            diag: 0.5,
            n: 100,
            nnz: 400,
            ..rec("bench_route", "CSR", 4, 4, 1.0)
        });
        let back = PerfLog::parse(&log.to_json()).unwrap();
        assert_eq!(back.records.len(), 1);
        assert_eq!(back.records[0].cv, 0.0);
        assert_eq!(back.records[0].hub, 0.0);
        assert_eq!(back.records[0].diag, 0.5);
    }

    #[test]
    fn merge_save_replaces_own_bench_only() {
        let dir = std::env::temp_dir();
        let path = dir.join("perf_merge_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);

        let mut a = PerfLog::new();
        a.push(rec("bench_batch", "CSB", 16, 16, 5.0));
        a.merge_save(path).unwrap();

        let mut b = PerfLog::new();
        b.push(rec("bench_schedule", "CSR", 64, 8, 4.0));
        b.merge_save(path).unwrap();

        // re-run bench_batch with a new number: replaces only its own
        let mut a2 = PerfLog::new();
        a2.push(rec("bench_batch", "CSB", 16, 16, 6.0));
        a2.merge_save(path).unwrap();

        let on_disk = PerfLog::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(on_disk.records.len(), 2);
        let batch: Vec<_> =
            on_disk.records.iter().filter(|r| r.bench == "bench_batch").collect();
        assert_eq!(batch.len(), 1);
        assert!((batch[0].gflops - 6.0).abs() < 1e-9);
        assert!(on_disk.records.iter().any(|r| r.bench == "bench_schedule"));
        let _ = std::fs::remove_file(path);
    }
}
