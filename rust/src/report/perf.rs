//! Machine-readable perf artifacts: `BENCH_schedule.json`,
//! `BENCH_route.json`.
//!
//! The benches (`bench_schedule`, `bench_batch`, `bench_workloads`)
//! used to report throughput as prose only, so the repo's perf
//! trajectory across PRs lived in commit messages. This module gives
//! them a shared flat record schema and a merge-on-save JSON file: each
//! bench replaces *its own* records and leaves the other benches'
//! latest numbers in place, so one artifact accumulates the current
//! state of every bench.
//!
//! The format is a single top-level object
//! `{"records": [ {...}, ... ]}` with flat records (no nesting), so the
//! hand-rolled parser below — the crate builds offline, serde is
//! unavailable — stays trivial and total. Tiled-vs-untiled comparisons
//! are encoded as record pairs sharing (bench, matrix, impl, d) and
//! differing in `dt` (`dt == d` is the untiled run).

use crate::error::{Error, Result};
use crate::report::state::{atomic_write, FileLock};

/// One measured cell: a bench × matrix × implementation × dense-width
/// point at a specific column-tile width and matrix ordering.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfRecord {
    /// Which bench produced the record (e.g. `bench_schedule`).
    pub bench: String,
    /// Matrix / workload name.
    pub matrix: String,
    /// Sparsity class (or workload kind).
    pub class: String,
    /// Implementation name (`CSR`, `OPT`, ...).
    pub impl_name: String,
    /// Dense width.
    pub d: usize,
    /// Column-tile width the run executed with (`dt == d` = untiled).
    pub dt: usize,
    /// Matrix ordering the run executed under (`none`, `rcm`,
    /// `degree`). Routing records carry the router's pinned choice;
    /// older artifacts without the key parse as `none`.
    pub reorder: String,
    /// Model-predicted GFLOP/s for this cell (0 when the bench does
    /// not predict; optional in the artifact for back-compat).
    pub predicted_gflops: f64,
    /// Measured GFLOP/s.
    pub gflops: f64,
}

impl PerfRecord {
    /// A record with the routing extras defaulted (`reorder = "none"`,
    /// no prediction) — what the pre-routing benches emit.
    pub fn basic(
        bench: impl Into<String>,
        matrix: impl Into<String>,
        class: impl Into<String>,
        impl_name: impl Into<String>,
        d: usize,
        dt: usize,
        gflops: f64,
    ) -> PerfRecord {
        PerfRecord {
            bench: bench.into(),
            matrix: matrix.into(),
            class: class.into(),
            impl_name: impl_name.into(),
            d,
            dt,
            reorder: "none".into(),
            predicted_gflops: 0.0,
            gflops,
        }
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl PerfRecord {
    fn to_json(&self) -> String {
        // non-finite throughput (a degenerate zero-length timing)
        // would serialise as `inf`/`NaN`, which is not JSON and would
        // poison the whole artifact on the next parse — record 0
        let gf = if self.gflops.is_finite() { self.gflops } else { 0.0 };
        let pred = if self.predicted_gflops.is_finite() { self.predicted_gflops } else { 0.0 };
        format!(
            "{{\"bench\": \"{}\", \"matrix\": \"{}\", \"class\": \"{}\", \
             \"impl\": \"{}\", \"d\": {}, \"dt\": {}, \"reorder\": \"{}\", \
             \"predicted\": {:.4}, \"gflops\": {:.4}}}",
            esc(&self.bench),
            esc(&self.matrix),
            esc(&self.class),
            esc(&self.impl_name),
            self.d,
            self.dt,
            esc(&self.reorder),
            pred,
            gf
        )
    }
}

/// A collection of perf records with JSON round-tripping and
/// per-bench merge semantics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PerfLog {
    pub records: Vec<PerfRecord>,
}

impl PerfLog {
    pub fn new() -> PerfLog {
        PerfLog::default()
    }

    /// Append one record.
    pub fn push(&mut self, rec: PerfRecord) {
        self.records.push(rec);
    }

    /// Serialise to the artifact format.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&r.to_json());
            if i + 1 < self.records.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }

    /// Parse the artifact format back (tolerant of whitespace; strict
    /// about the flat schema).
    pub fn parse(text: &str) -> Result<PerfLog> {
        let mut records = Vec::new();
        let mut rest = text;
        while let Some(start) = rest.find('{') {
            rest = &rest[start + 1..];
            // skip the top-level wrapper: objects without a "bench" key
            let end = match rest.find('}') {
                Some(e) => e,
                None => break,
            };
            let body = &rest[..end];
            if !body.contains("\"bench\"") {
                continue;
            }
            records.push(parse_record(body)?);
            rest = &rest[end + 1..];
        }
        Ok(PerfLog { records })
    }

    /// Write `path`, replacing any previous records from the same
    /// benches while keeping other benches' records. A missing or
    /// unparsable existing file is treated as empty (the artifact is a
    /// build product, not a source of truth).
    ///
    /// The read-modify-write cycle holds a [`FileLock`] and lands via
    /// [`atomic_write`], so two benches merging into the same artifact
    /// concurrently cannot interleave and drop each other's records
    /// (regression-tested in `tests/integration_serve.rs`).
    pub fn merge_save(&self, path: &str) -> Result<()> {
        let _lock = FileLock::acquire(path)?;
        let mut merged = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| PerfLog::parse(&t).ok())
            .unwrap_or_default();
        merged.records.retain(|r| !self.records.iter().any(|n| n.bench == r.bench));
        merged.records.extend(self.records.iter().cloned());
        atomic_write(path, &merged.to_json())
    }
}

fn field<'a>(body: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("\"{key}\"");
    let at = body
        .find(&pat)
        .ok_or_else(|| Error::Parse(format!("perf record missing key '{key}'")))?;
    let after = &body[at + pat.len()..];
    let colon = after
        .find(':')
        .ok_or_else(|| Error::Parse(format!("perf record key '{key}' has no value")))?;
    Ok(after[colon + 1..].trim_start())
}

fn field_str(body: &str, key: &str) -> Result<String> {
    let v = field(body, key)?;
    let v = v
        .strip_prefix('"')
        .ok_or_else(|| Error::Parse(format!("'{key}' is not a string")))?;
    let end = v
        .find('"')
        .ok_or_else(|| Error::Parse(format!("'{key}' string unterminated")))?;
    Ok(v[..end].to_string())
}

fn field_num(body: &str, key: &str) -> Result<f64> {
    let v = field(body, key)?;
    let end = v
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == '+'))
        .unwrap_or(v.len());
    v[..end]
        .parse::<f64>()
        .map_err(|_| Error::Parse(format!("'{key}' is not a number: '{}'", &v[..end])))
}

fn parse_record(body: &str) -> Result<PerfRecord> {
    Ok(PerfRecord {
        bench: field_str(body, "bench")?,
        matrix: field_str(body, "matrix")?,
        class: field_str(body, "class")?,
        impl_name: field_str(body, "impl")?,
        d: field_num(body, "d")? as usize,
        dt: field_num(body, "dt")? as usize,
        // routing extras are optional: artifacts written before the
        // router existed parse with the defaults
        reorder: field_str(body, "reorder").unwrap_or_else(|_| "none".into()),
        predicted_gflops: field_num(body, "predicted").unwrap_or(0.0),
        gflops: field_num(body, "gflops")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(bench: &str, im: &str, d: usize, dt: usize, gf: f64) -> PerfRecord {
        PerfRecord::basic(bench, "er_18_10", "Random", im, d, dt, gf)
    }

    #[test]
    fn json_round_trips() {
        let mut log = PerfLog::new();
        log.push(rec("bench_schedule", "CSR", 64, 16, 3.25));
        log.push(rec("bench_schedule", "CSR", 64, 64, 2.75));
        // a routing record with the extras populated
        log.push(PerfRecord {
            reorder: "rcm".into(),
            predicted_gflops: 4.5,
            ..rec("bench_route", "CSB", 16, 8, 5.25)
        });
        let text = log.to_json();
        let back = PerfLog::parse(&text).unwrap();
        assert_eq!(back, log);
        assert_eq!(back.records[2].reorder, "rcm");
        assert!((back.records[2].predicted_gflops - 4.5).abs() < 1e-9);
    }

    #[test]
    fn pre_routing_artifacts_parse_with_defaults() {
        // an artifact written before the reorder/predicted keys existed
        let text = "{\"records\": [\n  {\"bench\": \"bench_batch\", \"matrix\": \"m\", \
                    \"class\": \"Random\", \"impl\": \"CSR\", \"d\": 4, \"dt\": 4, \
                    \"gflops\": 1.2500}\n]}\n";
        let log = PerfLog::parse(text).unwrap();
        assert_eq!(log.records.len(), 1);
        assert_eq!(log.records[0].reorder, "none");
        assert_eq!(log.records[0].predicted_gflops, 0.0);
        assert!((log.records[0].gflops - 1.25).abs() < 1e-9);
    }

    #[test]
    fn non_finite_gflops_serialises_as_zero() {
        let mut log = PerfLog::new();
        log.push(rec("bench_batch", "CSR", 4, 4, f64::INFINITY));
        log.push(rec("bench_batch", "OPT", 4, 4, f64::NAN));
        let back = PerfLog::parse(&log.to_json()).unwrap();
        assert!(back.records.iter().all(|r| r.gflops == 0.0));
    }

    #[test]
    fn parse_rejects_malformed_records() {
        assert!(PerfLog::parse("{\"records\": [{\"bench\": \"x\"}]}").is_err());
        // no records at all is fine (empty artifact)
        assert!(PerfLog::parse("{\"records\": []}").unwrap().records.is_empty());
        assert!(PerfLog::parse("").unwrap().records.is_empty());
    }

    #[test]
    fn merge_save_replaces_own_bench_only() {
        let dir = std::env::temp_dir();
        let path = dir.join("perf_merge_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);

        let mut a = PerfLog::new();
        a.push(rec("bench_batch", "CSB", 16, 16, 5.0));
        a.merge_save(path).unwrap();

        let mut b = PerfLog::new();
        b.push(rec("bench_schedule", "CSR", 64, 8, 4.0));
        b.merge_save(path).unwrap();

        // re-run bench_batch with a new number: replaces only its own
        let mut a2 = PerfLog::new();
        a2.push(rec("bench_batch", "CSB", 16, 16, 6.0));
        a2.merge_save(path).unwrap();

        let on_disk = PerfLog::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(on_disk.records.len(), 2);
        let batch: Vec<_> =
            on_disk.records.iter().filter(|r| r.bench == "bench_batch").collect();
        assert_eq!(batch.len(), 1);
        assert!((batch[0].gflops - 6.0).abs() < 1e-9);
        assert!(on_disk.records.iter().any(|r| r.bench == "bench_schedule"));
        let _ = std::fs::remove_file(path);
    }
}
