//! Self-contained SVG plotter — regenerates the paper's Fig. 1 (lines)
//! and Fig. 2 (roofline + vertical AI markers + measured points)
//! without any plotting dependency.
//!
//! Supports linear or log10 axes, line series with markers, scatter
//! series, vertical annotation lines, axis labels, and a legend.

use crate::error::Result;
use std::fmt::Write as _;
use std::path::Path;

/// Marker shapes for series points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Marker {
    Circle,
    Square,
    Triangle,
    Diamond,
    None,
}

/// One plotted series.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
    pub color: String,
    pub marker: Marker,
    /// Draw connecting lines.
    pub line: bool,
}

impl Series {
    pub fn line(label: impl Into<String>, color: &str, points: Vec<(f64, f64)>) -> Series {
        Series { label: label.into(), points, color: color.into(), marker: Marker::Circle, line: true }
    }
    pub fn scatter(label: impl Into<String>, color: &str, marker: Marker, points: Vec<(f64, f64)>) -> Series {
        Series { label: label.into(), points, color: color.into(), marker, line: false }
    }
}

/// A labeled vertical line (the model-AI markers of Fig. 2).
#[derive(Debug, Clone)]
pub struct VLine {
    pub x: f64,
    pub label: String,
    pub color: String,
}

/// Plot builder.
pub struct SvgPlot {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub log_x: bool,
    pub log_y: bool,
    series: Vec<Series>,
    vlines: Vec<VLine>,
    width: f64,
    height: f64,
}

const MARGIN_L: f64 = 64.0;
const MARGIN_R: f64 = 150.0;
const MARGIN_T: f64 = 36.0;
const MARGIN_B: f64 = 48.0;

/// A readable qualitative palette.
pub const PALETTE: [&str; 6] = ["#1f77b4", "#d62728", "#2ca02c", "#9467bd", "#ff7f0e", "#17becf"];

impl SvgPlot {
    pub fn new(title: impl Into<String>, x_label: impl Into<String>, y_label: impl Into<String>) -> SvgPlot {
        SvgPlot {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            log_x: false,
            log_y: false,
            series: Vec::new(),
            vlines: Vec::new(),
            width: 640.0,
            height: 420.0,
        }
    }

    pub fn log_axes(mut self, log_x: bool, log_y: bool) -> SvgPlot {
        self.log_x = log_x;
        self.log_y = log_y;
        self
    }

    pub fn add_series(&mut self, s: Series) -> &mut Self {
        self.series.push(s);
        self
    }

    pub fn add_vline(&mut self, v: VLine) -> &mut Self {
        self.vlines.push(v);
        self
    }

    fn tx(&self, x: f64) -> f64 {
        if self.log_x {
            x.max(1e-30).log10()
        } else {
            x
        }
    }
    fn ty(&self, y: f64) -> f64 {
        if self.log_y {
            y.max(1e-30).log10()
        } else {
            y
        }
    }

    /// Data ranges across all series and vlines (in transformed
    /// space).
    fn ranges(&self) -> ((f64, f64), (f64, f64)) {
        let mut xs: Vec<f64> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for s in &self.series {
            for &(x, y) in &s.points {
                xs.push(self.tx(x));
                ys.push(self.ty(y));
            }
        }
        for v in &self.vlines {
            xs.push(self.tx(v.x));
        }
        let pad = |lo: f64, hi: f64| {
            if lo == hi {
                (lo - 1.0, hi + 1.0)
            } else {
                let p = (hi - lo) * 0.06;
                (lo - p, hi + p)
            }
        };
        let (xlo, xhi) = xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        let (ylo, yhi) = ys.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        (pad(xlo, xhi), pad(ylo.min(if self.log_y { ylo } else { 0.0 }), yhi))
    }

    fn render(&self) -> String {
        let ((xlo, xhi), (ylo, yhi)) = self.ranges();
        let pw = self.width - MARGIN_L - MARGIN_R;
        let ph = self.height - MARGIN_T - MARGIN_B;
        let px = |x: f64| MARGIN_L + (self.tx(x) - xlo) / (xhi - xlo) * pw;
        let py = |y: f64| MARGIN_T + ph - (self.ty(y) - ylo) / (yhi - ylo) * ph;

        let mut s = String::new();
        let _ = write!(
            s,
            r##"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}" font-family="sans-serif" font-size="11">"##,
            w = self.width,
            h = self.height
        );
        let _ = write!(s, r##"<rect width="100%" height="100%" fill="white"/>"##);
        // frame
        let _ = write!(
            s,
            r##"<rect x="{x}" y="{y}" width="{w}" height="{h}" fill="none" stroke="#444"/>"##,
            x = MARGIN_L,
            y = MARGIN_T,
            w = pw,
            h = ph
        );
        // title + axis labels
        let _ = write!(
            s,
            r##"<text x="{x}" y="20" text-anchor="middle" font-size="13" font-weight="bold">{t}</text>"##,
            x = MARGIN_L + pw / 2.0,
            t = xml_escape(&self.title)
        );
        let _ = write!(
            s,
            r##"<text x="{x}" y="{y}" text-anchor="middle">{t}</text>"##,
            x = MARGIN_L + pw / 2.0,
            y = self.height - 10.0,
            t = xml_escape(&self.x_label)
        );
        let _ = write!(
            s,
            r##"<text x="14" y="{y}" text-anchor="middle" transform="rotate(-90 14 {y})">{t}</text>"##,
            y = MARGIN_T + ph / 2.0,
            t = xml_escape(&self.y_label)
        );

        // ticks (5 per axis, in transformed space, labeled in data space)
        for i in 0..=4 {
            let f = i as f64 / 4.0;
            let tx_v = xlo + f * (xhi - xlo);
            let ty_v = ylo + f * (yhi - ylo);
            let xd = if self.log_x { 10f64.powf(tx_v) } else { tx_v };
            let yd = if self.log_y { 10f64.powf(ty_v) } else { ty_v };
            let xp = MARGIN_L + f * pw;
            let yp = MARGIN_T + ph - f * ph;
            let _ = write!(
                s,
                r##"<line x1="{xp}" y1="{y1}" x2="{xp}" y2="{y2}" stroke="#ccc" stroke-dasharray="2,3"/>"##,
                y1 = MARGIN_T,
                y2 = MARGIN_T + ph
            );
            let _ = write!(
                s,
                r##"<text x="{xp}" y="{y}" text-anchor="middle">{v}</text>"##,
                y = MARGIN_T + ph + 14.0,
                v = fmt_tick(xd)
            );
            let _ = write!(
                s,
                r##"<line x1="{x1}" y1="{yp}" x2="{x2}" y2="{yp}" stroke="#ccc" stroke-dasharray="2,3"/>"##,
                x1 = MARGIN_L,
                x2 = MARGIN_L + pw
            );
            let _ = write!(
                s,
                r##"<text x="{x}" y="{yv}" text-anchor="end">{v}</text>"##,
                x = MARGIN_L - 6.0,
                yv = yp + 4.0,
                v = fmt_tick(yd)
            );
        }

        // vertical annotation lines
        for v in &self.vlines {
            let xp = px(v.x);
            let _ = write!(
                s,
                r##"<line x1="{xp}" y1="{y1}" x2="{xp}" y2="{y2}" stroke="{c}" stroke-dasharray="6,3"/>"##,
                y1 = MARGIN_T,
                y2 = MARGIN_T + ph,
                c = v.color
            );
            let _ = write!(
                s,
                r##"<text x="{x}" y="{y}" fill="{c}" font-size="10" transform="rotate(-90 {x} {y})">{t}</text>"##,
                x = xp - 4.0,
                y = MARGIN_T + 12.0,
                c = v.color,
                t = xml_escape(&v.label)
            );
        }

        // series
        for sr in &self.series {
            if sr.line && sr.points.len() > 1 {
                let d: Vec<String> = sr
                    .points
                    .iter()
                    .enumerate()
                    .map(|(i, &(x, y))| {
                        format!("{}{:.2},{:.2}", if i == 0 { "M" } else { "L" }, px(x), py(y))
                    })
                    .collect();
                let _ = write!(
                    s,
                    r##"<path d="{d}" fill="none" stroke="{c}" stroke-width="1.8"/>"##,
                    d = d.join(" "),
                    c = sr.color
                );
            }
            for &(x, y) in &sr.points {
                let (cx, cy) = (px(x), py(y));
                match sr.marker {
                    Marker::Circle => {
                        let _ = write!(s, r##"<circle cx="{cx:.2}" cy="{cy:.2}" r="3.4" fill="{c}"/>"##, c = sr.color);
                    }
                    Marker::Square => {
                        let _ = write!(s, r##"<rect x="{x:.2}" y="{y:.2}" width="6.4" height="6.4" fill="{c}"/>"##, x = cx - 3.2, y = cy - 3.2, c = sr.color);
                    }
                    Marker::Triangle => {
                        let _ = write!(s, r##"<path d="M{x1:.2},{y1:.2} L{x2:.2},{y2:.2} L{x3:.2},{y3:.2} Z" fill="{c}"/>"##, x1 = cx, y1 = cy - 4.0, x2 = cx - 3.6, y2 = cy + 3.0, x3 = cx + 3.6, y3 = cy + 3.0, c = sr.color);
                    }
                    Marker::Diamond => {
                        let _ = write!(s, r##"<path d="M{cx:.2},{y1:.2} L{x2:.2},{cy:.2} L{cx:.2},{y3:.2} L{x4:.2},{cy:.2} Z" fill="{c}"/>"##, y1 = cy - 4.2, x2 = cx + 4.2, y3 = cy + 4.2, x4 = cx - 4.2, c = sr.color);
                    }
                    Marker::None => {}
                }
            }
        }

        // legend
        let lx = MARGIN_L + pw + 10.0;
        let mut ly = MARGIN_T + 8.0;
        for sr in &self.series {
            let _ = write!(
                s,
                r##"<rect x="{lx}" y="{y}" width="10" height="10" fill="{c}"/><text x="{tx}" y="{ty}">{t}</text>"##,
                y = ly - 8.0,
                c = sr.color,
                tx = lx + 14.0,
                ty = ly + 1.0,
                t = xml_escape(&sr.label)
            );
            ly += 16.0;
        }
        s.push_str("</svg>");
        s
    }

    /// Write the SVG to a file (creating parent dirs).
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())?;
        Ok(())
    }

    /// Rendered SVG text (tests).
    pub fn to_string(&self) -> String {
        self.render()
    }
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 || v.abs() < 0.01 {
        format!("{v:.1e}")
    } else if v.abs() >= 10.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_valid_svg_with_all_elements() {
        let mut p = SvgPlot::new("T&T", "x", "y").log_axes(true, true);
        p.add_series(Series::line("roof", PALETTE[0], vec![(0.01, 1.0), (1.0, 100.0)]));
        p.add_series(Series::scatter("pts", PALETTE[1], Marker::Square, vec![(0.1, 5.0)]));
        p.add_vline(VLine { x: 0.2, label: "AI".into(), color: "#888".into() });
        let svg = p.to_string();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert!(svg.contains("T&amp;T"));
        assert!(svg.contains("stroke-dasharray=\"6,3\"")); // vline
        assert!(svg.contains("<rect x=")); // square marker/legend
        assert!(svg.contains("<path d=\"M")); // line path
    }

    #[test]
    fn saves_to_disk() {
        let dir = std::env::temp_dir().join("spmm_svg_test");
        let path = dir.join("plot.svg");
        let mut p = SvgPlot::new("t", "x", "y");
        p.add_series(Series::line("s", PALETTE[2], vec![(0.0, 0.0), (1.0, 1.0)]));
        p.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("</svg>"));
    }

    #[test]
    fn degenerate_single_point() {
        let mut p = SvgPlot::new("t", "x", "y");
        p.add_series(Series::scatter("s", PALETTE[0], Marker::Circle, vec![(5.0, 5.0)]));
        let svg = p.to_string();
        assert!(svg.contains("circle"));
    }
}
