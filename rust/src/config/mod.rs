//! Experiment configuration: a TOML-subset parser (serde is
//! unavailable offline) and the typed config structs the harness and
//! CLI consume.

mod toml_lite;

pub use toml_lite::{TomlLite, TomlValue};

use crate::error::{Error, Result};
use crate::spmm::Impl;
use std::path::Path;

/// Configuration for a full experiment run (Table V / Fig. 1 / Fig. 2
/// sweeps). Defaults reproduce the paper's settings scaled to this
/// testbed; a TOML-lite file and/or CLI flags override.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Global matrix scale multiplier (1.0 = DESIGN.md §6 sizes).
    pub scale: f64,
    /// Dense widths to sweep — the paper uses {1, 4, 16, 64}.
    pub d_values: Vec<usize>,
    /// Worker threads per kernel execution.
    pub threads: usize,
    /// Implementations to benchmark.
    pub impls: Vec<Impl>,
    /// Timed iterations per cell (median reported).
    pub iters: usize,
    /// Warmup iterations per cell.
    pub warmup: usize,
    /// Output directory for CSV/SVG/markdown artifacts.
    pub out_dir: String,
    /// Include the XLA/PJRT implementation where artifacts exist.
    pub use_xla: bool,
    /// Artifacts directory (HLO text + manifest).
    pub artifacts_dir: String,
    /// Enable the structure-adaptive autotuning router on the engine
    /// path (`engine --autotune`; the `route` command forces it on).
    pub autotune: bool,
    /// Client threads driving the serving front-end (`serve`).
    pub clients: usize,
    /// Serving queue capacity (admission control rejects past this).
    pub queue_cap: usize,
    /// Autotune snapshot path for the serving front-end: loaded at
    /// startup, saved at shutdown (`None` = in-memory only).
    pub state_path: Option<String>,
    /// MatrixMarket corpus directory for the `corpus` command (`None`
    /// = synthesize a proxy corpus from the generator suite).
    pub mtx_dir: Option<String>,
    /// Out-of-core band byte budget for corpus band planning.
    pub ooc_budget: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            scale: 1.0,
            d_values: vec![1, 4, 16, 64],
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
            iters: 5,
            warmup: 1,
            out_dir: "results".into(),
            use_xla: false,
            artifacts_dir: "artifacts".into(),
            autotune: false,
            clients: 4,
            queue_cap: 64,
            state_path: None,
            mtx_dir: None,
            ooc_budget: crate::harness::CORPUS_DEFAULT_BUDGET,
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-lite file, applying values over the defaults.
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_text(&text)
    }

    /// Parse from TOML-lite text.
    pub fn from_toml_text(text: &str) -> Result<Self> {
        let t = TomlLite::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = t.get_f64("scale")? {
            cfg.scale = v;
        }
        if let Some(v) = t.get_usize_array("d_values")? {
            cfg.d_values = v;
        }
        if let Some(v) = t.get_f64("threads")? {
            cfg.threads = v as usize;
        }
        if let Some(v) = t.get_f64("iters")? {
            cfg.iters = v as usize;
        }
        if let Some(v) = t.get_f64("warmup")? {
            cfg.warmup = v as usize;
        }
        if let Some(v) = t.get_str("out_dir")? {
            cfg.out_dir = v.to_string();
        }
        if let Some(v) = t.get_str("artifacts_dir")? {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = t.get_bool("use_xla")? {
            cfg.use_xla = v;
        }
        if let Some(v) = t.get_bool("autotune")? {
            cfg.autotune = v;
        }
        if let Some(v) = t.get_f64("clients")? {
            cfg.clients = v as usize;
        }
        if let Some(v) = t.get_f64("queue_cap")? {
            cfg.queue_cap = v as usize;
        }
        if let Some(v) = t.get_str("state_path")? {
            cfg.state_path = Some(v.to_string());
        }
        if let Some(v) = t.get_str("mtx_dir")? {
            cfg.mtx_dir = Some(v.to_string());
        }
        if let Some(v) = t.get_f64("ooc_budget")? {
            cfg.ooc_budget = v as usize;
        }
        if let Some(list) = t.get_str_array("impls")? {
            cfg.impls = list
                .iter()
                .map(|s| parse_impl(s))
                .collect::<Result<Vec<_>>>()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check field values.
    pub fn validate(&self) -> Result<()> {
        if self.scale <= 0.0 {
            return Err(Error::Config("scale must be > 0".into()));
        }
        if self.d_values.is_empty() || self.d_values.iter().any(|&d| d == 0 || d > 4096) {
            return Err(Error::Config("d_values must be nonempty, each in 1..=4096".into()));
        }
        if self.threads == 0 || self.iters == 0 {
            return Err(Error::Config("threads and iters must be >= 1".into()));
        }
        if self.clients == 0 || self.queue_cap == 0 {
            return Err(Error::Config("clients and queue_cap must be >= 1".into()));
        }
        Ok(())
    }
}

/// Parse an implementation name (paper or internal spelling).
pub fn parse_impl(s: &str) -> Result<Impl> {
    match s.to_ascii_uppercase().as_str() {
        "CSR" => Ok(Impl::Csr),
        "OPT" | "MKL" => Ok(Impl::Opt),
        "CSB" => Ok(Impl::Csb),
        "ELL" => Ok(Impl::Ell),
        "BSR" => Ok(Impl::Bsr),
        "PB" => Ok(Impl::Pb),
        "XLA" => Ok(Impl::Xla),
        other => Err(Error::Config(format!("unknown impl '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_settings() {
        let c = ExperimentConfig::default();
        assert_eq!(c.d_values, vec![1, 4, 16, 64]);
        assert_eq!(c.impls, vec![Impl::Csr, Impl::Opt, Impl::Csb]);
        c.validate().unwrap();
    }

    #[test]
    fn parses_overrides() {
        let text = r#"
# experiment overrides
scale = 0.5
d_values = [1, 8]
impls = ["CSR", "MKL", "ELL"]
out_dir = "out"
use_xla = true
"#;
        let c = ExperimentConfig::from_toml_text(text).unwrap();
        assert_eq!(c.scale, 0.5);
        assert_eq!(c.d_values, vec![1, 8]);
        assert_eq!(c.impls, vec![Impl::Csr, Impl::Opt, Impl::Ell]);
        assert_eq!(c.out_dir, "out");
        assert!(c.use_xla);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_toml_text("scale = -1").is_err());
        assert!(ExperimentConfig::from_toml_text("d_values = []").is_err());
        assert!(ExperimentConfig::from_toml_text("impls = [\"NOPE\"]").is_err());
        assert!(ExperimentConfig::from_toml_text("clients = 0").is_err());
        assert!(ExperimentConfig::from_toml_text("queue_cap = 0").is_err());
    }

    #[test]
    fn parses_corpus_keys() {
        let c = ExperimentConfig::default();
        assert!(c.mtx_dir.is_none());
        assert_eq!(c.ooc_budget, crate::harness::CORPUS_DEFAULT_BUDGET);
        let text = "mtx_dir = \"corpus\"\nooc_budget = 4096\n";
        let c = ExperimentConfig::from_toml_text(text).unwrap();
        assert_eq!(c.mtx_dir.as_deref(), Some("corpus"));
        assert_eq!(c.ooc_budget, 4096);
    }

    #[test]
    fn parses_serve_keys() {
        let c = ExperimentConfig::default();
        assert_eq!((c.clients, c.queue_cap), (4, 64));
        assert!(c.state_path.is_none());
        let text = "clients = 8\nqueue_cap = 16\nstate_path = \"autotune.json\"\n";
        let c = ExperimentConfig::from_toml_text(text).unwrap();
        assert_eq!((c.clients, c.queue_cap), (8, 16));
        assert_eq!(c.state_path.as_deref(), Some("autotune.json"));
    }

    #[test]
    fn impl_aliases() {
        assert_eq!(parse_impl("mkl").unwrap(), Impl::Opt);
        assert_eq!(parse_impl("csb").unwrap(), Impl::Csb);
        assert!(parse_impl("??").is_err());
    }
}
