//! A TOML-subset parser: top-level `key = value` pairs, `[section]`
//! headers flattened to `section.key`, comments, strings, numbers,
//! booleans, and flat arrays of strings/numbers. Exactly what the
//! config files need — nothing more.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

/// A parsed document: flattened dotted keys → values.
#[derive(Debug, Clone, Default)]
pub struct TomlLite {
    map: BTreeMap<String, TomlValue>,
}

impl TomlLite {
    /// Parse a document. Fails with a line-numbered message.
    pub fn parse(text: &str) -> Result<TomlLite> {
        let mut map = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(section) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                prefix = section.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Parse(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = if prefix.is_empty() {
                k.trim().to_string()
            } else {
                format!("{prefix}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .map_err(|e| Error::Parse(format!("line {}: {e}", lineno + 1)))?;
            map.insert(key, value);
        }
        Ok(TomlLite { map })
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    /// All flattened keys in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Distinct `[section]` names, in first-seen (sorted) order.
    pub fn sections(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for k in self.map.keys() {
            if let Some((sec, _)) = k.split_once('.') {
                if out.last().map(|s| s.as_str()) != Some(sec) {
                    out.push(sec.to_string());
                }
            }
        }
        out.dedup();
        out
    }

    /// Typed lookups — `Ok(None)` when absent, `Err` on wrong type.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Num(x)) => Ok(Some(*x)),
            Some(v) => Err(Error::Parse(format!("{key}: expected number, got {v:?}"))),
        }
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Bool(b)) => Ok(Some(*b)),
            Some(v) => Err(Error::Parse(format!("{key}: expected bool, got {v:?}"))),
        }
    }

    pub fn get_str(&self, key: &str) -> Result<Option<&str>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Str(s)) => Ok(Some(s)),
            Some(v) => Err(Error::Parse(format!("{key}: expected string, got {v:?}"))),
        }
    }

    pub fn get_usize_array(&self, key: &str) -> Result<Option<Vec<usize>>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Array(xs)) => xs
                .iter()
                .map(|v| match v {
                    TomlValue::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Ok(*x as usize),
                    other => {
                        Err(Error::Parse(format!("{key}: expected integer, got {other:?}")))
                    }
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
            Some(v) => Err(Error::Parse(format!("{key}: expected array, got {v:?}"))),
        }
    }

    pub fn get_str_array(&self, key: &str) -> Result<Option<Vec<String>>> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Array(xs)) => xs
                .iter()
                .map(|v| match v {
                    TomlValue::Str(s) => Ok(s.clone()),
                    other => Err(Error::Parse(format!("{key}: expected string, got {other:?}"))),
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
            Some(v) => Err(Error::Parse(format!("{key}: expected array, got {v:?}"))),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // honor '#' outside quotes
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = split_array_items(inner)?;
        let vals = items
            .iter()
            .map(|it| parse_value(it.trim()))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(vals));
    }
    s.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_array_items(s: &str) -> std::result::Result<Vec<String>, String> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for ch in s.chars() {
        match ch {
            '"' => {
                in_str = !in_str;
                cur.push(ch);
            }
            ',' if !in_str => {
                items.push(std::mem::take(&mut cur));
            }
            _ => cur.push(ch),
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    if !cur.trim().is_empty() {
        items.push(cur);
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let t = TomlLite::parse("a = 1.5\nb = \"hi\"\nc = true\n").unwrap();
        assert_eq!(t.get_f64("a").unwrap(), Some(1.5));
        assert_eq!(t.get_str("b").unwrap(), Some("hi"));
        assert_eq!(t.get_bool("c").unwrap(), Some(true));
        assert_eq!(t.get_f64("missing").unwrap(), None);
    }

    #[test]
    fn parses_arrays_and_sections() {
        let t = TomlLite::parse("[exp]\nds = [1, 2, 3]\nnames = [\"x\", \"y\"]\n").unwrap();
        assert_eq!(t.get_usize_array("exp.ds").unwrap(), Some(vec![1, 2, 3]));
        assert_eq!(
            t.get_str_array("exp.names").unwrap(),
            Some(vec!["x".into(), "y".into()])
        );
    }

    #[test]
    fn comments_and_hash_in_string() {
        let t = TomlLite::parse("a = 1 # trailing\ns = \"a#b\"\n").unwrap();
        assert_eq!(t.get_f64("a").unwrap(), Some(1.0));
        assert_eq!(t.get_str("s").unwrap(), Some("a#b"));
    }

    #[test]
    fn error_reports_line() {
        let err = TomlLite::parse("ok = 1\nbroken line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
    }

    #[test]
    fn type_errors() {
        let t = TomlLite::parse("a = \"s\"\narr = [1, \"x\"]\n").unwrap();
        assert!(t.get_f64("a").is_err());
        assert!(t.get_usize_array("arr").is_err());
    }

    #[test]
    fn fractional_in_usize_array_rejected() {
        let t = TomlLite::parse("xs = [1.5]\n").unwrap();
        assert!(t.get_usize_array("xs").is_err());
    }
}
