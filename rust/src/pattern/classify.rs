//! The pattern classifier: structural statistics → sparsity class →
//! parameterised roofline model.

use crate::model::SparsityModel;
use crate::pattern::powerlaw::fit_power_law_auto;
use crate::pattern::stats::{structural_stats, StructuralStats};
use crate::pattern::PowerLawFit;
use crate::sparse::Csr;
use crate::gen::SparsityClass;

/// Classification output: the class, the fitted model with its
/// parameters, and the evidence used.
#[derive(Debug, Clone)]
pub struct Classification {
    pub class: SparsityClass,
    /// The parameterised AI model (Eqs. 2/3/4/6) to use for this
    /// matrix.
    pub model: SparsityModel,
    pub stats: StructuralStats,
    /// Power-law fit over row degrees, when one exists.
    pub power_law: Option<PowerLawFit>,
    /// One-line human-readable rationale.
    pub rationale: String,
}

impl Classification {
    /// `"<class> — <rationale>"`, for routing tables and logs. The
    /// autotuner classifies each *reordered* layout of a matrix, so
    /// reports print this per candidate to show the class moving under
    /// permutation.
    pub fn summary(&self) -> String {
        format!("{} — {}", self.class, self.rationale)
    }
}

/// Decision thresholds (documented constants rather than magic
/// numbers; the integration tests pin the classifier's behaviour on
/// every generator).
mod thresholds {
    /// `diag_fraction` above this (and low skew) ⇒ Diagonal. Kept
    /// above 0.9: serpentine road meshes (asia_osm-like) put ~90% of
    /// edges at |Δid| = 1 yet behave like blocked matrices.
    pub const DIAG_FRACTION: f64 = 0.93;
    /// Row-length CV above this suggests hubs.
    pub const SKEW_CV: f64 = 1.0;
    /// Hub mass (top 1% of rows) above this confirms scale-free.
    /// (1% rather than the model's 0.1%: on small/scaled matrices
    /// 0.1% of rows is too few samples to be stable.)
    pub const HUB_MASS_1PCT: f64 = 0.05;
    /// Fraction of nonzeros in diagonal probe blocks above this (with
    /// low skew) ⇒ Blocked.
    pub const BLOCK_DIAG_FRACTION: f64 = 0.5;
}

/// Classify a square sparse matrix into one of the paper's four
/// regimes and attach the matching parameterised model.
///
/// Decision order mirrors the strength of the structural evidence:
/// 1. heavy-tailed rows (high CV + hub mass, power-law fit) → Scale-free
/// 2. almost everything within a narrow band → Diagonal
/// 3. nonzeros concentrated in diagonal blocks → Blocked
/// 4. otherwise → Random (the conservative lower-bound model)
pub fn classify(a: &Csr) -> Classification {
    let stats = structural_stats(a, 0);
    let lens: Vec<usize> = (0..a.nrows).map(|r| a.row_len(r)).collect();
    let power_law = fit_power_law_auto(&lens);

    // 1. scale-free evidence
    if stats.row_len_cv > thresholds::SKEW_CV && stats.hub_mass_1pct > thresholds::HUB_MASS_1PCT {
        let alpha = power_law.map(|f| f.alpha).unwrap_or(2.3).clamp(2.01, 3.5);
        return Classification {
            class: SparsityClass::ScaleFree,
            model: SparsityModel::ScaleFree { alpha, f: 0.001 },
            rationale: format!(
                "row-length CV {:.2} > {} and top-1% rows hold {:.1}% of nnz (α̂={alpha:.2})",
                stats.row_len_cv,
                thresholds::SKEW_CV,
                stats.hub_mass_1pct * 100.0
            ),
            stats,
            power_law,
        };
    }

    // 2. diagonal evidence
    if stats.diag_fraction > thresholds::DIAG_FRACTION {
        return Classification {
            class: SparsityClass::Diagonal,
            model: SparsityModel::Diagonal,
            rationale: format!(
                "{:.1}% of nonzeros within band ±{}",
                stats.diag_fraction * 100.0,
                stats.diag_band
            ),
            stats,
            power_law,
        };
    }

    // 3. blocked evidence
    if stats.block_diag_fraction > thresholds::BLOCK_DIAG_FRACTION {
        return Classification {
            class: SparsityClass::Blocked,
            model: SparsityModel::Blocked { t: stats.probe_block, n_blocks: stats.n_blocks },
            rationale: format!(
                "{:.1}% of nonzeros in diagonal {}-blocks (D̄={:.1})",
                stats.block_diag_fraction * 100.0,
                stats.probe_block,
                stats.block_density
            ),
            stats,
            power_law,
        };
    }

    // 4. fallback
    Classification {
        class: SparsityClass::Random,
        model: SparsityModel::Random,
        rationale: format!(
            "no dominant structure (diag {:.2}, block-diag {:.2}, CV {:.2})",
            stats.diag_fraction, stats.block_diag_fraction, stats.row_len_cv
        ),
        stats,
        power_law,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{
        banded, chung_lu, erdos_renyi, ideal_diagonal, mesh2d, ChungLuParams, MeshKind, Prng,
    };

    #[test]
    fn classifies_er_as_random() {
        let a = erdos_renyi(4000, 4000, 8.0, &mut Prng::new(140));
        let c = classify(&a);
        assert_eq!(c.class, SparsityClass::Random, "{}", c.rationale);
        assert_eq!(c.model, SparsityModel::Random);
    }

    #[test]
    fn classifies_banded_as_diagonal() {
        let a = banded(4000, 8, 0.25, &mut Prng::new(141));
        let c = classify(&a);
        assert_eq!(c.class, SparsityClass::Diagonal, "{}", c.rationale);
    }

    #[test]
    fn classifies_ideal_diagonal() {
        let a = ideal_diagonal(2000);
        let c = classify(&a);
        assert_eq!(c.class, SparsityClass::Diagonal, "{}", c.rationale);
    }

    #[test]
    fn classifies_chung_lu_as_scalefree_with_alpha() {
        let a = chung_lu(
            ChungLuParams { n: 10_000, alpha: 2.2, avg_deg: 14.0, k_min: 2.0 },
            &mut Prng::new(142),
        );
        let c = classify(&a);
        assert_eq!(c.class, SparsityClass::ScaleFree, "{}", c.rationale);
        if let SparsityModel::ScaleFree { alpha, f } = c.model {
            assert!(alpha > 2.0 && alpha < 3.2, "alpha {alpha}");
            assert_eq!(f, 0.001);
        } else {
            panic!("wrong model {:?}", c.model);
        }
    }

    #[test]
    fn classifies_mesh_as_blocked() {
        let a = mesh2d(72, MeshKind::Road, 0.62, &mut Prng::new(143));
        let c = classify(&a);
        assert_eq!(c.class, SparsityClass::Blocked, "{}", c.rationale);
        if let SparsityModel::Blocked { t, n_blocks } = c.model {
            assert!(t > 0 && n_blocks > 0);
        } else {
            panic!("wrong model {:?}", c.model);
        }
    }
}
