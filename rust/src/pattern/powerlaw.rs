//! Discrete power-law fitting (Clauset, Shalizi & Newman 2009) — the
//! `α` estimate the scale-free model (Eq. 6) consumes.

/// Result of a power-law fit over a degree sequence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerLawFit {
    /// MLE exponent `α̂ = 1 + m / Σ ln(k_i / (k_min − ½))`.
    pub alpha: f64,
    /// The `k_min` the tail was fit above.
    pub k_min: f64,
    /// Number of tail samples used.
    pub n_tail: usize,
    /// Kolmogorov–Smirnov distance between the empirical tail CDF and
    /// the fitted CDF (goodness-of-fit; < ~0.1 is a decent fit at our
    /// sizes).
    pub ks_distance: f64,
}

/// Fit `p(k) ∝ k^{−α}` to the degrees ≥ `k_min` with the continuous
/// MLE (the standard approximation for discrete data,
/// `α̂ = 1 + n/Σln(k/(kmin−0.5))`). Returns `None` when fewer than 10
/// tail samples exist.
pub fn fit_power_law(degrees: &[usize], k_min: usize) -> Option<PowerLawFit> {
    let k_min = k_min.max(1);
    let tail: Vec<f64> = degrees
        .iter()
        .filter(|&&k| k >= k_min)
        .map(|&k| k as f64)
        .collect();
    if tail.len() < 10 {
        return None;
    }
    let km = k_min as f64 - 0.5;
    let log_sum: f64 = tail.iter().map(|&k| (k / km).ln()).sum();
    if log_sum <= 0.0 {
        return None;
    }
    let alpha = 1.0 + tail.len() as f64 / log_sum;

    // KS distance over the tail, with the discreteness correction:
    // the empirical CDF of integer degrees steps at k, so the fitted
    // CDF is evaluated at the bucket boundary k + 0.5 (each integer k
    // collects the continuous mass of [k − 0.5, k + 0.5)).
    let mut sorted = tail.clone();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len() as f64;
    let mut ks: f64 = 0.0;
    let mut i = 0usize;
    while i < sorted.len() {
        let k = sorted[i];
        let mut j = i;
        while j < sorted.len() && sorted[j] == k {
            j += 1;
        }
        let emp_below = i as f64 / n; // F_emp(k⁻)
        let emp_at = j as f64 / n; // F_emp(k)
        let fit_lo = 1.0 - ((k - 0.5).max(km) / km).powf(1.0 - alpha);
        let fit_hi = 1.0 - ((k + 0.5) / km).powf(1.0 - alpha);
        ks = ks.max((fit_lo - emp_below).abs()).max((fit_hi - emp_at).abs());
        i = j;
    }

    Some(PowerLawFit { alpha, k_min: k_min as f64, n_tail: tail.len(), ks_distance: ks })
}

/// Scan `k_min` candidates and keep the fit minimising the KS distance
/// (the Clauset et al. model-selection recipe, restricted to a small
/// candidate grid for speed).
pub fn fit_power_law_auto(degrees: &[usize]) -> Option<PowerLawFit> {
    let max_deg = *degrees.iter().max()?;
    let mut best: Option<PowerLawFit> = None;
    let mut k = 2usize;
    while k <= max_deg / 4 + 1 && k <= 256 {
        if let Some(fit) = fit_power_law(degrees, k) {
            if fit.n_tail >= 50 && best.map_or(true, |b| fit.ks_distance < b.ks_distance) {
                best = Some(fit);
            }
        }
        k *= 2;
    }
    best.or_else(|| fit_power_law(degrees, 2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::Prng;

    fn synth_degrees(alpha: f64, n: usize, seed: u64) -> Vec<usize> {
        let mut rng = Prng::new(seed);
        // generate at continuous k_min 1.5 so rounding fills the k=2 bucket
        // completely (matches the estimator's k_min - 0.5 correction)
        (0..n).map(|_| rng.power_law(alpha, 1.5).round() as usize).collect()
    }

    #[test]
    fn recovers_alpha() {
        for alpha in [2.1, 2.5, 2.9] {
            let degs = synth_degrees(alpha, 30_000, 130);
            let fit = fit_power_law(&degs, 2).unwrap();
            assert!(
                (fit.alpha - alpha).abs() < 0.25,
                "alpha {alpha} fitted {}",
                fit.alpha
            );
            assert!(fit.ks_distance < 0.1, "ks {}", fit.ks_distance);
        }
    }

    #[test]
    fn auto_picks_reasonable_kmin() {
        let degs = synth_degrees(2.3, 30_000, 131);
        let fit = fit_power_law_auto(&degs).unwrap();
        assert!((fit.alpha - 2.3).abs() < 0.25, "{}", fit.alpha);
    }

    #[test]
    fn uniform_degrees_fit_poorly() {
        // constant degrees are not a power law: the fit degenerates to
        // an absurd exponent with a large KS distance
        let degs = vec![8usize; 5000];
        let fit = fit_power_law(&degs, 8).unwrap();
        assert!(fit.alpha > 5.0, "alpha {}", fit.alpha);
        assert!(fit.ks_distance > 0.1, "ks {}", fit.ks_distance);
    }

    #[test]
    fn too_few_samples_none() {
        assert!(fit_power_law(&[5, 6, 7], 2).is_none());
    }
}
