//! Sparsity-pattern analysis: structural statistics and the classifier
//! that maps a matrix to the roofline model that governs it.
//!
//! The paper assigns each matrix to a structural class by provenance
//! (road network → blocking, social graph → scale-free, ...). The
//! engine cannot rely on provenance, so this module derives the class
//! from measurable structure — which also makes the assignment testable
//! against the generators.
//!
//! **Hand-off** (classify → predict → schedule → route → execute):
//! this module is the *classify* stage. [`classify()`] runs once per
//! registered matrix (and once per candidate reordered layout during
//! autotuning) and produces a [`Classification`] — the
//! [`crate::model::SparsityModel`] with fitted parameters plus the
//! structural statistics ([`StructuralStats`]) the planner's
//! predictions consume. Everything downstream
//! ([`crate::coordinator::Planner`], the router, the schedule layer)
//! keys off this output; nothing downstream re-reads the matrix
//! structure. Formula derivations live in `MODELS.md`.

mod classify;
mod powerlaw;
mod stats;

pub use classify::{classify, Classification};
pub use powerlaw::{fit_power_law, PowerLawFit};
pub use stats::{structural_stats, StructuralStats};

pub use crate::gen::SparsityClass;
