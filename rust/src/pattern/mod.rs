//! Sparsity-pattern analysis: structural statistics and the classifier
//! that maps a matrix to the roofline model that governs it.
//!
//! The paper assigns each matrix to a structural class by provenance
//! (road network → blocking, social graph → scale-free, ...). The
//! engine cannot rely on provenance, so this module derives the class
//! from measurable structure — which also makes the assignment testable
//! against the generators.

mod classify;
mod powerlaw;
mod stats;

pub use classify::{classify, Classification};
pub use powerlaw::{fit_power_law, PowerLawFit};
pub use stats::{structural_stats, StructuralStats};

pub use crate::gen::SparsityClass;
