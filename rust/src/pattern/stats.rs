//! Structural statistics of a sparse matrix — the classifier's and the
//! models' raw inputs.

use crate::sparse::{Csb, Csr};

/// Structure summary of a square sparse matrix.
#[derive(Debug, Clone)]
pub struct StructuralStats {
    pub n: usize,
    pub nnz: usize,
    /// Mean nonzeros per row.
    pub avg_row_len: f64,
    /// Max nonzeros per row.
    pub max_row_len: usize,
    /// Coefficient of variation of row lengths (σ/μ) — skew indicator.
    pub row_len_cv: f64,
    /// Fraction of nonzeros with `|i − j| ≤ diag_band` (band window =
    /// 2·avg_row_len + 1, min 16).
    pub diag_fraction: f64,
    /// The band half-width used for `diag_fraction`.
    pub diag_band: usize,
    /// Fraction of nonzeros falling in *diagonal* blocks of the probe
    /// block size (block-locality indicator).
    pub block_diag_fraction: f64,
    /// Probe block size used for block statistics.
    pub probe_block: usize,
    /// Average nonzeros per nonzero probe block (`D` of Eq. 4).
    pub block_density: f64,
    /// Nonzero probe blocks (`N` of Eq. 4).
    pub n_blocks: usize,
    /// Empirical top-0.1%-of-rows share of nonzeros (hub mass at the
    /// paper's f).
    pub hub_mass_01pct: f64,
    /// Hub mass at f = 1% — the classifier's skew evidence (more
    /// robust than 0.1% on small matrices, where 0.1% of rows is a
    /// handful of samples).
    pub hub_mass_1pct: f64,
}

/// Compute [`StructuralStats`] for a CSR matrix.
///
/// `probe_block` is the CSB block size used for block statistics; pass
/// 0 for the default heuristic.
pub fn structural_stats(a: &Csr, probe_block: usize) -> StructuralStats {
    let n = a.nrows;
    let nnz = a.nnz();
    let avg = a.avg_row_len();
    let mut max_len = 0usize;
    let mut var = 0.0f64;
    let lens: Vec<usize> = (0..n).map(|r| a.row_len(r)).collect();
    for &l in &lens {
        max_len = max_len.max(l);
        let dl = l as f64 - avg;
        var += dl * dl;
    }
    let sd = if n > 1 { (var / (n - 1) as f64).sqrt() } else { 0.0 };
    let cv = if avg > 0.0 { sd / avg } else { 0.0 };

    // diagonal band fraction — the band is kept narrow (≥8) so
    // tile-local mesh edges (|Δid| ≈ tile width) do not masquerade as
    // banded structure
    let band = ((2.0 * avg) as usize + 1).max(8);
    let mut in_band = 0usize;
    for r in 0..n {
        for &c in a.row_cols(r) {
            if (r as i64 - c as i64).unsigned_abs() as usize <= band {
                in_band += 1;
            }
        }
    }
    let diag_fraction = if nnz > 0 { in_band as f64 / nnz as f64 } else { 0.0 };

    // block statistics through a CSB probe
    let probe_block = if probe_block == 0 {
        Csb::default_block_dim(n.max(a.ncols))
    } else {
        probe_block
    };
    let csb = Csb::from_csr_with_block(a, probe_block);
    let mut block_diag = 0usize;
    for br in 0..csb.n_block_rows {
        for b in csb.block_row(br) {
            if b.bcol as usize == br {
                block_diag += b.len();
            }
        }
    }
    let block_diag_fraction = if nnz > 0 { block_diag as f64 / nnz as f64 } else { 0.0 };

    // hub mass at the paper's f = 0.1% and at the classifier's 1%
    let hub_mass_01pct = crate::model::measured_hub_mass(&lens, 0.001);
    let hub_mass_1pct = crate::model::measured_hub_mass(&lens, 0.01);

    StructuralStats {
        n,
        nnz,
        avg_row_len: avg,
        max_row_len: max_len,
        row_len_cv: cv,
        diag_fraction,
        diag_band: band,
        block_diag_fraction,
        probe_block,
        block_density: csb.avg_block_density(),
        n_blocks: csb.n_nonzero_blocks(),
        hub_mass_01pct,
        hub_mass_1pct,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{banded, chung_lu, erdos_renyi, ChungLuParams, Prng};

    #[test]
    fn banded_has_high_diag_fraction() {
        let mut rng = Prng::new(120);
        let a = banded(2000, 6, 0.4, &mut rng);
        let st = structural_stats(&a, 0);
        assert!(st.diag_fraction > 0.99, "{}", st.diag_fraction);
        assert!(st.row_len_cv < 0.5);
    }

    #[test]
    fn er_low_cv_low_diag() {
        let mut rng = Prng::new(121);
        let a = erdos_renyi(4000, 4000, 8.0, &mut rng);
        let st = structural_stats(&a, 256);
        assert!(st.diag_fraction < 0.1, "{}", st.diag_fraction);
        assert!(st.row_len_cv < 0.6, "{}", st.row_len_cv);
        assert!(st.hub_mass_01pct < 0.02);
        assert!(st.hub_mass_1pct < 0.04, "{}", st.hub_mass_1pct);
    }

    #[test]
    fn scalefree_high_cv_and_hub_mass() {
        let mut rng = Prng::new(122);
        let a = chung_lu(
            ChungLuParams { n: 8000, alpha: 2.2, avg_deg: 12.0, k_min: 2.0 },
            &mut rng,
        );
        let st = structural_stats(&a, 256);
        assert!(st.row_len_cv > 1.0, "cv {}", st.row_len_cv);
        assert!(st.hub_mass_01pct > 0.03, "hub {}", st.hub_mass_01pct);
        assert!(st.hub_mass_1pct > 0.08, "hub1 {}", st.hub_mass_1pct);
    }

    #[test]
    fn counts_consistent() {
        let mut rng = Prng::new(123);
        let a = erdos_renyi(1000, 1000, 4.0, &mut rng);
        let st = structural_stats(&a, 128);
        assert_eq!(st.nnz, a.nnz());
        assert!(st.block_density * st.n_blocks as f64 > 0.99 * st.nnz as f64);
    }
}
