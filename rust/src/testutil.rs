//! `proptest_lite` — a miniature property-testing harness, plus the
//! shared **differential-test oracle** every kernel suite checks
//! against.
//!
//! `proptest` cannot be vendored offline, so this module provides the
//! slice of it the test suite needs: seeded random case generation, a
//! configurable case count, and on-failure reporting of the failing
//! seed so a case can be replayed deterministically. (No shrinking —
//! cases are kept small instead.)
//!
//! The oracle half ([`dense_spmm`], [`dense_spgemm`], [`csr_eq`],
//! [`close_slice`]) is deliberately *independent* of the kernels under
//! test: both multiplies render the sparse operands dense and run the
//! obvious triple loop, so a structural bug shared by every CSR
//! traversal cannot cancel out of the comparison. `tests/prop_spmm.rs`,
//! `tests/prop_pb.rs`, and `tests/prop_spgemm.rs` all differentiate
//! against it.

use crate::gen::Prng;
use crate::sparse::Csr;
use crate::spmm::DenseMatrix;

/// Number of cases per property (override with env
/// `PROPTEST_LITE_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROPTEST_LITE_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32)
}

/// Fleet-wide seed offset: the `PROP_SEED` env var is folded into
/// every property's base seed, so CI can re-run the suites over a
/// seed matrix without editing tests. Unset or `0` keeps the
/// committed seeds.
fn prop_seed_offset() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0)
        .wrapping_mul(0xA076_1D64_78BD_642F)
}

/// Run `prop` on `cases` seeded PRNGs derived from `seed` (and the
/// `PROP_SEED` offset — see [`prop_seed_offset`]). The closure returns
/// `Err(msg)` (or panics) to fail; the harness reports the failing
/// case seed for replay.
pub fn check<F>(seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    let seed = seed ^ prop_seed_offset();
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (case {case}, replay seed {case_seed:#x}): {msg}");
        }
    }
}

/// Like [`check`] with [`default_cases`].
pub fn check_default<F>(seed: u64, prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    check(seed, default_cases(), prop)
}

/// Assert two f64s agree to `tol`, returning a property-style error.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Dense-reference SpMM oracle: render `A` dense and run the obvious
/// triple loop (`k` ascending). Independent of every CSR kernel's
/// traversal, so it differentiates rather than mirrors them.
pub fn dense_spmm(a: &Csr, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.ncols, b.nrows);
    let ad = a.to_dense();
    let mut c = DenseMatrix::zeros(a.nrows, b.ncols);
    for i in 0..a.nrows {
        for k in 0..a.ncols {
            let v = ad[i * a.ncols + k];
            if v != 0.0 {
                let brow = b.row(k);
                let crow = c.row_mut(i);
                for (cc, &x) in crow.iter_mut().zip(brow) {
                    *cc += v * x;
                }
            }
        }
    }
    c
}

/// Dense-reference SpGEMM oracle: the product `A·B` as a dense
/// row-major `a.nrows × b.ncols` buffer, accumulated `k`-ascending.
/// Compare a kernel's CSR output via `to_dense()` + [`close_slice`] —
/// comparing dense renderings sidesteps structural-zero brittleness
/// (an exactly-cancelled output is a stored zero for the kernels but
/// absent from a dense-built CSR).
pub fn dense_spgemm(a: &Csr, b: &Csr) -> Vec<f64> {
    assert_eq!(a.ncols, b.nrows);
    let (ad, bd) = (a.to_dense(), b.to_dense());
    let (m, p, n) = (a.nrows, a.ncols, b.ncols);
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for k in 0..p {
            let v = ad[i * p + k];
            if v != 0.0 {
                for j in 0..n {
                    c[i * n + j] += v * bd[k * n + j];
                }
            }
        }
    }
    c
}

/// Elementwise slice comparison to `tol`, returning a property-style
/// error naming the first offending index.
pub fn close_slice(got: &[f64], want: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if (g - w).abs() > tol {
            return Err(format!("{what}: [{i}] {g} vs {w} (tol {tol})"));
        }
    }
    Ok(())
}

/// Structural + numeric CSR comparison: shapes, row pointers, and
/// column indices must match exactly; values to `tol`. Use between
/// kernels (identical structure guaranteed); use [`dense_spgemm`] +
/// [`close_slice`] against the dense oracle.
pub fn csr_eq(got: &Csr, want: &Csr, tol: f64, what: &str) -> Result<(), String> {
    if (got.nrows, got.ncols) != (want.nrows, want.ncols) {
        return Err(format!(
            "{what}: shape {}x{} vs {}x{}",
            got.nrows, got.ncols, want.nrows, want.ncols
        ));
    }
    if got.row_ptr != want.row_ptr {
        return Err(format!("{what}: row_ptr differs"));
    }
    if got.col_idx != want.col_idx {
        return Err(format!("{what}: col_idx differs"));
    }
    close_slice(&got.vals, &want.vals, tol, what)
}

/// Panicking wrapper over [`csr_eq`] for unit tests.
pub fn assert_csr_eq(got: &Csr, want: &Csr, tol: f64) {
    if let Err(msg) = csr_eq(got, want, tol, "csr") {
        panic!("{msg}");
    }
}

/// Panicking wrapper over [`close_slice`] for unit tests.
pub fn assert_close_slice(got: &[f64], want: &[f64], tol: f64) {
    if let Err(msg) = close_slice(got, want, tol, "slice") {
        panic!("{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check(1, 16, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_seed_report() {
        check(2, 8, |rng| {
            if rng.f64() < 2.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-9, "x").is_err());
    }

    #[test]
    fn dense_oracles_agree_with_each_other() {
        use crate::gen::erdos_renyi;
        let mut rng = Prng::new(7);
        let a = erdos_renyi(20, 15, 3.0, &mut rng);
        let b_sparse = erdos_renyi(15, 10, 3.0, &mut rng);
        // SpGEMM oracle vs SpMM oracle fed the densified B
        let bd = DenseMatrix::from_vec(15, 10, b_sparse.to_dense());
        let via_spmm = dense_spmm(&a, &bd);
        let via_spgemm = dense_spgemm(&a, &b_sparse);
        assert_close_slice(&via_spmm.data, &via_spgemm, 1e-12);
    }

    #[test]
    fn close_slice_reports_index_and_length() {
        assert!(close_slice(&[1.0, 2.0], &[1.0, 2.0], 1e-12, "x").is_ok());
        let err = close_slice(&[1.0, 2.0], &[1.0, 3.0], 1e-12, "x").unwrap_err();
        assert!(err.contains("[1]"), "{err}");
        assert!(close_slice(&[1.0], &[1.0, 2.0], 1e-12, "x").is_err());
    }

    #[test]
    fn csr_eq_checks_structure_then_values() {
        let a = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 2.0]);
        let mut b = a.clone();
        assert!(csr_eq(&a, &b, 1e-12, "x").is_ok());
        assert_csr_eq(&a, &b, 1e-12);
        b.vals[0] = 1.5;
        assert!(csr_eq(&a, &b, 1e-12, "x").is_err());
        let c = Csr::from_dense(2, 2, &[0.0, 1.0, 0.0, 2.0]);
        let err = csr_eq(&a, &c, 1e-12, "x").unwrap_err();
        assert!(err.contains("col_idx") || err.contains("row_ptr"), "{err}");
    }
}
