//! `proptest_lite` — a miniature property-testing harness.
//!
//! `proptest` cannot be vendored offline, so this module provides the
//! slice of it the test suite needs: seeded random case generation, a
//! configurable case count, and on-failure reporting of the failing
//! seed so a case can be replayed deterministically. (No shrinking —
//! cases are kept small instead.)

use crate::gen::Prng;

/// Number of cases per property (override with env
/// `PROPTEST_LITE_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROPTEST_LITE_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32)
}

/// Run `prop` on `cases` seeded PRNGs derived from `seed`. The closure
/// returns `Err(msg)` (or panics) to fail; the harness reports the
/// failing case seed for replay.
pub fn check<F>(seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (case {case}, replay seed {case_seed:#x}): {msg}");
        }
    }
}

/// Like [`check`] with [`default_cases`].
pub fn check_default<F>(seed: u64, prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    check(seed, default_cases(), prop)
}

/// Assert two f64s agree to `tol`, returning a property-style error.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check(1, 16, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_seed_report() {
        check(2, 8, |rng| {
            if rng.f64() < 2.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-9, "x").is_err());
    }
}
