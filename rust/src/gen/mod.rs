//! Structural matrix generators.
//!
//! The paper's dataset (Table III) comes from SuiteSparse, which is not
//! available offline. The roofline models depend only on *structural
//! statistics* — nonzeros per row, bandwidth, block density `D`, block
//! occupancy `z`, power-law exponent `α` — so each generator here
//! controls exactly those statistics, and [`suite`] assembles a scaled
//! proxy of every Table III matrix (see DESIGN.md §6).

mod banded;
mod blocked;
mod erdos_renyi;
mod prng;
mod rmat;
mod scalefree;
pub mod suite;

pub use banded::{banded, ideal_diagonal};
pub use blocked::{mesh2d, MeshKind};
pub use erdos_renyi::erdos_renyi;
pub use prng::Prng;
pub use rmat::rmat;
pub use scalefree::{chung_lu, ChungLuParams};
pub use suite::{proxy_suite, representative_suite, ProxyMatrix, SparsityClass};
