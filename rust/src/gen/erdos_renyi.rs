//! Erdős–Rényi G(n, p) generator — the paper's "uniform random"
//! class (`er_22_1`, `er_22_10`, `er_22_20`).

use crate::gen::Prng;
use crate::sparse::{Coo, Csr};

/// Generate an `nrows × ncols` Erdős–Rényi matrix with an *expected*
/// `avg_deg` nonzeros per row (i.e. `p = avg_deg / ncols`), values
/// uniform in `[-1, 1)`.
///
/// Uses geometric skip-sampling over the flattened index space, so the
/// cost is O(nnz), independent of `n²`.
pub fn erdos_renyi(nrows: usize, ncols: usize, avg_deg: f64, rng: &mut Prng) -> Csr {
    assert!(nrows > 0 && ncols > 0);
    let p = (avg_deg / ncols as f64).clamp(0.0, 1.0);
    let expected = (nrows as f64 * avg_deg) as usize;
    let mut coo = Coo::with_capacity(nrows, ncols, expected + expected / 8 + 16);
    if p <= 0.0 {
        return Csr::from_coo(coo);
    }
    let total = (nrows as u64) * (ncols as u64);
    let ln_q = (1.0 - p).ln();
    // degenerate p == 1.0 (dense) — only reachable in tests
    if !ln_q.is_finite() {
        for r in 0..nrows {
            for c in 0..ncols {
                coo.push(r, c, rng.range_f64(-1.0, 1.0));
            }
        }
        return Csr::from_coo(coo);
    }
    let mut idx: u64 = 0;
    loop {
        // skip ~ Geometric(p): floor(ln(U)/ln(1-p))
        let u = rng.f64().max(f64::MIN_POSITIVE);
        let skip = (u.ln() / ln_q).floor() as u64;
        idx = match idx.checked_add(skip) {
            Some(v) => v,
            None => break,
        };
        if idx >= total {
            break;
        }
        let r = (idx / ncols as u64) as usize;
        let c = (idx % ncols as u64) as usize;
        coo.push(r, c, rng.range_f64(-1.0, 1.0));
        idx += 1;
        if idx >= total {
            break;
        }
    }
    Csr::from_coo(coo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_density() {
        let mut rng = Prng::new(1);
        let m = erdos_renyi(2000, 2000, 10.0, &mut rng);
        m.validate().unwrap();
        let avg = m.avg_row_len();
        assert!((avg - 10.0).abs() < 0.5, "avg row len {avg}");
    }

    #[test]
    fn rows_are_roughly_uniform() {
        let mut rng = Prng::new(2);
        let m = erdos_renyi(1000, 1000, 8.0, &mut rng);
        // no row should be wildly hub-like under ER
        assert!(m.max_row_len() < 30, "max {}", m.max_row_len());
    }

    #[test]
    fn zero_degree_gives_empty() {
        let mut rng = Prng::new(3);
        let m = erdos_renyi(100, 100, 0.0, &mut rng);
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = erdos_renyi(500, 500, 5.0, &mut Prng::new(42));
        let b = erdos_renyi(500, 500, 5.0, &mut Prng::new(42));
        assert_eq!(a, b);
    }

    #[test]
    fn dense_limit() {
        let mut rng = Prng::new(4);
        let m = erdos_renyi(8, 8, 8.0, &mut rng);
        assert_eq!(m.nnz(), 64);
    }
}
