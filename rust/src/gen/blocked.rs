//! Mesh generators with tile-major node ordering — the paper's
//! "Blocking" class (`road_usa`, `hugebubbles-00010`, `asia_osm`,
//! `333SP`).
//!
//! Road networks and FE meshes are near-planar graphs whose SuiteSparse
//! orderings cluster incident vertices, so the adjacency matrix falls
//! into dense-ish tiles. We reproduce that by generating a 2D mesh and
//! numbering vertices *tile-by-tile*: edges then connect indices inside
//! the same tile (intra-block nonzeros) or adjacent tiles (a thin
//! cross-block fringe) — exactly the structure CSB exploits.

use crate::gen::Prng;
use crate::sparse::{Coo, Csr};

/// Mesh connectivity kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshKind {
    /// 4-neighbour grid with random edge thinning — road-network-like
    /// (deg ≈ 2–3 after thinning).
    Road,
    /// 6-neighbour (triangulated) grid — FE-mesh-like (`333SP`,
    /// `hugebubbles`; deg ≈ 5–6 before thinning).
    Triangular,
    /// Path-dominant: 2-neighbour chain plus sparse shortcuts —
    /// `asia_osm`-like (deg ≈ 2.1).
    Path,
}

/// Generate a symmetric mesh adjacency matrix over a `side × side`
/// vertex grid (`n = side²`), with tile-major vertex numbering
/// (`tile = 16×16` vertices) and edge-keep probability `keep`.
///
/// Values are uniform in `[-1, 1)`, mirrored so the matrix is
/// numerically symmetric.
pub fn mesh2d(side: usize, kind: MeshKind, keep: f64, rng: &mut Prng) -> Csr {
    assert!(side >= 2);
    let n = side * side;
    const TILE: usize = 16;
    let tiles_per_side = side.div_ceil(TILE);
    // tile-major vertex id
    let vid = |x: usize, y: usize| -> usize {
        let (tx, ty) = (x / TILE, y / TILE);
        let tile_id = ty * tiles_per_side + tx;
        // tiles at the right/bottom edge are smaller
        let tw = TILE.min(side - tx * TILE);
        let (lx, ly) = (x % TILE, y % TILE);
        // base = number of vertices in all preceding tiles
        // Precomputing exactly is messy with ragged edge tiles; instead
        // use a uniform TILE*TILE stride and compact afterwards.
        let _ = tw;
        tile_id * TILE * TILE + ly * TILE + lx
    };
    // map padded ids -> dense 0..n ids
    let padded = tiles_per_side * tiles_per_side * TILE * TILE;
    let mut compact = vec![u32::MAX; padded];
    let mut next = 0u32;
    for ty in 0..side {
        for tx in 0..side {
            let p = vid(tx, ty);
            if compact[p] == u32::MAX {
                compact[p] = 0; // mark
            }
        }
    }
    // assign compact ids in padded order so tile-major order survives
    for slot in compact.iter_mut() {
        if *slot != u32::MAX {
            *slot = next;
            next += 1;
        }
    }
    debug_assert_eq!(next as usize, n);

    let id = |x: usize, y: usize| compact[vid(x, y)] as usize;

    let mut coo = Coo::with_capacity(n, n, (n as f64 * 6.0 * keep) as usize + 16);
    let mut add = |rng: &mut Prng, a: usize, b: usize| {
        let v = rng.range_f64(-1.0, 1.0);
        coo.push(a, b, v);
        coo.push(b, a, v);
    };
    for y in 0..side {
        for x in 0..side {
            let a = id(x, y);
            match kind {
                MeshKind::Road => {
                    if x + 1 < side && rng.bernoulli(keep) {
                        add(rng, a, id(x + 1, y));
                    }
                    if y + 1 < side && rng.bernoulli(keep) {
                        add(rng, a, id(x, y + 1));
                    }
                }
                MeshKind::Triangular => {
                    if x + 1 < side && rng.bernoulli(keep) {
                        add(rng, a, id(x + 1, y));
                    }
                    if y + 1 < side && rng.bernoulli(keep) {
                        add(rng, a, id(x, y + 1));
                    }
                    if x + 1 < side && y + 1 < side && rng.bernoulli(keep) {
                        add(rng, a, id(x + 1, y + 1));
                    }
                }
                MeshKind::Path => {
                    // serpentine chain through the grid + rare shortcuts
                    let next_in_chain = if y % 2 == 0 {
                        if x + 1 < side {
                            Some(id(x + 1, y))
                        } else if y + 1 < side {
                            Some(id(x, y + 1))
                        } else {
                            None
                        }
                    } else if x > 0 {
                        Some(id(x - 1, y))
                    } else if y + 1 < side {
                        Some(id(x, y + 1))
                    } else {
                        None
                    };
                    if let Some(b) = next_in_chain {
                        add(rng, a, b);
                    }
                    if y + 1 < side && rng.bernoulli(keep * 0.2) {
                        add(rng, a, id(x, y + 1));
                    }
                }
            }
        }
    }
    Csr::from_coo(coo.sorted_dedup())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn road_mesh_degree() {
        let mut rng = Prng::new(8);
        let m = mesh2d(64, MeshKind::Road, 0.6, &mut rng);
        m.validate().unwrap();
        assert_eq!(m.nrows, 64 * 64);
        // 2 undirected incident edge slots/vertex * keep * 2 directions
        let avg = m.avg_row_len();
        assert!(avg > 1.5 && avg < 3.2, "avg {avg}");
    }

    #[test]
    fn triangular_denser_than_road() {
        let mut rng = Prng::new(9);
        let road = mesh2d(48, MeshKind::Road, 0.8, &mut rng);
        let tri = mesh2d(48, MeshKind::Triangular, 0.8, &mut rng);
        assert!(tri.avg_row_len() > road.avg_row_len());
    }

    #[test]
    fn path_is_sparse_and_connected_ish() {
        let mut rng = Prng::new(10);
        let m = mesh2d(48, MeshKind::Path, 0.5, &mut rng);
        let avg = m.avg_row_len();
        assert!(avg > 1.5 && avg < 2.6, "avg {avg}");
    }

    #[test]
    fn symmetric() {
        let mut rng = Prng::new(11);
        let m = mesh2d(24, MeshKind::Triangular, 0.7, &mut rng);
        let d = m.to_dense();
        let n = m.nrows;
        for r in 0..n {
            for c in 0..n {
                assert_eq!(d[r * n + c], d[c * n + r]);
            }
        }
    }

    #[test]
    fn tile_ordering_concentrates_blocks() {
        // With tile-major ordering most edges should fall within a
        // 256-wide diagonal block span.
        let mut rng = Prng::new(12);
        let m = mesh2d(64, MeshKind::Road, 0.9, &mut rng);
        let t = 256usize;
        let mut intra = 0usize;
        for r in 0..m.nrows {
            for &c in m.row_cols(r) {
                if r / t == (c as usize) / t {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / m.nnz() as f64;
        assert!(frac > 0.6, "intra-block fraction {frac}");
    }
}
