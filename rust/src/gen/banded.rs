//! Banded / diagonal generators — the paper's "Diagonal" class
//! (`rajat31`, `ideal_diagonal_22`).

use crate::gen::Prng;
use crate::sparse::{Coo, Csr};

/// Exact diagonal pattern (the paper's `ideal_diagonal_22`): `n` rows,
/// one nonzero per row at column `r`, value 1.0.
pub fn ideal_diagonal(n: usize) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n);
    for r in 0..n {
        coo.push(r, r, 1.0);
    }
    Csr::from_coo(coo)
}

/// Banded matrix: the diagonal is always present; every off-diagonal
/// cell within `|i−j| ≤ bandwidth` is present with probability `fill`.
/// Expected nonzeros per row ≈ `1 + 2·bandwidth·fill` (edge rows
/// slightly fewer). Values uniform in `[-1, 1)`.
///
/// `rajat31` (circuit simulation, ~4.3 nnz/row clustered near the
/// diagonal) is proxied with `bandwidth = 8, fill ≈ 0.21`.
pub fn banded(n: usize, bandwidth: usize, fill: f64, rng: &mut Prng) -> Csr {
    assert!(n > 0);
    let expected = (n as f64 * (1.0 + 2.0 * bandwidth as f64 * fill)) as usize;
    let mut coo = Coo::with_capacity(n, n, expected + 16);
    for r in 0..n {
        let lo = r.saturating_sub(bandwidth);
        let hi = (r + bandwidth).min(n - 1);
        for c in lo..=hi {
            if c == r {
                coo.push(r, c, rng.range_f64(0.5, 1.5)); // keep the diagonal robustly nonzero
            } else if rng.bernoulli(fill) {
                coo.push(r, c, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    Csr::from_coo(coo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_diagonal_is_identity_pattern() {
        let m = ideal_diagonal(100);
        m.validate().unwrap();
        assert_eq!(m.nnz(), 100);
        for r in 0..100 {
            assert_eq!(m.row_cols(r), &[r as u32]);
        }
    }

    #[test]
    fn banded_within_band() {
        let mut rng = Prng::new(5);
        let bw = 4;
        let m = banded(200, bw, 0.5, &mut rng);
        m.validate().unwrap();
        for r in 0..200usize {
            for &c in m.row_cols(r) {
                assert!((r as i64 - c as i64).unsigned_abs() as usize <= bw);
            }
        }
    }

    #[test]
    fn banded_density_close_to_expected() {
        let mut rng = Prng::new(6);
        let m = banded(4000, 8, 0.25, &mut rng);
        let want = 1.0 + 2.0 * 8.0 * 0.25;
        assert!((m.avg_row_len() - want).abs() < 0.4, "avg {}", m.avg_row_len());
    }

    #[test]
    fn diagonal_always_present() {
        let mut rng = Prng::new(7);
        let m = banded(300, 2, 0.0, &mut rng);
        assert_eq!(m.nnz(), 300);
    }
}
