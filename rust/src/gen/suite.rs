//! The proxy dataset — a scaled reproduction of the paper's Table III.
//!
//! Every SuiteSparse matrix in the paper is replaced by a generated
//! proxy from the same structural class with matching nonzeros-per-row
//! and locality statistics (see DESIGN.md §2/§6 for the substitution
//! argument). `scale = 1.0` produces matrices large enough to exceed
//! on-chip caches on this machine while keeping single-core benchmark
//! runtimes tractable; `--scale` grows or shrinks everything.

use crate::gen::{banded, chung_lu, erdos_renyi, ideal_diagonal, mesh2d, ChungLuParams, MeshKind, Prng};
use crate::sparse::Csr;

/// The paper's four structural regimes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SparsityClass {
    Blocked,
    ScaleFree,
    Diagonal,
    Random,
}

impl std::fmt::Display for SparsityClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            SparsityClass::Blocked => "Blocking",
            SparsityClass::ScaleFree => "Scale-free",
            SparsityClass::Diagonal => "Diagonal",
            SparsityClass::Random => "Uniform Random",
        };
        write!(f, "{s}")
    }
}

/// A proxy-dataset entry: the paper matrix it stands in for plus the
/// recipe that generates the stand-in.
pub struct ProxyMatrix {
    /// Proxy name (paper name + `_p`, or `er_N_k` for the synthetic
    /// randoms, which the paper also generated).
    pub name: &'static str,
    /// Paper matrix this proxies.
    pub paper_name: &'static str,
    pub class: SparsityClass,
    /// Rows/nonzeros of the *paper's* matrix (Table III), for reports.
    pub paper_rows: usize,
    pub paper_nnz: usize,
    /// Generator (given global scale and seed).
    gen: fn(f64, u64) -> Csr,
}

impl ProxyMatrix {
    /// Generate the proxy at `scale` (1.0 = default size) with a fixed
    /// per-matrix seed, so every experiment sees identical matrices.
    pub fn generate(&self, scale: f64) -> Csr {
        (self.gen)(scale, seed_of(self.name))
    }
}

fn seed_of(name: &str) -> u64 {
    // FNV-1a over the name — stable across runs/platforms.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn scaled(base: usize, scale: f64) -> usize {
    ((base as f64 * scale) as usize).max(64)
}

fn scaled_side(base_side: usize, scale: f64) -> usize {
    ((base_side as f64 * scale.sqrt()) as usize).max(8)
}

/// The full 12-matrix proxy suite in Table III order.
pub fn proxy_suite() -> Vec<ProxyMatrix> {
    vec![
        ProxyMatrix {
            name: "road_usa_p",
            paper_name: "road_usa",
            class: SparsityClass::Blocked,
            paper_rows: 23_947_347,
            paper_nnz: 57_708_624,
            gen: |s, seed| mesh2d(scaled_side(512, s), MeshKind::Road, 0.62, &mut Prng::new(seed)),
        },
        ProxyMatrix {
            name: "hugebubbles_p",
            paper_name: "hugebubbles-00010",
            class: SparsityClass::Blocked,
            paper_rows: 19_458_087,
            paper_nnz: 58_359_528,
            gen: |s, seed| {
                mesh2d(scaled_side(512, s), MeshKind::Triangular, 0.50, &mut Prng::new(seed))
            },
        },
        ProxyMatrix {
            name: "asia_osm_p",
            paper_name: "asia_osm",
            class: SparsityClass::Blocked,
            paper_rows: 11_950_757,
            paper_nnz: 25_423_206,
            gen: |s, seed| mesh2d(scaled_side(448, s), MeshKind::Path, 0.5, &mut Prng::new(seed)),
        },
        ProxyMatrix {
            name: "333sp_p",
            paper_name: "333SP",
            class: SparsityClass::Blocked,
            paper_rows: 3_712_815,
            paper_nnz: 22_217_266,
            gen: |s, seed| {
                mesh2d(scaled_side(360, s), MeshKind::Triangular, 1.0, &mut Prng::new(seed))
            },
        },
        ProxyMatrix {
            name: "com_orkut_p",
            paper_name: "com-Orkut",
            class: SparsityClass::ScaleFree,
            paper_rows: 3_072_441,
            paper_nnz: 234_370_166,
            gen: |s, seed| {
                chung_lu(
                    ChungLuParams {
                        n: scaled(32_768, s),
                        alpha: 2.2,
                        avg_deg: 76.0,
                        k_min: 16.0,
                    },
                    &mut Prng::new(seed),
                )
            },
        },
        ProxyMatrix {
            name: "com_lj_p",
            paper_name: "com-LiveJournal",
            class: SparsityClass::ScaleFree,
            paper_rows: 3_997_962,
            paper_nnz: 69_362_378,
            gen: |s, seed| {
                chung_lu(
                    ChungLuParams { n: scaled(65_536, s), alpha: 2.3, avg_deg: 17.4, k_min: 4.0 },
                    &mut Prng::new(seed),
                )
            },
        },
        ProxyMatrix {
            name: "uk2002_p",
            paper_name: "uk-2002",
            class: SparsityClass::ScaleFree,
            paper_rows: 18_520_486,
            paper_nnz: 298_113_762,
            gen: |s, seed| {
                chung_lu(
                    ChungLuParams { n: scaled(98_304, s), alpha: 2.1, avg_deg: 16.1, k_min: 4.0 },
                    &mut Prng::new(seed),
                )
            },
        },
        ProxyMatrix {
            name: "rajat31_p",
            paper_name: "rajat31",
            class: SparsityClass::Diagonal,
            paper_rows: 4_690_002,
            paper_nnz: 20_316_253,
            gen: |s, seed| banded(scaled(262_144, s), 8, 0.21, &mut Prng::new(seed)),
        },
        ProxyMatrix {
            name: "ideal_diag_p",
            paper_name: "ideal_diagonal_22",
            class: SparsityClass::Diagonal,
            paper_rows: 4_194_304,
            paper_nnz: 4_194_304,
            gen: |s, _seed| ideal_diagonal(scaled(262_144, s)),
        },
        ProxyMatrix {
            name: "er_18_1",
            paper_name: "er_22_1",
            class: SparsityClass::Random,
            paper_rows: 4_194_304,
            paper_nnz: 4_194_304,
            gen: |s, seed| {
                let n = scaled(262_144, s);
                erdos_renyi(n, n, 1.0, &mut Prng::new(seed))
            },
        },
        ProxyMatrix {
            name: "er_18_10",
            paper_name: "er_22_10",
            class: SparsityClass::Random,
            paper_rows: 4_194_304,
            paper_nnz: 41_942_990,
            gen: |s, seed| {
                let n = scaled(131_072, s);
                erdos_renyi(n, n, 10.0, &mut Prng::new(seed))
            },
        },
        ProxyMatrix {
            name: "er_18_20",
            paper_name: "er_22_20",
            class: SparsityClass::Random,
            paper_rows: 4_194_304,
            paper_nnz: 83_885_880,
            gen: |s, seed| {
                let n = scaled(131_072, s);
                erdos_renyi(n, n, 20.0, &mut Prng::new(seed))
            },
        },
    ]
}

/// The four representative matrices of Fig. 1 / Fig. 2 (one per class):
/// er_22_1, rajat31, road_usa, com-LiveJournal — proxied.
pub fn representative_suite() -> Vec<ProxyMatrix> {
    proxy_suite()
        .into_iter()
        .filter(|m| matches!(m.name, "er_18_1" | "rajat31_p" | "road_usa_p" | "com_lj_p"))
        .collect()
}

/// Find one entry by proxy name.
pub fn find(name: &str) -> Option<ProxyMatrix> {
    proxy_suite().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_twelve_entries_in_four_classes() {
        let s = proxy_suite();
        assert_eq!(s.len(), 12);
        for class in [
            SparsityClass::Blocked,
            SparsityClass::ScaleFree,
            SparsityClass::Diagonal,
            SparsityClass::Random,
        ] {
            assert!(s.iter().any(|m| m.class == class));
        }
    }

    #[test]
    fn representative_has_one_per_class() {
        let s = representative_suite();
        assert_eq!(s.len(), 4);
        let mut classes: Vec<_> = s.iter().map(|m| m.class).collect();
        classes.dedup();
        assert_eq!(classes.len(), 4);
    }

    #[test]
    fn tiny_scale_generates_valid_matrices() {
        for m in proxy_suite() {
            let csr = m.generate(0.02);
            csr.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
            assert!(csr.nnz() > 0, "{} empty", m.name);
        }
    }

    #[test]
    fn density_tracks_paper() {
        // nnz/row of each proxy should be within 2x of the paper's
        for m in proxy_suite() {
            let csr = m.generate(0.05);
            let got = csr.avg_row_len();
            let want = m.paper_nnz as f64 / m.paper_rows as f64;
            assert!(
                got > want * 0.45 && got < want * 2.2,
                "{}: proxy {got:.2} vs paper {want:.2}",
                m.name
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let m = find("er_18_10").unwrap();
        assert_eq!(m.generate(0.02), m.generate(0.02));
    }
}
