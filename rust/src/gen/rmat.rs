//! R-MAT recursive matrix generator (Chakrabarti et al.) — an
//! alternative skewed generator used by the ablation studies to check
//! that the scale-free model's conclusions are not an artifact of the
//! Chung–Lu construction.

use crate::gen::Prng;
use crate::sparse::{Coo, Csr};

/// Generate a `2^scale × 2^scale` R-MAT matrix with `avg_deg · 2^scale`
/// sampled edges and quadrant probabilities `(a, b, c)` (d = 1−a−b−c).
/// The classic skewed setting is `(0.57, 0.19, 0.19)`.
pub fn rmat(scale: u32, avg_deg: f64, a: f64, b: f64, c: f64, rng: &mut Prng) -> Csr {
    assert!(a + b + c < 1.0 + 1e-9, "quadrant probabilities exceed 1");
    let n = 1usize << scale;
    let edges = (n as f64 * avg_deg) as usize;
    let mut coo = Coo::with_capacity(n, n, edges);
    for _ in 0..edges {
        let (mut r, mut col) = (0usize, 0usize);
        for level in (0..scale).rev() {
            let u = rng.f64();
            let bit = 1usize << level;
            if u < a {
                // top-left: nothing
            } else if u < a + b {
                col |= bit;
            } else if u < a + b + c {
                r |= bit;
            } else {
                r |= bit;
                col |= bit;
            }
        }
        coo.push(r, col, rng.range_f64(-1.0, 1.0));
    }
    Csr::from_coo(coo.sorted_dedup())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_density() {
        let mut rng = Prng::new(31);
        let m = rmat(10, 8.0, 0.57, 0.19, 0.19, &mut rng);
        m.validate().unwrap();
        assert_eq!(m.nrows, 1024);
        // dedup collapses duplicates, so avg ≤ 8 but within reason
        assert!(m.avg_row_len() > 4.0 && m.avg_row_len() <= 8.0);
    }

    #[test]
    fn skew_produces_hubs() {
        let mut rng = Prng::new(32);
        let m = rmat(12, 8.0, 0.57, 0.19, 0.19, &mut rng);
        assert!(m.max_row_len() > 8 * (m.avg_row_len() as usize).max(1));
    }

    #[test]
    fn uniform_quadrants_are_er_like() {
        let mut rng = Prng::new(33);
        let m = rmat(10, 8.0, 0.25, 0.25, 0.25, &mut rng);
        assert!(m.max_row_len() < 28, "max {}", m.max_row_len());
    }
}
