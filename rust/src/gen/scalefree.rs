//! Scale-free generator (Chung–Lu model) — the paper's "Scale-free"
//! class (`com-Orkut`, `com-LiveJournal`, `uk-2002`).
//!
//! Degrees are drawn from a power law `p(k) ∝ k^{-α}` (the paper
//! assumes `2 < α < 3`); edges are then placed with probability
//! proportional to the endpoint weights, sampled through an alias
//! table so generation is O(nnz).

use crate::gen::Prng;
use crate::sparse::{Coo, Csr};

/// Parameters for [`chung_lu`].
#[derive(Debug, Clone, Copy)]
pub struct ChungLuParams {
    /// Number of vertices.
    pub n: usize,
    /// Power-law exponent `α` (the paper's real-world range is 2–3).
    pub alpha: f64,
    /// Target average degree (average nonzeros per row of the
    /// symmetrized adjacency matrix).
    pub avg_deg: f64,
    /// Minimum degree for the power law (`k_min` in the appendix).
    pub k_min: f64,
}

impl Default for ChungLuParams {
    fn default() -> Self {
        ChungLuParams { n: 1 << 14, alpha: 2.3, avg_deg: 16.0, k_min: 2.0 }
    }
}

/// Walker alias table for O(1) sampling from a discrete distribution.
pub(crate) struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    pub(crate) fn new(weights: &[f64]) -> AliasTable {
        let n = weights.len();
        let sum: f64 = weights.iter().sum();
        assert!(sum > 0.0 && n > 0);
        let scale = n as f64 / sum;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s as usize] = l;
            prob[l as usize] = (prob[l as usize] + prob[s as usize]) - 1.0;
            if prob[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // leftovers are numerically 1.0
        for i in small.into_iter().chain(large) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    #[inline]
    pub(crate) fn sample(&self, rng: &mut Prng) -> usize {
        let i = rng.below_usize(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

/// Generate a symmetric Chung–Lu scale-free adjacency matrix.
///
/// Weights `w_i` are power-law samples rescaled so the expected average
/// degree matches `params.avg_deg`; each directed stub picks its
/// endpoint from the weight distribution via the alias table, then the
/// matrix is symmetrized and deduplicated (so the realized average
/// degree lands slightly below target on dense hubs — matching real
/// graphs, where multi-edges collapse).
pub fn chung_lu(params: ChungLuParams, rng: &mut Prng) -> Csr {
    let ChungLuParams { n, alpha, avg_deg, k_min } = params;
    assert!(n > 1 && alpha > 1.0 && avg_deg > 0.0);
    // draw power-law weights, capped at ~sqrt(n * avg_deg) (the
    // Chung-Lu validity bound: w_i w_j / S must stay ≤ 1)
    let cap = ((n as f64 * avg_deg).sqrt() * 2.0).max(k_min * 4.0);
    let mut w: Vec<f64> = (0..n).map(|_| rng.power_law(alpha, k_min).min(cap)).collect();
    let sum_w: f64 = w.iter().sum();
    // rescale so total stub count hits the target nnz
    let target_stubs = (n as f64 * avg_deg) / 2.0; // undirected edges
    let scale = (2.0 * target_stubs) / sum_w;
    for wi in w.iter_mut() {
        *wi *= scale;
    }

    let table = AliasTable::new(&w);
    let m_edges = target_stubs as usize;
    let mut coo = Coo::with_capacity(n, n, m_edges * 2 + 16);
    for _ in 0..m_edges {
        let a = table.sample(rng);
        let b = table.sample(rng);
        if a == b {
            continue;
        }
        let v = rng.range_f64(-1.0, 1.0);
        coo.push(a, b, v);
        coo.push(b, a, v);
    }
    // Dedup keeps first occurrence semantics via summation; for an
    // adjacency-like matrix we re-normalize duplicate sums to a single
    // weight by regenerating values after dedup.
    let mut csr = Csr::from_coo(coo.sorted_dedup());
    for v in csr.vals.iter_mut() {
        // collapse summed duplicates back into [-1,1) deterministically
        if !(-1.0..1.0).contains(v) {
            *v = v.rem_euclid(2.0) - 1.0;
        }
    }
    csr
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alias_table_distribution() {
        let mut rng = Prng::new(21);
        let t = AliasTable::new(&[1.0, 2.0, 7.0]);
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            counts[t.sample(&mut rng)] += 1;
        }
        let f2 = counts[2] as f64 / n as f64;
        assert!((f2 - 0.7).abs() < 0.02, "f2={f2}");
        let f0 = counts[0] as f64 / n as f64;
        assert!((f0 - 0.1).abs() < 0.02, "f0={f0}");
    }

    #[test]
    fn chung_lu_degree_and_hubs() {
        let mut rng = Prng::new(22);
        let m = chung_lu(ChungLuParams { n: 4000, alpha: 2.2, avg_deg: 12.0, k_min: 2.0 }, &mut rng);
        m.validate().unwrap();
        let avg = m.avg_row_len();
        assert!(avg > 6.0 && avg < 13.0, "avg {avg}");
        // hubs exist: max degree far above average
        assert!(m.max_row_len() as f64 > 6.0 * avg, "max {}", m.max_row_len());
    }

    #[test]
    fn chung_lu_symmetric_pattern() {
        let mut rng = Prng::new(23);
        let m = chung_lu(ChungLuParams { n: 300, alpha: 2.5, avg_deg: 6.0, k_min: 1.5 }, &mut rng);
        let d = m.to_dense();
        for r in 0..300 {
            for c in 0..300 {
                assert_eq!(d[r * 300 + c] != 0.0, d[c * 300 + r] != 0.0);
            }
        }
    }

    #[test]
    fn values_in_range() {
        let mut rng = Prng::new(24);
        let m = chung_lu(ChungLuParams { n: 1000, alpha: 2.1, avg_deg: 10.0, k_min: 2.0 }, &mut rng);
        assert!(m.vals.iter().all(|v| (-1.0..1.0).contains(v)));
    }
}
