//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** generation.
//!
//! The `rand` crate is unavailable offline, and determinism across runs
//! matters for reproducible experiments, so we carry our own small
//! generator (public-domain algorithms by Vigna & Blackman).

/// SplitMix64 step — used to expand a single `u64` seed into the
/// xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** pseudo-random generator. Deterministic, splittable via
/// [`Prng::fork`], and fast enough to drive matrix generation at the
/// tens-of-millions-of-nonzeros scale.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // xoshiro must not start from the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Prng { s }
    }

    /// Derive an independent child stream (used to give each generated
    /// row / thread its own stream without long jumps).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// rejection method. `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound {
                return (m >> 64) as u64;
            }
            // rejection zone: low < bound && low < (2^64 mod bound)
            let threshold = bound.wrapping_neg() % bound;
            if low >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (one sample per call; simple and
    /// adequate here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Geometric-like skip sampling helper: Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample from a discrete power-law `P(k) ∝ k^{-alpha}` for
    /// `k ≥ kmin` via continuous inverse-CDF + rounding (Clauset et al.
    /// Appendix D approximation).
    pub fn power_law(&mut self, alpha: f64, kmin: f64) -> f64 {
        debug_assert!(alpha > 1.0);
        let u = 1.0 - self.f64(); // (0,1]
        kmin * u.powf(-1.0 / (alpha - 1.0))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut p = Prng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = p.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut p = Prng::new(3);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| p.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn power_law_tail_heavier_for_smaller_alpha() {
        let mut p = Prng::new(9);
        let big = |alpha: f64, p: &mut Prng| {
            (0..20_000).map(|_| p.power_law(alpha, 1.0)).filter(|&k| k > 100.0).count()
        };
        let heavy = big(2.1, &mut p);
        let light = big(2.9, &mut p);
        assert!(heavy > light, "heavy={heavy} light={light}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(5);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }
}
