//! Corpus harness (EXPERIMENTS row CO): ingest a directory tree of
//! MatrixMarket files, classify each matrix, route it through the
//! autotuner, and report **per structure group** — the paper's
//! group-by-structure evaluation (its SuiteSparse tables) on real
//! matrices instead of the synthetic generators.
//!
//! Ingestion runs through the streaming reader
//! ([`crate::sparse::mm_io::read_csr_streaming`]); when the corpus
//! directory is absent or holds no `.mtx` files, a stand-in corpus is
//! synthesized from the proxy suite ([`crate::gen::representative_suite`])
//! so the harness (and the CI smoke job) always has something
//! structurally diverse to chew on. Each matrix also gets an
//! out-of-core band plan under the configured byte budget
//! ([`crate::sparse::mm_io::plan_row_bands`]) and the band-pass model
//! AI ([`crate::model::ai_ooc`], MODELS.md §9), so the report shows
//! what executing it under that residency budget would cost.
//!
//! Artifact: `BENCH_corpus.json` via the shared merge-on-save perf log
//! ([`crate::report::PerfLog::merge_save`]) — one record per routed
//! `(matrix, d)`, class = structure group.

use std::path::{Path, PathBuf};

use crate::coordinator::{AutotunePolicy, Engine, EngineConfig, JobSpec};
use crate::error::{Error, Result};
use crate::model::{ai_ooc, AiParams, MachineParams};
use crate::report::{PerfLog, PerfRecord, Table};
use crate::sparse::mm_io::{self, plan_row_bands};
use crate::sparse::Csr;
use crate::spmm::Impl;

/// Default out-of-core band budget for corpus planning: 64 MiB, the
/// same order as the PB kernel's spill arena bound.
pub const CORPUS_DEFAULT_BUDGET: usize = 1 << 26;

/// Knobs for one corpus run. `dir = None` (or an empty/absent tree)
/// synthesizes the proxy corpus at `scale`.
pub struct CorpusConfig {
    pub dir: Option<PathBuf>,
    pub scale: f64,
    pub threads: usize,
    pub iters: usize,
    pub warmup: usize,
    pub d_values: Vec<usize>,
    /// Nominal machine override (`REPRO_FAST` / tests); `None` runs
    /// STREAM calibration.
    pub machine: Option<MachineParams>,
    /// Out-of-core band byte budget used for the plan/model columns.
    pub ooc_budget: usize,
}

impl Default for CorpusConfig {
    fn default() -> CorpusConfig {
        CorpusConfig {
            dir: None,
            scale: 0.05,
            threads: 1,
            iters: 2,
            warmup: 1,
            d_values: vec![8],
            machine: None,
            ooc_budget: CORPUS_DEFAULT_BUDGET,
        }
    }
}

/// One ingested matrix: structural facts + its out-of-core plan.
pub struct CorpusMatrix {
    pub name: String,
    /// Source path; `None` for synthesized matrices.
    pub path: Option<PathBuf>,
    pub class: String,
    pub class_summary: String,
    pub nrows: usize,
    pub nnz: usize,
    /// Bands the byte budget would split this matrix into.
    pub n_bands: usize,
    /// In-memory model AI at the first configured `d`.
    pub ai_mem: f64,
    /// Band-pass model AI at the same `d` ([`crate::model::ai_ooc`]).
    pub ai_banded: f64,
}

/// One routed `(matrix, d)` cell from the pinned pass.
pub struct CorpusRow {
    pub matrix: String,
    pub class: String,
    pub impl_name: String,
    pub reorder: String,
    pub d: usize,
    pub dt: usize,
    pub ai: f64,
    pub predicted_gflops: f64,
    pub measured_gflops: f64,
}

/// Aggregates over one structure group.
pub struct GroupRow {
    pub class: String,
    pub matrices: usize,
    pub jobs: usize,
    pub geomean_gflops: f64,
    /// Geometric mean of measured/predicted (1.0 = perfect model).
    pub geomean_pred_ratio: f64,
}

/// Everything one corpus run produced.
pub struct CorpusReport {
    /// True when no `.mtx` corpus was found and the proxy suite stood
    /// in.
    pub synthesized: bool,
    pub matrices: Vec<CorpusMatrix>,
    pub rows: Vec<CorpusRow>,
    pub groups: Vec<GroupRow>,
    /// Explore measurements in the pinned (second) pass — 0 proves the
    /// router pinned every decision during tuning.
    pub pinned_explores: usize,
}

fn walk_mtx(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.map(|e| Ok(e?.path())).collect::<Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_mtx(&p, out)?;
        } else if p.extension().map(|e| e == "mtx").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Collect every `.mtx` under `dir` (recursive, sorted for
/// deterministic job order) and parse each through the streaming
/// reader. A malformed file is a typed error naming the file — a
/// corpus run must not die with a panic halfway through a directory.
pub fn ingest_dir(dir: &Path) -> Result<Vec<(String, PathBuf, Csr)>> {
    let mut paths = Vec::new();
    walk_mtx(dir, &mut paths)?;
    let mut out = Vec::with_capacity(paths.len());
    for p in paths {
        let name = p
            .file_stem()
            .and_then(|s| s.to_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| p.display().to_string());
        let csr = mm_io::read_csr_streaming(&p)
            .map_err(|e| Error::Parse(format!("{}: {e}", p.display())))?;
        out.push((name, p, csr));
    }
    Ok(out)
}

/// Write the proxy suite as a small `.mtx` tree under `dir`, one
/// subdirectory per structure group (`dir/<class>/<name>.mtx`) — what
/// the CI corpus smoke job ingests. Returns the written paths.
pub fn synthesize_corpus(dir: &Path, scale: f64) -> Result<Vec<PathBuf>> {
    let mut written = Vec::new();
    for proxy in crate::gen::representative_suite() {
        let sub = dir.join(proxy.class.to_string().replace(' ', "_").to_lowercase());
        std::fs::create_dir_all(&sub)?;
        let path = sub.join(format!("{}.mtx", proxy.name));
        mm_io::write_csr(&path, &proxy.generate(scale))?;
        written.push(path);
    }
    Ok(written)
}

fn geomean(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for x in xs {
        if x > 0.0 && x.is_finite() {
            sum += x.ln();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).exp()
    }
}

/// Run the corpus: ingest (or synthesize), classify + register, one
/// tuning batch (explores candidates), one pinned batch (the reported
/// rows), then group by structure.
pub fn run_corpus(cfg: &CorpusConfig) -> Result<CorpusReport> {
    if cfg.d_values.is_empty() {
        return Err(Error::Usage("corpus needs at least one d value".into()));
    }
    let mut synthesized = false;
    let mats: Vec<(String, Option<PathBuf>, Csr)> = match &cfg.dir {
        Some(dir) if dir.is_dir() => {
            let found = ingest_dir(dir)?;
            if found.is_empty() {
                synthesized = true;
                synth_mats(cfg.scale)
            } else {
                found.into_iter().map(|(n, p, c)| (n, Some(p), c)).collect()
            }
        }
        _ => {
            synthesized = true;
            synth_mats(cfg.scale)
        }
    };

    let mut engine = Engine::new(EngineConfig {
        threads: cfg.threads,
        machine: cfg.machine,
        iters: cfg.iters,
        warmup: cfg.warmup,
        // the paper trio: ELL/BSR preparation is O(n·max_row_degree)
        // and a hub row in an untrusted corpus matrix would blow it up
        impls: vec![Impl::Csr, Impl::Opt, Impl::Csb],
        artifacts_dir: None,
        autotune: AutotunePolicy::enabled(),
    })?;

    let d0 = cfg.d_values[0];
    let mut matrices = Vec::with_capacity(mats.len());
    let mut names = Vec::with_capacity(mats.len());
    for (name, path, csr) in mats {
        let n_bands = plan_row_bands(&csr.row_ptr, cfg.ooc_budget).len().saturating_sub(1);
        let p = AiParams { n: csr.nrows, d: d0, nnz: csr.nnz() };
        engine.register(&name, csr)?;
        let entry = engine
            .registry()
            .get(&name)
            .ok_or_else(|| Error::InvalidStructure(format!("{name} vanished from registry")))?;
        let model = entry.classification.model;
        matrices.push(CorpusMatrix {
            name: name.clone(),
            path,
            class: entry.classification.class.to_string(),
            class_summary: entry.classification.summary(),
            nrows: p.n,
            nnz: p.nnz,
            n_bands,
            ai_mem: ai_ooc(&model, p, 1),
            ai_banded: ai_ooc(&model, p, n_bands),
        });
        names.push(name);
    }

    let jobs: Vec<JobSpec> = names
        .iter()
        .flat_map(|n| cfg.d_values.iter().map(|&d| JobSpec::new(n.clone(), d)))
        .collect();

    // pass 1 explores impl × reordering candidates and pins winners
    engine.submit_batch(&jobs)?;
    // pass 2 serves the pinned decisions — these are the report rows
    let h0 = engine.history().len();
    let pinned = engine.submit_batch(&jobs)?;
    let rows: Vec<CorpusRow> = engine.history()[h0..]
        .iter()
        .map(|r| CorpusRow {
            matrix: r.matrix.clone(),
            class: r.class.to_string(),
            impl_name: r.chosen.to_string(),
            reorder: r.reorder.to_string(),
            d: r.d,
            dt: r.dt.min(r.d),
            ai: r.ai,
            predicted_gflops: r.predicted_gflops,
            measured_gflops: r.measured_gflops,
        })
        .collect();

    // group by structure class, in first-seen order
    let mut groups: Vec<GroupRow> = Vec::new();
    let mut classes: Vec<String> = Vec::new();
    for r in &rows {
        if !classes.contains(&r.class) {
            classes.push(r.class.clone());
        }
    }
    for class in classes {
        let in_group: Vec<&CorpusRow> = rows.iter().filter(|r| r.class == class).collect();
        let mut mats_in: Vec<&str> = in_group.iter().map(|r| r.matrix.as_str()).collect();
        mats_in.dedup();
        groups.push(GroupRow {
            class,
            matrices: mats_in.len(),
            jobs: in_group.len(),
            geomean_gflops: geomean(in_group.iter().map(|r| r.measured_gflops)),
            geomean_pred_ratio: geomean(in_group.iter().map(|r| {
                if r.predicted_gflops > 0.0 {
                    r.measured_gflops / r.predicted_gflops
                } else {
                    0.0
                }
            })),
        });
    }

    Ok(CorpusReport {
        synthesized,
        matrices,
        rows,
        groups,
        pinned_explores: pinned.explore_measurements,
    })
}

fn synth_mats(scale: f64) -> Vec<(String, Option<PathBuf>, Csr)> {
    crate::gen::representative_suite()
        .into_iter()
        .map(|p| (p.name.to_string(), None, p.generate(scale)))
        .collect()
}

impl CorpusReport {
    /// The ingest table: one line per matrix with its structure group
    /// and out-of-core plan.
    pub fn matrix_table(&self) -> Table {
        let mut t = Table::new(
            "corpus — ingested matrices and band plans",
            &["Matrix", "Group", "Rows", "Nnz", "Bands", "AI mem", "AI banded"],
        );
        for m in &self.matrices {
            t.row(vec![
                m.name.clone(),
                m.class.clone(),
                m.nrows.to_string(),
                m.nnz.to_string(),
                m.n_bands.to_string(),
                format!("{:.3}", m.ai_mem),
                format!("{:.3}", m.ai_banded),
            ]);
        }
        t
    }

    /// The per-structure-group aggregate table — the paper's
    /// group-by-structure view.
    pub fn group_table(&self) -> Table {
        let mut t = Table::new(
            "corpus — per structure group (pinned routing)",
            &["Group", "Matrices", "Jobs", "geomean GF/s", "geomean meas/pred"],
        );
        for g in &self.groups {
            t.row(vec![
                g.class.clone(),
                g.matrices.to_string(),
                g.jobs.to_string(),
                format!("{:.2}", g.geomean_gflops),
                format!("{:.2}", g.geomean_pred_ratio),
            ]);
        }
        t
    }

    /// Flat perf records (bench = `bench_corpus`) for the artifact.
    pub fn perf_records(&self) -> Vec<PerfRecord> {
        self.rows
            .iter()
            .map(|r| PerfRecord {
                reorder: r.reorder.clone(),
                predicted_gflops: r.predicted_gflops,
                ..PerfRecord::basic(
                    "bench_corpus",
                    r.matrix.clone(),
                    r.class.clone(),
                    r.impl_name.clone(),
                    r.d,
                    r.dt,
                    r.measured_gflops,
                )
            })
            .collect()
    }

    /// Merge the records into `path` (replacing only `bench_corpus`
    /// records — other benches' latest numbers survive).
    pub fn save(&self, path: &str) -> Result<()> {
        let mut log = PerfLog::new();
        for rec in self.perf_records() {
            log.push(rec);
        }
        log.merge_save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("spmm_roofline_corpus_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn synthesize_then_ingest_round_trips() {
        let dir = tmp_dir("roundtrip");
        let written = synthesize_corpus(&dir, 0.02).unwrap();
        assert_eq!(written.len(), crate::gen::representative_suite().len());
        // one subdirectory per structure group
        assert!(written.iter().all(|p| p.parent().unwrap() != dir));
        let got = ingest_dir(&dir).unwrap();
        assert_eq!(got.len(), written.len());
        for (name, _, csr) in &got {
            let proxy = crate::gen::suite::find(name).expect("ingested name is a proxy");
            let want = proxy.generate(0.02);
            assert_eq!(csr.nrows, want.nrows, "{name}");
            assert_eq!(csr.vals, want.vals, "{name}: write→stream-read must be bitwise");
        }
    }

    #[test]
    fn ingest_reports_malformed_files_by_name() {
        let dir = tmp_dir("malformed");
        std::fs::write(dir.join("bad.mtx"), "%%MatrixMarket matrix coordinate real general\n2 2 5\n1 1 1.0\n").unwrap();
        let err = ingest_dir(&dir).unwrap_err();
        assert!(err.to_string().contains("bad.mtx"), "error names the file: {err}");
    }

    #[test]
    fn run_corpus_synthesizes_when_dir_missing() {
        let cfg = CorpusConfig {
            dir: Some(tmp_dir("empty")),
            scale: 0.015,
            machine: Some(MachineParams { beta_gbs: 25.0, pi_gflops: 100.0 }),
            iters: 1,
            warmup: 0,
            d_values: vec![4],
            ..CorpusConfig::default()
        };
        let rep = run_corpus(&cfg).unwrap();
        assert!(rep.synthesized);
        assert_eq!(rep.rows.len(), rep.matrices.len());
        assert_eq!(rep.pinned_explores, 0, "second pass must serve pins only");
        assert!(!rep.groups.is_empty());
        let total: usize = rep.groups.iter().map(|g| g.jobs).sum();
        assert_eq!(total, rep.rows.len());
    }
}
